//! Calibration sweep over one shared network build: scans the (η, g)
//! plane of the hpc_benchmark verification network — the grid
//! `examples/calibrate.rs` used to rebuild from scratch per point —
//! but through the [`Ensemble`] API, so every point is a cheap
//! state-only trajectory over the same immutable rank stores. (Here
//! η and g change the network itself, so the sweep axes are the drive
//! seed and a DC offset; the η/g scan keeps one (η, g) per ensemble.)
//!
//! Usage: cargo run --example sweep_grid [n_neurons] [indegree]
//!
//! [`Ensemble`]: cortex::engine::Ensemble

use std::sync::Arc;
use std::time::Instant;

use cortex::atlas::hpc::{hpc_benchmark_spec, HpcParams};
use cortex::engine::Ensemble;
use cortex::metrics::Table;
use cortex::probe::PopRates;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let n: usize =
        args.first().map(|s| s.parse().unwrap()).unwrap_or(1000);
    let k: u32 =
        args.get(1).map(|s| s.parse().unwrap()).unwrap_or(100);
    let steps = 3000u64; // 300 ms at 0.1 ms

    let spec = Arc::new(hpc_benchmark_spec(
        &HpcParams {
            n_neurons: n,
            indegree: k,
            eta: 0.7,
            g: 6.0,
            plastic: false,
            ..Default::default()
        },
        1,
    ));
    let t0 = Instant::now();
    let ens = Ensemble::builder(Arc::clone(&spec))
        .ranks(1)
        .threads(2)
        .build()?;
    println!(
        "built once in {:.3}s — sweeping {} trajectories over it",
        ens.build_seconds(),
        4 * 3
    );

    let mut table = Table::new(
        "hpc_benchmark sweep (300 ms, one shared build)",
        &["drive_seed", "dc_pa", "rate_hz", "verdict"],
    );
    for drive_seed in [1u64, 2, 3, 4] {
        for dc_pa in [0.0, 50.0, 100.0] {
            let mut sim = ens
                .trajectory()
                .drive_seed(drive_seed)
                .dc("E", dc_pa)
                .probe(PopRates::new("rates", steps))
                .build()?;
            sim.run_for(steps)?;
            let _ = sim.drain("rates")?;
            let out = sim.finish()?;
            let rate = out.total_spikes as f64
                / spec.n_total() as f64
                / 0.3;
            let verdict = if rate > 0.05 && rate < 10.0 {
                "PASS"
            } else {
                "-"
            };
            table.row(&[
                format!("{drive_seed}"),
                format!("{dc_pa}"),
                format!("{rate:.2}"),
                verdict.into(),
            ]);
        }
    }
    println!("{}", table.render());
    println!(
        "total wall {:.3}s (standalone would pay the build 12 times)",
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}
