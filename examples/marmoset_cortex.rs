//! End-to-end evaluation driver — the paper's §IV.B workload on this
//! testbed: a multi-area marmoset-like cortical network simulated by the
//! full CORTEX stack (atlas → area-processes mapping → multisection →
//! per-rank indegree stores → mutex-free threads → windowed overlap
//! exchange), with the headline quantities of Fig 18 (per-rank memory,
//! wall time per simulated second) and Fig 19 (raster of area "V1")
//! reported and written to `target/bench_out/`.
//!
//! Run: `cargo run --release --example marmoset_cortex [n_neurons]`
//! (default 20 000 neurons, ~5M synapses, 4 ranks × 3 threads, 200 ms)

use std::path::Path;
use std::sync::Arc;

use cortex::atlas::marmoset::{marmoset_spec, MarmosetParams};
use cortex::comm::TofuModel;
use cortex::config::{
    BuildMode, CommMode, DynamicsBackend, ExecMode, IntegrateMode,
    MappingKind, RoutingMode,
};
use cortex::engine::{run_simulation, RunConfig};
use cortex::metrics::table::{human_bytes, write_csv};
use cortex::metrics::Table;

fn main() -> anyhow::Result<()> {
    let n: usize = std::env::args()
        .nth(1)
        .map(|s| s.parse().expect("n_neurons"))
        .unwrap_or(20_000);
    let params = MarmosetParams {
        n_neurons: n,
        n_areas: 8,
        indegree: 250,
        ..Default::default()
    };
    let spec = Arc::new(marmoset_spec(&params, 20240710));
    println!(
        "marmoset atlas: {} neurons / {} synapses / {} areas",
        spec.n_total(),
        spec.n_edges(),
        spec.n_areas()
    );

    let sim_ms = 200.0;
    let steps = (sim_ms / spec.dt_ms) as u64;
    let cfg = RunConfig {
        ranks: 4,
        threads: 3,
        mapping: MappingKind::AreaProcesses,
        comm: CommMode::Overlap,
        backend: DynamicsBackend::Native,
        exec: ExecMode::Pool,
        build: BuildMode::TwoPass,
        integrate: IntegrateMode::Vector,
        routing: RoutingMode::Routed,
        comm_group: Vec::new(),
        steps,
        record_limit: Some(u32::MAX),
        verify_ownership: false,
        artifacts_dir: "artifacts".into(),
        seed: 20240710,
    };
    let out = run_simulation(&spec, &cfg)?;

    // -- headline metrics -------------------------------------------------
    let sim_s = sim_ms * 1e-3;
    let rate = out.total_spikes as f64 / spec.n_total() as f64 / sim_s;
    let slowdown = out.wall_seconds / sim_s;
    println!(
        "\nsimulated {sim_ms} ms in {:.2}s wall ({slowdown:.0}x real time) \
         on {} ranks x {} threads",
        out.wall_seconds, cfg.ranks, cfg.threads
    );
    println!(
        "activity : {} spikes, mean rate {rate:.2} Hz",
        out.total_spikes
    );
    println!(
        "memory   : max-rank {} (imbalance {:.2}), {} synapses/rank avg",
        human_bytes(out.memory.max_rank_bytes()),
        out.memory.imbalance(),
        spec.n_edges() / cfg.ranks as u64
    );
    println!(
        "comm     : {} payload over {} windows",
        human_bytes(out.comm_bytes),
        out.windows
    );
    print!("{}", out.timer_max.report());

    // Fugaku-scale projection of the same spike traffic (Tofu-D model)
    let tofu = TofuModel::default();
    let bytes_per_rank_window =
        out.comm_bytes as f64 / cfg.ranks as f64 / out.windows as f64;
    let projected = tofu.total_comm_seconds(
        1536, // the paper's largest NEST-comparison config (384 nodes)
        out.windows,
        bytes_per_rank_window,
    );
    println!(
        "tofu-d projection: this spike traffic on 1536 Fugaku ranks \
         ≈ {projected:.3}s communication"
    );

    // -- per-area activity table + V1 raster (Fig 19 artifacts) ----------
    let mut table = Table::new(
        "per-area activity",
        &["area", "neurons", "rate_hz", "isi_cv"],
    );
    let sim_steps = steps;
    for a in 0..spec.n_areas() as u16 {
        let gids: Vec<(u32, u32)> = spec
            .populations
            .iter()
            .filter(|p| p.area == a)
            .map(|p| (p.first_gid, p.first_gid + p.n))
            .collect();
        let in_area = |g: u32| gids.iter().any(|&(lo, hi)| g >= lo && g < hi);
        let n_area: u32 = gids.iter().map(|&(lo, hi)| hi - lo).sum();
        let events: Vec<(u64, u32)> = out
            .raster
            .events
            .iter()
            .filter(|&&(_, g)| in_area(g))
            .copied()
            .collect();
        let mut sub = cortex::metrics::SpikeRecorder::new(u32::MAX);
        sub.events = events;
        let first = gids[0].0;
        // shift gids so stats index from 0
        for e in &mut sub.events {
            e.1 -= first;
        }
        let st = sub.stats(n_area as usize, spec.dt_ms, sim_steps);
        table.row(&[
            format!("A{a:02}"),
            n_area.to_string(),
            format!("{:.2}", st.mean_rate_hz),
            format!("{:.2}", st.mean_isi_cv),
        ]);
    }
    let out_dir = Path::new("target/bench_out");
    table.emit(out_dir, "marmoset_area_rates")?;

    // V1 = area 0 raster, first 1000 neurons (the Fig 19 plot data)
    let v1_limit = 1000u32;
    let mut v1 = String::from("time_ms,gid\n");
    for &(t, g) in &out.raster.events {
        if g < v1_limit {
            v1.push_str(&format!("{},{g}\n", t as f64 * spec.dt_ms));
        }
    }
    write_csv(out_dir, "marmoset_v1_raster", &v1)?;
    println!("wrote target/bench_out/marmoset_v1_raster.csv (Fig 19 data)");
    Ok(())
}
