//! Mid-run control of a live microcircuit: probes, stimulus steering,
//! checkpoint/restore — the session API end to end.
//!
//! Opens a persistent session over the downscaled Potjans-Diesmann
//! microcircuit, watches layer 2/3 through raster/rate/voltage probes,
//! injects a DC step into L4E mid-run (applied at a window boundary, so
//! the experiment stays bit-reproducible from its command schedule),
//! then checkpoints the live session and proves a restored session
//! replays the remainder spike-for-spike.
//!
//! Run: `cargo run --release --example session_control [sim_ms]`

use std::sync::Arc;

use cortex::atlas::potjans::potjans_spec;
use cortex::engine::Simulation;
use cortex::metrics::table::human_bytes;
use cortex::probe::{PopRates, ProbeData, SpikeRaster, VoltageTrace};

fn main() -> anyhow::Result<()> {
    let sim_ms: f64 = std::env::args()
        .nth(1)
        .map(|s| s.parse().expect("sim_ms"))
        .unwrap_or(120.0);
    let spec = Arc::new(potjans_spec(4000.0 / 77_169.0, 7));
    let steps_half = ((sim_ms / spec.dt_ms) as u64 / 2 / 2) * 2; // window-aligned
    println!(
        "microcircuit: {} neurons, {} synapses; session of 2 ranks x 2 \
         threads",
        spec.n_total(),
        spec.n_edges()
    );

    let builder = || {
        Simulation::builder(Arc::clone(&spec))
            .ranks(2)
            .threads(2)
            .record_limit(Some(u32::MAX))
            .probe(SpikeRaster::pops("l23", &["L23E", "L23I"]))
            .probe(PopRates::new("rates", steps_half.max(2)))
            .probe(VoltageTrace::new("vm", &[0, 1, 2], 5))
    };
    let mut sim = builder().build()?;
    println!(
        "built all rank engines once in {:.3}s (worker pools stay \
         alive across calls)",
        sim.build_seconds()
    );

    // phase 1: spontaneous activity
    sim.run_for(steps_half)?;
    report("spontaneous", &sim.drain("rates")?, &spec);

    // phase 2: DC step into L4E, applied at the next window boundary
    sim.set_dc("L4E", 30.0)?;
    sim.run_for(steps_half)?;
    report("L4E +30 pA DC", &sim.drain("rates")?, &spec);
    if let ProbeData::Traces(traces) = sim.drain("vm")? {
        for (gid, samples) in traces.iter().take(1) {
            let (t, v) = samples.last().copied().unwrap_or((0, 0.0));
            println!(
                "vm probe: gid {gid} at {:.1} ms -> {v:.2} mV \
                 ({} samples)",
                t as f64 * spec.dt_ms,
                samples.len()
            );
        }
    }
    let l23_events = sim.drain("l23")?.into_raster()?;
    println!("L2/3 raster probe: {} events so far", l23_events.len());

    // checkpoint the live session, keep running, then prove a restored
    // session replays the identical tail
    let mut blob = Vec::new();
    sim.checkpoint(&mut blob)?;
    println!(
        "checkpointed the session at step {} ({})",
        sim.step(),
        human_bytes(blob.len() as u64)
    );
    let at = sim.step();
    sim.run_for(steps_half)?;
    let out = sim.finish()?;
    let tail: Vec<(u64, u32)> = out
        .raster
        .events
        .iter()
        .copied()
        .filter(|&(t, _)| t >= at)
        .collect();

    let mut resumed =
        builder().restore(&mut std::io::Cursor::new(&blob))?;
    resumed.run_for(steps_half)?;
    let replayed = resumed.finish()?;
    assert_eq!(
        tail, replayed.raster.events,
        "restored session must replay the tail spike-for-spike"
    );
    println!(
        "restore check: {} tail spikes replayed bit-identically ✓",
        tail.len()
    );
    println!(
        "total: {} spikes in {:.3}s simulation wall",
        out.total_spikes, out.wall_seconds
    );
    Ok(())
}

fn report(
    label: &str,
    rates: &ProbeData,
    spec: &cortex::atlas::NetworkSpec,
) {
    let ProbeData::Rates { pops, rows, .. } = rates else { return };
    let Some((start, row)) = rows.last() else { return };
    let cells: Vec<String> = pops
        .iter()
        .zip(row)
        .map(|(n, hz)| format!("{n} {hz:.1}"))
        .collect();
    println!(
        "[{label}] rates from t = {:.1} ms [Hz]: {}",
        *start as f64 * spec.dt_ms,
        cells.join(", ")
    );
}
