//! Calibration sweep for the hpc_benchmark verification network: scans
//! the (η, g) plane and reports the population firing rate of each point,
//! marking the paper's acceptance band (< 10 Hz, asynchronous-irregular).
//!
//! Usage: cargo run --example calibrate [n_neurons] [indegree]

use std::sync::Arc;

use cortex::atlas::hpc::{hpc_benchmark_spec, HpcParams};
use cortex::config::{
    BuildMode, CommMode, DynamicsBackend, ExecMode, IntegrateMode,
    MappingKind, RoutingMode,
};
use cortex::engine::{run_simulation, RunConfig};
use cortex::metrics::Table;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let n: usize = args.first().map(|s| s.parse().unwrap()).unwrap_or(1000);
    let k: u32 = args.get(1).map(|s| s.parse().unwrap()).unwrap_or(100);

    let mut table = Table::new(
        "hpc_benchmark calibration (300 ms)",
        &["eta", "g", "rate_hz", "isi_cv", "verdict"],
    );
    for &eta in &[0.6, 0.65, 0.7, 0.75, 0.8] {
        for &g in &[5.0, 6.0, 7.0, 8.0] {
            let spec = Arc::new(hpc_benchmark_spec(
                &HpcParams {
                    n_neurons: n,
                    indegree: k,
                    eta,
                    g,
                    plastic: false,
                    ..Default::default()
                },
                1,
            ));
            let steps = 3000;
            let out = run_simulation(
                &spec,
                &RunConfig {
                    ranks: 1,
                    threads: 2,
                    mapping: MappingKind::AreaProcesses,
                    comm: CommMode::Serialized,
                    backend: DynamicsBackend::Native,
                    exec: ExecMode::Pool,
                    build: BuildMode::TwoPass,
                    integrate: IntegrateMode::Vector,
                    routing: RoutingMode::Routed,
                    steps,
                    record_limit: Some(u32::MAX),
                    verify_ownership: false,
                    artifacts_dir: "artifacts".into(),
                    seed: 5,
                },
            )
            .unwrap();
            let rate =
                out.total_spikes as f64 / spec.n_total() as f64 / 0.3;
            let stats = out.raster.stats(spec.n_total(), 0.1, steps);
            let verdict = if rate > 0.05 && rate < 10.0 { "PASS" } else { "-" };
            table.row(&[
                format!("{eta}"),
                format!("{g}"),
                format!("{rate:.2}"),
                format!("{:.2}", stats.mean_isi_cv),
                verdict.into(),
            ]);
        }
    }
    println!("{}", table.render());
}
