//! Brunel-style balanced random network on **AdEx** neurons — the first
//! non-LIF workload through the model-generic dynamics layer. The
//! adaptation current (`a`, `b`, `tau_w`) produces the signature rate
//! transient a LIF network cannot show: the onset response is vigorous,
//! then spike-triggered adaptation charges up and the population rate
//! relaxes toward a lower steady state.
//!
//! Run: `cargo run --release --example brunel_adex [sim_ms]`

use std::sync::Arc;

use cortex::atlas::hpc::{hpc_benchmark_spec, HpcParams};
use cortex::config::{
    BuildMode, CommMode, DynamicsBackend, ExecMode, IntegrateMode,
    MappingKind, RoutingMode,
};
use cortex::engine::{run_simulation, RunConfig};
use cortex::model::{AdexParams, ModelParams};

fn main() -> anyhow::Result<()> {
    let sim_ms: f64 = std::env::args()
        .nth(1)
        .map(|s| s.parse().expect("sim_ms must be a number"))
        .unwrap_or(400.0);
    let dt = 0.1;
    let steps = (sim_ms / dt).round() as u64;

    // the hpc_benchmark scaffold (4:1 E/I, fixed indegree, Poisson
    // drive) with both populations on AdEx; a suprathreshold i_ext makes
    // the onset transient strong enough to watch the adaptation bite
    let adex = ModelParams::Adex(AdexParams {
        i_ext: 680.0,
        b: 120.0, // pronounced spike-triggered adaptation
        ..Default::default()
    });
    let spec = Arc::new(hpc_benchmark_spec(
        &HpcParams {
            n_neurons: 2_000,
            indegree: 200,
            plastic: false,
            g: 5.0,
            model_e: adex,
            model_i: adex,
            ..Default::default()
        },
        7,
    ));
    println!(
        "network '{}': {} AdEx neurons, {} synapses",
        spec.name,
        spec.n_total(),
        spec.n_edges()
    );

    let out = run_simulation(
        &spec,
        &RunConfig {
            ranks: 2,
            threads: 2,
            mapping: MappingKind::AreaProcesses,
            comm: CommMode::Overlap,
            backend: DynamicsBackend::Native,
            exec: ExecMode::Pool,
            build: BuildMode::TwoPass,
            integrate: IntegrateMode::Vector,
            routing: RoutingMode::Routed,
            comm_group: Vec::new(),
            steps,
            record_limit: Some(u32::MAX),
            verify_ownership: true,
            artifacts_dir: "artifacts".into(),
            seed: 7,
        },
    )?;
    let mean_rate = out.total_spikes as f64
        / spec.n_total() as f64
        / (sim_ms * 1e-3);
    println!(
        "{} spikes in {:.3}s wall — mean rate {mean_rate:.2} Hz",
        out.total_spikes, out.wall_seconds
    );

    // population rate per 20 ms bin: the adaptation-driven transient
    let bin_ms = 20.0;
    let n_bins = (sim_ms / bin_ms).ceil() as usize;
    let mut bins = vec![0u64; n_bins];
    for &(step, _gid) in &out.raster.events {
        let b = ((step as f64 * dt) / bin_ms) as usize;
        bins[b.min(n_bins - 1)] += 1;
    }
    let to_hz = 1.0 / (spec.n_total() as f64 * bin_ms * 1e-3);
    println!("population rate (Hz) per {bin_ms} ms bin:");
    let peak = bins.iter().copied().max().unwrap_or(1).max(1) as f64;
    for (i, &c) in bins.iter().enumerate() {
        let hz = c as f64 * to_hz;
        let bar = "#".repeat((c as f64 / peak * 50.0).round() as usize);
        println!("{:>6.0} ms {:>8.1} | {}", i as f64 * bin_ms, hz, bar);
    }
    let onset = bins.first().copied().unwrap_or(0) as f64 * to_hz;
    let tail_bins = &bins[n_bins.saturating_sub(5)..];
    let tail = tail_bins.iter().sum::<u64>() as f64 * to_hz
        / tail_bins.len().max(1) as f64;
    println!(
        "onset {onset:.1} Hz -> steady {tail:.1} Hz \
         (spike-frequency adaptation)"
    );
    Ok(())
}
