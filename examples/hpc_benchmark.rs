//! Verification case (paper §IV.A): the NEST `hpc_benchmark` — a balanced
//! random network whose E→E synapses exhibit STDP with multiplicative
//! depression and power-law potentiation.
//!
//! What this demonstrates, in the paper's own terms:
//! * CORTEX supports nonlinear plastic synaptic interactions **without
//!   any mutex or atomic operation** — plastic edge state lives with the
//!   post-owning thread;
//! * the thread-mapping result is checked at runtime: any edge or
//!   post-vertex access from a foreign thread calls Abort
//!   (`verify_ownership: true` compiles the check into the hot loop);
//! * the network stays in the asynchronous-irregular regime with mean
//!   firing below 10 Hz.
//!
//! Run: `cargo run --release --example hpc_benchmark [n_neurons] [sim_ms]`

use std::sync::Arc;

use cortex::atlas::hpc::{hpc_benchmark_spec, HpcParams};
use cortex::config::{
    BuildMode, CommMode, DynamicsBackend, ExecMode, IntegrateMode,
    MappingKind, RoutingMode,
};
use cortex::engine::{run_simulation, RunConfig};

fn main() -> anyhow::Result<()> {
    let mut args = std::env::args().skip(1);
    let n: usize =
        args.next().map(|s| s.parse().unwrap()).unwrap_or(2250);
    let sim_ms: f64 =
        args.next().map(|s| s.parse().unwrap()).unwrap_or(1000.0);

    let params = HpcParams { n_neurons: n, ..Default::default() };
    let spec = Arc::new(hpc_benchmark_spec(&params, 42));
    println!(
        "hpc_benchmark: {} neurons ({}E/{}I), indegree {}, STDP on E->E",
        spec.n_total(),
        spec.populations[0].n,
        spec.populations[1].n,
        params.indegree
    );

    let steps = (sim_ms / spec.dt_ms) as u64;
    let cfg = RunConfig {
        ranks: 2,
        threads: 2,
        mapping: MappingKind::AreaProcesses,
        comm: CommMode::Overlap,
        backend: DynamicsBackend::Native,
        exec: ExecMode::Pool,
        build: BuildMode::TwoPass,
        integrate: IntegrateMode::Vector,
        routing: RoutingMode::Routed,
        comm_group: Vec::new(),
        steps,
        record_limit: Some(u32::MAX),
        verify_ownership: true, // the paper's Abort-on-foreign-access
        artifacts_dir: "artifacts".into(),
        seed: 42,
    };
    let out = run_simulation(&spec, &cfg)?;

    let rate =
        out.total_spikes as f64 / spec.n_total() as f64 / (sim_ms * 1e-3);
    let stats = out.raster.stats(spec.n_total(), spec.dt_ms, steps);
    println!(
        "simulated {sim_ms} ms in {:.2}s wall: {} spikes",
        out.wall_seconds, out.total_spikes
    );
    println!(
        "mean rate {rate:.2} Hz | ISI-CV {:.2} | active fraction {:.2}",
        stats.mean_isi_cv, stats.active_fraction
    );
    println!("thread-ownership violations: 0 (no abort raised)");

    anyhow::ensure!(
        rate > 0.05 && rate < 10.0,
        "rate {rate:.2} Hz outside the paper's verification band"
    );
    println!("VERIFICATION PASSED: asynchronous regime, rate < 10 Hz");
    Ok(())
}
