use std::sync::Arc;
use cortex::atlas::random_spec;
use cortex::comm::SPIKE_WIRE_BYTES;
use cortex::config::{BuildMode, CommMode, DynamicsBackend, ExecMode, IntegrateMode, MappingKind, RoutingMode};
use cortex::engine::{integrate_rates, run_simulation, RunConfig};
fn main() {
    let which = std::env::args().nth(1).unwrap_or_default();
    let spec = Arc::new(random_spec(6000, 300, 31));
    if which == "nest" {
        let o = cortex::nest_baseline::run_nest_simulation(&spec, &cortex::nest_baseline::NestRunConfig{ranks:1,threads:1,steps:500,record_limit:None,seed:31});
        println!("nest {} spikes {:.3}s", o.total_spikes, o.wall_seconds);
        print!("{}", o.memory.report());
    } else {
        // `perfprobe scalar` flips the kernel ablation; `comm`/`bcast`
        // run 2 ranks under routed/broadcast exchange; default is the
        // single-rank vector-kernel probe
        let integrate = if which == "scalar" { IntegrateMode::Scalar } else { IntegrateMode::Vector };
        let ranks = if which == "comm" || which == "bcast" { 2 } else { 1 };
        let routing = if which == "bcast" { RoutingMode::Broadcast } else { RoutingMode::Routed };
        let steps = 500;
        let o = run_simulation(&spec, &RunConfig{ranks,threads:1,mapping:MappingKind::AreaProcesses,comm:CommMode::Serialized,backend:DynamicsBackend::Native,exec:ExecMode::Pool,build:BuildMode::TwoPass,integrate,routing,steps,record_limit:None,verify_ownership:false,artifacts_dir:"artifacts".into(),seed:31}).unwrap();
        println!("cortex {} spikes {:.3}s", o.total_spikes, o.wall_seconds); print!("{}", o.timer_max.report());
        // wire volumes, whole-run and per window ({routing:?} filters
        // the spike packets down to each peer's subscription)
        if o.windows > 0 {
            println!(
                "comm {routing:?}: {} sent / {} received over {} windows ({:.1} / {:.1} spikes per rank-window)",
                o.comm_bytes, o.comm_recv_bytes, o.windows,
                o.comm_bytes as f64 / (SPIKE_WIRE_BYTES * o.windows * ranks as u64) as f64,
                o.comm_recv_bytes as f64 / (SPIKE_WIRE_BYTES * o.windows * ranks as u64) as f64,
            );
        }
        // per-model integrate throughput (aggregate timer, exact count)
        for (m, n, ns) in integrate_rates(&spec, &o.timer_sum, steps) {
            println!("{m:?}: {n} neurons, {ns:.1} ns/neuron-step ({integrate:?})");
        }
        // resident-memory breakdown incl. neuron-model state (was
        // edge-store-only before the dynamics layer accounted it)
        print!("{}", o.memory.report());
    }
}
