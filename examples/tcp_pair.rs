//! Two-rank distributed runtime demo: the same downscaled Potjans
//! microcircuit run (a) as one process with two in-memory ranks and
//! (b) as a two-endpoint TCP cluster exchanging BSB frames over real
//! localhost sockets — then the rasters are diffed, which must be
//! **bit-identical** (the distributed-runtime acceptance criterion;
//! `rust/tests/comm_wire.rs` asserts the same under `cargo test`).
//!
//! The two TCP endpoints live on threads here so the example is
//! self-contained; `cortex launch --ranks 2` runs the identical
//! exchange across OS processes.
//!
//! Run: `cargo run --release --example tcp_pair [sim_ms]`

use std::net::TcpListener;
use std::sync::Arc;
use std::thread;
use std::time::Duration;

use cortex::atlas::potjans::potjans_spec;
use cortex::comm::{Communicator, TcpComm};
use cortex::config::{
    BuildMode, CommMode, DynamicsBackend, ExecMode, IntegrateMode,
    MappingKind, RoutingMode,
};
use cortex::engine::{run_simulation, RunConfig, Simulation};

const SEED: u64 = 23;

fn main() -> anyhow::Result<()> {
    let sim_ms: f64 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(50.0);
    let steps = (sim_ms / 0.1).round() as u64;
    let spec = Arc::new(potjans_spec(1600.0 / 77_169.0, SEED));
    println!(
        "network '{}': {} neurons, {} synapses — {sim_ms} ms",
        spec.name,
        spec.n_total(),
        spec.n_edges()
    );

    // (a) reference: both ranks in-process over channel transport
    let local = run_simulation(
        &spec,
        &RunConfig {
            ranks: 2,
            threads: 2,
            mapping: MappingKind::AreaProcesses,
            comm: CommMode::Overlap,
            backend: DynamicsBackend::Native,
            exec: ExecMode::Pool,
            build: BuildMode::TwoPass,
            integrate: IntegrateMode::Vector,
            routing: RoutingMode::Routed,
            comm_group: Vec::new(),
            steps,
            record_limit: Some(u32::MAX),
            verify_ownership: false,
            artifacts_dir: "artifacts".into(),
            seed: SEED,
        },
    )?;
    println!(
        "local transport : {} spikes in {:.3}s",
        local.total_spikes, local.wall_seconds
    );

    // (b) the same two ranks as a TCP cluster on ephemeral ports
    let listeners: Vec<TcpListener> = (0..2)
        .map(|_| TcpListener::bind("127.0.0.1:0"))
        .collect::<Result<_, _>>()?;
    let peers: Vec<String> = listeners
        .iter()
        .map(|l| Ok(l.local_addr()?.to_string()))
        .collect::<anyhow::Result<_>>()?;
    println!("tcp transport   : peers {}", peers.join(", "));
    let handles: Vec<_> = listeners
        .into_iter()
        .enumerate()
        .map(|(rank, listener)| {
            let spec = Arc::clone(&spec);
            let peers = peers.clone();
            thread::spawn(move || -> anyhow::Result<Vec<(u64, u32)>> {
                let endpoint = TcpComm::join_with_listener(
                    rank as u16,
                    listener,
                    &peers,
                    Duration::from_secs(30),
                )?;
                let mut sim = Simulation::builder(spec)
                    .ranks(2)
                    .threads(2)
                    .comm(CommMode::Overlap)
                    .record_limit(Some(u32::MAX))
                    .seed(SEED)
                    .transport_with(move |_| {
                        Ok(vec![(
                            rank,
                            Box::new(endpoint)
                                as Box<dyn Communicator>,
                        )])
                    })
                    .build()?;
                sim.run_for(steps)?;
                let out = sim.finish()?;
                println!(
                    "  rank {rank}: {} spikes, {} exchanged over {} \
                     windows",
                    out.total_spikes, out.comm_bytes, out.windows
                );
                Ok(out.raster.events)
            })
        })
        .collect();
    let mut merged = Vec::new();
    for h in handles {
        merged.extend(
            h.join().expect("rank thread panicked")?,
        );
    }
    merged.sort_unstable();

    anyhow::ensure!(
        merged == local.raster.events,
        "rasters diverged: local {} events, tcp {} events",
        local.raster.events.len(),
        merged.len()
    );
    println!(
        "rasters bit-identical across transports ({} events)",
        merged.len()
    );
    Ok(())
}
