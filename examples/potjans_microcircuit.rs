//! Potjans-Diesmann 2014 cortical microcircuit — the architecture the
//! paper derives its areas' internal structure from (ref [30]). Runs the
//! downscaled column (variance-preserving 1/√scale weights + DC mean
//! compensation) and compares per-population firing rates against the
//! published full-scale spontaneous rates.
//!
//! Run: `cargo run --release --example potjans_microcircuit [scale]`
//! (default scale 0.05 ≈ 3 860 neurons)

use std::sync::Arc;

use cortex::atlas::potjans::{potjans_spec, POP_NAMES, TARGET_RATES_HZ};
use cortex::config::{CommMode, DynamicsBackend, ExecMode, MappingKind};
use cortex::engine::{run_simulation, RunConfig};
use cortex::metrics::Table;

fn main() -> anyhow::Result<()> {
    let scale: f64 = std::env::args()
        .nth(1)
        .map(|s| s.parse().expect("scale"))
        .unwrap_or(0.05);
    let spec = Arc::new(potjans_spec(scale, 7));
    println!(
        "microcircuit at scale {scale}: {} neurons, {} synapses",
        spec.n_total(),
        spec.n_edges()
    );

    let sim_ms = 500.0;
    let steps = (sim_ms / spec.dt_ms) as u64;
    let cfg = RunConfig {
        ranks: 2,
        threads: 2,
        mapping: MappingKind::AreaProcesses,
        comm: CommMode::Overlap,
        backend: DynamicsBackend::Native,
        exec: ExecMode::Pool,
        steps,
        record_limit: Some(u32::MAX),
        verify_ownership: false,
        artifacts_dir: "artifacts".into(),
        seed: 7,
    };
    let out = run_simulation(&spec, &cfg)?;
    println!(
        "simulated {sim_ms} ms in {:.2}s wall, {} spikes",
        out.wall_seconds, out.total_spikes
    );

    let sim_s = sim_ms * 1e-3;
    let mut table = Table::new(
        "per-population rates (published full-scale target in parens)",
        &["pop", "neurons", "rate_hz", "target_hz"],
    );
    for (i, p) in spec.populations.iter().enumerate() {
        let count = out
            .raster
            .events
            .iter()
            .filter(|&&(_, g)| g >= p.first_gid && g < p.first_gid + p.n)
            .count();
        let rate = count as f64 / p.n as f64 / sim_s;
        table.row(&[
            POP_NAMES[i].to_string(),
            p.n.to_string(),
            format!("{rate:.2}"),
            format!("{:.2}", TARGET_RATES_HZ[i]),
        ]);
    }
    table.emit(std::path::Path::new("target/bench_out"), "potjans_rates")?;
    Ok(())
}
