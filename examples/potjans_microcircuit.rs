//! Potjans-Diesmann 2014 cortical microcircuit — the architecture the
//! paper derives its areas' internal structure from (ref [30]). Runs the
//! downscaled column (variance-preserving 1/√scale weights + DC mean
//! compensation) through a `Simulation` session with a per-population
//! rate probe, and compares the probed firing rates against the
//! published full-scale spontaneous rates.
//!
//! Run: `cargo run --release --example potjans_microcircuit [scale]`
//! (default scale 0.05 ≈ 3 860 neurons)

use std::sync::Arc;

use cortex::atlas::potjans::{potjans_spec, TARGET_RATES_HZ};
use cortex::engine::Simulation;
use cortex::metrics::Table;
use cortex::probe::{PopRates, ProbeData};

fn main() -> anyhow::Result<()> {
    let scale: f64 = std::env::args()
        .nth(1)
        .map(|s| s.parse().expect("scale"))
        .unwrap_or(0.05);
    let spec = Arc::new(potjans_spec(scale, 7));
    println!(
        "microcircuit at scale {scale}: {} neurons, {} synapses",
        spec.n_total(),
        spec.n_edges()
    );

    let sim_ms = 500.0;
    let steps = (sim_ms / spec.dt_ms) as u64;
    let mut sim = Simulation::builder(Arc::clone(&spec))
        .ranks(2)
        .threads(2)
        .probe(PopRates::new("rates", steps))
        .build()?;
    sim.run_for(steps)?;
    let rates = sim.drain("rates")?;
    let out = sim.finish()?;
    println!(
        "simulated {sim_ms} ms in {:.2}s wall, {} spikes",
        out.wall_seconds, out.total_spikes
    );

    let ProbeData::Rates { pops, rows, .. } = rates else {
        anyhow::bail!("rates probe returned the wrong variant");
    };
    let row = &rows.last().expect("one full bin").1;
    let mut table = Table::new(
        "per-population rates (published full-scale target in parens)",
        &["pop", "neurons", "rate_hz", "target_hz"],
    );
    for (i, p) in spec.populations.iter().enumerate() {
        table.row(&[
            pops[i].clone(),
            p.n.to_string(),
            format!("{:.2}", row[i]),
            format!("{:.2}", TARGET_RATES_HZ[i]),
        ]);
    }
    table.emit(std::path::Path::new("target/bench_out"), "potjans_rates")?;
    Ok(())
}
