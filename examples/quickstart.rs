//! Quickstart: the smallest complete CORTEX run.
//!
//! Builds a 2000-neuron balanced random network, decomposes it onto two
//! simulated ranks with two compute threads each (mutex-free indegree
//! ownership), simulates 100 ms of biological time with overlapped spike
//! exchange, and prints activity + performance. If `make artifacts` has
//! been run, the same network is then re-simulated with neuron dynamics
//! executed by the AOT-compiled JAX/Pallas kernel via PJRT, and the two
//! backends are checked to agree spike-for-spike.
//!
//! Run: `cargo run --release --example quickstart`

use std::sync::Arc;

use cortex::atlas::random_spec;
use cortex::config::{CommMode, DynamicsBackend, ExecMode, MappingKind};
use cortex::engine::{run_simulation, RunConfig};
use cortex::metrics::table::human_bytes;

fn main() -> anyhow::Result<()> {
    let spec = Arc::new(random_spec(2000, 200, 42));
    println!(
        "network: {} neurons, {} synapses (fixed indegree 200)",
        spec.n_total(),
        spec.n_edges()
    );

    let cfg = RunConfig {
        ranks: 2,
        threads: 2,
        mapping: MappingKind::AreaProcesses,
        comm: CommMode::Overlap,
        backend: DynamicsBackend::Native,
        exec: ExecMode::Pool,
        steps: 1000, // 100 ms at dt = 0.1 ms
        record_limit: Some(u32::MAX),
        verify_ownership: true,
        artifacts_dir: "artifacts".into(),
        seed: 42,
    };
    let out = run_simulation(&spec, &cfg)?;
    let rate = out.total_spikes as f64 / spec.n_total() as f64 / 0.1;
    println!(
        "native backend : {} spikes in {:.3}s wall ({rate:.2} Hz mean rate)",
        out.total_spikes, out.wall_seconds
    );
    println!(
        "memory         : max-rank {}, comm {} over {} windows",
        human_bytes(out.memory.max_rank_bytes()),
        human_bytes(out.comm_bytes),
        out.windows
    );
    print!("{}", out.timer_max.report());

    // PJRT backend (needs `make artifacts`)
    if std::path::Path::new("artifacts/manifest.json").exists() {
        let mut cfg2 = cfg.clone();
        cfg2.backend = DynamicsBackend::Pjrt;
        cfg2.ranks = 1; // one PJRT client
        cfg2.threads = 1;
        let mut cfg1 = cfg2.clone();
        cfg1.backend = DynamicsBackend::Native;
        let native = run_simulation(&spec, &cfg1)?;
        let accel = run_simulation(&spec, &cfg2)?;
        println!(
            "pjrt backend   : {} spikes in {:.3}s wall \
             (AOT JAX/Pallas lif_step via XLA)",
            accel.total_spikes, accel.wall_seconds
        );
        assert_eq!(
            native.raster.events, accel.raster.events,
            "backends must agree spike-for-spike"
        );
        println!("native and PJRT backends agree spike-for-spike ✓");
    } else {
        println!("(run `make artifacts` to exercise the PJRT backend)");
    }
    Ok(())
}
