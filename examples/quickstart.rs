//! Quickstart: the smallest complete CORTEX session.
//!
//! Builds a 2000-neuron balanced random network, decomposes it onto two
//! simulated ranks with two compute threads each (mutex-free indegree
//! ownership), and opens a persistent `Simulation` session: rank
//! engines and their worker pools are constructed once, then driven
//! through repeated `run_for` calls with a spike-raster and a
//! population-rate probe attached. Between calls the session doubles
//! the excitatory Poisson drive — the rate probe shows the response.
//! If `make artifacts` has been run, the same network is re-simulated
//! with neuron dynamics executed by the AOT-compiled JAX/Pallas kernel
//! via PJRT, and the two backends are checked to agree spike-for-spike.
//!
//! Run: `cargo run --release --example quickstart`

use std::sync::Arc;

use cortex::atlas::random_spec;
use cortex::config::DynamicsBackend;
use cortex::engine::{run_simulation, RunConfig, Simulation};
use cortex::metrics::table::human_bytes;
use cortex::probe::{PopRates, ProbeData, SpikeRaster};

fn main() -> anyhow::Result<()> {
    let spec = Arc::new(random_spec(2000, 200, 42));
    println!(
        "network: {} neurons, {} synapses (fixed indegree 200)",
        spec.n_total(),
        spec.n_edges()
    );

    // a persistent session: engines built once, driven repeatedly
    let mut sim = Simulation::builder(Arc::clone(&spec))
        .ranks(2)
        .threads(2)
        .record_limit(Some(u32::MAX))
        .verify_ownership(true)
        .probe(SpikeRaster::all("raster"))
        .probe(PopRates::new("rates", 500)) // 50 ms bins
        .build()?;

    sim.run_for(500)?; // 50 ms at dt = 0.1 ms
    sim.set_poisson("E", 16_000.0, 87.8)?; // double the E drive …
    sim.run_for(500)?; // … and watch the response

    if let ProbeData::Rates { pops, rows, .. } = sim.drain("rates")? {
        for (start, rates) in rows {
            let cells: Vec<String> = pops
                .iter()
                .zip(&rates)
                .map(|(n, hz)| format!("{n} {hz:.1} Hz"))
                .collect();
            println!(
                "t = {:>5.1} ms  {}",
                start as f64 * spec.dt_ms,
                cells.join(", ")
            );
        }
    }
    let events = sim.drain("raster")?.into_raster()?;
    let out = sim.finish()?;
    let rate = out.total_spikes as f64 / spec.n_total() as f64 / 0.1;
    println!(
        "native backend : {} spikes in {:.3}s wall ({rate:.2} Hz mean \
         rate, {} probed)",
        out.total_spikes,
        out.wall_seconds,
        events.len()
    );
    println!(
        "memory         : max-rank {}, comm {} over {} windows",
        human_bytes(out.memory.max_rank_bytes()),
        human_bytes(out.comm_bytes),
        out.windows
    );
    print!("{}", out.timer_max.report());

    // PJRT backend (needs `make artifacts`); the one-shot wrapper is
    // the right tool for a fire-and-forget comparison run
    if std::path::Path::new("artifacts/manifest.json").exists() {
        let cfg = RunConfig {
            ranks: 1,
            threads: 1,
            backend: DynamicsBackend::Pjrt,
            steps: 1000,
            record_limit: Some(u32::MAX),
            seed: 42,
            ..Default::default()
        };
        let mut native_cfg = cfg.clone();
        native_cfg.backend = DynamicsBackend::Native;
        let native = run_simulation(&spec, &native_cfg)?;
        let accel = run_simulation(&spec, &cfg)?;
        println!(
            "pjrt backend   : {} spikes in {:.3}s wall \
             (AOT JAX/Pallas lif_step via XLA)",
            accel.total_spikes, accel.wall_seconds
        );
        assert_eq!(
            native.raster.events, accel.raster.events,
            "backends must agree spike-for-spike"
        );
        println!("native and PJRT backends agree spike-for-spike ✓");
    } else {
        println!("(run `make artifacts` to exercise the PJRT backend)");
    }
    Ok(())
}
