"""AOT: lower the L2 graph (with its L1 Pallas kernels) to HLO text.

Interchange format is HLO *text*, NOT a serialized HloModuleProto: jax>=0.5
emits protos with 64-bit instruction ids, which the xla_extension 0.5.1
backing the published `xla` crate rejects (`proto.id() <= INT_MAX`).  The
text parser on the Rust side reassigns ids, so text round-trips cleanly
(see /opt/xla-example/README.md).

Outputs, under --out-dir (default ../artifacts):

  lif_step_n{N}.hlo.txt      one LIF state-update step, N neurons
  dense_net_n{N}.hlo.txt     full dense-coupling network step, N neurons
  manifest.json              baked LifConfig + propagators + shapes so the
                             Rust engine can mirror the computation
  fixtures/lif_fixtures.json reference trajectories for Rust unit tests

Run via `make artifacts`; it is a no-op when inputs are unchanged.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model
from compile.kernels.ref import _dump_fixtures

# Shapes baked into artifacts. The Rust engine pads its per-rank neuron
# blocks to LIF_SIZES; the dense demo network uses DENSE_SIZES.
LIF_SIZES = (512, 2048)
DENSE_SIZES = (256,)


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (return_tuple for rust side)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_lif_step(cfg: model.LifConfig, n: int) -> str:
    step = model.lif_step(cfg, block=min(n, 2048))
    vec = jax.ShapeDtypeStruct((n,), jnp.float64)
    lowered = jax.jit(step).lower(vec, vec, vec, vec, vec, vec)
    return to_hlo_text(lowered)


def lower_dense_net(cfg: model.LifConfig, n: int) -> str:
    net = model.dense_net_step(cfg, block=min(n, 128))
    vec = jax.ShapeDtypeStruct((n,), jnp.float64)
    mat = jax.ShapeDtypeStruct((n, n), jnp.float64)
    lowered = jax.jit(net).lower(vec, vec, vec, vec, vec, mat, mat)
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    out = args.out_dir
    os.makedirs(out, exist_ok=True)
    os.makedirs(os.path.join(out, "fixtures"), exist_ok=True)

    cfg = model.LifConfig()
    files = {}

    for n in LIF_SIZES:
        name = f"lif_step_n{n}.hlo.txt"
        text = lower_lif_step(cfg, n)
        with open(os.path.join(out, name), "w") as f:
            f.write(text)
        files[name] = {"kind": "lif_step", "n": n}
        print(f"wrote {name} ({len(text)} chars)")

    for n in DENSE_SIZES:
        name = f"dense_net_n{n}.hlo.txt"
        text = lower_dense_net(cfg, n)
        with open(os.path.join(out, name), "w") as f:
            f.write(text)
        files[name] = {"kind": "dense_net", "n": n}
        print(f"wrote {name} ({len(text)} chars)")

    manifest = {
        **model.config_manifest(cfg),
        "artifacts": files,
        "jax_version": jax.__version__,
    }
    with open(os.path.join(out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print("wrote manifest.json")

    _dump_fixtures(os.path.join(out, "fixtures", "lif_fixtures.json"))


if __name__ == "__main__":
    main()
