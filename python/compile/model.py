"""L2: the JAX compute graph of the CORTEX neuron-dynamics hot path.

The CORTEX paper (eqs. 1-3) advances leaky integrate-and-fire neurons with
exponential post-synaptic currents each time step.  The exact-integration
propagators (Rotter & Diesmann 1999, the method the paper cites as [21])
turn the ODE step into an affine update, which is what the L1 Pallas kernel
(`kernels/lif_step.py`) computes for a block of neurons.

Two exported computations (lowered by aot.py):

- ``lif_step``       — the per-neuron state update used by the Rust engine's
                       PJRT dynamics path (synaptic input arrives as two
                       pre-accumulated vectors).
- ``dense_net_step`` — a full dense-coupling network step (spike vector →
                       synaptic accumulation via the `syn_accum` kernel →
                       `lif_step`), used by the quickstart / kernel bench.

State layout (all float64 vectors of length N):
  u    membrane potential [mV]
  ie   excitatory synaptic current [pA]
  ii   inhibitory synaptic current [pA]
  r    refractory countdown [steps] (kept f64; values are small exact ints)

Update order matches NEST's iaf_psc_exp (and the Rust native engine):
  1. non-refractory membranes integrate with the exact propagator,
  2. refractory neurons hold u_reset and count down,
  3. threshold crossing emits a spike, resets, arms the refractory timer,
  4. synaptic currents decay, then this step's arriving input is added
     (so input delivered at step t first moves the membrane at t+1).
"""

from dataclasses import dataclass, asdict
import math

from compile.kernels import lif_step as lif_kernel
from compile.kernels import syn_accum as syn_kernel


@dataclass(frozen=True)
class LifConfig:
    """Parameters of the LIF / exponential-PSC neuron (NEST iaf_psc_exp names).

    Defaults are the values used by the Potjans-Diesmann microcircuit and the
    NEST hpc_benchmark family, which the paper's evaluation builds on.
    """

    tau_m: float = 10.0       # membrane time constant [ms]
    tau_syn_ex: float = 0.5   # excitatory synaptic time constant [ms]
    tau_syn_in: float = 0.5   # inhibitory synaptic time constant [ms]
    c_m: float = 250.0        # membrane capacitance [pF]
    e_l: float = -65.0        # resting potential [mV]
    v_reset: float = -65.0    # post-spike reset [mV]
    v_th: float = -50.0       # spike threshold [mV]
    t_ref: float = 2.0        # absolute refractory period [ms]
    i_ext: float = 0.0        # constant external current [pA]
    dt: float = 0.1           # integration step [ms]

    @property
    def ref_steps(self) -> int:
        return int(round(self.t_ref / self.dt))


@dataclass(frozen=True)
class Propagators:
    """Exact-integration propagators for one dt (Rotter & Diesmann 1999)."""

    p22: float      # membrane decay        exp(-dt/tau_m)
    p11e: float     # exc current decay     exp(-dt/tau_syn_ex)
    p11i: float     # inh current decay     exp(-dt/tau_syn_in)
    p21e: float     # exc current -> membrane coupling
    p21i: float     # inh current -> membrane coupling
    p20: float      # constant current -> membrane  (tau_m/C)(1-p22)
    ref_steps: int

    @staticmethod
    def from_config(cfg: LifConfig) -> "Propagators":
        h = cfg.dt
        p22 = math.exp(-h / cfg.tau_m)

        def p21(tau_s: float) -> float:
            p11 = math.exp(-h / tau_s)
            if abs(tau_s - cfg.tau_m) < 1e-12:
                # degenerate (equal time constants) limit: h·e^{-h/tau}/C
                return h * p11 / cfg.c_m
            return (
                tau_s
                * cfg.tau_m
                / (cfg.c_m * (tau_s - cfg.tau_m))
                * (p11 - p22)
            )

        return Propagators(
            p22=p22,
            p11e=math.exp(-h / cfg.tau_syn_ex),
            p11i=math.exp(-h / cfg.tau_syn_in),
            p21e=p21(cfg.tau_syn_ex),
            p21i=p21(cfg.tau_syn_in),
            p20=cfg.tau_m / cfg.c_m * (1.0 - p22),
            ref_steps=cfg.ref_steps,
        )


def lif_step(cfg: LifConfig, *, block: int = 256, interpret: bool = True):
    """Return f(u, ie, ii, r, in_e, in_i) -> (u', ie', ii', r', spiked).

    The returned function is traceable/jittable; the heavy lifting is the
    L1 Pallas kernel. `spiked` is a f64 0/1 vector.
    """
    prop = Propagators.from_config(cfg)

    def step(u, ie, ii, r, in_e, in_i):
        return lif_kernel.lif_step(
            u, ie, ii, r, in_e, in_i, cfg=cfg, prop=prop,
            block=block, interpret=interpret,
        )

    return step


def dense_net_step(cfg: LifConfig, *, block: int = 128, interpret: bool = True):
    """Return f(u, ie, ii, r, s_prev, w_exc, w_inh) -> (u', ie', ii', r', s).

    Dense single-delay coupling: the incoming synaptic drive of this step is
    W⁺ᵀ·s_prev (excitatory) and W⁻ᵀ·s_prev (inhibitory), computed by the
    blocked `syn_accum` Pallas kernel (the TPU re-expression of the paper's
    scatter hot loop), followed by the `lif_step` kernel.

    w_exc must be >= 0 elementwise and w_inh <= 0; both are (N, N) with
    w[j, i] = weight from pre-synaptic neuron j to post-synaptic neuron i
    (the paper's W_ji convention).
    """
    step = lif_step(cfg, block=max(block, 128), interpret=interpret)

    def net(u, ie, ii, r, s_prev, w_exc, w_inh):
        in_e = syn_kernel.syn_accum(w_exc, s_prev, block=block, interpret=interpret)
        in_i = syn_kernel.syn_accum(w_inh, s_prev, block=block, interpret=interpret)
        return step(u, ie, ii, r, in_e, in_i)

    return net


def config_manifest(cfg: LifConfig) -> dict:
    """Everything the Rust side needs to mirror the baked computation."""
    prop = Propagators.from_config(cfg)
    return {"config": asdict(cfg), "propagators": asdict(prop)}
