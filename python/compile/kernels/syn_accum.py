"""Blocked spike→current accumulation as a Pallas kernel.

The paper's hot loop is a CPU scatter: for every spiking pre-synaptic
neuron, walk its (delay-sorted) edges and accumulate the weight into the
post-synaptic neuron's input.  A TPU has no scatter unit; the equivalent
dense formulation is a tiled mat-vec against the spike indicator vector:

    input[i] = Σ_j  W[j, i] · s[j]

with W[j, i] the paper's W_ji (pre j → post i).  The grid tiles the post
axis; each grid cell streams the full pre axis through VMEM in `block`-row
chunks and accumulates a partial dot-product per post lane.  On real TPU
this contraction maps onto the MXU (a (1, K) × (K, block) matmul per tile);
see DESIGN.md §Hardware-Adaptation.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_JIT_CACHE = {}


def _syn_accum_kernel(w_ref, s_ref, o_ref):
    # w_ref: (pre_block, post_block) tile; s_ref: (pre_block,) tile.
    # Grid = (post_tiles, pre_tiles); pre axis is the reduction (innermost).
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # (1, K) @ (K, B): the MXU-shaped contraction for this tile pair.
    s = s_ref[...]
    w = w_ref[...]
    o_ref[...] += jnp.dot(s[None, :], w, precision="highest")[0]


def syn_accum(w, s, *, block=128, interpret=True):
    """Return `input = Wᵀ·s` where w is (n_pre, n_post), s is (n_pre,).

    Arbitrary shapes; both axes are padded to multiples of `block`.
    """
    n_pre, n_post = w.shape
    dtype = w.dtype
    bpre = max(1, -(-n_pre // block))
    bpost = max(1, -(-n_post // block))
    pad_pre = bpre * block - n_pre
    pad_post = bpost * block - n_post

    if pad_pre or pad_post:
        w = jnp.pad(w, ((0, pad_pre), (0, pad_post)))
        s = jnp.pad(s.astype(dtype), (0, pad_pre))
    else:
        s = s.astype(dtype)

    key = (bpre, bpost, block, str(dtype), interpret)
    call = _JIT_CACHE.get(key)
    if call is None:
        call = jax.jit(pl.pallas_call(
            _syn_accum_kernel,
            grid=(bpost, bpre),
            in_specs=[
                pl.BlockSpec((block, block), lambda i, k: (k, i)),
                pl.BlockSpec((block,), lambda i, k: (k,)),
            ],
            out_specs=pl.BlockSpec((block,), lambda i, k: (i,)),
            out_shape=jax.ShapeDtypeStruct((bpost * block,), dtype),
            interpret=interpret,
        ))
        _JIT_CACHE[key] = call
    out = call(w, s)

    return out[:n_post]
