"""L1 Pallas kernels for the CORTEX hot path.

- ``lif_step``  — fused exact-integration LIF state update (element-wise,
                  VPU-bound on real TPU).
- ``syn_accum`` — blocked spike→current accumulation expressed as a tiled
                  dense mat-vec (the MXU re-think of the paper's CPU
                  scatter loop; see DESIGN.md §Hardware-Adaptation).
- ``ref``       — pure-jnp oracles for both, used by pytest/hypothesis and
                  to dump fixtures for the Rust unit tests.

All kernels are lowered with ``interpret=True``: the CPU PJRT plugin cannot
execute Mosaic custom-calls, so interpret mode is the correctness (and
artifact) path, while TPU performance is analysed statically (DESIGN.md §8).
"""
