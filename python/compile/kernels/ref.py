"""Pure-jnp oracles for the L1 kernels + fixture dumper for the Rust tests.

``lif_step_ref`` / ``syn_accum_ref`` implement exactly the semantics
documented in model.py, with no Pallas involved.  pytest asserts the Pallas
kernels match these to f64 round-off; ``python -m compile.kernels.ref
--dump out.json`` writes step-by-step trajectories that the Rust native
engine's unit tests replay (same propagators, same update order).
"""

import argparse
import json

import jax.numpy as jnp


def lif_step_ref(u, ie, ii, r, in_e, in_i, *, cfg, prop):
    refractory = r > 0.0

    u_prop = (
        cfg.e_l
        + (u - cfg.e_l) * prop.p22
        + ie * prop.p21e
        + ii * prop.p21i
        + cfg.i_ext * prop.p20
    )
    u_new = jnp.where(refractory, cfg.v_reset, u_prop)
    r_new = jnp.where(refractory, r - 1.0, r)

    spiked = jnp.logical_and(jnp.logical_not(refractory), u_new >= cfg.v_th)
    u_new = jnp.where(spiked, cfg.v_reset, u_new)
    r_new = jnp.where(spiked, float(prop.ref_steps), r_new)

    ie_new = ie * prop.p11e + in_e
    ii_new = ii * prop.p11i + in_i
    return u_new, ie_new, ii_new, r_new, spiked.astype(u.dtype)


def syn_accum_ref(w, s):
    return w.T @ s.astype(w.dtype)


def dense_net_step_ref(u, ie, ii, r, s_prev, w_exc, w_inh, *, cfg, prop):
    in_e = syn_accum_ref(w_exc, s_prev)
    in_i = syn_accum_ref(w_inh, s_prev)
    return lif_step_ref(u, ie, ii, r, in_e, in_i, cfg=cfg, prop=prop)


def _dump_fixtures(path: str) -> None:
    """Deterministic multi-step LIF trajectories for the Rust unit tests."""
    import numpy as np

    from compile.model import LifConfig, Propagators, config_manifest

    cases = []
    rng = np.random.default_rng(20240710)
    for name, cfg in [
        ("default", LifConfig()),
        ("slow_syn", LifConfig(tau_syn_ex=2.0, tau_syn_in=4.0, i_ext=300.0)),
        ("equal_tau", LifConfig(tau_syn_ex=10.0, tau_syn_in=10.0, i_ext=380.0)),
        ("drive", LifConfig(i_ext=400.0, t_ref=1.0)),
    ]:
        prop = Propagators.from_config(cfg)
        n, steps = 8, 50
        u = jnp.asarray(cfg.e_l + rng.uniform(0.0, 14.0, n))
        ie = jnp.asarray(rng.uniform(0.0, 200.0, n))
        ii = jnp.asarray(rng.uniform(-200.0, 0.0, n))
        r = jnp.zeros(n)
        traj = {"u0": u.tolist(), "ie0": ie.tolist(), "ii0": ii.tolist(),
                "in_e": [], "in_i": [], "u": [], "ie": [], "ii": [],
                "refrac": [], "spiked": []}
        for t in range(steps):
            in_e = jnp.asarray(rng.uniform(0.0, 120.0, n) * (rng.random(n) < 0.3))
            in_i = jnp.asarray(-rng.uniform(0.0, 120.0, n) * (rng.random(n) < 0.2))
            u, ie, ii, r, s = lif_step_ref(
                u, ie, ii, r, in_e, in_i, cfg=cfg, prop=prop)
            traj["in_e"].append(in_e.tolist())
            traj["in_i"].append(in_i.tolist())
            traj["u"].append(u.tolist())
            traj["ie"].append(ie.tolist())
            traj["ii"].append(ii.tolist())
            traj["refrac"].append(r.tolist())
            traj["spiked"].append(s.tolist())
        cases.append({"name": name, **config_manifest(cfg), "trajectory": traj})

    with open(path, "w") as f:
        json.dump({"cases": cases}, f)
    print(f"wrote {len(cases)} LIF fixture cases to {path}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--dump", required=True, help="output JSON path")
    _dump_fixtures(ap.parse_args().dump)
