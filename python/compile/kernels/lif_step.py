"""Fused exact-integration LIF step as a Pallas kernel.

One grid cell processes a block of `block` neurons: all six input vectors
are staged into VMEM tiles, the affine propagator update + threshold /
reset / refractory logic run element-wise, and five output tiles are
written back.  The kernel is purely element-wise, so on a real TPU it is
VPU work and the HBM↔VMEM streaming schedule expressed by the BlockSpecs
is the whole performance story (see DESIGN.md §Hardware-Adaptation for the
VMEM budget: 11 tiles × block × 8 B ≈ 176 KiB at block=2048 — far below
the ~16 MiB VMEM, leaving room for double buffering).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# pallas_call in interpret mode is expensive to retrace; cache the jitted
# padded-step per (params, shape, dtype) so repeated calls are cheap.
_JIT_CACHE = {}


def _lif_kernel(u_ref, ie_ref, ii_ref, r_ref, ine_ref, ini_ref,
                uo_ref, ieo_ref, iio_ref, ro_ref, so_ref,
                *, p22, p11e, p11i, p21e, p21i, p20,
                e_l, v_reset, v_th, i_ext, ref_steps):
    u = u_ref[...]
    ie = ie_ref[...]
    ii = ii_ref[...]
    r = r_ref[...]

    refractory = r > 0.0

    # 1. exact-integration membrane propagation (non-refractory only)
    u_prop = e_l + (u - e_l) * p22 + ie * p21e + ii * p21i + i_ext * p20
    u_new = jnp.where(refractory, v_reset, u_prop)

    # 2. refractory countdown
    r_new = jnp.where(refractory, r - 1.0, r)

    # 3. threshold, reset, arm refractory timer
    spiked = jnp.logical_and(jnp.logical_not(refractory), u_new >= v_th)
    u_new = jnp.where(spiked, v_reset, u_new)
    r_new = jnp.where(spiked, float(ref_steps), r_new)

    # 4. synaptic currents decay, then this step's input lands
    ie_new = ie * p11e + ine_ref[...]
    ii_new = ii * p11i + ini_ref[...]

    uo_ref[...] = u_new
    ieo_ref[...] = ie_new
    iio_ref[...] = ii_new
    ro_ref[...] = r_new
    so_ref[...] = spiked.astype(u.dtype)


def lif_step(u, ie, ii, r, in_e, in_i, *, cfg, prop, block=256, interpret=True):
    """Apply one LIF step to N neurons (N arbitrary; padded to `block`).

    Returns (u', ie', ii', r', spiked) with the same shape/dtype as `u`.
    """
    n = u.shape[0]
    dtype = u.dtype
    nb = max(1, -(-n // block))          # ceil-div, >= 1 block even for n=0
    pad = nb * block - n

    def padded(x, fill=0.0):
        x = x.astype(dtype)
        if pad:
            x = jnp.pad(x, (0, pad), constant_values=fill)
        return x

    # Padding lanes are parked in the refractory state with u at reset so
    # they can never spike and never interact with live lanes.
    args = (
        padded(u, cfg.v_reset),
        padded(ie),
        padded(ii),
        padded(r, float(prop.ref_steps)),
        padded(in_e),
        padded(in_i),
    )

    key = (cfg, prop, block, nb, str(dtype), interpret)
    call = _JIT_CACHE.get(key)
    if call is None:
        kern = functools.partial(
            _lif_kernel,
            p22=prop.p22, p11e=prop.p11e, p11i=prop.p11i,
            p21e=prop.p21e, p21i=prop.p21i, p20=prop.p20,
            e_l=cfg.e_l, v_reset=cfg.v_reset, v_th=cfg.v_th,
            i_ext=cfg.i_ext, ref_steps=prop.ref_steps,
        )
        shape = jax.ShapeDtypeStruct((nb * block,), dtype)
        spec = pl.BlockSpec((block,), lambda i: (i,))
        call = jax.jit(pl.pallas_call(
            kern,
            grid=(nb,),
            in_specs=[spec] * 6,
            out_specs=[spec] * 5,
            out_shape=[shape] * 5,
            interpret=interpret,
        ))
        _JIT_CACHE[key] = call
    outs = call(*args)

    if pad:
        outs = tuple(o[:n] for o in outs)
    return tuple(outs)
