"""CORTEX build-time python package (L1 Pallas kernels + L2 JAX model + AOT).

Python is ONLY used at build time: `make artifacts` lowers the L2 graph
(which calls the L1 kernels) to HLO text that the Rust runtime loads via
PJRT. Nothing in this package runs on the simulation path.

All numerics are float64 (the paper: "IEEE 754 64-bit floating point format
without any compression on accuracy"), hence x64 is enabled on import.
"""

import jax

jax.config.update("jax_enable_x64", True)
