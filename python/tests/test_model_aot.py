"""L2 model graph + AOT lowering sanity (shapes, HLO text round-trip)."""

import jax
import jax.numpy as jnp
import numpy as np
from numpy.testing import assert_allclose

from compile import model
from compile.aot import lower_lif_step, lower_dense_net, to_hlo_text
from compile.kernels.ref import dense_net_step_ref


def _net_state(n, seed=0):
    rng = np.random.default_rng(seed)
    cfg = model.LifConfig(i_ext=450.0)  # steady-state -47 mV > v_th ⇒ fires
    u = jnp.asarray(cfg.e_l + rng.uniform(0, 14, n))
    z = jnp.zeros(n)
    w = rng.normal(scale=40.0, size=(n, n))
    w_exc = jnp.asarray(np.maximum(w, 0.0))
    w_inh = jnp.asarray(np.minimum(w, 0.0))
    return cfg, (u, z, z, z, z, w_exc, w_inh)


def test_dense_net_step_matches_ref():
    cfg, (u, ie, ii, r, s, we, wi) = _net_state(96, seed=4)
    prop = model.Propagators.from_config(cfg)
    net = model.dense_net_step(cfg, block=32)
    # seed one spike
    s = s.at[5].set(1.0)
    got = net(u, ie, ii, r, s, we, wi)
    want = dense_net_step_ref(u, ie, ii, r, s, we, wi, cfg=cfg, prop=prop)
    for g, w in zip(got, want):
        assert_allclose(np.asarray(g), np.asarray(w), rtol=1e-13, atol=1e-11)


def test_dense_net_produces_activity():
    """A recurrently-driven network must actually spike within 100 steps."""
    cfg, (u, ie, ii, r, s, we, wi) = _net_state(96, seed=5)
    net = jax.jit(model.dense_net_step(cfg, block=32))
    total = 0.0
    for _ in range(100):
        u, ie, ii, r, s = net(u, ie, ii, r, s, we, wi)
        total += float(s.sum())
    assert total > 0


def test_propagator_degenerate_equal_tau():
    cfg = model.LifConfig(tau_syn_ex=10.0, tau_m=10.0)
    p = model.Propagators.from_config(cfg)
    # limit of p21 as tau_s -> tau_m is h*exp(-h/tau)/C
    near = model.Propagators.from_config(
        model.LifConfig(tau_syn_ex=10.0 + 1e-7, tau_m=10.0))
    assert abs(p.p21e - near.p21e) < 1e-9


def test_lif_step_hlo_text_lowers():
    text = lower_lif_step(model.LifConfig(), 64)
    assert "HloModule" in text
    # interpret-mode pallas must lower to plain HLO, no mosaic custom-calls
    assert "custom-call" not in text.lower() or "mosaic" not in text.lower()
    assert "f64" in text


def test_dense_net_hlo_text_lowers():
    text = lower_dense_net(model.LifConfig(), 32)
    assert "HloModule" in text
    assert "dot(" in text  # the syn_accum contraction survives lowering


def test_manifest_contents():
    m = model.config_manifest(model.LifConfig())
    assert set(m) == {"config", "propagators"}
    assert m["propagators"]["ref_steps"] == 20
    assert 0.0 < m["propagators"]["p22"] < 1.0
