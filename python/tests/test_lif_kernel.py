"""L1 lif_step Pallas kernel vs the pure-jnp oracle (the core signal)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.model import LifConfig, Propagators
from compile.kernels.lif_step import lif_step
from compile.kernels.ref import lif_step_ref

CFGS = {
    "default": LifConfig(),
    "slow_syn": LifConfig(tau_syn_ex=2.0, tau_syn_in=4.0, i_ext=300.0),
    "equal_tau": LifConfig(tau_syn_ex=10.0, tau_syn_in=10.0, i_ext=380.0),
    "short_ref": LifConfig(t_ref=0.5, i_ext=420.0),
}


def random_state(n, rng, cfg, dtype=jnp.float64, refractory_frac=0.2):
    u = jnp.asarray(cfg.e_l + rng.uniform(0.0, 16.0, n), dtype)
    ie = jnp.asarray(rng.uniform(0.0, 400.0, n), dtype)
    ii = jnp.asarray(rng.uniform(-400.0, 0.0, n), dtype)
    r = jnp.asarray(
        (rng.random(n) < refractory_frac) * rng.integers(1, 20, n), dtype)
    in_e = jnp.asarray(rng.uniform(0.0, 150.0, n), dtype)
    in_i = jnp.asarray(-rng.uniform(0.0, 150.0, n), dtype)
    return u, ie, ii, r, in_e, in_i


@pytest.mark.parametrize("cfg_name", sorted(CFGS))
@pytest.mark.parametrize("n,block", [(256, 256), (300, 128), (7, 64), (1024, 256)])
def test_kernel_matches_ref(cfg_name, n, block):
    cfg = CFGS[cfg_name]
    prop = Propagators.from_config(cfg)
    rng = np.random.default_rng(hash((cfg_name, n)) % 2**32)
    state = random_state(n, rng, cfg)

    got = lif_step(*state, cfg=cfg, prop=prop, block=block)
    want = lif_step_ref(*state, cfg=cfg, prop=prop)
    for g, w, name in zip(got, want, ["u", "ie", "ii", "r", "spiked"]):
        assert_allclose(np.asarray(g), np.asarray(w), rtol=1e-14, atol=1e-12,
                        err_msg=name)


def test_multi_step_trajectory_matches_ref():
    """Iterating the kernel must track the oracle over a long trajectory."""
    cfg = CFGS["slow_syn"]
    prop = Propagators.from_config(cfg)
    rng = np.random.default_rng(7)
    u, ie, ii, r, _, _ = random_state(64, rng, cfg)
    ku, kie, kii, kr = u, ie, ii, r
    for t in range(200):
        in_e = jnp.asarray(rng.uniform(0.0, 100.0, 64) * (rng.random(64) < 0.3))
        in_i = jnp.asarray(-rng.uniform(0.0, 100.0, 64) * (rng.random(64) < 0.2))
        u, ie, ii, r, s_ref = lif_step_ref(u, ie, ii, r, in_e, in_i,
                                           cfg=cfg, prop=prop)
        ku, kie, kii, kr, s_k = lif_step(ku, kie, kii, kr, in_e, in_i,
                                         cfg=cfg, prop=prop, block=64)
        assert_allclose(np.asarray(ku), np.asarray(u), rtol=1e-13, atol=1e-11)
        assert (np.asarray(s_k) == np.asarray(s_ref)).all(), f"step {t}"


def test_refractory_hold_and_countdown():
    cfg = LifConfig(t_ref=0.3)  # 3 steps
    prop = Propagators.from_config(cfg)
    # huge drive: spikes immediately
    u = jnp.asarray([cfg.v_th + 1.0])
    z = jnp.zeros(1)
    u1, ie1, ii1, r1, s1 = lif_step(u, z, z, z, z, z, cfg=cfg, prop=prop, block=64)
    assert s1[0] == 1.0 and u1[0] == cfg.v_reset and r1[0] == 3.0
    # during refractoriness u holds at reset even with strong input current
    strong = jnp.asarray([1e4])
    u2, ie2, _, r2, s2 = lif_step(u1, strong, z, r1, z, z, cfg=cfg, prop=prop, block=64)
    assert s2[0] == 0.0 and u2[0] == cfg.v_reset and r2[0] == 2.0


def test_spike_threshold_exact_boundary():
    cfg = LifConfig()
    prop = Propagators.from_config(cfg)
    z = jnp.zeros(1)
    # membrane that lands exactly on v_th must spike (>= semantics)
    # solve for u0 such that e_l + (u0-e_l)*p22 == v_th
    u0 = (cfg.v_th - cfg.e_l) / prop.p22 + cfg.e_l
    u, _, _, r, s = lif_step(jnp.asarray([u0]), z, z, z, z, z,
                             cfg=cfg, prop=prop, block=64)
    assert s[0] == 1.0 and r[0] == float(prop.ref_steps)


def test_subthreshold_leak_decays_to_rest():
    cfg = LifConfig()
    prop = Propagators.from_config(cfg)
    u = jnp.asarray([cfg.e_l + 5.0] * 4)
    ie = ii = r = jnp.zeros(4)
    z = jnp.zeros(4)
    for _ in range(2000):
        u, ie, ii, r, s = lif_step(u, ie, ii, r, z, z, cfg=cfg, prop=prop, block=64)
        assert not np.any(np.asarray(s))
    assert_allclose(np.asarray(u), cfg.e_l, atol=1e-8)


def test_steady_state_under_constant_drive():
    """With constant i_ext and no spikes, u converges to e_l + tau_m*I/C."""
    cfg = LifConfig(i_ext=300.0)  # target = -65 + 10*300/250 = -53 mV < v_th
    prop = Propagators.from_config(cfg)
    u = jnp.asarray([cfg.e_l])
    z = jnp.zeros(1)
    ie = ii = r = jnp.zeros(1)
    for _ in range(5000):
        u, ie, ii, r, _ = lif_step(u, ie, ii, r, z, z, cfg=cfg, prop=prop, block=64)
    assert_allclose(float(u[0]), cfg.e_l + cfg.tau_m * cfg.i_ext / cfg.c_m,
                    atol=1e-9)


@settings(max_examples=30, deadline=None)
@given(
    n=st.integers(1, 513),
    block=st.sampled_from([32, 64, 128, 256]),
    seed=st.integers(0, 2**31),
    dtype=st.sampled_from([jnp.float32, jnp.float64]),
)
def test_hypothesis_shapes_dtypes(n, block, seed, dtype):
    """Sweep shapes/dtypes: padding must never change live-lane results."""
    cfg = CFGS["default"]
    prop = Propagators.from_config(cfg)
    rng = np.random.default_rng(seed)
    state = random_state(n, rng, cfg, dtype=dtype)
    got = lif_step(*state, cfg=cfg, prop=prop, block=block)
    want = lif_step_ref(*state, cfg=cfg, prop=prop)
    tol = dict(rtol=1e-13, atol=1e-11) if dtype == jnp.float64 else \
          dict(rtol=1e-5, atol=1e-4)
    for g, w in zip(got, want):
        assert g.dtype == dtype
        assert g.shape == (n,)
        assert_allclose(np.asarray(g), np.asarray(w), **tol)
