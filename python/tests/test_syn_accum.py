"""L1 syn_accum Pallas kernel vs the dense mat-vec oracle."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels.syn_accum import syn_accum
from compile.kernels.ref import syn_accum_ref


def test_identity_delivery():
    w = jnp.eye(16) * 2.5
    s = jnp.zeros(16).at[3].set(1.0).at[9].set(1.0)
    out = syn_accum(w, s, block=8)
    want = np.zeros(16)
    want[[3, 9]] = 2.5
    assert_allclose(np.asarray(out), want)


def test_no_spikes_no_input():
    rng = np.random.default_rng(1)
    w = jnp.asarray(rng.normal(size=(40, 24)))
    out = syn_accum(w, jnp.zeros(40), block=16)
    assert_allclose(np.asarray(out), 0.0)


def test_all_spikes_column_sums():
    rng = np.random.default_rng(2)
    w = jnp.asarray(rng.normal(size=(33, 57)))
    out = syn_accum(w, jnp.ones(33), block=16)
    assert_allclose(np.asarray(out), np.asarray(w).sum(axis=0),
                    rtol=1e-13, atol=1e-12)


def test_rectangular_multi_tile():
    rng = np.random.default_rng(3)
    w = jnp.asarray(rng.normal(size=(300, 130)))
    s = jnp.asarray((rng.random(300) < 0.05).astype(np.float64))
    out = syn_accum(w, s, block=64)
    assert_allclose(np.asarray(out), np.asarray(syn_accum_ref(w, s)),
                    rtol=1e-13, atol=1e-12)


@settings(max_examples=30, deadline=None)
@given(
    n_pre=st.integers(1, 260),
    n_post=st.integers(1, 260),
    block=st.sampled_from([16, 32, 64, 128]),
    seed=st.integers(0, 2**31),
    dtype=st.sampled_from([jnp.float32, jnp.float64]),
    density=st.floats(0.0, 1.0),
)
def test_hypothesis_shapes(n_pre, n_post, block, seed, dtype, density):
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(size=(n_pre, n_post)), dtype)
    s = jnp.asarray((rng.random(n_pre) < density).astype(np.float64), dtype)
    out = syn_accum(w, s, block=block)
    want = syn_accum_ref(w, s)
    assert out.shape == (n_post,)
    assert out.dtype == dtype
    tol = dict(rtol=1e-12, atol=1e-11) if dtype == jnp.float64 else \
          dict(rtol=1e-4, atol=1e-3)
    assert_allclose(np.asarray(out), np.asarray(want), **tol)
