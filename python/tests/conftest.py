import os
import sys

# Tests run from python/ (see Makefile) but make the layout robust anyway.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import compile  # noqa: F401  (enables jax x64 on import)
