//! Typed experiment schema on top of [`ConfigDoc`], with validation.

use super::toml::{ConfigDoc, ConfigError, Value};
use crate::model::{
    AdexParams, HhParams, LifParams, ModelParams, NeuronModel,
};

/// Which network builder to instantiate (see `atlas`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NetworkKind {
    /// Synthetic multi-area "marmoset-like" atlas (the evaluation workload).
    Marmoset,
    /// Potjans-Diesmann 2014 cortical microcircuit.
    Potjans,
    /// NEST hpc_benchmark: balanced random network with STDP (verification).
    HpcBenchmark,
    /// Uniform random network (unit tests / micro-benches).
    Random,
    /// TOML-described populations (`network.populations`), each with its
    /// own neuron model — see `atlas::custom`.
    Custom,
}

/// One `network.populations` descriptor: `"name:count:model:e|i"`.
#[derive(Clone, Debug, PartialEq)]
pub struct CustomPop {
    pub name: String,
    pub n: u32,
    pub model: NeuronModel,
    pub exc: bool,
}

impl CustomPop {
    pub fn parse(s: &str) -> Result<CustomPop, ConfigError> {
        let bad = |msg: String| ConfigError::Invalid {
            key: "network.populations".into(),
            msg,
        };
        let parts: Vec<&str> = s.split(':').collect();
        let &[name, n, model, ei] = parts.as_slice() else {
            return Err(bad(format!(
                "'{s}' is not of the form name:count:model:e|i"
            )));
        };
        let n: u32 = n
            .parse()
            .map_err(|_| bad(format!("'{n}' is not a population size")))?;
        let model = NeuronModel::parse(model).ok_or_else(|| {
            bad(format!(
                "unknown model '{model}' (expected lif|adex|hh|parrot)"
            ))
        })?;
        let exc = match ei {
            "e" | "exc" => true,
            "i" | "inh" => false,
            other => {
                return Err(bad(format!(
                    "'{other}' must be e|exc or i|inh"
                )))
            }
        };
        Ok(CustomPop { name: name.to_string(), n, model, exc })
    }
}

/// Which simulation engine to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineKind {
    /// The paper's engine: indegree sub-graph decomposition.
    Cortex,
    /// NEST-style baseline (random distribution, atomic delivery).
    NestBaseline,
}

/// Rank → neuron mapping strategy (paper Fig 8-10).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MappingKind {
    /// Area-Processes Mapping + Multisection Division with Sampling.
    AreaProcesses,
    /// Random Equivalent Mapping (the naive baseline).
    RandomEquivalent,
}

/// Neuron-dynamics backend for the CORTEX engine.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DynamicsBackend {
    /// Native Rust exact-integration LIF (mirrors the L1 kernel bitwise
    /// formulas).
    Native,
    /// AOT-compiled JAX/Pallas artifact executed via PJRT.
    Pjrt,
}

/// Inter-rank transport (`engine.transport`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CommTransport {
    /// All ranks in one process, in-memory channels (the default).
    Local,
    /// One rank per OS process, BSB frames over TCP sockets
    /// (`cortex launch` / `cortex run --rank i --peers ...`).
    Tcp,
}

/// Spike-exchange mode (paper §III.C).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CommMode {
    /// Dedicated communication thread; exchange overlaps the next window's
    /// computation.
    Overlap,
    /// Blocking exchange at each window end (the ablation baseline).
    Serialized,
}

/// Per-rank compute-thread execution backend (see `engine::workers`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecMode {
    /// Persistent worker pool: compute threads are created once per rank
    /// engine, own their state permanently, and are driven through steps
    /// by a channel protocol (the paper's long-lived compute threads).
    Pool,
    /// Ablation fallback: scoped OS threads spawned and joined every
    /// integration step (the pre-pool behaviour; measures spawn overhead).
    Scoped,
}

/// Store-construction pipeline (`engine.build`, see `decomp::store`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BuildMode {
    /// Two-pass thread-parallel builder streaming edges straight into
    /// each thread's exact-capacity CSR (~1.5× final store at peak).
    TwoPass,
    /// Ablation fallback: the single-threaded staging builder (holds
    /// three edge copies at peak; measures what streaming removes).
    Serial,
}

/// Spike-exchange routing policy (`engine.routing`, see `comm`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RoutingMode {
    /// Interest-routed exchange: each rank sends every peer only the
    /// spikes that peer's sub-graph subscribes to, using subscription
    /// sets shipped rank-to-rank during build. Bit-identical to
    /// broadcast — unsubscribed spikes are dropped receive-side anyway.
    Routed,
    /// Ablation fallback: the full allgather of every rank's packet to
    /// every peer (measures what interest routing saves on the wire).
    Broadcast,
    /// Two-level exchange: ranks are partitioned into host groups
    /// (`engine.comm_group`), each group's relay rank merges its
    /// members' routed packets into one multi-source frame per
    /// destination group (see `comm::hier`). Bit-identical to `routed`;
    /// trades per-peer frames for per-group frames.
    Hierarchical,
}

/// Integrate-kernel formulation (`engine.integrate`, see `model`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum IntegrateMode {
    /// Branch-free, run-segmented kernels: propagator lookups hoisted
    /// over homogeneous `pidx` runs, refractory/threshold handling as
    /// select arithmetic with spike-mask compaction. Bit-identical to
    /// the scalar formulation.
    Vector,
    /// Ablation fallback: the original per-neuron branching kernels
    /// (measures what the branch-free rewrite buys).
    Scalar,
}

/// Fully-validated experiment description.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    pub title: String,
    pub seed: u64,

    // [network]
    pub network: NetworkKind,
    pub n_neurons: usize,
    pub n_areas: usize,
    pub indegree: usize,
    pub plastic: bool,
    /// Neuron model of excitatory / inhibitory populations
    /// (`network.model` sets both; `network.model_e` / `network.model_i`
    /// override individually — mixed circuits fall out of that).
    pub model_e: NeuronModel,
    pub model_i: NeuronModel,
    /// `kind = "custom"` population descriptors.
    pub custom_pops: Vec<CustomPop>,
    /// Synaptic scaffold knobs of the custom builder.
    pub weight_pa: f64,
    pub g: f64,
    pub bg_rate_hz: f64,

    // [model.lif] / [model.adex] / [model.hh] parameter tables
    pub lif: LifParams,
    pub adex: AdexParams,
    pub hh: HhParams,

    // [sim]
    pub dt_ms: f64,
    pub sim_ms: f64,
    pub record_raster: bool,
    pub record_limit: usize,

    // [engine]
    pub engine: EngineKind,
    pub ranks: usize,
    pub threads: usize,
    pub mapping: MappingKind,
    pub backend: DynamicsBackend,
    pub comm: CommMode,
    pub exec: ExecMode,
    pub build: BuildMode,
    pub integrate: IntegrateMode,
    pub routing: RoutingMode,
    pub artifacts_dir: String,
    /// Inter-rank transport: in-process channels or TCP processes.
    pub transport: CommTransport,
    /// Global rank this process hosts (`engine.rank` / `--rank`;
    /// TCP transport only).
    pub tcp_rank: Option<usize>,
    /// Rank-ordered listen addresses of the TCP cluster
    /// (`engine.peers` / `--peers`); must have exactly `ranks` entries.
    pub peers: Vec<String>,
    /// Per-rank host-group ids for the hierarchical exchange
    /// (`engine.comm_group`); empty = auto groups of two consecutive
    /// ranks when `engine.routing = "hierarchical"`.
    pub comm_group: Vec<usize>,

    // [serve]
    pub serve: ServeConfig,

    // [sweep]
    pub sweep: SweepConfig,
}

/// `[serve]` — the `cortex serve` daemon's listen address and
/// admission-control quotas. All keys have defaults, so any experiment
/// config doubles as a daemon config.
#[derive(Clone, Debug, PartialEq)]
pub struct ServeConfig {
    /// `serve.addr` — daemon listen address (`--addr` overrides).
    pub addr: String,
    /// `serve.max_sessions` — hosted sessions, active + suspended.
    pub max_sessions: usize,
    /// `serve.thread_budget` — shared worker-thread pool all active
    /// sessions draw from (one session costs `ranks × threads`).
    pub thread_budget: usize,
    /// `serve.max_session_threads` — per-session worker-thread cap;
    /// `0` means "bounded only by the shared budget".
    pub max_session_threads: usize,
    /// `serve.memory_budget_mb` — resident-state budget across active
    /// sessions plus suspended checkpoint blobs; `0` disables the
    /// memory gate.
    pub memory_budget_mb: usize,
    /// `serve.idle_suspend_ms` — suspend sessions idle this long to
    /// checkpoint blobs (threads reclaimed); `0` disables the sweep.
    pub idle_suspend_ms: u64,
    /// `serve.spill_dir` — directory suspended-session checkpoint
    /// blobs spill to (one file per session, deleted on resume/close);
    /// empty keeps blobs on the heap.
    pub spill_dir: String,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            addr: "127.0.0.1:9077".into(),
            max_sessions: 8,
            thread_budget: 16,
            max_session_threads: 0,
            memory_budget_mb: 0,
            idle_suspend_ms: 0,
            spill_dir: String::new(),
        }
    }
}

fn serve_config_from(doc: &ConfigDoc) -> Result<ServeConfig, ConfigError> {
    let d = ServeConfig::default();
    Ok(ServeConfig {
        addr: doc.str("serve.addr", &d.addr)?,
        max_sessions: doc.usize("serve.max_sessions", d.max_sessions)?,
        thread_budget: doc
            .usize("serve.thread_budget", d.thread_budget)?,
        max_session_threads: doc.usize(
            "serve.max_session_threads",
            d.max_session_threads,
        )?,
        memory_budget_mb: doc
            .usize("serve.memory_budget_mb", d.memory_budget_mb)?,
        idle_suspend_ms: doc
            .usize("serve.idle_suspend_ms", d.idle_suspend_ms as usize)?
            as u64,
        spill_dir: doc.str("serve.spill_dir", &d.spill_dir)?,
    })
}

/// One `sweep.dc` axis point: `"POP:dc_pa"`.
#[derive(Clone, Debug, PartialEq)]
pub struct SweepDc {
    pub pop: String,
    pub dc_pa: f64,
}

/// One `sweep.poisson` axis point: `"POP:rate_hz:weight_pa"`.
#[derive(Clone, Debug, PartialEq)]
pub struct SweepPoisson {
    pub pop: String,
    pub rate_hz: f64,
    pub weight_pa: f64,
}

/// `[sweep]` — the trajectory grid `cortex sweep` runs over one shared
/// network build: the cartesian product of `seeds × dc × poisson`
/// (empty axes contribute a single "no override" point).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SweepConfig {
    /// `sweep.steps` — steps per trajectory (default: `sim.sim_ms`).
    pub steps: Option<u64>,
    /// `sweep.parallel` — trajectories stepped concurrently
    /// (`0` = one at a time).
    pub parallel: usize,
    /// `sweep.seeds` — Poisson drive seeds (default: the config seed).
    pub seeds: Vec<u64>,
    /// `sweep.dc` — DC-offset axis, `"POP:dc_pa"` per point.
    pub dc: Vec<SweepDc>,
    /// `sweep.poisson` — Poisson-drive axis, `"POP:rate_hz:weight_pa"`.
    pub poisson: Vec<SweepPoisson>,
}

impl SweepConfig {
    /// Trajectory count of the grid.
    pub fn n_trajectories(&self) -> usize {
        self.seeds.len().max(1)
            * self.dc.len().max(1)
            * self.poisson.len().max(1)
    }
}

fn sweep_config_from(doc: &ConfigDoc) -> Result<SweepConfig, ConfigError> {
    let steps = match doc.get("sweep.steps") {
        None => None,
        Some(v) => Some(
            v.as_i64().filter(|x| *x > 0).ok_or(ConfigError::Type {
                key: "sweep.steps".into(),
                expected: "positive integer",
            })? as u64,
        ),
    };
    let seeds = match doc.get("sweep.seeds") {
        None => Vec::new(),
        Some(Value::Array(items)) => items
            .iter()
            .map(|v| {
                v.as_i64().filter(|x| *x >= 0).map(|x| x as u64).ok_or(
                    ConfigError::Type {
                        key: "sweep.seeds".into(),
                        expected: "array of non-negative integers",
                    },
                )
            })
            .collect::<Result<_, _>>()?,
        Some(_) => {
            return Err(ConfigError::Type {
                key: "sweep.seeds".into(),
                expected: "array of non-negative integers",
            })
        }
    };
    let dc = parse_str_axis(doc, "sweep.dc")?
        .into_iter()
        .map(|s| parse_sweep_dc(&s))
        .collect::<Result<_, _>>()?;
    let poisson = parse_str_axis(doc, "sweep.poisson")?
        .into_iter()
        .map(|s| parse_sweep_poisson(&s))
        .collect::<Result<_, _>>()?;
    Ok(SweepConfig {
        steps,
        parallel: doc.usize("sweep.parallel", 0)?,
        seeds,
        dc,
        poisson,
    })
}

fn parse_str_axis(
    doc: &ConfigDoc,
    key: &str,
) -> Result<Vec<String>, ConfigError> {
    match doc.get(key) {
        None => Ok(Vec::new()),
        Some(Value::Array(items)) => items
            .iter()
            .map(|v| {
                v.as_str().map(str::to_string).ok_or(ConfigError::Type {
                    key: key.into(),
                    expected: "array of strings",
                })
            })
            .collect(),
        Some(_) => Err(ConfigError::Type {
            key: key.into(),
            expected: "array of strings",
        }),
    }
}

fn parse_sweep_dc(s: &str) -> Result<SweepDc, ConfigError> {
    let bad = || ConfigError::Invalid {
        key: "sweep.dc".into(),
        msg: format!("'{s}' is not of the form POP:dc_pa"),
    };
    let (pop, dc) = s.split_once(':').ok_or_else(bad)?;
    if pop.is_empty() {
        return Err(bad());
    }
    let dc_pa: f64 = dc.parse().map_err(|_| bad())?;
    Ok(SweepDc { pop: pop.to_string(), dc_pa })
}

fn parse_sweep_poisson(s: &str) -> Result<SweepPoisson, ConfigError> {
    let bad = || ConfigError::Invalid {
        key: "sweep.poisson".into(),
        msg: format!("'{s}' is not of the form POP:rate_hz:weight_pa"),
    };
    let parts: Vec<&str> = s.split(':').collect();
    let &[pop, rate, weight] = parts.as_slice() else {
        return Err(bad());
    };
    if pop.is_empty() {
        return Err(bad());
    }
    let rate_hz: f64 = rate.parse().map_err(|_| bad())?;
    let weight_pa: f64 = weight.parse().map_err(|_| bad())?;
    if !(rate_hz >= 0.0) {
        return Err(bad());
    }
    Ok(SweepPoisson { pop: pop.to_string(), rate_hz, weight_pa })
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            title: "untitled".into(),
            seed: 20240710,
            network: NetworkKind::Marmoset,
            n_neurons: 10_000,
            n_areas: 8,
            indegree: 250,
            plastic: false,
            model_e: NeuronModel::Lif,
            model_i: NeuronModel::Lif,
            custom_pops: Vec::new(),
            weight_pa: 87.8,
            g: 4.0,
            bg_rate_hz: 8000.0,
            lif: LifParams::default(),
            adex: AdexParams::default(),
            hh: HhParams::default(),
            dt_ms: 0.1,
            sim_ms: 100.0,
            record_raster: false,
            record_limit: 1000,
            engine: EngineKind::Cortex,
            ranks: 4,
            threads: 3,
            mapping: MappingKind::AreaProcesses,
            backend: DynamicsBackend::Native,
            comm: CommMode::Overlap,
            exec: ExecMode::Pool,
            build: BuildMode::TwoPass,
            integrate: IntegrateMode::Vector,
            routing: RoutingMode::Routed,
            artifacts_dir: "artifacts".into(),
            transport: CommTransport::Local,
            tcp_rank: None,
            peers: Vec::new(),
            comm_group: Vec::new(),
            serve: ServeConfig::default(),
            sweep: SweepConfig::default(),
        }
    }
}

impl ExperimentConfig {
    pub fn from_doc(doc: &ConfigDoc) -> Result<Self, ConfigError> {
        let d = ExperimentConfig::default();
        let cfg = ExperimentConfig {
            title: doc.str("title", &d.title)?,
            seed: doc.usize("seed", d.seed as usize)? as u64,
            network: parse_enum(
                doc,
                "network.kind",
                "marmoset",
                &[
                    ("marmoset", NetworkKind::Marmoset),
                    ("potjans", NetworkKind::Potjans),
                    ("hpc_benchmark", NetworkKind::HpcBenchmark),
                    ("random", NetworkKind::Random),
                    ("custom", NetworkKind::Custom),
                ],
            )?,
            n_neurons: doc.usize("network.n_neurons", d.n_neurons)?,
            n_areas: doc.usize("network.n_areas", d.n_areas)?,
            indegree: doc.usize("network.indegree", d.indegree)?,
            plastic: doc.bool("network.plastic", d.plastic)?,
            model_e: parse_model(doc, "network.model_e")?,
            model_i: parse_model(doc, "network.model_i")?,
            custom_pops: parse_custom_pops(doc)?,
            weight_pa: doc.f64("network.weight_pa", d.weight_pa)?,
            g: doc.f64("network.g", d.g)?,
            bg_rate_hz: doc.f64("network.bg_rate_hz", d.bg_rate_hz)?,
            lif: lif_params_from(doc)?,
            adex: adex_params_from(doc)?,
            hh: hh_params_from(doc)?,
            dt_ms: doc.f64("sim.dt_ms", d.dt_ms)?,
            sim_ms: doc.f64("sim.sim_ms", d.sim_ms)?,
            record_raster: doc.bool("sim.record_raster", d.record_raster)?,
            record_limit: doc.usize("sim.record_limit", d.record_limit)?,
            engine: parse_enum(
                doc,
                "engine.kind",
                "cortex",
                &[
                    ("cortex", EngineKind::Cortex),
                    ("nest_baseline", EngineKind::NestBaseline),
                ],
            )?,
            ranks: doc.usize("engine.ranks", d.ranks)?,
            threads: doc.usize("engine.threads", d.threads)?,
            mapping: parse_enum(
                doc,
                "engine.mapping",
                "area_processes",
                &[
                    ("area_processes", MappingKind::AreaProcesses),
                    ("random_equivalent", MappingKind::RandomEquivalent),
                ],
            )?,
            backend: parse_enum(
                doc,
                "engine.backend",
                "native",
                &[
                    ("native", DynamicsBackend::Native),
                    ("pjrt", DynamicsBackend::Pjrt),
                ],
            )?,
            comm: parse_enum(
                doc,
                "engine.comm",
                "overlap",
                &[
                    ("overlap", CommMode::Overlap),
                    ("serialized", CommMode::Serialized),
                ],
            )?,
            exec: parse_enum(
                doc,
                "engine.exec",
                "pool",
                &[
                    ("pool", ExecMode::Pool),
                    ("scoped", ExecMode::Scoped),
                ],
            )?,
            build: parse_enum(
                doc,
                "engine.build",
                "two_pass",
                &[
                    ("two_pass", BuildMode::TwoPass),
                    ("serial", BuildMode::Serial),
                ],
            )?,
            integrate: parse_enum(
                doc,
                "engine.integrate",
                "vector",
                &[
                    ("vector", IntegrateMode::Vector),
                    ("scalar", IntegrateMode::Scalar),
                ],
            )?,
            routing: parse_enum(
                doc,
                "engine.routing",
                "routed",
                &[
                    ("routed", RoutingMode::Routed),
                    ("broadcast", RoutingMode::Broadcast),
                    ("hierarchical", RoutingMode::Hierarchical),
                ],
            )?,
            artifacts_dir: doc.str("engine.artifacts_dir", &d.artifacts_dir)?,
            transport: parse_enum(
                doc,
                "engine.transport",
                "local",
                &[
                    ("local", CommTransport::Local),
                    ("tcp", CommTransport::Tcp),
                ],
            )?,
            tcp_rank: parse_tcp_rank(doc)?,
            peers: parse_peers(doc)?,
            comm_group: parse_comm_group(doc)?,
            serve: serve_config_from(doc)?,
            sweep: sweep_config_from(doc)?,
        };
        // the custom-builder scaffold knobs are not wired into the
        // parametric builders (which have their own calibrated values) —
        // reject rather than silently ignore them
        if cfg.network != NetworkKind::Custom {
            for key in [
                "network.populations",
                "network.weight_pa",
                "network.g",
                "network.bg_rate_hz",
            ] {
                if doc.get(key).is_some() {
                    return Err(ConfigError::Invalid {
                        key: key.into(),
                        msg: "only used by network.kind = \"custom\""
                            .into(),
                    });
                }
            }
        }
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<(), ConfigError> {
        let bad = |key: &str, msg: &str| {
            Err(ConfigError::Invalid { key: key.into(), msg: msg.into() })
        };
        if self.n_neurons == 0 {
            return bad("network.n_neurons", "must be > 0");
        }
        // the custom builder sizes itself from its population list
        // (multapses make any indegree well-defined); n_neurons-based
        // bounds apply to the parametric builders only
        if self.network != NetworkKind::Custom
            && self.indegree >= self.n_neurons
        {
            return bad("network.indegree", "must be < n_neurons");
        }
        if self.network == NetworkKind::Custom {
            if self.custom_pops.is_empty() {
                return bad(
                    "network.populations",
                    "kind = \"custom\" needs at least one population \
                     descriptor (\"name:count:model:e|i\")",
                );
            }
            if self.custom_pops.iter().any(|p| p.n == 0) {
                return bad("network.populations", "population size 0");
            }
        }
        if self.hh.substeps == 0 {
            return bad("model.hh.substeps", "must be >= 1");
        }
        if self.n_areas == 0 {
            return bad("network.n_areas", "must be > 0");
        }
        if !(self.dt_ms > 0.0) {
            return bad("sim.dt_ms", "must be > 0");
        }
        if !(self.sim_ms >= self.dt_ms) {
            return bad("sim.sim_ms", "must cover at least one step");
        }
        if self.ranks == 0 || self.ranks > u16::MAX as usize {
            return bad("engine.ranks", "must be in 1..65535");
        }
        if self.threads == 0 {
            return bad("engine.threads", "must be > 0");
        }
        if self.transport == CommTransport::Tcp {
            if self.peers.is_empty() {
                return bad(
                    "engine.peers",
                    "tcp transport needs a rank-ordered \"host:port\" \
                     address list",
                );
            }
            if self.peers.len() != self.ranks {
                return bad(
                    "engine.peers",
                    "must list exactly engine.ranks addresses",
                );
            }
            if let Some(r) = self.tcp_rank {
                if r >= self.peers.len() {
                    return bad(
                        "engine.rank",
                        "must index the engine.peers list",
                    );
                }
            }
            if self.engine == EngineKind::NestBaseline {
                return bad(
                    "engine.transport",
                    "nest_baseline supports only the local transport",
                );
            }
        } else if self.tcp_rank.is_some() || !self.peers.is_empty() {
            return bad(
                "engine.rank",
                "engine.rank / engine.peers are only used with \
                 engine.transport = \"tcp\"",
            );
        }
        if !self.comm_group.is_empty() {
            if self.routing != RoutingMode::Hierarchical {
                return bad(
                    "engine.comm_group",
                    "only used with engine.routing = \"hierarchical\"",
                );
            }
            if self.comm_group.len() != self.ranks {
                return bad(
                    "engine.comm_group",
                    "must assign a group id to every engine.ranks rank",
                );
            }
            // group ids must be contiguous from zero (each group gets
            // a relay; an empty group would elect nobody)
            let n_groups =
                self.comm_group.iter().copied().max().unwrap_or(0) + 1;
            let mut seen = vec![false; n_groups];
            for &g in &self.comm_group {
                seen[g] = true;
            }
            if seen.iter().any(|s| !s) {
                return bad(
                    "engine.comm_group",
                    "group ids must be contiguous from zero",
                );
            }
        }
        if self.serve.addr.is_empty() {
            return bad("serve.addr", "must be a host:port address");
        }
        if self.serve.max_sessions == 0 {
            return bad("serve.max_sessions", "must be > 0");
        }
        if self.serve.thread_budget == 0 {
            return bad("serve.thread_budget", "must be > 0");
        }
        if self.serve.max_session_threads > self.serve.thread_budget {
            return bad(
                "serve.max_session_threads",
                "cannot exceed serve.thread_budget",
            );
        }
        if let Some(steps) = self.sweep.steps {
            if steps == 0 {
                return bad("sweep.steps", "must be > 0");
            }
        }
        Ok(())
    }

    pub fn steps(&self) -> u64 {
        (self.sim_ms / self.dt_ms).round() as u64
    }

    /// The configured parameter set of a neuron model (the `[model.*]`
    /// tables with defaults filled in).
    pub fn model_params(&self, m: NeuronModel) -> ModelParams {
        match m {
            NeuronModel::Lif => ModelParams::Lif(self.lif),
            NeuronModel::Adex => ModelParams::Adex(self.adex),
            NeuronModel::Hh => ModelParams::Hh(self.hh),
            NeuronModel::Parrot => ModelParams::Parrot,
        }
    }
}

/// `network.model` sets both population types; `network.model_e` /
/// `network.model_i` override individually.
fn parse_model(
    doc: &ConfigDoc,
    key: &str,
) -> Result<NeuronModel, ConfigError> {
    let both = doc.str("network.model", "lif")?;
    let s = doc.str(key, &both)?;
    NeuronModel::parse(&s).ok_or_else(|| ConfigError::Invalid {
        key: key.into(),
        msg: format!(
            "unknown neuron model '{s}' (expected lif|adex|hh|parrot)"
        ),
    })
}

/// `engine.rank` — optional (the launcher's parent config omits it and
/// each spawned process supplies its own via `--rank`).
fn parse_tcp_rank(
    doc: &ConfigDoc,
) -> Result<Option<usize>, ConfigError> {
    match doc.get("engine.rank") {
        None => Ok(None),
        Some(v) => v
            .as_i64()
            .filter(|x| *x >= 0)
            .map(|x| Some(x as usize))
            .ok_or(ConfigError::Type {
                key: "engine.rank".into(),
                expected: "non-negative integer",
            }),
    }
}

/// `engine.peers` — rank-ordered `"host:port"` strings.
fn parse_peers(doc: &ConfigDoc) -> Result<Vec<String>, ConfigError> {
    match doc.get("engine.peers") {
        None => Ok(Vec::new()),
        Some(Value::Array(items)) => items
            .iter()
            .map(|v| {
                v.as_str().map(str::to_string).ok_or(ConfigError::Type {
                    key: "engine.peers".into(),
                    expected: "array of \"host:port\" strings",
                })
            })
            .collect(),
        Some(_) => Err(ConfigError::Type {
            key: "engine.peers".into(),
            expected: "array of \"host:port\" strings",
        }),
    }
}

/// `engine.comm_group` — per-rank host-group ids of the hierarchical
/// exchange (index = rank, value = group).
fn parse_comm_group(
    doc: &ConfigDoc,
) -> Result<Vec<usize>, ConfigError> {
    match doc.get("engine.comm_group") {
        None => Ok(Vec::new()),
        Some(Value::Array(items)) => items
            .iter()
            .map(|v| {
                v.as_i64().filter(|x| *x >= 0).map(|x| x as usize).ok_or(
                    ConfigError::Type {
                        key: "engine.comm_group".into(),
                        expected: "array of non-negative integers",
                    },
                )
            })
            .collect(),
        Some(_) => Err(ConfigError::Type {
            key: "engine.comm_group".into(),
            expected: "array of non-negative integers",
        }),
    }
}

fn parse_custom_pops(
    doc: &ConfigDoc,
) -> Result<Vec<CustomPop>, ConfigError> {
    match doc.get("network.populations") {
        None => Ok(Vec::new()),
        Some(Value::Array(items)) => items
            .iter()
            .map(|v| {
                let s = v.as_str().ok_or(ConfigError::Type {
                    key: "network.populations".into(),
                    expected: "array of \"name:count:model:e|i\" strings",
                })?;
                CustomPop::parse(s)
            })
            .collect(),
        Some(_) => Err(ConfigError::Type {
            key: "network.populations".into(),
            expected: "array of \"name:count:model:e|i\" strings",
        }),
    }
}

fn lif_params_from(doc: &ConfigDoc) -> Result<LifParams, ConfigError> {
    let d = LifParams::default();
    Ok(LifParams {
        tau_m: doc.f64("model.lif.tau_m", d.tau_m)?,
        tau_syn_ex: doc.f64("model.lif.tau_syn_ex", d.tau_syn_ex)?,
        tau_syn_in: doc.f64("model.lif.tau_syn_in", d.tau_syn_in)?,
        c_m: doc.f64("model.lif.c_m", d.c_m)?,
        e_l: doc.f64("model.lif.e_l", d.e_l)?,
        v_reset: doc.f64("model.lif.v_reset", d.v_reset)?,
        v_th: doc.f64("model.lif.v_th", d.v_th)?,
        t_ref: doc.f64("model.lif.t_ref", d.t_ref)?,
        i_ext: doc.f64("model.lif.i_ext", d.i_ext)?,
    })
}

fn adex_params_from(doc: &ConfigDoc) -> Result<AdexParams, ConfigError> {
    let d = AdexParams::default();
    Ok(AdexParams {
        c_m: doc.f64("model.adex.c_m", d.c_m)?,
        g_l: doc.f64("model.adex.g_l", d.g_l)?,
        e_l: doc.f64("model.adex.e_l", d.e_l)?,
        v_t: doc.f64("model.adex.v_t", d.v_t)?,
        delta_t: doc.f64("model.adex.delta_t", d.delta_t)?,
        tau_w: doc.f64("model.adex.tau_w", d.tau_w)?,
        a: doc.f64("model.adex.a", d.a)?,
        b: doc.f64("model.adex.b", d.b)?,
        v_reset: doc.f64("model.adex.v_reset", d.v_reset)?,
        v_peak: doc.f64("model.adex.v_peak", d.v_peak)?,
        t_ref: doc.f64("model.adex.t_ref", d.t_ref)?,
        tau_syn_ex: doc.f64("model.adex.tau_syn_ex", d.tau_syn_ex)?,
        tau_syn_in: doc.f64("model.adex.tau_syn_in", d.tau_syn_in)?,
        i_ext: doc.f64("model.adex.i_ext", d.i_ext)?,
    })
}

fn hh_params_from(doc: &ConfigDoc) -> Result<HhParams, ConfigError> {
    let d = HhParams::default();
    Ok(HhParams {
        c_m: doc.f64("model.hh.c_m", d.c_m)?,
        g_na: doc.f64("model.hh.g_na", d.g_na)?,
        g_k: doc.f64("model.hh.g_k", d.g_k)?,
        g_l: doc.f64("model.hh.g_l", d.g_l)?,
        e_na: doc.f64("model.hh.e_na", d.e_na)?,
        e_k: doc.f64("model.hh.e_k", d.e_k)?,
        e_l: doc.f64("model.hh.e_l", d.e_l)?,
        v_spike: doc.f64("model.hh.v_spike", d.v_spike)?,
        substeps: doc.usize("model.hh.substeps", d.substeps as usize)?
            as u32,
        tau_syn_ex: doc.f64("model.hh.tau_syn_ex", d.tau_syn_ex)?,
        tau_syn_in: doc.f64("model.hh.tau_syn_in", d.tau_syn_in)?,
        i_ext: doc.f64("model.hh.i_ext", d.i_ext)?,
        syn_scale: doc.f64("model.hh.syn_scale", d.syn_scale)?,
    })
}

fn parse_enum<T: Copy>(
    doc: &ConfigDoc,
    key: &str,
    default: &str,
    table: &[(&str, T)],
) -> Result<T, ConfigError> {
    let s = doc.str(key, default)?;
    table
        .iter()
        .find(|(name, _)| *name == s)
        .map(|(_, v)| *v)
        .ok_or_else(|| ConfigError::Invalid {
            key: key.into(),
            msg: format!(
                "unknown variant '{s}' (expected one of {:?})",
                table.iter().map(|(n, _)| *n).collect::<Vec<_>>()
            ),
        })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_from_empty_doc() {
        let doc = ConfigDoc::parse("").unwrap();
        let cfg = ExperimentConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.network, NetworkKind::Marmoset);
        assert_eq!(cfg.engine, EngineKind::Cortex);
        assert_eq!(cfg.steps(), 1000);
    }

    #[test]
    fn full_file() {
        let doc = ConfigDoc::parse(
            r#"
title = "verify"
seed = 7
[network]
kind = "hpc_benchmark"
n_neurons = 2250
indegree = 200
plastic = true
[sim]
dt_ms = 0.1
sim_ms = 50
[engine]
kind = "cortex"
ranks = 2
threads = 2
mapping = "random_equivalent"
backend = "native"
comm = "serialized"
"#,
        )
        .unwrap();
        let cfg = ExperimentConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.network, NetworkKind::HpcBenchmark);
        assert!(cfg.plastic);
        assert_eq!(cfg.mapping, MappingKind::RandomEquivalent);
        assert_eq!(cfg.comm, CommMode::Serialized);
        assert_eq!(cfg.steps(), 500);
    }

    #[test]
    fn exec_mode_parses_and_defaults_to_pool() {
        let doc = ConfigDoc::parse("").unwrap();
        let cfg = ExperimentConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.exec, ExecMode::Pool);
        let doc = ConfigDoc::parse("[engine]\nexec = \"scoped\"").unwrap();
        let cfg = ExperimentConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.exec, ExecMode::Scoped);
        let doc = ConfigDoc::parse("[engine]\nexec = \"forked\"").unwrap();
        assert!(ExperimentConfig::from_doc(&doc).is_err());
    }

    #[test]
    fn build_mode_parses_and_defaults_to_two_pass() {
        let doc = ConfigDoc::parse("").unwrap();
        let cfg = ExperimentConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.build, BuildMode::TwoPass);
        let doc =
            ConfigDoc::parse("[engine]\nbuild = \"serial\"").unwrap();
        let cfg = ExperimentConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.build, BuildMode::Serial);
        let doc =
            ConfigDoc::parse("[engine]\nbuild = \"staged\"").unwrap();
        assert!(ExperimentConfig::from_doc(&doc).is_err());
    }

    #[test]
    fn integrate_mode_parses_and_defaults_to_vector() {
        let doc = ConfigDoc::parse("").unwrap();
        let cfg = ExperimentConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.integrate, IntegrateMode::Vector);
        let doc =
            ConfigDoc::parse("[engine]\nintegrate = \"scalar\"").unwrap();
        let cfg = ExperimentConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.integrate, IntegrateMode::Scalar);
        let doc =
            ConfigDoc::parse("[engine]\nintegrate = \"simd\"").unwrap();
        assert!(ExperimentConfig::from_doc(&doc).is_err());
    }

    #[test]
    fn routing_mode_parses_and_defaults_to_routed() {
        let doc = ConfigDoc::parse("").unwrap();
        let cfg = ExperimentConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.routing, RoutingMode::Routed);
        let doc =
            ConfigDoc::parse("[engine]\nrouting = \"broadcast\"").unwrap();
        let cfg = ExperimentConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.routing, RoutingMode::Broadcast);
        let doc =
            ConfigDoc::parse("[engine]\nrouting = \"multicast\"").unwrap();
        assert!(ExperimentConfig::from_doc(&doc).is_err());
    }

    #[test]
    fn comm_group_parses_and_validates() {
        // default: empty assignment (auto-grouped downstream)
        let doc = ConfigDoc::parse("").unwrap();
        let cfg = ExperimentConfig::from_doc(&doc).unwrap();
        assert!(cfg.comm_group.is_empty());

        let doc = ConfigDoc::parse(
            "[engine]\nrouting = \"hierarchical\"\nranks = 4\n\
             comm_group = [0, 0, 1, 1]",
        )
        .unwrap();
        let cfg = ExperimentConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.routing, RoutingMode::Hierarchical);
        assert_eq!(cfg.comm_group, vec![0, 0, 1, 1]);

        // hierarchical without an assignment is fine (auto groups)
        let doc = ConfigDoc::parse(
            "[engine]\nrouting = \"hierarchical\"\nranks = 4",
        )
        .unwrap();
        assert!(ExperimentConfig::from_doc(&doc).is_ok());

        // wrong length, non-contiguous ids, wrong routing mode,
        // non-integer entries: all rejected
        for toml in [
            "[engine]\nrouting = \"hierarchical\"\nranks = 4\n\
             comm_group = [0, 0, 1]",
            "[engine]\nrouting = \"hierarchical\"\nranks = 4\n\
             comm_group = [0, 0, 2, 2]",
            "[engine]\nranks = 4\ncomm_group = [0, 0, 1, 1]",
            "[engine]\nrouting = \"hierarchical\"\nranks = 2\n\
             comm_group = [0, -1]",
            "[engine]\nrouting = \"hierarchical\"\nranks = 2\n\
             comm_group = \"both\"",
        ] {
            let doc = ConfigDoc::parse(toml).unwrap();
            assert!(
                ExperimentConfig::from_doc(&doc).is_err(),
                "expected rejection: {toml}"
            );
        }
    }

    #[test]
    fn validation_errors() {
        for (k, v) in [
            ("network.n_neurons", "0"),
            ("network.indegree", "999999"),
            ("sim.dt_ms", "0.0"),
            ("engine.ranks", "0"),
            ("engine.threads", "0"),
        ] {
            let doc = ConfigDoc::parse(&format!(
                "[{}]\n{} = {}",
                k.split('.').next().unwrap(),
                k.split('.').nth(1).unwrap(),
                v
            ))
            .unwrap();
            assert!(
                ExperimentConfig::from_doc(&doc).is_err(),
                "expected error for {k}={v}"
            );
        }
    }

    #[test]
    fn tcp_transport_parses_and_validates() {
        // defaults to local
        let doc = ConfigDoc::parse("").unwrap();
        let cfg = ExperimentConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.transport, CommTransport::Local);
        assert_eq!(cfg.tcp_rank, None);
        assert!(cfg.peers.is_empty());

        // a complete tcp config
        let doc = ConfigDoc::parse(
            r#"
[engine]
transport = "tcp"
ranks = 2
rank = 1
peers = ["127.0.0.1:7001", "127.0.0.1:7002"]
"#,
        )
        .unwrap();
        let cfg = ExperimentConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.transport, CommTransport::Tcp);
        assert_eq!(cfg.tcp_rank, Some(1));
        assert_eq!(cfg.peers.len(), 2);

        // tcp without peers is rejected
        let doc =
            ConfigDoc::parse("[engine]\ntransport = \"tcp\"").unwrap();
        assert!(ExperimentConfig::from_doc(&doc).is_err());
        // peer-count / rank-count mismatch is rejected
        let doc = ConfigDoc::parse(
            "[engine]\ntransport = \"tcp\"\nranks = 3\n\
             peers = [\"a:1\", \"b:2\"]",
        )
        .unwrap();
        assert!(ExperimentConfig::from_doc(&doc).is_err());
        // rank outside the peer list is rejected
        let doc = ConfigDoc::parse(
            "[engine]\ntransport = \"tcp\"\nranks = 2\nrank = 7\n\
             peers = [\"a:1\", \"b:2\"]",
        )
        .unwrap();
        assert!(ExperimentConfig::from_doc(&doc).is_err());
        // tcp-only keys on the local transport are rejected
        let doc = ConfigDoc::parse("[engine]\nrank = 0").unwrap();
        assert!(ExperimentConfig::from_doc(&doc).is_err());
        // nest_baseline cannot run distributed
        let doc = ConfigDoc::parse(
            "[engine]\nkind = \"nest_baseline\"\ntransport = \"tcp\"\n\
             ranks = 2\npeers = [\"a:1\", \"b:2\"]",
        )
        .unwrap();
        assert!(ExperimentConfig::from_doc(&doc).is_err());
    }

    #[test]
    fn sweep_section_parses_and_validates() {
        // empty doc: one-trajectory default grid, heap-resident serve
        let doc = ConfigDoc::parse("").unwrap();
        let cfg = ExperimentConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.sweep, SweepConfig::default());
        assert_eq!(cfg.sweep.n_trajectories(), 1);
        assert!(cfg.serve.spill_dir.is_empty());

        let doc = ConfigDoc::parse(
            r#"
[sweep]
steps = 200
parallel = 2
seeds = [1, 2, 3]
dc = ["L5E:30", "L5E:-12.5"]
poisson = ["E:8000:87.8"]
[serve]
spill_dir = "/tmp/spill"
"#,
        )
        .unwrap();
        let cfg = ExperimentConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.sweep.steps, Some(200));
        assert_eq!(cfg.sweep.parallel, 2);
        assert_eq!(cfg.sweep.seeds, vec![1, 2, 3]);
        assert_eq!(
            cfg.sweep.dc[1],
            SweepDc { pop: "L5E".into(), dc_pa: -12.5 }
        );
        assert_eq!(
            cfg.sweep.poisson[0],
            SweepPoisson {
                pop: "E".into(),
                rate_hz: 8000.0,
                weight_pa: 87.8
            }
        );
        // seeds × dc × poisson
        assert_eq!(cfg.sweep.n_trajectories(), 6);
        assert_eq!(cfg.serve.spill_dir, "/tmp/spill");

        // malformed axes are rejected
        for bad in [
            "[sweep]\nsteps = 0",
            "[sweep]\nseeds = [-1]",
            "[sweep]\nseeds = \"1\"",
            "[sweep]\ndc = [\"L5E\"]",
            "[sweep]\ndc = [\"L5E:x\"]",
            "[sweep]\ndc = [\":30\"]",
            "[sweep]\npoisson = [\"E:8000\"]",
            "[sweep]\npoisson = [\"E:-1:87.8\"]",
        ] {
            let doc = ConfigDoc::parse(bad).unwrap();
            assert!(
                ExperimentConfig::from_doc(&doc).is_err(),
                "expected error for {bad}"
            );
        }
    }

    #[test]
    fn unknown_enum_variant() {
        let doc = ConfigDoc::parse("[engine]\nbackend = \"cuda\"").unwrap();
        let err = ExperimentConfig::from_doc(&doc).unwrap_err();
        assert!(format!("{err}").contains("cuda"));
    }

    #[test]
    fn model_knobs_default_to_lif_and_cascade() {
        let doc = ConfigDoc::parse("").unwrap();
        let cfg = ExperimentConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.model_e, NeuronModel::Lif);
        assert_eq!(cfg.model_i, NeuronModel::Lif);

        // network.model sets both …
        let doc =
            ConfigDoc::parse("[network]\nmodel = \"adex\"").unwrap();
        let cfg = ExperimentConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.model_e, NeuronModel::Adex);
        assert_eq!(cfg.model_i, NeuronModel::Adex);

        // … and model_e / model_i override individually (mixed circuit)
        let doc = ConfigDoc::parse(
            "[network]\nmodel = \"lif\"\nmodel_e = \"adex\"",
        )
        .unwrap();
        let cfg = ExperimentConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.model_e, NeuronModel::Adex);
        assert_eq!(cfg.model_i, NeuronModel::Lif);

        let doc =
            ConfigDoc::parse("[network]\nmodel = \"izhikevich\"").unwrap();
        assert!(ExperimentConfig::from_doc(&doc).is_err());
    }

    #[test]
    fn model_parameter_tables_override_defaults() {
        let doc = ConfigDoc::parse(
            r#"
[model.adex]
b = 120.0
tau_w = 200.0
[model.hh]
substeps = 20
[model.lif]
tau_m = 15.0
"#,
        )
        .unwrap();
        let cfg = ExperimentConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.adex.b, 120.0);
        assert_eq!(cfg.adex.tau_w, 200.0);
        assert_eq!(cfg.adex.a, AdexParams::default().a);
        assert_eq!(cfg.hh.substeps, 20);
        assert_eq!(cfg.lif.tau_m, 15.0);
        let ModelParams::Adex(a) = cfg.model_params(NeuronModel::Adex)
        else {
            panic!()
        };
        assert_eq!(a.b, 120.0);
    }

    #[test]
    fn custom_population_descriptors_parse() {
        let doc = ConfigDoc::parse(
            r#"
[network]
kind = "custom"
indegree = 50
populations = ["E:400:adex:e", "I:100:lif:i", "S:20:parrot:e"]
"#,
        )
        .unwrap();
        let cfg = ExperimentConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.network, NetworkKind::Custom);
        assert_eq!(cfg.custom_pops.len(), 3);
        assert_eq!(
            cfg.custom_pops[0],
            CustomPop {
                name: "E".into(),
                n: 400,
                model: NeuronModel::Adex,
                exc: true
            }
        );
        assert!(!cfg.custom_pops[1].exc);
        assert_eq!(cfg.custom_pops[2].model, NeuronModel::Parrot);

        // custom without populations is rejected
        let doc =
            ConfigDoc::parse("[network]\nkind = \"custom\"").unwrap();
        assert!(ExperimentConfig::from_doc(&doc).is_err());
        // custom-scaffold knobs on a parametric builder are rejected
        // rather than silently ignored
        for knob in ["g = 8.0", "weight_pa = 50.0", "bg_rate_hz = 1.0"] {
            let doc = ConfigDoc::parse(&format!("[network]\n{knob}"))
                .unwrap();
            assert!(
                ExperimentConfig::from_doc(&doc).is_err(),
                "{knob} should be custom-only"
            );
        }
        // frozen-network guard
        let doc = ConfigDoc::parse("[model.hh]\nsubsteps = 0").unwrap();
        assert!(ExperimentConfig::from_doc(&doc).is_err());
        // malformed descriptor is rejected
        for bad in
            ["E:400:adex", "E:x:lif:e", "E:400:foo:e", "E:400:lif:q"]
        {
            assert!(
                CustomPop::parse(bad).is_err(),
                "descriptor '{bad}' should be rejected"
            );
        }
    }
}
