//! Typed experiment schema on top of [`ConfigDoc`], with validation.

use super::toml::{ConfigDoc, ConfigError};

/// Which network builder to instantiate (see `atlas`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NetworkKind {
    /// Synthetic multi-area "marmoset-like" atlas (the evaluation workload).
    Marmoset,
    /// Potjans-Diesmann 2014 cortical microcircuit.
    Potjans,
    /// NEST hpc_benchmark: balanced random network with STDP (verification).
    HpcBenchmark,
    /// Uniform random network (unit tests / micro-benches).
    Random,
}

/// Which simulation engine to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineKind {
    /// The paper's engine: indegree sub-graph decomposition.
    Cortex,
    /// NEST-style baseline (random distribution, atomic delivery).
    NestBaseline,
}

/// Rank → neuron mapping strategy (paper Fig 8-10).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MappingKind {
    /// Area-Processes Mapping + Multisection Division with Sampling.
    AreaProcesses,
    /// Random Equivalent Mapping (the naive baseline).
    RandomEquivalent,
}

/// Neuron-dynamics backend for the CORTEX engine.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DynamicsBackend {
    /// Native Rust exact-integration LIF (mirrors the L1 kernel bitwise
    /// formulas).
    Native,
    /// AOT-compiled JAX/Pallas artifact executed via PJRT.
    Pjrt,
}

/// Spike-exchange mode (paper §III.C).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CommMode {
    /// Dedicated communication thread; exchange overlaps the next window's
    /// computation.
    Overlap,
    /// Blocking exchange at each window end (the ablation baseline).
    Serialized,
}

/// Per-rank compute-thread execution backend (see `engine::workers`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecMode {
    /// Persistent worker pool: compute threads are created once per rank
    /// engine, own their state permanently, and are driven through steps
    /// by a channel protocol (the paper's long-lived compute threads).
    Pool,
    /// Ablation fallback: scoped OS threads spawned and joined every
    /// integration step (the pre-pool behaviour; measures spawn overhead).
    Scoped,
}

/// Fully-validated experiment description.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    pub title: String,
    pub seed: u64,

    // [network]
    pub network: NetworkKind,
    pub n_neurons: usize,
    pub n_areas: usize,
    pub indegree: usize,
    pub plastic: bool,

    // [sim]
    pub dt_ms: f64,
    pub sim_ms: f64,
    pub record_raster: bool,
    pub record_limit: usize,

    // [engine]
    pub engine: EngineKind,
    pub ranks: usize,
    pub threads: usize,
    pub mapping: MappingKind,
    pub backend: DynamicsBackend,
    pub comm: CommMode,
    pub exec: ExecMode,
    pub artifacts_dir: String,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            title: "untitled".into(),
            seed: 20240710,
            network: NetworkKind::Marmoset,
            n_neurons: 10_000,
            n_areas: 8,
            indegree: 250,
            plastic: false,
            dt_ms: 0.1,
            sim_ms: 100.0,
            record_raster: false,
            record_limit: 1000,
            engine: EngineKind::Cortex,
            ranks: 4,
            threads: 3,
            mapping: MappingKind::AreaProcesses,
            backend: DynamicsBackend::Native,
            comm: CommMode::Overlap,
            exec: ExecMode::Pool,
            artifacts_dir: "artifacts".into(),
        }
    }
}

impl ExperimentConfig {
    pub fn from_doc(doc: &ConfigDoc) -> Result<Self, ConfigError> {
        let d = ExperimentConfig::default();
        let cfg = ExperimentConfig {
            title: doc.str("title", &d.title)?,
            seed: doc.usize("seed", d.seed as usize)? as u64,
            network: parse_enum(
                doc,
                "network.kind",
                "marmoset",
                &[
                    ("marmoset", NetworkKind::Marmoset),
                    ("potjans", NetworkKind::Potjans),
                    ("hpc_benchmark", NetworkKind::HpcBenchmark),
                    ("random", NetworkKind::Random),
                ],
            )?,
            n_neurons: doc.usize("network.n_neurons", d.n_neurons)?,
            n_areas: doc.usize("network.n_areas", d.n_areas)?,
            indegree: doc.usize("network.indegree", d.indegree)?,
            plastic: doc.bool("network.plastic", d.plastic)?,
            dt_ms: doc.f64("sim.dt_ms", d.dt_ms)?,
            sim_ms: doc.f64("sim.sim_ms", d.sim_ms)?,
            record_raster: doc.bool("sim.record_raster", d.record_raster)?,
            record_limit: doc.usize("sim.record_limit", d.record_limit)?,
            engine: parse_enum(
                doc,
                "engine.kind",
                "cortex",
                &[
                    ("cortex", EngineKind::Cortex),
                    ("nest_baseline", EngineKind::NestBaseline),
                ],
            )?,
            ranks: doc.usize("engine.ranks", d.ranks)?,
            threads: doc.usize("engine.threads", d.threads)?,
            mapping: parse_enum(
                doc,
                "engine.mapping",
                "area_processes",
                &[
                    ("area_processes", MappingKind::AreaProcesses),
                    ("random_equivalent", MappingKind::RandomEquivalent),
                ],
            )?,
            backend: parse_enum(
                doc,
                "engine.backend",
                "native",
                &[
                    ("native", DynamicsBackend::Native),
                    ("pjrt", DynamicsBackend::Pjrt),
                ],
            )?,
            comm: parse_enum(
                doc,
                "engine.comm",
                "overlap",
                &[
                    ("overlap", CommMode::Overlap),
                    ("serialized", CommMode::Serialized),
                ],
            )?,
            exec: parse_enum(
                doc,
                "engine.exec",
                "pool",
                &[
                    ("pool", ExecMode::Pool),
                    ("scoped", ExecMode::Scoped),
                ],
            )?,
            artifacts_dir: doc.str("engine.artifacts_dir", &d.artifacts_dir)?,
        };
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<(), ConfigError> {
        let bad = |key: &str, msg: &str| {
            Err(ConfigError::Invalid { key: key.into(), msg: msg.into() })
        };
        if self.n_neurons == 0 {
            return bad("network.n_neurons", "must be > 0");
        }
        if self.indegree >= self.n_neurons {
            return bad("network.indegree", "must be < n_neurons");
        }
        if self.n_areas == 0 {
            return bad("network.n_areas", "must be > 0");
        }
        if !(self.dt_ms > 0.0) {
            return bad("sim.dt_ms", "must be > 0");
        }
        if !(self.sim_ms >= self.dt_ms) {
            return bad("sim.sim_ms", "must cover at least one step");
        }
        if self.ranks == 0 || self.ranks > u16::MAX as usize {
            return bad("engine.ranks", "must be in 1..65535");
        }
        if self.threads == 0 {
            return bad("engine.threads", "must be > 0");
        }
        Ok(())
    }

    pub fn steps(&self) -> u64 {
        (self.sim_ms / self.dt_ms).round() as u64
    }
}

fn parse_enum<T: Copy>(
    doc: &ConfigDoc,
    key: &str,
    default: &str,
    table: &[(&str, T)],
) -> Result<T, ConfigError> {
    let s = doc.str(key, default)?;
    table
        .iter()
        .find(|(name, _)| *name == s)
        .map(|(_, v)| *v)
        .ok_or_else(|| ConfigError::Invalid {
            key: key.into(),
            msg: format!(
                "unknown variant '{s}' (expected one of {:?})",
                table.iter().map(|(n, _)| *n).collect::<Vec<_>>()
            ),
        })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_from_empty_doc() {
        let doc = ConfigDoc::parse("").unwrap();
        let cfg = ExperimentConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.network, NetworkKind::Marmoset);
        assert_eq!(cfg.engine, EngineKind::Cortex);
        assert_eq!(cfg.steps(), 1000);
    }

    #[test]
    fn full_file() {
        let doc = ConfigDoc::parse(
            r#"
title = "verify"
seed = 7
[network]
kind = "hpc_benchmark"
n_neurons = 2250
indegree = 200
plastic = true
[sim]
dt_ms = 0.1
sim_ms = 50
[engine]
kind = "cortex"
ranks = 2
threads = 2
mapping = "random_equivalent"
backend = "native"
comm = "serialized"
"#,
        )
        .unwrap();
        let cfg = ExperimentConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.network, NetworkKind::HpcBenchmark);
        assert!(cfg.plastic);
        assert_eq!(cfg.mapping, MappingKind::RandomEquivalent);
        assert_eq!(cfg.comm, CommMode::Serialized);
        assert_eq!(cfg.steps(), 500);
    }

    #[test]
    fn exec_mode_parses_and_defaults_to_pool() {
        let doc = ConfigDoc::parse("").unwrap();
        let cfg = ExperimentConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.exec, ExecMode::Pool);
        let doc = ConfigDoc::parse("[engine]\nexec = \"scoped\"").unwrap();
        let cfg = ExperimentConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.exec, ExecMode::Scoped);
        let doc = ConfigDoc::parse("[engine]\nexec = \"forked\"").unwrap();
        assert!(ExperimentConfig::from_doc(&doc).is_err());
    }

    #[test]
    fn validation_errors() {
        for (k, v) in [
            ("network.n_neurons", "0"),
            ("network.indegree", "999999"),
            ("sim.dt_ms", "0.0"),
            ("engine.ranks", "0"),
            ("engine.threads", "0"),
        ] {
            let doc = ConfigDoc::parse(&format!(
                "[{}]\n{} = {}",
                k.split('.').next().unwrap(),
                k.split('.').nth(1).unwrap(),
                v
            ))
            .unwrap();
            assert!(
                ExperimentConfig::from_doc(&doc).is_err(),
                "expected error for {k}={v}"
            );
        }
    }

    #[test]
    fn unknown_enum_variant() {
        let doc = ConfigDoc::parse("[engine]\nbackend = \"cuda\"").unwrap();
        let err = ExperimentConfig::from_doc(&doc).unwrap_err();
        assert!(format!("{err}").contains("cuda"));
    }
}
