//! Experiment configuration: a from-scratch TOML-subset parser plus the
//! typed experiment schema used by the `cortex` launcher.
//!
//! Supported syntax (covers all files in `configs/`): `[section.sub]`
//! headers, `key = value` with strings, integers, floats, booleans, and
//! flat arrays; `#` comments. Keys are exposed as dotted paths
//! (`network.n_neurons`).

mod schema;
mod toml;

pub use schema::{
    BuildMode, CommMode, CommTransport, CustomPop, DynamicsBackend,
    EngineKind, ExecMode, ExperimentConfig, IntegrateMode, MappingKind,
    NetworkKind, RoutingMode, ServeConfig, SweepConfig, SweepDc,
    SweepPoisson,
};
pub use toml::{ConfigDoc, ConfigError, Value};
