//! TOML-subset parser producing a flat dotted-path → [`Value`] map.

use std::collections::BTreeMap;

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<Value>),
}

impl Value {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(x) => Some(*x),
            Value::Int(x) => Some(*x as f64),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

#[derive(Debug)]
pub enum ConfigError {
    Parse { line: usize, msg: String },
    Missing(String),
    Type { key: String, expected: &'static str },
    Invalid { key: String, msg: String },
    Io(std::io::Error),
}

impl std::fmt::Display for ConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ConfigError::Parse { line, msg } => {
                write!(f, "config parse error on line {line}: {msg}")
            }
            ConfigError::Missing(key) => write!(f, "missing key '{key}'"),
            ConfigError::Type { key, expected } => {
                write!(f, "key '{key}': expected {expected}")
            }
            ConfigError::Invalid { key, msg } => {
                write!(f, "key '{key}': {msg}")
            }
            ConfigError::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for ConfigError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ConfigError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for ConfigError {
    fn from(e: std::io::Error) -> ConfigError {
        ConfigError::Io(e)
    }
}

/// Parsed document: dotted path → value.
#[derive(Clone, Debug, Default)]
pub struct ConfigDoc {
    map: BTreeMap<String, Value>,
}

impl ConfigDoc {
    pub fn parse(text: &str) -> Result<ConfigDoc, ConfigError> {
        let mut map = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            let err = |msg: &str| ConfigError::Parse {
                line: lineno + 1,
                msg: msg.to_string(),
            };
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| err("unterminated section header"))?
                    .trim();
                if name.is_empty() {
                    return Err(err("empty section name"));
                }
                section = name.to_string();
            } else if let Some(eq) = line.find('=') {
                let key = line[..eq].trim();
                if key.is_empty() {
                    return Err(err("empty key"));
                }
                let val = parse_value(line[eq + 1..].trim())
                    .map_err(|m| err(&m))?;
                let path = if section.is_empty() {
                    key.to_string()
                } else {
                    format!("{section}.{key}")
                };
                map.insert(path, val);
            } else {
                return Err(err("expected 'key = value' or '[section]'"));
            }
        }
        Ok(ConfigDoc { map })
    }

    pub fn load(path: &std::path::Path) -> Result<ConfigDoc, ConfigError> {
        ConfigDoc::parse(&std::fs::read_to_string(path)?)
    }

    /// Apply `key=value` command-line overrides on top of the file.
    pub fn apply_overrides(&mut self, overrides: &[String]) -> Result<(), ConfigError> {
        for ov in overrides {
            let Some(eq) = ov.find('=') else {
                return Err(ConfigError::Invalid {
                    key: ov.clone(),
                    msg: "override must be key=value".into(),
                });
            };
            let key = ov[..eq].trim().to_string();
            let val = parse_value(ov[eq + 1..].trim()).map_err(|m| {
                ConfigError::Invalid { key: key.clone(), msg: m }
            })?;
            self.map.insert(key, val);
        }
        Ok(())
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.map.get(key)
    }

    pub fn keys(&self) -> impl Iterator<Item = &String> {
        self.map.keys()
    }

    pub fn f64(&self, key: &str, default: f64) -> Result<f64, ConfigError> {
        match self.map.get(key) {
            None => Ok(default),
            Some(v) => v.as_f64().ok_or(ConfigError::Type {
                key: key.into(),
                expected: "number",
            }),
        }
    }

    pub fn usize(&self, key: &str, default: usize) -> Result<usize, ConfigError> {
        match self.map.get(key) {
            None => Ok(default),
            Some(v) => match v.as_i64() {
                Some(x) if x >= 0 => Ok(x as usize),
                _ => Err(ConfigError::Type {
                    key: key.into(),
                    expected: "non-negative integer",
                }),
            },
        }
    }

    pub fn bool(&self, key: &str, default: bool) -> Result<bool, ConfigError> {
        match self.map.get(key) {
            None => Ok(default),
            Some(v) => v.as_bool().ok_or(ConfigError::Type {
                key: key.into(),
                expected: "bool",
            }),
        }
    }

    pub fn str(&self, key: &str, default: &str) -> Result<String, ConfigError> {
        match self.map.get(key) {
            None => Ok(default.to_string()),
            Some(v) => v
                .as_str()
                .map(str::to_string)
                .ok_or(ConfigError::Type { key: key.into(), expected: "string" }),
        }
    }
}

fn strip_comment(line: &str) -> &str {
    // a '#' inside a quoted string does not start a comment
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest
            .strip_suffix('"')
            .ok_or_else(|| "unterminated string".to_string())?;
        return Ok(Value::Str(inner.to_string()));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(rest) = s.strip_prefix('[') {
        let inner = rest
            .strip_suffix(']')
            .ok_or_else(|| "unterminated array".to_string())?
            .trim();
        if inner.is_empty() {
            return Ok(Value::Array(vec![]));
        }
        let items: Result<Vec<Value>, String> =
            inner.split(',').map(|p| parse_value(p.trim())).collect();
        return Ok(Value::Array(items?));
    }
    // numbers: int if it parses as i64 and has no '.', 'e', 'E'
    if !s.contains(['.', 'e', 'E']) {
        if let Ok(i) = s.parse::<i64>() {
            return Ok(Value::Int(i));
        }
    }
    s.parse::<f64>()
        .map(Value::Float)
        .map_err(|_| format!("cannot parse value '{s}'"))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# experiment file
title = "fig18"           # inline comment
[network]
n_neurons = 20000
indegree = 500
scale = 1.5
plastic = false
sizes = [0.25, 0.5, 1, 2]
[engine]
threads = 3
backend = "native"
"#;

    #[test]
    fn parse_sections_and_types() {
        let doc = ConfigDoc::parse(SAMPLE).unwrap();
        assert_eq!(doc.str("title", "").unwrap(), "fig18");
        assert_eq!(doc.usize("network.n_neurons", 0).unwrap(), 20000);
        assert_eq!(doc.f64("network.scale", 0.0).unwrap(), 1.5);
        assert!(!doc.bool("network.plastic", true).unwrap());
        assert_eq!(doc.str("engine.backend", "").unwrap(), "native");
        let Value::Array(a) = doc.get("network.sizes").unwrap() else {
            panic!()
        };
        assert_eq!(a.len(), 4);
        assert_eq!(a[2], Value::Int(1));
    }

    #[test]
    fn defaults_and_type_errors() {
        let doc = ConfigDoc::parse(SAMPLE).unwrap();
        assert_eq!(doc.usize("missing.key", 7).unwrap(), 7);
        assert!(doc.usize("title", 0).is_err()); // string, not int
        assert!(doc.f64("engine.backend", 0.0).is_err());
    }

    #[test]
    fn overrides() {
        let mut doc = ConfigDoc::parse(SAMPLE).unwrap();
        doc.apply_overrides(&[
            "network.n_neurons=99".to_string(),
            "engine.backend=\"pjrt\"".to_string(),
        ])
        .unwrap();
        assert_eq!(doc.usize("network.n_neurons", 0).unwrap(), 99);
        assert_eq!(doc.str("engine.backend", "").unwrap(), "pjrt");
        assert!(doc.apply_overrides(&["nonsense".to_string()]).is_err());
    }

    #[test]
    fn parse_errors_carry_line_numbers() {
        let e = ConfigDoc::parse("a = 1\nbad line\n").unwrap_err();
        let msg = format!("{e}");
        assert!(msg.contains("line 2"), "{msg}");
        assert!(ConfigDoc::parse("[unterminated\n").is_err());
        assert!(ConfigDoc::parse("k = \"open\n").is_err());
        assert!(ConfigDoc::parse("= 3\n").is_err());
    }

    #[test]
    fn hash_inside_string_is_not_comment() {
        let doc = ConfigDoc::parse("k = \"a#b\"").unwrap();
        assert_eq!(doc.str("k", "").unwrap(), "a#b");
    }

    #[test]
    fn negative_and_scientific_numbers() {
        let doc = ConfigDoc::parse("a = -5\nb = -2.5e-3\nc = 1e4").unwrap();
        assert_eq!(doc.get("a").unwrap().as_i64(), Some(-5));
        assert!((doc.f64("b", 0.0).unwrap() + 0.0025).abs() < 1e-15);
        assert_eq!(doc.f64("c", 0.0).unwrap(), 1e4);
    }
}
