//! # CORTEX — large-scale spiking-network brain simulator
//!
//! Reproduction of *"CORTEX: Large-Scale Brain Simulator Utilizing Indegree
//! Sub-Graph Decomposition on Fugaku Supercomputer"* (Lyu et al., cs.DC 2024)
//! as a three-layer Rust + JAX + Pallas system.  This crate is Layer 3: the
//! paper's coordination contribution plus every substrate it depends on.
//!
//! Module map (see DESIGN.md for the full inventory):
//!
//! - [`graph`]  — directed-graph abstraction of SNNs, indegree/outdegree
//!   sub-graph triplets and their ⊼ / ⊻ algebra (paper §II.A).
//! - [`atlas`]  — connectome builders: synthetic multi-area "marmoset"
//!   atlas, Potjans-Diesmann 2014 microcircuit, NEST `hpc_benchmark`,
//!   TOML-described custom circuits — all with per-population neuron
//!   models.
//! - [`model`]  — the dynamics layer: LIF with exact integration
//!   (Rotter-Diesmann propagators identical to the L1 Pallas kernel),
//!   AdEx, Hodgkin-Huxley and parrot relays behind the enum-dispatched
//!   [`model::dynamics::PopulationState`] SoA interface; STDP synapses;
//!   Poisson sources.
//! - [`decomp`] — the paper's §III.A: Area-Processes Mapping, Multisection
//!   Division with Sampling, Random Equivalent Mapping (baseline), thread
//!   partitioning and the (thread, delay)-sorted edge layout.
//! - [`engine`] — the per-rank CORTEX engine: a persistent worker pool of
//!   long-lived compute threads over permanently-owned disjoint state
//!   (paper §III.B), mutex-free delivery, spike ring buffers, native or
//!   PJRT dynamics, windowed overlap exchange, checkpointing — and the
//!   public facade, the persistent [`engine::Simulation`] session
//!   (`engine::session`): rank engines built once on session-owned
//!   threads, repeated `run_for` calls, mid-run stimulus control,
//!   session-wide checkpoint/restore. [`engine::run_simulation`] is a
//!   thin one-shot wrapper over it. The ownership model splits each
//!   worker into shared read-only topology (`Arc<RankStore>`) and
//!   mutable per-trajectory state, so an [`engine::Ensemble`] builds
//!   the network **once** and instantiates N cheap trajectories
//!   (seed/stimulus variations; `cortex sweep` and the `[sweep]`
//!   config section drive it from the CLI).
//! - [`probe`]  — pluggable per-rank observers drained through the
//!   session: spike rasters with gid/population filters, population
//!   firing rates, membrane-voltage traces, STDP weight snapshots,
//!   phase-timer streams.
//! - [`comm`]   — MPI-like communicator over in-memory ranks **or TCP
//!   sockets between OS processes** (`cortex launch` / `cortex run
//!   --rank`), spike exchange with dedicated communication thread
//!   (paper §III.C): broadcast, interest-routed per-peer frames, or
//!   hierarchical two-level relay merge over host groups
//!   (`engine.comm_group`) with an in-process fast path for co-located
//!   ranks; the fallible BSB wire codec (varint delta coding,
//!   window-counter verification, merged multi-source frames), and a
//!   Tofu-D network cost model for Fugaku-scale projections.
//! - [`nest_baseline`] — a NEST-style reference engine embodying the design
//!   choices the paper compares against (random distribution, atomic
//!   delivery, serialized exchange).
//! - [`runtime`] — XLA/PJRT loading + execution of the AOT artifacts
//!   produced by `python/compile/aot.py`.
//! - [`serve`]  — `cortex serve`, the resident multi-session daemon:
//!   many concurrent [`engine::Simulation`] sessions behind a
//!   versioned length-prefixed control protocol with typed admission
//!   control against `[serve]` thread/memory quotas, server-push
//!   probe streaming, and suspend-to-blob — optionally spilled to
//!   disk via `serve.spill_dir` — with transparent resume
//!   (plus the [`serve::Client`] behind `cortex client`).
//! - [`config`], [`metrics`], [`util`], [`cli`] — experiment configuration,
//!   instrumentation and the from-scratch support substrates (the build is
//!   fully offline: `anyhow` and `xla` are vendored path crates under
//!   `rust/vendor/`, the latter a compile-only PJRT stub).
//!
//! # Quickstart: a simulation session
//!
//! ```
//! use std::sync::Arc;
//! use cortex::atlas::random_spec;
//! use cortex::engine::Simulation;
//! use cortex::probe::{PopRates, SpikeRaster};
//!
//! # fn main() -> anyhow::Result<()> {
//! let spec = Arc::new(random_spec(400, 40, 7));
//! let mut sim = Simulation::builder(Arc::clone(&spec))
//!     .ranks(2)
//!     .threads(2)
//!     .probe(SpikeRaster::pops("e_raster", &["E"]))
//!     .probe(PopRates::new("rates", 100))
//!     .build()?;
//!
//! sim.run_for(200)?;                       // 20 ms at dt = 0.1 ms
//! let before = sim.drain("rates")?;        // per-population Hz, binned
//! sim.set_poisson("E", 12_000.0, 87.8)?;   // steer the stimulus …
//! sim.run_for(200)?;                       // … and keep simulating
//! let after = sim.drain("rates")?;
//! let raster = sim.drain("e_raster")?.into_raster()?;
//! let out = sim.finish()?;                 // classic merged RunOutput
//! # let _ = (before, after, raster, out);
//! # Ok(())
//! # }
//! ```

pub mod atlas;
pub mod cli;
pub mod comm;
pub mod config;
pub mod decomp;
pub mod engine;
pub mod graph;
pub mod metrics;
pub mod model;
pub mod nest_baseline;
pub mod probe;
pub mod runtime;
pub mod serve;
pub mod util;

/// Global neuron id.
pub type Gid = u32;
/// Rank (simulated MPI process) id.
pub type RankId = u16;
/// Thread id within a rank.
pub type ThreadId = u16;
/// Synaptic delay in integration steps (>= 1).
pub type DelaySteps = u16;
/// Simulation step counter.
pub type Step = u64;
