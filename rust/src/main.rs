//! `cortex` — launcher binary. See `cortex::cli` for subcommands.

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = cortex::cli::main_with(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
