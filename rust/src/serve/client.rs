//! Typed client for the `cortex serve` control protocol.
//!
//! One [`Client`] is one connection: a hello exchange at connect, then
//! strictly request → (push frames) → final reply. Admission refusals
//! surface as errors carrying a downcastable
//! [`AdmissionError`](super::proto::AdmissionError); server-side
//! simulation failures surface as plain errors. The `cortex client`
//! subcommand is a thin argv wrapper over these methods, which keeps
//! the daemon scriptable from CI shell jobs and usable as a library
//! from tests.

use std::net::TcpStream;

use anyhow::{anyhow, bail, Context, Error, Result};

use crate::probe::ProbeData;

use super::proto::{
    self, ProbeSpec, Reply, Request, ServeStats,
};

/// A connected control-protocol endpoint.
pub struct Client {
    stream: TcpStream,
}

impl Client {
    /// Connect and exchange hellos (magic + protocol version).
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr)
            .with_context(|| format!("connecting to {addr}"))?;
        // command/reply turnaround dominates; don't batch tiny frames
        let _ = stream.set_nodelay(true);
        let mut client = Client { stream };
        proto::send_hello(&mut client.stream)?;
        proto::expect_hello(&mut client.stream)?;
        Ok(client)
    }

    /// One request/reply exchange, collecting any push frames that
    /// precede the final reply.
    fn call(
        &mut self,
        req: &Request,
    ) -> Result<(Vec<(String, ProbeData)>, Reply)> {
        proto::write_frame(&mut self.stream, &proto::encode_request(req))?;
        let mut pushes = Vec::new();
        loop {
            let frame = proto::read_frame(&mut self.stream)?;
            match proto::decode_reply(&frame)? {
                Reply::Push { probe, data, .. } => {
                    pushes.push((probe, data))
                }
                reply => return Ok((pushes, reply)),
            }
        }
    }

    /// Map the two failure replies to errors; refusals keep the typed
    /// [`AdmissionError`](super::proto::AdmissionError) downcastable.
    fn finish(reply: Reply) -> Result<Reply> {
        match reply {
            Reply::Refused(adm) => {
                Err(Error::new(adm).context("admission refused"))
            }
            Reply::Error(msg) => Err(anyhow!("server error: {msg}")),
            other => Ok(other),
        }
    }

    /// Create a session from a TOML document plus `key=value`
    /// overrides (the launcher's config surface) and a probe list.
    pub fn create(
        &mut self,
        doc: &str,
        overrides: &[String],
        probes: &[ProbeSpec],
    ) -> Result<u64> {
        let (_, reply) = self.call(&Request::Create {
            doc: doc.to_string(),
            overrides: overrides.to_vec(),
            probes: probes.to_vec(),
        })?;
        match Self::finish(reply)? {
            Reply::Created { session } => Ok(session),
            other => bail!("unexpected create reply: {other:?}"),
        }
    }

    /// Advance `steps`; with `push`, returns every probe's drained
    /// data as streamed by the server.
    pub fn run(
        &mut self,
        session: u64,
        steps: u64,
        push: bool,
    ) -> Result<(u64, Vec<(String, ProbeData)>)> {
        let (pushes, reply) =
            self.call(&Request::Run { session, steps, push })?;
        match Self::finish(reply)? {
            Reply::Ran { step, .. } => Ok((step, pushes)),
            other => bail!("unexpected run reply: {other:?}"),
        }
    }

    /// Drain one probe by name.
    pub fn drain(
        &mut self,
        session: u64,
        probe: &str,
    ) -> Result<ProbeData> {
        let (_, reply) = self.call(&Request::Drain {
            session,
            probe: probe.to_string(),
        })?;
        match Self::finish(reply)? {
            Reply::Data { data, .. } => Ok(data),
            other => bail!("unexpected drain reply: {other:?}"),
        }
    }

    pub fn set_poisson(
        &mut self,
        session: u64,
        pop: &str,
        rate_hz: f64,
        weight_pa: f64,
    ) -> Result<()> {
        let (_, reply) = self.call(&Request::Poisson {
            session,
            pop: pop.to_string(),
            rate_hz,
            weight_pa,
        })?;
        Self::expect_ok(reply)
    }

    pub fn set_dc(
        &mut self,
        session: u64,
        pop: &str,
        dc_pa: f64,
    ) -> Result<()> {
        let (_, reply) = self.call(&Request::Dc {
            session,
            pop: pop.to_string(),
            dc_pa,
        })?;
        Self::expect_ok(reply)
    }

    /// Park the session as a checkpoint blob (threads reclaimed).
    pub fn suspend(&mut self, session: u64) -> Result<()> {
        let (_, reply) = self.call(&Request::Suspend { session })?;
        Self::expect_ok(reply)
    }

    /// Rebuild a suspended session now. Optional — any session
    /// command resumes transparently — but lets a script pay the
    /// rebuild cost at a chosen time.
    pub fn resume(&mut self, session: u64) -> Result<()> {
        let (_, reply) = self.call(&Request::Resume { session })?;
        Self::expect_ok(reply)
    }

    /// Fetch the session's checkpoint container bytes.
    pub fn checkpoint(&mut self, session: u64) -> Result<Vec<u8>> {
        let (_, reply) = self.call(&Request::Checkpoint { session })?;
        match Self::finish(reply)? {
            Reply::Blob(bytes) => Ok(bytes),
            other => bail!("unexpected checkpoint reply: {other:?}"),
        }
    }

    pub fn close(&mut self, session: u64) -> Result<()> {
        let (_, reply) = self.call(&Request::Close { session })?;
        Self::expect_ok(reply)
    }

    pub fn stats(&mut self) -> Result<ServeStats> {
        let (_, reply) = self.call(&Request::Stats)?;
        match Self::finish(reply)? {
            Reply::Stats(stats) => Ok(stats),
            other => bail!("unexpected stats reply: {other:?}"),
        }
    }

    /// Ask the daemon to exit its serve loop.
    pub fn shutdown(&mut self) -> Result<()> {
        let (_, reply) = self.call(&Request::Shutdown)?;
        Self::expect_ok(reply)
    }

    fn expect_ok(reply: Reply) -> Result<()> {
        match Self::finish(reply)? {
            Reply::Ok => Ok(()),
            other => bail!("unexpected reply: {other:?}"),
        }
    }
}
