//! Multi-session admission control and lifecycle for `cortex serve`.
//!
//! The [`SessionManager`] owns every hosted [`Simulation`] and meters
//! two shared quotas from the `[serve]` config: a worker-thread budget
//! (one session costs `ranks × threads` rank threads) and an optional
//! resident-memory budget. Memory is measured post-build from the
//! engine's own separable accounting
//! ([`Simulation::memory_split`]: shared topology bytes — the CSR
//! rank store — plus per-trajectory state bytes), plus any suspended
//! checkpoint blobs still held on the heap. A request the quotas
//! cannot cover is refused with a typed [`AdmissionError`] — the
//! caller can retry after `close`/`suspend`, distinguishing "over
//! budget" from a hard failure.
//!
//! Suspended blobs normally stay heap-resident and count against the
//! memory budget. With `[serve] spill_dir` set, suspend writes the
//! CORTEX3 blob to `<spill_dir>/session-<id>.ckpt` instead — the
//! session then costs zero resident bytes until resumed. Spill files
//! are deleted on resume and on close.
//!
//! Concurrency model: connection threads `checkout` a session (its
//! slot is marked busy), drive it **outside** the manager lock — long
//! `run_for` calls on one session never block commands to another —
//! and `checkin` when done. A command addressed to a busy session
//! fails fast instead of queueing.
//!
//! Suspend/resume: `suspend` drains every probe into a parked carry
//! list, snapshots the session to a CORTEX3 blob
//! ([`Simulation::checkpoint`]) and tears the rank threads down; only
//! the blob stays resident. `checkout` of a suspended session rebuilds
//! it transparently via [`SimulationBuilder::restore`] (re-running
//! admission, since the quotas may have been claimed meanwhile) and
//! re-attaches the parked probe data, so a drain after resume returns
//! exactly what an uninterrupted session would have.
//!
//! [`SimulationBuilder::restore`]: crate::engine::SimulationBuilder::restore

use std::collections::HashMap;
use std::io::Cursor;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, ensure, Result};

use crate::atlas::NetworkSpec;
use crate::config::{
    CommTransport, ConfigDoc, EngineKind, ExperimentConfig, ServeConfig,
};
use crate::engine::{RunConfig, Simulation};
use crate::probe::{PhaseStream, PopRates, ProbeData, SpikeRaster};

use super::proto::{AdmissionError, ProbeSpec, ServeStats};

/// Everything needed to build a session's engines — retained so a
/// suspended session can be rebuilt bit-identically on resume.
#[derive(Clone)]
struct SessionCfg {
    spec: Arc<NetworkSpec>,
    run: RunConfig,
    probes: Vec<ProbeSpec>,
}

/// A hosted session with live rank threads, checked out by one
/// connection at a time.
pub struct ActiveSession {
    sim: Simulation,
    cfg: SessionCfg,
    threads: u64,
    /// Immutable topology bytes (CSR rank store), summed over ranks.
    shared_bytes: u64,
    /// Mutable per-trajectory state bytes (rings, traces, blocks).
    state_bytes: u64,
    /// Probe data drained at suspend time, merged back into the next
    /// drain of the same probe after resume.
    carry: Vec<(String, ProbeData)>,
    last_used: Instant,
}

impl ActiveSession {
    /// Steps completed so far.
    pub fn step(&self) -> u64 {
        self.sim.step()
    }

    /// Advance all ranks; returns the new step count.
    pub fn run(&mut self, steps: u64) -> Result<u64> {
        self.sim.run_for(steps)?;
        Ok(self.sim.step())
    }

    /// Drain one probe, merging any parked pre-suspend data in front
    /// of the freshly collected events.
    pub fn drain(&mut self, probe: &str) -> Result<ProbeData> {
        let fresh = self.sim.drain(probe)?;
        match self.carry.iter().position(|(n, _)| n == probe) {
            Some(i) => self.carry.remove(i).1.merge(fresh),
            None => Ok(fresh),
        }
    }

    /// Drain every registered probe (the server-push path).
    pub fn drain_all(&mut self) -> Result<Vec<(String, ProbeData)>> {
        let names: Vec<String> = self
            .cfg
            .probes
            .iter()
            .map(|p| p.name().to_string())
            .collect();
        let mut out = Vec::with_capacity(names.len());
        for name in names {
            let data = self.drain(&name)?;
            out.push((name, data));
        }
        Ok(out)
    }

    pub fn set_poisson(
        &mut self,
        pop: &str,
        rate_hz: f64,
        weight_pa: f64,
    ) -> Result<()> {
        self.sim.set_poisson(pop, rate_hz, weight_pa)
    }

    pub fn set_dc(&mut self, pop: &str, dc_pa: f64) -> Result<()> {
        self.sim.set_dc(pop, dc_pa)
    }

    /// Serialize the session container (magic, ranks, step, per-rank
    /// CORTEX3 sections) — the same bytes `cortex run` checkpoints.
    pub fn checkpoint_bytes(&mut self) -> Result<Vec<u8>> {
        let mut blob = Vec::new();
        self.sim.checkpoint(&mut blob)?;
        Ok(blob)
    }

    /// True at an exchange-window boundary (where checkpoints are
    /// legal).
    fn at_boundary(&self) -> bool {
        let m = self.cfg.spec.min_delay_steps as u64;
        m > 0 && self.sim.step() % m == 0
    }

    /// Bytes charged against the serve memory budget: shared topology
    /// plus per-trajectory state.
    fn mem_bytes(&self) -> u64 {
        self.shared_bytes + self.state_bytes
    }

    /// The session's measured (shared topology, per-trajectory state)
    /// byte split, as charged at admission time.
    pub fn memory_split(&self) -> (u64, u64) {
        (self.shared_bytes, self.state_bytes)
    }
}

/// Where a suspended session's CORTEX3 blob lives. Heap blobs count
/// against the resident-memory budget; spilled blobs cost only disk.
enum Blob {
    Heap(Vec<u8>),
    Disk { path: PathBuf, len: u64 },
}

impl Blob {
    /// Bytes charged against the resident-memory budget.
    fn resident_bytes(&self) -> u64 {
        match self {
            Blob::Heap(b) => b.len() as u64,
            Blob::Disk { .. } => 0,
        }
    }

    /// Load the blob contents, reading the spill file if on disk.
    fn read(&self) -> Result<Vec<u8>> {
        match self {
            Blob::Heap(b) => Ok(b.clone()),
            Blob::Disk { path, len } => {
                let bytes = std::fs::read(path).map_err(|e| {
                    anyhow::anyhow!(
                        "reading spilled session blob {}: {e}",
                        path.display()
                    )
                })?;
                ensure!(
                    bytes.len() as u64 == *len,
                    "spilled session blob {} is {} bytes, expected {}",
                    path.display(),
                    bytes.len(),
                    len
                );
                Ok(bytes)
            }
        }
    }

    /// Delete the backing spill file, if any. Removal failures are
    /// ignored: the session is already gone, a stale file is the
    /// operator's only cost.
    fn discard(self) {
        if let Blob::Disk { path, .. } = self {
            let _ = std::fs::remove_file(&path);
        }
    }
}

/// Park a freshly serialized checkpoint blob: on the heap when
/// `spill_dir` is empty, otherwise spilled to
/// `<spill_dir>/session-<id>.ckpt`.
fn park_blob(spill_dir: &str, id: u64, blob: Vec<u8>) -> Result<Blob> {
    if spill_dir.is_empty() {
        return Ok(Blob::Heap(blob));
    }
    std::fs::create_dir_all(spill_dir).map_err(|e| {
        anyhow::anyhow!("creating serve.spill_dir {spill_dir}: {e}")
    })?;
    let path = Path::new(spill_dir).join(format!("session-{id}.ckpt"));
    std::fs::write(&path, &blob).map_err(|e| {
        anyhow::anyhow!(
            "spilling session blob to {}: {e}",
            path.display()
        )
    })?;
    Ok(Blob::Disk { path, len: blob.len() as u64 })
}

/// A session parked as a checkpoint blob: no threads, no engines.
struct SuspendedSession {
    blob: Blob,
    cfg: SessionCfg,
    threads: u64,
    parked: Vec<(String, ProbeData)>,
}

enum Slot {
    Active(Box<ActiveSession>),
    Suspended(Box<SuspendedSession>),
    /// Checked out by a connection thread; commands fail fast rather
    /// than queue behind it.
    Busy,
}

/// The daemon's session table and quota ledger. Wrap in a
/// `Mutex` and hold the lock only for table operations — checked-out
/// sessions run outside it.
pub struct SessionManager {
    limits: ServeConfig,
    next_id: u64,
    slots: HashMap<u64, Slot>,
    threads_in_use: u64,
    mem_in_use: u64,
}

impl SessionManager {
    pub fn new(limits: ServeConfig) -> SessionManager {
        SessionManager {
            limits,
            next_id: 1,
            slots: HashMap::new(),
            threads_in_use: 0,
            mem_in_use: 0,
        }
    }

    fn mem_budget_bytes(&self) -> u64 {
        (self.limits.memory_budget_mb as u64) << 20
    }

    /// Parse the client's config document + overrides, run admission,
    /// build the session, and return its id. Over-quota requests fail
    /// with a downcastable [`AdmissionError`].
    pub fn create(
        &mut self,
        doc_text: &str,
        overrides: &[String],
        probes: &[ProbeSpec],
    ) -> Result<u64> {
        if self.slots.len() >= self.limits.max_sessions {
            return Err(AdmissionError::Sessions {
                active: self.slots.len() as u64,
                max: self.limits.max_sessions as u64,
            }
            .into());
        }
        let mut doc = ConfigDoc::parse(doc_text)?;
        doc.apply_overrides(overrides)?;
        let cfg = ExperimentConfig::from_doc(&doc)?;
        ensure!(
            cfg.engine == EngineKind::Cortex,
            "serve hosts the cortex engine only"
        );
        ensure!(
            cfg.transport == CommTransport::Local,
            "serve sessions use the in-process transport; \
             distributed TCP runs go through `cortex launch`"
        );
        for (i, p) in probes.iter().enumerate() {
            ensure!(
                !probes[..i].iter().any(|q| q.name() == p.name()),
                "duplicate probe name '{}'",
                p.name()
            );
        }
        let want = (cfg.ranks * cfg.threads) as u64;
        let cap = self.limits.max_session_threads as u64;
        if cap != 0 && want > cap {
            return Err(
                AdmissionError::SessionThreads { want, max: cap }.into()
            );
        }
        self.admit_threads(want)?;
        let scfg = SessionCfg {
            spec: Arc::new(crate::cli::build_spec(&cfg)),
            run: crate::cli::run_config_of(&cfg),
            probes: probes.to_vec(),
        };
        let mut sim = build_session(&scfg, None)?;
        // measured, not estimated: shared topology + trajectory state
        let (shared_bytes, state_bytes) = sim.memory_split()?;
        self.admit_memory(shared_bytes + state_bytes)?; // drops `sim`
        let id = self.next_id;
        self.next_id += 1;
        self.threads_in_use += want;
        self.mem_in_use += shared_bytes + state_bytes;
        self.slots.insert(
            id,
            Slot::Active(Box::new(ActiveSession {
                sim,
                cfg: scfg,
                threads: want,
                shared_bytes,
                state_bytes,
                carry: Vec::new(),
                last_used: Instant::now(),
            })),
        );
        Ok(id)
    }

    fn admit_threads(&self, want: u64) -> Result<()> {
        let budget = self.limits.thread_budget as u64;
        if self.threads_in_use + want > budget {
            return Err(AdmissionError::Threads {
                want,
                in_use: self.threads_in_use,
                budget,
            }
            .into());
        }
        Ok(())
    }

    fn admit_memory(&self, want_bytes: u64) -> Result<()> {
        let budget = self.mem_budget_bytes();
        if budget != 0 && self.mem_in_use + want_bytes > budget {
            return Err(AdmissionError::Memory {
                want_bytes,
                in_use: self.mem_in_use,
                budget,
            }
            .into());
        }
        Ok(())
    }

    /// Take exclusive ownership of a session for the duration of one
    /// client command; the slot reads busy until [`checkin`]. A
    /// suspended session is transparently rebuilt from its blob —
    /// re-admitted against the thread/memory quotas first.
    ///
    /// [`checkin`]: SessionManager::checkin
    pub fn checkout(&mut self, id: u64) -> Result<Box<ActiveSession>> {
        let slot = match self.slots.get_mut(&id) {
            Some(s) => std::mem::replace(s, Slot::Busy),
            None => bail!("no session {id}"),
        };
        match slot {
            Slot::Busy => {
                bail!(
                    "session {id} is busy with another client's command"
                )
            }
            Slot::Active(mut s) => {
                s.last_used = Instant::now();
                Ok(s)
            }
            Slot::Suspended(s) => match self.resume_suspended(*s) {
                Ok(active) => Ok(active),
                Err((parked, e)) => {
                    // leave the blob in place: resume may succeed once
                    // quota frees up
                    self.slots
                        .insert(id, Slot::Suspended(Box::new(parked)));
                    Err(e)
                }
            },
        }
    }

    fn resume_suspended(
        &mut self,
        s: SuspendedSession,
    ) -> std::result::Result<
        Box<ActiveSession>,
        (SuspendedSession, anyhow::Error),
    > {
        if let Err(e) = self.admit_threads(s.threads) {
            return Err((s, e));
        }
        let bytes = match s.blob.read() {
            Ok(b) => b,
            Err(e) => return Err((s, e)),
        };
        let mut sim = match build_session(&s.cfg, Some(&bytes)) {
            Ok(sim) => sim,
            Err(e) => return Err((s, e)),
        };
        let (shared_bytes, state_bytes) = match sim.memory_split() {
            Ok(split) => split,
            Err(e) => return Err((s, e)),
        };
        let mem_bytes = shared_bytes + state_bytes;
        // the blob is released on success, so re-admit the difference
        let blob_bytes = s.blob.resident_bytes();
        let budget = self.mem_budget_bytes();
        if budget != 0
            && self.mem_in_use - blob_bytes + mem_bytes > budget
        {
            let e = AdmissionError::Memory {
                want_bytes: mem_bytes,
                in_use: self.mem_in_use - blob_bytes,
                budget,
            };
            return Err((s, e.into()));
        }
        self.mem_in_use = self.mem_in_use - blob_bytes + mem_bytes;
        self.threads_in_use += s.threads;
        s.blob.discard(); // spill file, if any, is now stale
        Ok(Box::new(ActiveSession {
            sim,
            cfg: s.cfg,
            threads: s.threads,
            shared_bytes,
            state_bytes,
            carry: s.parked,
            last_used: Instant::now(),
        }))
    }

    /// Return a checked-out session to its slot.
    pub fn checkin(&mut self, id: u64, mut s: Box<ActiveSession>) {
        s.last_used = Instant::now();
        self.slots.insert(id, Slot::Active(s));
    }

    /// Snapshot a session to its checkpoint blob, drain every probe
    /// into the parked carry list, and reclaim its rank threads.
    /// Idempotent on an already-suspended session. Requires an
    /// exchange-window boundary (run totals that are a multiple of the
    /// spec's `min_delay_steps`).
    pub fn suspend(&mut self, id: u64) -> Result<()> {
        let slot = match self.slots.get_mut(&id) {
            Some(s) => std::mem::replace(s, Slot::Busy),
            None => bail!("no session {id}"),
        };
        let mut s = match slot {
            Slot::Suspended(s) => {
                self.slots.insert(id, Slot::Suspended(s));
                return Ok(());
            }
            Slot::Busy => bail!(
                "session {id} is busy with another client's command"
            ),
            Slot::Active(s) => s,
        };
        let parked = match suspend_drain(&mut s) {
            Ok(parked) => parked,
            Err(e) => {
                self.slots.insert(id, Slot::Active(s));
                return Err(e);
            }
        };
        let mut bytes = Vec::new();
        if let Err(e) = s.sim.checkpoint(&mut bytes) {
            s.carry = parked; // keep drained probe data with the session
            self.slots.insert(id, Slot::Active(s));
            return Err(e);
        }
        let blob = match park_blob(&self.limits.spill_dir, id, bytes) {
            Ok(blob) => blob,
            Err(e) => {
                s.carry = parked;
                self.slots.insert(id, Slot::Active(s));
                return Err(e);
            }
        };
        // rank threads join here; only the blob (heap case) stays
        // resident
        let mem_bytes = s.mem_bytes();
        let ActiveSession { sim, cfg, threads, .. } = *s;
        drop(sim);
        self.threads_in_use -= threads;
        self.mem_in_use -= mem_bytes;
        self.mem_in_use += blob.resident_bytes();
        self.slots.insert(
            id,
            Slot::Suspended(Box::new(SuspendedSession {
                blob,
                cfg,
                threads,
                parked,
            })),
        );
        Ok(())
    }

    /// Tear a session down and release its quota.
    pub fn close(&mut self, id: u64) -> Result<()> {
        match self.slots.remove(&id) {
            None => bail!("no session {id}"),
            Some(Slot::Busy) => {
                self.slots.insert(id, Slot::Busy);
                bail!(
                    "session {id} is busy with another client's command"
                )
            }
            Some(Slot::Active(s)) => {
                self.threads_in_use -= s.threads;
                self.mem_in_use -= s.mem_bytes();
                // dropping the Simulation joins its rank threads
            }
            Some(Slot::Suspended(s)) => {
                self.mem_in_use -= s.blob.resident_bytes();
                s.blob.discard();
            }
        }
        Ok(())
    }

    /// Occupancy counters for [`super::proto::Request::Stats`].
    pub fn stats(&self) -> ServeStats {
        let mut active = 0u64;
        let mut suspended = 0u64;
        for slot in self.slots.values() {
            match slot {
                Slot::Active(_) | Slot::Busy => active += 1,
                Slot::Suspended(_) => suspended += 1,
            }
        }
        ServeStats {
            sessions: self.slots.len() as u64,
            active,
            suspended,
            threads_in_use: self.threads_in_use,
            thread_budget: self.limits.thread_budget as u64,
            mem_in_use: self.mem_in_use,
            mem_budget: self.mem_budget_bytes(),
        }
    }

    /// Suspend sessions idle past the configured timeout (no-op when
    /// `serve.idle_suspend_ms = 0`). Only sessions parked at a window
    /// boundary qualify — a mid-window session stays live until its
    /// next run lands on one.
    pub fn sweep_idle(&mut self) {
        if self.limits.idle_suspend_ms == 0 {
            return;
        }
        let timeout =
            std::time::Duration::from_millis(self.limits.idle_suspend_ms);
        let due: Vec<u64> = self
            .slots
            .iter()
            .filter_map(|(&id, slot)| match slot {
                Slot::Active(s)
                    if s.last_used.elapsed() >= timeout
                        && s.at_boundary() =>
                {
                    Some(id)
                }
                _ => None,
            })
            .collect();
        for id in due {
            // boundary was checked; a failure here (e.g. a poisoned
            // rank) leaves the session active and surfaces on the
            // next client command
            let _ = self.suspend(id);
        }
    }

    /// Drop every session (joins all rank threads) and delete any
    /// spill files still on disk.
    pub fn shutdown(&mut self) {
        for (_, slot) in self.slots.drain() {
            if let Slot::Suspended(s) = slot {
                s.blob.discard();
            }
        }
        self.threads_in_use = 0;
        self.mem_in_use = 0;
    }
}

/// Drain every probe ahead of a suspend, merging into any carry left
/// from a previous suspend cycle.
fn suspend_drain(
    s: &mut ActiveSession,
) -> Result<Vec<(String, ProbeData)>> {
    ensure!(
        s.at_boundary(),
        "suspend requires a window boundary (step {} is not a \
         multiple of min_delay {})",
        s.sim.step(),
        s.cfg.spec.min_delay_steps
    );
    s.drain_all()
}

/// Build (or rebuild from a checkpoint blob) a session's
/// [`Simulation`] with its probes registered per rank.
fn build_session(
    cfg: &SessionCfg,
    restore: Option<&[u8]>,
) -> Result<Simulation> {
    let mut b =
        Simulation::builder(cfg.spec.clone()).run_config(&cfg.run);
    for p in &cfg.probes {
        b = match p {
            ProbeSpec::Raster { name } => {
                let n = name.clone();
                b.probe_with(name, move |_| {
                    Box::new(SpikeRaster::all(&n))
                })
            }
            ProbeSpec::Rates { name, bin_steps } => {
                let n = name.clone();
                let bin = *bin_steps;
                b.probe_with(name, move |_| {
                    Box::new(PopRates::new(&n, bin))
                })
            }
            ProbeSpec::Phases { name } => {
                let n = name.clone();
                b.probe_with(name, move |_| {
                    Box::new(PhaseStream::new(&n))
                })
            }
        };
    }
    match restore {
        Some(blob) => b.restore(&mut Cursor::new(blob)),
        None => b.build(),
    }
}

// ---------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_overrides(ranks: usize, threads: usize) -> Vec<String> {
        vec![
            "network.kind=\"random\"".into(),
            "network.n_neurons=200".into(),
            "network.indegree=20".into(),
            "seed=7".into(),
            format!("engine.ranks={ranks}"),
            format!("engine.threads={threads}"),
        ]
    }

    fn limits(
        max_sessions: usize,
        thread_budget: usize,
        max_session_threads: usize,
    ) -> ServeConfig {
        ServeConfig {
            max_sessions,
            thread_budget,
            max_session_threads,
            ..ServeConfig::default()
        }
    }

    fn admission_of(e: &anyhow::Error) -> &AdmissionError {
        e.downcast_ref::<AdmissionError>().unwrap_or_else(|| {
            panic!("expected a typed AdmissionError, got: {e:#}")
        })
    }

    #[test]
    fn per_session_thread_cap_refuses_before_building() {
        let mut mgr = SessionManager::new(limits(4, 16, 2));
        let err = mgr
            .create("", &tiny_overrides(2, 2), &[])
            .unwrap_err();
        assert_eq!(
            *admission_of(&err),
            AdmissionError::SessionThreads { want: 4, max: 2 }
        );
        assert_eq!(mgr.stats().sessions, 0);
    }

    #[test]
    fn thread_budget_and_session_quota_are_enforced() {
        let mut mgr = SessionManager::new(limits(2, 2, 0));
        let a = mgr.create("", &tiny_overrides(1, 2), &[]).unwrap();
        let err = mgr
            .create("", &tiny_overrides(1, 1), &[])
            .unwrap_err();
        assert_eq!(
            *admission_of(&err),
            AdmissionError::Threads { want: 1, in_use: 2, budget: 2 }
        );

        // suspending A releases its threads; the next create fits
        mgr.suspend(a).unwrap();
        assert_eq!(mgr.stats().threads_in_use, 0);
        let _b = mgr.create("", &tiny_overrides(1, 1), &[]).unwrap();

        // ... but now the session count is the binding quota
        let err = mgr
            .create("", &tiny_overrides(1, 1), &[])
            .unwrap_err();
        assert_eq!(
            *admission_of(&err),
            AdmissionError::Sessions { active: 2, max: 2 }
        );

        // resume of A must re-admit: B holds 1 of 2 threads, A wants 2
        let err = mgr.checkout(a).unwrap_err();
        assert_eq!(
            *admission_of(&err),
            AdmissionError::Threads { want: 2, in_use: 1, budget: 2 }
        );
        assert_eq!(mgr.stats().suspended, 1, "blob stays parked");
    }

    #[test]
    fn close_releases_quota_for_suspended_and_active() {
        let mut mgr = SessionManager::new(limits(8, 8, 0));
        let a = mgr.create("", &tiny_overrides(1, 1), &[]).unwrap();
        let b = mgr.create("", &tiny_overrides(1, 1), &[]).unwrap();
        mgr.suspend(b).unwrap();
        assert!(mgr.stats().mem_in_use > 0);
        mgr.close(a).unwrap();
        mgr.close(b).unwrap();
        let s = mgr.stats();
        assert_eq!(
            (s.sessions, s.threads_in_use, s.mem_in_use),
            (0, 0, 0)
        );
        assert!(mgr.close(a).is_err(), "double close is an error");
    }

    #[test]
    fn admission_charges_shared_plus_trajectory_bytes() {
        let mut mgr = SessionManager::new(limits(8, 8, 0));
        let a = mgr.create("", &tiny_overrides(1, 1), &[]).unwrap();
        let s = mgr.checkout(a).unwrap();
        let (shared, state) = s.memory_split();
        assert!(shared > 0, "CSR store must have measurable bytes");
        assert!(state > 0, "trajectory state must have bytes");
        mgr.checkin(a, s);
        assert_eq!(mgr.stats().mem_in_use, shared + state);
        mgr.close(a).unwrap();
        assert_eq!(mgr.stats().mem_in_use, 0);
    }

    #[test]
    fn spill_dir_moves_suspended_blobs_to_disk() {
        let dir = std::env::temp_dir().join(format!(
            "cortex-spill-test-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let mut mgr = SessionManager::new(ServeConfig {
            spill_dir: dir.to_string_lossy().into_owned(),
            ..limits(8, 8, 0)
        });
        let a = mgr.create("", &tiny_overrides(1, 1), &[]).unwrap();

        mgr.suspend(a).unwrap();
        let spilled = dir.join(format!("session-{a}.ckpt"));
        assert!(spilled.is_file(), "blob must land in spill_dir");
        assert_eq!(
            mgr.stats().mem_in_use,
            0,
            "a spilled session costs no resident bytes"
        );

        // resume reloads from disk and deletes the spill file
        let s = mgr.checkout(a).unwrap();
        assert!(!spilled.exists(), "resume deletes the spill file");
        assert!(mgr.stats().mem_in_use > 0);
        mgr.checkin(a, s);

        // close of a suspended session also deletes its file
        mgr.suspend(a).unwrap();
        assert!(spilled.is_file());
        mgr.close(a).unwrap();
        assert!(!spilled.exists(), "close deletes the spill file");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn missing_spill_file_fails_resume_but_keeps_the_slot() {
        let dir = std::env::temp_dir().join(format!(
            "cortex-spill-gone-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let mut mgr = SessionManager::new(ServeConfig {
            spill_dir: dir.to_string_lossy().into_owned(),
            ..limits(8, 8, 0)
        });
        let a = mgr.create("", &tiny_overrides(1, 1), &[]).unwrap();
        mgr.suspend(a).unwrap();
        std::fs::remove_file(dir.join(format!("session-{a}.ckpt")))
            .unwrap();
        assert!(mgr.checkout(a).is_err(), "blob is gone");
        assert_eq!(
            mgr.stats().suspended,
            1,
            "slot survives for a later close"
        );
        mgr.close(a).unwrap();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn busy_sessions_fail_fast() {
        let mut mgr = SessionManager::new(limits(8, 8, 0));
        let a = mgr.create("", &tiny_overrides(1, 1), &[]).unwrap();
        let s = mgr.checkout(a).unwrap();
        assert!(mgr.checkout(a).is_err());
        assert!(mgr.suspend(a).is_err());
        assert!(mgr.close(a).is_err());
        mgr.checkin(a, s);
        mgr.close(a).unwrap();
    }
}
