//! `cortex serve` — the resident simulation daemon: build once, serve
//! many.
//!
//! The ROADMAP's service direction made concrete: a persistent process
//! hosts many concurrent [`Simulation`](crate::engine::Simulation)
//! sessions behind a versioned, length-prefixed control protocol
//! ([`proto`], reusing the BSB codec's fallible varint discipline and
//! the TCP transport's magic/version/frame-cap conventions). The
//! pieces:
//!
//! * [`proto`] — wire types: [`Request`]/[`Reply`] frames, typed
//!   [`ProtoError`] decode failures, probe drain-to-frame
//!   serialization, typed [`AdmissionError`] refusals.
//! * [`manager`] — the session table: admission control against
//!   `[serve]` thread/memory quotas, busy-slot checkout so one
//!   session's long `run` never blocks another, suspend-to-blob and
//!   transparent resume.
//! * [`client`] — a thin typed client ([`Client`]) driving the full
//!   protocol; `cortex client` wraps it for scripting and CI.
//!
//! One OS thread per accepted connection speaks the protocol
//! synchronously; the shared [`SessionManager`] lock is held only for
//! table bookkeeping, never across a simulation command, so N clients
//! drive N sessions genuinely in parallel. Probe output travels as
//! server-push [`Reply::Push`] frames preceding a run's final reply.
//! Suspended sessions cost no threads and only their checkpoint blob
//! in memory; any later command on the session rebuilds it
//! transparently (re-running admission first).

pub mod client;
pub mod manager;
pub mod proto;

pub use client::Client;
pub use manager::{ActiveSession, SessionManager};
pub use proto::{
    AdmissionError, ProbeSpec, ProtoError, Reply, Request, ServeStats,
};

use std::io;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};
use std::thread;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::config::ServeConfig;
use crate::probe::ProbeData;

/// Accept-loop poll interval (the listener is nonblocking so the loop
/// can observe the shutdown flag and run the idle-suspend sweep).
const ACCEPT_POLL: Duration = Duration::from_millis(50);

/// Bind the configured listen address and serve until a client sends
/// [`Request::Shutdown`].
pub fn serve(limits: &ServeConfig) -> Result<()> {
    let listener = TcpListener::bind(&limits.addr)
        .with_context(|| format!("binding {}", limits.addr))?;
    serve_on(listener, limits.clone())
}

/// Serve on an already-bound listener (lets tests use an ephemeral
/// port in-process). Returns after a clean shutdown request.
pub fn serve_on(
    listener: TcpListener,
    limits: ServeConfig,
) -> Result<()> {
    let addr = listener.local_addr()?;
    println!(
        "cortex serve: listening on {addr} \
         (max_sessions {}, thread_budget {}, memory_budget_mb {}, \
         idle_suspend_ms {})",
        limits.max_sessions,
        limits.thread_budget,
        limits.memory_budget_mb,
        limits.idle_suspend_ms,
    );
    listener.set_nonblocking(true)?;
    let mgr = Arc::new(Mutex::new(SessionManager::new(limits)));
    let stop = Arc::new(AtomicBool::new(false));
    while !stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                // the listener's nonblocking flag must not leak onto
                // the connection socket
                stream.set_nonblocking(false)?;
                let mgr = Arc::clone(&mgr);
                let stop = Arc::clone(&stop);
                thread::Builder::new()
                    .name("cortex-serve-conn".into())
                    .spawn(move || handle_conn(stream, mgr, stop))?;
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                lock(&mgr).sweep_idle();
                thread::sleep(ACCEPT_POLL);
            }
            Err(e) => return Err(e).context("accepting a connection"),
        }
    }
    lock(&mgr).shutdown();
    println!("cortex serve: shut down");
    Ok(())
}

/// A panicked connection thread must not wedge the daemon: recover
/// the manager from a poisoned lock instead of propagating.
fn lock(mgr: &Mutex<SessionManager>) -> MutexGuard<'_, SessionManager> {
    mgr.lock().unwrap_or_else(|p| p.into_inner())
}

/// One connection's synchronous request loop. An undecodable frame
/// gets a [`Reply::Error`] and a hangup (the stream may be desynced);
/// a clean EOF between frames ends the loop quietly.
fn handle_conn(
    mut stream: TcpStream,
    mgr: Arc<Mutex<SessionManager>>,
    stop: Arc<AtomicBool>,
) {
    if proto::send_hello(&mut stream).is_err()
        || proto::expect_hello(&mut stream).is_err()
    {
        return;
    }
    loop {
        let frame = match proto::read_frame_opt(&mut stream) {
            Ok(Some(frame)) => frame,
            Ok(None) | Err(_) => return,
        };
        let req = match proto::decode_request(&frame) {
            Ok(req) => req,
            Err(e) => {
                let rep = Reply::Error(format!("bad request: {e}"));
                let _ = proto::write_frame(
                    &mut stream,
                    &proto::encode_reply(&rep),
                );
                return;
            }
        };
        let shutting_down = matches!(req, Request::Shutdown);
        let reply = dispatch(req, &mgr, &mut stream, &stop);
        if proto::write_frame(&mut stream, &proto::encode_reply(&reply))
            .is_err()
            || shutting_down
        {
            return;
        }
    }
}

fn dispatch(
    req: Request,
    mgr: &Mutex<SessionManager>,
    stream: &mut TcpStream,
    stop: &AtomicBool,
) -> Reply {
    match req {
        Request::Create { doc, overrides, probes } => {
            match lock(mgr).create(&doc, &overrides, &probes) {
                Ok(id) => Reply::Created { session: id },
                Err(e) => refusal_or_error(e),
            }
        }
        Request::Run { session, steps, push } => {
            run_session(session, steps, push, mgr, stream)
        }
        Request::Drain { session, probe } => {
            with_session(session, mgr, |s| {
                let data = s.drain(&probe)?;
                Ok(Reply::Data { probe, data })
            })
        }
        Request::Poisson { session, pop, rate_hz, weight_pa } => {
            with_session(session, mgr, |s| {
                s.set_poisson(&pop, rate_hz, weight_pa)?;
                Ok(Reply::Ok)
            })
        }
        Request::Dc { session, pop, dc_pa } => {
            with_session(session, mgr, |s| {
                s.set_dc(&pop, dc_pa)?;
                Ok(Reply::Ok)
            })
        }
        Request::Suspend { session } => {
            match lock(mgr).suspend(session) {
                Ok(()) => Reply::Ok,
                Err(e) => refusal_or_error(e),
            }
        }
        // checkout rebuilds a suspended session; nothing else to do
        Request::Resume { session } => {
            with_session(session, mgr, |_s| Ok(Reply::Ok))
        }
        Request::Checkpoint { session } => {
            with_session(session, mgr, |s| {
                Ok(Reply::Blob(s.checkpoint_bytes()?))
            })
        }
        Request::Close { session } => match lock(mgr).close(session) {
            Ok(()) => Reply::Ok,
            Err(e) => Reply::Error(format!("{e:#}")),
        },
        Request::Stats => Reply::Stats(lock(mgr).stats()),
        Request::Shutdown => {
            stop.store(true, Ordering::SeqCst);
            Reply::Ok
        }
    }
}

/// Check a session out, run `f` on it **outside** the manager lock,
/// check it back in. Admission refusals (transparent resume may hit
/// quota) map to [`Reply::Refused`].
fn with_session<F>(
    id: u64,
    mgr: &Mutex<SessionManager>,
    f: F,
) -> Reply
where
    F: FnOnce(&mut ActiveSession) -> Result<Reply>,
{
    let mut s = match lock(mgr).checkout(id) {
        Ok(s) => s,
        Err(e) => return refusal_or_error(e),
    };
    let rep = f(&mut s);
    lock(mgr).checkin(id, s);
    match rep {
        Ok(reply) => reply,
        Err(e) => Reply::Error(format!("{e:#}")),
    }
}

/// `Run` with optional server-push: advance outside the lock, then
/// stream each drained probe as a [`Reply::Push`] frame ahead of the
/// final [`Reply::Ran`].
fn run_session(
    id: u64,
    steps: u64,
    push: bool,
    mgr: &Mutex<SessionManager>,
    stream: &mut TcpStream,
) -> Reply {
    let mut s = match lock(mgr).checkout(id) {
        Ok(s) => s,
        Err(e) => return refusal_or_error(e),
    };
    let result = (|| -> Result<(u64, Vec<(String, ProbeData)>)> {
        let step = s.run(steps)?;
        let pushes = if push { s.drain_all()? } else { Vec::new() };
        Ok((step, pushes))
    })();
    lock(mgr).checkin(id, s);
    match result {
        Ok((step, pushes)) => {
            for (probe, data) in pushes {
                let frame = proto::encode_reply(&Reply::Push {
                    session: id,
                    probe,
                    data,
                });
                if proto::write_frame(stream, &frame).is_err() {
                    // client went away; the final write fails too and
                    // the request loop hangs up
                    break;
                }
            }
            Reply::Ran { session: id, step }
        }
        Err(e) => Reply::Error(format!("{e:#}")),
    }
}

/// Typed admission refusals travel as [`Reply::Refused`]; everything
/// else as [`Reply::Error`].
fn refusal_or_error(e: anyhow::Error) -> Reply {
    match e.downcast::<AdmissionError>() {
        Ok(adm) => Reply::Refused(adm),
        Err(e) => Reply::Error(format!("{e:#}")),
    }
}
