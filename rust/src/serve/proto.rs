//! The `cortex serve` control protocol: versioned, length-prefixed
//! frames carrying session commands and server-push probe data.
//!
//! The wire discipline deliberately mirrors the spike-exchange stack:
//! the varint layer is the BSB codec's ([`crate::comm::bsb`], shared
//! `put_varint`/`get_varint` with the same 10-byte/63-bit overflow
//! rules), the transport framing follows the TCP communicator (fixed
//! magic + version hello, 4-byte little-endian length prefix, a hard
//! frame-size cap so a corrupt prefix cannot drive a giant
//! allocation). Every decode path is fallible and total: adversarial
//! bytes produce a typed [`ProtoError`], never a panic and never an
//! unbounded `Vec::with_capacity`.
//!
//! Frame layout:
//!
//! | bytes | content                                         |
//! |-------|-------------------------------------------------|
//! | 8     | hello only: magic `0x434f5254_45585356` ("CORTEXSV", LE) |
//! | 2     | hello only: protocol version (LE)               |
//! | 4     | every frame: payload length (LE, ≤ 64 MiB)      |
//! | 1     | payload tag ([`Request`] 0x01.., [`Reply`] 0x81..) |
//! | ...   | tag-specific fields (varints, length-prefixed UTF-8, f64 LE bits) |

use std::io;

use anyhow::{bail, Context, Result};

use crate::comm::bsb::{get_varint, put_varint, CodecError};
use crate::probe::ProbeData;
use crate::{Gid, Step};

/// Hello magic: ASCII "CORTEXSV".
pub const SERVE_MAGIC: u64 = 0x434f_5254_4558_5356;
/// Control-protocol version; bumped on any wire change.
pub const SERVE_VERSION: u16 = 1;
/// Hard cap on one frame's payload (matches the spike-exchange
/// transport cap): a corrupt or hostile length prefix is rejected
/// before any allocation.
pub const MAX_SERVE_FRAME: usize = 64 << 20;

// ---------------------------------------------------------------------
// Errors
// ---------------------------------------------------------------------

/// Typed decode/handshake failures. Totality contract: any byte string
/// fed to [`decode_request`]/[`decode_reply`] yields `Ok` or one of
/// these — the fuzz suite in `comm_wire.rs` holds the codec to it.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ProtoError {
    /// Varint-layer failure (truncated buffer, overlong varint, or a
    /// value too wide for its field), inherited from the BSB codec.
    Codec(CodecError),
    /// Payload tag byte not assigned by this protocol version.
    UnknownTag(u8),
    /// Payload decoded cleanly but left unconsumed bytes.
    TrailingBytes { used: usize, len: usize },
    /// A length-prefixed string was not valid UTF-8.
    BadUtf8,
    /// Hello carried the wrong magic — not a cortex serve endpoint.
    BadMagic { got: u64 },
    /// Hello magic matched but the protocol version did not.
    BadVersion { got: u16 },
    /// Length prefix beyond [`MAX_SERVE_FRAME`].
    FrameTooLarge { bytes: u64, limit: u64 },
}

impl From<CodecError> for ProtoError {
    fn from(e: CodecError) -> Self {
        ProtoError::Codec(e)
    }
}

impl std::fmt::Display for ProtoError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ProtoError::Codec(e) => write!(f, "{e}"),
            ProtoError::UnknownTag(t) => {
                write!(f, "unknown control-frame tag 0x{t:02x}")
            }
            ProtoError::TrailingBytes { used, len } => write!(
                f,
                "control frame decoded {used} of {len} bytes; \
                 trailing garbage"
            ),
            ProtoError::BadUtf8 => {
                write!(f, "control frame string is not valid UTF-8")
            }
            ProtoError::BadMagic { got } => write!(
                f,
                "bad hello magic 0x{got:016x} (want 0x{SERVE_MAGIC:016x}); \
                 peer is not a cortex serve endpoint"
            ),
            ProtoError::BadVersion { got } => write!(
                f,
                "protocol version mismatch: peer speaks v{got}, \
                 this build speaks v{SERVE_VERSION}"
            ),
            ProtoError::FrameTooLarge { bytes, limit } => write!(
                f,
                "control frame of {bytes} bytes exceeds the {limit}-byte cap"
            ),
        }
    }
}

impl std::error::Error for ProtoError {}

/// Typed admission-control rejection, carried on the wire inside
/// [`Reply::Refused`] so clients can distinguish "over budget, retry
/// later" from a hard protocol or simulation error.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum AdmissionError {
    /// The daemon already hosts `max` sessions (active + suspended).
    Sessions { active: u64, max: u64 },
    /// The shared worker-thread budget cannot cover this session.
    Threads { want: u64, in_use: u64, budget: u64 },
    /// The resident-memory budget cannot cover this session.
    Memory { want_bytes: u64, in_use: u64, budget: u64 },
    /// The session alone exceeds the per-session thread cap.
    SessionThreads { want: u64, max: u64 },
}

impl std::fmt::Display for AdmissionError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AdmissionError::Sessions { active, max } => write!(
                f,
                "session quota exhausted: {active} of {max} sessions \
                 in use"
            ),
            AdmissionError::Threads { want, in_use, budget } => write!(
                f,
                "thread budget exhausted: session wants {want} worker \
                 threads but {in_use} of {budget} are in use"
            ),
            AdmissionError::Memory { want_bytes, in_use, budget } => {
                write!(
                    f,
                    "memory budget exhausted: session wants \
                     {want_bytes} bytes but {in_use} of {budget} are \
                     in use"
                )
            }
            AdmissionError::SessionThreads { want, max } => write!(
                f,
                "session wants {want} worker threads; per-session cap \
                 is {max}"
            ),
        }
    }
}

impl std::error::Error for AdmissionError {}

// ---------------------------------------------------------------------
// Message types
// ---------------------------------------------------------------------

/// A probe to register at session creation, mirroring the built-in
/// probe constructors the daemon instantiates per rank.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ProbeSpec {
    /// [`crate::probe::SpikeRaster::all`].
    Raster { name: String },
    /// [`crate::probe::PopRates::new`].
    Rates { name: String, bin_steps: Step },
    /// [`crate::probe::PhaseStream::new`].
    Phases { name: String },
}

impl ProbeSpec {
    /// The probe's drain name.
    pub fn name(&self) -> &str {
        match self {
            ProbeSpec::Raster { name }
            | ProbeSpec::Rates { name, .. }
            | ProbeSpec::Phases { name } => name,
        }
    }

    /// Parse the CLI form: `raster:NAME`, `rates:NAME:BIN_STEPS`, or
    /// `phases:NAME`.
    pub fn parse(s: &str) -> Result<ProbeSpec> {
        let mut parts = s.split(':');
        let kind = parts.next().unwrap_or("");
        let name = parts
            .next()
            .filter(|n| !n.is_empty())
            .with_context(|| {
                format!("probe spec '{s}' is missing a name")
            })?
            .to_string();
        let spec = match kind {
            "raster" => ProbeSpec::Raster { name },
            "rates" => {
                let bin = parts.next().with_context(|| {
                    format!(
                        "probe spec '{s}' needs rates:NAME:BIN_STEPS"
                    )
                })?;
                let bin_steps = bin.parse::<Step>().with_context(|| {
                    format!("bad bin_steps '{bin}' in probe spec '{s}'")
                })?;
                ProbeSpec::Rates { name, bin_steps }
            }
            "phases" => ProbeSpec::Phases { name },
            other => bail!(
                "unknown probe kind '{other}' in '{s}' \
                 (want raster|rates|phases)"
            ),
        };
        if parts.next().is_some() && !matches!(spec, ProbeSpec::Rates { .. })
        {
            bail!("trailing fields in probe spec '{s}'");
        }
        Ok(spec)
    }
}

/// Client → daemon commands.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    /// Build a session: a TOML document (may be empty) plus
    /// `key=value` override lines, exactly the launcher's config
    /// surface, and the probes to register.
    Create {
        doc: String,
        overrides: Vec<String>,
        probes: Vec<ProbeSpec>,
    },
    /// Advance the session `steps` steps. With `push`, the daemon
    /// drains every probe afterwards and streams each as a
    /// [`Reply::Push`] frame before the final [`Reply::Ran`].
    Run { session: u64, steps: u64, push: bool },
    /// Drain one probe by name.
    Drain { session: u64, probe: String },
    /// Retune a population's Poisson drive.
    Poisson { session: u64, pop: String, rate_hz: f64, weight_pa: f64 },
    /// Retune a population's DC clamp.
    Dc { session: u64, pop: String, dc_pa: f64 },
    /// Snapshot to a CORTEX3 blob and release threads + state.
    Suspend { session: u64 },
    /// Rebuild a suspended session now (resume is otherwise
    /// transparent on the next session command).
    Resume { session: u64 },
    /// Fetch the session's checkpoint bytes (the `cortex run`
    /// compatible CORTEX3 session container).
    Checkpoint { session: u64 },
    /// Tear the session down and release its quota.
    Close { session: u64 },
    /// Daemon-wide occupancy counters.
    Stats,
    /// Stop accepting connections and exit the serve loop.
    Shutdown,
}

/// Daemon → client responses. `Push` frames may precede the final
/// reply of a `Run`/`Suspend`; everything else is one frame per
/// request.
#[derive(Debug, Clone, PartialEq)]
pub enum Reply {
    Ok,
    Created { session: u64 },
    Refused(AdmissionError),
    Error(String),
    Ran { session: u64, step: u64 },
    Data { probe: String, data: ProbeData },
    /// Server-push probe frame (precedes the request's final reply).
    Push { session: u64, probe: String, data: ProbeData },
    Blob(Vec<u8>),
    Stats(ServeStats),
}

/// Daemon occupancy counters ([`Request::Stats`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct ServeStats {
    pub sessions: u64,
    pub active: u64,
    pub suspended: u64,
    pub threads_in_use: u64,
    pub thread_budget: u64,
    pub mem_in_use: u64,
    pub mem_budget: u64,
}

// ---------------------------------------------------------------------
// Primitive field codecs
// ---------------------------------------------------------------------

fn put_str(out: &mut Vec<u8>, s: &str) {
    put_varint(out, s.len() as u64);
    out.extend_from_slice(s.as_bytes());
}

fn get_str(buf: &[u8], pos: &mut usize) -> Result<String, ProtoError> {
    let len = get_varint(buf, pos)? as usize;
    if len > buf.len().saturating_sub(*pos) {
        return Err(CodecError::Truncated.into());
    }
    let s = std::str::from_utf8(&buf[*pos..*pos + len])
        .map_err(|_| ProtoError::BadUtf8)?;
    *pos += len;
    Ok(s.to_string())
}

fn put_f64(out: &mut Vec<u8>, x: f64) {
    out.extend_from_slice(&x.to_bits().to_le_bytes());
}

fn get_f64(buf: &[u8], pos: &mut usize) -> Result<f64, ProtoError> {
    if buf.len().saturating_sub(*pos) < 8 {
        return Err(CodecError::Truncated.into());
    }
    let bits =
        u64::from_le_bytes(buf[*pos..*pos + 8].try_into().unwrap());
    *pos += 8;
    Ok(f64::from_bits(bits))
}

fn put_bytes(out: &mut Vec<u8>, b: &[u8]) {
    put_varint(out, b.len() as u64);
    out.extend_from_slice(b);
}

fn get_bytes(buf: &[u8], pos: &mut usize) -> Result<Vec<u8>, ProtoError> {
    let len = get_varint(buf, pos)? as usize;
    if len > buf.len().saturating_sub(*pos) {
        return Err(CodecError::Truncated.into());
    }
    let b = buf[*pos..*pos + len].to_vec();
    *pos += len;
    Ok(b)
}

/// Element-count guard: a declared count larger than the bytes left
/// cannot be honest (every element costs ≥ 1 byte), so reject before
/// `Vec::with_capacity` can amplify a hostile prefix.
fn get_count(buf: &[u8], pos: &mut usize) -> Result<usize, ProtoError> {
    let n = get_varint(buf, pos)? as usize;
    if n > buf.len().saturating_sub(*pos) {
        return Err(CodecError::Truncated.into());
    }
    Ok(n)
}

fn get_u32(buf: &[u8], pos: &mut usize) -> Result<u32, ProtoError> {
    u32::try_from(get_varint(buf, pos)?)
        .map_err(|_| CodecError::ValueOverflow.into())
}

fn get_u16(buf: &[u8], pos: &mut usize) -> Result<u16, ProtoError> {
    u16::try_from(get_varint(buf, pos)?)
        .map_err(|_| CodecError::ValueOverflow.into())
}

fn get_bool(buf: &[u8], pos: &mut usize) -> Result<bool, ProtoError> {
    match get_varint(buf, pos)? {
        0 => Ok(false),
        1 => Ok(true),
        _ => Err(CodecError::ValueOverflow.into()),
    }
}

// ---------------------------------------------------------------------
// Probe data
// ---------------------------------------------------------------------

const PD_RASTER: u8 = 0;
const PD_RATES: u8 = 1;
const PD_TRACES: u8 = 2;
const PD_WEIGHTS: u8 = 3;
const PD_PHASES: u8 = 4;
const PD_LINES: u8 = 5;

/// Serialize a drained [`ProbeData`] into the frame body — the
/// "drain-to-frame" half of server-push probes.
pub fn encode_probe_data(out: &mut Vec<u8>, data: &ProbeData) {
    match data {
        ProbeData::Raster(events) => {
            out.push(PD_RASTER);
            put_varint(out, events.len() as u64);
            for &(step, gid) in events {
                put_varint(out, step);
                put_varint(out, gid as u64);
            }
        }
        ProbeData::Rates { bin_steps, pops, rows } => {
            out.push(PD_RATES);
            put_varint(out, *bin_steps);
            put_varint(out, pops.len() as u64);
            for p in pops {
                put_str(out, p);
            }
            put_varint(out, rows.len() as u64);
            for (start, vals) in rows {
                put_varint(out, *start);
                put_varint(out, vals.len() as u64);
                for &v in vals {
                    put_f64(out, v);
                }
            }
        }
        ProbeData::Traces(traces) => {
            out.push(PD_TRACES);
            put_varint(out, traces.len() as u64);
            for (gid, pts) in traces {
                put_varint(out, *gid as u64);
                put_varint(out, pts.len() as u64);
                for &(step, v) in pts {
                    put_varint(out, step);
                    put_f64(out, v);
                }
            }
        }
        ProbeData::Weights(snaps) => {
            out.push(PD_WEIGHTS);
            put_varint(out, snaps.len() as u64);
            for (step, edges) in snaps {
                put_varint(out, *step);
                put_varint(out, edges.len() as u64);
                for &(pre, post, delay, w) in edges {
                    put_varint(out, pre as u64);
                    put_varint(out, post as u64);
                    put_varint(out, delay as u64);
                    put_f64(out, w);
                }
            }
        }
        ProbeData::Phases(rows) => {
            out.push(PD_PHASES);
            put_varint(out, rows.len() as u64);
            for (rank, phase, secs) in rows {
                put_varint(out, *rank as u64);
                put_str(out, phase);
                put_f64(out, *secs);
            }
        }
        ProbeData::Lines(lines) => {
            out.push(PD_LINES);
            put_varint(out, lines.len() as u64);
            for l in lines {
                put_str(out, l);
            }
        }
    }
}

/// Decode one [`ProbeData`]; advances `pos`.
pub fn decode_probe_data(
    buf: &[u8],
    pos: &mut usize,
) -> Result<ProbeData, ProtoError> {
    let tag = *buf.get(*pos).ok_or(CodecError::Truncated)?;
    *pos += 1;
    match tag {
        PD_RASTER => {
            let n = get_count(buf, pos)?;
            let mut events: Vec<(Step, Gid)> = Vec::with_capacity(n);
            for _ in 0..n {
                let step = get_varint(buf, pos)?;
                let gid = get_u32(buf, pos)?;
                events.push((step, gid));
            }
            Ok(ProbeData::Raster(events))
        }
        PD_RATES => {
            let bin_steps = get_varint(buf, pos)?;
            let np = get_count(buf, pos)?;
            let mut pops = Vec::with_capacity(np);
            for _ in 0..np {
                pops.push(get_str(buf, pos)?);
            }
            let nr = get_count(buf, pos)?;
            let mut rows: Vec<(Step, Vec<f64>)> = Vec::with_capacity(nr);
            for _ in 0..nr {
                let start = get_varint(buf, pos)?;
                let nv = get_count(buf, pos)?;
                let mut vals = Vec::with_capacity(nv);
                for _ in 0..nv {
                    vals.push(get_f64(buf, pos)?);
                }
                rows.push((start, vals));
            }
            Ok(ProbeData::Rates { bin_steps, pops, rows })
        }
        PD_TRACES => {
            let n = get_count(buf, pos)?;
            let mut traces: Vec<(Gid, Vec<(Step, f64)>)> =
                Vec::with_capacity(n);
            for _ in 0..n {
                let gid = get_u32(buf, pos)?;
                let np = get_count(buf, pos)?;
                let mut pts = Vec::with_capacity(np);
                for _ in 0..np {
                    let step = get_varint(buf, pos)?;
                    let v = get_f64(buf, pos)?;
                    pts.push((step, v));
                }
                traces.push((gid, pts));
            }
            Ok(ProbeData::Traces(traces))
        }
        PD_WEIGHTS => {
            let n = get_count(buf, pos)?;
            let mut snaps: Vec<(Step, Vec<(Gid, Gid, u16, f64)>)> =
                Vec::with_capacity(n);
            for _ in 0..n {
                let step = get_varint(buf, pos)?;
                let ne = get_count(buf, pos)?;
                let mut edges = Vec::with_capacity(ne);
                for _ in 0..ne {
                    let pre = get_u32(buf, pos)?;
                    let post = get_u32(buf, pos)?;
                    let delay = get_u16(buf, pos)?;
                    let w = get_f64(buf, pos)?;
                    edges.push((pre, post, delay, w));
                }
                snaps.push((step, edges));
            }
            Ok(ProbeData::Weights(snaps))
        }
        PD_PHASES => {
            let n = get_count(buf, pos)?;
            let mut rows: Vec<(u16, String, f64)> = Vec::with_capacity(n);
            for _ in 0..n {
                let rank = get_u16(buf, pos)?;
                let phase = get_str(buf, pos)?;
                let secs = get_f64(buf, pos)?;
                rows.push((rank, phase, secs));
            }
            Ok(ProbeData::Phases(rows))
        }
        PD_LINES => {
            let n = get_count(buf, pos)?;
            let mut lines = Vec::with_capacity(n);
            for _ in 0..n {
                lines.push(get_str(buf, pos)?);
            }
            Ok(ProbeData::Lines(lines))
        }
        other => Err(ProtoError::UnknownTag(other)),
    }
}

// ---------------------------------------------------------------------
// Requests
// ---------------------------------------------------------------------

const RQ_CREATE: u8 = 0x01;
const RQ_RUN: u8 = 0x02;
const RQ_DRAIN: u8 = 0x03;
const RQ_POISSON: u8 = 0x04;
const RQ_DC: u8 = 0x05;
const RQ_SUSPEND: u8 = 0x06;
const RQ_RESUME: u8 = 0x07;
const RQ_CHECKPOINT: u8 = 0x08;
const RQ_CLOSE: u8 = 0x09;
const RQ_STATS: u8 = 0x0a;
const RQ_SHUTDOWN: u8 = 0x0b;

const PS_RASTER: u8 = 0;
const PS_RATES: u8 = 1;
const PS_PHASES: u8 = 2;

fn put_probe_spec(out: &mut Vec<u8>, p: &ProbeSpec) {
    match p {
        ProbeSpec::Raster { name } => {
            out.push(PS_RASTER);
            put_str(out, name);
        }
        ProbeSpec::Rates { name, bin_steps } => {
            out.push(PS_RATES);
            put_str(out, name);
            put_varint(out, *bin_steps);
        }
        ProbeSpec::Phases { name } => {
            out.push(PS_PHASES);
            put_str(out, name);
        }
    }
}

fn get_probe_spec(
    buf: &[u8],
    pos: &mut usize,
) -> Result<ProbeSpec, ProtoError> {
    let tag = *buf.get(*pos).ok_or(CodecError::Truncated)?;
    *pos += 1;
    match tag {
        PS_RASTER => Ok(ProbeSpec::Raster { name: get_str(buf, pos)? }),
        PS_RATES => Ok(ProbeSpec::Rates {
            name: get_str(buf, pos)?,
            bin_steps: get_varint(buf, pos)?,
        }),
        PS_PHASES => Ok(ProbeSpec::Phases { name: get_str(buf, pos)? }),
        other => Err(ProtoError::UnknownTag(other)),
    }
}

/// Serialize one request into a frame payload.
pub fn encode_request(req: &Request) -> Vec<u8> {
    let mut out = Vec::new();
    match req {
        Request::Create { doc, overrides, probes } => {
            out.push(RQ_CREATE);
            put_str(&mut out, doc);
            put_varint(&mut out, overrides.len() as u64);
            for o in overrides {
                put_str(&mut out, o);
            }
            put_varint(&mut out, probes.len() as u64);
            for p in probes {
                put_probe_spec(&mut out, p);
            }
        }
        Request::Run { session, steps, push } => {
            out.push(RQ_RUN);
            put_varint(&mut out, *session);
            put_varint(&mut out, *steps);
            put_varint(&mut out, *push as u64);
        }
        Request::Drain { session, probe } => {
            out.push(RQ_DRAIN);
            put_varint(&mut out, *session);
            put_str(&mut out, probe);
        }
        Request::Poisson { session, pop, rate_hz, weight_pa } => {
            out.push(RQ_POISSON);
            put_varint(&mut out, *session);
            put_str(&mut out, pop);
            put_f64(&mut out, *rate_hz);
            put_f64(&mut out, *weight_pa);
        }
        Request::Dc { session, pop, dc_pa } => {
            out.push(RQ_DC);
            put_varint(&mut out, *session);
            put_str(&mut out, pop);
            put_f64(&mut out, *dc_pa);
        }
        Request::Suspend { session } => {
            out.push(RQ_SUSPEND);
            put_varint(&mut out, *session);
        }
        Request::Resume { session } => {
            out.push(RQ_RESUME);
            put_varint(&mut out, *session);
        }
        Request::Checkpoint { session } => {
            out.push(RQ_CHECKPOINT);
            put_varint(&mut out, *session);
        }
        Request::Close { session } => {
            out.push(RQ_CLOSE);
            put_varint(&mut out, *session);
        }
        Request::Stats => out.push(RQ_STATS),
        Request::Shutdown => out.push(RQ_SHUTDOWN),
    }
    out
}

/// Decode one request payload; total over arbitrary bytes.
pub fn decode_request(buf: &[u8]) -> Result<Request, ProtoError> {
    let mut pos = 0usize;
    let tag = *buf.get(pos).ok_or(CodecError::Truncated)?;
    pos += 1;
    let req = match tag {
        RQ_CREATE => {
            let doc = get_str(buf, &mut pos)?;
            let no = get_count(buf, &mut pos)?;
            let mut overrides = Vec::with_capacity(no);
            for _ in 0..no {
                overrides.push(get_str(buf, &mut pos)?);
            }
            let np = get_count(buf, &mut pos)?;
            let mut probes = Vec::with_capacity(np);
            for _ in 0..np {
                probes.push(get_probe_spec(buf, &mut pos)?);
            }
            Request::Create { doc, overrides, probes }
        }
        RQ_RUN => Request::Run {
            session: get_varint(buf, &mut pos)?,
            steps: get_varint(buf, &mut pos)?,
            push: get_bool(buf, &mut pos)?,
        },
        RQ_DRAIN => Request::Drain {
            session: get_varint(buf, &mut pos)?,
            probe: get_str(buf, &mut pos)?,
        },
        RQ_POISSON => Request::Poisson {
            session: get_varint(buf, &mut pos)?,
            pop: get_str(buf, &mut pos)?,
            rate_hz: get_f64(buf, &mut pos)?,
            weight_pa: get_f64(buf, &mut pos)?,
        },
        RQ_DC => Request::Dc {
            session: get_varint(buf, &mut pos)?,
            pop: get_str(buf, &mut pos)?,
            dc_pa: get_f64(buf, &mut pos)?,
        },
        RQ_SUSPEND => {
            Request::Suspend { session: get_varint(buf, &mut pos)? }
        }
        RQ_RESUME => {
            Request::Resume { session: get_varint(buf, &mut pos)? }
        }
        RQ_CHECKPOINT => {
            Request::Checkpoint { session: get_varint(buf, &mut pos)? }
        }
        RQ_CLOSE => {
            Request::Close { session: get_varint(buf, &mut pos)? }
        }
        RQ_STATS => Request::Stats,
        RQ_SHUTDOWN => Request::Shutdown,
        other => return Err(ProtoError::UnknownTag(other)),
    };
    if pos != buf.len() {
        return Err(ProtoError::TrailingBytes { used: pos, len: buf.len() });
    }
    Ok(req)
}

// ---------------------------------------------------------------------
// Replies
// ---------------------------------------------------------------------

const RP_OK: u8 = 0x81;
const RP_CREATED: u8 = 0x82;
const RP_REFUSED: u8 = 0x83;
const RP_ERROR: u8 = 0x84;
const RP_RAN: u8 = 0x85;
const RP_DATA: u8 = 0x86;
const RP_PUSH: u8 = 0x87;
const RP_BLOB: u8 = 0x88;
const RP_STATS: u8 = 0x89;

const ADM_SESSIONS: u8 = 0;
const ADM_THREADS: u8 = 1;
const ADM_MEMORY: u8 = 2;
const ADM_SESSION_THREADS: u8 = 3;

fn put_admission(out: &mut Vec<u8>, e: &AdmissionError) {
    match e {
        AdmissionError::Sessions { active, max } => {
            out.push(ADM_SESSIONS);
            put_varint(out, *active);
            put_varint(out, *max);
        }
        AdmissionError::Threads { want, in_use, budget } => {
            out.push(ADM_THREADS);
            put_varint(out, *want);
            put_varint(out, *in_use);
            put_varint(out, *budget);
        }
        AdmissionError::Memory { want_bytes, in_use, budget } => {
            out.push(ADM_MEMORY);
            put_varint(out, *want_bytes);
            put_varint(out, *in_use);
            put_varint(out, *budget);
        }
        AdmissionError::SessionThreads { want, max } => {
            out.push(ADM_SESSION_THREADS);
            put_varint(out, *want);
            put_varint(out, *max);
        }
    }
}

fn get_admission(
    buf: &[u8],
    pos: &mut usize,
) -> Result<AdmissionError, ProtoError> {
    let tag = *buf.get(*pos).ok_or(CodecError::Truncated)?;
    *pos += 1;
    match tag {
        ADM_SESSIONS => Ok(AdmissionError::Sessions {
            active: get_varint(buf, pos)?,
            max: get_varint(buf, pos)?,
        }),
        ADM_THREADS => Ok(AdmissionError::Threads {
            want: get_varint(buf, pos)?,
            in_use: get_varint(buf, pos)?,
            budget: get_varint(buf, pos)?,
        }),
        ADM_MEMORY => Ok(AdmissionError::Memory {
            want_bytes: get_varint(buf, pos)?,
            in_use: get_varint(buf, pos)?,
            budget: get_varint(buf, pos)?,
        }),
        ADM_SESSION_THREADS => Ok(AdmissionError::SessionThreads {
            want: get_varint(buf, pos)?,
            max: get_varint(buf, pos)?,
        }),
        other => Err(ProtoError::UnknownTag(other)),
    }
}

/// Serialize one reply into a frame payload.
pub fn encode_reply(rep: &Reply) -> Vec<u8> {
    let mut out = Vec::new();
    match rep {
        Reply::Ok => out.push(RP_OK),
        Reply::Created { session } => {
            out.push(RP_CREATED);
            put_varint(&mut out, *session);
        }
        Reply::Refused(e) => {
            out.push(RP_REFUSED);
            put_admission(&mut out, e);
        }
        Reply::Error(msg) => {
            out.push(RP_ERROR);
            put_str(&mut out, msg);
        }
        Reply::Ran { session, step } => {
            out.push(RP_RAN);
            put_varint(&mut out, *session);
            put_varint(&mut out, *step);
        }
        Reply::Data { probe, data } => {
            out.push(RP_DATA);
            put_str(&mut out, probe);
            encode_probe_data(&mut out, data);
        }
        Reply::Push { session, probe, data } => {
            out.push(RP_PUSH);
            put_varint(&mut out, *session);
            put_str(&mut out, probe);
            encode_probe_data(&mut out, data);
        }
        Reply::Blob(bytes) => {
            out.push(RP_BLOB);
            put_bytes(&mut out, bytes);
        }
        Reply::Stats(s) => {
            out.push(RP_STATS);
            put_varint(&mut out, s.sessions);
            put_varint(&mut out, s.active);
            put_varint(&mut out, s.suspended);
            put_varint(&mut out, s.threads_in_use);
            put_varint(&mut out, s.thread_budget);
            put_varint(&mut out, s.mem_in_use);
            put_varint(&mut out, s.mem_budget);
        }
    }
    out
}

/// Decode one reply payload; total over arbitrary bytes.
pub fn decode_reply(buf: &[u8]) -> Result<Reply, ProtoError> {
    let mut pos = 0usize;
    let tag = *buf.get(pos).ok_or(CodecError::Truncated)?;
    pos += 1;
    let rep = match tag {
        RP_OK => Reply::Ok,
        RP_CREATED => {
            Reply::Created { session: get_varint(buf, &mut pos)? }
        }
        RP_REFUSED => Reply::Refused(get_admission(buf, &mut pos)?),
        RP_ERROR => Reply::Error(get_str(buf, &mut pos)?),
        RP_RAN => Reply::Ran {
            session: get_varint(buf, &mut pos)?,
            step: get_varint(buf, &mut pos)?,
        },
        RP_DATA => Reply::Data {
            probe: get_str(buf, &mut pos)?,
            data: decode_probe_data(buf, &mut pos)?,
        },
        RP_PUSH => Reply::Push {
            session: get_varint(buf, &mut pos)?,
            probe: get_str(buf, &mut pos)?,
            data: decode_probe_data(buf, &mut pos)?,
        },
        RP_BLOB => Reply::Blob(get_bytes(buf, &mut pos)?),
        RP_STATS => Reply::Stats(ServeStats {
            sessions: get_varint(buf, &mut pos)?,
            active: get_varint(buf, &mut pos)?,
            suspended: get_varint(buf, &mut pos)?,
            threads_in_use: get_varint(buf, &mut pos)?,
            thread_budget: get_varint(buf, &mut pos)?,
            mem_in_use: get_varint(buf, &mut pos)?,
            mem_budget: get_varint(buf, &mut pos)?,
        }),
        other => return Err(ProtoError::UnknownTag(other)),
    };
    if pos != buf.len() {
        return Err(ProtoError::TrailingBytes { used: pos, len: buf.len() });
    }
    Ok(rep)
}

// ---------------------------------------------------------------------
// Stream I/O: hello + length-prefixed frames
// ---------------------------------------------------------------------

/// Write the 10-byte hello (magic + version).
pub fn send_hello(w: &mut impl io::Write) -> io::Result<()> {
    w.write_all(&SERVE_MAGIC.to_le_bytes())?;
    w.write_all(&SERVE_VERSION.to_le_bytes())?;
    w.flush()
}

/// Read and validate the peer's hello.
pub fn expect_hello(r: &mut impl io::Read) -> Result<()> {
    let mut b = [0u8; 10];
    r.read_exact(&mut b).context("reading protocol hello")?;
    let magic = u64::from_le_bytes(b[..8].try_into().unwrap());
    if magic != SERVE_MAGIC {
        return Err(ProtoError::BadMagic { got: magic }.into());
    }
    let version = u16::from_le_bytes(b[8..].try_into().unwrap());
    if version != SERVE_VERSION {
        return Err(ProtoError::BadVersion { got: version }.into());
    }
    Ok(())
}

/// Write one length-prefixed frame.
pub fn write_frame(w: &mut impl io::Write, payload: &[u8]) -> Result<()> {
    if payload.len() > MAX_SERVE_FRAME {
        return Err(ProtoError::FrameTooLarge {
            bytes: payload.len() as u64,
            limit: MAX_SERVE_FRAME as u64,
        }
        .into());
    }
    w.write_all(&(payload.len() as u32).to_le_bytes())?;
    w.write_all(payload)?;
    w.flush()?;
    Ok(())
}

/// Read one frame payload; errors on EOF (use [`read_frame_opt`] when
/// a clean close between frames is expected).
pub fn read_frame(r: &mut impl io::Read) -> Result<Vec<u8>> {
    read_frame_opt(r)?.context("connection closed")
}

/// Read one frame payload, or `None` on a clean EOF at a frame
/// boundary. The length prefix is validated against
/// [`MAX_SERVE_FRAME`] before any allocation.
pub fn read_frame_opt(
    r: &mut impl io::Read,
) -> Result<Option<Vec<u8>>> {
    let mut len4 = [0u8; 4];
    let mut got = 0usize;
    while got < 4 {
        match r.read(&mut len4[got..]) {
            Ok(0) if got == 0 => return Ok(None),
            Ok(0) => bail!("connection closed mid-frame header"),
            Ok(n) => got += n,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e.into()),
        }
    }
    let len = u32::from_le_bytes(len4) as usize;
    if len > MAX_SERVE_FRAME {
        return Err(ProtoError::FrameTooLarge {
            bytes: len as u64,
            limit: MAX_SERVE_FRAME as u64,
        }
        .into());
    }
    let mut buf = vec![0u8; len];
    r.read_exact(&mut buf).context("reading frame payload")?;
    Ok(Some(buf))
}

// ---------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip_request(req: Request) {
        let bytes = encode_request(&req);
        assert_eq!(decode_request(&bytes).unwrap(), req);
    }

    fn roundtrip_reply(rep: Reply) {
        let bytes = encode_reply(&rep);
        assert_eq!(decode_reply(&bytes).unwrap(), rep);
    }

    #[test]
    fn request_roundtrips() {
        roundtrip_request(Request::Create {
            doc: "[network]\nkind = \"potjans\"\n".into(),
            overrides: vec!["seed=23".into(), "engine.ranks=2".into()],
            probes: vec![
                ProbeSpec::Raster { name: "spikes".into() },
                ProbeSpec::Rates { name: "rates".into(), bin_steps: 100 },
                ProbeSpec::Phases { name: "phases".into() },
            ],
        });
        roundtrip_request(Request::Run {
            session: 7,
            steps: 600,
            push: true,
        });
        roundtrip_request(Request::Drain {
            session: u64::MAX,
            probe: "spikes".into(),
        });
        roundtrip_request(Request::Poisson {
            session: 1,
            pop: "L4e".into(),
            rate_hz: 8000.0,
            weight_pa: 87.8,
        });
        roundtrip_request(Request::Dc {
            session: 1,
            pop: "L4e".into(),
            dc_pa: -30.5,
        });
        roundtrip_request(Request::Suspend { session: 3 });
        roundtrip_request(Request::Resume { session: 3 });
        roundtrip_request(Request::Checkpoint { session: 3 });
        roundtrip_request(Request::Close { session: 3 });
        roundtrip_request(Request::Stats);
        roundtrip_request(Request::Shutdown);
    }

    #[test]
    fn reply_roundtrips() {
        roundtrip_reply(Reply::Ok);
        roundtrip_reply(Reply::Created { session: 42 });
        roundtrip_reply(Reply::Refused(AdmissionError::Threads {
            want: 8,
            in_use: 12,
            budget: 16,
        }));
        roundtrip_reply(Reply::Refused(AdmissionError::Sessions {
            active: 4,
            max: 4,
        }));
        roundtrip_reply(Reply::Refused(AdmissionError::Memory {
            want_bytes: 1 << 30,
            in_use: 1 << 29,
            budget: 1 << 30,
        }));
        roundtrip_reply(Reply::Refused(
            AdmissionError::SessionThreads { want: 9, max: 8 },
        ));
        roundtrip_reply(Reply::Error("rank 1: boom".into()));
        roundtrip_reply(Reply::Ran { session: 2, step: 1200 });
        roundtrip_reply(Reply::Blob(vec![0xde, 0xad, 0xbe, 0xef]));
        roundtrip_reply(Reply::Stats(ServeStats {
            sessions: 3,
            active: 2,
            suspended: 1,
            threads_in_use: 6,
            thread_budget: 16,
            mem_in_use: 1 << 20,
            mem_budget: 0,
        }));
    }

    #[test]
    fn probe_data_roundtrips() {
        let variants = vec![
            ProbeData::Raster(vec![(0, 1), (5, 1599), (600, 0)]),
            ProbeData::Rates {
                bin_steps: 100,
                pops: vec!["E".into(), "I".into()],
                rows: vec![(0, vec![3.5, 8.25]), (100, vec![0.0, 1.0])],
            },
            ProbeData::Traces(vec![(7, vec![(0, -65.0), (1, -64.5)])]),
            ProbeData::Weights(vec![(
                300,
                vec![(0, 1, 15, 87.8), (2, 3, 40, -351.2)],
            )]),
            ProbeData::Phases(vec![
                (0, "compute".into(), 1.25),
                (1, "comm_wait".into(), 0.5),
            ]),
            ProbeData::Lines(vec!["a".into(), "b".into()]),
        ];
        for data in variants {
            roundtrip_reply(Reply::Push {
                session: 9,
                probe: "p".into(),
                data,
            });
        }
    }

    #[test]
    fn empty_and_unknown_tags_are_typed_errors() {
        assert!(matches!(
            decode_request(&[]),
            Err(ProtoError::Codec(CodecError::Truncated))
        ));
        assert!(matches!(
            decode_request(&[0x7f]),
            Err(ProtoError::UnknownTag(0x7f))
        ));
        assert!(matches!(
            decode_reply(&[0x01]),
            Err(ProtoError::UnknownTag(0x01))
        ));
    }

    #[test]
    fn trailing_bytes_are_rejected() {
        let mut bytes = encode_request(&Request::Stats);
        bytes.push(0);
        assert!(matches!(
            decode_request(&bytes),
            Err(ProtoError::TrailingBytes { used: 1, len: 2 })
        ));
    }

    #[test]
    fn absurd_counts_are_rejected_before_allocation() {
        // Create with 2^60 overrides declared in a 12-byte frame
        let mut bytes = vec![RQ_CREATE];
        put_str(&mut bytes, ""); // empty doc
        put_varint(&mut bytes, 1u64 << 60);
        let err = decode_request(&bytes).unwrap_err();
        assert!(matches!(
            err,
            ProtoError::Codec(CodecError::Truncated)
        ));
    }

    #[test]
    fn bad_utf8_is_a_typed_error() {
        let mut bytes = vec![RQ_DRAIN];
        put_varint(&mut bytes, 5); // session
        put_varint(&mut bytes, 2); // string length
        bytes.extend_from_slice(&[0xff, 0xfe]);
        assert!(matches!(
            decode_request(&bytes),
            Err(ProtoError::BadUtf8)
        ));
    }

    #[test]
    fn probe_spec_parse_forms() {
        assert_eq!(
            ProbeSpec::parse("raster:spikes").unwrap(),
            ProbeSpec::Raster { name: "spikes".into() }
        );
        assert_eq!(
            ProbeSpec::parse("rates:r:250").unwrap(),
            ProbeSpec::Rates { name: "r".into(), bin_steps: 250 }
        );
        assert_eq!(
            ProbeSpec::parse("phases:p").unwrap(),
            ProbeSpec::Phases { name: "p".into() }
        );
        assert!(ProbeSpec::parse("raster").is_err());
        assert!(ProbeSpec::parse("rates:r").is_err());
        assert!(ProbeSpec::parse("voltage:v").is_err());
        assert!(ProbeSpec::parse("raster:a:b").is_err());
    }

    #[test]
    fn hello_roundtrip_and_mismatches() {
        let mut buf = Vec::new();
        send_hello(&mut buf).unwrap();
        assert_eq!(buf.len(), 10);
        expect_hello(&mut &buf[..]).unwrap();

        let mut bad = buf.clone();
        bad[0] ^= 0xff;
        let err = expect_hello(&mut &bad[..]).unwrap_err();
        let proto = err.downcast_ref::<ProtoError>().unwrap();
        assert!(matches!(proto, ProtoError::BadMagic { .. }));

        let mut old = buf.clone();
        old[8] = 0xff;
        old[9] = 0xff;
        let err = expect_hello(&mut &old[..]).unwrap_err();
        let proto = err.downcast_ref::<ProtoError>().unwrap();
        assert!(matches!(
            proto,
            ProtoError::BadVersion { got: 0xffff }
        ));
    }

    #[test]
    fn frame_roundtrip_and_oversized_prefix() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"hello").unwrap();
        let got = read_frame(&mut &buf[..]).unwrap();
        assert_eq!(got, b"hello");
        assert!(read_frame_opt(&mut &[][..]).unwrap().is_none());

        // a hostile length prefix must be rejected before allocation
        let huge = u32::MAX.to_le_bytes();
        let err = read_frame(&mut &huge[..]).unwrap_err();
        let proto = err.downcast_ref::<ProtoError>().unwrap();
        assert!(matches!(proto, ProtoError::FrameTooLarge { .. }));
    }
}
