//! `cortex` launcher: from-scratch argument parsing (no clap in the
//! offline registry) + the subcommand implementations.
//!
//! ```text
//! cortex run       [--config F] [--set k=v]...   run an experiment
//! cortex verify    [--config F] [--set k=v]...   paper §IV.A verification
//! cortex partition [--config F] [--set k=v]...   inspect the decomposition
//! cortex info      [--artifacts DIR]             PJRT artifact report
//! ```

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::atlas::custom::{custom_spec, CustomNetParams, CustomPopSpec};
use crate::atlas::hpc::{hpc_benchmark_spec, HpcParams};
use crate::atlas::marmoset::{marmoset_spec, MarmosetParams};
use crate::atlas::potjans::{potjans_spec_with, PotjansModels};
use crate::atlas::{random_spec_with, NetworkSpec};
use crate::config::{
    ConfigDoc, EngineKind, ExperimentConfig, NetworkKind,
};
use crate::decomp::{
    area_processes_partition, random_equivalent_partition, RankStore,
};
use crate::engine::{run_simulation, RunConfig, Simulation};
use crate::metrics::table::human_bytes;
use crate::nest_baseline::{run_nest_simulation, NestRunConfig};
use crate::probe::{PopRates, ProbeData};

/// Parsed command line.
#[derive(Debug, Default)]
pub struct Args {
    pub subcommand: String,
    pub config_path: Option<String>,
    pub overrides: Vec<String>,
    pub artifacts_dir: String,
}

impl Args {
    pub fn parse(argv: &[String]) -> Result<Args> {
        let mut args = Args {
            artifacts_dir: "artifacts".into(),
            ..Default::default()
        };
        let mut it = argv.iter().peekable();
        let Some(sub) = it.next() else {
            bail!("usage: cortex <run|verify|partition|info> [options]");
        };
        args.subcommand = sub.clone();
        while let Some(a) = it.next() {
            match a.as_str() {
                "--config" | "-c" => {
                    args.config_path = Some(
                        it.next().context("--config needs a path")?.clone(),
                    );
                }
                "--set" | "-s" => {
                    args.overrides.push(
                        it.next().context("--set needs key=value")?.clone(),
                    );
                }
                "--artifacts" => {
                    args.artifacts_dir =
                        it.next().context("--artifacts needs a dir")?.clone();
                }
                other => bail!("unknown argument '{other}'"),
            }
        }
        Ok(args)
    }

    pub fn experiment(&self) -> Result<ExperimentConfig> {
        let mut doc = match &self.config_path {
            Some(p) => ConfigDoc::load(std::path::Path::new(p))?,
            None => ConfigDoc::parse("")?,
        };
        doc.apply_overrides(&self.overrides)?;
        Ok(ExperimentConfig::from_doc(&doc)?)
    }
}

/// Instantiate the configured network. Every builder receives the
/// configured neuron models (`network.model[_e|_i]` + `[model.*]`
/// parameter tables), so AdEx/HH/parrot populations are reachable from
/// any workload kind.
pub fn build_spec(cfg: &ExperimentConfig) -> NetworkSpec {
    let model_e = cfg.model_params(cfg.model_e);
    let model_i = cfg.model_params(cfg.model_i);
    match cfg.network {
        NetworkKind::Marmoset => marmoset_spec(
            &MarmosetParams {
                n_neurons: cfg.n_neurons,
                n_areas: cfg.n_areas,
                indegree: cfg.indegree as u32,
                model_e,
                model_i,
                ..Default::default()
            },
            cfg.seed,
        ),
        NetworkKind::Potjans => {
            let scale = cfg.n_neurons as f64 / 77_169.0;
            potjans_spec_with(
                scale.min(1.0),
                cfg.seed,
                &PotjansModels { e: model_e, i: model_i },
            )
        }
        NetworkKind::HpcBenchmark => hpc_benchmark_spec(
            &HpcParams {
                n_neurons: cfg.n_neurons,
                indegree: cfg.indegree as u32,
                plastic: cfg.plastic,
                model_e,
                model_i,
                ..Default::default()
            },
            cfg.seed,
        ),
        NetworkKind::Random => random_spec_with(
            cfg.n_neurons,
            cfg.indegree as u32,
            cfg.seed,
            model_e,
            model_i,
        ),
        NetworkKind::Custom => custom_spec(
            &CustomNetParams {
                pops: cfg
                    .custom_pops
                    .iter()
                    .map(|cp| CustomPopSpec {
                        name: cp.name.clone(),
                        n: cp.n,
                        exc: cp.exc,
                        params: cfg.model_params(cp.model),
                    })
                    .collect(),
                indegree: cfg.indegree as u32,
                weight_pa: cfg.weight_pa,
                g: cfg.g,
                bg_rate_hz: cfg.bg_rate_hz,
                ..Default::default()
            },
            cfg.seed,
        ),
    }
}

pub fn run_config_of(cfg: &ExperimentConfig) -> RunConfig {
    RunConfig {
        ranks: cfg.ranks,
        threads: cfg.threads,
        mapping: cfg.mapping,
        comm: cfg.comm,
        backend: cfg.backend,
        exec: cfg.exec,
        steps: cfg.steps(),
        record_limit: cfg.record_raster.then_some(cfg.record_limit as u32),
        verify_ownership: false,
        artifacts_dir: cfg.artifacts_dir.clone(),
        seed: cfg.seed,
    }
}

/// `cortex run`
pub fn cmd_run(args: &Args) -> Result<()> {
    let cfg = args.experiment()?;
    let spec = Arc::new(build_spec(&cfg));
    println!(
        "network '{}': {} neurons, {} synapses, {} areas",
        spec.name,
        spec.n_total(),
        spec.n_edges(),
        spec.n_areas()
    );
    match cfg.engine {
        EngineKind::Cortex => {
            // the launcher runs on the session facade: persistent rank
            // engines plus a per-population rate probe over the run
            let mut sim = Simulation::builder(Arc::clone(&spec))
                .run_config(&run_config_of(&cfg))
                .probe(PopRates::new("rates", cfg.steps().max(1)))
                .build()?;
            sim.run_for(cfg.steps())?;
            let rates = sim.drain("rates")?;
            let out = sim.finish()?;
            let stats = out.raster.stats(
                spec.n_total(),
                cfg.dt_ms,
                cfg.steps(),
            );
            println!(
                "CORTEX: {} steps on {} ranks x {} threads in {:.3}s \
                 ({} spikes, mean rate {:.2} Hz)",
                cfg.steps(),
                cfg.ranks,
                cfg.threads,
                out.wall_seconds,
                out.total_spikes,
                out.total_spikes as f64
                    / spec.n_total() as f64
                    / (cfg.sim_ms * 1e-3)
            );
            if let ProbeData::Rates { pops, rows, .. } = &rates {
                if let Some((_, row)) = rows.last() {
                    let cells: Vec<String> = pops
                        .iter()
                        .zip(row)
                        .map(|(name, hz)| format!("{name} {hz:.2}"))
                        .collect();
                    println!(
                        "per-population rates [Hz]: {}",
                        cells.join(", ")
                    );
                }
            }
            if cfg.record_raster {
                println!(
                    "recorded {} events (ISI-CV {:.2}, synchrony {:.2})",
                    stats.n_events, stats.mean_isi_cv, stats.synchrony
                );
            }
            println!(
                "memory: max-rank {}, imbalance {:.2}; comm {} over {} windows",
                human_bytes(out.memory.max_rank_bytes()),
                out.memory.imbalance(),
                human_bytes(out.comm_bytes),
                out.windows
            );
            println!("--- phase times (critical path) ---");
            print!("{}", out.timer_max.report());
        }
        EngineKind::NestBaseline => {
            let out = run_nest_simulation(
                &spec,
                &NestRunConfig {
                    ranks: cfg.ranks,
                    threads: cfg.threads,
                    steps: cfg.steps(),
                    record_limit: cfg
                        .record_raster
                        .then_some(cfg.record_limit as u32),
                    seed: cfg.seed,
                },
            );
            println!(
                "NEST-baseline: {} steps in {:.3}s ({} spikes); \
                 memory max-rank {}",
                cfg.steps(),
                out.wall_seconds,
                out.total_spikes,
                human_bytes(out.memory.max_rank_bytes()),
            );
            print!("{}", out.timer_max.report());
        }
    }
    Ok(())
}

/// `cortex verify` — the paper's §IV.A case: hpc_benchmark with STDP,
/// thread-ownership aborts armed, firing rate below 10 Hz.
pub fn cmd_verify(args: &Args) -> Result<()> {
    let mut cfg = args.experiment()?;
    cfg.network = NetworkKind::HpcBenchmark;
    cfg.plastic = true;
    let spec = Arc::new(build_spec(&cfg));
    let mut rc = run_config_of(&cfg);
    rc.verify_ownership = true; // the paper's Abort check
    rc.record_limit = Some(spec.n_total() as u32);
    println!(
        "verification network: {} neurons, {} synapses, STDP on E->E",
        spec.n_total(),
        spec.n_edges()
    );
    let out = run_simulation(&spec, &rc)?;
    let rate = out.total_spikes as f64
        / spec.n_total() as f64
        / (cfg.sim_ms * 1e-3);
    println!(
        "simulated {:.0} ms: {} spikes, mean rate {:.2} Hz",
        cfg.sim_ms, out.total_spikes, rate
    );
    println!("thread-ownership violations: 0 (no abort raised)");
    if rate > 0.05 && rate < 10.0 {
        println!("VERIFICATION PASSED (asynchronous regime, rate < 10 Hz)");
        Ok(())
    } else {
        bail!("VERIFICATION FAILED: rate {rate:.2} Hz outside (0.05, 10)");
    }
}

/// `cortex partition` — decomposition inspection (pre-vertex counts, the
/// Fig 9/10 quantities).
pub fn cmd_partition(args: &Args) -> Result<()> {
    let cfg = args.experiment()?;
    let spec = Arc::new(build_spec(&cfg));
    let part = match cfg.mapping {
        crate::config::MappingKind::AreaProcesses => {
            area_processes_partition(&spec, cfg.ranks, cfg.seed)
        }
        crate::config::MappingKind::RandomEquivalent => {
            random_equivalent_partition(spec.n_total(), cfg.ranks, cfg.seed)
        }
    };
    println!(
        "{:?} mapping of '{}' onto {} ranks (imbalance {:.3})",
        cfg.mapping,
        spec.name,
        cfg.ranks,
        part.imbalance()
    );
    println!(
        "{:>5} {:>8} {:>10} {:>10} {:>12} {:>12}",
        "rank", "posts", "pres", "remote", "edges", "memory"
    );
    for r in 0..cfg.ranks {
        let rank_of = part.rank_of.clone();
        let store = RankStore::build(
            &spec,
            &part.members[r],
            move |g| rank_of[g as usize] as usize == r,
            r as u16,
            cfg.threads,
        );
        println!(
            "{:>5} {:>8} {:>10} {:>10} {:>12} {:>12}",
            r,
            store.n_posts(),
            store.n_pres(),
            store.n_remote_pres(),
            store.n_edges(),
            human_bytes(store.memory().total())
        );
    }
    Ok(())
}

/// `cortex info` — artifact + PJRT platform report.
pub fn cmd_info(args: &Args) -> Result<()> {
    let dir = std::path::Path::new(&args.artifacts_dir);
    let manifest = crate::runtime::Manifest::load(dir)?;
    println!("artifacts dir: {}", dir.display());
    println!("lif_step block sizes: {:?}", manifest.lif_sizes);
    let (p22, ..) = manifest.propagators()?;
    println!("baked p22 = {p22}");
    let name = format!("lif_step_n{}", manifest.lif_sizes[0]);
    let exe = crate::runtime::HloExecutable::load(dir, &name)?;
    println!("compiled {} on platform '{}'", exe.name, exe.platform());
    Ok(())
}

pub fn main_with(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv)?;
    match args.subcommand.as_str() {
        "run" => cmd_run(&args),
        "verify" => cmd_verify(&args),
        "partition" => cmd_partition(&args),
        "info" => cmd_info(&args),
        other => bail!(
            "unknown subcommand '{other}' \
             (expected run|verify|partition|info)"
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parse_basic() {
        let a = Args::parse(&s(&[
            "run",
            "--config",
            "configs/x.toml",
            "--set",
            "engine.ranks=8",
        ]))
        .unwrap();
        assert_eq!(a.subcommand, "run");
        assert_eq!(a.config_path.as_deref(), Some("configs/x.toml"));
        assert_eq!(a.overrides, vec!["engine.ranks=8"]);
    }

    #[test]
    fn parse_errors() {
        assert!(Args::parse(&s(&[])).is_err());
        assert!(Args::parse(&s(&["run", "--config"])).is_err());
        assert!(Args::parse(&s(&["run", "--bogus"])).is_err());
    }

    #[test]
    fn experiment_from_overrides_only() {
        let a = Args::parse(&s(&[
            "run",
            "--set",
            "network.n_neurons=500",
            "--set",
            "network.indegree=50",
        ]))
        .unwrap();
        let cfg = a.experiment().unwrap();
        assert_eq!(cfg.n_neurons, 500);
        assert_eq!(cfg.indegree, 50);
    }

    #[test]
    fn exec_mode_flows_into_run_config() {
        let a = Args::parse(&s(&[
            "run",
            "--set",
            "engine.exec=\"scoped\"",
        ]))
        .unwrap();
        let cfg = a.experiment().unwrap();
        assert_eq!(cfg.exec, crate::config::ExecMode::Scoped);
        let rc = run_config_of(&cfg);
        assert_eq!(rc.exec, crate::config::ExecMode::Scoped);
    }

    #[test]
    fn build_spec_all_kinds() {
        for kind in ["marmoset", "potjans", "hpc_benchmark", "random"] {
            let a = Args::parse(&s(&[
                "run",
                "--set",
                &format!("network.kind=\"{kind}\""),
                "--set",
                "network.n_neurons=2000",
                "--set",
                "network.indegree=100",
            ]))
            .unwrap();
            let spec = build_spec(&a.experiment().unwrap());
            assert!(spec.n_total() > 0, "{kind}");
            assert!(spec.n_edges() > 0, "{kind}");
        }
    }

    #[test]
    fn model_knobs_reach_the_spec() {
        use crate::model::NeuronModel;
        // adex E over lif I on the hpc benchmark, AdEx b from [model.adex]
        let a = Args::parse(&s(&[
            "run",
            "--set",
            "network.kind=\"hpc_benchmark\"",
            "--set",
            "network.n_neurons=1000",
            "--set",
            "network.indegree=100",
            "--set",
            "network.model_e=\"adex\"",
            "--set",
            "model.adex.b=99.0",
        ]))
        .unwrap();
        let spec = build_spec(&a.experiment().unwrap());
        assert_eq!(spec.populations[0].model, NeuronModel::Adex);
        assert_eq!(spec.populations[1].model, NeuronModel::Lif);
        let crate::model::ModelParams::Adex(ap) =
            &spec.params[spec.populations[0].params as usize]
        else {
            panic!("E population should be AdEx")
        };
        assert_eq!(ap.b, 99.0);

        // hh everywhere on the random network
        let a = Args::parse(&s(&[
            "run",
            "--set",
            "network.kind=\"random\"",
            "--set",
            "network.n_neurons=500",
            "--set",
            "network.indegree=50",
            "--set",
            "network.model=\"hh\"",
        ]))
        .unwrap();
        let spec = build_spec(&a.experiment().unwrap());
        assert!(spec
            .populations
            .iter()
            .all(|p| p.model == NeuronModel::Hh));
    }

    #[test]
    fn custom_kind_builds_mixed_circuit() {
        use crate::model::NeuronModel;
        let a = Args::parse(&s(&[
            "run",
            "--set",
            "network.kind=\"custom\"",
            "--set",
            "network.indegree=40",
            "--set",
            "network.populations=[\"E:400:adex:e\", \"I:100:lif:i\", \
             \"S:50:parrot:e\"]",
        ]))
        .unwrap();
        let spec = build_spec(&a.experiment().unwrap());
        assert_eq!(spec.n_total(), 550);
        assert_eq!(spec.populations[0].model, NeuronModel::Adex);
        assert_eq!(spec.populations[2].model, NeuronModel::Parrot);
        assert!(spec.n_edges() > 0);
    }
}
