//! `cortex` launcher: from-scratch argument parsing (no clap in the
//! offline registry) + the subcommand implementations.
//!
//! ```text
//! cortex run       [--config F] [--set k=v]...   run an experiment
//!                  [--rank I --peers H:P,...]    … as one TCP cluster rank
//!                  [--raster-out FILE]           … dumping the spike raster
//! cortex sweep     [--config F] [--set k=v]...   run the [sweep] grid over
//!                  [--steps N] [--out FILE]      one shared network build
//! cortex launch    --ranks N [--config F] ...    spawn an N-process TCP
//!                  [--port-base P]               cluster on localhost
//!                  [--group-size N]              … hierarchical host groups
//! cortex verify    [--config F] [--set k=v]...   paper §IV.A verification
//! cortex partition [--config F] [--set k=v]...   inspect the decomposition
//! cortex info      [--artifacts DIR]             PJRT artifact report
//! cortex serve     [--addr H:P] [--set k=v]...   multi-session daemon
//! cortex client    [--addr H:P] VERB [options]   drive a running daemon
//! ```
//!
//! The distributed runtime: `cortex launch --ranks N` spawns N copies of
//! this binary, each running `cortex run --rank i --peers <list>`; the
//! peers flag switches the session onto the TCP transport
//! (`engine.transport = "tcp"`), where every process hosts one rank and
//! exchanges BSB-packed spike frames over sockets. The same flags work
//! by hand across real hosts — give every process the same rank-ordered
//! `--peers` list and a distinct `--rank`.

use std::sync::Arc;

use anyhow::{bail, ensure, Context, Result};

use crate::atlas::custom::{custom_spec, CustomNetParams, CustomPopSpec};
use crate::atlas::hpc::{hpc_benchmark_spec, HpcParams};
use crate::atlas::marmoset::{marmoset_spec, MarmosetParams};
use crate::atlas::potjans::{potjans_spec_with, PotjansModels};
use crate::atlas::{random_spec_with, NetworkSpec};
use crate::comm::CommGroups;
use crate::config::{
    CommTransport, ConfigDoc, EngineKind, ExperimentConfig, NetworkKind,
    RoutingMode, SweepDc, SweepPoisson,
};
use crate::decomp::{
    area_processes_partition, random_equivalent_partition, RankStore,
};
use crate::engine::{
    integrate_rates, run_simulation, Ensemble, RunConfig, Simulation,
    Transport,
};
use crate::metrics::table::human_bytes;
use crate::nest_baseline::{run_nest_simulation, NestRunConfig};
use crate::probe::{PopRates, ProbeData};

/// Parsed command line.
#[derive(Debug, Default)]
pub struct Args {
    pub subcommand: String,
    pub config_path: Option<String>,
    pub overrides: Vec<String>,
    pub artifacts_dir: String,
    /// `--rank I` — the global rank this process hosts (TCP transport).
    pub rank: Option<u16>,
    /// `--peers H:P,H:P,...` — rank-ordered cluster addresses; its
    /// presence switches the run onto the TCP transport.
    pub peers: Option<String>,
    /// `--ranks N` — cluster size for `cortex launch`.
    pub ranks: Option<usize>,
    /// `--group-size N` — host-group width `cortex launch` auto-assigns
    /// under hierarchical routing when `engine.comm_group` is unset.
    pub group_size: Option<usize>,
    /// `--port-base P` — first localhost port `cortex launch` assigns.
    pub port_base: u16,
    /// `--raster-out FILE` — dump the merged spike raster as
    /// "step gid" lines (TCP ranks write `FILE.r<rank>`).
    pub raster_out: Option<String>,
    /// `--addr H:P` — daemon listen/connect address for
    /// `cortex serve` / `cortex client` (overrides `serve.addr`).
    pub addr: Option<String>,
    /// `--session ID` — target session for `cortex client` verbs.
    pub session: Option<u64>,
    /// `--steps N` — step count for `cortex client run`.
    pub steps: Option<u64>,
    /// `--probe SPEC` (repeatable) — probe specs for
    /// `cortex client create` (`raster:NAME`, `rates:NAME:BIN`,
    /// `phases:NAME`) or the probe name for `drain`.
    pub probes: Vec<String>,
    /// `--pop NAME` — target population for `cortex client stim`.
    pub pop: Option<String>,
    /// `--poisson RATE:WEIGHT` — Poisson drive for `cortex client stim`.
    pub poisson: Option<String>,
    /// `--dc PA` — DC drive for `cortex client stim`.
    pub dc: Option<f64>,
    /// `--push` — stream probe data with `cortex client run`.
    pub push: bool,
    /// `--out FILE` — output path for `cortex client checkpoint`.
    pub out: Option<String>,
    /// Bare (non-flag) tokens after the subcommand — the
    /// `cortex client` verb and its operands.
    pub positional: Vec<String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Result<Args> {
        let mut args = Args {
            artifacts_dir: "artifacts".into(),
            port_base: 29600,
            ..Default::default()
        };
        let mut it = argv.iter().peekable();
        let Some(sub) = it.next() else {
            bail!(
                "usage: cortex \
                 <run|sweep|launch|verify|partition|info|serve|client> \
                 [options]"
            );
        };
        args.subcommand = sub.clone();
        while let Some(a) = it.next() {
            match a.as_str() {
                "--config" | "-c" => {
                    args.config_path = Some(
                        it.next().context("--config needs a path")?.clone(),
                    );
                }
                "--set" | "-s" => {
                    args.overrides.push(
                        it.next().context("--set needs key=value")?.clone(),
                    );
                }
                "--artifacts" => {
                    args.artifacts_dir =
                        it.next().context("--artifacts needs a dir")?.clone();
                }
                "--rank" => {
                    args.rank = Some(
                        it.next()
                            .context("--rank needs a rank index")?
                            .parse()
                            .context("--rank must be an integer")?,
                    );
                }
                "--peers" => {
                    args.peers = Some(
                        it.next()
                            .context(
                                "--peers needs a comma-separated \
                                 host:port list",
                            )?
                            .clone(),
                    );
                }
                "--ranks" => {
                    args.ranks = Some(
                        it.next()
                            .context("--ranks needs a count")?
                            .parse()
                            .context("--ranks must be an integer")?,
                    );
                }
                "--group-size" => {
                    args.group_size = Some(
                        it.next()
                            .context("--group-size needs a count")?
                            .parse()
                            .context("--group-size must be an integer")?,
                    );
                }
                "--port-base" => {
                    args.port_base = it
                        .next()
                        .context("--port-base needs a port")?
                        .parse()
                        .context("--port-base must be a port number")?;
                }
                "--raster-out" => {
                    args.raster_out = Some(
                        it.next()
                            .context("--raster-out needs a path")?
                            .clone(),
                    );
                }
                "--addr" => {
                    args.addr = Some(
                        it.next()
                            .context("--addr needs host:port")?
                            .clone(),
                    );
                }
                "--session" => {
                    args.session = Some(
                        it.next()
                            .context("--session needs an id")?
                            .parse()
                            .context("--session must be an integer")?,
                    );
                }
                "--steps" => {
                    args.steps = Some(
                        it.next()
                            .context("--steps needs a count")?
                            .parse()
                            .context("--steps must be an integer")?,
                    );
                }
                "--probe" => {
                    args.probes.push(
                        it.next().context("--probe needs a spec")?.clone(),
                    );
                }
                "--pop" => {
                    args.pop = Some(
                        it.next()
                            .context("--pop needs a population name")?
                            .clone(),
                    );
                }
                "--poisson" => {
                    args.poisson = Some(
                        it.next()
                            .context("--poisson needs RATE:WEIGHT")?
                            .clone(),
                    );
                }
                "--dc" => {
                    args.dc = Some(
                        it.next()
                            .context("--dc needs a current in pA")?
                            .parse()
                            .context("--dc must be a number")?,
                    );
                }
                "--push" => args.push = true,
                "--out" => {
                    args.out = Some(
                        it.next().context("--out needs a path")?.clone(),
                    );
                }
                other if !other.starts_with('-') => {
                    args.positional.push(other.to_string());
                }
                other => bail!("unknown argument '{other}'"),
            }
        }
        Ok(args)
    }

    pub fn experiment(&self) -> Result<ExperimentConfig> {
        let mut doc = match &self.config_path {
            Some(p) => ConfigDoc::load(std::path::Path::new(p))?,
            None => ConfigDoc::parse("")?,
        };
        doc.apply_overrides(&self.overrides)?;
        // --peers / --rank translate into the equivalent config keys;
        // a peers list implies the TCP transport and fixes the rank
        // count, so one flag is enough to join a cluster
        let mut synth = Vec::new();
        if let Some(peers) = &self.peers {
            let quoted: Vec<String> = peers
                .split(',')
                .map(|s| {
                    let s = s.trim();
                    // the list is spliced into a TOML override below —
                    // reject anything that could escape the string
                    // literal (no host:port contains a quote or
                    // backslash; IPv6 brackets are fine inside one)
                    ensure!(
                        !s.is_empty() && !s.contains(['"', '\\']),
                        "invalid peer address '{s}'"
                    );
                    Ok(format!("\"{s}\""))
                })
                .collect::<Result<_>>()?;
            synth.push("engine.transport=\"tcp\"".to_string());
            synth.push(format!("engine.peers=[{}]", quoted.join(", ")));
            synth.push(format!("engine.ranks={}", quoted.len()));
        }
        if let Some(r) = self.rank {
            synth.push(format!("engine.rank={r}"));
        }
        doc.apply_overrides(&synth)?;
        Ok(ExperimentConfig::from_doc(&doc)?)
    }
}

/// Instantiate the configured network. Every builder receives the
/// configured neuron models (`network.model[_e|_i]` + `[model.*]`
/// parameter tables), so AdEx/HH/parrot populations are reachable from
/// any workload kind.
pub fn build_spec(cfg: &ExperimentConfig) -> NetworkSpec {
    let model_e = cfg.model_params(cfg.model_e);
    let model_i = cfg.model_params(cfg.model_i);
    match cfg.network {
        NetworkKind::Marmoset => marmoset_spec(
            &MarmosetParams {
                n_neurons: cfg.n_neurons,
                n_areas: cfg.n_areas,
                indegree: cfg.indegree as u32,
                model_e,
                model_i,
                ..Default::default()
            },
            cfg.seed,
        ),
        NetworkKind::Potjans => {
            let scale = cfg.n_neurons as f64 / 77_169.0;
            potjans_spec_with(
                scale.min(1.0),
                cfg.seed,
                &PotjansModels { e: model_e, i: model_i },
            )
        }
        NetworkKind::HpcBenchmark => hpc_benchmark_spec(
            &HpcParams {
                n_neurons: cfg.n_neurons,
                indegree: cfg.indegree as u32,
                plastic: cfg.plastic,
                model_e,
                model_i,
                ..Default::default()
            },
            cfg.seed,
        ),
        NetworkKind::Random => random_spec_with(
            cfg.n_neurons,
            cfg.indegree as u32,
            cfg.seed,
            model_e,
            model_i,
        ),
        NetworkKind::Custom => custom_spec(
            &CustomNetParams {
                pops: cfg
                    .custom_pops
                    .iter()
                    .map(|cp| CustomPopSpec {
                        name: cp.name.clone(),
                        n: cp.n,
                        exc: cp.exc,
                        params: cfg.model_params(cp.model),
                    })
                    .collect(),
                indegree: cfg.indegree as u32,
                weight_pa: cfg.weight_pa,
                g: cfg.g,
                bg_rate_hz: cfg.bg_rate_hz,
                ..Default::default()
            },
            cfg.seed,
        ),
    }
}

pub fn run_config_of(cfg: &ExperimentConfig) -> RunConfig {
    RunConfig {
        ranks: cfg.ranks,
        threads: cfg.threads,
        mapping: cfg.mapping,
        comm: cfg.comm,
        backend: cfg.backend,
        exec: cfg.exec,
        build: cfg.build,
        integrate: cfg.integrate,
        routing: cfg.routing,
        comm_group: cfg.comm_group.clone(),
        steps: cfg.steps(),
        record_limit: cfg.record_raster.then_some(cfg.record_limit as u32),
        verify_ownership: false,
        artifacts_dir: cfg.artifacts_dir.clone(),
        seed: cfg.seed,
    }
}

/// `cortex run`
pub fn cmd_run(args: &Args) -> Result<()> {
    let cfg = args.experiment()?;
    let spec = Arc::new(build_spec(&cfg));
    println!(
        "network '{}': {} neurons, {} synapses, {} areas",
        spec.name,
        spec.n_total(),
        spec.n_edges(),
        spec.n_areas()
    );
    match cfg.engine {
        EngineKind::Cortex => {
            // the launcher runs on the session facade: persistent rank
            // engines plus a per-population rate probe over the run
            let transport = match cfg.transport {
                CommTransport::Local => Transport::Local,
                CommTransport::Tcp => {
                    let rank = cfg.tcp_rank.context(
                        "engine.transport = \"tcp\" needs --rank (or \
                         engine.rank): the global rank this process \
                         hosts",
                    )?;
                    println!(
                        "rank {rank}: joining a {}-rank TCP cluster",
                        cfg.peers.len()
                    );
                    Transport::Tcp {
                        rank: rank as u16,
                        peers: cfg.peers.clone(),
                    }
                }
            };
            let mut sim = Simulation::builder(Arc::clone(&spec))
                .run_config(&run_config_of(&cfg))
                .transport(transport)
                .probe(PopRates::new("rates", cfg.steps().max(1)))
                .build()?;
            sim.run_for(cfg.steps())?;
            let rates = sim.drain("rates")?;
            let out = sim.finish()?;
            let stats = out.raster.stats(
                spec.n_total(),
                cfg.dt_ms,
                cfg.steps(),
            );
            println!(
                "CORTEX: {} steps on {} ranks x {} threads in {:.3}s \
                 ({} spikes, mean rate {:.2} Hz)",
                cfg.steps(),
                cfg.ranks,
                cfg.threads,
                out.wall_seconds,
                out.total_spikes,
                out.total_spikes as f64
                    / spec.n_total() as f64
                    / (cfg.sim_ms * 1e-3)
            );
            if let ProbeData::Rates { pops, rows, .. } = &rates {
                if let Some((_, row)) = rows.last() {
                    let cells: Vec<String> = pops
                        .iter()
                        .zip(row)
                        .map(|(name, hz)| format!("{name} {hz:.2}"))
                        .collect();
                    println!(
                        "per-population rates [Hz]: {}",
                        cells.join(", ")
                    );
                }
            }
            if cfg.record_raster {
                println!(
                    "recorded {} events (ISI-CV {:.2}, synchrony {:.2})",
                    stats.n_events, stats.mean_isi_cv, stats.synchrony
                );
            }
            println!(
                "memory: max-rank {}, imbalance {:.2}; comm {} sent / \
                 {} received over {} windows ({:?} routing)",
                human_bytes(out.memory.max_rank_bytes()),
                out.memory.imbalance(),
                human_bytes(out.comm_bytes),
                human_bytes(out.comm_recv_bytes),
                out.windows,
                cfg.routing
            );
            println!(
                "comm frames: {} total; overlap ratio {:.2} \
                 (exchange ns hidden behind compute, min over ranks)",
                out.comm_frames, out.comm_overlap_ratio
            );
            println!("--- phase times (critical path) ---");
            print!("{}", out.timer_max.report());
            // per-model integrate throughput, from the aggregate timer
            // (summed over workers/ranks, so the division is exact)
            for (m, n, ns) in
                integrate_rates(&spec, &out.timer_sum, cfg.steps())
            {
                println!(
                    "integrate {m:?} ({:?}): {n} neurons, \
                     {ns:.1} ns/neuron-step",
                    cfg.integrate
                );
            }
            if let Some(path) = &args.raster_out {
                // TCP ranks each dump their own shard; `sort -n` over
                // the concatenation reproduces a single-process dump
                let path = match (cfg.transport, cfg.tcp_rank) {
                    (CommTransport::Tcp, Some(r)) => {
                        format!("{path}.r{r}")
                    }
                    _ => path.clone(),
                };
                write_raster(&path, &out.raster.events)?;
            }
        }
        EngineKind::NestBaseline => {
            let out = run_nest_simulation(
                &spec,
                &NestRunConfig {
                    ranks: cfg.ranks,
                    threads: cfg.threads,
                    steps: cfg.steps(),
                    record_limit: cfg
                        .record_raster
                        .then_some(cfg.record_limit as u32),
                    seed: cfg.seed,
                },
            );
            println!(
                "NEST-baseline: {} steps in {:.3}s ({} spikes); \
                 memory max-rank {}",
                cfg.steps(),
                out.wall_seconds,
                out.total_spikes,
                human_bytes(out.memory.max_rank_bytes()),
            );
            print!("{}", out.timer_max.report());
            if let Some(path) = &args.raster_out {
                write_raster(path, &out.raster.events)?;
            }
        }
    }
    Ok(())
}

/// Dump a spike raster as "step gid" lines (already (step, gid)-sorted
/// by the merge) — the format the distributed smoke test diffs.
fn write_raster(path: &str, events: &[(u64, u32)]) -> Result<()> {
    use std::fmt::Write;
    let mut s = String::with_capacity(events.len() * 12);
    for (step, gid) in events {
        let _ = writeln!(s, "{step} {gid}");
    }
    std::fs::write(path, s)
        .with_context(|| format!("writing raster to {path}"))?;
    println!("raster written to {path} ({} events)", events.len());
    Ok(())
}

/// One point of the `[sweep]` grid: a drive seed plus optional
/// stimulus overrides.
struct SweepPoint {
    drive_seed: u64,
    dc: Option<SweepDc>,
    poisson: Option<SweepPoisson>,
}

impl SweepPoint {
    fn dc_label(&self) -> String {
        match &self.dc {
            Some(d) => format!("{}:{}", d.pop, d.dc_pa),
            None => "-".into(),
        }
    }

    fn poisson_label(&self) -> String {
        match &self.poisson {
            Some(p) => format!("{}:{}:{}", p.pop, p.rate_hz, p.weight_pa),
            None => "-".into(),
        }
    }
}

/// One trajectory's merged results.
struct SweepRow {
    spikes: u64,
    rate_hz: f64,
    /// Integrate ns per neuron-step, averaged over models.
    ns_per: f64,
    /// This trajectory's private state bytes (summed over ranks).
    state_bytes: u64,
    /// State-only construction seconds (the amortization evidence:
    /// compare against the shared build).
    build_seconds: f64,
    wall_seconds: f64,
}

/// `cortex sweep` — build the network once ([`Ensemble`]), then run the
/// `[sweep]` grid of trajectories (drive seeds × DC × Poisson) over the
/// shared stores, `sweep.parallel` at a time.
pub fn cmd_sweep(args: &Args) -> Result<()> {
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Mutex;

    let cfg = args.experiment()?;
    ensure!(
        cfg.engine == EngineKind::Cortex,
        "cortex sweep drives the CORTEX engine \
         (engine.kind = \"cortex\")"
    );
    ensure!(
        cfg.transport == CommTransport::Local,
        "cortex sweep runs in-process \
         (engine.transport = \"local\")"
    );
    let spec = Arc::new(build_spec(&cfg));
    println!(
        "network '{}': {} neurons, {} synapses, {} areas",
        spec.name,
        spec.n_total(),
        spec.n_edges(),
        spec.n_areas()
    );

    let ens = Ensemble::builder(Arc::clone(&spec))
        .run_config(&run_config_of(&cfg))
        .build()?;
    let shared_bytes = ens.shared_memory().total_bytes();
    println!(
        "shared build: {:.3}s, {} topology across {} ranks x {} threads \
         (counted once for every trajectory)",
        ens.build_seconds(),
        human_bytes(shared_bytes),
        cfg.ranks,
        cfg.threads
    );

    // the grid: seeds × dc × poisson, empty axes contributing a single
    // "no override" point
    let seeds = if cfg.sweep.seeds.is_empty() {
        vec![cfg.seed]
    } else {
        cfg.sweep.seeds.clone()
    };
    let dc_axis: Vec<Option<SweepDc>> = if cfg.sweep.dc.is_empty() {
        vec![None]
    } else {
        cfg.sweep.dc.iter().cloned().map(Some).collect()
    };
    let poisson_axis: Vec<Option<SweepPoisson>> =
        if cfg.sweep.poisson.is_empty() {
            vec![None]
        } else {
            cfg.sweep.poisson.iter().cloned().map(Some).collect()
        };
    let mut points = Vec::new();
    for &drive_seed in &seeds {
        for dc in &dc_axis {
            for poisson in &poisson_axis {
                points.push(SweepPoint {
                    drive_seed,
                    dc: dc.clone(),
                    poisson: poisson.clone(),
                });
            }
        }
    }
    let steps =
        args.steps.or(cfg.sweep.steps).unwrap_or_else(|| cfg.steps()).max(1);
    let parallel = cfg.sweep.parallel.max(1).min(points.len());
    println!(
        "sweep: {} trajectories ({} seeds x {} dc x {} poisson), \
         {} steps each, {} concurrent",
        points.len(),
        seeds.len(),
        dc_axis.len(),
        poisson_axis.len(),
        steps,
        parallel
    );

    // bounded-parallel execution: `parallel` workers pull trajectory
    // indices off a shared counter (each trajectory is itself a full
    // multi-rank session over the shared stores)
    let next = AtomicUsize::new(0);
    let results: Vec<Mutex<Option<Result<SweepRow>>>> =
        points.iter().map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..parallel {
            let (next, results, points, ens, spec, cfg) =
                (&next, &results, &points, &ens, &spec, &cfg);
            scope.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= points.len() {
                    break;
                }
                let row =
                    run_trajectory(ens, spec, cfg, &points[i], steps);
                *results[i].lock().unwrap() = Some(row);
            });
        }
    });

    let mut table = crate::metrics::Table::new(
        "sweep",
        &[
            "traj", "seed", "dc", "poisson", "spikes", "rate_hz",
            "ns/step", "state", "build_s", "wall_s",
        ],
    );
    let mut rows = Vec::with_capacity(points.len());
    for (i, cell) in results.iter().enumerate() {
        let row = cell
            .lock()
            .unwrap()
            .take()
            .expect("sweep worker skipped a trajectory")
            .with_context(|| format!("trajectory {i} failed"))?;
        let pt = &points[i];
        table.row(&[
            i.to_string(),
            pt.drive_seed.to_string(),
            pt.dc_label(),
            pt.poisson_label(),
            row.spikes.to_string(),
            format!("{:.2}", row.rate_hz),
            format!("{:.1}", row.ns_per),
            human_bytes(row.state_bytes),
            format!("{:.3}", row.build_seconds),
            format!("{:.3}", row.wall_seconds),
        ]);
        rows.push(row);
    }
    println!("{}", table.render());
    let max_traj_build = rows
        .iter()
        .map(|r| r.build_seconds)
        .fold(0.0f64, f64::max);
    println!(
        "build amortization: shared {:.3}s once vs {:.3}s max per \
         trajectory ({} trajectories share {} of topology)",
        ens.build_seconds(),
        max_traj_build,
        rows.len(),
        human_bytes(shared_bytes)
    );

    if let Some(path) = &args.out {
        use crate::util::json::Json;
        use std::collections::BTreeMap;
        let trajectories: Vec<Json> = points
            .iter()
            .zip(&rows)
            .map(|(pt, r)| {
                let mut o = BTreeMap::new();
                o.insert(
                    "seed".into(),
                    Json::Num(pt.drive_seed as f64),
                );
                o.insert("dc".into(), Json::Str(pt.dc_label()));
                o.insert(
                    "poisson".into(),
                    Json::Str(pt.poisson_label()),
                );
                o.insert("spikes".into(), Json::Num(r.spikes as f64));
                o.insert("rate_hz".into(), Json::Num(r.rate_hz));
                o.insert(
                    "integrate_ns_per_neuron_step".into(),
                    Json::Num(r.ns_per),
                );
                o.insert(
                    "state_bytes".into(),
                    Json::Num(r.state_bytes as f64),
                );
                o.insert(
                    "build_seconds".into(),
                    Json::Num(r.build_seconds),
                );
                o.insert(
                    "wall_seconds".into(),
                    Json::Num(r.wall_seconds),
                );
                Json::Obj(o)
            })
            .collect();
        let mut top = BTreeMap::new();
        top.insert("network".into(), Json::Str(spec.name.clone()));
        top.insert(
            "n_neurons".into(),
            Json::Num(spec.n_total() as f64),
        );
        top.insert("steps".into(), Json::Num(steps as f64));
        top.insert(
            "shared_build_seconds".into(),
            Json::Num(ens.build_seconds()),
        );
        top.insert(
            "shared_store_bytes".into(),
            Json::Num(shared_bytes as f64),
        );
        top.insert("trajectories".into(), Json::Arr(trajectories));
        std::fs::write(path, Json::Obj(top).to_string_pretty())
            .with_context(|| format!("writing sweep results to {path}"))?;
        println!("results written to {path}");
    }
    Ok(())
}

/// Run one sweep trajectory over the shared network and merge its
/// results.
fn run_trajectory(
    ens: &Ensemble,
    spec: &NetworkSpec,
    cfg: &ExperimentConfig,
    pt: &SweepPoint,
    steps: u64,
) -> Result<SweepRow> {
    let mut tb = ens
        .trajectory()
        .drive_seed(pt.drive_seed)
        .probe(PopRates::new("rates", steps));
    if let Some(d) = &pt.dc {
        tb = tb.dc(&d.pop, d.dc_pa);
    }
    if let Some(p) = &pt.poisson {
        tb = tb.poisson(&p.pop, p.rate_hz, p.weight_pa);
    }
    let mut sim = tb.build()?;
    let build_seconds = sim.build_seconds();
    let (_shared, state_bytes) = sim.memory_split()?;
    sim.run_for(steps)?;
    let _rates = sim.drain("rates")?;
    let out = sim.finish()?;
    let (mut ns_weighted, mut n_neurons) = (0.0f64, 0u64);
    for (_m, n, ns) in integrate_rates(spec, &out.timer_sum, steps) {
        ns_weighted += ns * n as f64;
        n_neurons += n;
    }
    let rate_hz = out.total_spikes as f64
        / spec.n_total() as f64
        / (steps as f64 * cfg.dt_ms * 1e-3);
    Ok(SweepRow {
        spikes: out.total_spikes,
        rate_hz,
        ns_per: if n_neurons > 0 {
            ns_weighted / n_neurons as f64
        } else {
            0.0
        },
        state_bytes,
        build_seconds,
        wall_seconds: out.wall_seconds,
    })
}

/// `cortex launch` — spawn an N-process TCP cluster on localhost: rank
/// i runs `cortex run --rank i --peers 127.0.0.1:base,...` with the
/// parent's config/overrides forwarded verbatim. Exits non-zero if any
/// rank does.
pub fn cmd_launch(args: &Args) -> Result<()> {
    let cfg = args.experiment()?;
    let n = args.ranks.unwrap_or(cfg.ranks);
    ensure!(
        (1..=1024).contains(&n),
        "launch supports 1..=1024 ranks, got {n}"
    );
    // Hierarchical routing: pin the host-group map down before
    // spawning. `engine.comm_group` from the config wins; otherwise
    // chop the ranks into consecutive groups of `--group-size`
    // (default 2) and pass the assignment to every child explicitly,
    // so relay election is identical across the cluster.
    let groups = if cfg.routing == RoutingMode::Hierarchical && n > 1 {
        let g = if cfg.comm_group.is_empty() {
            CommGroups::even(n, args.group_size.unwrap_or(2))
        } else {
            ensure!(
                cfg.comm_group.len() == n,
                "engine.comm_group assigns {} ranks, launch runs {n}",
                cfg.comm_group.len()
            );
            match CommGroups::new(cfg.comm_group.clone()) {
                Ok(g) => g,
                Err(e) => bail!("engine.comm_group: {e}"),
            }
        };
        Some(g)
    } else {
        ensure!(
            args.group_size.is_none(),
            "--group-size needs engine.routing = \"hierarchical\""
        );
        None
    };
    // Each group's ranks take consecutive ports from their own block,
    // with a one-port stagger gap between blocks: a relay that dies
    // and is relaunched never races a neighbouring group's member
    // socket for the same port while the cluster drains.
    let ports: Vec<usize> = match &groups {
        Some(g) => {
            let mut ports = vec![0usize; n];
            let mut next = args.port_base as usize;
            for grp in 0..g.n_groups() {
                for &r in g.members(grp) {
                    ports[r] = next;
                    next += 1;
                }
                next += 1;
            }
            ports
        }
        None => (args.port_base as usize..).take(n).collect(),
    };
    let top = ports.iter().copied().max().unwrap_or(0);
    ensure!(
        top <= u16::MAX as usize,
        "--port-base {} leaves no room for {n} ports",
        args.port_base
    );
    let peers: Vec<String> =
        ports.iter().map(|p| format!("127.0.0.1:{p}")).collect();
    let peers_arg = peers.join(",");
    if let Some(g) = &groups {
        let map: Vec<String> = (0..g.n_groups())
            .map(|i| {
                format!(
                    "g{i}[{}] relay r{}",
                    g.members(i)
                        .iter()
                        .map(|r| r.to_string())
                        .collect::<Vec<_>>()
                        .join(","),
                    g.relay(i)
                )
            })
            .collect();
        println!(
            "hierarchical routing: {} host groups: {}",
            g.n_groups(),
            map.join("; ")
        );
    }
    let exe = std::env::current_exe()
        .context("cannot locate the cortex binary")?;
    println!("launching {n} rank processes: {peers_arg}");
    let mut children = Vec::with_capacity(n);
    for r in 0..n {
        let mut cmd = std::process::Command::new(&exe);
        cmd.arg("run")
            .arg("--rank")
            .arg(r.to_string())
            .arg("--peers")
            .arg(&peers_arg);
        if let Some(c) = &args.config_path {
            cmd.arg("--config").arg(c);
        }
        for s in &args.overrides {
            cmd.arg("--set").arg(s);
        }
        if let Some(g) = &groups {
            // every child gets the explicit assignment, even when it
            // came from CommGroups::even — relay election must not
            // depend on per-process defaults
            let ids: Vec<String> = g
                .assignment()
                .iter()
                .map(|id| id.to_string())
                .collect();
            cmd.arg("--set").arg(format!(
                "engine.comm_group=[{}]",
                ids.join(", ")
            ));
        }
        if args.artifacts_dir != "artifacts" {
            cmd.arg("--artifacts").arg(&args.artifacts_dir);
        }
        if let Some(p) = &args.raster_out {
            cmd.arg("--raster-out").arg(p);
        }
        match cmd.spawn() {
            Ok(child) => children.push((r, child)),
            Err(e) => {
                // don't leak the ranks already launched — they would sit
                // in their join loop for the full TCP timeout with no
                // parent to reap them
                for (_, mut child) in children {
                    let _ = child.kill();
                    let _ = child.wait();
                }
                return Err(anyhow::Error::from(e)
                    .context(format!("spawning rank {r}")));
            }
        }
    }
    // Poll every child instead of wait()ing in rank order: a rank
    // that dies (OOM, panic, bad config on one host) leaves its peers
    // blocked in the TCP exchange for the full socket timeout. The
    // first nonzero exit kills the survivors and fails the launch
    // immediately with the culprit's rank in the message.
    let mut failed: Option<usize> = None;
    while !children.is_empty() {
        let mut progressed = false;
        let mut i = 0;
        while i < children.len() {
            let (r, child) = &mut children[i];
            let status = match child.try_wait() {
                Ok(Some(status)) => status,
                Ok(None) => {
                    i += 1;
                    continue;
                }
                Err(e) => {
                    eprintln!("waiting for rank {r}: {e}");
                    failed = Some(*r);
                    // unpollable — treat as dead and reap below
                    let _ = child.kill();
                    let _ = child.wait();
                    children.swap_remove(i);
                    progressed = true;
                    continue;
                }
            };
            let r = *r;
            if !status.success() {
                eprintln!("rank {r} exited with {status}");
                failed = Some(r);
            }
            children.swap_remove(i);
            progressed = true;
        }
        if let Some(f) = failed {
            // one casualty dooms the cluster — don't let the rest
            // hang out their join/exchange timeouts. Under
            // hierarchical routing the casualty's own host group goes
            // first: if the dead rank was a relay, its members are
            // wedged in the gather round and can never make progress.
            if let Some(g) = &groups {
                let gid = g.group_of(f);
                let role = if g.relay(gid) == f {
                    "relay"
                } else {
                    "member"
                };
                eprintln!(
                    "rank {f} was the {role} of group {gid}; \
                     killing group {gid} first"
                );
                let mut i = 0;
                while i < children.len() {
                    if g.group_of(children[i].0) == gid {
                        let (r, mut child) = children.swap_remove(i);
                        eprintln!(
                            "killing rank {r} (group {gid} casualty)"
                        );
                        let _ = child.kill();
                        let _ = child.wait();
                    } else {
                        i += 1;
                    }
                }
            }
            for (r, mut child) in children.drain(..) {
                eprintln!("killing rank {r} (sibling failed)");
                let _ = child.kill();
                let _ = child.wait();
            }
            break;
        }
        if !progressed {
            std::thread::sleep(std::time::Duration::from_millis(30));
        }
    }
    if let Some(r) = failed {
        bail!("rank {r} failed; remaining ranks were terminated");
    }
    println!("all {n} ranks completed");
    Ok(())
}

/// `cortex verify` — the paper's §IV.A case: hpc_benchmark with STDP,
/// thread-ownership aborts armed, firing rate below 10 Hz.
pub fn cmd_verify(args: &Args) -> Result<()> {
    let mut cfg = args.experiment()?;
    cfg.network = NetworkKind::HpcBenchmark;
    cfg.plastic = true;
    let spec = Arc::new(build_spec(&cfg));
    let mut rc = run_config_of(&cfg);
    rc.verify_ownership = true; // the paper's Abort check
    rc.record_limit = Some(spec.n_total() as u32);
    println!(
        "verification network: {} neurons, {} synapses, STDP on E->E",
        spec.n_total(),
        spec.n_edges()
    );
    let out = run_simulation(&spec, &rc)?;
    let rate = out.total_spikes as f64
        / spec.n_total() as f64
        / (cfg.sim_ms * 1e-3);
    println!(
        "simulated {:.0} ms: {} spikes, mean rate {:.2} Hz",
        cfg.sim_ms, out.total_spikes, rate
    );
    println!("thread-ownership violations: 0 (no abort raised)");
    if rate > 0.05 && rate < 10.0 {
        println!("VERIFICATION PASSED (asynchronous regime, rate < 10 Hz)");
        Ok(())
    } else {
        bail!("VERIFICATION FAILED: rate {rate:.2} Hz outside (0.05, 10)");
    }
}

/// `cortex partition` — decomposition inspection (pre-vertex counts, the
/// Fig 9/10 quantities).
pub fn cmd_partition(args: &Args) -> Result<()> {
    let cfg = args.experiment()?;
    let spec = Arc::new(build_spec(&cfg));
    let part = match cfg.mapping {
        crate::config::MappingKind::AreaProcesses => {
            area_processes_partition(&spec, cfg.ranks, cfg.seed)
        }
        crate::config::MappingKind::RandomEquivalent => {
            random_equivalent_partition(spec.n_total(), cfg.ranks, cfg.seed)
        }
    };
    println!(
        "{:?} mapping of '{}' onto {} ranks (imbalance {:.3})",
        cfg.mapping,
        spec.name,
        cfg.ranks,
        part.imbalance()
    );
    for r in 0..cfg.ranks {
        if part.members[r].is_empty() {
            // an empty post range is legal (more ranks than an area
            // has neurons) but usually a sizing mistake — warn, don't
            // panic; the store builders handle it
            println!(
                "warning: rank {r} owns zero posts — consider fewer \
                 ranks or a different mapping"
            );
        }
    }
    println!(
        "{:>5} {:>8} {:>10} {:>10} {:>12} {:>12} {:>12} \
         {:>9} {:>9} {:>9}",
        "rank",
        "posts",
        "pres",
        "remote",
        "edges",
        "memory",
        "build_peak",
        "count_ms",
        "merge_ms",
        "fill_ms"
    );
    // Build every rank's store in parallel — the builds are
    // independent and inspection runs want the table fast for wide
    // clusters (each worker still honours engine.threads internally;
    // this tool favours wall-clock over a tidy CPU budget). Workers
    // return the formatted row plus the rank's subscription counts:
    // sub_counts[r][s] = gids rank r subscribes to from rank s (what
    // interest routing puts on the s→r wire). Printing stays in rank
    // order.
    let build_mode = cfg.build;
    let threads = cfg.threads;
    let rows: Vec<(String, Vec<u64>)> = std::thread::scope(|scope| {
        let spec = &spec;
        let part = &part;
        let handles: Vec<_> = (0..cfg.ranks)
            .map(|r| {
                scope.spawn(move || {
                    let rank_of = part.rank_of.clone();
                    let is_local =
                        move |g: u32| rank_of[g as usize] as usize == r;
                    // honour engine.build so the ablation's
                    // peak/timings are inspectable from here too
                    let store = match build_mode {
                        crate::config::BuildMode::TwoPass => {
                            RankStore::build(
                                spec,
                                &part.members[r],
                                is_local,
                                r as u16,
                                threads,
                            )
                        }
                        crate::config::BuildMode::Serial => {
                            RankStore::build_serial(
                                spec,
                                &part.members[r],
                                is_local,
                                r as u16,
                                threads,
                            )
                        }
                    };
                    let b = store.build;
                    let subs: Vec<u64> = store
                        .subscriptions(part)
                        .iter()
                        .map(|bucket| bucket.len() as u64)
                        .collect();
                    let row = format!(
                        "{:>5} {:>8} {:>10} {:>10} {:>12} {:>12} \
                         {:>12} {:>9.2} {:>9.2} {:>9.2}",
                        r,
                        store.n_posts(),
                        store.n_pres(),
                        store.n_remote_pres(),
                        store.n_edges(),
                        human_bytes(store.memory().total()),
                        human_bytes(b.peak_bytes),
                        b.count_ns as f64 * 1e-6,
                        b.merge_ns as f64 * 1e-6,
                        b.fill_ns as f64 * 1e-6,
                    );
                    (row, subs)
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("partition build worker panicked"))
            .collect()
    });
    for (row, _) in &rows {
        println!("{row}");
    }
    let sub_counts: Vec<Vec<u64>> =
        rows.into_iter().map(|(_, subs)| subs).collect();
    if cfg.ranks > 1 {
        // worst-case per-window wire volumes: every owned gid spiking
        // once per window — broadcast ships the full packet to every
        // peer, routing ships each peer its subscribed subset. The Tofu
        // projection prices one such exchange on Fugaku's interconnect.
        println!("--- interest routing (1 spike/gid/window bound) ---");
        println!(
            "{:>5} {:>10} {:>12} {:>12} {:>6} {:>12} {:>12}",
            "rank",
            "sub_in",
            "bcast",
            "routed",
            "share",
            "tofu_bcast",
            "tofu_routed"
        );
        let tofu = crate::comm::TofuModel::default();
        const WIRE: u64 = crate::comm::SPIKE_WIRE_BYTES;
        for r in 0..cfg.ranks {
            let sub_in: u64 = sub_counts[r].iter().sum();
            let sub_out: u64 =
                sub_counts.iter().map(|c| c[r]).sum();
            let posts = part.members[r].len() as u64;
            let bcast = (cfg.ranks as u64 - 1) * posts * WIRE;
            let routed = sub_out * WIRE;
            let share = if bcast > 0 {
                routed as f64 / bcast as f64
            } else {
                0.0
            };
            println!(
                "{:>5} {:>10} {:>12} {:>12} {:>6.3} {:>10.1}us {:>10.1}us",
                r,
                sub_in,
                human_bytes(bcast),
                human_bytes(routed),
                share,
                tofu.allgather_seconds(cfg.ranks, (posts * WIRE) as f64)
                    * 1e6,
                tofu.routed_exchange_seconds(
                    cfg.ranks,
                    routed as f64,
                    sub_in as f64 * WIRE as f64,
                ) * 1e6,
            );
        }
        if cfg.routing == RoutingMode::Hierarchical {
            // per-group aggregation: what the relay merge does to the
            // same worst-case window — frames collapse to the
            // two-level count, and the wire carries merged
            // multi-source frames between relays
            let groups = if cfg.comm_group.is_empty() {
                CommGroups::even(cfg.ranks, 2)
            } else {
                match CommGroups::new(cfg.comm_group.clone()) {
                    Ok(g) => g,
                    Err(e) => bail!("engine.comm_group: {e}"),
                }
            };
            let (flat, hier) = crate::comm::frames_per_window(
                cfg.ranks,
                groups.n_groups(),
            );
            println!(
                "--- hierarchical aggregation ({} host groups) ---",
                groups.n_groups()
            );
            println!(
                "frames/window: flat mesh {flat} -> hierarchical {hier}"
            );
            println!(
                "{:>5} {:>12} {:>5} {:>12} {:>12} {:>12}",
                "group",
                "ranks",
                "relay",
                "gather_max",
                "merged_max",
                "tofu_hier"
            );
            for gi in 0..groups.n_groups() {
                let members = groups.members(gi);
                // worst member→relay gather frame: one member's
                // inter-group routed bytes, bundled into a single
                // hand-off
                let gather_max = members
                    .iter()
                    .map(|&s| {
                        (0..cfg.ranks)
                            .filter(|&r| groups.group_of(r) != gi)
                            .map(|r| sub_counts[r][s])
                            .sum::<u64>()
                            * WIRE
                    })
                    .max()
                    .unwrap_or(0);
                // worst relay→relay merged frame: everything this
                // group ships to its busiest destination group
                let merged_max = (0..groups.n_groups())
                    .filter(|&b| b != gi)
                    .map(|b| {
                        groups
                            .members(b)
                            .iter()
                            .map(|&r| {
                                members
                                    .iter()
                                    .map(|&s| sub_counts[r][s])
                                    .sum::<u64>()
                            })
                            .sum::<u64>()
                            * WIRE
                    })
                    .max()
                    .unwrap_or(0);
                println!(
                    "{:>5} {:>12} {:>5} {:>12} {:>12} {:>10.1}us",
                    gi,
                    members
                        .iter()
                        .map(|r| r.to_string())
                        .collect::<Vec<_>>()
                        .join(","),
                    groups.relay(gi),
                    human_bytes(gather_max),
                    human_bytes(merged_max),
                    tofu.hierarchical_exchange_seconds(
                        groups.n_groups(),
                        members.len(),
                        gather_max as f64,
                        merged_max as f64,
                    ) * 1e6,
                );
            }
        }
    }
    Ok(())
}

/// `cortex info` — artifact + PJRT platform report.
pub fn cmd_info(args: &Args) -> Result<()> {
    let dir = std::path::Path::new(&args.artifacts_dir);
    let manifest = crate::runtime::Manifest::load(dir)?;
    println!("artifacts dir: {}", dir.display());
    println!("lif_step block sizes: {:?}", manifest.lif_sizes);
    let (p22, ..) = manifest.propagators()?;
    println!("baked p22 = {p22}");
    let name = format!("lif_step_n{}", manifest.lif_sizes[0]);
    let exe = crate::runtime::HloExecutable::load(dir, &name)?;
    println!("compiled {} on platform '{}'", exe.name, exe.platform());
    Ok(())
}

/// `cortex serve` — the resident multi-session daemon. `[serve]`
/// config keys set the quotas; `--addr` overrides the listen address.
pub fn cmd_serve(args: &Args) -> Result<()> {
    let cfg = args.experiment()?;
    let mut limits = cfg.serve.clone();
    if let Some(addr) = &args.addr {
        limits.addr = addr.clone();
    }
    crate::serve::serve(&limits)
}

/// `cortex client` — drive a running daemon over the control
/// protocol: one verb per invocation, line-oriented output that CI
/// shell jobs can parse.
pub fn cmd_client(args: &Args) -> Result<()> {
    use crate::serve::{Client, ProbeSpec};
    let verb = args.positional.first().map(String::as_str).context(
        "client needs a verb: create|run|drain|stim|suspend|resume|\
         checkpoint|close|stats|shutdown",
    )?;
    let addr = args.addr.as_deref().unwrap_or("127.0.0.1:9077");
    let mut client = Client::connect(addr)?;
    let session = || args.session.context("--session ID is required");
    match verb {
        "create" => {
            let doc = match &args.config_path {
                Some(p) => std::fs::read_to_string(p)
                    .with_context(|| format!("reading config {p}"))?,
                None => String::new(),
            };
            let probes = args
                .probes
                .iter()
                .map(|s| ProbeSpec::parse(s))
                .collect::<Result<Vec<_>>>()?;
            let id = client.create(&doc, &args.overrides, &probes)?;
            // parseable: scripts grab the id with `awk '{print $2}'`
            println!("session {id}");
        }
        "run" => {
            let sid = session()?;
            let steps = args.steps.context("--steps N is required")?;
            let push = args.push || args.raster_out.is_some();
            let (step, pushes) = client.run(sid, steps, push)?;
            for (probe, data) in pushes {
                report_probe(args, &probe, data)?;
            }
            println!("session {sid} at step {step}");
        }
        "drain" => {
            let sid = session()?;
            let probe = args
                .probes
                .first()
                .context("drain needs --probe NAME")?;
            // accept the bare drain name or a full create-time spec
            let name = probe.split(':').nth(1).unwrap_or(probe);
            let data = client.drain(sid, name)?;
            report_probe(args, name, data)?;
        }
        "stim" => {
            let sid = session()?;
            let pop =
                args.pop.as_deref().context("stim needs --pop NAME")?;
            match (&args.poisson, args.dc) {
                (Some(p), None) => {
                    let (rate, weight) = p
                        .split_once(':')
                        .context("--poisson needs RATE:WEIGHT")?;
                    client.set_poisson(
                        sid,
                        pop,
                        rate.parse().context("bad poisson rate")?,
                        weight.parse().context("bad poisson weight")?,
                    )?;
                }
                (None, Some(dc)) => client.set_dc(sid, pop, dc)?,
                _ => bail!("stim needs exactly one of --poisson, --dc"),
            }
            println!("stim applied to '{pop}'");
        }
        "suspend" => {
            let sid = session()?;
            client.suspend(sid)?;
            println!("session {sid} suspended");
        }
        "resume" => {
            let sid = session()?;
            client.resume(sid)?;
            println!("session {sid} resumed");
        }
        "checkpoint" => {
            let sid = session()?;
            let blob = client.checkpoint(sid)?;
            match &args.out {
                Some(path) => {
                    std::fs::write(path, &blob).with_context(|| {
                        format!("writing checkpoint to {path}")
                    })?;
                    println!(
                        "checkpoint written to {path} ({} bytes)",
                        blob.len()
                    );
                }
                None => println!("checkpoint: {} bytes", blob.len()),
            }
        }
        "close" => {
            let sid = session()?;
            client.close(sid)?;
            println!("session {sid} closed");
        }
        "stats" => {
            let s = client.stats()?;
            let mem_budget = if s.mem_budget == 0 {
                "unlimited".to_string()
            } else {
                human_bytes(s.mem_budget)
            };
            println!(
                "sessions {} (active {}, suspended {}) \
                 threads {}/{} memory {}/{}",
                s.sessions,
                s.active,
                s.suspended,
                s.threads_in_use,
                s.thread_budget,
                human_bytes(s.mem_in_use),
                mem_budget,
            );
        }
        "shutdown" => {
            client.shutdown()?;
            println!("daemon shut down");
        }
        other => bail!(
            "unknown client verb '{other}' (expected create|run|drain|\
             stim|suspend|resume|checkpoint|close|stats|shutdown)"
        ),
    }
    Ok(())
}

/// Print or persist one drained probe: rasters honour `--raster-out`,
/// everything else gets a one-line summary.
fn report_probe(
    args: &Args,
    probe: &str,
    data: ProbeData,
) -> Result<()> {
    match data {
        ProbeData::Raster(events) => match &args.raster_out {
            Some(path) => write_raster(path, &events)?,
            None => {
                println!("probe '{probe}': {} spikes", events.len())
            }
        },
        ProbeData::Rates { rows, .. } => {
            println!("probe '{probe}': {} rate rows", rows.len())
        }
        ProbeData::Phases(rows) => {
            for (rank, phase, ms) in &rows {
                println!(
                    "probe '{probe}': rank {rank} {phase} {ms:.3} ms"
                );
            }
        }
        other => println!("probe '{probe}': {other:?}"),
    }
    Ok(())
}

pub fn main_with(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv)?;
    match args.subcommand.as_str() {
        "run" => cmd_run(&args),
        "sweep" => cmd_sweep(&args),
        "launch" => cmd_launch(&args),
        "verify" => cmd_verify(&args),
        "partition" => cmd_partition(&args),
        "info" => cmd_info(&args),
        "serve" => cmd_serve(&args),
        "client" => cmd_client(&args),
        other => bail!(
            "unknown subcommand '{other}' \
             (expected run|sweep|launch|verify|partition|info|serve|\
             client)"
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parse_basic() {
        let a = Args::parse(&s(&[
            "run",
            "--config",
            "configs/x.toml",
            "--set",
            "engine.ranks=8",
        ]))
        .unwrap();
        assert_eq!(a.subcommand, "run");
        assert_eq!(a.config_path.as_deref(), Some("configs/x.toml"));
        assert_eq!(a.overrides, vec!["engine.ranks=8"]);
    }

    #[test]
    fn parse_errors() {
        assert!(Args::parse(&s(&[])).is_err());
        assert!(Args::parse(&s(&["run", "--config"])).is_err());
        assert!(Args::parse(&s(&["run", "--bogus"])).is_err());
        assert!(Args::parse(&s(&["run", "--rank", "x"])).is_err());
        assert!(Args::parse(&s(&["launch", "--ranks"])).is_err());
    }

    #[test]
    fn distributed_flags_parse_and_reach_the_config() {
        let a = Args::parse(&s(&[
            "run",
            "--rank",
            "1",
            "--peers",
            "127.0.0.1:7100, 127.0.0.1:7101",
            "--raster-out",
            "/tmp/r.txt",
        ]))
        .unwrap();
        assert_eq!(a.rank, Some(1));
        assert_eq!(a.raster_out.as_deref(), Some("/tmp/r.txt"));
        let cfg = a.experiment().unwrap();
        assert_eq!(cfg.transport, CommTransport::Tcp);
        assert_eq!(cfg.tcp_rank, Some(1));
        assert_eq!(cfg.ranks, 2);
        assert_eq!(
            cfg.peers,
            vec![
                "127.0.0.1:7100".to_string(),
                "127.0.0.1:7101".to_string()
            ]
        );

        let a = Args::parse(&s(&[
            "launch",
            "--ranks",
            "3",
            "--port-base",
            "31000",
        ]))
        .unwrap();
        assert_eq!(a.ranks, Some(3));
        assert_eq!(a.port_base, 31000);
        // launch itself stays on the local transport (children get
        // --peers)
        assert_eq!(
            a.experiment().unwrap().transport,
            CommTransport::Local
        );
    }

    #[test]
    fn sweep_flags_parse() {
        let a = Args::parse(&s(&[
            "sweep",
            "--steps",
            "100",
            "--out",
            "/tmp/sweep.json",
            "--set",
            "sweep.parallel=2",
        ]))
        .unwrap();
        assert_eq!(a.subcommand, "sweep");
        assert_eq!(a.steps, Some(100));
        assert_eq!(a.out.as_deref(), Some("/tmp/sweep.json"));
        assert_eq!(a.experiment().unwrap().sweep.parallel, 2);
    }

    #[test]
    fn experiment_from_overrides_only() {
        let a = Args::parse(&s(&[
            "run",
            "--set",
            "network.n_neurons=500",
            "--set",
            "network.indegree=50",
        ]))
        .unwrap();
        let cfg = a.experiment().unwrap();
        assert_eq!(cfg.n_neurons, 500);
        assert_eq!(cfg.indegree, 50);
    }

    #[test]
    fn exec_mode_flows_into_run_config() {
        let a = Args::parse(&s(&[
            "run",
            "--set",
            "engine.exec=\"scoped\"",
        ]))
        .unwrap();
        let cfg = a.experiment().unwrap();
        assert_eq!(cfg.exec, crate::config::ExecMode::Scoped);
        let rc = run_config_of(&cfg);
        assert_eq!(rc.exec, crate::config::ExecMode::Scoped);
    }

    #[test]
    fn build_mode_flows_into_run_config() {
        use crate::config::BuildMode;
        let a = Args::parse(&s(&[
            "run",
            "--set",
            "engine.build=\"serial\"",
        ]))
        .unwrap();
        let cfg = a.experiment().unwrap();
        assert_eq!(cfg.build, BuildMode::Serial);
        assert_eq!(run_config_of(&cfg).build, BuildMode::Serial);
        let a = Args::parse(&s(&["run"])).unwrap();
        assert_eq!(
            run_config_of(&a.experiment().unwrap()).build,
            BuildMode::TwoPass
        );
    }

    #[test]
    fn integrate_mode_flows_into_run_config() {
        use crate::config::IntegrateMode;
        let a = Args::parse(&s(&[
            "run",
            "--set",
            "engine.integrate=\"scalar\"",
        ]))
        .unwrap();
        let cfg = a.experiment().unwrap();
        assert_eq!(cfg.integrate, IntegrateMode::Scalar);
        assert_eq!(run_config_of(&cfg).integrate, IntegrateMode::Scalar);
        let a = Args::parse(&s(&["run"])).unwrap();
        assert_eq!(
            run_config_of(&a.experiment().unwrap()).integrate,
            IntegrateMode::Vector
        );
    }

    #[test]
    fn routing_mode_flows_into_run_config() {
        use crate::config::RoutingMode;
        let a = Args::parse(&s(&[
            "run",
            "--set",
            "engine.routing=\"broadcast\"",
        ]))
        .unwrap();
        let cfg = a.experiment().unwrap();
        assert_eq!(cfg.routing, RoutingMode::Broadcast);
        assert_eq!(run_config_of(&cfg).routing, RoutingMode::Broadcast);
        let a = Args::parse(&s(&["run"])).unwrap();
        assert_eq!(
            run_config_of(&a.experiment().unwrap()).routing,
            RoutingMode::Routed
        );
    }

    #[test]
    fn hierarchical_routing_flows_into_run_config() {
        let a = Args::parse(&s(&[
            "run",
            "--set",
            "engine.routing=\"hierarchical\"",
            "--set",
            "engine.ranks=4",
            "--set",
            "engine.comm_group=[0, 0, 1, 1]",
        ]))
        .unwrap();
        let cfg = a.experiment().unwrap();
        assert_eq!(cfg.routing, RoutingMode::Hierarchical);
        assert_eq!(cfg.comm_group, vec![0, 0, 1, 1]);
        let rc = run_config_of(&cfg);
        assert_eq!(rc.routing, RoutingMode::Hierarchical);
        assert_eq!(rc.comm_group, vec![0, 0, 1, 1]);
        // --group-size parses (cortex launch auto-grouping)
        let a = Args::parse(&s(&[
            "launch",
            "--ranks",
            "4",
            "--group-size",
            "2",
        ]))
        .unwrap();
        assert_eq!(a.group_size, Some(2));
        assert!(Args::parse(&s(&["launch", "--group-size"])).is_err());
    }

    #[test]
    fn build_spec_all_kinds() {
        for kind in ["marmoset", "potjans", "hpc_benchmark", "random"] {
            let a = Args::parse(&s(&[
                "run",
                "--set",
                &format!("network.kind=\"{kind}\""),
                "--set",
                "network.n_neurons=2000",
                "--set",
                "network.indegree=100",
            ]))
            .unwrap();
            let spec = build_spec(&a.experiment().unwrap());
            assert!(spec.n_total() > 0, "{kind}");
            assert!(spec.n_edges() > 0, "{kind}");
        }
    }

    #[test]
    fn model_knobs_reach_the_spec() {
        use crate::model::NeuronModel;
        // adex E over lif I on the hpc benchmark, AdEx b from [model.adex]
        let a = Args::parse(&s(&[
            "run",
            "--set",
            "network.kind=\"hpc_benchmark\"",
            "--set",
            "network.n_neurons=1000",
            "--set",
            "network.indegree=100",
            "--set",
            "network.model_e=\"adex\"",
            "--set",
            "model.adex.b=99.0",
        ]))
        .unwrap();
        let spec = build_spec(&a.experiment().unwrap());
        assert_eq!(spec.populations[0].model, NeuronModel::Adex);
        assert_eq!(spec.populations[1].model, NeuronModel::Lif);
        let crate::model::ModelParams::Adex(ap) =
            &spec.params[spec.populations[0].params as usize]
        else {
            panic!("E population should be AdEx")
        };
        assert_eq!(ap.b, 99.0);

        // hh everywhere on the random network
        let a = Args::parse(&s(&[
            "run",
            "--set",
            "network.kind=\"random\"",
            "--set",
            "network.n_neurons=500",
            "--set",
            "network.indegree=50",
            "--set",
            "network.model=\"hh\"",
        ]))
        .unwrap();
        let spec = build_spec(&a.experiment().unwrap());
        assert!(spec
            .populations
            .iter()
            .all(|p| p.model == NeuronModel::Hh));
    }

    #[test]
    fn custom_kind_builds_mixed_circuit() {
        use crate::model::NeuronModel;
        let a = Args::parse(&s(&[
            "run",
            "--set",
            "network.kind=\"custom\"",
            "--set",
            "network.indegree=40",
            "--set",
            "network.populations=[\"E:400:adex:e\", \"I:100:lif:i\", \
             \"S:50:parrot:e\"]",
        ]))
        .unwrap();
        let spec = build_spec(&a.experiment().unwrap());
        assert_eq!(spec.n_total(), 550);
        assert_eq!(spec.populations[0].model, NeuronModel::Adex);
        assert_eq!(spec.populations[2].model, NeuronModel::Parrot);
        assert!(spec.n_edges() > 0);
    }

    #[test]
    fn serve_client_flags_parse() {
        let a = Args::parse(&s(&[
            "client",
            "run",
            "--addr",
            "127.0.0.1:29860",
            "--session",
            "3",
            "--steps",
            "300",
            "--push",
            "--probe",
            "raster:spikes",
            "--probe",
            "rates:r:100",
        ]))
        .unwrap();
        assert_eq!(a.subcommand, "client");
        assert_eq!(a.positional, vec!["run"]);
        assert_eq!(a.addr.as_deref(), Some("127.0.0.1:29860"));
        assert_eq!(a.session, Some(3));
        assert_eq!(a.steps, Some(300));
        assert!(a.push);
        assert_eq!(a.probes, vec!["raster:spikes", "rates:r:100"]);

        let a = Args::parse(&s(&[
            "client", "stim", "--pop", "L4E", "--poisson", "8000:87.8",
        ]))
        .unwrap();
        assert_eq!(a.pop.as_deref(), Some("L4E"));
        assert_eq!(a.poisson.as_deref(), Some("8000:87.8"));

        // a flag value may start with '-' (consumed, not a flag)
        let a =
            Args::parse(&s(&["client", "stim", "--dc", "-120.5"]))
                .unwrap();
        assert_eq!(a.dc, Some(-120.5));

        // malformed values and unknown flags still error
        assert!(Args::parse(&s(&["client", "--session", "x"])).is_err());
        assert!(Args::parse(&s(&["serve", "--bogus"])).is_err());
    }

    #[test]
    fn serve_config_reaches_the_daemon_limits() {
        let a = Args::parse(&s(&[
            "serve",
            "--set",
            "serve.max_sessions=3",
            "--set",
            "serve.thread_budget=4",
        ]))
        .unwrap();
        let cfg = a.experiment().unwrap();
        assert_eq!(cfg.serve.max_sessions, 3);
        assert_eq!(cfg.serve.thread_budget, 4);
        assert_eq!(cfg.serve.addr, "127.0.0.1:9077");
    }
}
