//! Random Equivalent Mapping (paper Fig 9) — the naive baseline: neurons
//! are scattered uniformly over ranks with no regard for the atlas. Its
//! pathology, which the mapping ablation quantifies: nearly every rank
//! ends up needing pre-synaptic data for nearly every neuron in the
//! network, so per-rank memory grows with global N instead of N/R.

use super::Partition;
use crate::util::rng::hash_stream;
use crate::RankId;

/// Hash-uniform gid → rank assignment (deterministic in `seed`).
pub fn random_equivalent_partition(
    n: usize,
    n_ranks: usize,
    seed: u64,
) -> Partition {
    assert!(n_ranks >= 1 && n_ranks <= u16::MAX as usize);
    let rank_of: Vec<RankId> = (0..n)
        .map(|gid| {
            (hash_stream(&[seed, 0x524d4150, gid as u64]) % n_ranks as u64)
                as RankId
        })
        .collect();
    Partition::from_rank_of(n_ranks, rank_of)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest_lite::property;

    #[test]
    fn deterministic() {
        let a = random_equivalent_partition(1000, 7, 42);
        let b = random_equivalent_partition(1000, 7, 42);
        assert_eq!(a.rank_of, b.rank_of);
        assert_ne!(
            a.rank_of,
            random_equivalent_partition(1000, 7, 43).rank_of
        );
    }

    #[test]
    fn roughly_balanced() {
        let p = random_equivalent_partition(10_000, 8, 1);
        p.check_well_formed().unwrap();
        assert!(p.imbalance() < 1.15, "imbalance {}", p.imbalance());
    }

    #[test]
    fn property_well_formed() {
        property("random mapping well-formed", 30, |g| {
            let n = g.usize(1..2000);
            let r = g.usize(1..32);
            let p = random_equivalent_partition(n, r, g.case as u64);
            p.check_well_formed()
        });
    }
}
