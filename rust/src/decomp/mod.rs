//! Domain decomposition (paper §III.A): partition the vertex set, derive
//! each rank's indegree sub-graph, and lay its edges out for mutex-free
//! thread-level processing.
//!
//! Pipeline:
//! 1. [`area_map`] — Area-Processes Mapping: ranks are apportioned to
//!    atlas areas by estimated memory (paper §III.A.2, Fig 10);
//! 2. [`multisection`] — Multisection Division with Sampling (FDPS-style,
//!    paper §III.A.3, Fig 11): within an area, post-synaptic neurons are
//!    split into equal-count spatial cells;
//! 3. [`random_map`] — Random Equivalent Mapping, the naive baseline of
//!    Fig 9 (and what NEST-class round-robin distribution amounts to);
//! 4. [`store`] — the per-rank data instance (paper Fig 12): local and
//!    remote pre-synaptic views, and per-thread edge groups sorted by
//!    (pre, delay) so each thread writes only post-neurons it owns.

pub mod area_map;
pub mod multisection;
pub mod random_map;
pub mod store;

pub use area_map::area_processes_partition;
pub use random_map::random_equivalent_partition;
pub use store::{
    BuildPart, BuildRunner, BuildStats, BuildTask, RankStore,
    ThreadEdges, ThreadRunner,
};

use crate::{Gid, RankId};

/// A partition of the global vertex set onto ranks.
#[derive(Clone, Debug)]
pub struct Partition {
    pub n_ranks: usize,
    /// gid → rank.
    pub rank_of: Vec<RankId>,
    /// rank → sorted member gids.
    pub members: Vec<Vec<Gid>>,
}

impl Partition {
    pub fn from_rank_of(n_ranks: usize, rank_of: Vec<RankId>) -> Self {
        let mut members = vec![Vec::new(); n_ranks];
        for (gid, &r) in rank_of.iter().enumerate() {
            assert!((r as usize) < n_ranks, "rank {r} out of range");
            members[r as usize].push(gid as Gid);
        }
        // members are pushed in gid order, hence sorted
        Partition { n_ranks, rank_of, members }
    }

    pub fn n_vertices(&self) -> usize {
        self.rank_of.len()
    }

    /// Validate the well-partition property of paper eq. (9): member sets
    /// are disjoint and cover 0..n. (Holds by construction for
    /// `from_rank_of`; used by property tests on custom constructions.)
    pub fn check_well_formed(&self) -> Result<(), String> {
        let mut seen = vec![false; self.rank_of.len()];
        for (r, ms) in self.members.iter().enumerate() {
            for &g in ms {
                let gi = g as usize;
                if gi >= seen.len() {
                    return Err(format!("gid {g} out of range"));
                }
                if seen[gi] {
                    return Err(format!("gid {g} in two ranks"));
                }
                seen[gi] = true;
                if self.rank_of[gi] as usize != r {
                    return Err(format!("rank_of[{g}] inconsistent"));
                }
            }
        }
        if !seen.iter().all(|&s| s) {
            return Err("partition does not cover all vertices".into());
        }
        Ok(())
    }

    /// max/mean member count (1.0 = perfectly balanced).
    pub fn imbalance(&self) -> f64 {
        let max = self.members.iter().map(Vec::len).max().unwrap_or(0);
        let mean = self.n_vertices() as f64 / self.n_ranks.max(1) as f64;
        if mean == 0.0 {
            1.0
        } else {
            max as f64 / mean
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_rank_of_builds_sorted_members() {
        let p = Partition::from_rank_of(2, vec![0, 1, 0, 1, 0]);
        assert_eq!(p.members[0], vec![0, 2, 4]);
        assert_eq!(p.members[1], vec![1, 3]);
        p.check_well_formed().unwrap();
        assert!((p.imbalance() - 3.0 / 2.5).abs() < 1e-12);
    }

    #[test]
    fn well_formed_detects_violations() {
        let mut p = Partition::from_rank_of(2, vec![0, 0, 1]);
        p.members[1].push(0); // duplicate
        assert!(p.check_well_formed().is_err());
        let mut q = Partition::from_rank_of(2, vec![0, 0, 1]);
        q.members[1].clear(); // hole
        assert!(q.check_well_formed().is_err());
    }
}
