//! Multisection Division with Sampling Method (paper §III.A.3, Fig 11;
//! after FDPS / Ishiyama et al. 2012).
//!
//! Splits a point set into `k1 × k2 × k3` spatial cells holding roughly
//! equal counts, even for non-uniform distributions: a random sample is
//! sorted along the widest axis, cut at equal-count quantiles, and each
//! slab is recursed on with the remaining factors. The actual points are
//! then binned by the sampled cut planes.

use crate::util::rng::Rng;
use crate::Gid;

/// Factor `n` into up to three near-equal factors k1 >= k2 >= k3 with
/// k1·k2·k3 = n (grid dimensions of the multisection).
pub fn factor3(n: usize) -> [usize; 3] {
    assert!(n >= 1);
    let mut best = [n, 1, 1];
    let mut best_score = usize::MAX;
    let mut a = 1;
    while a * a * a <= n {
        if n % a == 0 {
            let m = n / a;
            let mut b = a;
            while b * b <= m {
                if m % b == 0 {
                    let c = m / b;
                    // minimise spread between the largest and smallest
                    let score = c - a;
                    if score < best_score {
                        best_score = score;
                        best = [c, b, a];
                    }
                }
                b += 1;
            }
        }
        a += 1;
    }
    best
}

/// Divide `ids` (with positions `pos[i]` for `ids[i]`) into `n_cells`
/// equal-count cells. Returns one sorted gid list per cell; every input
/// id appears in exactly one cell.
pub fn multisection(
    ids: &[Gid],
    pos: &[[f64; 3]],
    n_cells: usize,
    rng: &mut Rng,
) -> Vec<Vec<Gid>> {
    assert_eq!(ids.len(), pos.len());
    assert!(n_cells >= 1);
    if n_cells == 1 {
        let mut v = ids.to_vec();
        v.sort_unstable();
        return vec![v];
    }
    let dims = factor3(n_cells);
    let mut items: Vec<(Gid, [f64; 3])> =
        ids.iter().copied().zip(pos.iter().copied()).collect();
    let mut cells = Vec::with_capacity(n_cells);
    recurse(&mut items, &dims, rng, &mut cells);
    for c in &mut cells {
        c.sort_unstable();
    }
    cells
}

fn recurse(
    items: &mut [(Gid, [f64; 3])],
    dims: &[usize],
    rng: &mut Rng,
    out: &mut Vec<Vec<Gid>>,
) {
    // find the first remaining factor > 1; if none, emit the cell
    let Some((level, &k)) = dims.iter().enumerate().find(|(_, &k)| k > 1)
    else {
        out.push(items.iter().map(|(g, _)| *g).collect());
        return;
    };

    // widest axis of this slab
    let axis = widest_axis(items);

    // sampling: sort a bounded random sample, read cut planes at quantiles
    let sample_size = (items.len() / 10).clamp(k * 4, 4096).min(items.len());
    let mut sample: Vec<f64> = (0..sample_size)
        .map(|_| items[rng.below(items.len() as u64) as usize].1[axis])
        .collect();
    sample.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let cuts: Vec<f64> = (1..k)
        .map(|i| sample[i * sample.len() / k])
        .collect();

    // order items by axis, then split at the cut planes with equal-count
    // correction: the sampled cut gives the split *hint*, the actual split
    // index is clamped so every sub-slab keeps a proportional share (this
    // guarantees balance even when the sample was unlucky).
    items.sort_by(|a, b| a.1[axis].partial_cmp(&b.1[axis]).unwrap());
    let n = items.len();
    let mut bounds = Vec::with_capacity(k + 1);
    bounds.push(0usize);
    for (i, &cut) in cuts.iter().enumerate() {
        let hint = items.partition_point(|it| it.1[axis] < cut);
        let ideal = (i + 1) * n / k;
        // allow the sampled plane to deviate by at most 20% of a cell
        let tol = (n / k) / 5;
        let lo = ideal.saturating_sub(tol).max(bounds[i]);
        let hi = (ideal + tol).min(n);
        bounds.push(hint.clamp(lo, hi));
    }
    bounds.push(n);

    let rest = &dims[level + 1..];
    let mut remaining = items;
    let mut prev = 0usize;
    for w in bounds.windows(2).skip(1) {
        let take = w[0] - prev;
        let (slab, tail) = remaining.split_at_mut(take);
        prev = w[0];
        remaining = tail;
        recurse(slab, rest, rng, out);
    }
    recurse(remaining, rest, rng, out);
}

fn widest_axis(items: &[(Gid, [f64; 3])]) -> usize {
    let mut lo = [f64::INFINITY; 3];
    let mut hi = [f64::NEG_INFINITY; 3];
    for (_, p) in items {
        for a in 0..3 {
            lo[a] = lo[a].min(p[a]);
            hi[a] = hi[a].max(p[a]);
        }
    }
    let mut axis = 0;
    let mut best = f64::NEG_INFINITY;
    for a in 0..3 {
        let w = hi[a] - lo[a];
        if w > best {
            best = w;
            axis = a;
        }
    }
    axis
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest_lite::property;

    #[test]
    fn factor3_balanced() {
        assert_eq!(factor3(1), [1, 1, 1]);
        assert_eq!(factor3(8), [2, 2, 2]);
        assert_eq!(factor3(12), [3, 2, 2]);
        assert_eq!(factor3(7), [7, 1, 1]);
        let f = factor3(24);
        assert_eq!(f.iter().product::<usize>(), 24);
        assert!(f[0] <= 4);
    }

    fn cube_points(n: usize, seed: u64) -> (Vec<Gid>, Vec<[f64; 3]>) {
        let mut rng = Rng::new(seed);
        let ids: Vec<Gid> = (0..n as Gid).collect();
        let pos: Vec<[f64; 3]> = (0..n)
            .map(|_| {
                [
                    rng.range_f64(0.0, 1.0),
                    rng.range_f64(0.0, 1.0),
                    rng.range_f64(0.0, 1.0),
                ]
            })
            .collect();
        (ids, pos)
    }

    #[test]
    fn covers_and_balances_uniform() {
        let (ids, pos) = cube_points(5000, 1);
        let mut rng = Rng::new(2);
        let cells = multisection(&ids, &pos, 8, &mut rng);
        assert_eq!(cells.len(), 8);
        let mut all: Vec<Gid> = cells.iter().flatten().copied().collect();
        all.sort_unstable();
        assert_eq!(all, ids, "cells must partition the input");
        let target = 5000.0 / 8.0;
        for c in &cells {
            assert!(
                (c.len() as f64 - target).abs() < 0.25 * target,
                "cell size {} vs target {target}",
                c.len()
            );
        }
    }

    #[test]
    fn balances_gaussian_cluster() {
        // non-uniform distribution: dense ball + sparse halo (the case the
        // sampling method exists for)
        let mut rng = Rng::new(3);
        let n = 4000;
        let ids: Vec<Gid> = (0..n as Gid).collect();
        let pos: Vec<[f64; 3]> = (0..n)
            .map(|i| {
                let r = if i % 4 == 0 { 10.0 } else { 0.5 };
                [
                    rng.normal() * r,
                    rng.normal() * r,
                    rng.normal() * r,
                ]
            })
            .collect();
        let cells = multisection(&ids, &pos, 6, &mut rng);
        let sizes: Vec<usize> = cells.iter().map(Vec::len).collect();
        let max = *sizes.iter().max().unwrap() as f64;
        let mean = n as f64 / 6.0;
        assert!(max / mean < 1.3, "imbalance {} ({sizes:?})", max / mean);
    }

    #[test]
    fn single_cell_identity() {
        let (ids, pos) = cube_points(17, 4);
        let mut rng = Rng::new(5);
        let cells = multisection(&ids, &pos, 1, &mut rng);
        assert_eq!(cells, vec![ids]);
    }

    #[test]
    fn property_partition_and_balance() {
        property("multisection partition", 20, |g| {
            let n = g.usize(32..3000);
            let k = g.usize(1..13);
            let mut rng = Rng::new(g.case as u64 + 100);
            let ids: Vec<Gid> = (0..n as Gid).collect();
            let pos: Vec<[f64; 3]> = (0..n)
                .map(|_| {
                    [
                        rng.range_f64(-3.0, 3.0),
                        rng.range_f64(-1.0, 1.0),
                        rng.range_f64(0.0, 9.0),
                    ]
                })
                .collect();
            let cells = multisection(&ids, &pos, k, &mut rng);
            if cells.len() != k {
                return Err(format!("{} cells != {k}", cells.len()));
            }
            let mut all: Vec<Gid> = cells.iter().flatten().copied().collect();
            all.sort_unstable();
            if all != ids {
                return Err("not a partition".into());
            }
            let mean = n as f64 / k as f64;
            if mean >= 16.0 {
                let max = cells.iter().map(Vec::len).max().unwrap() as f64;
                if max / mean > 1.5 {
                    return Err(format!("imbalance {}", max / mean));
                }
            }
            Ok(())
        });
    }
}
