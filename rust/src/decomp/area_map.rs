//! Area-Processes Mapping (paper §III.A.2, Fig 10): apportion ranks to
//! atlas areas in proportion to estimated memory, then subdivide each
//! area's post-synaptic neurons spatially with the multisection method.

use super::multisection::multisection;
use super::Partition;
use crate::atlas::NetworkSpec;
use crate::util::rng::Rng;
use crate::{Gid, RankId};

/// Estimated memory weight of each area: O(n_pre + n_post + n_edges) with
/// edges dominating (paper §III.A.4). Edge counts are exact (fixed
/// indegree × population sizes); the pre/post terms use the same units
/// (one neuron ≈ the engine's per-neuron state, one edge ≈ one edge
/// record — the constant factors cancel in the apportionment).
pub fn estimate_area_memory(spec: &NetworkSpec) -> Vec<f64> {
    let mut est = vec![0.0f64; spec.n_areas()];
    const NEURON_COST: f64 = 64.0; // bytes of state per neuron
    const EDGE_COST: f64 = 16.0;   // bytes per edge record
    for p in &spec.populations {
        est[p.area as usize] += p.n as f64 * NEURON_COST;
    }
    for r in &spec.rules {
        let dst = &spec.populations[r.dst_pop as usize];
        est[dst.area as usize] +=
            r.indegree as f64 * dst.n as f64 * EDGE_COST;
    }
    est
}

/// Largest-remainder apportionment of `n_ranks` to areas by weight; every
/// area with nonzero weight gets at least one rank when `n_ranks >=`
/// number of areas, otherwise areas are greedily packed onto ranks.
pub fn apportion(weights: &[f64], n_ranks: usize) -> Vec<usize> {
    assert!(!weights.is_empty());
    assert!(n_ranks >= 1);
    let total: f64 = weights.iter().sum();
    if total <= 0.0 {
        let mut out = vec![0; weights.len()];
        out[0] = n_ranks;
        return out;
    }
    if n_ranks >= weights.len() {
        // one rank guaranteed per area, remainder by largest fraction
        let spare = n_ranks - weights.len();
        let quota: Vec<f64> =
            weights.iter().map(|w| w / total * spare as f64).collect();
        let mut counts: Vec<usize> =
            quota.iter().map(|q| 1 + q.floor() as usize).collect();
        let assigned: usize = counts.iter().sum();
        let mut rem: Vec<(f64, usize)> = quota
            .iter()
            .enumerate()
            .map(|(i, q)| (q - q.floor(), i))
            .collect();
        rem.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
        for i in 0..(n_ranks - assigned) {
            counts[rem[i % rem.len()].1] += 1;
        }
        counts
    } else {
        // fewer ranks than areas: areas share ranks — mark each area with
        // count 0 and let the caller group them (returned counts sum to
        // n_ranks with zeros for co-located areas).
        let mut counts = vec![0usize; weights.len()];
        // greedy: assign each rank slot to the currently heaviest
        // uncovered group; here we simply give the n_ranks largest areas
        // one rank each — smaller areas are folded into the nearest
        // assigned area by the partition function below.
        let mut idx: Vec<usize> = (0..weights.len()).collect();
        idx.sort_by(|&a, &b| weights[b].partial_cmp(&weights[a]).unwrap());
        for &i in idx.iter().take(n_ranks) {
            counts[i] = 1;
        }
        counts
    }
}

/// Full Area-Processes Mapping + Multisection Division partition.
pub fn area_processes_partition(
    spec: &NetworkSpec,
    n_ranks: usize,
    seed: u64,
) -> Partition {
    let weights = estimate_area_memory(spec);
    let counts = apportion(&weights, n_ranks);
    let n = spec.n_total();

    // area → gids
    let mut area_gids: Vec<Vec<Gid>> = vec![Vec::new(); spec.n_areas()];
    for p in &spec.populations {
        area_gids[p.area as usize].extend(p.gids());
    }

    // areas with zero ranks (n_ranks < n_areas) fold into the nearest
    // area that did get ranks
    let holders: Vec<usize> =
        (0..counts.len()).filter(|&a| counts[a] > 0).collect();
    assert!(!holders.is_empty());
    let mut folded: Vec<Vec<Gid>> = vec![Vec::new(); counts.len()];
    for a in 0..counts.len() {
        if counts[a] > 0 {
            folded[a].append(&mut area_gids[a]);
        } else if !area_gids[a].is_empty() {
            let nearest = *holders
                .iter()
                .min_by(|&&x, &&y| {
                    spec.area_distance(a as u16, x as u16)
                        .partial_cmp(&spec.area_distance(a as u16, y as u16))
                        .unwrap()
                })
                .unwrap();
            let mut gids = std::mem::take(&mut area_gids[a]);
            folded[nearest].append(&mut gids);
        }
    }

    // within each rank-holding area: multisection into `counts[a]` cells
    let mut rank_of: Vec<RankId> = vec![0; n];
    let mut next_rank: RankId = 0;
    let mut rng = Rng::stream(seed, &[0x4d554c54]); // "MULT"
    for a in 0..counts.len() {
        if counts[a] == 0 {
            continue;
        }
        let gids = &folded[a];
        let pos: Vec<[f64; 3]> =
            gids.iter().map(|&g| spec.position(g)).collect();
        let cells = multisection(gids, &pos, counts[a], &mut rng);
        for cell in cells {
            for g in cell {
                rank_of[g as usize] = next_rank;
            }
            next_rank += 1;
        }
    }
    assert_eq!(next_rank as usize, n_ranks);
    Partition::from_rank_of(n_ranks, rank_of)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::atlas::marmoset::{marmoset_spec, MarmosetParams};

    #[test]
    fn apportion_exact_sum_and_minimum() {
        let counts = apportion(&[10.0, 30.0, 60.0], 10);
        assert_eq!(counts.iter().sum::<usize>(), 10);
        assert!(counts.iter().all(|&c| c >= 1));
        assert!(counts[2] > counts[0]);
    }

    #[test]
    fn apportion_fewer_ranks_than_areas() {
        let counts = apportion(&[5.0, 1.0, 3.0, 2.0], 2);
        assert_eq!(counts.iter().sum::<usize>(), 2);
        assert_eq!(counts[0], 1); // heaviest get the ranks
        assert_eq!(counts[2], 1);
    }

    #[test]
    fn estimate_scales_with_area_size() {
        let spec = marmoset_spec(&MarmosetParams::default(), 11);
        let est = estimate_area_memory(&spec);
        assert_eq!(est.len(), 8);
        assert!(est.iter().all(|&e| e > 0.0));
        // edges dominate: estimate per area >> neuron term alone
        let n0: u32 = spec
            .populations
            .iter()
            .filter(|p| p.area == 0)
            .map(|p| p.n)
            .sum();
        assert!(est[0] > n0 as f64 * 64.0 * 5.0);
    }

    #[test]
    fn partition_well_formed_and_balanced() {
        let spec = marmoset_spec(
            &MarmosetParams { n_neurons: 4000, ..Default::default() },
            3,
        );
        for ranks in [1, 4, 8, 12] {
            let part = area_processes_partition(&spec, ranks, 5);
            part.check_well_formed().unwrap();
            assert_eq!(part.n_ranks, ranks);
            if ranks >= 8 {
                assert!(
                    part.imbalance() < 1.8,
                    "ranks={ranks} imbalance {}",
                    part.imbalance()
                );
            }
        }
    }

    #[test]
    fn fewer_ranks_than_areas_folds_areas() {
        let spec = marmoset_spec(
            &MarmosetParams { n_neurons: 2000, ..Default::default() },
            7,
        );
        let part = area_processes_partition(&spec, 3, 1);
        part.check_well_formed().unwrap();
        assert_eq!(part.n_ranks, 3);
    }

    #[test]
    fn area_locality_preserved() {
        // with ranks == areas every rank holds exactly one area's neurons
        let spec = marmoset_spec(
            &MarmosetParams { n_neurons: 3000, ..Default::default() },
            9,
        );
        let part = area_processes_partition(&spec, 8, 2);
        part.check_well_formed().unwrap();
        for r in 0..8 {
            let areas: std::collections::BTreeSet<u16> = part.members[r]
                .iter()
                .map(|&g| spec.area_of(g))
                .collect();
            assert_eq!(areas.len(), 1, "rank {r} spans areas {areas:?}");
        }
    }
}
