//! Per-rank data instance of the indegree sub-graph (paper Fig 12).
//!
//! For a rank owning post-neurons `V_i`:
//!
//! * `posts` — the owned (post-synaptic) gids, in local index order;
//! * `pres` — every source gid with at least one edge onto this rank,
//!   i.e. exactly the sub-graph's pre-vertex set `in-V_i^pre`; split
//!   into the local part (`pre ∈ V_i`) and the remote part, whose sizes
//!   are the quantities of the paper's Fig 8-10 memory argument;
//! * per-thread [`ThreadEdges`] — each compute thread owns a contiguous
//!   range of local posts and a private edge store holding **only** the
//!   edges targeting those posts, as a CSR over pre index with runs
//!   sorted by delay (paper Fig 12b: "synaptic interactions reordered
//!   according to their delays and the corresponding threads"). During
//!   delivery a thread walks just its own run for each spiking pre:
//!   every write lands in thread-owned state — no mutex, no atomic.
//!
//! # Construction: the two-pass streaming builder
//!
//! The paper's maximum-problem-size claim requires a rank to build its
//! sub-graph in memory proportional to its own share. Because thread
//! ownership is a pure function of the post gid (contiguous post-range
//! split) and [`NetworkSpec::for_each_in_edge`] generates edges *per
//! post*, each thread can generate exactly its own edges, twice,
//! independently and deterministically:
//!
//! 1. **count** (parallel) — every thread streams its posts' edges,
//!    recording only each source gid; the scratch is sorted and
//!    run-length-encoded into a sorted-unique `(source, count)` table.
//! 2. **merge** (serial, O(pres·threads)) — the per-thread source
//!    tables are k-way-merged into the rank's `pres` array (replacing
//!    the old sort+dedup over all edges), and each thread's exact CSR
//!    `offsets` plus a thread-local → rank pre-index remap fall out of
//!    the same walk.
//! 3. **fill** (parallel) — every thread re-streams its edges straight
//!    into its exact-capacity CSR arrays via a cursor per pre, then
//!    delay-sorts each run in place (stably, so multapse ties keep
//!    generation order and results are bit-identical to the serial
//!    ablation builder at any thread count).
//!
//! Peak construction memory is the final CSR plus ~4 bytes/edge of
//! transient scratch (≤ ~1.5× the final store), where the serial
//! staging builder holds three edge copies (~3×). Both builders report
//! analytic [`BuildStats`] (per-phase nanoseconds + peak bytes); the
//! engine runs the parallel passes on its persistent worker pool via
//! the [`BuildRunner`] seam.

use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::sync::Arc;
use std::time::Instant;

use super::Partition;
use crate::atlas::NetworkSpec;
use crate::graph::Edge;
use crate::metrics::memory::{vec_bytes, MemoryBreakdown};
use crate::util::bitset::BitSet;
use crate::{DelaySteps, Gid, ThreadId};

/// One compute thread's private share of the rank's indegree sub-graph.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ThreadEdges {
    /// CSR offsets over the rank's `pres` array: edges of pre `p` owned by
    /// this thread live at `post[offsets[p]..offsets[p+1]]`, delay-sorted.
    pub offsets: Vec<u32>,
    /// Local post index (into the rank's `posts`).
    pub post: Vec<u32>,
    pub weight: Vec<f64>,
    pub delay: Vec<DelaySteps>,
    /// Plastic-edge markers, one bit per edge (empty — zero bytes — for
    /// non-STDP networks; an empty [`BitSet`] reads as all-false).
    pub plastic: BitSet,
    /// Pre index of each edge (present only for STDP networks, where the
    /// potentiation path walks a post's incoming edges and needs their
    /// sources' traces).
    pub epre: Vec<u32>,
    /// CSR by local post over this thread's *plastic* edges (potentiation
    /// walks a post's incoming plastic edges when it fires): offsets are
    /// relative to the thread's post range `[post_lo, post_hi)`.
    pub plastic_by_post_offsets: Vec<u32>,
    pub plastic_by_post_edge: Vec<u32>,
    /// Owned local post range.
    pub post_lo: u32,
    pub post_hi: u32,
}

impl ThreadEdges {
    pub fn n_edges(&self) -> usize {
        self.post.len()
    }

    pub fn bytes(&self) -> u64 {
        vec_bytes(&self.offsets)
            + vec_bytes(&self.post)
            + vec_bytes(&self.weight)
            + vec_bytes(&self.delay)
            + self.plastic.bytes()
            + vec_bytes(&self.epre)
            + vec_bytes(&self.plastic_by_post_offsets)
            + vec_bytes(&self.plastic_by_post_edge)
    }

    /// Edge run of pre index `p` (delay-sorted).
    #[inline]
    pub fn run(&self, p: usize) -> std::ops::Range<usize> {
        self.offsets[p] as usize..self.offsets[p + 1] as usize
    }
}

/// Owning thread of `local_post` under the contiguous equal split of
/// `n_posts` posts over `n_threads` threads (`lo_t = ⌊t·n/T⌋`).
///
/// O(1) closed-form inverse of the range table: the arithmetic guess
/// `⌊p·T/n⌋` is exact or one below the owner whenever `n >= T`, and the
/// correction loops walk the (then possibly empty) ranges otherwise.
/// Replaces the linear `position()` scan that sat on the per-spike
/// collection path and on every staged edge during store construction.
///
/// A rank may own **zero** posts (more ranks than an area has neurons);
/// every range is then empty and thread 0 is the conventional owner —
/// the early return keeps the arithmetic from dividing by zero.
#[inline]
pub fn owner_of(local_post: u32, n_posts: usize, n_threads: usize) -> ThreadId {
    debug_assert!(n_threads >= 1);
    if n_posts == 0 {
        return 0;
    }
    debug_assert!((local_post as usize) < n_posts);
    let p = local_post as usize;
    let mut t = (p as u64 * n_threads as u64 / n_posts as u64) as usize;
    // correct the floor-division guess onto the owning half-open range
    while (t + 1) * n_posts / n_threads <= p {
        t += 1;
    }
    while t * n_posts / n_threads > p {
        t -= 1;
    }
    t as ThreadId
}

/// Contiguous equal split of `n_posts` local posts over `n_threads`.
fn split_ranges(n_posts: usize, n_threads: usize) -> Vec<(u32, u32)> {
    (0..n_threads)
        .map(|t| {
            (
                (t * n_posts / n_threads) as u32,
                ((t + 1) * n_posts / n_threads) as u32,
            )
        })
        .collect()
}

// ---------------------------------------------------------------------
// Two-pass build pipeline
// ---------------------------------------------------------------------

/// Per-phase wall time and analytic peak heap of one store construction.
/// Surfaced through the engine's `PhaseTimer` (`build_count` /
/// `build_merge` / `build_fill`), `cortex partition`, and the
/// `build_scaling` bench.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct BuildStats {
    /// Pass 1: streaming edge generation + source counting.
    pub count_ns: u64,
    /// K-way source-table merge + CSR offset/remap derivation.
    pub merge_ns: u64,
    /// Pass 2: streaming fill into the exact-capacity CSR + delay sort.
    pub fill_ns: u64,
    /// Analytic peak heap bytes held at any point during construction
    /// (the build-time counterpart of the Fig 9-10 memory argument).
    pub peak_bytes: u64,
}

/// Pass-1 result of one thread: its posts' sources, sorted unique, with
/// per-source edge counts.
pub struct CountPart {
    upres: Vec<Gid>,
    ucounts: Vec<u32>,
    n_edges: u64,
    max_delay: DelaySteps,
    peak_bytes: u64,
}

/// What one build task returns (count or fill, by pass).
pub enum BuildPart {
    Count(CountPart),
    Fill { edges: ThreadEdges, peak_bytes: u64 },
}

/// A unit of build work for one thread. Tasks own their inputs
/// (`Arc`-shared spec and posts), so any executor with `'static`
/// workers — notably the engine's persistent pool — can run them.
pub type BuildTask = Box<dyn FnOnce() -> BuildPart + Send + 'static>;

/// Executes one build pass: runs the indexed tasks (one per thread) to
/// completion and returns their results **in task order**. Implemented
/// by the engine's `WorkerPool` (so construction parallelises across
/// the same threads that later step) and by [`ThreadRunner`].
pub trait BuildRunner {
    fn run(&self, tasks: Vec<BuildTask>) -> Vec<BuildPart>;
}

/// Default runner outside a live engine (CLI inspection, tests,
/// benches): one OS thread per task, joined in order.
pub struct ThreadRunner;

impl BuildRunner for ThreadRunner {
    fn run(&self, tasks: Vec<BuildTask>) -> Vec<BuildPart> {
        if tasks.len() == 1 {
            return tasks.into_iter().map(|t| t()).collect();
        }
        let handles: Vec<_> =
            tasks.into_iter().map(std::thread::spawn).collect();
        handles
            .into_iter()
            .map(|h| match h.join() {
                Ok(part) => part,
                // re-raise with the original payload (an invariant
                // message like the fill pass's) instead of flattening
                // it into "Any { .. }"
                Err(panic) => std::panic::resume_unwind(panic),
            })
            .collect()
    }
}

/// Pass 1 for one thread: stream the owned posts' edges, keeping only
/// each source gid, then sort + run-length-encode. The 4-byte-per-edge
/// scratch is the pass's entire footprint.
fn count_pass(spec: &NetworkSpec, posts: &[Gid]) -> CountPart {
    let mut srcs: Vec<Gid> = Vec::new();
    let mut max_delay: DelaySteps = 1;
    for &gid in posts {
        spec.for_each_in_edge(gid, |e, _| {
            srcs.push(e.pre);
            if e.delay > max_delay {
                max_delay = e.delay;
            }
        });
    }
    let n_edges = srcs.len() as u64;
    let scratch_bytes = vec_bytes(&srcs); // capacity incl. growth slack
    srcs.sort_unstable();
    // count the uniques first so the RLE tables allocate exactly once
    // — no doubling growth, no shrink copy, and the analytic peak
    // below is the true high-water mark of this pass
    let n_unique = srcs.windows(2).filter(|w| w[0] != w[1]).count()
        + usize::from(!srcs.is_empty());
    let mut upres: Vec<Gid> = Vec::with_capacity(n_unique);
    let mut ucounts: Vec<u32> = Vec::with_capacity(n_unique);
    for &g in &srcs {
        if upres.last() == Some(&g) {
            *ucounts.last_mut().unwrap() += 1;
        } else {
            upres.push(g);
            ucounts.push(1);
        }
    }
    debug_assert_eq!(upres.len(), n_unique);
    let peak_bytes =
        scratch_bytes + vec_bytes(&upres) + vec_bytes(&ucounts);
    CountPart { upres, ucounts, n_edges, max_delay, peak_bytes }
}

/// Pass 2 for one thread: re-stream the owned posts' edges directly
/// into the exact-capacity CSR (cursor per pre), then stably delay-sort
/// each run in place. `offsets` is the prefix-summed CSR from the
/// merge; `upres`/`remap` translate a source gid to its rank-wide pre
/// index without touching the shared `pres` table.
#[allow(clippy::too_many_arguments)]
fn fill_pass(
    spec: &NetworkSpec,
    posts: &[Gid],
    lo: u32,
    hi: u32,
    offsets: Vec<u32>,
    remap: Vec<u32>,
    upres: Vec<Gid>,
    plastic_net: bool,
) -> BuildPart {
    let n_e = *offsets.last().expect("offsets never empty") as usize;
    let n_pres = offsets.len() - 1;
    let mut cursor = offsets.clone();
    let mut post = vec![0u32; n_e];
    let mut weight = vec![0.0f64; n_e];
    let mut delay: Vec<DelaySteps> = vec![0; n_e];
    let mut plastic =
        if plastic_net { BitSet::zeros(n_e) } else { BitSet::new() };
    let mut epre: Vec<u32> =
        if plastic_net { vec![0; n_e] } else { Vec::new() };

    for lp in lo..hi {
        let gid = posts[lp as usize];
        let dst_pop = spec.pop_of(gid);
        spec.for_each_in_edge(gid, |e, src_pop| {
            let j = upres
                .binary_search(&e.pre)
                .expect("pass 2 saw a source pass 1 did not");
            let p = remap[j] as usize;
            let k = cursor[p] as usize;
            cursor[p] += 1;
            post[k] = lp;
            weight[k] = e.weight;
            delay[k] = e.delay;
            if plastic_net {
                epre[k] = p as u32;
                if spec.pair_plastic(src_pop, dst_pop) {
                    plastic.set(k, true);
                }
            }
        });
    }
    debug_assert!(
        (0..n_pres).all(|p| cursor[p] == offsets[p + 1]),
        "pass 2 edge counts disagree with pass 1"
    );

    // Delay-sort every pre run, stably: within a (pre, delay) group the
    // arrival order above *is* generation order, so the layout matches
    // the serial builder's stable (pre, delay) sort bit for bit.
    let mut perm: Vec<u32> = Vec::new();
    let mut s32: Vec<u32> = Vec::new();
    let mut sf: Vec<f64> = Vec::new();
    let mut s16: Vec<DelaySteps> = Vec::new();
    let mut sb: Vec<bool> = Vec::new();
    for p in 0..n_pres {
        let r = offsets[p] as usize..offsets[p + 1] as usize;
        if r.len() <= 1
            || delay[r.clone()].windows(2).all(|w| w[0] <= w[1])
        {
            continue;
        }
        perm.clear();
        perm.extend(r.clone().map(|i| i as u32));
        perm.sort_by_key(|&i| delay[i as usize]); // stable
        s16.clear();
        s16.extend(perm.iter().map(|&i| delay[i as usize]));
        delay[r.clone()].copy_from_slice(&s16);
        s32.clear();
        s32.extend(perm.iter().map(|&i| post[i as usize]));
        post[r.clone()].copy_from_slice(&s32);
        sf.clear();
        sf.extend(perm.iter().map(|&i| weight[i as usize]));
        weight[r.clone()].copy_from_slice(&sf);
        if plastic_net {
            s32.clear();
            s32.extend(perm.iter().map(|&i| epre[i as usize]));
            epre[r.clone()].copy_from_slice(&s32);
            sb.clear();
            sb.extend(perm.iter().map(|&i| plastic.get(i as usize)));
            for (o, &b) in r.clone().zip(sb.iter()) {
                plastic.set(o, b);
            }
        }
    }

    // plastic-by-post CSR (potentiation path), from the final layout
    let span = (hi - lo) as usize;
    let (pbp_off, pbp_edge) = if plastic_net {
        let mut off = vec![0u32; span + 1];
        for ei in 0..n_e {
            if plastic.get(ei) {
                off[(post[ei] - lo) as usize + 1] += 1;
            }
        }
        for i in 0..span {
            off[i + 1] += off[i];
        }
        let mut cur = off.clone();
        let mut idx = vec![0u32; off[span] as usize];
        for (ei, &po) in post.iter().enumerate() {
            if plastic.get(ei) {
                let b = (po - lo) as usize;
                idx[cur[b] as usize] = ei as u32;
                cur[b] += 1;
            }
        }
        (off, idx)
    } else {
        (Vec::new(), Vec::new())
    };

    let peak_bytes = vec_bytes(&offsets)
        + vec_bytes(&cursor)
        + vec_bytes(&remap)
        + vec_bytes(&upres)
        + vec_bytes(&post)
        + vec_bytes(&weight)
        + vec_bytes(&delay)
        + plastic.bytes()
        + vec_bytes(&epre)
        + 2 * vec_bytes(&pbp_off)
        + vec_bytes(&pbp_edge);
    BuildPart::Fill {
        edges: ThreadEdges {
            offsets,
            post,
            weight,
            delay,
            plastic,
            epre,
            plastic_by_post_offsets: pbp_off,
            plastic_by_post_edge: pbp_edge,
            post_lo: lo,
            post_hi: hi,
        },
        peak_bytes,
    }
}

/// K-way merge of sorted-unique gid lists into their sorted-unique
/// union, heap-based: each round pops every head equal to the current
/// minimum off a min-heap of `(head gid, list)` pairs and advances it —
/// O(total · log k), replacing the old linear scan over all `k` heads
/// per emitted gid. A counting sweep (`emit = None`) returns the union
/// size without writing, so the fill sweep can allocate exactly.
fn merge_sorted_unique(
    lists: &[&[Gid]],
    mut emit: Option<&mut Vec<Gid>>,
) -> usize {
    let mut heads = vec![0usize; lists.len()];
    let mut heap: BinaryHeap<Reverse<(Gid, usize)>> = lists
        .iter()
        .enumerate()
        .filter_map(|(t, l)| l.first().map(|&g| Reverse((g, t))))
        .collect();
    let mut merged = 0usize;
    while let Some(&Reverse((g, _))) = heap.peek() {
        if let Some(out) = emit.as_mut() {
            out.push(g);
        }
        merged += 1;
        while let Some(&Reverse((h, t))) = heap.peek() {
            if h != g {
                break;
            }
            heap.pop();
            heads[t] += 1;
            if let Some(&next) = lists[t].get(heads[t]) {
                debug_assert!(next > g, "list {t} not sorted-unique");
                heap.push(Reverse((next, t)));
            }
        }
    }
    merged
}

/// The rank's full data instance.
#[derive(Clone, Debug)]
pub struct RankStore {
    pub rank: u16,
    /// Owned posts, ascending gid; local index = position here.
    pub posts: Vec<Gid>,
    /// All sources with edges onto this rank, ascending gid;
    /// pre index = position here.
    pub pres: Vec<Gid>,
    /// Number of `pres` that are also owned posts (the local part of
    /// eq. 16; `pres.len() - n_local_pres` is the remote part).
    pub n_local_pres: usize,
    /// Edges arriving from local sources (the `in-S^l` of eq. 16).
    pub n_local_edges: u64,
    pub n_remote_edges: u64,
    pub threads: Vec<ThreadEdges>,
    /// thread → owned local post range.
    pub thread_ranges: Vec<(u32, u32)>,
    pub max_delay: DelaySteps,
    /// Analytic heap bytes of the posts' neuron-model state (per-model
    /// SoA layout × population sizes). Reported by [`Self::memory`]
    /// until the live state blocks move into the engine's worker
    /// contexts, which then report their actual bytes.
    pub state_bytes: u64,
    /// How this store's construction went (timings + peak memory).
    pub build: BuildStats,
}

impl RankStore {
    /// Build the store for `rank` with the two-pass streaming pipeline,
    /// its passes spread over transient OS threads. Inside a live
    /// engine use [`Self::build_with`] and hand in the worker pool.
    pub fn build(
        spec: &Arc<NetworkSpec>,
        posts: &[Gid],
        is_local: impl Fn(Gid) -> bool,
        rank: u16,
        n_threads: usize,
    ) -> RankStore {
        Self::build_with(spec, posts, is_local, rank, n_threads, &ThreadRunner)
    }

    /// Two-pass parallel construction on an arbitrary [`BuildRunner`]
    /// (the engine passes its persistent `WorkerPool`, so construction
    /// parallelises across the same threads that later step). Produces
    /// contents bit-identical to [`Self::build_serial`] at any thread
    /// count.
    pub fn build_with(
        spec: &Arc<NetworkSpec>,
        posts: &[Gid],
        is_local: impl Fn(Gid) -> bool,
        rank: u16,
        n_threads: usize,
        runner: &dyn BuildRunner,
    ) -> RankStore {
        assert!(n_threads >= 1);
        let n_posts = posts.len();
        let plastic_net = spec.stdp.is_some();
        let thread_ranges = split_ranges(n_posts, n_threads);
        let posts_arc: Arc<Vec<Gid>> = Arc::new(posts.to_vec());
        let posts_bytes = vec_bytes(&posts_arc);

        // ---- pass 1: count (parallel) --------------------------------
        let t0 = Instant::now();
        let tasks: Vec<BuildTask> = thread_ranges
            .iter()
            .map(|&(lo, hi)| {
                let spec = Arc::clone(spec);
                let posts = Arc::clone(&posts_arc);
                Box::new(move || {
                    BuildPart::Count(count_pass(
                        &spec,
                        &posts[lo as usize..hi as usize],
                    ))
                }) as BuildTask
            })
            .collect();
        let counts: Vec<CountPart> = runner
            .run(tasks)
            .into_iter()
            .map(|p| match p {
                BuildPart::Count(c) => c,
                BuildPart::Fill { .. } => {
                    unreachable!("count pass returned a fill part")
                }
            })
            .collect();
        let count_ns = t0.elapsed().as_nanos() as u64;
        let count_peak: u64 =
            posts_bytes + counts.iter().map(|c| c.peak_bytes).sum::<u64>();

        // ---- merge (serial) ------------------------------------------
        // heap-based k-way merge of the sorted-unique per-thread source
        // tables ([`merge_sorted_unique`]), run twice: a counting sweep
        // sizes `pres` exactly (no growth, no shrink copy — the
        // analytic peak stays honest), then the fill sweep writes it
        let t1 = Instant::now();
        let k = counts.len();
        let upres_lists: Vec<&[Gid]> =
            counts.iter().map(|c| c.upres.as_slice()).collect();
        let n_pres = merge_sorted_unique(&upres_lists, None);
        let mut pres: Vec<Gid> = Vec::with_capacity(n_pres);
        merge_sorted_unique(&upres_lists, Some(&mut pres));
        let n_local_pres =
            pres.iter().filter(|&&g| is_local(g)).count();
        let max_delay = counts
            .iter()
            .map(|c| c.max_delay)
            .fold(1, DelaySteps::max);

        // per-thread exact CSR offsets + thread-local → rank pre remap
        let mut n_local_edges = 0u64;
        let mut n_remote_edges = 0u64;
        let mut upres_bytes = 0u64;
        let mut table_bytes = 0u64;
        let mut per_thread: Vec<(Vec<u32>, Vec<u32>)> =
            Vec::with_capacity(k);
        for c in &counts {
            let mut offsets = vec![0u32; n_pres + 1];
            let mut remap = vec![0u32; c.upres.len()];
            let mut i = 0usize;
            for (j, (&g, &cnt)) in
                c.upres.iter().zip(&c.ucounts).enumerate()
            {
                while pres[i] != g {
                    i += 1;
                }
                remap[j] = i as u32;
                offsets[i + 1] = cnt;
                if is_local(g) {
                    n_local_edges += cnt as u64;
                } else {
                    n_remote_edges += cnt as u64;
                }
            }
            for i in 0..n_pres {
                offsets[i + 1] += offsets[i];
            }
            upres_bytes +=
                vec_bytes(&c.upres) + vec_bytes(&c.ucounts);
            table_bytes += vec_bytes(&offsets) + vec_bytes(&remap);
            per_thread.push((offsets, remap));
        }
        debug_assert_eq!(
            counts.iter().map(|c| c.n_edges).sum::<u64>(),
            n_local_edges + n_remote_edges,
            "per-source counts disagree with the edge totals"
        );
        let merge_ns = t1.elapsed().as_nanos() as u64;
        let merge_peak = posts_bytes
            + upres_bytes
            + vec_bytes(&pres)
            + table_bytes;

        // ---- pass 2: fill (parallel) ---------------------------------
        let t2 = Instant::now();
        let pres_bytes = vec_bytes(&pres);
        let tasks: Vec<BuildTask> = counts
            .into_iter()
            .zip(per_thread)
            .zip(&thread_ranges)
            .map(|((c, (offsets, remap)), &(lo, hi))| {
                let spec = Arc::clone(spec);
                let posts = Arc::clone(&posts_arc);
                Box::new(move || {
                    fill_pass(
                        &spec, &posts, lo, hi, offsets, remap, c.upres,
                        plastic_net,
                    )
                }) as BuildTask
            })
            .collect();
        let mut fill_peak = posts_bytes + pres_bytes;
        let threads: Vec<ThreadEdges> = runner
            .run(tasks)
            .into_iter()
            .map(|p| match p {
                BuildPart::Fill { edges, peak_bytes } => {
                    fill_peak += peak_bytes;
                    edges
                }
                BuildPart::Count(_) => {
                    unreachable!("fill pass returned a count part")
                }
            })
            .collect();
        let fill_ns = t2.elapsed().as_nanos() as u64;

        let state_bytes = model_state_bytes(spec, posts);
        let posts = Arc::try_unwrap(posts_arc)
            .unwrap_or_else(|a| (*a).clone());
        RankStore {
            rank,
            posts,
            pres,
            n_local_pres,
            n_local_edges,
            n_remote_edges,
            threads,
            thread_ranges,
            max_delay,
            state_bytes,
            build: BuildStats {
                count_ns,
                merge_ns,
                fill_ns,
                peak_bytes: count_peak.max(merge_peak).max(fill_peak),
            },
        }
    }

    /// The single-threaded staging builder, kept as the ablation path:
    /// it materialises the full edge list, re-stages it per thread and
    /// only then lays out the CSR — three edge copies at peak, built
    /// serially. [`Self::build`] produces bit-identical contents.
    pub fn build_serial(
        spec: &NetworkSpec,
        posts: &[Gid],
        is_local: impl Fn(Gid) -> bool,
        rank: u16,
        n_threads: usize,
    ) -> RankStore {
        assert!(n_threads >= 1);
        let n_posts = posts.len();
        let plastic_net = spec.stdp.is_some();
        let posts_bytes = (n_posts * std::mem::size_of::<Gid>()) as u64;

        let thread_ranges = split_ranges(n_posts, n_threads);
        let thread_of =
            |local_post: u32| -> ThreadId { owner_of(local_post, n_posts, n_threads) };

        // generate the indegree sub-graph: all incoming edges of our posts
        let t0 = Instant::now();
        let mut edges: Vec<Edge> = Vec::new();
        for &gid in posts {
            spec.in_edges(gid, &mut edges);
        }
        let count_ns = t0.elapsed().as_nanos() as u64;
        let edges_bytes = vec_bytes(&edges);

        // pres = sorted unique sources
        let t1 = Instant::now();
        let mut pres: Vec<Gid> = edges.iter().map(|e| e.pre).collect();
        pres.sort_unstable();
        pres.dedup();
        pres.shrink_to_fit(); // dedup leaves the pre-dedup capacity
        let n_local_pres = pres.iter().filter(|&&p| is_local(p)).count();
        let merge_ns = t1.elapsed().as_nanos() as u64;

        let pre_index = |gid: Gid| -> u32 {
            pres.binary_search(&gid).expect("pre not in table") as u32
        };
        let post_index = |gid: Gid| -> u32 {
            posts.binary_search(&gid).expect("post not in table") as u32
        };

        let mut n_local_edges = 0u64;
        let mut n_remote_edges = 0u64;
        let mut max_delay: DelaySteps = 1;

        // (thread, pre, delay)-sorted staging: one bucket per thread
        let t2 = Instant::now();
        struct Staged {
            pre: u32,
            post: u32,
            weight: f64,
            delay: DelaySteps,
            plastic: bool,
        }
        let mut staged: Vec<Vec<Staged>> =
            (0..n_threads).map(|_| Vec::new()).collect();
        for e in &edges {
            let lp = post_index(e.post);
            let t = thread_of(lp) as usize;
            if is_local(e.pre) {
                n_local_edges += 1;
            } else {
                n_remote_edges += 1;
            }
            max_delay = max_delay.max(e.delay);
            staged[t].push(Staged {
                pre: pre_index(e.pre),
                post: lp,
                weight: e.weight,
                delay: e.delay,
                plastic: plastic_net && spec.edge_plastic(e.pre, e.post),
            });
        }
        drop(edges);
        let staged_bytes: u64 =
            staged.iter().map(vec_bytes).sum::<u64>();

        let threads: Vec<ThreadEdges> = staged
            .into_iter()
            .enumerate()
            .map(|(t, mut st)| {
                // paper Fig 12b: sort by (pre, delay) within the thread.
                // Stable + cached key: multapse ties keep generation
                // order, so delivery's per-slot addition order matches
                // the baseline engine's (spike-exact comparability).
                st.sort_by_cached_key(|s| {
                    ((s.pre as u64) << 16) | s.delay as u64
                });
                let mut offsets = vec![0u32; pres.len() + 1];
                for s in &st {
                    offsets[s.pre as usize + 1] += 1;
                }
                for i in 0..pres.len() {
                    offsets[i + 1] += offsets[i];
                }
                let post: Vec<u32> = st.iter().map(|s| s.post).collect();
                let weight: Vec<f64> = st.iter().map(|s| s.weight).collect();
                let delay: Vec<DelaySteps> =
                    st.iter().map(|s| s.delay).collect();
                let plastic = if plastic_net {
                    let mut bits = BitSet::zeros(st.len());
                    for (i, s) in st.iter().enumerate() {
                        if s.plastic {
                            bits.set(i, true);
                        }
                    }
                    bits
                } else {
                    BitSet::new()
                };
                let epre: Vec<u32> = if plastic_net {
                    st.iter().map(|s| s.pre).collect()
                } else {
                    Vec::new()
                };

                // plastic-by-post CSR (potentiation path)
                let (lo, hi) = thread_ranges[t];
                let span = (hi - lo) as usize;
                let (pbp_off, pbp_edge) = if plastic_net {
                    let mut off = vec![0u32; span + 1];
                    for s in &st {
                        if s.plastic {
                            off[(s.post - lo) as usize + 1] += 1;
                        }
                    }
                    for i in 0..span {
                        off[i + 1] += off[i];
                    }
                    let mut cursor = off.clone();
                    let mut idx = vec![0u32; off[span] as usize];
                    for (ei, s) in st.iter().enumerate() {
                        if s.plastic {
                            let b = (s.post - lo) as usize;
                            idx[cursor[b] as usize] = ei as u32;
                            cursor[b] += 1;
                        }
                    }
                    (off, idx)
                } else {
                    (Vec::new(), Vec::new())
                };

                ThreadEdges {
                    offsets,
                    post,
                    weight,
                    delay,
                    plastic,
                    epre,
                    plastic_by_post_offsets: pbp_off,
                    plastic_by_post_edge: pbp_edge,
                    post_lo: lo,
                    post_hi: hi,
                }
            })
            .collect();
        let fill_ns = t2.elapsed().as_nanos() as u64;
        let final_bytes: u64 =
            threads.iter().map(|t| t.bytes()).sum::<u64>();
        // three copies at peak: the global edge list coexists with the
        // staging buckets, and the buckets with the growing CSR
        let peak_bytes = posts_bytes
            + vec_bytes(&pres)
            + (edges_bytes + staged_bytes)
                .max(staged_bytes + final_bytes);

        let state_bytes = model_state_bytes(spec, posts);
        RankStore {
            rank,
            posts: posts.to_vec(),
            pres,
            n_local_pres,
            n_local_edges,
            n_remote_edges,
            threads,
            thread_ranges,
            max_delay,
            state_bytes,
            build: BuildStats { count_ns, merge_ns, fill_ns, peak_bytes },
        }
    }

    /// True when the two stores describe the identical sub-graph — every
    /// field the engine consumes compared exactly (build statistics are
    /// timing-dependent and ignored). The contract between
    /// [`Self::build`] and [`Self::build_serial`].
    pub fn same_graph(&self, other: &RankStore) -> bool {
        self.rank == other.rank
            && self.posts == other.posts
            && self.pres == other.pres
            && self.n_local_pres == other.n_local_pres
            && self.n_local_edges == other.n_local_edges
            && self.n_remote_edges == other.n_remote_edges
            && self.threads == other.threads
            && self.thread_ranges == other.thread_ranges
            && self.max_delay == other.max_delay
            && self.state_bytes == other.state_bytes
    }

    pub fn n_posts(&self) -> usize {
        self.posts.len()
    }

    pub fn n_pres(&self) -> usize {
        self.pres.len()
    }

    pub fn n_remote_pres(&self) -> usize {
        self.pres.len() - self.n_local_pres
    }

    pub fn n_edges(&self) -> u64 {
        self.n_local_edges + self.n_remote_edges
    }

    /// Pre index of a gid if any of our edges source from it.
    #[inline]
    pub fn pre_index_of(&self, gid: Gid) -> Option<u32> {
        self.pres.binary_search(&gid).ok().map(|i| i as u32)
    }

    /// Local post index of an owned gid.
    #[inline]
    pub fn post_index_of(&self, gid: Gid) -> Option<u32> {
        self.posts.binary_search(&gid).ok().map(|i| i as u32)
    }

    /// Owning thread of a local post index (O(1) on the equal split).
    #[inline]
    pub fn thread_of(&self, local_post: u32) -> ThreadId {
        owner_of(local_post, self.n_posts(), self.thread_ranges.len())
    }

    /// Per-source-rank subscription sets: the sources this rank's
    /// sub-graph consumes, bucketed by owning rank. `pres` is ascending,
    /// so every bucket comes out strictly increasing — exactly the
    /// precondition of the gid-list wire codec
    /// ([`crate::comm::bsb::encode_gid_list`]). The own-rank slot stays
    /// empty: local spikes never cross the wire. Shipped to the source
    /// ranks by the build-time subscription collective, these sets are
    /// what interest-routed exchange filters against.
    pub fn subscriptions(&self, part: &Partition) -> Vec<Vec<Gid>> {
        let mut subs = vec![Vec::new(); part.n_ranks];
        for &g in &self.pres {
            let src = part.rank_of[g as usize] as usize;
            if src != self.rank as usize {
                subs[src].push(g);
            }
        }
        subs
    }

    /// Move the per-thread edge stores out. The engine no longer does
    /// this — since the topology/state split it shares the whole store
    /// immutably (`Arc<RankStore>`) across worker contexts and
    /// trajectories — but standalone consumers (benches, ablations)
    /// may still claim exclusive ownership of the shares.
    pub fn take_threads(&mut self) -> Vec<ThreadEdges> {
        std::mem::take(&mut self.threads)
    }

    /// Memory accounting for the Fig 18 / Fig 9-10 benches, for a store
    /// inspected **standalone** (`cortex partition`, build benches):
    /// structure plus an analytic neuron-state figure while the store
    /// owns its per-thread shares. The engine instead reports
    /// [`Self::shared_memory`] + its trajectory's actual state bytes,
    /// which never double-counts. The transient construction peak is
    /// attached as a gauge — reported next to the components, never
    /// summed into the steady-state total.
    pub fn memory(&self) -> MemoryBreakdown {
        let mut m = MemoryBreakdown::new();
        m.add("posts", vec_bytes(&self.posts));
        m.add("pres", vec_bytes(&self.pres));
        if !self.threads.is_empty() {
            m.add("state", self.state_bytes);
        }
        for t in &self.threads {
            m.add("edges", t.bytes());
        }
        m.set_gauge("build_peak", self.build.peak_bytes);
        m
    }

    /// Bytes of the **shared, immutable** build product alone: gid maps
    /// plus every thread's edge store, no neuron state. This is what an
    /// ensemble of N trajectories holds exactly once (each trajectory's
    /// own state is accounted by `RankEngine::trajectory_memory`), and
    /// what the serve daemon's admission control charges per built
    /// network rather than per session state.
    pub fn shared_memory(&self) -> MemoryBreakdown {
        let mut m = MemoryBreakdown::new();
        m.add("posts", vec_bytes(&self.posts));
        m.add("pres", vec_bytes(&self.pres));
        for t in &self.threads {
            m.add("edges", t.bytes());
        }
        m.set_gauge("build_peak", self.build.peak_bytes);
        m
    }
}

/// Analytic heap bytes of the posts' neuron-model state.
fn model_state_bytes(spec: &NetworkSpec, posts: &[Gid]) -> u64 {
    posts
        .iter()
        .map(|&g| {
            spec.params[spec.pidx(g) as usize].state_bytes_per_neuron()
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::atlas::hpc::{hpc_benchmark_spec, HpcParams};
    use crate::atlas::random_spec;
    use crate::decomp::random_equivalent_partition;
    use crate::util::proptest_lite::property;

    fn build_stores(
        n: usize,
        k: u32,
        ranks: usize,
        threads: usize,
        seed: u64,
    ) -> (Arc<crate::atlas::NetworkSpec>, Vec<RankStore>) {
        let spec = Arc::new(random_spec(n, k, seed));
        let part = random_equivalent_partition(n, ranks, seed);
        let stores = (0..ranks)
            .map(|r| {
                let rank_of = part.rank_of.clone();
                RankStore::build(
                    &spec,
                    &part.members[r],
                    move |g| rank_of[g as usize] as usize == r,
                    r as u16,
                    threads,
                )
            })
            .collect();
        (spec, stores)
    }

    #[test]
    fn edges_conserved_across_ranks() {
        let (spec, stores) = build_stores(400, 40, 3, 2, 1);
        let total: u64 = stores.iter().map(|s| s.n_edges()).sum();
        assert_eq!(total, spec.n_edges());
    }

    #[test]
    fn thread_write_sets_disjoint_and_covering() {
        let (_, stores) = build_stores(300, 30, 2, 3, 2);
        for s in &stores {
            // ranges tile [0, n_posts)
            let mut expect = 0u32;
            for &(lo, hi) in &s.thread_ranges {
                assert_eq!(lo, expect);
                expect = hi;
            }
            assert_eq!(expect as usize, s.n_posts());
            // every edge's post lies in its thread's range — the no-race
            // invariant of paper §III.B.1
            for (t, te) in s.threads.iter().enumerate() {
                let (lo, hi) = s.thread_ranges[t];
                assert!(te
                    .post
                    .iter()
                    .all(|&p| p >= lo && p < hi));
            }
        }
    }

    #[test]
    fn runs_are_delay_sorted() {
        let (_, stores) = build_stores(300, 30, 2, 3, 3);
        for s in &stores {
            for te in &s.threads {
                for p in 0..s.pres.len() {
                    let r = te.run(p);
                    let ds = &te.delay[r];
                    assert!(
                        ds.windows(2).all(|w| w[0] <= w[1]),
                        "run not delay-sorted"
                    );
                }
            }
        }
    }

    #[test]
    fn pres_exactly_the_sources() {
        let (spec, stores) = build_stores(200, 25, 2, 2, 4);
        for s in &stores {
            // every pre has >= 1 edge in some thread
            for (pi, _) in s.pres.iter().enumerate() {
                let total: usize =
                    s.threads.iter().map(|t| t.run(pi).len()).sum();
                assert!(total > 0, "pre with no edges");
            }
            // and conversely every generated edge's source is in pres
            let mut edges = Vec::new();
            for &g in &s.posts {
                spec.in_edges(g, &mut edges);
            }
            for e in &edges {
                assert!(s.pre_index_of(e.pre).is_some());
            }
        }
    }

    #[test]
    fn local_remote_split_consistent() {
        let (_, stores) = build_stores(300, 30, 3, 2, 5);
        for s in &stores {
            assert!(s.n_local_pres <= s.n_pres());
            assert_eq!(
                s.n_edges(),
                s.threads.iter().map(|t| t.n_edges() as u64).sum::<u64>()
            );
        }
    }

    #[test]
    fn memory_breakdown_nonzero() {
        let (_, stores) = build_stores(200, 20, 2, 2, 6);
        let m = stores[0].memory();
        assert!(m.get("edges") > 0);
        assert!(m.get("posts") > 0);
        // neuron-model state accounted: LIF = 33 B/neuron
        assert_eq!(m.get("state"), 33 * stores[0].n_posts() as u64);
        assert!(m.total() > m.get("edges"));
        // the construction peak rides along as a gauge, excluded from
        // the steady-state total
        assert!(m.gauge("build_peak") > 0);
        assert_eq!(
            m.total(),
            m.components().map(|(_, b)| b).sum::<u64>()
        );
    }

    #[test]
    fn state_bytes_follow_population_models() {
        use crate::atlas::random_spec_with;
        use crate::model::{AdexParams, LifParams, ModelParams};
        let spec = Arc::new(random_spec_with(
            200,
            20,
            6,
            ModelParams::Adex(AdexParams::default()),
            ModelParams::Lif(LifParams::default()),
        ));
        let posts: Vec<u32> = (0..200).collect();
        let store = RankStore::build(&spec, &posts, |_| true, 0, 2);
        // 160 AdEx × 40 B + 40 LIF × 33 B
        assert_eq!(store.state_bytes, 160 * 40 + 40 * 33);
        assert_eq!(store.memory().get("state"), store.state_bytes);
    }

    #[test]
    fn owner_of_matches_linear_scan() {
        // the O(1) arithmetic must agree with a scan of the range table
        // for every post, including degenerate splits (n < threads, where
        // some ranges are empty)
        for &(n, threads) in &[
            (1usize, 1usize),
            (1, 4),
            (3, 4),
            (7, 3),
            (100, 1),
            (100, 3),
            (101, 7),
            (1000, 13),
        ] {
            let ranges: Vec<(u32, u32)> = (0..threads)
                .map(|t| {
                    (
                        (t * n / threads) as u32,
                        ((t + 1) * n / threads) as u32,
                    )
                })
                .collect();
            for p in 0..n as u32 {
                let want = ranges
                    .iter()
                    .position(|&(lo, hi)| p >= lo && p < hi)
                    .expect("post uncovered") as ThreadId;
                assert_eq!(
                    owner_of(p, n, threads),
                    want,
                    "n={n} threads={threads} p={p}"
                );
            }
        }
    }

    #[test]
    fn owner_of_zero_posts_returns_thread_zero() {
        // regression: `owner_of` divided by `n_posts`; a rank owning
        // zero posts (more ranks than an area has neurons) must answer
        // thread 0 instead of dividing by zero
        for threads in [1usize, 2, 7] {
            assert_eq!(owner_of(0, 0, threads), 0);
        }
    }

    #[test]
    fn empty_rank_builds_and_answers() {
        // a rank with an empty post range must build (both pipelines),
        // report zeros, and keep thread_of total
        let spec = Arc::new(random_spec(50, 5, 11));
        let par = RankStore::build(&spec, &[], |_| false, 3, 4);
        let ser = RankStore::build_serial(&spec, &[], |_| false, 3, 4);
        assert!(par.same_graph(&ser));
        assert_eq!(par.n_posts(), 0);
        assert_eq!(par.n_pres(), 0);
        assert_eq!(par.n_edges(), 0);
        assert_eq!(par.threads.len(), 4);
        assert!(par
            .thread_ranges
            .iter()
            .all(|&(lo, hi)| lo == 0 && hi == 0));
        assert_eq!(par.thread_of(0), 0);
        assert!(par.memory().total() < 1024);
    }

    #[test]
    fn thread_of_agrees_with_ranges_after_take() {
        let (_, mut stores) = build_stores(157, 12, 1, 5, 8);
        let s = &mut stores[0];
        let ranges = s.thread_ranges.clone();
        for p in 0..s.n_posts() as u32 {
            let t = s.thread_of(p) as usize;
            assert!(p >= ranges[t].0 && p < ranges[t].1);
        }
        // taking the thread stores must not break the O(1) lookup
        let taken = s.take_threads();
        assert_eq!(taken.len(), 5);
        assert!(s.threads.is_empty());
        assert_eq!(s.thread_of(0), owner_of(0, s.n_posts(), ranges.len()));
    }

    #[test]
    fn parallel_builder_matches_serial_field_for_field() {
        // the acceptance contract: the two-pass streaming builder and
        // the staging ablation builder produce bit-identical stores at
        // 1/2/4 threads, on plain and plastic networks
        let plain = Arc::new(random_spec(300, 30, 9));
        let plastic = Arc::new(hpc_benchmark_spec(
            &HpcParams {
                n_neurons: 240,
                indegree: 60,
                plastic: true,
                ..Default::default()
            },
            9,
        ));
        for spec in [&plain, &plastic] {
            assert_eq!(
                spec.stdp.is_some(),
                Arc::ptr_eq(spec, &plastic)
            );
            let n = spec.n_total();
            for ranks in [1usize, 3] {
                let part = random_equivalent_partition(n, ranks, 9);
                for threads in [1usize, 2, 4] {
                    for r in 0..ranks {
                        let rank_of = part.rank_of.clone();
                        let is_local = move |g: Gid| {
                            rank_of[g as usize] as usize == r
                        };
                        let par = RankStore::build(
                            spec,
                            &part.members[r],
                            is_local,
                            r as u16,
                            threads,
                        );
                        let rank_of = part.rank_of.clone();
                        let ser = RankStore::build_serial(
                            spec,
                            &part.members[r],
                            move |g| rank_of[g as usize] as usize == r,
                            r as u16,
                            threads,
                        );
                        assert!(
                            par.same_graph(&ser),
                            "builder divergence: {} ranks={ranks} \
                             threads={threads} rank={r}",
                            spec.name
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn plastic_markers_are_bit_packed() {
        let spec = Arc::new(hpc_benchmark_spec(
            &HpcParams {
                n_neurons: 200,
                indegree: 50,
                plastic: true,
                ..Default::default()
            },
            13,
        ));
        let posts: Vec<u32> = (0..spec.n_total() as u32).collect();
        let store = RankStore::build(&spec, &posts, |_| true, 0, 2);
        let mut marked = 0usize;
        for te in &store.threads {
            let n = te.n_edges();
            assert_eq!(te.plastic.len(), n);
            // one bit per edge, not one byte
            assert!(te.plastic.bytes() <= (n as u64 / 8) + 8);
            assert_eq!(te.epre.len(), n);
            marked += te.plastic.count_ones();
        }
        assert!(marked > 0, "hpc_benchmark must have plastic E→E edges");

        // non-plastic networks allocate nothing for the markers
        let plain = Arc::new(random_spec(200, 20, 13));
        let store = RankStore::build(&plain, &posts[..200], |_| true, 0, 2);
        for te in &store.threads {
            assert!(te.plastic.is_empty());
            assert_eq!(te.plastic.bytes(), 0);
            assert!(te.epre.is_empty());
        }
    }

    #[test]
    fn build_stats_populated_and_peak_bounded() {
        let (_, stores) = build_stores(400, 60, 1, 4, 14);
        let s = &stores[0];
        let b = s.build;
        assert!(b.count_ns > 0 && b.fill_ns > 0);
        let final_bytes = s.memory().get("posts")
            + s.memory().get("pres")
            + s.memory().get("edges");
        assert!(b.peak_bytes >= final_bytes);
        // the headline bound: streaming construction stays under ~1.5×
        // the final store (the serial path holds ~3×)
        assert!(
            b.peak_bytes as f64 <= 1.5 * final_bytes as f64 + 4096.0,
            "peak {} vs final {final_bytes}",
            b.peak_bytes
        );
        let ser = RankStore::build_serial(
            &Arc::new(random_spec(400, 60, 14)),
            &s.posts,
            |_| true,
            0,
            4,
        );
        assert!(
            ser.build.peak_bytes > b.peak_bytes,
            "staging builder should peak higher than streaming"
        );
    }

    #[test]
    fn property_store_invariants() {
        property("rank store invariants", 15, |g| {
            let n = g.usize(50..400);
            let k = g.u32(1..30.min(n as u32));
            let ranks = g.usize(1..5);
            let threads = g.usize(1..4);
            let (spec, stores) =
                build_stores(n, k, ranks, threads, g.case as u64 + 50);
            let total: u64 = stores.iter().map(|s| s.n_edges()).sum();
            if total != spec.n_edges() {
                return Err(format!(
                    "edge conservation {total} != {}",
                    spec.n_edges()
                ));
            }
            for s in &stores {
                if s.threads.len() != threads {
                    return Err("thread count".into());
                }
                for te in &s.threads {
                    if *te.offsets.last().unwrap() as usize != te.post.len() {
                        return Err("csr tail mismatch".into());
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn property_heap_merge_equals_linear_scan() {
        // the heap merge must produce exactly what the old
        // O(pres·threads) per-element min-scan produced, counting
        // sweep included
        fn linear_scan(lists: &[&[Gid]]) -> Vec<Gid> {
            let mut heads = vec![0usize; lists.len()];
            let mut out = Vec::new();
            loop {
                let min = lists
                    .iter()
                    .zip(&heads)
                    .filter_map(|(l, &h)| l.get(h))
                    .min()
                    .copied();
                let Some(g) = min else { break };
                out.push(g);
                for (l, h) in lists.iter().zip(&mut heads) {
                    if l.get(*h) == Some(&g) {
                        *h += 1;
                    }
                }
            }
            out
        }
        property("heap merge == linear scan", 40, |g| {
            let k = g.usize(1..9);
            let lists: Vec<Vec<Gid>> = (0..k)
                .map(|_| {
                    let len = g.usize(0..60);
                    let mut l: Vec<Gid> =
                        (0..len).map(|_| g.u32(0..120)).collect();
                    l.sort_unstable();
                    l.dedup();
                    l
                })
                .collect();
            let refs: Vec<&[Gid]> =
                lists.iter().map(|l| l.as_slice()).collect();
            let want = linear_scan(&refs);
            let n = merge_sorted_unique(&refs, None);
            let mut got = Vec::with_capacity(n);
            merge_sorted_unique(&refs, Some(&mut got));
            if n != want.len() || got != want {
                return Err(format!(
                    "merge diverged: count {n} vs {}, {got:?} vs \
                     {want:?}",
                    want.len()
                ));
            }
            Ok(())
        });
    }

    #[test]
    fn subscriptions_bucket_pres_by_owner_and_skip_local() {
        let (_, stores) = build_stores(120, 7, 3, 2, 411);
        let part = random_equivalent_partition(120, 3, 411);
        for s in &stores {
            let subs = s.subscriptions(&part);
            assert_eq!(subs.len(), 3);
            assert!(subs[s.rank as usize].is_empty());
            let n_remote: usize =
                subs.iter().map(|b| b.len()).sum();
            assert_eq!(n_remote, s.pres.len() - s.n_local_pres);
            for (src, bucket) in subs.iter().enumerate() {
                assert!(
                    bucket.windows(2).all(|w| w[0] < w[1]),
                    "bucket {src} not strictly increasing"
                );
                for &g in bucket {
                    assert_eq!(
                        part.rank_of[g as usize] as usize, src,
                        "gid {g} bucketed under the wrong rank"
                    );
                    assert!(s.pre_index_of(g).is_some());
                }
            }
        }
    }

    #[test]
    fn property_parallel_equals_serial() {
        // proptest-style sweep of the bit-identity contract across
        // network shapes, rank counts and thread counts
        property("two-pass == serial", 12, |g| {
            let n = g.usize(40..250);
            let k = g.u32(1..20.min(n as u32));
            let ranks = g.usize(1..4);
            let threads = [1usize, 2, 4][g.usize(0..3)];
            let seed = g.case as u64 + 90;
            let spec = Arc::new(random_spec(n, k, seed));
            let part = random_equivalent_partition(n, ranks, seed);
            for r in 0..ranks {
                let rank_of = part.rank_of.clone();
                let par = RankStore::build(
                    &spec,
                    &part.members[r],
                    move |g| rank_of[g as usize] as usize == r,
                    r as u16,
                    threads,
                );
                let rank_of = part.rank_of.clone();
                let ser = RankStore::build_serial(
                    &spec,
                    &part.members[r],
                    move |g| rank_of[g as usize] as usize == r,
                    r as u16,
                    threads,
                );
                if !par.same_graph(&ser) {
                    return Err(format!(
                        "divergence at n={n} k={k} ranks={ranks} \
                         threads={threads} rank={r}"
                    ));
                }
            }
            Ok(())
        });
    }
}
