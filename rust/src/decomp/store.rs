//! Per-rank data instance of the indegree sub-graph (paper Fig 12).
//!
//! For a rank owning post-neurons `V_i`:
//!
//! * `posts` — the owned (post-synaptic) gids, in local index order;
//! * `pres` — every source gid with at least one edge onto this rank,
//!   i.e. exactly the sub-graph's pre-vertex set `in-V_i^pre`; split
//!   into the local part (`pre ∈ V_i`) and the remote part, whose sizes
//!   are the quantities of the paper's Fig 8-10 memory argument;
//! * per-thread [`ThreadEdges`] — each compute thread owns a contiguous
//!   range of local posts and a private edge store holding **only** the
//!   edges targeting those posts, as a CSR over pre index with runs
//!   sorted by delay (paper Fig 12b: "synaptic interactions reordered
//!   according to their delays and the corresponding threads"). During
//!   delivery a thread walks just its own run for each spiking pre:
//!   every write lands in thread-owned state — no mutex, no atomic.

use crate::atlas::NetworkSpec;
use crate::graph::Edge;
use crate::metrics::memory::{vec_bytes, MemoryBreakdown};
use crate::{DelaySteps, Gid, ThreadId};

/// One compute thread's private share of the rank's indegree sub-graph.
#[derive(Clone, Debug, Default)]
pub struct ThreadEdges {
    /// CSR offsets over the rank's `pres` array: edges of pre `p` owned by
    /// this thread live at `post[offsets[p]..offsets[p+1]]`, delay-sorted.
    pub offsets: Vec<u32>,
    /// Local post index (into the rank's `posts`).
    pub post: Vec<u32>,
    pub weight: Vec<f64>,
    pub delay: Vec<DelaySteps>,
    /// Plastic-edge marker (present only for STDP networks).
    pub plastic: Vec<bool>,
    /// Pre index of each edge (present only for STDP networks, where the
    /// potentiation path walks a post's incoming edges and needs their
    /// sources' traces).
    pub epre: Vec<u32>,
    /// CSR by local post over this thread's *plastic* edges (potentiation
    /// walks a post's incoming plastic edges when it fires): offsets are
    /// relative to the thread's post range `[post_lo, post_hi)`.
    pub plastic_by_post_offsets: Vec<u32>,
    pub plastic_by_post_edge: Vec<u32>,
    /// Owned local post range.
    pub post_lo: u32,
    pub post_hi: u32,
}

impl ThreadEdges {
    pub fn n_edges(&self) -> usize {
        self.post.len()
    }

    pub fn bytes(&self) -> u64 {
        vec_bytes(&self.offsets)
            + vec_bytes(&self.post)
            + vec_bytes(&self.weight)
            + vec_bytes(&self.delay)
            + vec_bytes(&self.plastic)
            + vec_bytes(&self.epre)
            + vec_bytes(&self.plastic_by_post_offsets)
            + vec_bytes(&self.plastic_by_post_edge)
    }

    /// Edge run of pre index `p` (delay-sorted).
    #[inline]
    pub fn run(&self, p: usize) -> std::ops::Range<usize> {
        self.offsets[p] as usize..self.offsets[p + 1] as usize
    }
}

/// Owning thread of `local_post` under the contiguous equal split of
/// `n_posts` posts over `n_threads` threads (`lo_t = ⌊t·n/T⌋`).
///
/// O(1) closed-form inverse of the range table: the arithmetic guess
/// `⌊p·T/n⌋` is exact or one below the owner whenever `n >= T`, and the
/// correction loops walk the (then possibly empty) ranges otherwise.
/// Replaces the linear `position()` scan that sat on the per-spike
/// collection path and on every staged edge during store construction.
#[inline]
pub fn owner_of(local_post: u32, n_posts: usize, n_threads: usize) -> ThreadId {
    debug_assert!(n_threads >= 1);
    debug_assert!((local_post as usize) < n_posts);
    let p = local_post as usize;
    let mut t = (p as u64 * n_threads as u64 / n_posts as u64) as usize;
    // correct the floor-division guess onto the owning half-open range
    while (t + 1) * n_posts / n_threads <= p {
        t += 1;
    }
    while t * n_posts / n_threads > p {
        t -= 1;
    }
    t as ThreadId
}

/// The rank's full data instance.
#[derive(Clone, Debug)]
pub struct RankStore {
    pub rank: u16,
    /// Owned posts, ascending gid; local index = position here.
    pub posts: Vec<Gid>,
    /// All sources with edges onto this rank, ascending gid;
    /// pre index = position here.
    pub pres: Vec<Gid>,
    /// Number of `pres` that are also owned posts (the local part of
    /// eq. 16; `pres.len() - n_local_pres` is the remote part).
    pub n_local_pres: usize,
    /// Edges arriving from local sources (the `in-S^l` of eq. 16).
    pub n_local_edges: u64,
    pub n_remote_edges: u64,
    pub threads: Vec<ThreadEdges>,
    /// thread → owned local post range.
    pub thread_ranges: Vec<(u32, u32)>,
    pub max_delay: DelaySteps,
    /// Analytic heap bytes of the posts' neuron-model state (per-model
    /// SoA layout × population sizes). Reported by [`Self::memory`]
    /// until the live state blocks move into the engine's worker
    /// contexts, which then report their actual bytes.
    pub state_bytes: u64,
}

impl RankStore {
    /// Build the store for `rank`, generating exactly the rank's own
    /// indegree sub-graph from the deterministic spec (no global state).
    pub fn build(
        spec: &NetworkSpec,
        posts: &[Gid],
        is_local: impl Fn(Gid) -> bool,
        rank: u16,
        n_threads: usize,
    ) -> RankStore {
        assert!(n_threads >= 1);
        let n_posts = posts.len();
        let plastic_net = spec.stdp.is_some();

        // thread ranges: contiguous equal split of local posts
        let thread_ranges: Vec<(u32, u32)> = (0..n_threads)
            .map(|t| {
                (
                    (t * n_posts / n_threads) as u32,
                    ((t + 1) * n_posts / n_threads) as u32,
                )
            })
            .collect();
        let thread_of =
            |local_post: u32| -> ThreadId { owner_of(local_post, n_posts, n_threads) };

        // generate the indegree sub-graph: all incoming edges of our posts
        let mut edges: Vec<Edge> = Vec::new();
        for &gid in posts {
            spec.in_edges(gid, &mut edges);
        }

        // pres = sorted unique sources
        let mut pres: Vec<Gid> = edges.iter().map(|e| e.pre).collect();
        pres.sort_unstable();
        pres.dedup();
        pres.shrink_to_fit(); // dedup leaves the pre-dedup capacity
        let n_local_pres = pres.iter().filter(|&&p| is_local(p)).count();

        let pre_index = |gid: Gid| -> u32 {
            pres.binary_search(&gid).expect("pre not in table") as u32
        };
        let post_index = |gid: Gid| -> u32 {
            posts.binary_search(&gid).expect("post not in table") as u32
        };

        let mut n_local_edges = 0u64;
        let mut n_remote_edges = 0u64;
        let mut max_delay: DelaySteps = 1;

        // (thread, pre, delay)-sorted staging: one bucket per thread
        struct Staged {
            pre: u32,
            post: u32,
            weight: f64,
            delay: DelaySteps,
            plastic: bool,
        }
        let mut staged: Vec<Vec<Staged>> =
            (0..n_threads).map(|_| Vec::new()).collect();
        for e in &edges {
            let lp = post_index(e.post);
            let t = thread_of(lp) as usize;
            if is_local(e.pre) {
                n_local_edges += 1;
            } else {
                n_remote_edges += 1;
            }
            max_delay = max_delay.max(e.delay);
            staged[t].push(Staged {
                pre: pre_index(e.pre),
                post: lp,
                weight: e.weight,
                delay: e.delay,
                plastic: plastic_net && spec.edge_plastic(e.pre, e.post),
            });
        }
        drop(edges);

        let threads: Vec<ThreadEdges> = staged
            .into_iter()
            .enumerate()
            .map(|(t, mut st)| {
                // paper Fig 12b: sort by (pre, delay) within the thread.
                // Stable + cached key: multapse ties keep generation
                // order, so delivery's per-slot addition order matches
                // the baseline engine's (spike-exact comparability).
                st.sort_by_cached_key(|s| {
                    ((s.pre as u64) << 16) | s.delay as u64
                });
                let mut offsets = vec![0u32; pres.len() + 1];
                for s in &st {
                    offsets[s.pre as usize + 1] += 1;
                }
                for i in 0..pres.len() {
                    offsets[i + 1] += offsets[i];
                }
                let post: Vec<u32> = st.iter().map(|s| s.post).collect();
                let weight: Vec<f64> = st.iter().map(|s| s.weight).collect();
                let delay: Vec<DelaySteps> =
                    st.iter().map(|s| s.delay).collect();
                let plastic: Vec<bool> = if plastic_net {
                    st.iter().map(|s| s.plastic).collect()
                } else {
                    Vec::new()
                };
                let epre: Vec<u32> = if plastic_net {
                    st.iter().map(|s| s.pre).collect()
                } else {
                    Vec::new()
                };

                // plastic-by-post CSR (potentiation path)
                let (lo, hi) = thread_ranges[t];
                let span = (hi - lo) as usize;
                let (pbp_off, pbp_edge) = if plastic_net {
                    let mut off = vec![0u32; span + 1];
                    for s in &st {
                        if s.plastic {
                            off[(s.post - lo) as usize + 1] += 1;
                        }
                    }
                    for i in 0..span {
                        off[i + 1] += off[i];
                    }
                    let mut cursor = off.clone();
                    let mut idx = vec![0u32; off[span] as usize];
                    for (ei, s) in st.iter().enumerate() {
                        if s.plastic {
                            let b = (s.post - lo) as usize;
                            idx[cursor[b] as usize] = ei as u32;
                            cursor[b] += 1;
                        }
                    }
                    (off, idx)
                } else {
                    (Vec::new(), Vec::new())
                };

                ThreadEdges {
                    offsets,
                    post,
                    weight,
                    delay,
                    plastic,
                    epre,
                    plastic_by_post_offsets: pbp_off,
                    plastic_by_post_edge: pbp_edge,
                    post_lo: lo,
                    post_hi: hi,
                }
            })
            .collect();

        let state_bytes: u64 = posts
            .iter()
            .map(|&g| {
                spec.params[spec.pidx(g) as usize].state_bytes_per_neuron()
            })
            .sum();

        RankStore {
            rank,
            posts: posts.to_vec(),
            pres,
            n_local_pres,
            n_local_edges,
            n_remote_edges,
            threads,
            thread_ranges,
            max_delay,
            state_bytes,
        }
    }

    pub fn n_posts(&self) -> usize {
        self.posts.len()
    }

    pub fn n_pres(&self) -> usize {
        self.pres.len()
    }

    pub fn n_remote_pres(&self) -> usize {
        self.pres.len() - self.n_local_pres
    }

    pub fn n_edges(&self) -> u64 {
        self.n_local_edges + self.n_remote_edges
    }

    /// Pre index of a gid if any of our edges source from it.
    #[inline]
    pub fn pre_index_of(&self, gid: Gid) -> Option<u32> {
        self.pres.binary_search(&gid).ok().map(|i| i as u32)
    }

    /// Local post index of an owned gid.
    #[inline]
    pub fn post_index_of(&self, gid: Gid) -> Option<u32> {
        self.posts.binary_search(&gid).ok().map(|i| i as u32)
    }

    /// Owning thread of a local post index (O(1) on the equal split).
    #[inline]
    pub fn thread_of(&self, local_post: u32) -> ThreadId {
        owner_of(local_post, self.n_posts(), self.thread_ranges.len())
    }

    /// Move the per-thread edge stores out (engine construction hands
    /// each one to its permanently-owning worker; see `engine::workers`).
    /// Rank-level structure (`posts`, `pres`, ranges, counts) stays.
    pub fn take_threads(&mut self) -> Vec<ThreadEdges> {
        std::mem::take(&mut self.threads)
    }

    /// Memory accounting for the Fig 18 / Fig 9-10 benches. Neuron-model
    /// state is included analytically while this store still owns the
    /// per-thread shares; after [`Self::take_threads`] the worker
    /// contexts own both edges and state and report their actual bytes
    /// (so `RankEngine::memory` never double-counts).
    pub fn memory(&self) -> MemoryBreakdown {
        let mut m = MemoryBreakdown::new();
        m.add("posts", vec_bytes(&self.posts));
        m.add("pres", vec_bytes(&self.pres));
        if !self.threads.is_empty() {
            m.add("state", self.state_bytes);
        }
        for t in &self.threads {
            m.add("edges", t.bytes());
        }
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::atlas::random_spec;
    use crate::decomp::random_equivalent_partition;
    use crate::util::proptest_lite::property;

    fn build_stores(
        n: usize,
        k: u32,
        ranks: usize,
        threads: usize,
        seed: u64,
    ) -> (crate::atlas::NetworkSpec, Vec<RankStore>) {
        let spec = random_spec(n, k, seed);
        let part = random_equivalent_partition(n, ranks, seed);
        let stores = (0..ranks)
            .map(|r| {
                let rank_of = part.rank_of.clone();
                RankStore::build(
                    &spec,
                    &part.members[r],
                    move |g| rank_of[g as usize] as usize == r,
                    r as u16,
                    threads,
                )
            })
            .collect();
        (spec, stores)
    }

    #[test]
    fn edges_conserved_across_ranks() {
        let (spec, stores) = build_stores(400, 40, 3, 2, 1);
        let total: u64 = stores.iter().map(|s| s.n_edges()).sum();
        assert_eq!(total, spec.n_edges());
    }

    #[test]
    fn thread_write_sets_disjoint_and_covering() {
        let (_, stores) = build_stores(300, 30, 2, 3, 2);
        for s in &stores {
            // ranges tile [0, n_posts)
            let mut expect = 0u32;
            for &(lo, hi) in &s.thread_ranges {
                assert_eq!(lo, expect);
                expect = hi;
            }
            assert_eq!(expect as usize, s.n_posts());
            // every edge's post lies in its thread's range — the no-race
            // invariant of paper §III.B.1
            for (t, te) in s.threads.iter().enumerate() {
                let (lo, hi) = s.thread_ranges[t];
                assert!(te
                    .post
                    .iter()
                    .all(|&p| p >= lo && p < hi));
            }
        }
    }

    #[test]
    fn runs_are_delay_sorted() {
        let (_, stores) = build_stores(300, 30, 2, 3, 3);
        for s in &stores {
            for te in &s.threads {
                for p in 0..s.pres.len() {
                    let r = te.run(p);
                    let ds = &te.delay[r];
                    assert!(
                        ds.windows(2).all(|w| w[0] <= w[1]),
                        "run not delay-sorted"
                    );
                }
            }
        }
    }

    #[test]
    fn pres_exactly_the_sources() {
        let (spec, stores) = build_stores(200, 25, 2, 2, 4);
        for s in &stores {
            // every pre has >= 1 edge in some thread
            for (pi, _) in s.pres.iter().enumerate() {
                let total: usize =
                    s.threads.iter().map(|t| t.run(pi).len()).sum();
                assert!(total > 0, "pre with no edges");
            }
            // and conversely every generated edge's source is in pres
            let mut edges = Vec::new();
            for &g in &s.posts {
                spec.in_edges(g, &mut edges);
            }
            for e in &edges {
                assert!(s.pre_index_of(e.pre).is_some());
            }
        }
    }

    #[test]
    fn local_remote_split_consistent() {
        let (_, stores) = build_stores(300, 30, 3, 2, 5);
        for s in &stores {
            assert!(s.n_local_pres <= s.n_pres());
            assert_eq!(
                s.n_edges(),
                s.threads.iter().map(|t| t.n_edges() as u64).sum::<u64>()
            );
        }
    }

    #[test]
    fn memory_breakdown_nonzero() {
        let (_, stores) = build_stores(200, 20, 2, 2, 6);
        let m = stores[0].memory();
        assert!(m.get("edges") > 0);
        assert!(m.get("posts") > 0);
        // neuron-model state accounted: LIF = 33 B/neuron
        assert_eq!(m.get("state"), 33 * stores[0].n_posts() as u64);
        assert!(m.total() > m.get("edges"));
    }

    #[test]
    fn state_bytes_follow_population_models() {
        use crate::atlas::random_spec_with;
        use crate::model::{AdexParams, LifParams, ModelParams};
        let spec = random_spec_with(
            200,
            20,
            6,
            ModelParams::Adex(AdexParams::default()),
            ModelParams::Lif(LifParams::default()),
        );
        let posts: Vec<u32> = (0..200).collect();
        let store = RankStore::build(&spec, &posts, |_| true, 0, 2);
        // 160 AdEx × 40 B + 40 LIF × 33 B
        assert_eq!(store.state_bytes, 160 * 40 + 40 * 33);
        assert_eq!(store.memory().get("state"), store.state_bytes);
    }

    #[test]
    fn owner_of_matches_linear_scan() {
        // the O(1) arithmetic must agree with a scan of the range table
        // for every post, including degenerate splits (n < threads, where
        // some ranges are empty)
        for &(n, threads) in &[
            (1usize, 1usize),
            (1, 4),
            (3, 4),
            (7, 3),
            (100, 1),
            (100, 3),
            (101, 7),
            (1000, 13),
        ] {
            let ranges: Vec<(u32, u32)> = (0..threads)
                .map(|t| {
                    (
                        (t * n / threads) as u32,
                        ((t + 1) * n / threads) as u32,
                    )
                })
                .collect();
            for p in 0..n as u32 {
                let want = ranges
                    .iter()
                    .position(|&(lo, hi)| p >= lo && p < hi)
                    .expect("post uncovered") as ThreadId;
                assert_eq!(
                    owner_of(p, n, threads),
                    want,
                    "n={n} threads={threads} p={p}"
                );
            }
        }
    }

    #[test]
    fn thread_of_agrees_with_ranges_after_take() {
        let (_, mut stores) = build_stores(157, 12, 1, 5, 8);
        let s = &mut stores[0];
        let ranges = s.thread_ranges.clone();
        for p in 0..s.n_posts() as u32 {
            let t = s.thread_of(p) as usize;
            assert!(p >= ranges[t].0 && p < ranges[t].1);
        }
        // taking the thread stores must not break the O(1) lookup
        let taken = s.take_threads();
        assert_eq!(taken.len(), 5);
        assert!(s.threads.is_empty());
        assert_eq!(s.thread_of(0), owner_of(0, s.n_posts(), ranges.len()));
    }

    #[test]
    fn property_store_invariants() {
        property("rank store invariants", 15, |g| {
            let n = g.usize(50..400);
            let k = g.u32(1..30.min(n as u32));
            let ranks = g.usize(1..5);
            let threads = g.usize(1..4);
            let (spec, stores) =
                build_stores(n, k, ranks, threads, g.case as u64 + 50);
            let total: u64 = stores.iter().map(|s| s.n_edges()).sum();
            if total != spec.n_edges() {
                return Err(format!(
                    "edge conservation {total} != {}",
                    spec.n_edges()
                ));
            }
            for s in &stores {
                if s.threads.len() != threads {
                    return Err("thread count".into());
                }
                for te in &s.threads {
                    if *te.offsets.last().unwrap() as usize != te.post.len() {
                        return Err("csr tail mismatch".into());
                    }
                }
            }
            Ok(())
        });
    }
}
