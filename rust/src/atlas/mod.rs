//! Connectome builders ("the Atlas", paper §III.A.1 and Fig 7).
//!
//! A [`NetworkSpec`] describes populations (per brain area), connection
//! rules, neuron parameters, positions and external drive. Everything
//! downstream — edges, positions, initial membrane potentials — is a
//! **deterministic function of (seed, gid)**: edges are generated
//! *post-synaptically* (`in_edges`), so any rank can materialise exactly
//! its own indegree sub-graph without ever touching the full network.
//! That is the constructive counterpart of the paper's indegree sub-graph
//! decomposition, and it also makes the realised network independent of
//! rank/thread counts and mapping strategy (the test suite's spike-exact
//! engine comparisons rely on it).
//!
//! Builders:
//! - [`marmoset::marmoset_spec`] — synthetic multi-area cortex standing in
//!   for the paper's marmoset connectome (see DESIGN.md §2 substitutions),
//! - [`potjans::potjans_spec`] — Potjans-Diesmann 2014 microcircuit (the
//!   paper's internal-architecture reference [30]),
//! - [`hpc::hpc_benchmark_spec`] — NEST hpc_benchmark verification network
//!   (balanced random + STDP),
//! - [`custom::custom_spec`] — TOML-described populations with
//!   per-population neuron models (LIF / AdEx / HH / parrot),
//! - [`random_spec`] — uniform random network for unit tests.
//!
//! Every builder fills the spec's **model parameter table**
//! (`params: Vec<ModelParams>`); populations reference entries by index
//! and additionally carry their [`NeuronModel`] tag, so mixed circuits
//! (AdEx excitatory over LIF inhibitory, parrot stimulus relays, …) are
//! ordinary specs.

pub mod custom;
pub mod hpc;
pub mod marmoset;
pub mod potjans;

use crate::graph::{DiGraph, Edge};
use crate::model::dynamics::{ModelParams, ModelTables, NeuronModel};
use crate::model::{LifParams, PoissonDrive, Propagators, StdpParams};
use crate::util::rng::{hash_stream, Rng};
use crate::{DelaySteps, Gid};

/// Stream tags (must never collide across purposes).
const TAG_CONN: u64 = 0x434f4e4e; // "CONN"
const TAG_VINIT: u64 = 0x56494e49; // "VINI"
const TAG_POS: u64 = 0x504f5321; // "POS!"

/// A homogeneous group of neurons within one area.
#[derive(Clone, Debug)]
pub struct Population {
    pub name: String,
    pub area: u16,
    pub first_gid: Gid,
    pub n: u32,
    /// Index into `NetworkSpec::params`.
    pub params: u8,
    /// Neuron model this population runs (must match the variant of its
    /// `params` entry; validated by [`NetworkSpec::new`]).
    pub model: NeuronModel,
    /// Excitatory (outgoing weights > 0) or inhibitory.
    pub exc: bool,
    pub drive: PoissonDrive,
}

impl Population {
    pub fn gids(&self) -> std::ops::Range<Gid> {
        self.first_gid..self.first_gid + self.n
    }
}

/// Fixed-indegree connection rule: every neuron of `dst_pop` receives
/// exactly `indegree` synapses from uniformly drawn `src_pop` neurons
/// (multapses allowed, autapses excluded — NEST `fixed_indegree` style).
#[derive(Clone, Debug)]
pub struct ConnRule {
    pub src_pop: u16,
    pub dst_pop: u16,
    pub indegree: u32,
    /// Mean weight [pA]; sign must match the source population's type.
    pub weight_mean: f64,
    /// Relative standard deviation of the weight (clipped to keep sign).
    pub weight_rel_sd: f64,
    /// Mean delay [ms].
    pub delay_mean_ms: f64,
    /// Relative standard deviation of the delay.
    pub delay_rel_sd: f64,
    /// STDP-plastic edges (the verification case's E→E synapses).
    pub plastic: bool,
}

/// Per-area spatial layout: neurons are placed around the area centre.
#[derive(Clone, Debug)]
pub struct AreaGeometry {
    pub name: String,
    /// Centre in mm.
    pub center: [f64; 3],
    /// Per-axis uniform spread in mm.
    pub spread: f64,
}

/// Complete, deterministic network description.
#[derive(Clone, Debug)]
pub struct NetworkSpec {
    pub name: String,
    pub seed: u64,
    pub dt_ms: f64,
    /// Model parameter table; populations reference entries by index.
    pub params: Vec<ModelParams>,
    pub populations: Vec<Population>,
    pub rules: Vec<ConnRule>,
    pub areas: Vec<AreaGeometry>,
    pub stdp: Option<StdpParams>,
    /// Uniform jitter added to the resting potential at t=0, [lo, hi) mV.
    pub v_init_jitter: (f64, f64),
    /// Global lower bound on synaptic delays (steps). This is the
    /// communication window: spikes are exchanged once per
    /// `min_delay_steps` steps, and the exchange of window k may overlap
    /// the computation of window k+1 (paper §III.C / Fig 16) precisely
    /// because no synapse can deliver sooner. `in_edges` clamps delays
    /// to this floor.
    pub min_delay_steps: DelaySteps,
    /// Per-rule cache: rules targeting each population (built lazily).
    rules_by_dst: Vec<Vec<u32>>,
    /// (src_pop, dst_pop) → any plastic rule connects the pair. Replaces
    /// the O(rules) scan [`Self::edge_plastic`] used to do per edge — the
    /// store builders query this once per generated edge.
    plastic_pairs: Vec<bool>,
}

impl NetworkSpec {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        name: impl Into<String>,
        seed: u64,
        dt_ms: f64,
        params: Vec<ModelParams>,
        populations: Vec<Population>,
        rules: Vec<ConnRule>,
        areas: Vec<AreaGeometry>,
        stdp: Option<StdpParams>,
    ) -> Self {
        // validate gid layout is contiguous and rules reference real pops
        let mut next = 0;
        for p in &populations {
            assert_eq!(p.first_gid, next, "populations must tile gid space");
            next += p.n;
            assert!((p.params as usize) < params.len());
            assert!((p.area as usize) < areas.len());
            assert_eq!(
                params[p.params as usize].model(),
                p.model,
                "population {} model tag disagrees with its params entry",
                p.name
            );
        }
        for r in &rules {
            assert!((r.src_pop as usize) < populations.len());
            assert!((r.dst_pop as usize) < populations.len());
            let src = &populations[r.src_pop as usize];
            assert!(
                (r.weight_mean >= 0.0) == src.exc,
                "weight sign must match source population type ({})",
                src.name
            );
            assert!(r.delay_mean_ms >= dt_ms, "delay below one step");
        }
        let mut rules_by_dst = vec![Vec::new(); populations.len()];
        for (i, r) in rules.iter().enumerate() {
            rules_by_dst[r.dst_pop as usize].push(i as u32);
        }
        let n_pops = populations.len();
        let mut plastic_pairs = vec![false; n_pops * n_pops];
        for r in rules.iter().filter(|r| r.plastic) {
            plastic_pairs
                [r.src_pop as usize * n_pops + r.dst_pop as usize] = true;
        }
        NetworkSpec {
            name: name.into(),
            seed,
            dt_ms,
            params,
            populations,
            rules,
            areas,
            stdp,
            v_init_jitter: (0.0, 5.0),
            min_delay_steps: 2,
            rules_by_dst,
            plastic_pairs,
        }
    }

    pub fn n_total(&self) -> usize {
        self.populations.iter().map(|p| p.n as usize).sum()
    }

    pub fn n_areas(&self) -> usize {
        self.areas.len()
    }

    /// Expected total edge count (exact: fixed indegree × dst sizes).
    pub fn n_edges(&self) -> u64 {
        self.rules
            .iter()
            .map(|r| {
                r.indegree as u64
                    * self.populations[r.dst_pop as usize].n as u64
            })
            .sum()
    }

    /// Indices of every population named `name` (multi-area atlases may
    /// reuse a name across areas). Used by the session API's name-based
    /// stimulus and probe targeting.
    pub fn pops_named(&self, name: &str) -> Vec<u16> {
        self.populations
            .iter()
            .enumerate()
            .filter(|(_, p)| p.name == name)
            .map(|(i, _)| i as u16)
            .collect()
    }

    /// Population index of a gid (binary search over contiguous ranges).
    pub fn pop_of(&self, gid: Gid) -> u16 {
        let i = self
            .populations
            .partition_point(|p| p.first_gid + p.n <= gid);
        assert!(i < self.populations.len(), "gid {gid} out of range");
        i as u16
    }

    pub fn area_of(&self, gid: Gid) -> u16 {
        self.populations[self.pop_of(gid) as usize].area
    }

    /// Deterministic 3D position (mm) of a neuron.
    pub fn position(&self, gid: Gid) -> [f64; 3] {
        let area = &self.areas[self.area_of(gid) as usize];
        let mut rng = Rng::new(hash_stream(&[self.seed, TAG_POS, gid as u64]));
        [
            area.center[0] + rng.range_f64(-area.spread, area.spread),
            area.center[1] + rng.range_f64(-area.spread, area.spread),
            area.center[2] + rng.range_f64(-area.spread, area.spread),
        ]
    }

    /// Deterministic initial membrane potential (around the model's
    /// resting potential; meaningless-but-harmless for parrot relays).
    pub fn v_init(&self, gid: Gid) -> f64 {
        let p = &self.params
            [self.populations[self.pop_of(gid) as usize].params as usize];
        let mut rng =
            Rng::new(hash_stream(&[self.seed, TAG_VINIT, gid as u64]));
        p.rest_potential()
            + rng.range_f64(self.v_init_jitter.0, self.v_init_jitter.1)
    }

    /// Deterministically generate all incoming edges of `gid`, calling
    /// `f(edge, src_pop)` for each without materialising any list. This
    /// is the constructive indegree sub-graph in streaming form: the
    /// two-pass store builder visits a post's edges twice (count, then
    /// fill) and never holds them in bulk. The source-population index
    /// rides along because the visitor knows it for free (edges are
    /// generated per rule) and it keys the plasticity lookup.
    pub fn for_each_in_edge(
        &self,
        gid: Gid,
        mut f: impl FnMut(Edge, u16),
    ) {
        let dst_pop = self.pop_of(gid);
        let max_delay_steps = u16::MAX as f64;
        for &ri in &self.rules_by_dst[dst_pop as usize] {
            let r = &self.rules[ri as usize];
            let src = &self.populations[r.src_pop as usize];
            let mut rng = Rng::new(hash_stream(&[
                self.seed,
                TAG_CONN,
                ri as u64,
                gid as u64,
            ]));
            for _ in 0..r.indegree {
                // uniform source, excluding autapse
                let mut pre =
                    src.first_gid + rng.below(src.n as u64) as Gid;
                if pre == gid {
                    pre = src.first_gid
                        + ((pre - src.first_gid + 1) % src.n);
                    if pre == gid {
                        continue; // single-neuron population: skip autapse
                    }
                }
                // weight: normal, clipped to keep the source's sign
                let w_raw = rng.normal_ms(
                    r.weight_mean,
                    r.weight_mean.abs() * r.weight_rel_sd,
                );
                let weight = if src.exc {
                    w_raw.max(0.0)
                } else {
                    w_raw.min(0.0)
                };
                // delay: normal, clipped to [min_delay, u16::MAX steps]
                let d_ms = rng
                    .normal_ms(r.delay_mean_ms, r.delay_mean_ms * r.delay_rel_sd)
                    .max(self.dt_ms);
                let delay = ((d_ms / self.dt_ms).round() as f64)
                    .clamp(self.min_delay_steps as f64, max_delay_steps)
                    as DelaySteps;
                f(Edge { pre, post: gid, weight, delay }, r.src_pop);
            }
        }
    }

    /// [`Self::for_each_in_edge`] in `Vec`-appending form (small
    /// networks, the serial ablation builder, tests).
    pub fn in_edges(&self, gid: Gid, out: &mut Vec<Edge>) {
        self.for_each_in_edge(gid, |e, _| out.push(e));
    }

    /// Is the rule feeding this edge plastic? Recomputed from (pre, post)
    /// population types — only used by plastic networks.
    pub fn edge_plastic(&self, pre: Gid, post: Gid) -> bool {
        self.pair_plastic(self.pop_of(pre), self.pop_of(post))
    }

    /// Does any plastic rule connect `src_pop → dst_pop`? O(1) via the
    /// table precomputed in [`Self::new`]; the hot query of store
    /// construction on plastic networks.
    #[inline]
    pub fn pair_plastic(&self, src_pop: u16, dst_pop: u16) -> bool {
        self.plastic_pairs
            [src_pop as usize * self.populations.len() + dst_pop as usize]
    }

    /// External drive of a neuron.
    pub fn drive(&self, gid: Gid) -> PoissonDrive {
        self.populations[self.pop_of(gid) as usize].drive
    }

    /// LIF propagator table, aligned with the parameter table (non-LIF
    /// slots hold default-parameter propagators and are never indexed by
    /// a LIF block).
    pub fn lif_propagators(&self) -> Vec<Propagators> {
        self.params
            .iter()
            .map(|p| match p {
                ModelParams::Lif(lp) => Propagators::new(lp, self.dt_ms),
                _ => Propagators::new(&LifParams::default(), self.dt_ms),
            })
            .collect()
    }

    /// The engine's per-worker dispatch tables.
    pub fn model_tables(&self) -> ModelTables {
        ModelTables {
            dt_ms: self.dt_ms,
            lif_props: self.lif_propagators(),
            params: self.params.clone(),
        }
    }

    /// True when every population runs LIF (the PJRT backend and the
    /// NEST-style baseline support only this case).
    pub fn all_lif(&self) -> bool {
        self.populations.iter().all(|p| p.model == NeuronModel::Lif)
    }

    /// Parameter-table index of a neuron.
    pub fn pidx(&self, gid: Gid) -> u8 {
        self.populations[self.pop_of(gid) as usize].params
    }

    /// Neuron model of a gid.
    pub fn model_of(&self, gid: Gid) -> NeuronModel {
        self.populations[self.pop_of(gid) as usize].model
    }

    /// Upper bound on delays in steps (used to size ring buffers) — scans
    /// rule stats instead of materialising edges.
    pub fn max_delay_steps(&self) -> DelaySteps {
        let worst = self
            .rules
            .iter()
            .map(|r| r.delay_mean_ms * (1.0 + 6.0 * r.delay_rel_sd))
            .fold(1.0, f64::max);
        ((worst / self.dt_ms).ceil() as u32).clamp(1, u16::MAX as u32)
            as DelaySteps
    }

    /// Materialise the whole network as a [`DiGraph`] (small networks /
    /// tests / the sub-graph algebra cross-checks only).
    pub fn build_digraph(&self) -> DiGraph {
        let n = self.n_total();
        let mut edges = Vec::with_capacity(self.n_edges() as usize);
        for gid in 0..n as Gid {
            self.in_edges(gid, &mut edges);
        }
        DiGraph::new(n, edges)
    }

    /// Euclidean distance between two area centres (mm).
    pub fn area_distance(&self, a: u16, b: u16) -> f64 {
        let ca = self.areas[a as usize].center;
        let cb = self.areas[b as usize].center;
        ((ca[0] - cb[0]).powi(2) + (ca[1] - cb[1]).powi(2)
            + (ca[2] - cb[2]).powi(2))
        .sqrt()
    }
}

/// Intern `p` into a parameter table, returning its index. Identical
/// entries collapse to one slot, so a builder can offer per-population
/// models without bloating the table in the homogeneous case.
pub fn intern_params(params: &mut Vec<ModelParams>, p: ModelParams) -> u8 {
    if let Some(i) = params.iter().position(|q| *q == p) {
        return i as u8;
    }
    assert!(params.len() < u8::MAX as usize, "parameter table overflow");
    params.push(p);
    (params.len() - 1) as u8
}

/// Uniform random network over one excitatory + one inhibitory population
/// (unit tests and micro-benches).
pub fn random_spec(n: usize, indegree: u32, seed: u64) -> NetworkSpec {
    let lif = ModelParams::Lif(LifParams::default());
    random_spec_with(n, indegree, seed, lif, lif)
}

/// [`random_spec`] with explicit neuron models per population type.
pub fn random_spec_with(
    n: usize,
    indegree: u32,
    seed: u64,
    model_e: ModelParams,
    model_i: ModelParams,
) -> NetworkSpec {
    let ne = (n * 4 / 5) as u32;
    let ni = (n - n * 4 / 5) as u32;
    let mut params = Vec::new();
    let pe = intern_params(&mut params, model_e);
    let pi = intern_params(&mut params, model_i);
    let drive = PoissonDrive::new(8000.0, 87.8);
    let populations = vec![
        Population {
            name: "E".into(),
            area: 0,
            first_gid: 0,
            n: ne,
            params: pe,
            model: model_e.model(),
            exc: true,
            drive,
        },
        Population {
            name: "I".into(),
            area: 0,
            first_gid: ne,
            n: ni,
            params: pi,
            model: model_i.model(),
            exc: false,
            drive,
        },
    ];
    let ke = (indegree * 4) / 5;
    let ki = indegree - ke;
    let w = 87.8;
    let g = 4.0;
    let mut rules = Vec::new();
    for dst in 0..2u16 {
        rules.push(ConnRule {
            src_pop: 0,
            dst_pop: dst,
            indegree: ke,
            weight_mean: w,
            weight_rel_sd: 0.1,
            delay_mean_ms: 1.5,
            delay_rel_sd: 0.5,
            plastic: false,
        });
        rules.push(ConnRule {
            src_pop: 1,
            dst_pop: dst,
            indegree: ki,
            weight_mean: -g * w,
            weight_rel_sd: 0.1,
            delay_mean_ms: 0.8,
            delay_rel_sd: 0.5,
            plastic: false,
        });
    }
    let areas = vec![AreaGeometry {
        name: "A0".into(),
        center: [0.0; 3],
        spread: 1.0,
    }];
    NetworkSpec::new("random", seed, 0.1, params, populations, rules, areas, None)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest_lite::property;

    #[test]
    fn gid_layout_and_pop_lookup() {
        let s = random_spec(1000, 100, 1);
        assert_eq!(s.n_total(), 1000);
        assert_eq!(s.pop_of(0), 0);
        assert_eq!(s.pop_of(799), 0);
        assert_eq!(s.pop_of(800), 1);
        assert_eq!(s.pop_of(999), 1);
    }

    #[test]
    fn in_edges_deterministic_and_exact_indegree() {
        let s = random_spec(500, 50, 7);
        let mut a = Vec::new();
        s.in_edges(123, &mut a);
        let mut b = Vec::new();
        s.in_edges(123, &mut b);
        assert_eq!(a, b);
        assert_eq!(a.len(), 50);
        assert!(a.iter().all(|e| e.post == 123));
        assert!(a.iter().all(|e| e.pre != 123), "autapse found");
        assert!(a.iter().all(|e| e.delay >= s.min_delay_steps));
    }

    #[test]
    fn weight_signs_respect_population_type() {
        let s = random_spec(500, 50, 7);
        let mut edges = Vec::new();
        for gid in 0..500 {
            s.in_edges(gid, &mut edges);
        }
        for e in &edges {
            let exc = s.populations[s.pop_of(e.pre) as usize].exc;
            assert!(
                if exc { e.weight >= 0.0 } else { e.weight <= 0.0 },
                "edge {e:?}"
            );
        }
    }

    #[test]
    fn n_edges_matches_materialised_graph() {
        let s = random_spec(300, 30, 3);
        let g = s.build_digraph();
        // autapse-avoidance can only drop edges in 1-neuron pops
        assert_eq!(g.n_edges() as u64, s.n_edges());
        assert!(g.max_delay() >= g.min_delay());
        assert!(g.max_delay() <= s.max_delay_steps());
    }

    #[test]
    fn positions_and_vinit_deterministic() {
        let s = random_spec(100, 10, 9);
        assert_eq!(s.position(42), s.position(42));
        assert_ne!(s.position(42), s.position(43));
        let v = s.v_init(42);
        assert_eq!(v, s.v_init(42));
        let ModelParams::Lif(p) = &s.params[0] else { panic!() };
        assert!(v >= p.e_l && v < p.e_l + 5.0);
    }

    #[test]
    fn mixed_models_intern_and_tag_consistently() {
        use crate::model::{AdexParams, HhParams};
        let adex = ModelParams::Adex(AdexParams::default());
        let lif = ModelParams::Lif(LifParams::default());
        let s = random_spec_with(500, 50, 3, adex, lif);
        assert_eq!(s.params.len(), 2);
        assert_eq!(s.populations[0].model, NeuronModel::Adex);
        assert_eq!(s.populations[1].model, NeuronModel::Lif);
        assert_eq!(s.model_of(0), NeuronModel::Adex);
        assert_eq!(s.model_of(499), NeuronModel::Lif);
        assert!(!s.all_lif());
        assert!(random_spec(500, 50, 3).all_lif());
        // identical params collapse to one table entry
        let mut t = Vec::new();
        assert_eq!(intern_params(&mut t, lif), 0);
        assert_eq!(intern_params(&mut t, adex), 1);
        assert_eq!(intern_params(&mut t, lif), 0);
        assert_eq!(
            intern_params(&mut t, ModelParams::Hh(HhParams::default())),
            2
        );
        assert_eq!(t.len(), 3);
    }

    #[test]
    #[should_panic(expected = "model tag")]
    fn model_tag_mismatch_rejected() {
        let mut s = random_spec(100, 10, 1);
        let mut pops = std::mem::take(&mut s.populations);
        pops[0].model = NeuronModel::Adex; // params entry is Lif
        let _ = NetworkSpec::new(
            "bad",
            1,
            0.1,
            s.params.clone(),
            pops,
            s.rules.clone(),
            s.areas.clone(),
            None,
        );
    }

    #[test]
    fn visitor_and_vec_forms_agree() {
        let s = random_spec(400, 40, 5);
        let mut collected = Vec::new();
        let mut src_pops = Vec::new();
        s.for_each_in_edge(123, |e, sp| {
            collected.push(e);
            src_pops.push(sp);
        });
        let mut via_vec = Vec::new();
        s.in_edges(123, &mut via_vec);
        assert_eq!(collected, via_vec);
        // the visitor's source-population index is the edge's actual
        // source population
        for (e, &sp) in collected.iter().zip(&src_pops) {
            assert_eq!(s.pop_of(e.pre), sp);
        }
    }

    #[test]
    fn pair_plastic_table_matches_rule_scan() {
        use crate::atlas::hpc::{hpc_benchmark_spec, HpcParams};
        let s = hpc_benchmark_spec(
            &HpcParams {
                n_neurons: 200,
                indegree: 40,
                plastic: true,
                ..Default::default()
            },
            5,
        );
        let n_pops = s.populations.len() as u16;
        let mut any = false;
        for sp in 0..n_pops {
            for dp in 0..n_pops {
                let want = s.rules.iter().any(|r| {
                    r.src_pop == sp && r.dst_pop == dp && r.plastic
                });
                assert_eq!(s.pair_plastic(sp, dp), want);
                any |= want;
            }
        }
        assert!(any, "hpc_benchmark should have a plastic pair");
        // edge_plastic goes through the same table
        let e_gid = s.populations[0].first_gid;
        assert_eq!(
            s.edge_plastic(e_gid, e_gid),
            s.pair_plastic(0, 0)
        );
    }

    #[test]
    fn seed_changes_network() {
        let s1 = random_spec(200, 20, 1);
        let s2 = random_spec(200, 20, 2);
        let mut a = Vec::new();
        let mut b = Vec::new();
        s1.in_edges(50, &mut a);
        s2.in_edges(50, &mut b);
        assert_ne!(a, b);
    }

    #[test]
    fn property_indegree_and_ranges() {
        property("random_spec invariants", 25, |g| {
            let n = g.usize(10..400);
            let k = g.u32(1..(n as u32).min(40));
            let s = random_spec(n, k, g.case as u64);
            let gid = g.u32(0..n as u32);
            let mut edges = Vec::new();
            s.in_edges(gid, &mut edges);
            if edges.len() as u32 > k {
                return Err(format!("indegree {} > {k}", edges.len()));
            }
            for e in &edges {
                if e.pre as usize >= n || e.post != gid {
                    return Err(format!("bad edge {e:?}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "weight sign")]
    fn rule_sign_validation() {
        let mut s = random_spec(100, 10, 1);
        let mut rules = s.rules.clone();
        rules[0].weight_mean = -1.0; // exc source with negative weight
        let _ = NetworkSpec::new(
            "bad",
            1,
            0.1,
            s.params.clone(),
            std::mem::take(&mut s.populations),
            rules,
            s.areas.clone(),
            None,
        );
    }
}
