//! Potjans-Diesmann 2014 cortical microcircuit ("The Cell-Type Specific
//! Cortical Microcircuit", Cerebral Cortex 24(3)) — the model the paper
//! cites as the internal architecture of its marmoset simulation ([30]).
//!
//! Eight populations (layers 2/3, 4, 5, 6 × excitatory/inhibitory) with
//! the published sizes, connection-probability matrix, and layer-specific
//! external drive. Connection probabilities are converted to fixed
//! indegrees `K = round(ln(1-P) / ln(1 - 1/N_src))` (the paper's NEST
//! reference uses the same expected-multapse correction).

use super::{intern_params, AreaGeometry, ConnRule, NetworkSpec, Population};
use crate::model::dynamics::ModelParams;
use crate::model::{LifParams, PoissonDrive};

/// Published population sizes (full-scale model, 1 mm² column).
pub const POP_NAMES: [&str; 8] =
    ["L23E", "L23I", "L4E", "L4I", "L5E", "L5I", "L6E", "L6I"];
pub const POP_SIZES: [u32; 8] =
    [20_683, 5_834, 21_915, 5_479, 4_850, 1_065, 14_395, 2_948];

/// Connection probabilities P[dst][src] (Potjans & Diesmann, Table 5).
pub const CONN_PROB: [[f64; 8]; 8] = [
    // from: L23E   L23I    L4E     L4I     L5E     L5I     L6E     L6I
    [0.1009, 0.1689, 0.0437, 0.0818, 0.0323, 0.0000, 0.0076, 0.0000], // to L23E
    [0.1346, 0.1371, 0.0316, 0.0515, 0.0755, 0.0000, 0.0042, 0.0000], // to L23I
    [0.0077, 0.0059, 0.0497, 0.1350, 0.0067, 0.0003, 0.0453, 0.0000], // to L4E
    [0.0691, 0.0029, 0.0794, 0.1597, 0.0033, 0.0000, 0.1057, 0.0000], // to L4I
    [0.1004, 0.0622, 0.0505, 0.0057, 0.0831, 0.3726, 0.0204, 0.0000], // to L5E
    [0.0548, 0.0269, 0.0257, 0.0022, 0.0600, 0.3158, 0.0086, 0.0000], // to L5I
    [0.0156, 0.0066, 0.0211, 0.0166, 0.0572, 0.0197, 0.0396, 0.2252], // to L6E
    [0.0364, 0.0010, 0.0034, 0.0005, 0.0277, 0.0080, 0.0658, 0.1443], // to L6I
];

/// External (thalamo-cortical + cortico-cortical) indegrees per population.
pub const K_EXT: [u32; 8] = [1600, 1500, 2100, 1900, 2000, 1900, 2900, 2100];

/// Background rate per external synapse [Hz].
pub const BG_RATE_HZ: f64 = 8.0;

/// Published spontaneous firing rates [Hz] of the full-scale model
/// (Potjans & Diesmann Fig 6), used for downscaling compensation.
pub const TARGET_RATES_HZ: [f64; 8] =
    [0.85, 2.96, 4.39, 5.70, 7.59, 8.63, 1.09, 7.83];

/// Mean synaptic weight [pA] (≈0.15 mV PSP) and inhibition factor.
pub const W_PA: f64 = 87.8;
pub const G: f64 = 4.0;

/// Neuron models of the microcircuit's populations: one base parameter
/// set for the excitatory layers and one for the inhibitory layers.
/// Defaults reproduce the published all-LIF circuit; swapping `e` for
/// AdEx yields the mixed-model variant (adaptation on pyramidal cells
/// over fast LIF interneurons).
#[derive(Clone, Copy, Debug)]
pub struct PotjansModels {
    pub e: ModelParams,
    pub i: ModelParams,
}

impl Default for PotjansModels {
    fn default() -> Self {
        let lif = ModelParams::Lif(LifParams::default());
        PotjansModels { e: lif, i: lif }
    }
}

/// Build the microcircuit at `scale` ∈ (0, 1] of the published size.
/// Indegrees are scaled with population sizes (the "K preserved density"
/// downscaling of the original paper's supplement).
pub fn potjans_spec(scale: f64, seed: u64) -> NetworkSpec {
    potjans_spec_with(scale, seed, &PotjansModels::default())
}

/// [`potjans_spec`] with explicit neuron models. The downscaling DC
/// compensation is a LIF-propagator construct and is applied only to
/// LIF populations; non-LIF populations take their parameters verbatim.
pub fn potjans_spec_with(
    scale: f64,
    seed: u64,
    models: &PotjansModels,
) -> NetworkSpec {
    assert!(scale > 0.0 && scale <= 1.0);

    // full-scale indegrees and weights, used both for rule construction
    // (scaled) and for the downscaling compensation below
    let k_full = |dst: usize, src: usize| -> f64 {
        let p = CONN_PROB[dst][src];
        if p <= 0.0 {
            return 0.0;
        }
        let n_src = POP_SIZES[src] as f64;
        ((1.0 - p).ln() / (1.0 - 1.0 / n_src).ln()).round()
    };
    let w_of = |dst: usize, src: usize| -> f64 {
        if src % 2 == 0 {
            if src == 2 && dst == 0 { 2.0 * W_PA } else { W_PA }
        } else {
            -G * W_PA
        }
    };

    // Downscaling compensation (van Albada et al. 2015, the recipe the
    // NEST microcircuit example ships): with indegrees thinned by
    // `scale`, recurrent weights grow by 1/√scale so the *variance* of
    // the recurrent input is preserved, and a per-population DC current
    //   i_dc[d] = (1 − √scale) · Σ_src K_full·w·ν_target·τ_syn
    // restores its *mean* at the published operating point (negative in
    // the inhibition-dominated populations). External drive is kept at
    // full scale.
    let w_scale = 1.0 / scale.sqrt();
    let tau_syn_s = 0.5e-3;
    let mut params: Vec<ModelParams> = Vec::new();
    let pidx: Vec<u8> = (0..8)
        .map(|d| {
            let i_rec_full: f64 = (0..8)
                .map(|s| {
                    k_full(d, s) * w_of(d, s) * TARGET_RATES_HZ[s] * tau_syn_s
                })
                .sum();
            let base = if d % 2 == 0 { models.e } else { models.i };
            let entry = match base {
                // per-population compensated i_ext (LIF only)
                ModelParams::Lif(lp) => ModelParams::Lif(LifParams {
                    i_ext: lp.i_ext + (1.0 - scale.sqrt()) * i_rec_full,
                    ..lp
                }),
                other => other,
            };
            intern_params(&mut params, entry)
        })
        .collect();

    let mut populations = Vec::with_capacity(8);
    let mut next_gid = 0u32;
    for i in 0..8 {
        let n = ((POP_SIZES[i] as f64 * scale).round() as u32).max(5);
        let base = if i % 2 == 0 { models.e } else { models.i };
        populations.push(Population {
            name: POP_NAMES[i].into(),
            area: 0,
            first_gid: next_gid,
            n,
            params: pidx[i],
            model: base.model(),
            exc: i % 2 == 0,
            // external indegree × per-synapse rate. K_ext is NOT scaled
            // down with the network: downscaling thins the recurrent
            // indegrees, and keeping the published external drive holds
            // the operating point near the full-scale model's (the
            // standard microcircuit downscaling compensation).
            drive: PoissonDrive::new(K_EXT[i] as f64 * BG_RATE_HZ, W_PA),
        });
        next_gid += n;
    }

    let mut rules = Vec::new();
    for dst in 0..8usize {
        for src in 0..8usize {
            let p = CONN_PROB[dst][src];
            if p <= 0.0 {
                continue;
            }
            let n_src = populations[src].n as f64;
            // expected-multapse correction: K = ln(1-P)/ln(1-1/Nsrc)
            let k = ((1.0 - p).ln() / (1.0 - 1.0 / n_src).ln()).round() as u32;
            if k == 0 {
                continue;
            }
            let exc = src % 2 == 0;
            rules.push(ConnRule {
                src_pop: src as u16,
                dst_pop: dst as u16,
                indegree: k,
                weight_mean: w_of(dst, src) * w_scale,
                weight_rel_sd: 0.1,
                delay_mean_ms: if exc { 1.5 } else { 0.75 },
                delay_rel_sd: 0.5,
                plastic: false,
            });
        }
    }

    let areas = vec![AreaGeometry {
        name: "column".into(),
        center: [0.0; 3],
        spread: 0.5,
    }];
    NetworkSpec::new(
        format!("potjans-x{scale}"),
        seed,
        0.1,
        params,
        populations,
        rules,
        areas,
        None,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_scale_sizes() {
        let s = potjans_spec(1.0, 1);
        assert_eq!(s.n_total(), POP_SIZES.iter().sum::<u32>() as usize);
        assert_eq!(s.populations.len(), 8);
    }

    #[test]
    fn downscale_preserves_structure() {
        let s = potjans_spec(0.02, 1);
        assert!(s.n_total() > 1000 && s.n_total() < 2200);
        // every nonzero probability with K>=1 yields a rule
        assert!(s.rules.len() > 40, "rules {}", s.rules.len());
    }

    #[test]
    fn indegree_conversion_sane() {
        let s = potjans_spec(1.0, 1);
        // recurrent L23E->L23E: P=0.1009, Nsrc=20683 -> K ≈ 2199
        let r = s
            .rules
            .iter()
            .find(|r| r.src_pop == 0 && r.dst_pop == 0)
            .unwrap();
        assert!((r.indegree as i64 - 2199).abs() < 25, "K={}", r.indegree);
    }

    #[test]
    fn l4e_to_l23e_doubled_weight() {
        let s = potjans_spec(0.1, 1);
        let find = |src, dst| {
            s.rules
                .iter()
                .find(|r| r.src_pop == src && r.dst_pop == dst)
                .unwrap()
        };
        // doubled relative to the ordinary E weight, at any scale
        let ratio =
            find(2, 0).weight_mean / find(0, 0).weight_mean;
        assert!((ratio - 2.0).abs() < 1e-12);
        // 1/sqrt(scale) variance-preserving upscale
        let expect = W_PA / 0.1f64.sqrt();
        assert!((find(0, 0).weight_mean - expect).abs() < 1e-9);
    }

    fn lif_i_ext(s: &NetworkSpec, pop: usize) -> f64 {
        match &s.params[s.populations[pop].params as usize] {
            ModelParams::Lif(p) => p.i_ext,
            other => panic!("population {pop} is not LIF: {other:?}"),
        }
    }

    #[test]
    fn full_scale_has_no_compensation() {
        let s = potjans_spec(1.0, 1);
        assert!((0..8).all(|d| lif_i_ext(&s, d).abs() < 1e-9));
        let r = s
            .rules
            .iter()
            .find(|r| r.src_pop == 0 && r.dst_pop == 0)
            .unwrap();
        assert_eq!(r.weight_mean, W_PA);
    }

    #[test]
    fn downscale_dc_negative_for_inhibition_dominated_pops() {
        let s = potjans_spec(0.02, 1);
        // the microcircuit's recurrent mean input is inhibition-dominated
        // in most populations — compensation must inject negative DC
        let negatives =
            (0..8).filter(|&d| lif_i_ext(&s, d) < 0.0).count();
        assert!(negatives >= 6, "only {negatives} compensated negative");
    }

    #[test]
    fn mixed_model_variant_keeps_structure() {
        use crate::model::AdexParams;
        let s = potjans_spec_with(
            0.02,
            1,
            &PotjansModels {
                e: ModelParams::Adex(AdexParams::default()),
                ..Default::default()
            },
        );
        use crate::model::NeuronModel;
        for (i, p) in s.populations.iter().enumerate() {
            let want = if i % 2 == 0 {
                NeuronModel::Adex
            } else {
                NeuronModel::Lif
            };
            assert_eq!(p.model, want, "{}", p.name);
        }
        // E populations share one AdEx entry; I populations keep their
        // per-layer compensated LIF entries
        assert!(s.params.len() >= 2 && s.params.len() <= 5);
        // same connectivity rules as the all-LIF circuit
        assert_eq!(s.rules.len(), potjans_spec(0.02, 1).rules.len());
    }

    #[test]
    fn inhibitory_rules_negative() {
        let s = potjans_spec(0.1, 1);
        for r in &s.rules {
            let exc = r.src_pop % 2 == 0;
            assert_eq!(r.weight_mean > 0.0, exc);
        }
    }

    #[test]
    fn zero_probability_pairs_have_no_rule() {
        let s = potjans_spec(1.0, 1);
        // L5I (pop 5) projects only to L5E/L5I/L6E in the table
        let targets: Vec<u16> = s
            .rules
            .iter()
            .filter(|r| r.src_pop == 5)
            .map(|r| r.dst_pop)
            .collect();
        assert!(!targets.contains(&0), "L5I->L23E must not exist");
    }
}
