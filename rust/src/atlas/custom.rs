//! Custom network builder: populations (sizes, neuron models, E/I type)
//! described directly in the experiment TOML, wired into a Brunel-style
//! recurrent scaffold. This is the "as many scenarios as you can
//! imagine" entry point — mixed LIF/AdEx/HH circuits with parrot
//! stimulus relays are one config file away, no new Rust builder needed.
//!
//! ```toml
//! [network]
//! kind = "custom"
//! indegree = 100
//! populations = ["E:800:adex:e", "I:200:lif:i", "S:50:parrot:e"]
//! ```
//!
//! Connectivity: every non-parrot population receives `indegree`
//! synapses, split across all source populations proportionally to their
//! sizes (excitatory sources at `+weight_pa`, inhibitory at
//! `-g·weight_pa`). Parrot populations receive no recurrent input — they
//! relay their Poisson source 1:1 into the circuit, which makes them
//! deterministic, decomposition-independent stimulus layers.

use super::{intern_params, AreaGeometry, ConnRule, NetworkSpec, Population};
use crate::model::dynamics::{ModelParams, NeuronModel};
use crate::model::PoissonDrive;

/// One TOML-described population.
#[derive(Clone, Debug)]
pub struct CustomPopSpec {
    pub name: String,
    pub n: u32,
    pub exc: bool,
    pub params: ModelParams,
}

/// The custom network's knobs (see module docs for the TOML surface).
#[derive(Clone, Debug)]
pub struct CustomNetParams {
    pub pops: Vec<CustomPopSpec>,
    /// Recurrent indegree per (non-parrot) neuron.
    pub indegree: u32,
    /// Excitatory weight [pA].
    pub weight_pa: f64,
    /// Inhibition dominance factor (I weight = -g × weight).
    pub g: f64,
    /// Mean synaptic delay [ms].
    pub delay_ms: f64,
    /// Background Poisson rate [Hz] per neuron.
    pub bg_rate_hz: f64,
}

impl Default for CustomNetParams {
    fn default() -> Self {
        CustomNetParams {
            pops: Vec::new(),
            indegree: 100,
            weight_pa: 87.8,
            g: 4.0,
            delay_ms: 1.5,
            bg_rate_hz: 8000.0,
        }
    }
}

/// Build the custom network.
pub fn custom_spec(p: &CustomNetParams, seed: u64) -> NetworkSpec {
    assert!(!p.pops.is_empty(), "custom network needs >= 1 population");
    let mut params = Vec::new();
    let mut populations = Vec::with_capacity(p.pops.len());
    let mut next_gid = 0u32;
    for cp in &p.pops {
        assert!(cp.n > 0, "population {} is empty", cp.name);
        let pidx = intern_params(&mut params, cp.params);
        populations.push(Population {
            name: cp.name.clone(),
            area: 0,
            first_gid: next_gid,
            n: cp.n,
            params: pidx,
            model: cp.params.model(),
            exc: cp.exc,
            drive: PoissonDrive::new(p.bg_rate_hz, p.weight_pa),
        });
        next_gid += cp.n;
    }

    let n_src_total: u64 = p.pops.iter().map(|c| c.n as u64).sum();
    let mut rules = Vec::new();
    for (di, dpop) in p.pops.iter().enumerate() {
        if dpop.params.model() == NeuronModel::Parrot {
            continue; // relays take only their drive
        }
        for (si, spop) in p.pops.iter().enumerate() {
            let k = (p.indegree as f64 * spop.n as f64
                / n_src_total as f64)
                .round() as u32;
            if k == 0 {
                continue;
            }
            rules.push(ConnRule {
                src_pop: si as u16,
                dst_pop: di as u16,
                indegree: k,
                weight_mean: if spop.exc {
                    p.weight_pa
                } else {
                    -p.g * p.weight_pa
                },
                weight_rel_sd: 0.1,
                delay_mean_ms: p.delay_ms,
                delay_rel_sd: 0.5,
                plastic: false,
            });
        }
    }

    let areas = vec![AreaGeometry {
        name: "custom".into(),
        center: [0.0; 3],
        spread: 1.0,
    }];
    NetworkSpec::new(
        "custom", seed, 0.1, params, populations, rules, areas, None,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{AdexParams, LifParams};

    fn pops() -> Vec<CustomPopSpec> {
        vec![
            CustomPopSpec {
                name: "E".into(),
                n: 400,
                exc: true,
                params: ModelParams::Adex(AdexParams::default()),
            },
            CustomPopSpec {
                name: "I".into(),
                n: 100,
                exc: false,
                params: ModelParams::Lif(LifParams::default()),
            },
            CustomPopSpec {
                name: "S".into(),
                n: 50,
                exc: true,
                params: ModelParams::Parrot,
            },
        ]
    }

    #[test]
    fn builds_mixed_circuit_with_parrot_relays() {
        let p = CustomNetParams { pops: pops(), ..Default::default() };
        let s = custom_spec(&p, 7);
        assert_eq!(s.n_total(), 550);
        assert_eq!(s.populations.len(), 3);
        assert_eq!(s.populations[2].model, NeuronModel::Parrot);
        assert!(!s.all_lif());
        // parrots are never a rule destination
        assert!(s.rules.iter().all(|r| r.dst_pop != 2));
        // ...but they do project into the circuit
        assert!(s.rules.iter().any(|r| r.src_pop == 2));
        // weight signs follow the population type
        for r in &s.rules {
            let exc = s.populations[r.src_pop as usize].exc;
            assert_eq!(r.weight_mean > 0.0, exc);
        }
    }

    #[test]
    fn indegree_split_tracks_population_sizes() {
        let p = CustomNetParams {
            pops: pops(),
            indegree: 110,
            ..Default::default()
        };
        let s = custom_spec(&p, 7);
        // dst E receives from E (400/550), I (100/550), S (50/550)
        let k_of = |src: u16| {
            s.rules
                .iter()
                .find(|r| r.src_pop == src && r.dst_pop == 0)
                .map(|r| r.indegree)
                .unwrap_or(0)
        };
        assert_eq!(k_of(0), 80);
        assert_eq!(k_of(1), 20);
        assert_eq!(k_of(2), 10);
    }

    #[test]
    #[should_panic(expected = "custom network needs")]
    fn empty_population_list_rejected() {
        let _ = custom_spec(&CustomNetParams::default(), 1);
    }
}
