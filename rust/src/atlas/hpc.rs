//! NEST `hpc_benchmark` — the paper's verification case (§IV.A): a
//! balanced random network (Brunel 2000) whose E→E synapses exhibit STDP
//! with multiplicative depression and power-law potentiation.
//!
//! "The number of incoming synaptic interactions per neuron is fixed and
//! independent of network size" — fixed indegree `k`, 80% excitatory.
//! The acceptance criterion is the paper's: average firing rate below
//! ~10 Hz (asynchronous-irregular regime), plus CORTEX's own structural
//! check that no edge or post-vertex is ever touched by two threads.

use super::{intern_params, AreaGeometry, ConnRule, NetworkSpec, Population};
use crate::model::dynamics::ModelParams;
use crate::model::{LifParams, PoissonDrive, StdpParams};

#[derive(Clone, Debug)]
pub struct HpcParams {
    pub n_neurons: usize,
    /// Total indegree per neuron (0.8 E / 0.2 I).
    pub indegree: u32,
    /// Relative external drive η = ν_ext / ν_threshold.
    pub eta: f64,
    /// Inhibition dominance g (>4 ⇒ inhibition-dominated regime).
    pub g: f64,
    /// Excitatory weight [pA] (≈0.15 mV PSP).
    pub je_pa: f64,
    /// Enable STDP on E→E.
    pub plastic: bool,
    /// Neuron models of the E / I populations (default: the published
    /// all-LIF circuit; the η calibration assumes LIF).
    pub model_e: ModelParams,
    pub model_i: ModelParams,
}

impl Default for HpcParams {
    fn default() -> Self {
        HpcParams {
            n_neurons: 2_250,
            indegree: 225,
            // sub-threshold mean drive + inhibition dominance: the
            // fluctuation-driven asynchronous-irregular regime whose
            // rate stays below the paper's 10 Hz verification bound
            // (calibrated with `cargo run --example calibrate`)
            eta: 0.78,
            g: 6.0,
            je_pa: 45.61,
            plastic: true,
            model_e: ModelParams::Lif(LifParams::default()),
            model_i: ModelParams::Lif(LifParams::default()),
        }
    }
}

/// Build the verification network.
pub fn hpc_benchmark_spec(p: &HpcParams, seed: u64) -> NetworkSpec {
    let ne = (p.n_neurons * 4 / 5) as u32;
    let ni = (p.n_neurons - p.n_neurons * 4 / 5) as u32;
    let ce = p.indegree * 4 / 5;
    let ci = p.indegree - ce;

    // the η drive calibration is defined against LIF membrane constants;
    // non-LIF E populations inherit the same (then merely heuristic) rate
    let lif = match &p.model_e {
        ModelParams::Lif(lp) => *lp,
        _ => LifParams::default(),
    };
    // Brunel threshold rate: nu_th = theta_rel / (J_psp · CE · tau_m), with
    // the pA→mV PSP conversion of the default neuron (87.8 pA ≈ 0.15 mV).
    let j_psp_mv = p.je_pa * 0.15 / 87.8;
    let theta_rel = lif.v_th - lif.e_l;
    let nu_th_hz =
        theta_rel / (j_psp_mv * ce as f64 * lif.tau_m) * 1000.0;
    // external Poisson: eta · nu_th per external synapse × CE synapses
    let ext_rate_hz = p.eta * nu_th_hz * ce as f64;
    let drive = PoissonDrive::new(ext_rate_hz, p.je_pa);

    let mut params = Vec::new();
    let pe = intern_params(&mut params, p.model_e);
    let pi = intern_params(&mut params, p.model_i);
    let populations = vec![
        Population {
            name: "E".into(),
            area: 0,
            first_gid: 0,
            n: ne,
            params: pe,
            model: p.model_e.model(),
            exc: true,
            drive,
        },
        Population {
            name: "I".into(),
            area: 0,
            first_gid: ne,
            n: ni,
            params: pi,
            model: p.model_i.model(),
            exc: false,
            drive,
        },
    ];

    let mut rules = Vec::new();
    for dst in 0..2u16 {
        rules.push(ConnRule {
            src_pop: 0,
            dst_pop: dst,
            indegree: ce,
            weight_mean: p.je_pa,
            weight_rel_sd: 0.0,   // hpc_benchmark uses homogeneous J
            delay_mean_ms: 1.5,
            delay_rel_sd: 0.0,
            plastic: p.plastic && dst == 0, // STDP on E→E only
        });
        rules.push(ConnRule {
            src_pop: 1,
            dst_pop: dst,
            indegree: ci,
            weight_mean: -p.g * p.je_pa,
            weight_rel_sd: 0.0,
            delay_mean_ms: 1.5,
            delay_rel_sd: 0.0,
            plastic: false,
        });
    }

    let areas = vec![AreaGeometry {
        name: "net".into(),
        center: [0.0; 3],
        spread: 1.0,
    }];
    let stdp = p.plastic.then(|| StdpParams {
        w0: p.je_pa,
        w_max: 20.0 * p.je_pa,
        ..Default::default()
    });
    NetworkSpec::new(
        format!("hpc_benchmark-{}", p.n_neurons),
        seed,
        0.1,
        params,
        populations,
        rules,
        areas,
        stdp,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn structure() {
        let s = hpc_benchmark_spec(&HpcParams::default(), 1);
        assert_eq!(s.n_total(), 2250);
        assert_eq!(s.populations[0].n, 1800);
        assert_eq!(s.populations[1].n, 450);
        assert_eq!(s.rules.len(), 4);
        assert!(s.stdp.is_some());
    }

    #[test]
    fn only_ee_plastic() {
        let s = hpc_benchmark_spec(&HpcParams::default(), 1);
        for r in &s.rules {
            let want = r.src_pop == 0 && r.dst_pop == 0;
            assert_eq!(r.plastic, want, "rule {r:?}");
        }
        assert!(s.edge_plastic(0, 1));
        assert!(!s.edge_plastic(0, 2000)); // E→I
        assert!(!s.edge_plastic(2000, 0)); // I→E
    }

    #[test]
    fn fixed_indegree_independent_of_size() {
        for n in [1_000, 4_000] {
            let p = HpcParams { n_neurons: n, ..Default::default() };
            let s = hpc_benchmark_spec(&p, 1);
            let mut edges = Vec::new();
            s.in_edges(0, &mut edges);
            assert_eq!(edges.len(), 225, "indegree must not scale with N");
        }
    }

    #[test]
    fn drive_above_threshold() {
        let p = HpcParams::default();
        let s = hpc_benchmark_spec(&p, 1);
        let d = s.drive(0);
        assert!(d.rate_hz > 1000.0, "ext rate {} too small", d.rate_hz);
        assert_eq!(d.weight_pa, p.je_pa);
    }

    #[test]
    fn plastic_flag_off() {
        let p = HpcParams { plastic: false, ..Default::default() };
        let s = hpc_benchmark_spec(&p, 1);
        assert!(s.stdp.is_none());
        assert!(s.rules.iter().all(|r| !r.plastic));
    }
}
