//! Synthetic multi-area "marmoset-like" connectome — the evaluation
//! workload standing in for the paper's marmoset cerebral-cortex model
//! (built there from the Paxinos structural connectome, cell-density and
//! inter-areal-distance datasets; see DESIGN.md §2 for the substitution
//! argument).
//!
//! The statistics the paper's optimisations exploit are reproduced:
//!
//! * **varied density of synaptic interactions** (paper Fig 7/8): most of
//!   each neuron's indegree comes from its own area; inter-area indegree
//!   decays exponentially with the distance between area centres, with
//!   log-normally varied per-pair strength (connectome matrices are
//!   heavy-tailed);
//! * **varied cell density**: area sizes are drawn log-normally around the
//!   mean, then normalised to the requested total;
//! * **varied synaptic delays**: intra-area delays ~1.5 ± 0.75 ms;
//!   inter-area delays follow distance / conduction velocity (3.5 m/s)
//!   plus a base offset — the temporal sparsity of §I.B;
//! * **internal architecture from Potjans-Diesmann** (the paper does the
//!   same, citing [30]): each area is an E/I microcircuit with 4:1 ratio,
//!   inhibition-dominated recurrence, and per-neuron Poisson background.

use super::{intern_params, AreaGeometry, ConnRule, NetworkSpec, Population};
use crate::model::dynamics::ModelParams;
use crate::model::{LifParams, PoissonDrive};
use crate::util::rng::Rng;

/// Parameters of the synthetic atlas.
#[derive(Clone, Debug)]
pub struct MarmosetParams {
    pub n_neurons: usize,
    pub n_areas: usize,
    /// Total synaptic indegree per neuron.
    pub indegree: u32,
    /// Fraction of the indegree sourced within the neuron's own area.
    pub local_fraction: f64,
    /// Distance constant of inter-area connectivity decay [mm].
    pub decay_mm: f64,
    /// Conduction velocity for inter-area delays [m/s = mm/ms].
    pub velocity_mm_ms: f64,
    /// Excitatory weight [pA] (≈0.15 mV PSP at the default neuron).
    pub weight_pa: f64,
    /// Inhibition dominance factor g (I weight = -g × E weight).
    pub g: f64,
    /// Background Poisson rate [Hz] per neuron.
    pub bg_rate_hz: f64,
    /// Neuron models of the E / I populations of every area.
    pub model_e: ModelParams,
    pub model_i: ModelParams,
}

impl Default for MarmosetParams {
    fn default() -> Self {
        MarmosetParams {
            n_neurons: 10_000,
            n_areas: 8,
            indegree: 250,
            local_fraction: 0.85,
            decay_mm: 12.0,
            velocity_mm_ms: 3.5,
            weight_pa: 87.8,
            g: 4.5,
            bg_rate_hz: 7400.0,
            model_e: ModelParams::Lif(LifParams::default()),
            model_i: ModelParams::Lif(LifParams::default()),
        }
    }
}

/// Build the synthetic marmoset spec. Areas are placed on a jittered 3D
/// grid spanning ~30 mm (marmoset-cortex scale); each area holds an E and
/// an I population (4:1).
pub fn marmoset_spec(p: &MarmosetParams, seed: u64) -> NetworkSpec {
    assert!(p.n_areas >= 1);
    assert!(p.n_neurons >= p.n_areas * 10);
    let mut rng = Rng::stream(seed, &[0x4d41524d]); // "MARM"

    // --- area geometry: jittered grid, log-normal relative sizes -------
    let side = (p.n_areas as f64).cbrt().ceil() as usize;
    let pitch = 30.0 / side as f64;
    let mut areas = Vec::with_capacity(p.n_areas);
    let mut rel_size = Vec::with_capacity(p.n_areas);
    for a in 0..p.n_areas {
        let (i, j, k) = (a % side, (a / side) % side, a / (side * side));
        areas.push(AreaGeometry {
            name: format!("A{a:02}"),
            center: [
                i as f64 * pitch + rng.range_f64(-0.2, 0.2) * pitch,
                j as f64 * pitch + rng.range_f64(-0.2, 0.2) * pitch,
                k as f64 * pitch + rng.range_f64(-0.2, 0.2) * pitch,
            ],
            spread: 0.4 * pitch,
        });
        // cell-density variation: lognormal with ~30% spread
        rel_size.push(rng.lognormal(0.0, 0.3));
    }
    let total_rel: f64 = rel_size.iter().sum();

    // --- populations: E/I per area, sizes normalised to n_neurons ------
    let mut params = Vec::new();
    let pe = intern_params(&mut params, p.model_e);
    let pi = intern_params(&mut params, p.model_i);
    let drive = PoissonDrive::new(p.bg_rate_hz, p.weight_pa);
    let mut populations = Vec::with_capacity(2 * p.n_areas);
    let mut next_gid = 0u32;
    let mut area_n = Vec::with_capacity(p.n_areas);
    for a in 0..p.n_areas {
        let mut n_a =
            ((p.n_neurons as f64) * rel_size[a] / total_rel).round() as u32;
        n_a = n_a.max(10);
        let ne = n_a * 4 / 5;
        let ni = n_a - ne;
        populations.push(Population {
            name: format!("A{a:02}E"),
            area: a as u16,
            first_gid: next_gid,
            n: ne,
            params: pe,
            model: p.model_e.model(),
            exc: true,
            drive,
        });
        next_gid += ne;
        populations.push(Population {
            name: format!("A{a:02}I"),
            area: a as u16,
            first_gid: next_gid,
            n: ni,
            params: pi,
            model: p.model_i.model(),
            exc: false,
            drive,
        });
        next_gid += ni;
        area_n.push(n_a);
    }

    // --- rules ----------------------------------------------------------
    // intra-area: Brunel-style E/I recurrence carrying `local_fraction`
    // of the indegree; inter-area: E→E with exponential distance decay ×
    // log-normal pair strength carrying the rest.
    let mut rules = Vec::new();
    let k_local = (p.indegree as f64 * p.local_fraction).round() as u32;
    let k_remote_total = p.indegree - k_local.min(p.indegree);
    let ke = k_local * 4 / 5;
    let ki = k_local - ke;

    let dist = |a: usize, b: usize| -> f64 {
        let (ca, cb) = (&areas[a].center, &areas[b].center);
        ((ca[0] - cb[0]).powi(2) + (ca[1] - cb[1]).powi(2)
            + (ca[2] - cb[2]).powi(2))
        .sqrt()
    };

    for a in 0..p.n_areas {
        let e_pop = (2 * a) as u16;
        let i_pop = (2 * a + 1) as u16;
        for &dst in &[e_pop, i_pop] {
            rules.push(ConnRule {
                src_pop: e_pop,
                dst_pop: dst,
                indegree: ke,
                weight_mean: p.weight_pa,
                weight_rel_sd: 0.1,
                delay_mean_ms: 1.5,
                delay_rel_sd: 0.5,
                plastic: false,
            });
            rules.push(ConnRule {
                src_pop: i_pop,
                dst_pop: dst,
                indegree: ki,
                weight_mean: -p.g * p.weight_pa,
                weight_rel_sd: 0.1,
                delay_mean_ms: 0.75,
                delay_rel_sd: 0.5,
                plastic: false,
            });
        }

        // inter-area E→{E,I} of area a, distance-weighted across sources
        if k_remote_total > 0 && p.n_areas > 1 {
            let mut weights: Vec<f64> = (0..p.n_areas)
                .map(|b| {
                    if b == a {
                        0.0
                    } else {
                        (-dist(a, b) / p.decay_mm).exp()
                            * rng.lognormal(0.0, 0.5)
                    }
                })
                .collect();
            let wsum: f64 = weights.iter().sum();
            if wsum > 0.0 {
                for w in &mut weights {
                    *w /= wsum;
                }
                for (b, &frac) in weights.iter().enumerate() {
                    let k = (k_remote_total as f64 * frac).round() as u32;
                    if k == 0 {
                        continue;
                    }
                    let d_ms = 0.5 + dist(a, b) / p.velocity_mm_ms;
                    for &dst in &[e_pop, i_pop] {
                        rules.push(ConnRule {
                            src_pop: (2 * b) as u16, // remote E only
                            dst_pop: dst,
                            indegree: k,
                            weight_mean: p.weight_pa,
                            weight_rel_sd: 0.1,
                            delay_mean_ms: d_ms,
                            delay_rel_sd: 0.2,
                            plastic: false,
                        });
                    }
                }
            }
        }
    }

    NetworkSpec::new(
        format!("marmoset-{}x{}", p.n_areas, p.n_neurons),
        seed,
        0.1,
        params,
        populations,
        rules,
        areas,
        None,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_with_defaults() {
        let spec = marmoset_spec(&MarmosetParams::default(), 1);
        let n = spec.n_total();
        assert!(
            (n as f64 - 10_000.0).abs() < 500.0,
            "total {n} too far from requested"
        );
        assert_eq!(spec.n_areas(), 8);
        assert_eq!(spec.populations.len(), 16);
    }

    #[test]
    fn local_density_dominates_remote() {
        // the property Area-Processes Mapping exploits (paper Fig 8b):
        // n(remote indegree) << n(local indegree)
        let spec = marmoset_spec(&MarmosetParams::default(), 2);
        let mut local = 0u64;
        let mut remote = 0u64;
        for r in &spec.rules {
            let same_area = spec.populations[r.src_pop as usize].area
                == spec.populations[r.dst_pop as usize].area;
            let edges = r.indegree as u64
                * spec.populations[r.dst_pop as usize].n as u64;
            if same_area {
                local += edges;
            } else {
                remote += edges;
            }
        }
        assert!(local > 4 * remote, "local {local} remote {remote}");
    }

    #[test]
    fn interarea_delays_exceed_local() {
        let spec = marmoset_spec(&MarmosetParams::default(), 3);
        let local_max = spec
            .rules
            .iter()
            .filter(|r| {
                spec.populations[r.src_pop as usize].area
                    == spec.populations[r.dst_pop as usize].area
            })
            .map(|r| r.delay_mean_ms)
            .fold(0.0, f64::max);
        let remote_min = spec
            .rules
            .iter()
            .filter(|r| {
                spec.populations[r.src_pop as usize].area
                    != spec.populations[r.dst_pop as usize].area
            })
            .map(|r| r.delay_mean_ms)
            .fold(f64::INFINITY, f64::min);
        assert!(remote_min > local_max * 0.5, "delays not distance-varied");
    }

    #[test]
    fn area_sizes_vary() {
        let spec = marmoset_spec(&MarmosetParams::default(), 4);
        let sizes: Vec<u32> = (0..8)
            .map(|a| {
                spec.populations
                    .iter()
                    .filter(|p| p.area == a)
                    .map(|p| p.n)
                    .sum()
            })
            .collect();
        let min = *sizes.iter().min().unwrap();
        let max = *sizes.iter().max().unwrap();
        assert!(max > min, "cell density should vary: {sizes:?}");
    }

    #[test]
    fn scaling_preserves_indegree() {
        for n in [2_000, 8_000] {
            let p = MarmosetParams { n_neurons: n, ..Default::default() };
            let spec = marmoset_spec(&p, 5);
            let mut edges = Vec::new();
            spec.in_edges(0, &mut edges);
            let k = edges.len() as f64;
            assert!(
                (k - 250.0).abs() < 30.0,
                "indegree {k} at n={n} drifted from 250"
            );
        }
    }
}
