//! Concrete directed graph with weights/delays and CSR adjacency.

use crate::{DelaySteps, Gid};

/// One synaptic interaction (directed edge pre → post).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Edge {
    pub pre: Gid,
    pub post: Gid,
    pub weight: f64,
    pub delay: DelaySteps,
}

/// Directed graph over vertices `0..n` with CSR indices in both directions.
///
/// `out_csr` answers "edges *from* v" (outdegree view), `in_csr` answers
/// "edges *onto* v" (indegree view — the decomposition's native layout).
#[derive(Clone, Debug)]
pub struct DiGraph {
    n: usize,
    edges: Vec<Edge>,
    /// CSR over `edges` sorted by pre: offsets[v]..offsets[v+1]
    out_offsets: Vec<u32>,
    out_order: Vec<u32>,
    /// CSR over `edges` sorted by post
    in_offsets: Vec<u32>,
    in_order: Vec<u32>,
}

impl DiGraph {
    pub fn new(n: usize, edges: Vec<Edge>) -> Self {
        for e in &edges {
            assert!((e.pre as usize) < n, "edge pre {} out of range", e.pre);
            assert!((e.post as usize) < n, "edge post {} out of range", e.post);
            assert!(e.delay >= 1, "synaptic delay must be >= 1 step");
        }
        let (out_offsets, out_order) =
            build_csr(n, &edges, |e| e.pre as usize);
        let (in_offsets, in_order) = build_csr(n, &edges, |e| e.post as usize);
        DiGraph { n, edges, out_offsets, out_order, in_offsets, in_order }
    }

    pub fn n_vertices(&self) -> usize {
        self.n
    }

    pub fn n_edges(&self) -> usize {
        self.edges.len()
    }

    pub fn edges(&self) -> &[Edge] {
        &self.edges
    }

    /// Edges whose pre-synaptic neuron is `v`.
    pub fn out_edges(&self, v: Gid) -> impl Iterator<Item = &Edge> + '_ {
        let (a, b) = (
            self.out_offsets[v as usize] as usize,
            self.out_offsets[v as usize + 1] as usize,
        );
        self.out_order[a..b].iter().map(move |&i| &self.edges[i as usize])
    }

    /// Edges whose post-synaptic neuron is `v`.
    pub fn in_edges(&self, v: Gid) -> impl Iterator<Item = &Edge> + '_ {
        let (a, b) = (
            self.in_offsets[v as usize] as usize,
            self.in_offsets[v as usize + 1] as usize,
        );
        self.in_order[a..b].iter().map(move |&i| &self.edges[i as usize])
    }

    pub fn outdegree(&self, v: Gid) -> usize {
        (self.out_offsets[v as usize + 1] - self.out_offsets[v as usize]) as usize
    }

    pub fn indegree(&self, v: Gid) -> usize {
        (self.in_offsets[v as usize + 1] - self.in_offsets[v as usize]) as usize
    }

    /// Maximum synaptic delay (in steps); 1 for an edgeless graph.
    pub fn max_delay(&self) -> DelaySteps {
        self.edges.iter().map(|e| e.delay).max().unwrap_or(1)
    }

    pub fn min_delay(&self) -> DelaySteps {
        self.edges.iter().map(|e| e.delay).min().unwrap_or(1)
    }
}

fn build_csr(
    n: usize,
    edges: &[Edge],
    key: impl Fn(&Edge) -> usize,
) -> (Vec<u32>, Vec<u32>) {
    let mut counts = vec![0u32; n + 1];
    for e in edges {
        counts[key(e) + 1] += 1;
    }
    for i in 0..n {
        counts[i + 1] += counts[i];
    }
    let offsets = counts.clone();
    let mut cursor = counts;
    let mut order = vec![0u32; edges.len()];
    for (i, e) in edges.iter().enumerate() {
        let k = key(e);
        order[cursor[k] as usize] = i as u32;
        cursor[k] += 1;
    }
    (offsets, order)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn diamond() -> DiGraph {
        // 0 -> 1, 0 -> 2, 1 -> 3, 2 -> 3
        DiGraph::new(
            4,
            vec![
                Edge { pre: 0, post: 1, weight: 1.0, delay: 1 },
                Edge { pre: 0, post: 2, weight: 2.0, delay: 2 },
                Edge { pre: 1, post: 3, weight: 3.0, delay: 3 },
                Edge { pre: 2, post: 3, weight: 4.0, delay: 4 },
            ],
        )
    }

    #[test]
    fn degrees_and_iteration() {
        let g = diamond();
        assert_eq!(g.n_vertices(), 4);
        assert_eq!(g.n_edges(), 4);
        assert_eq!(g.outdegree(0), 2);
        assert_eq!(g.indegree(3), 2);
        assert_eq!(g.outdegree(3), 0);
        let onto3: Vec<f64> = g.in_edges(3).map(|e| e.weight).collect();
        assert_eq!(onto3, vec![3.0, 4.0]);
        let from0: Vec<Gid> = g.out_edges(0).map(|e| e.post).collect();
        assert_eq!(from0, vec![1, 2]);
    }

    #[test]
    fn delays() {
        let g = diamond();
        assert_eq!(g.max_delay(), 4);
        assert_eq!(g.min_delay(), 1);
        let empty = DiGraph::new(3, vec![]);
        assert_eq!(empty.max_delay(), 1);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn rejects_out_of_range_edges() {
        DiGraph::new(
            2,
            vec![Edge { pre: 0, post: 5, weight: 1.0, delay: 1 }],
        );
    }

    #[test]
    #[should_panic(expected = "delay must be >= 1")]
    fn rejects_zero_delay() {
        DiGraph::new(
            2,
            vec![Edge { pre: 0, post: 1, weight: 1.0, delay: 0 }],
        );
    }

    #[test]
    fn csr_consistency_in_equals_out() {
        let g = diamond();
        let via_out: usize = (0..4).map(|v| g.outdegree(v)).sum();
        let via_in: usize = (0..4).map(|v| g.indegree(v)).sum();
        assert_eq!(via_out, g.n_edges());
        assert_eq!(via_in, g.n_edges());
    }
}
