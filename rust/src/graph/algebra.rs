//! The sub-graph algebra of paper §II.A.1-2: binary operations ⊼ (meet,
//! component-wise ∩) and ⊻ (join, component-wise ∪) on sub-graph triplets
//! (eq. 7), the homomorphism `*S(Va) ⊛ *S(Vb) = *S(Va ⊙ Vb)` (eq. 8), and
//! the disjoint-write-set results of eq. (13)-(15) that justify choosing
//! indegree sub-graphs for parallelisation.


use super::subgraph::SubGraph;

/// ⊼: component-wise intersection of two same-kind sub-graphs (eq. 7).
pub fn meet(a: &SubGraph, b: &SubGraph) -> SubGraph {
    assert_eq!(a.kind, b.kind, "meet requires same sub-graph kind");
    SubGraph {
        kind: a.kind,
        pre: a.pre.intersection(&b.pre).copied().collect(),
        post: a.post.intersection(&b.post).copied().collect(),
        edges: a.edges.intersection(&b.edges).copied().collect(),
    }
}

/// ⊻: component-wise union of two same-kind sub-graphs (eq. 7).
pub fn join(a: &SubGraph, b: &SubGraph) -> SubGraph {
    assert_eq!(a.kind, b.kind, "join requires same sub-graph kind");
    SubGraph {
        kind: a.kind,
        pre: a.pre.union(&b.pre).copied().collect(),
        post: a.post.union(&b.post).copied().collect(),
        edges: a.edges.union(&b.edges).copied().collect(),
    }
}

/// The dependency between two sub-graphs during parallel synaptic
/// interaction (eq. 12): the overlap of their write sets. Empty ⇒ the two
/// can run on different threads/processes with no mutex or atomic.
pub fn write_conflict(a: &SubGraph, b: &SubGraph) -> SubGraph {
    meet(a, b)
}

/// Check eq. (14)/(15): given sub-graphs built over *disjoint* vertex
/// sets, return whether their post-vertex and edge sets overlap.
pub fn has_write_race(a: &SubGraph, b: &SubGraph) -> bool {
    let c = write_conflict(a, b);
    !c.post.is_empty() || !c.edges.is_empty()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::digraph::{DiGraph, Edge};
    use crate::graph::subgraph::SubGraphKind;
    use crate::util::proptest_lite::{property, Gen};
    use crate::Gid;
    use std::collections::BTreeSet;

    /// Random directed graph for property tests.
    fn random_graph(g: &mut Gen) -> DiGraph {
        let n = g.usize(2..40);
        let m = g.usize(0..200);
        let edges: Vec<Edge> = (0..m)
            .map(|_| Edge {
                pre: g.u32(0..n as u32),
                post: g.u32(0..n as u32),
                weight: g.f64(-2.0, 2.0),
                delay: g.u32(1..15) as u16,
            })
            .collect();
        // dedup (pre, post) pairs so edge-set semantics are exact
        let mut seen = BTreeSet::new();
        let edges: Vec<Edge> = edges
            .into_iter()
            .filter(|e| seen.insert((e.pre, e.post)))
            .collect();
        DiGraph::new(n, edges)
    }

    fn vset(g: &mut Gen, n: u32, p: f64) -> BTreeSet<Gid> {
        g.subset(n, p).into_iter().collect()
    }

    /// Eq. (8): `S(Va) ⊛ S(Vb) = S(Va ⊙ Vb)`.
    ///
    /// The join (⊻, ∪) homomorphism holds exactly in all three components.
    /// For the meet (⊼, ∩) the *post and edge* components — the ones all of
    /// the paper's later arguments (eq. 13-15) actually use — agree exactly,
    /// while the pre component of `S(Va) ⊼ S(Vb)` is only a superset of
    /// `S(Va ∩ Vb)`'s: a source feeding Va\Vb and Vb\Va sits in both
    /// pre-sets yet has no edge onto Va ∩ Vb. The paper's own eq. (14)
    /// writes the meet's pre component as the component-wise intersection,
    /// i.e. it adopts the ⊛-side as the definition; we verify exactly that
    /// relationship.
    #[test]
    fn homomorphism_eq8_meet_and_join() {
        for kind in [SubGraphKind::In, SubGraphKind::Out] {
            property(
                match kind {
                    SubGraphKind::In => "eq8 homomorphism (indegree)",
                    SubGraphKind::Out => "eq8 homomorphism (outdegree)",
                },
                60,
                |g| {
                    let graph = random_graph(g);
                    let n = graph.n_vertices() as u32;
                    let va = vset(g, n, 0.4);
                    let vb = vset(g, n, 0.4);
                    let sa = SubGraph::of(&graph, kind, &va);
                    let sb = SubGraph::of(&graph, kind, &vb);

                    // S(Va) ⊻ S(Vb) = S(Va ∪ Vb)  — exact in all components
                    let union_v: BTreeSet<Gid> =
                        va.union(&vb).copied().collect();
                    if join(&sa, &sb) != SubGraph::of(&graph, kind, &union_v) {
                        return Err("join homomorphism violated".into());
                    }

                    // S(Va) ⊼ S(Vb) vs S(Va ∩ Vb)
                    let inter_v: BTreeSet<Gid> =
                        va.intersection(&vb).copied().collect();
                    let lhs = meet(&sa, &sb);
                    let rhs = SubGraph::of(&graph, kind, &inter_v);
                    if lhs.edges != rhs.edges {
                        return Err("meet edge component violated".into());
                    }
                    // the defining vertex component (post for indegree, pre
                    // for outdegree) is exact; the derived one is ⊇
                    let (exact_ok, derived_ok) = match kind {
                        SubGraphKind::In => (
                            lhs.post == rhs.post,
                            lhs.pre.is_superset(&rhs.pre),
                        ),
                        SubGraphKind::Out => (
                            lhs.pre == rhs.pre,
                            lhs.post.is_superset(&rhs.post),
                        ),
                    };
                    if !exact_ok {
                        return Err("meet defining component violated".into());
                    }
                    if !derived_ok {
                        return Err("meet derived ⊇ relation violated".into());
                    }
                    Ok(())
                },
            );
        }
    }

    #[test]
    fn meet_join_commutative_associative() {
        property("⊼/⊻ commutative + associative", 40, |g| {
            let graph = random_graph(g);
            let n = graph.n_vertices() as u32;
            let kind = if g.bool(0.5) { SubGraphKind::In } else { SubGraphKind::Out };
            let sa = SubGraph::of(&graph, kind, &vset(g, n, 0.4));
            let sb = SubGraph::of(&graph, kind, &vset(g, n, 0.4));
            let sc = SubGraph::of(&graph, kind, &vset(g, n, 0.4));
            if meet(&sa, &sb) != meet(&sb, &sa) {
                return Err("meet not commutative".into());
            }
            if join(&sa, &sb) != join(&sb, &sa) {
                return Err("join not commutative".into());
            }
            if meet(&meet(&sa, &sb), &sc) != meet(&sa, &meet(&sb, &sc)) {
                return Err("meet not associative".into());
            }
            if join(&join(&sa, &sb), &sc) != join(&sa, &join(&sb, &sc)) {
                return Err("join not associative".into());
            }
            Ok(())
        });
    }

    /// Eq. (14) — the paper's key result: indegree sub-graphs over
    /// DISJOINT vertex sets never share post-vertices or edges, so writes
    /// need no synchronisation. The pre overlap may be non-empty (shared
    /// read-only data), which is exactly eq. (14)'s (V_pre∩V_pre, ∅, ∅).
    #[test]
    fn eq14_indegree_disjoint_write_sets() {
        property("eq14 indegree no write race", 80, |g| {
            let graph = random_graph(g);
            let n = graph.n_vertices() as u32;
            let va = vset(g, n, 0.5);
            let vb: BTreeSet<Gid> =
                (0..n).filter(|v| !va.contains(v)).collect();
            let sa = SubGraph::of(&graph, SubGraphKind::In, &va);
            let sb = SubGraph::of(&graph, SubGraphKind::In, &vb);
            if has_write_race(&sa, &sb) {
                return Err("indegree sub-graphs raced".into());
            }
            Ok(())
        });
    }

    /// Eq. (15) — outdegree sub-graphs over disjoint vertex sets CAN share
    /// post-vertices (two sources in different parts hitting one target),
    /// which is why the paper rejects them. We verify the conflict is of
    /// the (∅, post∩post, ∅) shape and demonstrate a concrete race.
    #[test]
    fn eq15_outdegree_conflict_shape() {
        property("eq15 outdegree conflict shape", 60, |g| {
            let graph = random_graph(g);
            let n = graph.n_vertices() as u32;
            let va = vset(g, n, 0.5);
            let vb: BTreeSet<Gid> =
                (0..n).filter(|v| !va.contains(v)).collect();
            let sa = SubGraph::of(&graph, SubGraphKind::Out, &va);
            let sb = SubGraph::of(&graph, SubGraphKind::Out, &vb);
            let c = write_conflict(&sa, &sb);
            // pres disjoint by construction, edges disjoint (an edge's pre
            // lives in exactly one part) — only posts may overlap
            if !c.pre.is_empty() {
                return Err("outdegree pres overlapped".into());
            }
            if !c.edges.is_empty() {
                return Err("outdegree edges overlapped".into());
            }
            Ok(())
        });
    }

    #[test]
    fn eq15_outdegree_concrete_race_exists() {
        // paper Fig 5: sources 1 and 6 in different parts both hit 9
        let graph = DiGraph::new(
            3,
            vec![
                Edge { pre: 0, post: 2, weight: 1.0, delay: 1 },
                Edge { pre: 1, post: 2, weight: 1.0, delay: 1 },
            ],
        );
        let sa = SubGraph::of(&graph, SubGraphKind::Out, &[0].into_iter().collect());
        let sb = SubGraph::of(&graph, SubGraphKind::Out, &[1].into_iter().collect());
        assert!(has_write_race(&sa, &sb), "expected the Fig 5 race");
    }

    /// Eq. (13): the spiking restriction distributes over the meet.
    #[test]
    fn eq13_spiking_distributes() {
        property("eq13 spiking ⊼ distributivity", 50, |g| {
            let graph = random_graph(g);
            let n = graph.n_vertices() as u32;
            let va = vset(g, n, 0.4);
            let vb = vset(g, n, 0.4);
            let spikes: BTreeSet<Gid> = vset(g, n, 0.3);
            let kind = SubGraphKind::In;
            let lhs = meet(
                &SubGraph::of(&graph, kind, &va).spiking(&spikes),
                &SubGraph::of(&graph, kind, &vb).spiking(&spikes),
            );
            let inter: BTreeSet<Gid> = va.intersection(&vb).copied().collect();
            let rhs = SubGraph::of(&graph, kind, &inter).spiking(&spikes);
            // compare edge sets (pre/post of both sides are derived from
            // edges after the spiking restriction)
            if lhs.edges != rhs.edges {
                return Err("eq13 edge sets differ".into());
            }
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "same sub-graph kind")]
    fn mixed_kind_meet_panics() {
        let g = DiGraph::new(2, vec![]);
        let a = SubGraph::of(&g, SubGraphKind::In, &[0].into_iter().collect());
        let b = SubGraph::of(&g, SubGraphKind::Out, &[1].into_iter().collect());
        let _ = meet(&a, &b);
    }
}
