//! Indegree / outdegree sub-graph triplets (paper eq. 4-6, 11).
//!
//! These are the *specification-level* objects the paper reasons with;
//! the engine uses compact CSR stores derived from them (see `decomp`).
//! Sets are `BTreeSet`s for deterministic iteration in tests.

use std::collections::BTreeSet;

use super::digraph::DiGraph;
use crate::Gid;

/// Edge identity within sub-graph algebra: the (pre, post) ordered pair.
pub type EdgeKey = (Gid, Gid);

/// Indegree or outdegree format (the `*` in the paper's `*S`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SubGraphKind {
    /// `in-S(V~)`: all edges whose *post* vertex is in V~ (eq. 5).
    In,
    /// `out-S(V~)`: all edges whose *pre* vertex is in V~ (eq. 6).
    Out,
}

/// The triplet `*S = (V_pre, V_post, E)` of eq. (4).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SubGraph {
    pub kind: SubGraphKind,
    pub pre: BTreeSet<Gid>,
    pub post: BTreeSet<Gid>,
    pub edges: BTreeSet<EdgeKey>,
}

impl SubGraph {
    /// Build `*S(V~)` from a concrete graph and a vertex subset.
    pub fn of(graph: &DiGraph, kind: SubGraphKind, vs: &BTreeSet<Gid>) -> Self {
        let mut pre = BTreeSet::new();
        let mut post = BTreeSet::new();
        let mut edges = BTreeSet::new();
        match kind {
            SubGraphKind::In => {
                // posts are exactly V~; pres are every source pointing in
                post.extend(vs.iter().copied());
                for &v in vs {
                    for e in graph.in_edges(v) {
                        pre.insert(e.pre);
                        edges.insert((e.pre, e.post));
                    }
                }
            }
            SubGraphKind::Out => {
                pre.extend(vs.iter().copied());
                for &v in vs {
                    for e in graph.out_edges(v) {
                        post.insert(e.post);
                        edges.insert((e.pre, e.post));
                    }
                }
            }
        }
        SubGraph { kind, pre, post, edges }
    }

    /// The spiking sub-graph of eq. (11): restrict to edges whose pre
    /// vertex is currently spiking (`*S(V_i) ⊼ *S_s`). The result keeps
    /// only the reachable pres/posts, mirroring the paper's Fig 4.
    pub fn spiking(&self, spiking_pres: &BTreeSet<Gid>) -> SubGraph {
        let edges: BTreeSet<EdgeKey> = self
            .edges
            .iter()
            .filter(|(p, _)| spiking_pres.contains(p))
            .copied()
            .collect();
        let pre: BTreeSet<Gid> = edges.iter().map(|(p, _)| *p).collect();
        let post: BTreeSet<Gid> = edges.iter().map(|(_, q)| *q).collect();
        SubGraph { kind: self.kind, pre, post, edges }
    }

    /// The write set of this sub-graph during synaptic interaction: the
    /// post vertices (their state is mutated) plus the edges themselves
    /// (plastic synapses mutate edge state).
    pub fn write_set(&self) -> (BTreeSet<Gid>, BTreeSet<EdgeKey>) {
        (self.post.clone(), self.edges.clone())
    }

    pub fn is_empty(&self) -> bool {
        self.pre.is_empty() && self.post.is_empty() && self.edges.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::digraph::Edge;

    fn set(xs: &[Gid]) -> BTreeSet<Gid> {
        xs.iter().copied().collect()
    }

    /// The paper's Fig 3 example topology (small directed graph).
    fn sample() -> DiGraph {
        DiGraph::new(
            6,
            vec![
                Edge { pre: 0, post: 2, weight: 1.0, delay: 1 },
                Edge { pre: 1, post: 2, weight: 1.0, delay: 1 },
                Edge { pre: 1, post: 3, weight: 1.0, delay: 1 },
                Edge { pre: 2, post: 4, weight: 1.0, delay: 1 },
                Edge { pre: 3, post: 4, weight: 1.0, delay: 1 },
                Edge { pre: 5, post: 0, weight: 1.0, delay: 1 },
            ],
        )
    }

    #[test]
    fn indegree_subgraph_definition() {
        let g = sample();
        // in-S({2, 3}): edges onto 2 or 3; pres are their sources
        let s = SubGraph::of(&g, SubGraphKind::In, &set(&[2, 3]));
        assert_eq!(s.post, set(&[2, 3]));
        assert_eq!(s.pre, set(&[0, 1]));
        assert_eq!(s.edges.len(), 3);
    }

    #[test]
    fn outdegree_subgraph_definition() {
        let g = sample();
        let s = SubGraph::of(&g, SubGraphKind::Out, &set(&[1, 2]));
        assert_eq!(s.pre, set(&[1, 2]));
        assert_eq!(s.post, set(&[2, 3, 4]));
        assert_eq!(s.edges.len(), 3);
    }

    #[test]
    fn spiking_subgraph_eq11() {
        let g = sample();
        let s = SubGraph::of(&g, SubGraphKind::In, &set(&[2, 3, 4]));
        let sp = s.spiking(&set(&[1]));
        // only edges 1->2 and 1->3 remain
        assert_eq!(sp.edges, [(1, 2), (1, 3)].into_iter().collect());
        assert_eq!(sp.pre, set(&[1]));
        assert_eq!(sp.post, set(&[2, 3]));
    }

    #[test]
    fn spiking_of_nonspiking_is_empty() {
        let g = sample();
        let s = SubGraph::of(&g, SubGraphKind::In, &set(&[2]));
        assert!(s.spiking(&set(&[4, 5])).is_empty());
    }
}
