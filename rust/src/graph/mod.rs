//! Graph abstraction of spiking neural networks (paper §II.A).
//!
//! Vertices are neurons, directed edges are synaptic interactions
//! (pre → post) carrying a weight and an integer synaptic delay.
//!
//! - [`DiGraph`] — the concrete network with CSR adjacency both ways.
//! - [`SubGraph`] — the (pre, post, edges) triplets of eq. (4)-(6) in
//!   indegree / outdegree form, over explicit vertex sets.
//! - [`algebra`] — the ⊼ (meet/∩) and ⊻ (join/∪) operations of eq. (7) and
//!   the homomorphism of eq. (8), with the property tests establishing the
//!   paper's central argument: indegree sub-graphs over disjoint vertex
//!   sets have **disjoint write sets** (eq. 14), outdegree sub-graphs do
//!   not (eq. 15) — hence "indegree sub-graphs should be the only choice".

pub mod algebra;
mod digraph;
mod subgraph;

pub use digraph::{DiGraph, Edge};
pub use subgraph::{SubGraph, SubGraphKind};
