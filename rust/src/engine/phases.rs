//! The per-step compute phases (paper Fig 17's circulatory dataflow),
//! each operating on one worker's permanently-owned [`WorkerCtx`]:
//!
//! 1. [`deliver`] — walk the thread's delay-sorted edge runs for every
//!    pending spike, accumulating weights into ring slots `emit + delay`
//!    (applying STDP depression at extrapolated arrival time);
//! 2. [`gather_inputs`] + [`integrate`] — consume the rings' due slot
//!    plus Poisson drive and advance every population block's dynamics
//!    (model-generic dispatch, one branch per block; LIF / AdEx / HH /
//!    parrot inner loops stay branch-free SoA), collecting new spikes;
//! 3. [`potentiate_post`] — a spiking post potentiates its incoming
//!    plastic edges. This is the **single** plasticity kernel: the native
//!    worker path and the engine-side PJRT path both call it — and it
//!    keys off the generic spike event, never off model internals, so
//!    STDP works on any spiking population.
//!
//! Every function here reads the **shared immutable topology** through
//! `ctx.topo` (never writes it — N ensemble trajectories step over the
//! same store concurrently) and writes only the per-trajectory
//! [`TrajectoryState`] of the context it was handed — the mutex-free
//! ownership discipline is enforced by what the signatures can reach,
//! plus the paper's optional runtime Abort check (`ctx.verify`). The
//! one historically-mutable store field, the plastic weights, lives in
//! `ctx.state.weights` (a private copy on STDP nets).

use std::time::Instant;

use crate::decomp::ThreadEdges;
use crate::model::dynamics::NeuronModel;
use crate::model::stdp::{StdpParams, TraceSet};
use crate::Step;

use super::workers::{StdpRank, StepJob, TrajectoryState, WorkerCtx};

/// Run one worker's share of a step: deliver, then (on the native
/// backend) integrate and apply plasticity. On the PJRT backend workers
/// only deliver; the engine thread drives the AOT artifact afterwards.
pub(crate) fn run_compute(
    ctx: &mut WorkerCtx,
    job: &StepJob,
    native: bool,
) {
    ctx.state.spikes.clear();
    ctx.model_ns = [0; NeuronModel::COUNT];
    let t0 = Instant::now();
    deliver(ctx, job);
    ctx.phase_ns[0] = t0.elapsed().as_nanos() as u64;
    let t1 = Instant::now();
    if native {
        gather_inputs(ctx, job.now);
        integrate(ctx);
        if let Some(stdp) = &job.stdp {
            plasticity(ctx, stdp, job.now);
        }
    }
    ctx.phase_ns[1] = t1.elapsed().as_nanos() as u64;
}

/// Phase 1: route every pending spike through this thread's edge runs.
/// Ring slots advance monotonically within a delay-sorted run (paper
/// Fig 12b/15), so the wrap is a subtract, not a division per edge.
///
/// Weights come from the trajectory's private copy on plastic nets
/// (read-modify-write) and straight from the shared store otherwise
/// (read-only — the branch is per-run-invariant and predicted away).
fn deliver(ctx: &mut WorkerCtx, job: &StepJob) {
    let (lo, hi) = (ctx.lo, ctx.hi);
    let (verify, t) = (ctx.verify, ctx.t);
    let params = job.stdp.as_ref().map(|s| s.params);
    let WorkerCtx { topo, state, .. } = ctx;
    let te: &ThreadEdges = &topo.threads[t];
    let TrajectoryState { ring_e, ring_i, post_traces, weights, .. } =
        state;
    let mut weights = weights.as_deref_mut();
    let ring_len = ring_e.len as Step;
    for &(p, emit) in &job.pending {
        let run = te.run(p as usize);
        if run.is_empty() {
            continue;
        }
        let mut prev_delay = te.delay[run.start] as Step;
        let mut slot = ((emit + prev_delay) % ring_len) as usize;
        for ei in run {
            let post = te.post[ei];
            if verify && !(post >= lo && post < hi) {
                // the paper's verification: Abort
                panic!(
                    "DATA RACE: thread {t} touched post {post} \
                     outside [{lo},{hi})"
                );
            }
            let delay = te.delay[ei] as Step;
            debug_assert!(delay >= prev_delay);
            slot += (delay - prev_delay) as usize;
            while slot >= ring_len as usize {
                slot -= ring_len as usize;
            }
            prev_delay = delay;
            let lp = (post - lo) as usize;
            let mut w = match &weights {
                Some(ws) => ws[ei],
                None => te.weight[ei],
            };
            if let (Some(params), Some(pt), Some(ws)) =
                (params.as_ref(), post_traces.as_ref(), weights.as_mut())
            {
                if te.plastic.get(ei) {
                    // depression at (extrapolated) arrival time
                    let x = pt.at(lp as u32, emit + delay);
                    w = params.depress(w, x);
                    ws[ei] = w;
                }
            }
            if w >= 0.0 {
                ring_e.add_at(lp, slot, w);
            } else {
                ring_i.add_at(lp, slot, w);
            }
        }
    }
}

/// Stage this step's synaptic input: drain the rings' due slot and add
/// the Poisson drive into the worker's scratch buffers. Shared by the
/// native integrate phase and the engine-side PJRT path.
///
/// The drive is batched per homogeneous run of identical prepared
/// drives (populations tile the worker span, so runs are long): the
/// off/λ/sign tests hoist out of the per-neuron loop while each sample
/// stays the same pure function of `(seed, gid, step)`, so
/// decomposition-independence is untouched. Negative-weight drives are
/// inhibitory and land in `scratch_i` — the seed engine silently
/// dropped them.
pub(crate) fn gather_inputs(ctx: &mut WorkerCtx, now: Step) {
    let seed = ctx.state.seed;
    let now_slot = ctx.state.ring_e.slot(now);
    let (lo, hi) = (ctx.lo as usize, ctx.hi as usize);
    let WorkerCtx { topo, state, .. } = ctx;
    let posts = &topo.posts[lo..hi];
    let TrajectoryState {
        ring_e, ring_i, drives, scratch_e, scratch_i, ..
    } = state;
    let n = drives.len();
    // drain the rings' due slot …
    for i in 0..n {
        scratch_e[i] = ring_e.take_at(i, now_slot);
        scratch_i[i] = ring_i.take_at(i, now_slot);
    }
    // … then add the drive, one homogeneous run at a time
    let mut start = 0usize;
    while start < n {
        let d = drives[start];
        let mut end = start + 1;
        while end < n && drives[end] == d {
            end += 1;
        }
        if !d.is_off() {
            if d.weight_pa >= 0.0 {
                for i in start..end {
                    scratch_e[i] += d.sample(seed, posts[i], now);
                }
            } else {
                for i in start..end {
                    scratch_i[i] += d.sample(seed, posts[i], now);
                }
            }
        }
        start = end;
    }
}

/// Phase 2 (native backend): advance the owned population blocks one
/// step, dispatching on each block's neuron model. Blocks tile the
/// worker span in order, so spikes come out ascending by local index —
/// exactly the order the old single-LIF-block loop produced. (A fused
/// ring+drive+integrate single pass was tried and measured slower — see
/// EXPERIMENTS.md §Perf.)
fn integrate(ctx: &mut WorkerCtx) {
    let mode = ctx.integrate;
    let model_ns = &mut ctx.model_ns;
    let TrajectoryState {
        blocks, scratch_e, scratch_i, tables, spikes, ..
    } = &mut ctx.state;
    for b in blocks.iter_mut() {
        let lo = b.offset as usize;
        let hi = lo + b.state.len();
        let t0 = Instant::now();
        b.state.step_block(
            &scratch_e[lo..hi],
            &scratch_i[lo..hi],
            tables,
            b.pidx,
            b.offset,
            mode,
            spikes,
        );
        // one clock pair per block per step — the per-model
        // ns/neuron-step instrument, far off the per-neuron path
        model_ns[b.state.model().index()] +=
            t0.elapsed().as_nanos() as u64;
    }
}

/// Phase 3 (native backend): potentiate for every spike this worker just
/// collected.
fn plasticity(ctx: &mut WorkerCtx, stdp: &StdpRank, now: Step) {
    let WorkerCtx { topo, state, t, .. } = ctx;
    let te: &ThreadEdges = &topo.threads[*t];
    let TrajectoryState { post_traces, weights, spikes, .. } = state;
    let pt = post_traces.as_mut().expect("stdp net without post traces");
    let ws = weights.as_deref_mut().expect("stdp net without weight copy");
    for &ls in spikes.iter() {
        potentiate_post(te, ws, pt, &stdp.pre_traces, &stdp.params, ls, now);
    }
}

/// A post spike potentiates its incoming plastic edges (thread-owned) and
/// bumps the post trace. `ls` is the worker-local post index. The single
/// shared kernel behind both the native and PJRT plasticity paths.
/// Topology (`edges`) is read-only; the mutated weights are the
/// trajectory's private copy (`ws`, indexed like `edges.weight`).
pub(crate) fn potentiate_post(
    edges: &ThreadEdges,
    ws: &mut [f64],
    post_traces: &mut TraceSet,
    pre_traces: &TraceSet,
    params: &StdpParams,
    ls: u32,
    now: Step,
) {
    let b = ls as usize;
    let r0 = edges.plastic_by_post_offsets[b] as usize;
    let r1 = edges.plastic_by_post_offsets[b + 1] as usize;
    for k in r0..r1 {
        let ei = edges.plastic_by_post_edge[k] as usize;
        let x = pre_traces.at(edges.epre[ei], now);
        ws[ei] = params.potentiate(ws[ei], x);
    }
    post_traces.bump(ls, now);
}
