//! Persistent compute workers (paper §III.B: "long-lived compute threads
//! over thread-owned, provably disjoint state").
//!
//! A [`WorkerCtx`] is everything one compute thread touches during the
//! deliver / integrate / plasticity phases, split along the ownership
//! boundary the ensemble runtime exploits: the **shared, immutable
//! topology** (an `Arc<RankStore>` holding every thread's
//! [`ThreadEdges`] share of the indegree sub-graph, the post/pre gid
//! maps and the thread ranges) and the **per-trajectory mutable state**
//! ([`TrajectoryState`]: neuron-model state blocks, both input rings,
//! STDP post-traces and the private plastic-weight copy, Poisson drives,
//! interned model tables, scratch buffers, the spike outbox and the
//! drive seed). The context is built **once** per trajectory in
//! `RankEngine::new` — the store is *shared* (via `Arc`), never moved or
//! re-borrowed with `split_at_mut` every step — and thereafter the
//! engine only hands whole contexts around, never slices. N trajectories
//! over one built network differ only in their `TrajectoryState`.
//!
//! Neuron dynamics are model-generic: the worker's contiguous post range
//! is segmented into [`PopBlock`]s, one per population run, each holding
//! a [`PopulationState`] (LIF / AdEx / HH / parrot SoA block). The
//! integrate phase dispatches once per block; the per-model inner loops
//! stay branch-free. Because a rank's posts are sorted by gid and
//! populations tile the gid space, a worker holds at most one block per
//! population and blocks tile the worker span in order.
//!
//! [`WorkerPool`] holds the long-lived OS threads. Each step the engine
//! transfers every context (plus one shared, read-only [`StepJob`]) to
//! its worker over a channel and receives the contexts back when the
//! phases are done; workers park in `recv` between steps. Two channel
//! operations per worker per step replace the spawn/join pair the old
//! scoped-thread engine paid every 0.1 ms of biological time, and the
//! ownership transfer is what keeps the hot loop free of any mutex or
//! atomic: while a worker holds its context, nothing else can reach that
//! state, by construction.
//!
//! The `StepJob` round-trips too: the engine moves the pending-spike list
//! and the rank-level STDP state (params + read-only pre-traces) into an
//! `Arc`, every worker drops its clone before handing its context back,
//! and the engine unwraps the `Arc` to reclaim both — no locks, no
//! copies, and the borrow checker stays happy across the 'static thread
//! boundary.
//!
//! Since the session API redesign the same ownership-transfer discipline
//! repeats one level up: the engine itself (pool included) is owned by a
//! session rank thread (`engine::session`) and driven over channels, so
//! pools now live for a whole session of repeated `run_for` calls, not
//! one batch run.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::atlas::NetworkSpec;
use crate::config::IntegrateMode;
use crate::decomp::{
    BuildPart, BuildRunner, BuildTask, RankStore, ThreadEdges,
};
use crate::engine::ring::InputRing;
use crate::model::dynamics::{ModelTables, NeuronModel, PopulationState};
use crate::model::poisson::PreparedPoisson;
use crate::model::stdp::{StdpParams, TraceSet};
use crate::{Gid, Step};

use super::phases;

/// Rank-level plasticity state: the STDP rule plus the pre-synaptic
/// traces of **all** sources (local + remote). Pre-traces are read-only
/// during the parallel phases and updated by the engine thread between
/// steps, so they ride along in the [`StepJob`] rather than being split.
pub(crate) struct StdpRank {
    pub params: StdpParams,
    pub pre_traces: TraceSet,
}

/// The read-only state every worker needs for one integration step.
/// Moved (not copied) out of the engine for the duration of the parallel
/// phases and reclaimed afterwards.
pub(crate) struct StepJob {
    pub now: Step,
    /// Spikes awaiting delivery: (pre index, emission step).
    pub pending: Vec<(u32, Step)>,
    pub stdp: Option<StdpRank>,
}

/// One population's share of a worker span: a contiguous run of posts
/// from the same population, with its model state block.
pub(crate) struct PopBlock {
    /// Population index in the spec.
    pub pop: u16,
    /// Parameter-table index (== the population's `params`).
    pub pidx: u8,
    /// Block start within the worker span (local offset).
    pub offset: u32,
    /// The model-generic SoA state of the block's neurons.
    pub state: PopulationState,
}

/// Everything one worker mutates while stepping **one trajectory**.
///
/// This is the carve-out that makes ensembles cheap: a second
/// trajectory over the same built network costs one of these per
/// thread (state blocks, rings, traces, drives, interned tables, a
/// plastic-weight copy on STDP nets) — never a second CSR store.
pub(crate) struct TrajectoryState {
    /// Model state of the owned posts, one block per population run,
    /// tiling `[0, hi - lo)` in order.
    pub blocks: Vec<PopBlock>,
    /// Excitatory / inhibitory input rings for the owned posts.
    pub ring_e: InputRing,
    pub ring_i: InputRing,
    /// STDP traces of the owned posts (locally indexed; STDP nets only).
    pub post_traces: Option<TraceSet>,
    /// Poisson drives of the owned posts.
    pub drives: Vec<PreparedPoisson>,
    /// Model dispatch tables (per-trajectory copy: DC stimulus interns
    /// shifted parameter sets into it mid-run).
    pub tables: ModelTables,
    /// Private plastic-weight copy, `Some` iff the net has STDP: the
    /// only part of [`ThreadEdges`] that mutates during stepping, so
    /// it is the only part a trajectory owns. Indexed exactly like
    /// `threads[t].weight`; `None` ⇒ read the shared immutable weights.
    pub weights: Option<Vec<f64>>,
    /// Per-step input staging (no per-step allocation).
    pub scratch_e: Vec<f64>,
    pub scratch_i: Vec<f64>,
    /// Local indices (relative to `lo`) of this step's spikes.
    pub spikes: Vec<u32>,
    /// Drive seed (Poisson drive hashing) — the per-trajectory noise
    /// stream; defaults to the spec's network seed.
    pub seed: u64,
}

impl TrajectoryState {
    /// Actual heap bytes of everything this trajectory owns for one
    /// worker span (the marginal cost of one more ensemble member).
    pub fn bytes(&self) -> u64 {
        use crate::metrics::memory::vec_bytes;
        let mut b = self.blocks.iter().map(|x| x.state.bytes()).sum::<u64>();
        b += self.ring_e.bytes() + self.ring_i.bytes();
        if let Some(pt) = &self.post_traces {
            b += pt.bytes();
        }
        b += vec_bytes(&self.drives);
        if let Some(w) = &self.weights {
            b += vec_bytes(w);
        }
        b += vec_bytes(&self.scratch_e) + vec_bytes(&self.scratch_i);
        b
    }
}

/// One compute thread's permanently-owned share of the rank: a handle
/// into the shared topology plus its private [`TrajectoryState`].
pub(crate) struct WorkerCtx {
    /// Worker index (== thread id in the decomposition).
    pub t: usize,
    /// Owned local-post range `[lo, hi)`.
    pub lo: u32,
    pub hi: u32,
    /// The shared, immutable build product. This worker's
    /// (pre, delay)-sorted edge store is `topo.threads[t]`; read-only
    /// during stepping (plastic weights live in `state.weights`).
    pub topo: Arc<RankStore>,
    /// Everything mutable per trajectory.
    pub state: TrajectoryState,
    /// [deliver_ns, integrate+plasticity_ns] of the last step.
    pub phase_ns: [u64; 2],
    /// Integrate nanoseconds of the last step, split per neuron model
    /// (indexed by [`NeuronModel::index`]); feeds the runtime
    /// ns/neuron-step metric.
    pub model_ns: [u64; NeuronModel::COUNT],
    /// Kernel formulation of the integrate phase (vector / scalar).
    pub integrate: IntegrateMode,
    /// Compile the paper's thread-ownership abort check into delivery.
    pub verify: bool,
}

impl WorkerCtx {
    /// Number of owned posts.
    pub fn span(&self) -> usize {
        (self.hi - self.lo) as usize
    }

    /// This worker's share of the shared edge store.
    pub fn edges(&self) -> &ThreadEdges {
        &self.topo.threads[self.t]
    }

    /// Gids of the owned posts (indexed by local offset `i = post - lo`).
    pub fn posts(&self) -> &[Gid] {
        &self.topo.posts[self.lo as usize..self.hi as usize]
    }

    /// Actual heap bytes of the neuron-model state blocks.
    pub fn state_bytes(&self) -> u64 {
        self.state.blocks.iter().map(|b| b.state.bytes()).sum()
    }
}

/// Segment a worker's posts into per-population blocks (posts are gid-
/// sorted and populations tile the gid space, so runs are maximal).
fn build_blocks(
    spec: &NetworkSpec,
    tables: &ModelTables,
    posts: &[Gid],
) -> Vec<PopBlock> {
    let mut blocks = Vec::new();
    let mut start = 0usize;
    while start < posts.len() {
        let pop = spec.pop_of(posts[start]);
        let mut end = start + 1;
        while end < posts.len() && spec.pop_of(posts[end]) == pop {
            end += 1;
        }
        let pidx = spec.populations[pop as usize].params;
        let mut state = PopulationState::new(tables, pidx, end - start);
        for (i, &g) in posts[start..end].iter().enumerate() {
            state.set_v_init(i, spec.v_init(g));
        }
        blocks.push(PopBlock { pop, pidx, offset: start as u32, state });
        start = end;
    }
    blocks
}

/// Build all worker contexts for one trajectory over a (possibly
/// shared) built store: every context holds an `Arc` of the topology
/// plus a freshly-initialized [`TrajectoryState`] split along the
/// decomposition's thread ranges. The store itself is never mutated —
/// N trajectories can run these contexts concurrently over one build.
pub(crate) fn build_worker_ctxs(
    spec: &NetworkSpec,
    store: &Arc<RankStore>,
    integrate: IntegrateMode,
    verify: bool,
    drive_seed: u64,
) -> Vec<WorkerCtx> {
    let tables = spec.model_tables();
    let ring_len = (store.max_delay as usize + 1).max(2);
    assert!(!store.threads.is_empty(), "store must have >= 1 thread");
    store
        .thread_ranges
        .iter()
        .enumerate()
        .map(|(t, &(lo, hi))| {
            let span = (hi - lo) as usize;
            let posts = &store.posts[lo as usize..hi as usize];
            let blocks = build_blocks(spec, &tables, posts);
            debug_assert_eq!(
                blocks.iter().map(|b| b.state.len()).sum::<usize>(),
                span
            );
            let drives: Vec<PreparedPoisson> = posts
                .iter()
                .map(|&g| spec.drive(g).prepare(spec.dt_ms))
                .collect();
            let post_traces = spec.stdp.map(|p| {
                TraceSet::new(span, p.tau_minus_ms, spec.dt_ms)
            });
            // STDP mutates weights during stepping — give the
            // trajectory its own copy; static nets read the shared
            // store's weights directly (the ensemble memory win)
            let weights = spec
                .stdp
                .map(|_| store.threads[t].weight.clone());
            WorkerCtx {
                t,
                lo,
                hi,
                topo: Arc::clone(store),
                state: TrajectoryState {
                    blocks,
                    ring_e: InputRing::new(span, ring_len),
                    ring_i: InputRing::new(span, ring_len),
                    post_traces,
                    drives,
                    tables: tables.clone(),
                    weights,
                    scratch_e: vec![0.0; span],
                    scratch_i: vec![0.0; span],
                    spikes: Vec::new(),
                    seed: drive_seed,
                },
                phase_ns: [0, 0],
                model_ns: [0; NeuronModel::COUNT],
                integrate,
                verify,
            }
        })
        .collect()
}

/// One unit of work for a pooled thread: a simulation step over its
/// context, or a store-construction task (`decomp::store`'s build
/// passes run on the same threads that later step — the pool exists
/// before the contexts it will eventually own).
///
/// The `Step` variant is deliberately unboxed: it crosses the channel
/// once per worker per step, and the context move is the whole point
/// of the ownership-transfer design — an indirection here would put an
/// allocation on the hot path to quiet a size-difference lint.
#[allow(clippy::large_enum_variant)]
enum Job {
    Step(WorkerCtx, Arc<StepJob>),
    Build(BuildTask),
}

/// A worker's answer, by job kind. Build results carry the worker index
/// because completions arrive over one shared channel in any order.
#[allow(clippy::large_enum_variant)]
enum Done {
    Step(WorkerCtx),
    Build(usize, BuildPart),
}

/// A worker's result: its answer, or the payload of its panic (the
/// paper's ownership-verification Abort re-raises on the engine
/// thread).
type DoneMsg = std::thread::Result<Done>;

/// The rank's long-lived compute threads, created once per engine.
pub(crate) struct WorkerPool {
    jobs: Vec<Sender<Job>>,
    done_rx: Receiver<DoneMsg>,
    handles: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    pub fn spawn(n_workers: usize, native: bool) -> WorkerPool {
        let (done_tx, done_rx) = channel::<DoneMsg>();
        let mut jobs = Vec::with_capacity(n_workers);
        let mut handles = Vec::with_capacity(n_workers);
        for t in 0..n_workers {
            let (tx, rx) = channel::<Job>();
            let done = done_tx.clone();
            let handle = std::thread::Builder::new()
                .name(format!("cortex-worker-{t}"))
                .spawn(move || worker_loop(t, rx, done, native))
                .expect("failed to spawn compute worker");
            jobs.push(tx);
            handles.push(handle);
        }
        WorkerPool { jobs, done_rx, handles }
    }

    /// Drive one step: transfer every context (and a shared clone of the
    /// job) to its worker, collect the contexts back in thread order, and
    /// reclaim the job. Blocks until all workers finish their phases.
    pub fn run_step(
        &self,
        ctxs: &mut Vec<WorkerCtx>,
        job: StepJob,
    ) -> StepJob {
        let n = self.jobs.len();
        debug_assert_eq!(ctxs.len(), n);
        let job = Arc::new(job);
        for (tx, ctx) in self.jobs.iter().zip(ctxs.drain(..)) {
            tx.send(Job::Step(ctx, Arc::clone(&job)))
                .expect("compute worker hung up");
        }
        for _ in 0..n {
            match self.done_rx.recv().expect("compute worker died") {
                Ok(Done::Step(ctx)) => ctxs.push(ctx),
                Ok(Done::Build(..)) => {
                    unreachable!("build result during a step")
                }
                Err(panic) => std::panic::resume_unwind(panic),
            }
        }
        // received in completion order; engine-side phases (spike
        // collection, checkpointing) require deterministic thread order
        ctxs.sort_unstable_by_key(|c| c.t);
        Arc::try_unwrap(job)
            .unwrap_or_else(|_| unreachable!("workers still hold the job"))
    }

    /// Run one build pass on the pool: task `t` executes on worker `t`
    /// (the thread that will own the resulting state), results return
    /// in task order. Blocks until every task completes; a task panic
    /// re-raises here after all siblings have reported, so the done
    /// channel never desynchronizes from the next step.
    pub fn run_build(&self, tasks: Vec<BuildTask>) -> Vec<BuildPart> {
        let n = self.jobs.len();
        assert_eq!(tasks.len(), n, "one build task per worker");
        for (tx, task) in self.jobs.iter().zip(tasks) {
            tx.send(Job::Build(task)).expect("compute worker hung up");
        }
        let mut out: Vec<Option<BuildPart>> =
            (0..n).map(|_| None).collect();
        let mut panic: Option<Box<dyn std::any::Any + Send>> = None;
        for _ in 0..n {
            match self.done_rx.recv().expect("compute worker died") {
                Ok(Done::Build(t, part)) => out[t] = Some(part),
                Ok(Done::Step(_)) => {
                    unreachable!("step result during a build pass")
                }
                Err(p) => {
                    panic.get_or_insert(p);
                }
            }
        }
        if let Some(p) = panic {
            std::panic::resume_unwind(p);
        }
        out.into_iter()
            .map(|o| o.expect("worker skipped its build task"))
            .collect()
    }
}

impl BuildRunner for WorkerPool {
    fn run(&self, tasks: Vec<BuildTask>) -> Vec<BuildPart> {
        self.run_build(tasks)
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        // hang up the job channels; workers fall out of their recv loop
        self.jobs.clear();
        for handle in self.handles.drain(..) {
            let _ = handle.join();
        }
    }
}

fn worker_loop(
    t: usize,
    rx: Receiver<Job>,
    done: Sender<DoneMsg>,
    native: bool,
) {
    while let Ok(job) = rx.recv() {
        let out: DoneMsg = match job {
            Job::Step(mut ctx, job) => {
                let res = std::panic::catch_unwind(
                    std::panic::AssertUnwindSafe(|| {
                        phases::run_compute(&mut ctx, &job, native);
                        ctx
                    }),
                );
                // release the shared step state before handing the
                // context back: the engine unwraps the Arc as soon as
                // all contexts are home
                drop(job);
                res.map(Done::Step)
            }
            Job::Build(task) => std::panic::catch_unwind(
                std::panic::AssertUnwindSafe(task),
            )
            .map(|part| Done::Build(t, part)),
        };
        let failed = out.is_err();
        if done.send(out).is_err() || failed {
            break;
        }
    }
}
