//! Ensemble runtime: build the network **once**, run N trajectories.
//!
//! Network construction dominates short parameter sweeps (the paper's
//! Fig 18 separates build from simulation time for exactly this
//! reason): every [`super::Simulation`] normally partitions the spec
//! and constructs its rank stores from scratch. An [`Ensemble`] hoists
//! that build product — the partition plus one immutable
//! [`RankStore`] per rank, wrapped in a [`SharedNetwork`] of `Arc`s —
//! out of the per-run path, so N trajectories pay for it once:
//!
//! ```text
//!            EnsembleBuilder::build()            (expensive, once)
//!                     │
//!           SharedNetwork (read-only)
//!         partition + Arc<RankStore> per rank
//!           ╱          │          ╲
//!   trajectory()   trajectory()   trajectory()   (cheap, N times)
//!        │              │              │
//!   Simulation     Simulation     Simulation
//!   state only     state only     state only
//!   (rings, neuron state, drives, traces, weights*, RNG, probes)
//! ```
//!
//! Each trajectory owns only its mutable per-trajectory state (see
//! `engine::workers::TrajectoryState`); the store is never written
//! during stepping — plastic nets mutate a private weight copy. A
//! trajectory is **bit-identical** to a standalone session over the
//! same spec/partition issuing the same stimulus schedule: sharing
//! changes ownership, never arithmetic.
//!
//! Trajectories differ by [`TrajectoryBuilder::drive_seed`] (the
//! Poisson noise stream), DC / Poisson stimulus overrides (queued
//! exactly like [`super::Simulation::set_dc`] /
//! [`super::Simulation::set_poisson`] calls before step 0), and
//! probes. `cortex sweep` drives this API from a `[sweep]` config
//! section.
//!
//! ```no_run
//! use std::sync::Arc;
//! use cortex::atlas::random_spec;
//! use cortex::engine::Ensemble;
//! use cortex::probe::PopRates;
//!
//! # fn main() -> anyhow::Result<()> {
//! let spec = Arc::new(random_spec(400, 40, 7));
//! let ens = Ensemble::builder(Arc::clone(&spec))
//!     .ranks(2)
//!     .threads(2)
//!     .build()?;                       // the one expensive build
//! for seed in [1u64, 2, 3, 4] {
//!     let mut sim = ens
//!         .trajectory()
//!         .drive_seed(seed)            // independent noise stream
//!         .probe(PopRates::new("rates", 100))
//!         .build()?;                   // state-only construction
//!     sim.run_for(1000)?;
//!     let rates = sim.drain("rates")?;
//!     # let _ = rates;
//! }
//! # Ok(())
//! # }
//! ```

use std::sync::Arc;
use std::time::Instant;

use anyhow::{ensure, Result};

use crate::atlas::NetworkSpec;
use crate::config::{
    BuildMode, CommMode, IntegrateMode, MappingKind, RoutingMode,
};
use crate::decomp::{
    area_processes_partition, random_equivalent_partition, Partition,
    RankStore,
};
use crate::metrics::MemoryReport;
use crate::probe::Probe;
use crate::Gid;

use super::session::Simulation;
use super::RunConfig;

/// The read-only build product N trajectories share: the partition
/// plus one built [`RankStore`] per rank. Cheap to clone (`Arc`s all
/// the way down); dropped when the last trajectory holding it drops.
#[derive(Clone)]
pub struct SharedNetwork {
    pub(crate) spec: Arc<NetworkSpec>,
    pub(crate) partition: Arc<Partition>,
    pub(crate) stores: Vec<Arc<RankStore>>,
    /// Decomposition thread count the stores were built for — every
    /// trajectory must run with exactly this many workers per rank.
    pub(crate) threads: usize,
    pub(crate) build_seconds: f64,
}

impl SharedNetwork {
    pub fn spec(&self) -> &Arc<NetworkSpec> {
        &self.spec
    }

    pub fn partition(&self) -> &Arc<Partition> {
        &self.partition
    }

    pub fn n_ranks(&self) -> usize {
        self.stores.len()
    }

    /// Worker threads per rank the decomposition was built for.
    pub fn threads(&self) -> usize {
        self.threads
    }

    pub fn store(&self, rank: usize) -> &Arc<RankStore> {
        &self.stores[rank]
    }

    /// Wall time of the one-time network construction (max over the
    /// concurrent per-rank builds).
    pub fn build_seconds(&self) -> f64 {
        self.build_seconds
    }

    /// Per-rank memory of the shared topology alone — counted **once**
    /// no matter how many trajectories share it. Trajectory state is
    /// reported separately by
    /// [`super::RankEngine::trajectory_memory`] (or
    /// [`super::Simulation::memory_split`]).
    pub fn shared_memory(&self) -> MemoryReport {
        MemoryReport::new(
            self.stores.iter().map(|s| s.shared_memory()).collect(),
        )
    }
}

/// Configures the one-time network build. Obtained from
/// [`Ensemble::builder`]; the knobs mirror the build-relevant subset
/// of [`RunConfig`] (and [`Self::run_config`] adopts one wholesale —
/// its per-run fields become the trajectories' defaults).
pub struct EnsembleBuilder {
    spec: Arc<NetworkSpec>,
    cfg: RunConfig,
}

impl EnsembleBuilder {
    fn new(spec: Arc<NetworkSpec>) -> EnsembleBuilder {
        let seed = spec.seed;
        EnsembleBuilder {
            spec,
            cfg: RunConfig {
                ranks: 1,
                threads: 1,
                seed,
                ..RunConfig::default()
            },
        }
    }

    pub fn ranks(mut self, n: usize) -> Self {
        self.cfg.ranks = n;
        self
    }

    pub fn threads(mut self, n: usize) -> Self {
        self.cfg.threads = n;
        self
    }

    pub fn mapping(mut self, m: MappingKind) -> Self {
        self.cfg.mapping = m;
        self
    }

    /// Partition seed (defaults to the spec's network seed). Distinct
    /// from a trajectory's [`TrajectoryBuilder::drive_seed`].
    pub fn seed(mut self, seed: u64) -> Self {
        self.cfg.seed = seed;
        self
    }

    /// Store-construction pipeline (two-pass streaming by default).
    pub fn build_mode(mut self, b: BuildMode) -> Self {
        self.cfg.build = b;
        self
    }

    /// Default exchange mode for trajectories (overridable per
    /// trajectory — it never affects the build).
    pub fn comm(mut self, c: CommMode) -> Self {
        self.cfg.comm = c;
        self
    }

    /// Default integrate-kernel formulation for trajectories.
    pub fn integrate(mut self, m: IntegrateMode) -> Self {
        self.cfg.integrate = m;
        self
    }

    /// Default spike-exchange routing for trajectories.
    pub fn routing(mut self, r: RoutingMode) -> Self {
        self.cfg.routing = r;
        self
    }

    /// Default built-in raster bound for trajectories.
    pub fn record_limit(mut self, limit: Option<Gid>) -> Self {
        self.cfg.record_limit = limit;
        self
    }

    /// Adopt every knob of a [`RunConfig`]: the build-relevant fields
    /// configure the one-time construction, the rest become the
    /// trajectories' defaults.
    pub fn run_config(mut self, cfg: &RunConfig) -> Self {
        self.cfg = cfg.clone();
        self
    }

    /// Partition the network and construct every rank's store, each on
    /// its own thread (mirroring the per-rank concurrency of a session
    /// build). The expensive step — everything after is state-only.
    pub fn build(self) -> Result<Ensemble> {
        let ranks = self.cfg.ranks;
        ensure!(
            ranks >= 1 && ranks <= u16::MAX as usize,
            "ranks must be in 1..=65535"
        );
        ensure!(self.cfg.threads >= 1, "threads must be >= 1");
        let spec = self.spec;
        let partition = Arc::new(match self.cfg.mapping {
            MappingKind::AreaProcesses => {
                area_processes_partition(&spec, ranks, self.cfg.seed)
            }
            MappingKind::RandomEquivalent => random_equivalent_partition(
                spec.n_total(),
                ranks,
                self.cfg.seed,
            ),
        });
        let t0 = Instant::now();
        let (threads, build) = (self.cfg.threads, self.cfg.build);
        let stores: Vec<Arc<RankStore>> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..ranks)
                .map(|r| {
                    let (spec, partition) = (&spec, &partition);
                    s.spawn(move || {
                        build_store(spec, partition, r, threads, build)
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| {
                    Arc::new(h.join().expect("rank store build panicked"))
                })
                .collect()
        });
        let build_seconds = t0.elapsed().as_secs_f64();
        Ok(Ensemble {
            net: SharedNetwork {
                spec,
                partition,
                stores,
                threads,
                build_seconds,
            },
            cfg: self.cfg,
        })
    }
}

/// Build rank `r`'s store exactly as a standalone engine would (same
/// two-pass/serial pipelines, same bit-identical product) — just
/// without an engine around it.
fn build_store(
    spec: &NetworkSpec,
    partition: &Partition,
    r: usize,
    n_threads: usize,
    build: BuildMode,
) -> RankStore {
    let posts = &partition.members[r];
    let rank_of = &partition.rank_of;
    let is_local = move |g: Gid| rank_of[g as usize] as usize == r;
    match build {
        BuildMode::Serial => RankStore::build_serial(
            spec,
            posts,
            is_local,
            r as u16,
            n_threads,
        ),
        BuildMode::TwoPass => {
            RankStore::build(spec, posts, is_local, r as u16, n_threads)
        }
    }
}

/// A built network plus trajectory defaults: the handle `cortex sweep`
/// (and any embedding program) instantiates cheap [`Simulation`]s
/// from. See the [module docs](self).
pub struct Ensemble {
    net: SharedNetwork,
    cfg: RunConfig,
}

impl Ensemble {
    /// Start configuring an ensemble over `spec`.
    pub fn builder(spec: Arc<NetworkSpec>) -> EnsembleBuilder {
        EnsembleBuilder::new(spec)
    }

    /// The shared read-only build product (cloneable; hold it to keep
    /// the stores alive independently of the `Ensemble`).
    pub fn network(&self) -> &SharedNetwork {
        &self.net
    }

    /// Wall time of the one-time network construction.
    pub fn build_seconds(&self) -> f64 {
        self.net.build_seconds
    }

    /// Memory of the shared topology, counted once for all trajectories.
    pub fn shared_memory(&self) -> MemoryReport {
        self.net.shared_memory()
    }

    /// Start configuring one trajectory: a full [`Simulation`] over the
    /// shared stores, differing only in per-trajectory state.
    pub fn trajectory(&self) -> TrajectoryBuilder {
        let builder = Simulation::builder(Arc::clone(&self.net.spec))
            .run_config(&self.cfg)
            .shared(self.net.clone());
        TrajectoryBuilder {
            builder,
            dc: Vec::new(),
            poisson: Vec::new(),
        }
    }
}

/// Configures one trajectory of an [`Ensemble`]. Build-time knobs
/// (ranks, threads, mapping, partition seed) are fixed by the shared
/// network; what varies here is the trajectory's noise stream,
/// stimulus overrides, probes, and exchange mode.
pub struct TrajectoryBuilder {
    builder: super::session::SimulationBuilder,
    dc: Vec<(String, f64)>,
    poisson: Vec<(String, f64, f64)>,
}

impl TrajectoryBuilder {
    /// This trajectory's Poisson noise stream (defaults to the spec's
    /// network seed — i.e. identical to a standalone session).
    pub fn drive_seed(mut self, seed: u64) -> Self {
        self.builder = self.builder.drive_seed(seed);
        self
    }

    /// Queue a DC offset for `pop` (name or prefix), applied before
    /// step 0 — exactly [`Simulation::set_dc`] issued at build.
    pub fn dc(mut self, pop: &str, dc_pa: f64) -> Self {
        self.dc.push((pop.into(), dc_pa));
        self
    }

    /// Queue a Poisson drive override for `pop`, applied before step 0
    /// — exactly [`Simulation::set_poisson`] issued at build.
    pub fn poisson(
        mut self,
        pop: &str,
        rate_hz: f64,
        weight_pa: f64,
    ) -> Self {
        self.poisson.push((pop.into(), rate_hz, weight_pa));
        self
    }

    /// Exchange mode for this trajectory (ablation knob; bit-identical
    /// either way).
    pub fn comm(mut self, c: CommMode) -> Self {
        self.builder = self.builder.comm(c);
        self
    }

    /// Built-in raster bound for this trajectory.
    pub fn record_limit(mut self, limit: Option<Gid>) -> Self {
        self.builder = self.builder.record_limit(limit);
        self
    }

    /// Register a probe on this trajectory (cloned onto every rank).
    pub fn probe<P>(mut self, probe: P) -> Self
    where
        P: Probe + Clone + Sync + 'static,
    {
        self.builder = self.builder.probe(probe);
        self
    }

    /// Register a probe via an explicit per-rank factory.
    pub fn probe_with(
        mut self,
        name: &str,
        make: impl Fn(u16) -> Box<dyn Probe> + Send + Sync + 'static,
    ) -> Self {
        self.builder = self.builder.probe_with(name, make);
        self
    }

    /// Construct the trajectory's [`Simulation`]: per-trajectory state
    /// only (rings, neuron state, drives, traces, weight copies on
    /// plastic nets), then the queued stimulus overrides.
    pub fn build(self) -> Result<Simulation> {
        let mut sim = self.builder.build()?;
        for (pop, dc_pa) in &self.dc {
            sim.set_dc(pop, *dc_pa)?;
        }
        for (pop, rate_hz, weight_pa) in &self.poisson {
            sim.set_poisson(pop, *rate_hz, *weight_pa)?;
        }
        Ok(sim)
    }
}
