//! Spike-exchange driver (paper §III.C): serialized (blocking at window
//! end) or overlapped via a dedicated communication thread.
//!
//! The communication thread is the one standing thread the engine owns
//! besides its compute worker pool; the session's rank loop
//! (`engine::session`) synchronizes the two at window boundaries — the
//! pool's workers compute window `k` while the comm thread exchanges
//! window `k-1`'s spikes (paper §III.C.2).
//!
//! With a [`RoutingTable`] installed (`engine.routing = "routed"`) the
//! driver splits each submitted packet into per-destination subsets
//! before handing it to the transport. In overlap mode the split runs
//! **on the communication thread**, so both the routing work and the
//! wire exchange overlap the next window's compute; the rank loop's
//! `submit` stays a channel send either way.
//!
//! Exchange failures ([`CommError`]: window misalignment, malformed
//! wire frames, lost peers) propagate through [`CommDriver::submit`] /
//! [`CommDriver::recv_completed`] as errors — in overlap mode the
//! communication thread forwards the error over its response channel
//! and exits, so a poisoned transport surfaces on the rank loop instead
//! of panicking a detached thread.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use crate::comm::{
    CommError, Communicator, Outbound, RoutingTable, SpikePacket,
};
use crate::config::CommMode;

/// Measured exchange accounting of one driver: how long the exchanges
/// themselves took (`busy_ns`, routing + wire time) and how much of
/// that the rank loop actually spent blocked (`wait_ns`). Serialized
/// drivers block for every nanosecond (`wait == busy`); an overlapped
/// driver's gap between the two is exchange time hidden behind
/// compute.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub(crate) struct CommStats {
    /// ns the transport spent inside `exchange_outbound` (plus the
    /// routing split, which runs on the same thread as the exchange).
    pub busy_ns: u64,
    /// ns the rank loop spent blocked on a completed exchange.
    pub wait_ns: u64,
}

impl CommStats {
    /// Fraction of exchange time hidden behind compute:
    /// `(busy − wait) / busy`, 0 when nothing was exchanged (and for
    /// any serialized driver, which hides nothing by construction).
    pub fn overlap_ratio(&self) -> f64 {
        if self.busy_ns == 0 {
            0.0
        } else {
            self.busy_ns.saturating_sub(self.wait_ns) as f64
                / self.busy_ns as f64
        }
    }
}

/// Split a window packet per destination if a routing table is
/// installed, else broadcast it whole.
fn outbound_of(
    routing: Option<&RoutingTable>,
    pkt: SpikePacket,
) -> Outbound {
    match routing {
        Some(rt) => Outbound::Routed(rt.route(&pkt)),
        None => Outbound::Broadcast(pkt),
    }
}

/// Spike-exchange driver: one per rank, owned by its session rank
/// thread (`engine::session::RankRuntime`).
pub(crate) enum CommDriver {
    Serialized {
        comm: Box<dyn Communicator>,
        routing: Option<RoutingTable>,
        staged: Option<SpikePacket>,
        busy_ns: u64,
    },
    Overlap {
        req: Sender<SpikePacket>,
        resp: Receiver<Result<SpikePacket, CommError>>,
        handle: JoinHandle<Box<dyn Communicator>>,
        /// Exchanges submitted but not yet received. The request
        /// channel double-buffers outbound windows: up to
        /// [`Self::STAGING_DEPTH`] may be in flight, so the rank loop
        /// can stage window `k`'s packet while `k-1` is still on the
        /// wire.
        in_flight: usize,
        busy_ns: Arc<AtomicU64>,
        wait_ns: u64,
    },
}

impl CommDriver {
    /// Outbound windows that may be submitted ahead of their receive
    /// (overlap mode): the one on the wire plus one staged behind it.
    pub const STAGING_DEPTH: usize = 2;

    /// `routing: None` keeps the broadcast allgather (the ablation
    /// baseline and the only shape `SoloComm` ever sees).
    pub fn new(
        comm: Box<dyn Communicator>,
        mode: CommMode,
        routing: Option<RoutingTable>,
    ) -> CommDriver {
        match mode {
            CommMode::Serialized => CommDriver::Serialized {
                comm,
                routing,
                staged: None,
                busy_ns: 0,
            },
            CommMode::Overlap => {
                let (req_tx, req_rx) = channel::<SpikePacket>();
                let (resp_tx, resp_rx) =
                    channel::<Result<SpikePacket, CommError>>();
                let busy = Arc::new(AtomicU64::new(0));
                let busy_in_thread = Arc::clone(&busy);
                let mut comm = comm;
                let handle = std::thread::spawn(move || {
                    // the dedicated communication thread: drains exchange
                    // requests until the engine hangs up or the transport
                    // errors out (the error is forwarded, then the thread
                    // exits — its endpoint is poisoned). Routing the
                    // packet happens here too, off the rank loop.
                    while let Ok(pkt) = req_rx.recv() {
                        let t = Instant::now();
                        let out = outbound_of(routing.as_ref(), pkt);
                        let got = comm.exchange_outbound(out);
                        busy_in_thread.fetch_add(
                            t.elapsed().as_nanos() as u64,
                            Ordering::Relaxed,
                        );
                        let failed = got.is_err();
                        if resp_tx.send(got).is_err() || failed {
                            break;
                        }
                    }
                    comm
                });
                CommDriver::Overlap {
                    req: req_tx,
                    resp: resp_rx,
                    handle,
                    in_flight: 0,
                    busy_ns: busy,
                    wait_ns: 0,
                }
            }
        }
    }

    /// Exchange-time accounting so far (see [`CommStats`]). Read this
    /// before [`Self::finish`]; a serialized driver reports
    /// `wait == busy`.
    pub fn stats(&self) -> CommStats {
        match self {
            CommDriver::Serialized { busy_ns, .. } => CommStats {
                busy_ns: *busy_ns,
                wait_ns: *busy_ns,
            },
            CommDriver::Overlap { busy_ns, wait_ns, .. } => CommStats {
                busy_ns: busy_ns.load(Ordering::Relaxed),
                wait_ns: *wait_ns,
            },
        }
    }

    /// Submit this window's spikes for exchange. In serialized mode the
    /// exchange happens here (and its failure surfaces here); in
    /// overlap mode failures surface on the matching
    /// [`Self::recv_completed`].
    pub fn submit(&mut self, pkt: SpikePacket) -> Result<(), CommError> {
        match self {
            CommDriver::Serialized {
                comm,
                routing,
                staged,
                busy_ns,
            } => {
                debug_assert!(staged.is_none());
                let t = Instant::now();
                let out = outbound_of(routing.as_ref(), pkt);
                let got = comm.exchange_outbound(out);
                *busy_ns += t.elapsed().as_nanos() as u64;
                *staged = Some(got?);
                Ok(())
            }
            CommDriver::Overlap { req, in_flight, .. } => {
                debug_assert!(
                    *in_flight < Self::STAGING_DEPTH,
                    "outbound staging is {} deep",
                    Self::STAGING_DEPTH
                );
                req.send(pkt).map_err(|_| CommError::Shutdown)?;
                *in_flight += 1;
                Ok(())
            }
        }
    }

    /// Receive the oldest submitted window's remote spikes.
    pub fn recv_completed(&mut self) -> Result<SpikePacket, CommError> {
        match self {
            CommDriver::Serialized { staged, .. } => {
                Ok(staged.take().unwrap_or_default())
            }
            CommDriver::Overlap {
                resp,
                in_flight,
                wait_ns,
                ..
            } => {
                if *in_flight > 0 {
                    *in_flight -= 1;
                    let t = Instant::now();
                    let got = resp.recv();
                    *wait_ns += t.elapsed().as_nanos() as u64;
                    match got {
                        Ok(r) => r,
                        Err(_) => Err(CommError::Shutdown),
                    }
                } else {
                    Ok(Vec::new())
                }
            }
        }
    }

    /// Tear down; returns the communicator for its statistics.
    pub fn finish(self) -> Box<dyn Communicator> {
        match self {
            CommDriver::Serialized { comm, .. } => comm,
            CommDriver::Overlap {
                req,
                resp,
                handle,
                in_flight,
                ..
            } => {
                for _ in 0..in_flight {
                    let _ = resp.recv();
                }
                drop(req);
                handle.join().expect("comm thread panicked")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::SpikeMsg;

    /// A transport whose exchange always fails — the poisoned-endpoint
    /// shape the overlap comm thread must surface, not panic on.
    struct FailComm {
        exchanges: u64,
    }

    impl Communicator for FailComm {
        fn rank(&self) -> u16 {
            0
        }
        fn size(&self) -> usize {
            2
        }
        fn exchange_outbound(
            &mut self,
            _out: Outbound,
        ) -> Result<SpikePacket, CommError> {
            self.exchanges += 1;
            Err(CommError::PeerLost { peer: 1, window: self.exchanges })
        }
        fn alltoall(
            &mut self,
            _out: Vec<Vec<u8>>,
        ) -> Result<Vec<Vec<u8>>, CommError> {
            Err(CommError::Shutdown)
        }
        fn bytes_sent(&self) -> u64 {
            0
        }
        fn bytes_received(&self) -> u64 {
            0
        }
        fn exchanges(&self) -> u64 {
            self.exchanges
        }
    }

    fn pkt() -> SpikePacket {
        vec![SpikeMsg { gid: 7, step: 3 }]
    }

    #[test]
    fn overlap_poisoned_transport_errors_on_recv_not_panic() {
        let mut d = CommDriver::new(
            Box::new(FailComm { exchanges: 0 }),
            CommMode::Overlap,
            None,
        );
        d.submit(pkt()).expect("submit is a channel send");
        let err = d.recv_completed().unwrap_err();
        assert!(
            matches!(err, CommError::PeerLost { peer: 1, window: 1 }),
            "unexpected error: {err}"
        );
        // the comm thread exited after forwarding the error; a further
        // submit/recv round reports the hangup instead of wedging
        match d.submit(pkt()) {
            Ok(()) => {
                let err = d.recv_completed().unwrap_err();
                assert!(matches!(err, CommError::Shutdown));
            }
            Err(err) => assert!(matches!(err, CommError::Shutdown)),
        }
        let comm = d.finish();
        assert_eq!(comm.exchanges(), 1);
    }

    #[test]
    fn finish_after_failed_in_flight_does_not_hang() {
        let mut d = CommDriver::new(
            Box::new(FailComm { exchanges: 0 }),
            CommMode::Overlap,
            None,
        );
        d.submit(pkt()).expect("submit is a channel send");
        // the in-flight exchange has failed (or is about to); finish
        // must drain it and join the thread without deadlocking
        let comm = d.finish();
        assert_eq!(comm.exchanges(), 1);
    }

    #[test]
    fn serialized_poisoned_transport_errors_on_submit() {
        let mut d = CommDriver::new(
            Box::new(FailComm { exchanges: 0 }),
            CommMode::Serialized,
            None,
        );
        let err = d.submit(pkt()).unwrap_err();
        assert!(
            matches!(err, CommError::PeerLost { peer: 1, window: 1 }),
            "unexpected error: {err}"
        );
    }

    /// A transport whose exchange takes a measurable amount of time —
    /// for exercising the busy/wait accounting.
    struct SlowComm {
        exchanges: u64,
        delay: std::time::Duration,
    }

    impl Communicator for SlowComm {
        fn rank(&self) -> u16 {
            0
        }
        fn size(&self) -> usize {
            2
        }
        fn exchange_outbound(
            &mut self,
            _out: Outbound,
        ) -> Result<SpikePacket, CommError> {
            std::thread::sleep(self.delay);
            self.exchanges += 1;
            Ok(Vec::new())
        }
        fn alltoall(
            &mut self,
            out: Vec<Vec<u8>>,
        ) -> Result<Vec<Vec<u8>>, CommError> {
            Ok(vec![Vec::new(); out.len()])
        }
        fn bytes_sent(&self) -> u64 {
            0
        }
        fn bytes_received(&self) -> u64 {
            0
        }
        fn exchanges(&self) -> u64 {
            self.exchanges
        }
    }

    #[test]
    fn serialized_driver_hides_nothing() {
        let mut d = CommDriver::new(
            Box::new(SlowComm {
                exchanges: 0,
                delay: std::time::Duration::from_millis(2),
            }),
            CommMode::Serialized,
            None,
        );
        d.submit(pkt()).unwrap();
        assert!(d.recv_completed().unwrap().is_empty());
        let s = d.stats();
        assert!(s.busy_ns > 0, "exchange time not measured");
        assert_eq!(s.wait_ns, s.busy_ns);
        assert_eq!(s.overlap_ratio(), 0.0);
    }

    #[test]
    fn overlapped_exchange_hidden_behind_compute_scores_high() {
        let mut d = CommDriver::new(
            Box::new(SlowComm {
                exchanges: 0,
                delay: std::time::Duration::from_millis(5),
            }),
            CommMode::Overlap,
            None,
        );
        d.submit(pkt()).unwrap();
        // "compute" for longer than the exchange takes: the receive
        // below should barely block
        std::thread::sleep(std::time::Duration::from_millis(25));
        assert!(d.recv_completed().unwrap().is_empty());
        let s = d.stats();
        assert!(s.busy_ns > 0, "exchange time not measured");
        assert!(
            s.overlap_ratio() > 0.2,
            "exchange not hidden: {s:?}"
        );
        let comm = d.finish();
        assert_eq!(comm.exchanges(), 1);
    }

    #[test]
    fn staging_depth_two_pipelines_submissions() {
        let mut d = CommDriver::new(
            Box::new(SlowComm {
                exchanges: 0,
                delay: std::time::Duration::from_millis(1),
            }),
            CommMode::Overlap,
            None,
        );
        // two windows in flight before the first receive
        d.submit(pkt()).unwrap();
        d.submit(pkt()).unwrap();
        assert!(d.recv_completed().unwrap().is_empty());
        assert!(d.recv_completed().unwrap().is_empty());
        let comm = d.finish();
        assert_eq!(comm.exchanges(), 2);
    }
}
