//! Spike-exchange driver (paper §III.C): serialized (blocking at window
//! end) or overlapped via a dedicated communication thread.
//!
//! The communication thread is the one standing thread the engine owns
//! besides its compute worker pool; the session's rank loop
//! (`engine::session`) synchronizes the two at window boundaries — the
//! pool's workers compute window `k` while the comm thread exchanges
//! window `k-1`'s spikes (paper §III.C.2).
//!
//! Exchange failures ([`CommError`]: window misalignment, malformed
//! wire frames, lost peers) propagate through [`CommDriver::submit`] /
//! [`CommDriver::recv_completed`] as errors — in overlap mode the
//! communication thread forwards the error over its response channel
//! and exits, so a poisoned transport surfaces on the rank loop instead
//! of panicking a detached thread.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;

use crate::comm::{CommError, Communicator, SpikePacket};
use crate::config::CommMode;

/// Spike-exchange driver: one per rank, owned by its session rank
/// thread (`engine::session::RankRuntime`).
pub(crate) enum CommDriver {
    Serialized {
        comm: Box<dyn Communicator>,
        staged: Option<SpikePacket>,
    },
    Overlap {
        req: Sender<SpikePacket>,
        resp: Receiver<Result<SpikePacket, CommError>>,
        handle: JoinHandle<Box<dyn Communicator>>,
        in_flight: bool,
    },
}

impl CommDriver {
    pub fn new(comm: Box<dyn Communicator>, mode: CommMode) -> CommDriver {
        match mode {
            CommMode::Serialized => {
                CommDriver::Serialized { comm, staged: None }
            }
            CommMode::Overlap => {
                let (req_tx, req_rx) = channel::<SpikePacket>();
                let (resp_tx, resp_rx) =
                    channel::<Result<SpikePacket, CommError>>();
                let mut comm = comm;
                let handle = std::thread::spawn(move || {
                    // the dedicated communication thread: drains exchange
                    // requests until the engine hangs up or the transport
                    // errors out (the error is forwarded, then the thread
                    // exits — its endpoint is poisoned)
                    while let Ok(pkt) = req_rx.recv() {
                        let got = comm.exchange(pkt);
                        let failed = got.is_err();
                        if resp_tx.send(got).is_err() || failed {
                            break;
                        }
                    }
                    comm
                });
                CommDriver::Overlap {
                    req: req_tx,
                    resp: resp_rx,
                    handle,
                    in_flight: false,
                }
            }
        }
    }

    /// Submit this window's spikes for exchange. In serialized mode the
    /// exchange happens here (and its failure surfaces here); in
    /// overlap mode failures surface on the matching
    /// [`Self::recv_completed`].
    pub fn submit(&mut self, pkt: SpikePacket) -> Result<(), CommError> {
        match self {
            CommDriver::Serialized { comm, staged } => {
                debug_assert!(staged.is_none());
                *staged = Some(comm.exchange(pkt)?);
                Ok(())
            }
            CommDriver::Overlap { req, in_flight, .. } => {
                debug_assert!(!*in_flight);
                req.send(pkt).map_err(|_| CommError::Shutdown)?;
                *in_flight = true;
                Ok(())
            }
        }
    }

    /// Receive the previously submitted window's remote spikes.
    pub fn recv_completed(&mut self) -> Result<SpikePacket, CommError> {
        match self {
            CommDriver::Serialized { staged, .. } => {
                Ok(staged.take().unwrap_or_default())
            }
            CommDriver::Overlap { resp, in_flight, .. } => {
                if *in_flight {
                    *in_flight = false;
                    match resp.recv() {
                        Ok(r) => r,
                        Err(_) => Err(CommError::Shutdown),
                    }
                } else {
                    Ok(Vec::new())
                }
            }
        }
    }

    /// Tear down; returns the communicator for its statistics.
    pub fn finish(self) -> Box<dyn Communicator> {
        match self {
            CommDriver::Serialized { comm, .. } => comm,
            CommDriver::Overlap { req, resp, handle, in_flight } => {
                if in_flight {
                    let _ = resp.recv();
                }
                drop(req);
                handle.join().expect("comm thread panicked")
            }
        }
    }
}
