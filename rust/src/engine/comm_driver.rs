//! Spike-exchange driver (paper §III.C): serialized (blocking at window
//! end) or overlapped via a dedicated communication thread.
//!
//! The communication thread is the one standing thread the engine owns
//! besides its compute worker pool; the session's rank loop
//! (`engine::session`) synchronizes the two at window boundaries — the
//! pool's workers compute window `k` while the comm thread exchanges
//! window `k-1`'s spikes (paper §III.C.2).
//!
//! With a [`RoutingTable`] installed (`engine.routing = "routed"`) the
//! driver splits each submitted packet into per-destination subsets
//! before handing it to the transport. In overlap mode the split runs
//! **on the communication thread**, so both the routing work and the
//! wire exchange overlap the next window's compute; the rank loop's
//! `submit` stays a channel send either way.
//!
//! Exchange failures ([`CommError`]: window misalignment, malformed
//! wire frames, lost peers) propagate through [`CommDriver::submit`] /
//! [`CommDriver::recv_completed`] as errors — in overlap mode the
//! communication thread forwards the error over its response channel
//! and exits, so a poisoned transport surfaces on the rank loop instead
//! of panicking a detached thread.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;

use crate::comm::{
    CommError, Communicator, Outbound, RoutingTable, SpikePacket,
};
use crate::config::CommMode;

/// Split a window packet per destination if a routing table is
/// installed, else broadcast it whole.
fn outbound_of(
    routing: Option<&RoutingTable>,
    pkt: SpikePacket,
) -> Outbound {
    match routing {
        Some(rt) => Outbound::Routed(rt.route(&pkt)),
        None => Outbound::Broadcast(pkt),
    }
}

/// Spike-exchange driver: one per rank, owned by its session rank
/// thread (`engine::session::RankRuntime`).
pub(crate) enum CommDriver {
    Serialized {
        comm: Box<dyn Communicator>,
        routing: Option<RoutingTable>,
        staged: Option<SpikePacket>,
    },
    Overlap {
        req: Sender<SpikePacket>,
        resp: Receiver<Result<SpikePacket, CommError>>,
        handle: JoinHandle<Box<dyn Communicator>>,
        in_flight: bool,
    },
}

impl CommDriver {
    /// `routing: None` keeps the broadcast allgather (the ablation
    /// baseline and the only shape `SoloComm` ever sees).
    pub fn new(
        comm: Box<dyn Communicator>,
        mode: CommMode,
        routing: Option<RoutingTable>,
    ) -> CommDriver {
        match mode {
            CommMode::Serialized => {
                CommDriver::Serialized { comm, routing, staged: None }
            }
            CommMode::Overlap => {
                let (req_tx, req_rx) = channel::<SpikePacket>();
                let (resp_tx, resp_rx) =
                    channel::<Result<SpikePacket, CommError>>();
                let mut comm = comm;
                let handle = std::thread::spawn(move || {
                    // the dedicated communication thread: drains exchange
                    // requests until the engine hangs up or the transport
                    // errors out (the error is forwarded, then the thread
                    // exits — its endpoint is poisoned). Routing the
                    // packet happens here too, off the rank loop.
                    while let Ok(pkt) = req_rx.recv() {
                        let out = outbound_of(routing.as_ref(), pkt);
                        let got = comm.exchange_outbound(out);
                        let failed = got.is_err();
                        if resp_tx.send(got).is_err() || failed {
                            break;
                        }
                    }
                    comm
                });
                CommDriver::Overlap {
                    req: req_tx,
                    resp: resp_rx,
                    handle,
                    in_flight: false,
                }
            }
        }
    }

    /// Submit this window's spikes for exchange. In serialized mode the
    /// exchange happens here (and its failure surfaces here); in
    /// overlap mode failures surface on the matching
    /// [`Self::recv_completed`].
    pub fn submit(&mut self, pkt: SpikePacket) -> Result<(), CommError> {
        match self {
            CommDriver::Serialized { comm, routing, staged } => {
                debug_assert!(staged.is_none());
                let out = outbound_of(routing.as_ref(), pkt);
                *staged = Some(comm.exchange_outbound(out)?);
                Ok(())
            }
            CommDriver::Overlap { req, in_flight, .. } => {
                debug_assert!(!*in_flight);
                req.send(pkt).map_err(|_| CommError::Shutdown)?;
                *in_flight = true;
                Ok(())
            }
        }
    }

    /// Receive the previously submitted window's remote spikes.
    pub fn recv_completed(&mut self) -> Result<SpikePacket, CommError> {
        match self {
            CommDriver::Serialized { staged, .. } => {
                Ok(staged.take().unwrap_or_default())
            }
            CommDriver::Overlap { resp, in_flight, .. } => {
                if *in_flight {
                    *in_flight = false;
                    match resp.recv() {
                        Ok(r) => r,
                        Err(_) => Err(CommError::Shutdown),
                    }
                } else {
                    Ok(Vec::new())
                }
            }
        }
    }

    /// Tear down; returns the communicator for its statistics.
    pub fn finish(self) -> Box<dyn Communicator> {
        match self {
            CommDriver::Serialized { comm, .. } => comm,
            CommDriver::Overlap { req, resp, handle, in_flight } => {
                if in_flight {
                    let _ = resp.recv();
                }
                drop(req);
                handle.join().expect("comm thread panicked")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::SpikeMsg;

    /// A transport whose exchange always fails — the poisoned-endpoint
    /// shape the overlap comm thread must surface, not panic on.
    struct FailComm {
        exchanges: u64,
    }

    impl Communicator for FailComm {
        fn rank(&self) -> u16 {
            0
        }
        fn size(&self) -> usize {
            2
        }
        fn exchange_outbound(
            &mut self,
            _out: Outbound,
        ) -> Result<SpikePacket, CommError> {
            self.exchanges += 1;
            Err(CommError::PeerLost { peer: 1, window: self.exchanges })
        }
        fn alltoall(
            &mut self,
            _out: Vec<Vec<u8>>,
        ) -> Result<Vec<Vec<u8>>, CommError> {
            Err(CommError::Shutdown)
        }
        fn bytes_sent(&self) -> u64 {
            0
        }
        fn bytes_received(&self) -> u64 {
            0
        }
        fn exchanges(&self) -> u64 {
            self.exchanges
        }
    }

    fn pkt() -> SpikePacket {
        vec![SpikeMsg { gid: 7, step: 3 }]
    }

    #[test]
    fn overlap_poisoned_transport_errors_on_recv_not_panic() {
        let mut d = CommDriver::new(
            Box::new(FailComm { exchanges: 0 }),
            CommMode::Overlap,
            None,
        );
        d.submit(pkt()).expect("submit is a channel send");
        let err = d.recv_completed().unwrap_err();
        assert!(
            matches!(err, CommError::PeerLost { peer: 1, window: 1 }),
            "unexpected error: {err}"
        );
        // the comm thread exited after forwarding the error; a further
        // submit/recv round reports the hangup instead of wedging
        match d.submit(pkt()) {
            Ok(()) => {
                let err = d.recv_completed().unwrap_err();
                assert!(matches!(err, CommError::Shutdown));
            }
            Err(err) => assert!(matches!(err, CommError::Shutdown)),
        }
        let comm = d.finish();
        assert_eq!(comm.exchanges(), 1);
    }

    #[test]
    fn finish_after_failed_in_flight_does_not_hang() {
        let mut d = CommDriver::new(
            Box::new(FailComm { exchanges: 0 }),
            CommMode::Overlap,
            None,
        );
        d.submit(pkt()).expect("submit is a channel send");
        // the in-flight exchange has failed (or is about to); finish
        // must drain it and join the thread without deadlocking
        let comm = d.finish();
        assert_eq!(comm.exchanges(), 1);
    }

    #[test]
    fn serialized_poisoned_transport_errors_on_submit() {
        let mut d = CommDriver::new(
            Box::new(FailComm { exchanges: 0 }),
            CommMode::Serialized,
            None,
        );
        let err = d.submit(pkt()).unwrap_err();
        assert!(
            matches!(err, CommError::PeerLost { peer: 1, window: 1 }),
            "unexpected error: {err}"
        );
    }
}
