//! Per-neuron input ring buffers (paper §III.C.1 "Spikes Broadcast and
//! Buffer" — the buffer where synaptic interactions park until their
//! delay elapses).
//!
//! Layout is post-major: `buf[post * len + slot]`, so a thread owning a
//! contiguous local-post range owns a contiguous buffer range — the ring
//! splits across threads with `split_at_mut`, no sharing, no atomics.
//! Writes always target slots strictly in the future of the slot being
//! consumed, because synaptic delays are >= 1 step.

/// One channel (excitatory or inhibitory) of ring input for `n` posts.
#[derive(Clone, Debug)]
pub struct InputRing {
    pub len: usize,
    buf: Vec<f64>,
}

impl InputRing {
    /// `len` must exceed the maximum synaptic delay in steps.
    pub fn new(n_posts: usize, len: usize) -> Self {
        assert!(len >= 2, "ring must cover at least delay 1");
        InputRing { len, buf: vec![0.0; n_posts * len] }
    }

    #[inline]
    pub fn slot(&self, step: u64) -> usize {
        (step % self.len as u64) as usize
    }

    /// Add `w` for `post`, arriving at absolute `due` step.
    #[inline]
    pub fn add(&mut self, post: usize, due: u64, w: f64) {
        let idx = post * self.len + self.slot(due);
        self.buf[idx] += w;
    }

    /// Consume (read + zero) the input of `post` due at `step`.
    #[inline]
    pub fn take(&mut self, post: usize, step: u64) -> f64 {
        let idx = post * self.len + self.slot(step);
        std::mem::take(&mut self.buf[idx])
    }

    /// Split into per-thread sub-rings along post ranges
    /// (`ranges[t] = (lo, hi)` local post bounds).
    pub fn split_mut<'a>(
        &'a mut self,
        ranges: &[(u32, u32)],
    ) -> Vec<RingSlice<'a>> {
        let len = self.len;
        let mut out = Vec::with_capacity(ranges.len());
        let mut rest: &'a mut [f64] = &mut self.buf;
        let mut consumed = 0usize;
        for &(lo, hi) in ranges {
            assert_eq!(lo as usize * len, consumed, "ranges must tile");
            let take = (hi - lo) as usize * len;
            let (head, tail) = rest.split_at_mut(take);
            consumed += take;
            rest = tail;
            out.push(RingSlice { len, post_lo: lo as usize, buf: head });
        }
        assert!(rest.is_empty(), "ranges must cover all posts");
        out
    }

    pub fn bytes(&self) -> u64 {
        crate::metrics::memory::vec_bytes(&self.buf)
    }

    /// Raw buffer access (checkpointing).
    pub fn raw(&self) -> &[f64] {
        &self.buf
    }

    pub fn raw_mut(&mut self) -> &mut [f64] {
        &mut self.buf
    }
}

/// A thread's exclusive window onto the ring (posts `[post_lo, ...)`).
pub struct RingSlice<'a> {
    len: usize,
    post_lo: usize,
    buf: &'a mut [f64],
}

impl RingSlice<'_> {
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    #[inline]
    pub fn slot(&self, step: u64) -> usize {
        (step % self.len as u64) as usize
    }

    #[inline]
    pub fn add(&mut self, post: usize, due: u64, w: f64) {
        let slot = self.slot(due);
        self.add_at(post, slot, w);
    }

    /// Accumulate with a precomputed slot — the delivery hot loop derives
    /// slots incrementally from the delay-sorted edge runs (paper Fig 12b)
    /// instead of dividing per edge.
    #[inline]
    pub fn add_at(&mut self, post: usize, slot: usize, w: f64) {
        debug_assert!(slot < self.len);
        self.buf[(post - self.post_lo) * self.len + slot] += w;
    }

    #[inline]
    pub fn take(&mut self, post: usize, step: u64) -> f64 {
        let slot = self.slot(step);
        self.take_at(post, slot)
    }

    /// Consume with a precomputed slot (one division per step, not per
    /// neuron).
    #[inline]
    pub fn take_at(&mut self, post: usize, slot: usize) -> f64 {
        debug_assert!(slot < self.len);
        std::mem::take(&mut self.buf[(post - self.post_lo) * self.len + slot])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_then_take_roundtrip() {
        let mut r = InputRing::new(4, 8);
        r.add(2, 13, 1.5);
        r.add(2, 13, 2.5);
        assert_eq!(r.take(2, 13), 4.0);
        assert_eq!(r.take(2, 13), 0.0, "take must zero the slot");
    }

    #[test]
    fn slots_wrap() {
        let mut r = InputRing::new(1, 4);
        r.add(0, 2, 1.0);
        r.add(0, 6, 2.0); // same slot (2 % 4 == 6 % 4) — accumulates
        assert_eq!(r.take(0, 2), 3.0);
    }

    #[test]
    fn split_respects_ownership() {
        let mut r = InputRing::new(6, 4);
        {
            let ranges = [(0u32, 2u32), (2, 5), (5, 6)];
            let mut parts = r.split_mut(&ranges);
            parts[0].add(1, 3, 1.0);
            parts[1].add(2, 3, 2.0);
            parts[1].add(4, 3, 3.0);
            parts[2].add(5, 3, 4.0);
        }
        assert_eq!(r.take(1, 3), 1.0);
        assert_eq!(r.take(2, 3), 2.0);
        assert_eq!(r.take(4, 3), 3.0);
        assert_eq!(r.take(5, 3), 4.0);
    }

    #[test]
    #[should_panic(expected = "tile")]
    fn split_requires_tiling_ranges() {
        let mut r = InputRing::new(4, 4);
        let _ = r.split_mut(&[(0, 1), (2, 4)]);
    }
}
