//! Per-neuron input ring buffers (paper §III.C.1 "Spikes Broadcast and
//! Buffer" — the buffer where synaptic interactions park until their
//! delay elapses).
//!
//! Layout is post-major: `buf[post * len + slot]`. Each compute worker
//! permanently owns one `InputRing` per channel covering exactly its
//! local-post range (indices are worker-local; see `engine::workers`) —
//! no sharing, no atomics. Writes always target slots strictly in the
//! future of the slot being consumed, because synaptic delays are
//! >= 1 step.

/// One channel (excitatory or inhibitory) of ring input for `n` posts.
#[derive(Clone, Debug)]
pub struct InputRing {
    pub len: usize,
    buf: Vec<f64>,
}

impl InputRing {
    /// `len` must exceed the maximum synaptic delay in steps.
    pub fn new(n_posts: usize, len: usize) -> Self {
        assert!(len >= 2, "ring must cover at least delay 1");
        InputRing { len, buf: vec![0.0; n_posts * len] }
    }

    #[inline]
    pub fn slot(&self, step: u64) -> usize {
        (step % self.len as u64) as usize
    }

    /// Add `w` for `post`, arriving at absolute `due` step.
    #[inline]
    pub fn add(&mut self, post: usize, due: u64, w: f64) {
        let idx = post * self.len + self.slot(due);
        self.buf[idx] += w;
    }

    /// Consume (read + zero) the input of `post` due at `step`.
    #[inline]
    pub fn take(&mut self, post: usize, step: u64) -> f64 {
        let idx = post * self.len + self.slot(step);
        std::mem::take(&mut self.buf[idx])
    }

    /// Accumulate with a precomputed slot — the delivery hot loop derives
    /// slots incrementally from delay-sorted edge runs (paper Fig 12b)
    /// instead of dividing per edge. Used on worker-owned rings where
    /// `post` is already a thread-local index.
    #[inline]
    pub fn add_at(&mut self, post: usize, slot: usize, w: f64) {
        debug_assert!(slot < self.len);
        self.buf[post * self.len + slot] += w;
    }

    /// Consume with a precomputed slot (one division per step, not per
    /// neuron).
    #[inline]
    pub fn take_at(&mut self, post: usize, slot: usize) -> f64 {
        debug_assert!(slot < self.len);
        std::mem::take(&mut self.buf[post * self.len + slot])
    }

    pub fn bytes(&self) -> u64 {
        crate::metrics::memory::vec_bytes(&self.buf)
    }

    /// Raw buffer access (checkpointing).
    pub fn raw(&self) -> &[f64] {
        &self.buf
    }

    pub fn raw_mut(&mut self) -> &mut [f64] {
        &mut self.buf
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_then_take_roundtrip() {
        let mut r = InputRing::new(4, 8);
        r.add(2, 13, 1.5);
        r.add(2, 13, 2.5);
        assert_eq!(r.take(2, 13), 4.0);
        assert_eq!(r.take(2, 13), 0.0, "take must zero the slot");
    }

    #[test]
    fn slots_wrap() {
        let mut r = InputRing::new(1, 4);
        r.add(0, 2, 1.0);
        r.add(0, 6, 2.0); // same slot (2 % 4 == 6 % 4) — accumulates
        assert_eq!(r.take(0, 2), 3.0);
    }

    #[test]
    fn precomputed_slot_matches_stepwise_access() {
        let mut r = InputRing::new(3, 4);
        let slot = r.slot(7);
        r.add_at(2, slot, 1.5);
        r.add(2, 7, 2.5); // same (post, step) through the dividing path
        assert_eq!(r.take_at(2, slot), 4.0);
        assert_eq!(r.take(2, 7), 0.0, "take_at must zero the slot");
    }
}
