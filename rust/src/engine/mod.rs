//! The CORTEX per-rank simulation engine (paper §III.B-C, Fig 16/17).
//!
//! Each rank owns the indegree sub-graph of its post-neurons
//! ([`RankStore`]) and advances it with `n_threads` compute threads whose
//! write sets are **provably disjoint** (graph::algebra, eq. 14): thread
//! `t` owns a contiguous local-post range, the edges targeting it, their
//! ring-buffer rows and plastic state. The synaptic hot loop therefore
//! runs without a single mutex or atomic; with `verify_ownership` the
//! engine additionally carries the paper's verification check ("if an
//! edge or post-vertex is accessed by different threads, Abort").
//!
//! Per-step pipeline (paper Fig 17's circulatory dataflow):
//!   1. **deliver** — every thread walks its delay-sorted edge runs for
//!      all pending spikes, accumulating weights into ring slots
//!      `emit + delay` (and applying STDP depression);
//!   2. **integrate** — every thread consumes its ring slot + Poisson
//!      drive and advances the LIF propagator (or the rank executes the
//!      AOT PJRT artifact) collecting new spikes;
//!   3. **plasticity** — spiking posts potentiate their incoming plastic
//!      edges (thread-owned);
//!   4. **exchange** — once per min-delay window, spiking gids are
//!      broadcast; in [`CommMode::Overlap`] a dedicated communication
//!      thread runs the exchange while the next window computes.

pub mod checkpoint;
pub mod ring;

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::atlas::NetworkSpec;
use crate::comm::{Communicator, LocalCluster, SpikeMsg, SpikePacket};
use crate::config::{CommMode, DynamicsBackend, MappingKind};
use crate::decomp::{
    area_processes_partition, random_equivalent_partition, Partition,
    RankStore,
};
use crate::metrics::memory::{vec_bytes, MemoryBreakdown, MemoryReport};
use crate::metrics::{PhaseTimer, SpikeRecorder};
use crate::model::lif::{LifState, Propagators};
use crate::model::stdp::{StdpParams, TraceSet};
use crate::model::poisson::PreparedPoisson;
use crate::{Gid, Step};
use ring::InputRing;

/// Engine knobs (a validated subset of [`crate::config::ExperimentConfig`]).
#[derive(Clone, Debug)]
pub struct EngineOptions {
    pub n_threads: usize,
    pub comm: CommMode,
    pub backend: DynamicsBackend,
    /// Record spikes of gids below this bound (None = no raster).
    pub record_limit: Option<Gid>,
    /// Compile the paper's thread-ownership abort check into the hot loop.
    pub verify_ownership: bool,
    /// Where the AOT artifacts live (PJRT backend).
    pub artifacts_dir: String,
}

impl Default for EngineOptions {
    fn default() -> Self {
        EngineOptions {
            n_threads: 1,
            comm: CommMode::Overlap,
            backend: DynamicsBackend::Native,
            record_limit: None,
            verify_ownership: false,
            artifacts_dir: "artifacts".into(),
        }
    }
}

/// Plasticity state of one rank.
struct StdpRank {
    params: StdpParams,
    /// Traces of all pres (local + remote) — read-only in parallel phases.
    pre_traces: TraceSet,
    /// Traces of owned posts — split per thread.
    post_traces: TraceSet,
}

/// One rank's engine.
pub struct RankEngine {
    pub rank: u16,
    spec: Arc<NetworkSpec>,
    pub store: RankStore,
    state: LifState,
    props: Vec<Propagators>,
    ring_e: InputRing,
    ring_i: InputRing,
    stdp: Option<StdpRank>,
    /// Spikes awaiting delivery: (pre index, emission step).
    pending: Vec<(u32, Step)>,
    drives: Vec<PreparedPoisson>,
    pub recorder: SpikeRecorder,
    pub timer: PhaseTimer,
    step: Step,
    opts: EngineOptions,
    pjrt: Option<crate::runtime::PjrtLif>,
    /// scratch buffers for the PJRT dynamics path
    scratch_in: (Vec<f64>, Vec<f64>),
    /// per-thread (in_e, in_i) scratch (no per-step allocation)
    scratch: Vec<(Vec<f64>, Vec<f64>)>,
    pub total_spikes: u64,
}

impl RankEngine {
    pub fn new(
        spec: Arc<NetworkSpec>,
        store: RankStore,
        opts: EngineOptions,
    ) -> anyhow::Result<RankEngine> {
        let props = spec.propagators();
        let n = store.n_posts();
        let pidx: Vec<u8> =
            store.posts.iter().map(|&g| spec.pidx(g)).collect();
        let mut state = LifState::new(n, &props, pidx);
        for (i, &g) in store.posts.iter().enumerate() {
            state.u[i] = spec.v_init(g);
        }
        let ring_len = store.max_delay as usize + 1;
        let stdp = spec.stdp.map(|params| StdpRank {
            params,
            pre_traces: TraceSet::new(
                store.n_pres(),
                params.tau_plus_ms,
                spec.dt_ms,
            ),
            post_traces: TraceSet::new(n, params.tau_minus_ms, spec.dt_ms),
        });
        let drives: Vec<PreparedPoisson> = store
            .posts
            .iter()
            .map(|&g| spec.drive(g).prepare(spec.dt_ms))
            .collect();
        let recorder = match opts.record_limit {
            Some(lim) => SpikeRecorder::new(lim),
            None => SpikeRecorder::disabled(),
        };
        let pjrt = match opts.backend {
            DynamicsBackend::Native => None,
            DynamicsBackend::Pjrt => Some(crate::runtime::PjrtLif::load(
                &opts.artifacts_dir,
                &spec,
            )?),
        };
        let scratch: Vec<(Vec<f64>, Vec<f64>)> = store
            .thread_ranges
            .iter()
            .map(|&(lo, hi)| {
                let span = (hi - lo) as usize;
                (vec![0.0; span], vec![0.0; span])
            })
            .collect();
        Ok(RankEngine {
            rank: store.rank,
            spec,
            ring_e: InputRing::new(n, ring_len.max(2)),
            ring_i: InputRing::new(n, ring_len.max(2)),
            store,
            state,
            props,
            stdp,
            pending: Vec::new(),
            drives,
            recorder,
            timer: PhaseTimer::new(),
            step: 0,
            opts,
            pjrt,
            scratch_in: (vec![0.0; n], vec![0.0; n]),
            scratch,
            total_spikes: 0,
        })
    }

    pub fn step(&self) -> Step {
        self.step
    }

    /// Enqueue spikes received from other ranks (window start).
    pub fn enqueue_remote(&mut self, spikes: &[SpikeMsg]) {
        for m in spikes {
            if let Some(p) = self.store.pre_index_of(m.gid) {
                self.pending.push((p, m.step as Step));
                if let Some(s) = &mut self.stdp {
                    s.pre_traces.bump(p, m.step as Step);
                }
            }
        }
    }

    /// One integration step; spiking gids are appended to `outbox`.
    pub fn step_once(&mut self, outbox: &mut SpikePacket) {
        let now = self.step;
        let n_threads = self.store.threads.len();
        let pending = std::mem::take(&mut self.pending);
        let mut worker_spikes: Vec<Vec<u32>> =
            vec![Vec::new(); n_threads];
        // per-worker [delivery_ns, integrate_ns] for the phase report
        let mut worker_ns: Vec<[u64; 2]> = vec![[0, 0]; n_threads];

        // -- phases 1-3: deliver / integrate / plasticity, thread-parallel
        let native = self.pjrt.is_none();
        {
            let ranges = &self.store.thread_ranges;
            let ring_e = self.ring_e.split_mut(ranges);
            let ring_i = self.ring_i.split_mut(ranges);
            let (post_traces, stdp_params, pre_traces) = match &mut self.stdp
            {
                Some(s) => (
                    Some(s.post_traces.split_mut(ranges)),
                    Some(s.params),
                    Some(&s.pre_traces),
                ),
                None => (None, None, None),
            };
            let mut post_traces = post_traces;

            // split the LIF state SoA along thread ranges
            let mut u: &mut [f64] = &mut self.state.u;
            let mut ie: &mut [f64] = &mut self.state.ie;
            let mut ii: &mut [f64] = &mut self.state.ii;
            let mut refrac: &mut [f64] = &mut self.state.refrac;
            let pidx: &[u8] = &self.state.pidx;
            let props: &[Propagators] = &self.props;
            let drives: &[PreparedPoisson] = &self.drives;
            let pending_ref: &[(u32, Step)] = &pending;
            let verify = self.opts.verify_ownership;
            let seed = self.spec.seed;
            let posts: &[Gid] = &self.store.posts;

            std::thread::scope(|scope| {
                let mut handles = Vec::new();
                let mut ring_e_iter = ring_e.into_iter();
                let mut ring_i_iter = ring_i.into_iter();
                for ((((t, te), spikes_out), phase_ns), scratch_t) in self
                    .store
                    .threads
                    .iter_mut()
                    .enumerate()
                    .zip(worker_spikes.iter_mut())
                    .zip(worker_ns.iter_mut())
                    .zip(self.scratch.iter_mut())
                {
                    let (lo, hi) = ranges[t];
                    let span = (hi - lo) as usize;
                    let (u_t, u_rest) = u.split_at_mut(span);
                    let (ie_t, ie_rest) = ie.split_at_mut(span);
                    let (ii_t, ii_rest) = ii.split_at_mut(span);
                    let (r_t, r_rest) = refrac.split_at_mut(span);
                    u = u_rest;
                    ie = ie_rest;
                    ii = ii_rest;
                    refrac = r_rest;
                    let mut re = ring_e_iter.next().unwrap();
                    let mut ri = ring_i_iter.next().unwrap();
                    let mut pt =
                        post_traces.as_mut().map(|v| v.remove(0));

                    let mut work = move || {
                        let t0 = std::time::Instant::now();
                        // ---- phase 1: delivery ------------------------
                        // Ring slots advance monotonically within a
                        // delay-sorted run (paper Fig 12b/15), so the
                        // wrap is a subtract, not a division per edge.
                        let ring_len = re.len() as Step;
                        for &(p, emit) in pending_ref {
                            let run = te.run(p as usize);
                            if run.is_empty() {
                                continue;
                            }
                            let mut prev_delay = te.delay[run.start] as Step;
                            let mut slot =
                                ((emit + prev_delay) % ring_len) as usize;
                            for ei in run {
                                let post = te.post[ei];
                                if verify && !(post >= lo && post < hi) {
                                    // the paper's verification: Abort
                                    panic!(
                                        "DATA RACE: thread {t} touched \
                                         post {post} outside [{lo},{hi})"
                                    );
                                }
                                let delay = te.delay[ei] as Step;
                                debug_assert!(delay >= prev_delay);
                                slot += (delay - prev_delay) as usize;
                                while slot >= ring_len as usize {
                                    slot -= ring_len as usize;
                                }
                                prev_delay = delay;
                                let mut w = te.weight[ei];
                                if let (Some(params), Some(pt)) =
                                    (stdp_params.as_ref(), pt.as_ref())
                                {
                                    if te.plastic[ei] {
                                        // depression at (extrapolated)
                                        // arrival time
                                        let x = pt.at(post, emit + delay);
                                        w = params.depress(w, x);
                                        te.weight[ei] = w;
                                    }
                                }
                                if w >= 0.0 {
                                    re.add_at(post as usize, slot, w);
                                } else {
                                    ri.add_at(post as usize, slot, w);
                                }
                            }
                        }

                        phase_ns[0] = t0.elapsed().as_nanos() as u64;
                        let t1 = std::time::Instant::now();

                        // ---- phase 2: integrate -----------------------
                        // (a fused ring+drive+LIF single pass was tried
                        // and measured slower — see EXPERIMENTS.md §Perf)
                        if native {
                            let (in_e, in_i) = scratch_t;
                            let now_slot = re.slot(now);
                            for i in 0..span {
                                let post = lo as usize + i;
                                let mut e = re.take_at(post, now_slot);
                                let inh = ri.take_at(post, now_slot);
                                let d = &drives[post];
                                if !d.is_off() {
                                    let x =
                                        d.sample(seed, posts[post], now);
                                    if x >= 0.0 {
                                        e += x;
                                    }
                                }
                                in_e[i] = e;
                                in_i[i] = inh;
                            }
                            // step in place over the borrowed slices
                            step_slices(
                                u_t, ie_t, ii_t, r_t,
                                &pidx[lo as usize..hi as usize],
                                in_e, in_i, props, spikes_out,
                            );

                            // ---- phase 3: plasticity ------------------
                            if let (Some(params), Some(pt), Some(pre_tr)) = (
                                stdp_params.as_ref(),
                                pt.as_mut(),
                                pre_traces,
                            ) {
                                for &ls in spikes_out.iter() {
                                    let post = lo + ls;
                                    // potentiate incoming plastic edges
                                    let b = ls as usize;
                                    let r0 = te.plastic_by_post_offsets[b]
                                        as usize;
                                    let r1 = te.plastic_by_post_offsets
                                        [b + 1]
                                        as usize;
                                    for k in r0..r1 {
                                        let ei = te.plastic_by_post_edge[k]
                                            as usize;
                                        let x = pre_tr
                                            .at(te.epre[ei], now);
                                        te.weight[ei] = params
                                            .potentiate(te.weight[ei], x);
                                    }
                                    pt.bump(post, now);
                                }
                            }
                        } else {
                            // PJRT backend: threads only deliver; the
                            // dynamics run below on the rank thread.
                        }
                        phase_ns[1] = t1.elapsed().as_nanos() as u64;
                    };
                    if n_threads == 1 {
                        work();
                    } else {
                        handles.push(scope.spawn(work));
                    }
                }
                for h in handles {
                    h.join().expect("worker thread panicked");
                }
            });
        }

        // -- PJRT dynamics (serial per rank over the AOT artifact) -------
        if !native {
            let n = self.store.n_posts();
            let (in_e, in_i) = &mut self.scratch_in;
            for i in 0..n {
                let mut e = self.ring_e.take(i, now);
                let inh = self.ring_i.take(i, now);
                let d = &self.drives[i];
                if !d.is_off() {
                    let x = d.sample(
                        self.spec.seed,
                        self.store.posts[i],
                        now,
                    );
                    if x >= 0.0 {
                        e += x;
                    }
                }
                in_e[i] = e;
                in_i[i] = inh;
            }
            let spiked = self
                .pjrt
                .as_mut()
                .unwrap()
                .step(&mut self.state, in_e, in_i)
                .expect("pjrt step failed");
            worker_spikes[0].extend(spiked);
            // plasticity for PJRT backend (serial, still post-owned)
            if let Some(s) = &mut self.stdp {
                for &ls in &worker_spikes[0] {
                    let t = self.store.thread_of(ls) as usize;
                    let te = &mut self.store.threads[t];
                    let (lo, _) = self.store.thread_ranges[t];
                    let b = (ls - lo) as usize;
                    let r0 = te.plastic_by_post_offsets[b] as usize;
                    let r1 = te.plastic_by_post_offsets[b + 1] as usize;
                    for k in r0..r1 {
                        let ei = te.plastic_by_post_edge[k] as usize;
                        let x = s.pre_traces.at(te.epre[ei], now);
                        te.weight[ei] = s.params.potentiate(te.weight[ei], x);
                    }
                    s.post_traces.bump(ls, now);
                }
            }
        }

        for ns in &worker_ns {
            self.timer.add("deliver", ns[0] as u128);
            self.timer.add("integrate", ns[1] as u128);
        }

        // -- collect spikes, refill pending, feed outbox ------------------
        for (t, spikes) in worker_spikes.iter().enumerate() {
            let lo = if native { self.store.thread_ranges[t].0 } else { 0 };
            for &ls in spikes {
                let local = lo + ls;
                let gid = self.store.posts[local as usize];
                self.total_spikes += 1;
                self.recorder.record(now, gid);
                outbox.push(SpikeMsg { gid, step: now as u32 });
                // deliver locally next step if we have edges from it
                if let Some(p) = self.store.pre_index_of(gid) {
                    self.pending.push((p, now));
                    if let Some(s) = &mut self.stdp {
                        s.pre_traces.bump(p, now);
                    }
                }
            }
        }

        self.step += 1;
    }

    /// Per-rank heap accounting (the Fig 18 memory panel's quantity).
    pub fn memory(&self) -> MemoryBreakdown {
        let mut m = self.store.memory();
        m.add("state", self.state.bytes());
        m.add("rings", self.ring_e.bytes() + self.ring_i.bytes());
        m.add("drives", vec_bytes(&self.drives));
        if let Some(s) = &self.stdp {
            m.add("traces", s.pre_traces.bytes() + s.post_traces.bytes());
        }
        m
    }
}

/// Advance one thread's state slices (the split-borrow twin of
/// `model::lif::step_slice`, operating on raw slices).
#[allow(clippy::too_many_arguments)]
fn step_slices(
    u: &mut [f64],
    ie: &mut [f64],
    ii: &mut [f64],
    refrac: &mut [f64],
    pidx: &[u8],
    in_e: &[f64],
    in_i: &[f64],
    props: &[Propagators],
    spikes: &mut Vec<u32>,
) {
    for i in 0..u.len() {
        let p = &props[pidx[i] as usize];
        let (mut u_new, mut r_new);
        if refrac[i] > 0.0 {
            u_new = p.v_reset;
            r_new = refrac[i] - 1.0;
        } else {
            u_new = p.e_l
                + (u[i] - p.e_l) * p.p22
                + ie[i] * p.p21e
                + ii[i] * p.p21i
                + p.i_ext * p.p20;
            r_new = refrac[i];
            if u_new >= p.v_th {
                u_new = p.v_reset;
                r_new = p.ref_steps as f64;
                spikes.push(i as u32);
            }
        }
        u[i] = u_new;
        refrac[i] = r_new;
        ie[i] = ie[i] * p.p11e + in_e[i];
        ii[i] = ii[i] * p.p11i + in_i[i];
    }
}

// ---------------------------------------------------------------------
// Window-driven rank loop + communication drivers
// ---------------------------------------------------------------------

/// Spike-exchange driver: serialized (blocking at window end) or
/// overlapped via a dedicated communication thread (paper §III.C.2).
enum CommDriver {
    Serialized {
        comm: Box<dyn Communicator>,
        staged: Option<SpikePacket>,
    },
    Overlap {
        req: Sender<SpikePacket>,
        resp: Receiver<SpikePacket>,
        handle: JoinHandle<Box<dyn Communicator>>,
        in_flight: bool,
    },
}

impl CommDriver {
    fn new(comm: Box<dyn Communicator>, mode: CommMode) -> CommDriver {
        match mode {
            CommMode::Serialized => {
                CommDriver::Serialized { comm, staged: None }
            }
            CommMode::Overlap => {
                let (req_tx, req_rx) = channel::<SpikePacket>();
                let (resp_tx, resp_rx) = channel::<SpikePacket>();
                let mut comm = comm;
                let handle = std::thread::spawn(move || {
                    // the dedicated communication thread: drains exchange
                    // requests until the engine hangs up
                    while let Ok(pkt) = req_rx.recv() {
                        let got = comm.exchange(pkt);
                        if resp_tx.send(got).is_err() {
                            break;
                        }
                    }
                    comm
                });
                CommDriver::Overlap {
                    req: req_tx,
                    resp: resp_rx,
                    handle,
                    in_flight: false,
                }
            }
        }
    }

    /// Submit this window's spikes for exchange.
    fn submit(&mut self, pkt: SpikePacket) {
        match self {
            CommDriver::Serialized { comm, staged } => {
                debug_assert!(staged.is_none());
                *staged = Some(comm.exchange(pkt));
            }
            CommDriver::Overlap { req, in_flight, .. } => {
                debug_assert!(!*in_flight);
                req.send(pkt).expect("comm thread died");
                *in_flight = true;
            }
        }
    }

    /// Receive the previously submitted window's remote spikes.
    fn recv_completed(&mut self) -> SpikePacket {
        match self {
            CommDriver::Serialized { staged, .. } => {
                staged.take().unwrap_or_default()
            }
            CommDriver::Overlap { resp, in_flight, .. } => {
                if *in_flight {
                    *in_flight = false;
                    resp.recv().expect("comm thread died")
                } else {
                    Vec::new()
                }
            }
        }
    }

    /// Tear down; returns the communicator for its statistics.
    fn finish(self) -> Box<dyn Communicator> {
        match self {
            CommDriver::Serialized { comm, .. } => comm,
            CommDriver::Overlap { req, resp, handle, in_flight } => {
                if in_flight {
                    let _ = resp.recv();
                }
                drop(req);
                handle.join().expect("comm thread panicked")
            }
        }
    }
}

/// Result of one rank's run.
pub struct RankOutput {
    pub rank: u16,
    pub recorder: SpikeRecorder,
    pub timer: PhaseTimer,
    pub memory: MemoryBreakdown,
    pub total_spikes: u64,
    pub comm_bytes: u64,
    pub windows: u64,
    /// store + engine construction time (not simulation)
    pub build_seconds: f64,
}

/// Drive one rank for `steps` steps with window-batched spike exchange.
pub fn run_rank(
    mut engine: RankEngine,
    comm: Box<dyn Communicator>,
    mode: CommMode,
    steps: Step,
) -> RankOutput {
    let m = engine.spec.min_delay_steps as Step;
    let mut driver = CommDriver::new(comm, mode);
    let mut done: Step = 0;
    while done < steps {
        // window start: pick up the previous window's exchange
        let incoming =
            engine.timer.time("comm_wait", || driver.recv_completed());
        engine.enqueue_remote(&incoming);

        let mut outbox = Vec::new();
        let this_window = m.min(steps - done);
        for _ in 0..this_window {
            let t0 = std::time::Instant::now();
            engine.step_once(&mut outbox);
            engine.timer.add("compute", t0.elapsed().as_nanos());
        }
        done += this_window;

        engine.timer.time("comm_submit", || driver.submit(outbox));
    }
    let comm = driver.finish();
    RankOutput {
        rank: engine.rank,
        recorder: engine.recorder.clone(),
        timer: engine.timer.clone(),
        memory: engine.memory(),
        total_spikes: engine.total_spikes,
        comm_bytes: comm.bytes_sent(),
        windows: comm.exchanges(),
        build_seconds: 0.0,
    }
}

// ---------------------------------------------------------------------
// Whole-simulation orchestration
// ---------------------------------------------------------------------

/// Run options for a full multi-rank simulation.
#[derive(Clone, Debug)]
pub struct RunConfig {
    pub ranks: usize,
    pub threads: usize,
    pub mapping: MappingKind,
    pub comm: CommMode,
    pub backend: DynamicsBackend,
    pub steps: Step,
    pub record_limit: Option<Gid>,
    pub verify_ownership: bool,
    pub artifacts_dir: String,
    pub seed: u64,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            ranks: 2,
            threads: 2,
            mapping: MappingKind::AreaProcesses,
            comm: CommMode::Overlap,
            backend: DynamicsBackend::Native,
            steps: 1000,
            record_limit: None,
            verify_ownership: false,
            artifacts_dir: "artifacts".into(),
            seed: 1,
        }
    }
}

/// Merged output of a full run.
pub struct RunOutput {
    pub raster: SpikeRecorder,
    /// Critical-path timer (max over ranks per phase).
    pub timer_max: PhaseTimer,
    /// Aggregate timer (sum over ranks).
    pub timer_sum: PhaseTimer,
    pub memory: MemoryReport,
    pub total_spikes: u64,
    /// Simulation wall time (the paper's Fig 18 quantity) — excludes
    /// network construction.
    pub wall_seconds: f64,
    /// Network construction time (per-rank max): indegree sub-graph
    /// generation + (pre, delay) edge layout.
    pub build_seconds: f64,
    pub comm_bytes: u64,
    pub windows: u64,
    pub partition: Partition,
}

/// Partition the network and run it on `cfg.ranks` simulated ranks.
pub fn run_simulation(
    spec: &Arc<NetworkSpec>,
    cfg: &RunConfig,
) -> anyhow::Result<RunOutput> {
    let partition = Arc::new(match cfg.mapping {
        MappingKind::AreaProcesses => {
            area_processes_partition(spec, cfg.ranks, cfg.seed)
        }
        MappingKind::RandomEquivalent => {
            random_equivalent_partition(spec.n_total(), cfg.ranks, cfg.seed)
        }
    });
    let comms = LocalCluster::new(cfg.ranks);
    // all ranks finish construction before simulation timing starts, so
    // build and simulation wall-clock separate cleanly (the paper's
    // Fig 18 reports simulation time)
    let barrier = Arc::new(std::sync::Barrier::new(cfg.ranks));

    let outputs: Vec<(RankOutput, f64)> = std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for (r, comm) in comms.into_iter().enumerate() {
            let spec = Arc::clone(spec);
            let partition = Arc::clone(&partition);
            let barrier = Arc::clone(&barrier);
            let cfg = cfg.clone();
            handles.push(scope.spawn(
                move || -> anyhow::Result<(RankOutput, f64)> {
                let t_build = std::time::Instant::now();
                let members = &partition.members[r];
                let rank_of = &partition.rank_of;
                let store = RankStore::build(
                    &spec,
                    members,
                    |g| rank_of[g as usize] as usize == r,
                    r as u16,
                    cfg.threads,
                );
                let engine = RankEngine::new(
                    Arc::clone(&spec),
                    store,
                    EngineOptions {
                        n_threads: cfg.threads,
                        comm: cfg.comm,
                        backend: cfg.backend,
                        record_limit: cfg.record_limit,
                        verify_ownership: cfg.verify_ownership,
                        artifacts_dir: cfg.artifacts_dir.clone(),
                    },
                )?;
                let build_seconds = t_build.elapsed().as_secs_f64();
                barrier.wait();
                let t_sim = std::time::Instant::now();
                let mut out =
                    run_rank(engine, Box::new(comm), cfg.comm, cfg.steps);
                out.build_seconds = build_seconds;
                Ok((out, t_sim.elapsed().as_secs_f64()))
            }));
        }
        handles
            .into_iter()
            .map(|h| h.join().expect("rank thread panicked"))
            .collect::<anyhow::Result<Vec<_>>>()
    })?;

    let mut raster = SpikeRecorder::new(
        cfg.record_limit.unwrap_or(0),
    );
    let mut timer_max = PhaseTimer::new();
    let mut timer_sum = PhaseTimer::new();
    let mut per_rank_mem = Vec::new();
    let mut total_spikes = 0;
    let mut comm_bytes = 0;
    let mut windows = 0;
    let mut wall_seconds: f64 = 0.0;
    let mut build_seconds: f64 = 0.0;
    for (o, sim_s) in &outputs {
        raster.merge(&o.recorder);
        timer_max.merge_max(&o.timer);
        timer_sum.merge(&o.timer);
        per_rank_mem.push(o.memory.clone());
        total_spikes += o.total_spikes;
        comm_bytes += o.comm_bytes;
        windows = windows.max(o.windows);
        wall_seconds = wall_seconds.max(*sim_s);
        build_seconds = build_seconds.max(o.build_seconds);
    }
    raster.events.sort_unstable();
    Ok(RunOutput {
        raster,
        timer_max,
        timer_sum,
        memory: MemoryReport::new(per_rank_mem),
        total_spikes,
        wall_seconds,
        build_seconds,
        comm_bytes,
        windows,
        partition: Arc::try_unwrap(partition)
            .unwrap_or_else(|a| (*a).clone()),
    })
}
