//! The CORTEX per-rank simulation engine (paper §III.B-C, Fig 16/17).
//!
//! Each rank owns the indegree sub-graph of its post-neurons
//! ([`RankStore`]) and advances it with `n_threads` compute threads whose
//! write sets are **provably disjoint** (graph::algebra, eq. 14): thread
//! `t` owns a contiguous local-post range, the edges targeting it, their
//! ring-buffer rows and plastic state. The synaptic hot loop therefore
//! runs without a single mutex or atomic; with `verify_ownership` the
//! engine additionally carries the paper's verification check ("if an
//! edge or post-vertex is accessed by different threads, Abort").
//!
//! # Execution core: the persistent worker pool
//!
//! The compute threads are **long-lived** (paper Fig 16: threads run
//! continuously across the whole simulation, not per step). At
//! construction, `RankEngine::new` moves every thread's state into a
//! `workers::WorkerCtx` — a handle into the shared immutable build
//! product (`Arc<RankStore>`: the (pre, delay)-sorted edges) plus a
//! private mutable `workers::TrajectoryState` — per-population model
//! state blocks (LIF / AdEx / HH / parrot via `model::dynamics`), ring
//! rows, STDP post-traces, drives, scratch, spike outbox — and (in
//! [`ExecMode::Pool`]) spawns one worker thread per context via
//! `workers::WorkerPool`. Per step, `step_once` transfers each context
//! plus one shared read-only `workers::StepJob` (pending spikes +
//! rank-level STDP pre-traces) to its worker over a channel and collects
//! the contexts back; workers park in `recv` between steps. The
//! [`ExecMode::Scoped`] fallback runs the same phase kernels on scoped
//! threads spawned every step — kept as the ablation baseline that measures
//! exactly the spawn/join overhead the pool removes (the timer's `sync`
//! phase).
//!
//! Per-step pipeline (paper Fig 17's circulatory dataflow, kernels in
//! `phases`):
//!   1. **deliver** — every worker walks its delay-sorted edge runs for
//!      all pending spikes, accumulating weights into ring slots
//!      `emit + delay` (and applying STDP depression);
//!   2. **integrate** — every worker consumes its ring slot + Poisson
//!      drive and advances its population blocks' dynamics, dispatching
//!      per block on the neuron model (or, on all-LIF networks, the rank
//!      executes the AOT PJRT artifact), collecting new spikes;
//!   3. **plasticity** — spiking posts potentiate their incoming plastic
//!      edges (thread-owned; one kernel shared by both backends);
//!   4. **exchange** — once per min-delay window, spiking gids are
//!      broadcast; in [`CommMode::Overlap`] a dedicated communication
//!      thread (`comm_driver`) runs the exchange while the next window
//!      computes, synchronized with the pool at the window barrier.
//!
//! # Public facade: the simulation session
//!
//! The public entry point is the persistent [`Simulation`] session
//! ([`session`]): rank engines (and their worker pools) are built once,
//! live on session-owned rank threads, and are driven through repeated
//! `run_for` calls with probes, mid-run stimulus mutation and
//! checkpoint/restore in between — extending the worker-pool
//! ownership-transfer design one level up. [`run_simulation`] is a thin
//! one-shot wrapper over it, and [`Ensemble`] ([`ensemble`]) shares one
//! immutable build product across N cheap trajectory sessions.

pub mod checkpoint;
mod comm_driver;
pub mod ensemble;
mod phases;
pub mod ring;
pub mod session;
mod workers;

pub use ensemble::{
    Ensemble, EnsembleBuilder, SharedNetwork, TrajectoryBuilder,
};
pub use session::{
    Simulation, SimulationBuilder, Transport, TransportFactory,
};

use std::sync::Arc;

use crate::atlas::NetworkSpec;
use crate::comm::{SpikeMsg, SpikePacket};
use crate::config::{
    BuildMode, CommMode, DynamicsBackend, ExecMode, IntegrateMode,
    MappingKind, RoutingMode,
};
use crate::decomp::{Partition, RankStore};
use crate::metrics::memory::{vec_bytes, MemoryBreakdown, MemoryReport};
use crate::metrics::{PhaseTimer, SpikeRecorder};
use crate::model::dynamics::{NeuronModel, PopulationState};
use crate::model::poisson::PoissonDrive;
use crate::model::stdp::TraceSet;
use crate::{Gid, Step};
use workers::{StdpRank, StepJob, TrajectoryState, WorkerCtx, WorkerPool};

/// Engine knobs (a validated subset of [`crate::config::ExperimentConfig`]).
#[derive(Clone, Debug)]
pub struct EngineOptions {
    pub n_threads: usize,
    pub comm: CommMode,
    pub backend: DynamicsBackend,
    /// Persistent worker pool vs per-step scoped threads (ablation).
    pub exec: ExecMode,
    /// Two-pass streaming store construction vs the serial staging
    /// builder (ablation; see `decomp::store`).
    pub build: BuildMode,
    /// Branch-free vector integrate kernels vs the scalar ablation
    /// (bit-identical; see `model`).
    pub integrate: IntegrateMode,
    /// Interest-routed spike exchange vs the broadcast allgather
    /// ablation (bit-identical; see `comm`).
    pub routing: RoutingMode,
    /// Built-in raster: record spikes of gids **below** this bound.
    /// `None` means the recorder is disabled (see
    /// [`SpikeRecorder::disabled`]) and no spikes are kept — use
    /// `Some(u32::MAX)` to record everything, or a [`crate::probe`]
    /// for filtered recording.
    pub record_limit: Option<Gid>,
    /// Compile the paper's thread-ownership abort check into the hot loop.
    pub verify_ownership: bool,
    /// Where the AOT artifacts live (PJRT backend).
    pub artifacts_dir: String,
    /// Per-trajectory noise stream: the seed the Poisson drive hashes
    /// with. `None` ⇒ the spec's network seed. Distinct from the
    /// partition seed — overriding it changes the stimulus realization
    /// only, never the built network, which is what lets N ensemble
    /// trajectories share one store while seeing independent noise.
    pub drive_seed: Option<u64>,
}

impl Default for EngineOptions {
    fn default() -> Self {
        EngineOptions {
            n_threads: 1,
            comm: CommMode::Overlap,
            backend: DynamicsBackend::Native,
            exec: ExecMode::Pool,
            build: BuildMode::TwoPass,
            integrate: IntegrateMode::Vector,
            routing: RoutingMode::Routed,
            record_limit: None,
            verify_ownership: false,
            artifacts_dir: "artifacts".into(),
            drive_seed: None,
        }
    }
}

/// One rank's engine: a (possibly shared) immutable topology plus this
/// trajectory's mutable state.
pub struct RankEngine {
    pub rank: u16,
    spec: Arc<NetworkSpec>,
    /// The shared, immutable build product: posts/pres gid maps, thread
    /// ranges **and** every thread's edge store. Read-only during
    /// stepping — an [`ensemble::Ensemble`] hands the same `Arc` to N
    /// engines, which then differ only in per-trajectory state.
    pub store: Arc<RankStore>,
    /// Worker-owned state, in thread order. Parked here between steps
    /// (and permanently in scoped/inline mode).
    ctxs: Vec<WorkerCtx>,
    /// The persistent compute threads (None ⇒ scoped fallback or 1 thread).
    pool: Option<WorkerPool>,
    stdp: Option<StdpRank>,
    /// Spikes awaiting delivery: (pre index, emission step).
    pending: Vec<(u32, Step)>,
    pub recorder: SpikeRecorder,
    pub timer: PhaseTimer,
    step: Step,
    pub opts: EngineOptions,
    pjrt: Option<crate::runtime::PjrtLif>,
    pub total_spikes: u64,
    /// Current external drive per population (starts at the spec's;
    /// mutated by [`Self::set_pop_poisson`]). Checkpointed.
    pop_drives: Vec<PoissonDrive>,
    /// Current DC current offset per population [pA] (starts at 0;
    /// mutated by [`Self::set_pop_dc`]). Checkpointed.
    pop_dc: Vec<f64>,
}

impl RankEngine {
    /// Build rank `r`'s whole engine from the spec and partition:
    /// store construction **and** execution share the same threads. In
    /// [`ExecMode::Pool`] the persistent worker pool is spawned first
    /// and the two-pass builder's count/fill passes run on it (each
    /// worker constructs the edge share it will later step); otherwise
    /// the builder uses transient threads. [`BuildMode::Serial`] keeps
    /// the staging builder as the ablation path.
    pub fn build(
        spec: Arc<NetworkSpec>,
        partition: &Partition,
        r: usize,
        opts: EngineOptions,
    ) -> anyhow::Result<RankEngine> {
        let posts = &partition.members[r];
        // borrow, don't clone: is_local is only consulted on this
        // thread (the builders' serial merge phase), and rank_of is
        // O(n_total) — a per-rank copy would be untracked build memory
        let rank_of = &partition.rank_of;
        let is_local = move |g: Gid| rank_of[g as usize] as usize == r;
        let use_pool = opts.exec == ExecMode::Pool && opts.n_threads > 1;
        let native = opts.backend == DynamicsBackend::Native;
        let (store, pool) = match opts.build {
            BuildMode::Serial => (
                RankStore::build_serial(
                    &spec,
                    posts,
                    is_local,
                    r as u16,
                    opts.n_threads,
                ),
                None,
            ),
            BuildMode::TwoPass if use_pool => {
                let pool = WorkerPool::spawn(opts.n_threads, native);
                let store = RankStore::build_with(
                    &spec,
                    posts,
                    is_local,
                    r as u16,
                    opts.n_threads,
                    &pool,
                );
                (store, Some(pool))
            }
            BuildMode::TwoPass => (
                RankStore::build(
                    &spec,
                    posts,
                    is_local,
                    r as u16,
                    opts.n_threads,
                ),
                None,
            ),
        };
        Self::with_store_and_pool(spec, Arc::new(store), opts, pool)
    }

    /// Construct the engine around an externally built store (tests,
    /// ablations). Spawns its own pool when one is warranted.
    pub fn new(
        spec: Arc<NetworkSpec>,
        store: RankStore,
        opts: EngineOptions,
    ) -> anyhow::Result<RankEngine> {
        Self::with_store_and_pool(spec, Arc::new(store), opts, None)
    }

    /// Construct one trajectory's engine over an **already-built shared
    /// store** (the ensemble path): no store construction, no edge
    /// copies — only the per-trajectory state is allocated. The store's
    /// decomposition fixes `opts.n_threads`.
    pub fn with_shared(
        spec: Arc<NetworkSpec>,
        store: Arc<RankStore>,
        opts: EngineOptions,
    ) -> anyhow::Result<RankEngine> {
        Self::with_store_and_pool(spec, store, opts, None)
    }

    fn with_store_and_pool(
        spec: Arc<NetworkSpec>,
        store: Arc<RankStore>,
        opts: EngineOptions,
        pool: Option<WorkerPool>,
    ) -> anyhow::Result<RankEngine> {
        let ctxs = workers::build_worker_ctxs(
            &spec,
            &store,
            opts.integrate,
            opts.verify_ownership,
            opts.drive_seed.unwrap_or(spec.seed),
        );
        assert_eq!(
            ctxs.len(),
            opts.n_threads,
            "EngineOptions::n_threads must match the store's decomposition"
        );
        let stdp = spec.stdp.map(|params| StdpRank {
            params,
            pre_traces: TraceSet::new(
                store.n_pres(),
                params.tau_plus_ms,
                spec.dt_ms,
            ),
        });
        let recorder = match opts.record_limit {
            Some(lim) => SpikeRecorder::new(lim),
            None => SpikeRecorder::disabled(),
        };
        let pjrt = match opts.backend {
            DynamicsBackend::Native => None,
            DynamicsBackend::Pjrt => Some(crate::runtime::PjrtLif::load(
                &opts.artifacts_dir,
                &spec,
            )?),
        };
        // the pool pays off only with real parallelism; a single context
        // runs inline on the rank thread either way. `build` may hand in
        // the pool that already ran the construction passes.
        let pool = pool.or_else(|| {
            (opts.exec == ExecMode::Pool && ctxs.len() > 1)
                .then(|| WorkerPool::spawn(ctxs.len(), pjrt.is_none()))
        });
        // per-phase construction cost lands in the same timer as the
        // simulation phases (perfprobe / `cortex partition` report it)
        let mut timer = PhaseTimer::new();
        let b = store.build;
        if b.count_ns + b.merge_ns + b.fill_ns > 0 {
            timer.add("build_count", b.count_ns as u128);
            timer.add("build_merge", b.merge_ns as u128);
            timer.add("build_fill", b.fill_ns as u128);
        }
        let pop_drives =
            spec.populations.iter().map(|p| p.drive).collect();
        let pop_dc = vec![0.0; spec.populations.len()];
        Ok(RankEngine {
            rank: store.rank,
            spec,
            store,
            ctxs,
            pool,
            stdp,
            pending: Vec::new(),
            recorder,
            timer,
            step: 0,
            opts,
            pjrt,
            total_spikes: 0,
            pop_drives,
            pop_dc,
        })
    }

    pub fn spec(&self) -> &NetworkSpec {
        &self.spec
    }

    pub fn step(&self) -> Step {
        self.step
    }

    /// Number of compute workers (== decomposition threads).
    pub fn n_workers(&self) -> usize {
        self.ctxs.len()
    }

    /// True when a persistent pool is driving the compute phases.
    pub fn uses_pool(&self) -> bool {
        self.pool.is_some()
    }

    /// Snapshot of the plastic edges as (pre index, local post, delay,
    /// weight), stably sorted by (pre, post, delay). Because every edge
    /// lives with the thread owning its post and within-thread runs keep
    /// generation order, multapse groups preserve their relative order —
    /// the snapshot is canonical, i.e. comparable across thread counts.
    pub fn plastic_edges(&self) -> Vec<(u32, u32, u16, f64)> {
        let mut out = Vec::new();
        for ctx in &self.ctxs {
            let te = ctx.edges();
            // live plastic weights are the trajectory's private copy;
            // static nets read the shared store
            let ws: &[f64] =
                ctx.state.weights.as_deref().unwrap_or(&te.weight);
            for ei in 0..te.n_edges() {
                if te.plastic.get(ei) {
                    out.push((
                        te.epre[ei],
                        te.post[ei],
                        te.delay[ei],
                        ws[ei],
                    ));
                }
            }
        }
        out.sort_by_key(|&(pre, post, delay, _)| (pre, post, delay));
        out
    }

    /// [`Self::plastic_edges`] with global gids: (pre gid, post gid,
    /// delay, weight), canonically sorted. The probe-facing form.
    /// `pres` and `posts` are ascending in gid, so the index-sorted
    /// order of [`Self::plastic_edges`] *is* gid order — no re-sort.
    pub fn plastic_edges_global(&self) -> Vec<(Gid, Gid, u16, f64)> {
        self.plastic_edges()
            .into_iter()
            .map(|(p, lp, delay, w)| {
                (
                    self.store.pres[p as usize],
                    self.store.posts[lp as usize],
                    delay,
                    w,
                )
            })
            .collect()
    }

    /// Membrane potential of `gid`: `Some` iff this rank owns it and its
    /// model has a membrane (parrot relays don't). Probe observation
    /// hook — reads thread-owned state between steps, when no worker
    /// holds it.
    pub fn voltage_of(&self, gid: Gid) -> Option<f64> {
        let local = self.store.post_index_of(gid)?;
        let ctx = self
            .ctxs
            .iter()
            .find(|c| local >= c.lo && local < c.hi)?;
        let i = (local - ctx.lo) as usize;
        let blocks = &ctx.state.blocks;
        let bi = blocks
            .partition_point(|b| b.offset as usize + b.state.len() <= i);
        let b = blocks.get(bi)?;
        b.state.voltage(i - b.offset as usize)
    }

    /// Replace population `pop`'s external Poisson drive. Takes effect
    /// on the next step; the session applies it at window boundaries so
    /// results stay reproducible from the command schedule.
    pub fn set_pop_poisson(
        &mut self,
        pop: u16,
        drive: PoissonDrive,
    ) -> anyhow::Result<()> {
        let pi = pop as usize;
        anyhow::ensure!(
            pi < self.spec.populations.len(),
            "population index {pop} out of range"
        );
        self.pop_drives[pi] = drive;
        let prep = drive.prepare(self.spec.dt_ms);
        for ctx in self.ctxs.iter_mut() {
            let TrajectoryState { blocks, drives, .. } = &mut ctx.state;
            for b in blocks.iter().filter(|b| b.pop == pop) {
                let lo = b.offset as usize;
                let hi = lo + b.state.len();
                for d in &mut drives[lo..hi] {
                    *d = prep;
                }
            }
        }
        Ok(())
    }

    /// Set population `pop`'s DC current offset [pA] (0 restores the
    /// spec's parameters). Implemented by interning an i_ext-shifted
    /// parameter set into each worker's owned dispatch tables and
    /// re-pointing the population's blocks at it — the hot loops are
    /// untouched and a zero offset is bit-identical to never setting
    /// one. Errors for parrot populations (no membrane current) and on
    /// the PJRT backend (the AOT artifact bakes its parameters).
    pub fn set_pop_dc(
        &mut self,
        pop: u16,
        dc_pa: f64,
    ) -> anyhow::Result<()> {
        let pi = pop as usize;
        anyhow::ensure!(
            pi < self.spec.populations.len(),
            "population index {pop} out of range"
        );
        anyhow::ensure!(
            self.pjrt.is_none() || dc_pa == 0.0,
            "DC drive updates are not supported on the PJRT backend \
             (the AOT artifact bakes its parameters)"
        );
        let base = self.spec.params
            [self.spec.populations[pi].params as usize];
        let Some(shifted) = base.with_dc(dc_pa) else {
            anyhow::bail!(
                "population '{}' runs parrot relays and takes no DC \
                 current",
                self.spec.populations[pi].name
            );
        };
        for ctx in self.ctxs.iter_mut() {
            // worker tables grow in lockstep (every update interns into
            // all of them), so a full table fails here on the first
            // context, before any block is re-pointed
            let Some(pidx) = ctx.state.tables.intern(shifted) else {
                anyhow::bail!(
                    "per-worker parameter table is full (255 distinct \
                     parameter sets); reuse previous DC values or reset \
                     offsets to 0 instead of sweeping unboundedly"
                );
            };
            for b in ctx.state.blocks.iter_mut().filter(|b| b.pop == pop)
            {
                b.pidx = pidx;
                if let PopulationState::Lif(s) = &mut b.state {
                    s.pidx.fill(pidx);
                }
            }
        }
        self.pop_dc[pi] = dc_pa;
        Ok(())
    }

    /// Current per-population stimulus state (drive, DC offset) — what
    /// the checkpoint serializes.
    pub fn stimulus_state(&self) -> Vec<(PoissonDrive, f64)> {
        self.pop_drives
            .iter()
            .copied()
            .zip(self.pop_dc.iter().copied())
            .collect()
    }

    /// Enqueue spikes received from other ranks (window start).
    pub fn enqueue_remote(&mut self, spikes: &[SpikeMsg]) {
        for m in spikes {
            if let Some(p) = self.store.pre_index_of(m.gid) {
                self.pending.push((p, m.step as Step));
                if let Some(s) = &mut self.stdp {
                    s.pre_traces.bump(p, m.step as Step);
                }
            }
        }
    }

    /// One integration step; spiking gids are appended to `outbox`.
    pub fn step_once(&mut self, outbox: &mut SpikePacket) {
        let now = self.step;
        let native = self.pjrt.is_none();

        // move the step's shared read-only state out of the engine …
        let job = StepJob {
            now,
            pending: std::mem::take(&mut self.pending),
            stdp: self.stdp.take(),
        };

        // -- phases 1-3: deliver / integrate / plasticity ---------------
        let t_par = std::time::Instant::now();
        let job = match &self.pool {
            Some(pool) => pool.run_step(&mut self.ctxs, job),
            None => {
                if self.ctxs.len() == 1 {
                    phases::run_compute(&mut self.ctxs[0], &job, native);
                } else {
                    // scoped fallback: spawn/join every step (ablation)
                    std::thread::scope(|scope| {
                        for ctx in self.ctxs.iter_mut() {
                            let job = &job;
                            scope.spawn(move || {
                                phases::run_compute(ctx, job, native)
                            });
                        }
                    });
                }
                job
            }
        };
        let wall_ns = t_par.elapsed().as_nanos() as u64;

        // coordination overhead of the parallel section: wall time minus
        // the busiest worker's own compute — channel round-trip for the
        // pool, spawn+join for the scoped fallback
        let busiest = self
            .ctxs
            .iter()
            .map(|c| c.phase_ns[0] + c.phase_ns[1])
            .max()
            .unwrap_or(0);
        self.timer.add("sync", wall_ns.saturating_sub(busiest) as u128);

        // … and reclaim it (all workers have handed their contexts back)
        let StepJob { pending: mut reclaimed, stdp, .. } = job;
        reclaimed.clear();
        self.pending = reclaimed;
        self.stdp = stdp;

        // -- PJRT dynamics (serial per rank over the AOT artifact) ------
        if !native {
            let t1 = std::time::Instant::now();
            let pjrt = self.pjrt.as_mut().unwrap();
            for ctx in &mut self.ctxs {
                phases::gather_inputs(ctx, now);
                {
                    let TrajectoryState {
                        blocks, scratch_e, scratch_i, spikes, ..
                    } = &mut ctx.state;
                    for b in blocks.iter_mut() {
                        let off = b.offset as usize;
                        let n = b.state.len();
                        // `PjrtLif::load` already rejected non-LIF specs
                        let PopulationState::Lif(state) = &mut b.state
                        else {
                            unreachable!("pjrt step on non-LIF block")
                        };
                        let spiked = pjrt
                            .step(
                                state,
                                &scratch_e[off..off + n],
                                &scratch_i[off..off + n],
                            )
                            .expect("pjrt step failed");
                        spikes.extend(
                            spiked.into_iter().map(|s| s + off as u32),
                        );
                    }
                }
                // plasticity: the same thread-owned kernel as the native
                // path, run serially on the rank thread
                if let Some(s) = &self.stdp {
                    let WorkerCtx { t, topo, state, .. } = ctx;
                    let te = &topo.threads[*t];
                    let TrajectoryState {
                        post_traces, weights, spikes, ..
                    } = state;
                    let pt = post_traces
                        .as_mut()
                        .expect("stdp net without post traces");
                    let ws = weights
                        .as_deref_mut()
                        .expect("stdp net without weight copy");
                    for i in 0..spikes.len() {
                        let ls = spikes[i];
                        phases::potentiate_post(
                            te,
                            ws,
                            pt,
                            &s.pre_traces,
                            &s.params,
                            ls,
                            now,
                        );
                    }
                }
            }
            self.timer.add("integrate", t1.elapsed().as_nanos());
        }

        // -- collect spikes, refill pending, feed outbox ----------------
        for ctx in &self.ctxs {
            self.timer.add("deliver", ctx.phase_ns[0] as u128);
            self.timer.add("integrate", ctx.phase_ns[1] as u128);
            for m in NeuronModel::ALL {
                let ns = ctx.model_ns[m.index()];
                if ns > 0 {
                    self.timer.add(integrate_phase_name(m), ns as u128);
                }
            }
            let lo = ctx.lo;
            for &ls in &ctx.state.spikes {
                let local = lo + ls;
                let gid = self.store.posts[local as usize];
                self.total_spikes += 1;
                self.recorder.record(now, gid);
                outbox.push(SpikeMsg { gid, step: now as u32 });
                // deliver locally next step if we have edges from it
                if let Some(p) = self.store.pre_index_of(gid) {
                    self.pending.push((p, now));
                    if let Some(s) = &mut self.stdp {
                        s.pre_traces.bump(p, now);
                    }
                }
            }
        }

        self.step += 1;
    }

    /// Bytes of the **shared** build product this engine reads: the
    /// immutable store (posts/pres maps + every thread's edges). In an
    /// ensemble these bytes exist once no matter how many trajectories
    /// run over them — account them once, not per engine.
    pub fn shared_memory(&self) -> MemoryBreakdown {
        self.store.shared_memory()
    }

    /// Bytes this trajectory **owns**: neuron state, rings, drives,
    /// traces, the private plastic-weight copy — the marginal cost of
    /// one more ensemble member over the same store.
    pub fn trajectory_memory(&self) -> MemoryBreakdown {
        let mut m = MemoryBreakdown::new();
        for ctx in &self.ctxs {
            m.add("state", ctx.state_bytes());
            m.add(
                "rings",
                ctx.state.ring_e.bytes() + ctx.state.ring_i.bytes(),
            );
            m.add("drives", vec_bytes(&ctx.state.drives));
            if let Some(pt) = &ctx.state.post_traces {
                m.add("traces", pt.bytes());
            }
            if let Some(w) = &ctx.state.weights {
                m.add("weights", vec_bytes(w));
            }
        }
        if let Some(s) = &self.stdp {
            m.add("traces", s.pre_traces.bytes());
        }
        m
    }

    /// Per-rank heap accounting (the Fig 18 memory panel's quantity):
    /// shared store + this trajectory's state. Standalone runs see the
    /// same total as before the topology/state split.
    pub fn memory(&self) -> MemoryBreakdown {
        let mut m = self.shared_memory();
        for (k, v) in self.trajectory_memory().components() {
            m.add(k, v);
        }
        m
    }
}

// ---------------------------------------------------------------------
// Per-rank run result + one-shot orchestration (session facade)
// ---------------------------------------------------------------------

/// Result of one rank's run, assembled by **moving** the recorder and
/// timer out of the engine when its session finishes (no terminal
/// clones).
pub struct RankOutput {
    pub rank: u16,
    pub recorder: SpikeRecorder,
    pub timer: PhaseTimer,
    pub memory: MemoryBreakdown,
    pub total_spikes: u64,
    pub comm_bytes: u64,
    /// Spike payload bytes this rank received (the wire-volume mirror
    /// of `comm_bytes`; under routed exchange both shrink to the
    /// subscribed subsets).
    pub comm_recv_bytes: u64,
    pub windows: u64,
    /// Payload frames this rank put on the wire (hierarchical routing
    /// merges these below the mesh's `windows × (ranks − 1)`).
    pub comm_frames: u64,
    /// Fraction of this rank's exchange time hidden behind compute
    /// (`(busy − wait) / busy` of its comm driver; 0 when serialized).
    pub comm_overlap_ratio: f64,
    /// Store + engine construction time (not simulation), measured on
    /// the rank thread that built the engine.
    pub build_seconds: f64,
}

/// Run options for a one-shot multi-rank simulation
/// ([`run_simulation`]); [`SimulationBuilder::run_config`] adopts the
/// same knobs for a persistent session.
#[derive(Clone, Debug)]
pub struct RunConfig {
    pub ranks: usize,
    pub threads: usize,
    pub mapping: MappingKind,
    pub comm: CommMode,
    pub backend: DynamicsBackend,
    pub exec: ExecMode,
    /// Store construction pipeline (two-pass streaming vs serial
    /// staging ablation).
    pub build: BuildMode,
    /// Integrate-kernel formulation (branch-free vector vs the scalar
    /// ablation; bit-identical either way).
    pub integrate: IntegrateMode,
    /// Spike-exchange routing (interest-routed vs the broadcast
    /// allgather ablation; bit-identical either way).
    pub routing: RoutingMode,
    /// Per-rank host-group ids for hierarchical routing (empty = auto
    /// groups of two consecutive ranks).
    pub comm_group: Vec<usize>,
    pub steps: Step,
    /// Built-in raster: record gids below this bound; `None` disables
    /// recording entirely (documented [`SpikeRecorder::disabled`]
    /// semantics — use `Some(u32::MAX)` to record everything).
    pub record_limit: Option<Gid>,
    pub verify_ownership: bool,
    pub artifacts_dir: String,
    pub seed: u64,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            ranks: 2,
            threads: 2,
            mapping: MappingKind::AreaProcesses,
            comm: CommMode::Overlap,
            backend: DynamicsBackend::Native,
            exec: ExecMode::Pool,
            build: BuildMode::TwoPass,
            integrate: IntegrateMode::Vector,
            routing: RoutingMode::Routed,
            comm_group: Vec::new(),
            steps: 1000,
            record_limit: None,
            verify_ownership: false,
            artifacts_dir: "artifacts".into(),
            seed: 1,
        }
    }
}

/// Merged output of a full run.
pub struct RunOutput {
    pub raster: SpikeRecorder,
    /// Critical-path timer (max over ranks per phase).
    pub timer_max: PhaseTimer,
    /// Aggregate timer (sum over ranks).
    pub timer_sum: PhaseTimer,
    pub memory: MemoryReport,
    pub total_spikes: u64,
    /// Simulation wall time (the paper's Fig 18 quantity) — excludes
    /// network construction.
    pub wall_seconds: f64,
    /// Network construction time (per-rank max): indegree sub-graph
    /// generation + (pre, delay) edge layout.
    pub build_seconds: f64,
    pub comm_bytes: u64,
    /// Total spike payload bytes received across ranks (== `comm_bytes`
    /// in a closed cluster; reported separately because the Tofu
    /// projection charges injection and reception independently).
    pub comm_recv_bytes: u64,
    pub windows: u64,
    /// Payload frames across ranks per run (hierarchical routing's
    /// headline metric: merged relay frames vs the mesh's
    /// `windows × ranks × (ranks − 1)`).
    pub comm_frames: u64,
    /// Fraction of exchange time hidden behind compute, worst rank
    /// (min over ranks — the critical-path view; 0 when serialized).
    pub comm_overlap_ratio: f64,
    pub partition: Partition,
}

/// Timer phase a model's integrate nanoseconds accumulate under
/// (alongside the aggregate `integrate` phase).
pub fn integrate_phase_name(m: NeuronModel) -> &'static str {
    match m {
        NeuronModel::Lif => "integrate_lif",
        NeuronModel::Adex => "integrate_adex",
        NeuronModel::Hh => "integrate_hh",
        NeuronModel::Parrot => "integrate_parrot",
    }
}

/// Per-model integrate throughput of a finished run: `(model, neurons,
/// ns/neuron-step)` for every model with recorded integrate time. Reads
/// the `integrate_<model>` phases of an **aggregate** timer (summed over
/// workers and ranks — [`RunOutput::timer_sum`] or a solo engine's
/// timer), so dividing by the spec-wide neuron count times `steps` is
/// exact: the same metric `benches/ablation_models.rs` tracks in
/// `BENCH_step.json`.
pub fn integrate_rates(
    spec: &NetworkSpec,
    timer: &PhaseTimer,
    steps: Step,
) -> Vec<(NeuronModel, u64, f64)> {
    let mut counts = [0u64; NeuronModel::COUNT];
    for p in &spec.populations {
        counts[p.model.index()] += p.n as u64;
    }
    let mut out = Vec::new();
    for m in NeuronModel::ALL {
        let n = counts[m.index()];
        let ns = timer.nanos(integrate_phase_name(m));
        if n > 0 && steps > 0 && ns > 0 {
            out.push((
                m,
                n,
                ns as f64 / (n as f64 * steps as f64),
            ));
        }
    }
    out
}

/// Partition the network and run it on `cfg.ranks` simulated ranks.
///
/// Since the session API redesign this is a thin compatibility wrapper:
/// it builds a persistent [`Simulation`], drives it for `cfg.steps`
/// steps and tears it down. Code that runs repeatedly, attaches probes,
/// steers stimuli mid-run or checkpoints should hold the [`Simulation`]
/// itself (`Simulation::builder(spec)` … `build()?` … `run_for(n)?`).
pub fn run_simulation(
    spec: &Arc<NetworkSpec>,
    cfg: &RunConfig,
) -> anyhow::Result<RunOutput> {
    let mut sim =
        Simulation::builder(Arc::clone(spec)).run_config(cfg).build()?;
    sim.run_for(cfg.steps)?;
    sim.finish()
}
