//! Engine checkpointing: snapshot a rank's complete dynamical state and
//! resume bit-exactly. A long brain simulation on a shared machine (the
//! paper's runs burn node-hours on Fugaku) needs restartability; the
//! deterministic substrate makes it exact here.
//!
//! The snapshot covers everything that evolves: step counter, neuron-
//! model state, both input rings, the pending spike list, plastic
//! weights, STDP traces — and the session-control state (per-population
//! Poisson-drive and DC-offset overrides), so a session restored
//! mid-experiment keeps the stimulus program the user had steered it
//! to. Static structure (the indegree store layout,
//! LIF pidx tables, HH gate layout) is *not* saved — it regenerates
//! deterministically from the spec, which keeps checkpoints small
//! (O(neurons + ring) instead of O(synapses)) except for plastic
//! weights, which are dynamical and are saved.
//!
//! Neuron-model state is serialized as **tagged model segments**: one
//! section per rank-level population run (posts are gid-sorted, so the
//! runs are the populations in order), carrying the population index, a
//! model tag, and the model's evolving f64 fields in a fixed order (see
//! `PopulationState::field_slices`). Mixed LIF/AdEx/HH/parrot circuits
//! checkpoint through the same path as homogeneous ones.
//!
//! The dynamical state lives in the engine's worker contexts (one per
//! compute thread; see `engine::workers`), so every section is gathered
//! across contexts in thread order on save and scattered back on
//! restore. Because thread ranges tile the rank's posts contiguously —
//! and worker blocks of the same population merge back into one segment
//! — the byte stream is independent of the thread count.
//!
//! Consistency contract: checkpoint at a **window boundary, with the
//! boundary's exchange drained into the pending list** so no spikes are
//! in flight outside the snapshot. The session facade
//! (`engine::session`) enforces exactly this: `Simulation::checkpoint`
//! requires a window boundary, drains each rank's in-flight exchange
//! first, and flags the rank loop so the next window does not receive
//! twice. [`RankEngine::run_windows_solo`] keeps the same alignment for
//! single-rank engine-level use.

use std::io::{Read, Write};

use anyhow::{bail, Context, Result};

use super::RankEngine;
use crate::Step;

// "CORTEX3": CORTEX2's tagged model blocks plus the per-population
// stimulus-override section. The bump makes pre-session-API CORTEX2
// blobs fail the magic check instead of misparsing.
const MAGIC: u64 = 0x434f52_54455833;

// u64 framing is shared with the session-level wrapper
// (`engine::session`), which prepends its own header to these blobs.
pub(crate) fn put_u64(w: &mut impl Write, x: u64) -> Result<()> {
    w.write_all(&x.to_le_bytes())?;
    Ok(())
}

pub(crate) fn get_u64(r: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn put_f64(w: &mut impl Write, x: f64) -> Result<()> {
    w.write_all(&x.to_le_bytes())?;
    Ok(())
}

fn get_f64(r: &mut impl Read) -> Result<f64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(f64::from_le_bytes(b))
}

fn put_f64s(w: &mut impl Write, xs: &[f64]) -> Result<()> {
    put_u64(w, xs.len() as u64)?;
    for &x in xs {
        w.write_all(&x.to_le_bytes())?;
    }
    Ok(())
}

fn get_f64s(r: &mut impl Read) -> Result<Vec<f64>> {
    let n = get_u64(r)? as usize;
    let mut out = Vec::with_capacity(n);
    let mut b = [0u8; 8];
    for _ in 0..n {
        r.read_exact(&mut b)?;
        out.push(f64::from_le_bytes(b));
    }
    Ok(out)
}

/// Write a length header followed by each part — the same byte stream
/// [`put_f64s`] produces for the concatenation.
fn gather_f64s(w: &mut impl Write, parts: &[&[f64]]) -> Result<()> {
    let total: usize = parts.iter().map(|p| p.len()).sum();
    put_u64(w, total as u64)?;
    for part in parts {
        for &x in *part {
            w.write_all(&x.to_le_bytes())?;
        }
    }
    Ok(())
}

/// Read one [`put_f64s`] section and split it along `spans`.
fn scatter_f64s(
    r: &mut impl Read,
    spans: &[usize],
) -> Result<Vec<Vec<f64>>> {
    let all = get_f64s(r)?;
    let want: usize = spans.iter().sum();
    if all.len() != want {
        bail!("checkpoint shape mismatch: {} vs {want}", all.len());
    }
    let mut out = Vec::with_capacity(spans.len());
    let mut off = 0;
    for &span in spans {
        out.push(all[off..off + span].to_vec());
        off += span;
    }
    Ok(out)
}

impl RankEngine {
    /// Serialize the dynamical state (see module docs for the
    /// consistency contract).
    pub fn checkpoint(&self, w: &mut impl Write) -> Result<()> {
        put_u64(w, MAGIC)?;
        put_u64(w, self.rank as u64)?;
        put_u64(w, self.step)?;
        put_u64(w, self.total_spikes)?;
        // session-control state: per-population stimulus overrides
        // (Poisson drive + DC offset). Rank-level, so the bytes stay
        // thread-count independent; restore re-derives the per-worker
        // drive tables and interned parameter sets from these.
        let stim = self.stimulus_state();
        put_u64(w, stim.len() as u64)?;
        for (drive, dc) in &stim {
            put_f64(w, drive.rate_hz)?;
            put_f64(w, drive.weight_pa)?;
            put_f64(w, *dc)?;
        }
        // neuron-model state: tagged per-population segments. Worker
        // blocks of the same population (split by thread ranges) merge
        // into one segment, so the bytes are thread-count independent.
        let mut segs: Vec<(
            u16,
            Vec<&crate::model::dynamics::PopulationState>,
        )> = Vec::new();
        for ctx in &self.ctxs {
            for b in &ctx.state.blocks {
                match segs.last_mut() {
                    Some((pop, parts)) if *pop == b.pop => {
                        parts.push(&b.state)
                    }
                    _ => segs.push((b.pop, vec![&b.state])),
                }
            }
        }
        put_u64(w, segs.len() as u64)?;
        for (pop, parts) in &segs {
            put_u64(w, *pop as u64)?;
            put_u64(w, parts[0].checkpoint_tag())?;
            put_u64(
                w,
                parts.iter().map(|s| s.len()).sum::<usize>() as u64,
            )?;
            for f in 0..parts[0].n_fields() {
                let field_parts: Vec<&[f64]> =
                    parts.iter().map(|s| s.field_slices()[f]).collect();
                gather_f64s(w, &field_parts)?;
            }
        }
        // rings: worker buffers are post-major rows of the same ring, so
        // their concatenation is the monolithic ring's buffer
        put_u64(w, self.ctxs[0].state.ring_e.len as u64)?;
        let parts: Vec<&[f64]> =
            self.ctxs.iter().map(|c| c.state.ring_e.raw()).collect();
        gather_f64s(w, &parts)?;
        put_u64(w, self.ctxs[0].state.ring_i.len as u64)?;
        let parts: Vec<&[f64]> =
            self.ctxs.iter().map(|c| c.state.ring_i.raw()).collect();
        gather_f64s(w, &parts)?;
        // pending spikes
        put_u64(w, self.pending.len() as u64)?;
        for &(p, emit) in &self.pending {
            put_u64(w, p as u64)?;
            put_u64(w, emit)?;
        }
        // plastic weights + traces
        match &self.stdp {
            None => put_u64(w, 0)?,
            Some(s) => {
                put_u64(w, 1)?;
                // live weights are the trajectory's private copy —
                // same per-thread order (and therefore bytes) as when
                // the store's own weights were serialized
                for ctx in &self.ctxs {
                    let ws = ctx
                        .state
                        .weights
                        .as_ref()
                        .expect("stdp net without weight copy");
                    put_f64s(w, ws)?;
                }
                s.pre_traces.save(w)?;
                // post traces (worker-owned): values then last-steps,
                // each gathered in thread order
                let parts: Vec<&[f64]> = self
                    .ctxs
                    .iter()
                    .map(|c| {
                        c.state.post_traces.as_ref().expect("stdp").raw().0
                    })
                    .collect();
                gather_f64s(w, &parts)?;
                let total: usize = self
                    .ctxs
                    .iter()
                    .map(|c| {
                        c.state
                            .post_traces
                            .as_ref()
                            .expect("stdp")
                            .raw()
                            .1
                            .len()
                    })
                    .sum();
                put_u64(w, total as u64)?;
                for ctx in &self.ctxs {
                    let (_, last) =
                        ctx.state.post_traces.as_ref().expect("stdp").raw();
                    for &x in last {
                        put_u64(w, x)?;
                    }
                }
            }
        }
        Ok(())
    }

    /// Restore a checkpoint written by [`Self::checkpoint`] into an
    /// engine freshly built from the same spec/partition/options.
    pub fn restore(&mut self, r: &mut impl Read) -> Result<()> {
        if get_u64(r)? != MAGIC {
            bail!("not a CORTEX checkpoint");
        }
        let rank = get_u64(r)?;
        if rank != self.rank as u64 {
            bail!("checkpoint is for rank {rank}, engine is {}", self.rank);
        }
        self.step = get_u64(r)?;
        self.total_spikes = get_u64(r)?;
        // stimulus overrides: reapply where they differ from the
        // fresh-built state (a no-op for never-mutated sessions)
        let n_pops = get_u64(r)? as usize;
        let current = self.stimulus_state();
        if n_pops != current.len() {
            bail!(
                "checkpoint has {n_pops} populations, engine has {}",
                current.len()
            );
        }
        for (pop, (cur_drive, cur_dc)) in current.into_iter().enumerate()
        {
            let drive = crate::model::poisson::PoissonDrive::new(
                get_f64(r)?,
                get_f64(r)?,
            );
            let dc = get_f64(r)?;
            if drive != cur_drive {
                self.set_pop_poisson(pop as u16, drive)?;
            }
            if dc != cur_dc {
                self.set_pop_dc(pop as u16, dc)?;
            }
        }
        // neuron-model state: mirror the save-side segmentation over our
        // own blocks ((ctx, block) indices per rank-level population run)
        let mut layout: Vec<(u16, u64, Vec<(usize, usize)>)> = Vec::new();
        for (ci, ctx) in self.ctxs.iter().enumerate() {
            for (bi, b) in ctx.state.blocks.iter().enumerate() {
                match layout.last_mut() {
                    Some((pop, _, parts)) if *pop == b.pop => {
                        parts.push((ci, bi))
                    }
                    _ => layout.push((
                        b.pop,
                        b.state.checkpoint_tag(),
                        vec![(ci, bi)],
                    )),
                }
            }
        }
        let n_segs = get_u64(r)? as usize;
        if n_segs != layout.len() {
            bail!(
                "checkpoint has {n_segs} model segments, engine has {}",
                layout.len()
            );
        }
        for (pop, tag, parts) in layout {
            let f_pop = get_u64(r)?;
            let f_tag = get_u64(r)?;
            let f_len = get_u64(r)? as usize;
            if f_pop != pop as u64 || f_tag != tag {
                bail!(
                    "checkpoint segment (pop {f_pop}, tag {f_tag}) does \
                     not match engine (pop {pop}, tag {tag})"
                );
            }
            let seg_spans: Vec<usize> = parts
                .iter()
                .map(|&(ci, bi)| {
                    self.ctxs[ci].state.blocks[bi].state.len()
                })
                .collect();
            if f_len != seg_spans.iter().sum::<usize>() {
                bail!("checkpoint segment length mismatch");
            }
            let (c0, b0) = parts[0];
            let n_fields =
                self.ctxs[c0].state.blocks[b0].state.n_fields();
            for f in 0..n_fields {
                let vals = scatter_f64s(r, &seg_spans)
                    .with_context(|| format!("pop {pop} field {f}"))?;
                for (&(ci, bi), v) in parts.iter().zip(vals) {
                    self.ctxs[ci].state.blocks[bi]
                        .state
                        .restore_field(f, v);
                }
            }
        }
        for chan in 0..2usize {
            let len = get_u64(r)? as usize;
            let ring_spans: Vec<usize> = self
                .ctxs
                .iter()
                .map(|c| {
                    if chan == 0 {
                        c.state.ring_e.raw().len()
                    } else {
                        c.state.ring_i.raw().len()
                    }
                })
                .collect();
            if len != self.ctxs[0].state.ring_e.len {
                bail!(
                    "ring length mismatch: {len} vs {}",
                    self.ctxs[0].state.ring_e.len
                );
            }
            let parts = scatter_f64s(r, &ring_spans).context("rings")?;
            for (ctx, part) in self.ctxs.iter_mut().zip(parts) {
                let buf = if chan == 0 {
                    ctx.state.ring_e.raw_mut()
                } else {
                    ctx.state.ring_i.raw_mut()
                };
                buf.copy_from_slice(&part);
            }
        }
        let np = get_u64(r)? as usize;
        self.pending.clear();
        for _ in 0..np {
            let p = get_u64(r)? as u32;
            let emit = get_u64(r)?;
            self.pending.push((p, emit));
        }
        let has_stdp = get_u64(r)? == 1;
        if has_stdp != self.stdp.is_some() {
            bail!("checkpoint plasticity flag mismatch");
        }
        // post traces are worker-owned over the full thread span
        let spans: Vec<usize> =
            self.ctxs.iter().map(|c| c.span()).collect();
        if let Some(s) = &mut self.stdp {
            for ctx in &mut self.ctxs {
                let w = get_f64s(r)?;
                if w.len() != ctx.edges().weight.len() {
                    bail!("plastic weight shape mismatch");
                }
                // restore into the trajectory's private copy; the
                // shared store keeps its pristine build-time weights
                ctx.state.weights = Some(w);
            }
            s.pre_traces.load(r).context("pre_traces")?;
            let values = scatter_f64s(r, &spans).context("post_traces")?;
            let total = get_u64(r)? as usize;
            if total != spans.iter().sum::<usize>() {
                bail!("post trace shape mismatch");
            }
            let mut lasts: Vec<Vec<Step>> = Vec::with_capacity(spans.len());
            for &span in &spans {
                let mut part = Vec::with_capacity(span);
                for _ in 0..span {
                    part.push(get_u64(r)?);
                }
                lasts.push(part);
            }
            for ((ctx, value), last) in
                self.ctxs.iter_mut().zip(values).zip(lasts)
            {
                ctx.state
                    .post_traces
                    .as_mut()
                    .expect("stdp")
                    .raw_restore(value, last)
                    .map_err(|e| anyhow::anyhow!(e))?;
            }
        }
        Ok(())
    }

    /// Run `windows` min-delay windows on a single-rank engine (no
    /// exchange), window-aligned so the result can be checkpointed and
    /// resumed exactly. Returns emitted spikes as (step, gid).
    pub fn run_windows_solo(&mut self, windows: u64) -> Vec<(Step, u32)> {
        let m = self.spec.min_delay_steps as u64;
        assert!(m >= 1, "window size must be positive");
        let mut events = Vec::new();
        for _ in 0..windows {
            let mut outbox = Vec::new();
            for _ in 0..m {
                self.step_once(&mut outbox);
            }
            for msg in outbox {
                events.push((msg.step as Step, msg.gid));
            }
        }
        events
    }
}

// persistence hooks for the pre-trace container (kept here so the main
// modules stay serialization-free; worker-owned rings and post-traces
// are gathered/scattered directly by checkpoint/restore above)
impl crate::model::stdp::TraceSet {
    pub fn save(&self, w: &mut impl Write) -> Result<()> {
        let (value, last) = self.raw();
        put_f64s(w, value)?;
        put_u64(w, last.len() as u64)?;
        for &x in last {
            put_u64(w, x)?;
        }
        Ok(())
    }

    pub fn load(&mut self, r: &mut impl Read) -> Result<()> {
        let value = get_f64s(r)?;
        let n = get_u64(r)? as usize;
        let mut last = Vec::with_capacity(n);
        for _ in 0..n {
            last.push(get_u64(r)?);
        }
        self.raw_restore(value, last)
            .map_err(|e| anyhow::anyhow!(e))
    }
}
