//! Engine checkpointing: snapshot a rank's complete dynamical state and
//! resume bit-exactly. A long brain simulation on a shared machine (the
//! paper's runs burn node-hours on Fugaku) needs restartability; the
//! deterministic substrate makes it exact here.
//!
//! The snapshot covers everything that evolves: step counter, LIF state,
//! both input rings, the pending spike list, plastic weights and STDP
//! traces. Static structure (the indegree store layout) is *not* saved —
//! it regenerates deterministically from the spec, which keeps
//! checkpoints small (O(neurons + ring) instead of O(synapses)) except
//! for plastic weights, which are dynamical and are saved.
//!
//! The dynamical state lives in the engine's worker contexts (one per
//! compute thread; see `engine::workers`), so every section is gathered
//! across contexts in thread order on save and scattered back on
//! restore. Because thread ranges tile the rank's posts contiguously,
//! the gathered byte stream is identical to what the old monolithic
//! (rank-level) containers produced.
//!
//! Consistency contract: checkpoint at a **window boundary, before
//! `enqueue_remote`** (i.e. right after `run_rank`'s exchange completes
//! and before the next window starts) so no spikes are in flight.
//! `checkpoint_window` drives a window-aligned run loop for single-rank
//! engines; multi-rank restart additionally requires replaying the same
//! window schedule on every rank.

use std::io::{Read, Write};

use anyhow::{bail, Context, Result};

use super::RankEngine;
use crate::Step;

const MAGIC: u64 = 0x434f52_54455831; // "CORTEX1"

fn put_u64(w: &mut impl Write, x: u64) -> Result<()> {
    w.write_all(&x.to_le_bytes())?;
    Ok(())
}

fn get_u64(r: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn put_f64s(w: &mut impl Write, xs: &[f64]) -> Result<()> {
    put_u64(w, xs.len() as u64)?;
    for &x in xs {
        w.write_all(&x.to_le_bytes())?;
    }
    Ok(())
}

fn get_f64s(r: &mut impl Read) -> Result<Vec<f64>> {
    let n = get_u64(r)? as usize;
    let mut out = Vec::with_capacity(n);
    let mut b = [0u8; 8];
    for _ in 0..n {
        r.read_exact(&mut b)?;
        out.push(f64::from_le_bytes(b));
    }
    Ok(out)
}

/// Write a length header followed by each part — the same byte stream
/// [`put_f64s`] produces for the concatenation.
fn gather_f64s(w: &mut impl Write, parts: &[&[f64]]) -> Result<()> {
    let total: usize = parts.iter().map(|p| p.len()).sum();
    put_u64(w, total as u64)?;
    for part in parts {
        for &x in *part {
            w.write_all(&x.to_le_bytes())?;
        }
    }
    Ok(())
}

/// Read one [`put_f64s`] section and split it along `spans`.
fn scatter_f64s(
    r: &mut impl Read,
    spans: &[usize],
) -> Result<Vec<Vec<f64>>> {
    let all = get_f64s(r)?;
    let want: usize = spans.iter().sum();
    if all.len() != want {
        bail!("checkpoint shape mismatch: {} vs {want}", all.len());
    }
    let mut out = Vec::with_capacity(spans.len());
    let mut off = 0;
    for &span in spans {
        out.push(all[off..off + span].to_vec());
        off += span;
    }
    Ok(out)
}

impl RankEngine {
    /// Serialize the dynamical state (see module docs for the
    /// consistency contract).
    pub fn checkpoint(&self, w: &mut impl Write) -> Result<()> {
        put_u64(w, MAGIC)?;
        put_u64(w, self.rank as u64)?;
        put_u64(w, self.step)?;
        put_u64(w, self.total_spikes)?;
        // LIF SoA, gathered across workers in thread order
        let parts: Vec<&[f64]> =
            self.ctxs.iter().map(|c| c.state.u.as_slice()).collect();
        gather_f64s(w, &parts)?;
        let parts: Vec<&[f64]> =
            self.ctxs.iter().map(|c| c.state.ie.as_slice()).collect();
        gather_f64s(w, &parts)?;
        let parts: Vec<&[f64]> =
            self.ctxs.iter().map(|c| c.state.ii.as_slice()).collect();
        gather_f64s(w, &parts)?;
        let parts: Vec<&[f64]> =
            self.ctxs.iter().map(|c| c.state.refrac.as_slice()).collect();
        gather_f64s(w, &parts)?;
        // rings: worker buffers are post-major rows of the same ring, so
        // their concatenation is the monolithic ring's buffer
        put_u64(w, self.ctxs[0].ring_e.len as u64)?;
        let parts: Vec<&[f64]> =
            self.ctxs.iter().map(|c| c.ring_e.raw()).collect();
        gather_f64s(w, &parts)?;
        put_u64(w, self.ctxs[0].ring_i.len as u64)?;
        let parts: Vec<&[f64]> =
            self.ctxs.iter().map(|c| c.ring_i.raw()).collect();
        gather_f64s(w, &parts)?;
        // pending spikes
        put_u64(w, self.pending.len() as u64)?;
        for &(p, emit) in &self.pending {
            put_u64(w, p as u64)?;
            put_u64(w, emit)?;
        }
        // plastic weights + traces
        match &self.stdp {
            None => put_u64(w, 0)?,
            Some(s) => {
                put_u64(w, 1)?;
                for ctx in &self.ctxs {
                    put_f64s(w, &ctx.edges.weight)?;
                }
                s.pre_traces.save(w)?;
                // post traces (worker-owned): values then last-steps,
                // each gathered in thread order
                let parts: Vec<&[f64]> = self
                    .ctxs
                    .iter()
                    .map(|c| c.post_traces.as_ref().expect("stdp").raw().0)
                    .collect();
                gather_f64s(w, &parts)?;
                let total: usize = self
                    .ctxs
                    .iter()
                    .map(|c| c.post_traces.as_ref().expect("stdp").raw().1.len())
                    .sum();
                put_u64(w, total as u64)?;
                for ctx in &self.ctxs {
                    let (_, last) =
                        ctx.post_traces.as_ref().expect("stdp").raw();
                    for &x in last {
                        put_u64(w, x)?;
                    }
                }
            }
        }
        Ok(())
    }

    /// Restore a checkpoint written by [`Self::checkpoint`] into an
    /// engine freshly built from the same spec/partition/options.
    pub fn restore(&mut self, r: &mut impl Read) -> Result<()> {
        if get_u64(r)? != MAGIC {
            bail!("not a CORTEX checkpoint");
        }
        let rank = get_u64(r)?;
        if rank != self.rank as u64 {
            bail!("checkpoint is for rank {rank}, engine is {}", self.rank);
        }
        self.step = get_u64(r)?;
        self.total_spikes = get_u64(r)?;
        let spans: Vec<usize> =
            self.ctxs.iter().map(|c| c.state.len()).collect();
        for field in 0..4usize {
            let parts = scatter_f64s(r, &spans)
                .with_context(|| format!("state field {field}"))?;
            for (ctx, part) in self.ctxs.iter_mut().zip(parts) {
                match field {
                    0 => ctx.state.u = part,
                    1 => ctx.state.ie = part,
                    2 => ctx.state.ii = part,
                    _ => ctx.state.refrac = part,
                }
            }
        }
        for chan in 0..2usize {
            let len = get_u64(r)? as usize;
            let ring_spans: Vec<usize> = self
                .ctxs
                .iter()
                .map(|c| {
                    if chan == 0 { c.ring_e.raw().len() } else { c.ring_i.raw().len() }
                })
                .collect();
            if len != self.ctxs[0].ring_e.len {
                bail!(
                    "ring length mismatch: {len} vs {}",
                    self.ctxs[0].ring_e.len
                );
            }
            let parts = scatter_f64s(r, &ring_spans).context("rings")?;
            for (ctx, part) in self.ctxs.iter_mut().zip(parts) {
                let buf = if chan == 0 {
                    ctx.ring_e.raw_mut()
                } else {
                    ctx.ring_i.raw_mut()
                };
                buf.copy_from_slice(&part);
            }
        }
        let np = get_u64(r)? as usize;
        self.pending.clear();
        for _ in 0..np {
            let p = get_u64(r)? as u32;
            let emit = get_u64(r)?;
            self.pending.push((p, emit));
        }
        let has_stdp = get_u64(r)? == 1;
        if has_stdp != self.stdp.is_some() {
            bail!("checkpoint plasticity flag mismatch");
        }
        if let Some(s) = &mut self.stdp {
            for ctx in &mut self.ctxs {
                let w = get_f64s(r)?;
                if w.len() != ctx.edges.weight.len() {
                    bail!("plastic weight shape mismatch");
                }
                ctx.edges.weight = w;
            }
            s.pre_traces.load(r).context("pre_traces")?;
            let values = scatter_f64s(r, &spans).context("post_traces")?;
            let total = get_u64(r)? as usize;
            if total != spans.iter().sum::<usize>() {
                bail!("post trace shape mismatch");
            }
            let mut lasts: Vec<Vec<Step>> = Vec::with_capacity(spans.len());
            for &span in &spans {
                let mut part = Vec::with_capacity(span);
                for _ in 0..span {
                    part.push(get_u64(r)?);
                }
                lasts.push(part);
            }
            for ((ctx, value), last) in
                self.ctxs.iter_mut().zip(values).zip(lasts)
            {
                ctx.post_traces
                    .as_mut()
                    .expect("stdp")
                    .raw_restore(value, last)
                    .map_err(|e| anyhow::anyhow!(e))?;
            }
        }
        Ok(())
    }

    /// Run `windows` min-delay windows on a single-rank engine (no
    /// exchange), window-aligned so the result can be checkpointed and
    /// resumed exactly. Returns emitted spikes as (step, gid).
    pub fn run_windows_solo(&mut self, windows: u64) -> Vec<(Step, u32)> {
        let m = self.spec.min_delay_steps as u64;
        assert!(m >= 1, "window size must be positive");
        let mut events = Vec::new();
        for _ in 0..windows {
            let mut outbox = Vec::new();
            for _ in 0..m {
                self.step_once(&mut outbox);
            }
            for msg in outbox {
                events.push((msg.step as Step, msg.gid));
            }
        }
        events
    }
}

// persistence hooks for the pre-trace container (kept here so the main
// modules stay serialization-free; worker-owned rings and post-traces
// are gathered/scattered directly by checkpoint/restore above)
impl crate::model::stdp::TraceSet {
    pub fn save(&self, w: &mut impl Write) -> Result<()> {
        let (value, last) = self.raw();
        put_f64s(w, value)?;
        put_u64(w, last.len() as u64)?;
        for &x in last {
            put_u64(w, x)?;
        }
        Ok(())
    }

    pub fn load(&mut self, r: &mut impl Read) -> Result<()> {
        let value = get_f64s(r)?;
        let n = get_u64(r)? as usize;
        let mut last = Vec::with_capacity(n);
        for _ in 0..n {
            last.push(get_u64(r)?);
        }
        self.raw_restore(value, last)
            .map_err(|e| anyhow::anyhow!(e))
    }
}
