//! Engine checkpointing: snapshot a rank's complete dynamical state and
//! resume bit-exactly. A long brain simulation on a shared machine (the
//! paper's runs burn node-hours on Fugaku) needs restartability; the
//! deterministic substrate makes it exact here.
//!
//! The snapshot covers everything that evolves: step counter, LIF state,
//! both input rings, the pending spike list, plastic weights and STDP
//! traces. Static structure (the indegree store layout) is *not* saved —
//! it regenerates deterministically from the spec, which keeps
//! checkpoints small (O(neurons + ring) instead of O(synapses)) except
//! for plastic weights, which are dynamical and are saved.
//!
//! Consistency contract: checkpoint at a **window boundary, before
//! `enqueue_remote`** (i.e. right after `run_rank`'s exchange completes
//! and before the next window starts) so no spikes are in flight.
//! `checkpoint_window` drives a window-aligned run loop for single-rank
//! engines; multi-rank restart additionally requires replaying the same
//! window schedule on every rank.

use std::io::{Read, Write};

use anyhow::{bail, Context, Result};

use super::RankEngine;
use crate::Step;

const MAGIC: u64 = 0x434f52_54455831; // "CORTEX1"

fn put_u64(w: &mut impl Write, x: u64) -> Result<()> {
    w.write_all(&x.to_le_bytes())?;
    Ok(())
}

fn get_u64(r: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn put_f64s(w: &mut impl Write, xs: &[f64]) -> Result<()> {
    put_u64(w, xs.len() as u64)?;
    for &x in xs {
        w.write_all(&x.to_le_bytes())?;
    }
    Ok(())
}

fn get_f64s(r: &mut impl Read) -> Result<Vec<f64>> {
    let n = get_u64(r)? as usize;
    let mut out = Vec::with_capacity(n);
    let mut b = [0u8; 8];
    for _ in 0..n {
        r.read_exact(&mut b)?;
        out.push(f64::from_le_bytes(b));
    }
    Ok(out)
}

impl RankEngine {
    /// Serialize the dynamical state (see module docs for the
    /// consistency contract).
    pub fn checkpoint(&self, w: &mut impl Write) -> Result<()> {
        put_u64(w, MAGIC)?;
        put_u64(w, self.rank as u64)?;
        put_u64(w, self.step)?;
        put_u64(w, self.total_spikes)?;
        put_f64s(w, &self.state.u)?;
        put_f64s(w, &self.state.ie)?;
        put_f64s(w, &self.state.ii)?;
        put_f64s(w, &self.state.refrac)?;
        self.ring_e.save(w)?;
        self.ring_i.save(w)?;
        // pending spikes
        put_u64(w, self.pending.len() as u64)?;
        for &(p, emit) in &self.pending {
            put_u64(w, p as u64)?;
            put_u64(w, emit)?;
        }
        // plastic weights + traces
        match &self.stdp {
            None => put_u64(w, 0)?,
            Some(s) => {
                put_u64(w, 1)?;
                for te in &self.store.threads {
                    put_f64s(w, &te.weight)?;
                }
                s.pre_traces.save(w)?;
                s.post_traces.save(w)?;
            }
        }
        Ok(())
    }

    /// Restore a checkpoint written by [`Self::checkpoint`] into an
    /// engine freshly built from the same spec/partition/options.
    pub fn restore(&mut self, r: &mut impl Read) -> Result<()> {
        if get_u64(r)? != MAGIC {
            bail!("not a CORTEX checkpoint");
        }
        let rank = get_u64(r)?;
        if rank != self.rank as u64 {
            bail!("checkpoint is for rank {rank}, engine is {}", self.rank);
        }
        self.step = get_u64(r)?;
        self.total_spikes = get_u64(r)?;
        let n = self.state.len();
        let load = |xs: Vec<f64>, want: usize| -> Result<Vec<f64>> {
            if xs.len() != want {
                bail!("checkpoint shape mismatch: {} vs {want}", xs.len());
            }
            Ok(xs)
        };
        self.state.u = load(get_f64s(r)?, n)?;
        self.state.ie = load(get_f64s(r)?, n)?;
        self.state.ii = load(get_f64s(r)?, n)?;
        self.state.refrac = load(get_f64s(r)?, n)?;
        self.ring_e.load(r).context("ring_e")?;
        self.ring_i.load(r).context("ring_i")?;
        let np = get_u64(r)? as usize;
        self.pending.clear();
        for _ in 0..np {
            let p = get_u64(r)? as u32;
            let emit = get_u64(r)?;
            self.pending.push((p, emit));
        }
        let has_stdp = get_u64(r)? == 1;
        if has_stdp != self.stdp.is_some() {
            bail!("checkpoint plasticity flag mismatch");
        }
        if let Some(s) = &mut self.stdp {
            for te in &mut self.store.threads {
                let w = get_f64s(r)?;
                if w.len() != te.weight.len() {
                    bail!("plastic weight shape mismatch");
                }
                te.weight = w;
            }
            s.pre_traces.load(r).context("pre_traces")?;
            s.post_traces.load(r).context("post_traces")?;
        }
        Ok(())
    }

    /// Run `windows` min-delay windows on a single-rank engine (no
    /// exchange), window-aligned so the result can be checkpointed and
    /// resumed exactly. Returns emitted spikes as (step, gid).
    pub fn run_windows_solo(&mut self, windows: u64) -> Vec<(Step, u32)> {
        assert_eq!(
            self.spec.min_delay_steps >= 1,
            true,
            "window size must be positive"
        );
        let m = self.spec.min_delay_steps as u64;
        let mut events = Vec::new();
        for _ in 0..windows {
            let mut outbox = Vec::new();
            for _ in 0..m {
                self.step_once(&mut outbox);
            }
            for msg in outbox {
                events.push((msg.step as Step, msg.gid));
            }
        }
        events
    }
}

// persistence hooks for the containers (kept here so the main modules
// stay serialization-free)
impl super::ring::InputRing {
    pub fn save(&self, w: &mut impl Write) -> Result<()> {
        put_u64(w, self.len as u64)?;
        put_f64s(w, self.raw())
    }

    pub fn load(&mut self, r: &mut impl Read) -> Result<()> {
        let len = get_u64(r)? as usize;
        if len != self.len {
            bail!("ring length mismatch: {len} vs {}", self.len);
        }
        let buf = get_f64s(r)?;
        self.raw_mut().copy_from_slice(&buf);
        Ok(())
    }
}

impl crate::model::stdp::TraceSet {
    pub fn save(&self, w: &mut impl Write) -> Result<()> {
        let (value, last) = self.raw();
        put_f64s(w, value)?;
        put_u64(w, last.len() as u64)?;
        for &x in last {
            put_u64(w, x)?;
        }
        Ok(())
    }

    pub fn load(&mut self, r: &mut impl Read) -> Result<()> {
        let value = get_f64s(r)?;
        let n = get_u64(r)? as usize;
        let mut last = Vec::with_capacity(n);
        for _ in 0..n {
            last.push(get_u64(r)?);
        }
        self.raw_restore(value, last)
            .map_err(|e| anyhow::anyhow!(e))
    }
}
