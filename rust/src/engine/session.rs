//! The persistent simulation session — CORTEX's public facade.
//!
//! NEST-class usability (repeated `Simulate()` calls, selective
//! recorders, stimulus steering between calls) on top of the indegree
//! sub-graph engine: a [`Simulation`] builds every rank engine **once**
//! — worker pools and communication threads stay alive — and then
//! answers an arbitrary interleaving of
//!
//! * [`Simulation::run_for`] — advance all ranks `n` steps. Splitting a
//!   run into multiple calls is **bit-identical** to one combined call,
//!   even mid-window: each rank thread keeps its position inside the
//!   min-delay exchange window across calls;
//! * [`Simulation::drain`] — collect a registered [`Probe`]'s
//!   accumulated data (merged across ranks);
//! * [`Simulation::set_poisson`] / [`Simulation::set_dc`] — mutate a
//!   population's external drive; updates are queued and applied at the
//!   next window boundary on every rank, so results remain a pure
//!   function of (spec, command schedule);
//! * [`Simulation::checkpoint`] / [`SimulationBuilder::restore`] —
//!   snapshot / resume the whole session bit-exactly (built on the
//!   per-rank CORTEX3 format, wrapped in a session header);
//! * [`Simulation::finish`] — tear down and merge the classic
//!   [`RunOutput`].
//!
//! # Transports
//!
//! The session is transport-agnostic: the builder takes a [`Transport`]
//! (or an arbitrary [`TransportFactory`] via
//! [`SimulationBuilder::transport_with`]) that wires each rank's
//! [`Communicator`] endpoint. With [`Transport::Local`] (the default)
//! every rank lives in this process on in-memory channels; with
//! [`Transport::Tcp`] this process hosts **one** rank of a
//! multi-process cluster and the session drives just that rank — every
//! process runs the same spec/seed/partition, so their per-rank rasters
//! are bit-identical to the corresponding ranks of a local-transport
//! run. `run_for`, `drain`, stimulus mutation and `finish` work
//! identically (drained probe data covers the ranks this process
//! hosts); session-wide checkpoint/restore requires the local
//! transport.
//!
//! # Threading model
//!
//! This module extends the PR-1 ownership-transfer design one level up:
//! each rank's engine is **moved onto a session-owned OS thread** at
//! build time (previously: scoped threads per `run_simulation` call)
//! and is driven by a command/response channel pair, exactly like the
//! engine drives its compute workers. While a rank thread holds its
//! engine nothing else can reach that state, so the mutex-free
//! no-data-racing property of the indegree decomposition is preserved
//! across the whole facade: session ↔ rank ↔ worker communicate by
//! value over channels only. Probes run on the rank threads and observe
//! engine state between steps through `&`-references.
//!
//! # Window bookkeeping
//!
//! The rank loop is step-driven: at each window start it first picks up
//! the previous window's exchange, applies queued stimulus updates,
//! then computes `min_delay` steps and submits the window's spikes.
//! `run_for` may stop mid-window; the partial window continues on the
//! next call. Checkpoints require a window boundary; the checkpointing
//! rank drains its in-flight exchange first so the snapshot contains
//! every spike (the `window_drained` flag keeps the next window from
//! receiving twice).

use std::io::{Read, Write};
use std::sync::mpsc::{
    channel, sync_channel, Receiver, Sender, SyncSender,
};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, bail, ensure, Result};

use crate::atlas::NetworkSpec;
use crate::comm::{
    bsb, hier::fastpath_links, CommGroups, Communicator,
    HierarchicalComm, LocalCluster, RoutingTable, SoloComm,
    SpikePacket, TcpComm,
};
use crate::config::{
    BuildMode, CommMode, DynamicsBackend, ExecMode, IntegrateMode,
    MappingKind, RoutingMode,
};
use crate::decomp::{
    area_processes_partition, random_equivalent_partition, Partition,
    RankStore,
};
use crate::metrics::memory::MemoryBreakdown;
use crate::metrics::{MemoryReport, PhaseTimer, SpikeRecorder};
use crate::model::dynamics::{ModelParams, NeuronModel};
use crate::model::poisson::PoissonDrive;
use crate::probe::{Probe, ProbeData, StepView};
use crate::{Gid, Step};

use super::checkpoint::{get_u64, put_u64};
use super::comm_driver::CommDriver;
use super::ensemble::SharedNetwork;
use super::{
    EngineOptions, RankEngine, RankOutput, RunConfig, RunOutput,
};

/// Session checkpoint magic: "CORTEXSS" (a header over per-rank CORTEX3
/// blobs).
const SESSION_MAGIC: u64 = 0x434f_5254_4558_5353;

/// Per-rank probe factory: invoked once on every rank thread at build.
pub type ProbeFactory =
    Arc<dyn Fn(u16) -> Box<dyn Probe> + Send + Sync>;

/// Wires the communicator endpoints for the ranks **this process**
/// hosts. Called once at `build()` with the total rank count; returns
/// `(global rank, endpoint)` pairs — one per locally hosted rank. Every
/// endpoint must span all `ranks` (`Communicator::size`) and report the
/// matching `Communicator::rank`.
pub type TransportFactory = Box<
    dyn FnOnce(usize) -> Result<Vec<(usize, Box<dyn Communicator>)>>
        + Send,
>;

/// How the session's ranks are wired together (see
/// [`SimulationBuilder::transport`]).
pub enum Transport {
    /// All ranks in this process, connected by in-memory channels
    /// (the default).
    Local,
    /// This process hosts exactly one rank of a TCP cluster:
    /// `peers[r]` is rank r's listen address, `rank` indexes it, and
    /// the builder's rank count must equal `peers.len()`. Joining
    /// blocks until the full mesh is connected (bounded by
    /// [`Transport::TCP_JOIN_TIMEOUT`]).
    Tcp { rank: u16, peers: Vec<String> },
    /// Bring-your-own endpoints (tests, future transports).
    Custom(TransportFactory),
}

impl Transport {
    /// How long a TCP rank waits for its peers at `build()`.
    pub const TCP_JOIN_TIMEOUT: std::time::Duration =
        std::time::Duration::from_secs(60);

    fn endpoints(
        self,
        ranks: usize,
    ) -> Result<Vec<(usize, Box<dyn Communicator>)>> {
        match self {
            Transport::Local => Ok(LocalCluster::new(ranks)
                .into_iter()
                .enumerate()
                .map(|(r, c)| (r, Box::new(c) as Box<dyn Communicator>))
                .collect()),
            Transport::Tcp { rank, peers } => {
                ensure!(
                    peers.len() == ranks,
                    "TCP transport lists {} peers but the session is \
                     configured for {ranks} ranks",
                    peers.len()
                );
                ensure!(
                    (rank as usize) < ranks,
                    "TCP rank {rank} does not index the {ranks}-rank \
                     peer list"
                );
                let comm = TcpComm::join(
                    rank,
                    &peers,
                    Self::TCP_JOIN_TIMEOUT,
                )?;
                Ok(vec![(
                    rank as usize,
                    Box::new(comm) as Box<dyn Communicator>,
                )])
            }
            Transport::Custom(f) => f(ranks),
        }
    }
}

struct ProbeReg {
    name: String,
    make: ProbeFactory,
}

/// Configures and constructs a [`Simulation`]. Obtained from
/// [`Simulation::builder`]; every knob mirrors [`RunConfig`] (and
/// [`Self::run_config`] adopts one wholesale).
pub struct SimulationBuilder {
    spec: Arc<NetworkSpec>,
    ranks: usize,
    threads: usize,
    mapping: MappingKind,
    comm: CommMode,
    backend: DynamicsBackend,
    exec: ExecMode,
    build: BuildMode,
    integrate: IntegrateMode,
    routing: RoutingMode,
    comm_group: Vec<usize>,
    record_limit: Option<Gid>,
    verify_ownership: bool,
    artifacts_dir: String,
    seed: u64,
    drive_seed: Option<u64>,
    probes: Vec<ProbeReg>,
    transport: Transport,
    /// Ensemble path: skip partitioning and store construction, build
    /// engines over these pre-built shared stores instead.
    shared: Option<SharedNetwork>,
}

impl SimulationBuilder {
    fn new(spec: Arc<NetworkSpec>) -> SimulationBuilder {
        let seed = spec.seed;
        SimulationBuilder {
            spec,
            ranks: 1,
            threads: 1,
            mapping: MappingKind::AreaProcesses,
            comm: CommMode::Overlap,
            backend: DynamicsBackend::Native,
            exec: ExecMode::Pool,
            build: BuildMode::TwoPass,
            integrate: IntegrateMode::Vector,
            routing: RoutingMode::Routed,
            comm_group: Vec::new(),
            record_limit: None,
            verify_ownership: false,
            artifacts_dir: "artifacts".into(),
            seed,
            drive_seed: None,
            probes: Vec::new(),
            transport: Transport::Local,
            shared: None,
        }
    }

    pub fn ranks(mut self, n: usize) -> Self {
        self.ranks = n;
        self
    }

    pub fn threads(mut self, n: usize) -> Self {
        self.threads = n;
        self
    }

    pub fn mapping(mut self, m: MappingKind) -> Self {
        self.mapping = m;
        self
    }

    pub fn comm(mut self, c: CommMode) -> Self {
        self.comm = c;
        self
    }

    pub fn backend(mut self, b: DynamicsBackend) -> Self {
        self.backend = b;
        self
    }

    pub fn exec(mut self, e: ExecMode) -> Self {
        self.exec = e;
        self
    }

    /// Select the store-construction pipeline (two-pass streaming by
    /// default; [`BuildMode::Serial`] keeps the staging ablation).
    pub fn build_mode(mut self, b: BuildMode) -> Self {
        self.build = b;
        self
    }

    /// Select the integrate-kernel formulation (branch-free vector by
    /// default; [`IntegrateMode::Scalar`] keeps the per-neuron
    /// branching kernels as an ablation).
    pub fn integrate(mut self, m: IntegrateMode) -> Self {
        self.integrate = m;
        self
    }

    /// Select the spike-exchange routing (interest-routed by default;
    /// [`RoutingMode::Broadcast`] keeps the full allgather as an
    /// ablation — bit-identical rasters either way).
    pub fn routing(mut self, r: RoutingMode) -> Self {
        self.routing = r;
        self
    }

    /// Per-rank host-group ids for [`RoutingMode::Hierarchical`]
    /// (`group_of[rank] = group`; ids contiguous from zero). Empty (the
    /// default) auto-groups pairs of consecutive ranks. Ignored by the
    /// flat routing modes.
    pub fn comm_group(mut self, group_of: Vec<usize>) -> Self {
        self.comm_group = group_of;
        self
    }

    /// Built-in raster bound: record gids below the limit; `None` (the
    /// default) disables the built-in recorder — attach a
    /// [`crate::probe::SpikeRaster`] for filtered recording instead.
    pub fn record_limit(mut self, limit: Option<Gid>) -> Self {
        self.record_limit = limit;
        self
    }

    /// Compile the paper's thread-ownership abort check into delivery.
    pub fn verify_ownership(mut self, on: bool) -> Self {
        self.verify_ownership = on;
        self
    }

    pub fn artifacts_dir(mut self, dir: &str) -> Self {
        self.artifacts_dir = dir.into();
        self
    }

    /// Partition seed (defaults to the spec's network seed).
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Poisson drive seed (defaults to the spec's network seed).
    /// Changes the stimulus realization only — never the built network
    /// — which is what lets ensemble trajectories over one shared
    /// store see independent noise streams.
    pub fn drive_seed(mut self, seed: u64) -> Self {
        self.drive_seed = Some(seed);
        self
    }

    /// Ensemble path ([`super::Ensemble::trajectory`]): adopt an
    /// already-built [`SharedNetwork`] — its partition replaces this
    /// builder's mapping/seed, and every rank engine is constructed
    /// over the shared store (per-trajectory state only).
    pub(crate) fn shared(mut self, net: SharedNetwork) -> Self {
        self.shared = Some(net);
        self
    }

    /// Select how ranks are wired ([`Transport::Local`] by default).
    /// With [`Transport::Tcp`] this process hosts a single rank of a
    /// multi-process cluster; `build()` blocks until the mesh connects.
    pub fn transport(mut self, t: Transport) -> Self {
        self.transport = t;
        self
    }

    /// Install an arbitrary [`TransportFactory`] — full control over
    /// the endpoints this process hosts (pre-bound listeners in tests,
    /// alternative transports).
    pub fn transport_with(
        mut self,
        f: impl FnOnce(
                usize,
            )
                -> Result<Vec<(usize, Box<dyn Communicator>)>>
            + Send
            + 'static,
    ) -> Self {
        self.transport = Transport::Custom(Box::new(f));
        self
    }

    /// Adopt every knob of a one-shot [`RunConfig`] (except `steps`,
    /// which a session supplies per `run_for` call).
    pub fn run_config(mut self, cfg: &RunConfig) -> Self {
        self.ranks = cfg.ranks;
        self.threads = cfg.threads;
        self.mapping = cfg.mapping;
        self.comm = cfg.comm;
        self.backend = cfg.backend;
        self.exec = cfg.exec;
        self.build = cfg.build;
        self.integrate = cfg.integrate;
        self.routing = cfg.routing;
        self.comm_group = cfg.comm_group.clone();
        self.record_limit = cfg.record_limit;
        self.verify_ownership = cfg.verify_ownership;
        self.artifacts_dir = cfg.artifacts_dir.clone();
        self.seed = cfg.seed;
        self
    }

    /// Register a probe: the configured instance is cloned onto every
    /// rank thread at build time and later drained (merged) by name.
    pub fn probe<P>(mut self, probe: P) -> Self
    where
        P: Probe + Clone + Sync + 'static,
    {
        let name = probe.name().to_string();
        self.probes.push(ProbeReg {
            name,
            make: Arc::new(move |_rank| {
                Box::new(probe.clone()) as Box<dyn Probe>
            }),
        });
        self
    }

    /// Register a probe via an explicit per-rank factory (for probes
    /// that are not `Clone` or want rank-dependent configuration).
    pub fn probe_with(
        mut self,
        name: &str,
        make: impl Fn(u16) -> Box<dyn Probe> + Send + Sync + 'static,
    ) -> Self {
        self.probes.push(ProbeReg {
            name: name.into(),
            make: Arc::new(make),
        });
        self
    }

    /// Partition the network, spawn one session-owned thread per rank
    /// and construct all rank engines (worker pools included) on them.
    pub fn build(self) -> Result<Simulation> {
        ensure!(
            self.ranks >= 1 && self.ranks <= u16::MAX as usize,
            "ranks must be in 1..=65535"
        );
        ensure!(self.threads >= 1, "threads must be >= 1");
        for (i, p) in self.probes.iter().enumerate() {
            ensure!(
                !self.probes[..i].iter().any(|q| q.name == p.name),
                "duplicate probe name '{}'",
                p.name
            );
        }
        let spec = self.spec;
        if let Some(net) = &self.shared {
            ensure!(
                Arc::ptr_eq(&net.spec, &spec),
                "shared network was built over a different spec"
            );
            ensure!(
                net.stores.len() == self.ranks,
                "shared network was built for {} ranks, session is \
                 configured for {}",
                net.stores.len(),
                self.ranks
            );
            ensure!(
                net.threads == self.threads,
                "shared network was decomposed for {} threads, session \
                 is configured for {}",
                net.threads,
                self.threads
            );
        }
        let partition = match &self.shared {
            Some(net) => Arc::clone(&net.partition),
            None => Arc::new(match self.mapping {
                MappingKind::AreaProcesses => area_processes_partition(
                    &spec, self.ranks, self.seed,
                ),
                MappingKind::RandomEquivalent => {
                    random_equivalent_partition(
                        spec.n_total(),
                        self.ranks,
                        self.seed,
                    )
                }
            }),
        };
        let min_delay = spec.min_delay_steps as Step;
        assert!(min_delay >= 1, "window size must be positive");
        let factories: Arc<Vec<(String, ProbeFactory)>> = Arc::new(
            self.probes
                .into_iter()
                .map(|p| (p.name, p.make))
                .collect(),
        );
        let probe_names: Vec<String> =
            factories.iter().map(|(n, _)| n.clone()).collect();

        // wire the transport: (global rank, endpoint) for every rank
        // this process hosts — all of them (local) or one (tcp)
        let n_ranks = self.ranks;
        let endpoints = self.transport.endpoints(n_ranks)?;
        ensure!(
            !endpoints.is_empty(),
            "transport produced no local ranks"
        );
        let mut seen = vec![false; n_ranks];
        for (r, comm) in &endpoints {
            ensure!(
                *r < n_ranks,
                "transport produced rank {r}, session is configured \
                 for {n_ranks} ranks"
            );
            ensure!(!seen[*r], "transport produced rank {r} twice");
            seen[*r] = true;
            ensure!(
                comm.size() == n_ranks,
                "endpoint for rank {r} spans {} ranks, session is \
                 configured for {n_ranks}",
                comm.size()
            );
            ensure!(
                comm.rank() as usize == *r,
                "endpoint for rank {r} reports rank {}",
                comm.rank()
            );
        }

        // hierarchical routing: wrap every endpoint in the relay
        // protocol, with in-process fast-path channels between
        // co-located same-group ranks (single-rank processes — one
        // rank per `cortex launch` child — keep everything on the
        // transport's point-to-point frames)
        let endpoints = if self.routing == RoutingMode::Hierarchical
            && n_ranks > 1
        {
            let groups = if self.comm_group.is_empty() {
                CommGroups::even(n_ranks, 2)
            } else {
                CommGroups::new(self.comm_group.clone())
                    .map_err(|e| anyhow!("engine.comm_group: {e}"))?
            };
            ensure!(
                groups.n_ranks() == n_ranks,
                "comm groups assign {} ranks, session is configured \
                 for {n_ranks}",
                groups.n_ranks()
            );
            let present: Vec<usize> =
                endpoints.iter().map(|(r, _)| *r).collect();
            let mut fast = fastpath_links(&groups, &present);
            endpoints
                .into_iter()
                .map(|(r, comm)| {
                    let links = fast.remove(&r).unwrap_or_default();
                    HierarchicalComm::new(comm, groups.clone())
                        .map(|h| {
                            (
                                r,
                                Box::new(h.with_fastpath(links))
                                    as Box<dyn Communicator>,
                            )
                        })
                        .map_err(|e| anyhow!("rank {r}: {e}"))
                })
                .collect::<Result<Vec<_>>>()?
        } else {
            endpoints
        };

        let mut links = Vec::with_capacity(endpoints.len());
        for (r, comm) in endpoints {
            let (cmd_tx, cmd_rx) = channel::<Cmd>();
            let (resp_tx, resp_rx) = channel::<Resp>();
            let spec = Arc::clone(&spec);
            let partition = Arc::clone(&partition);
            let factories = Arc::clone(&factories);
            let prebuilt = self
                .shared
                .as_ref()
                .map(|net| Arc::clone(&net.stores[r]));
            let opts = EngineOptions {
                n_threads: self.threads,
                comm: self.comm,
                backend: self.backend,
                exec: self.exec,
                build: self.build,
                integrate: self.integrate,
                routing: self.routing,
                record_limit: self.record_limit,
                verify_ownership: self.verify_ownership,
                artifacts_dir: self.artifacts_dir.clone(),
                drive_seed: self.drive_seed,
            };
            let comm_mode = self.comm;
            let handle = std::thread::Builder::new()
                .name(format!("cortex-rank-{r}"))
                .spawn(move || {
                    rank_main(
                        spec,
                        partition,
                        prebuilt,
                        r,
                        opts,
                        comm_mode,
                        comm,
                        &factories,
                        cmd_rx,
                        resp_tx,
                    )
                })
                .map_err(|e| anyhow!("failed to spawn rank {r}: {e}"))?;
            links.push(RankLink {
                rank: r,
                cmd: Some(cmd_tx),
                resp: resp_rx,
                handle: Some(handle),
            });
        }

        let stim_params = spec.params.clone();
        let mut sim = Simulation {
            spec,
            partition,
            links,
            n_ranks,
            probe_names,
            record_limit: self.record_limit,
            backend: self.backend,
            min_delay,
            steps_done: 0,
            build_seconds: 0.0,
            stim_params,
        };
        // all ranks report construction (or its failure) before the
        // session is handed out, so build and simulation time separate
        // cleanly (the paper's Fig 18 reports simulation time)
        for r in 0..sim.links.len() {
            match sim.recv(r)? {
                Resp::Built { build_seconds } => {
                    sim.build_seconds = sim.build_seconds.max(build_seconds)
                }
                _ => bail!("rank {r}: unexpected response during build"),
            }
        }
        Ok(sim)
    }

    /// Build the session and load a [`Simulation::checkpoint`] written
    /// by a session over the same network partition (same spec, ranks,
    /// mapping, seed). The **thread count may differ** — checkpoint
    /// bytes are thread-count independent and the restored session
    /// replays bit-exactly regardless. Stimulus overrides are restored;
    /// probes start empty.
    pub fn restore(self, r: &mut impl Read) -> Result<Simulation> {
        let ranks = self.ranks;
        if get_u64(r)? != SESSION_MAGIC {
            bail!("not a CORTEX session checkpoint");
        }
        let n_ranks = get_u64(r)? as usize;
        ensure!(
            n_ranks == ranks,
            "checkpoint has {n_ranks} ranks, session is configured \
             for {ranks}"
        );
        let steps_done = get_u64(r)?;
        let mut blobs = Vec::with_capacity(n_ranks);
        for _ in 0..n_ranks {
            let len = get_u64(r)? as usize;
            let mut blob = vec![0u8; len];
            r.read_exact(&mut blob)?;
            blobs.push(blob);
        }
        let mut sim = self.build()?;
        ensure!(
            sim.links.len() == ranks,
            "restore requires the local transport (this process hosts \
             {} of {ranks} ranks)",
            sim.links.len()
        );
        for (rank, blob) in blobs.into_iter().enumerate() {
            sim.send(rank, Cmd::Restore(blob))?;
        }
        for rank in 0..ranks {
            match sim.recv(rank)? {
                Resp::Ack => {}
                _ => bail!("rank {rank}: unexpected restore response"),
            }
        }
        // re-seed the session's parameter-table mirror with the DC
        // offsets the restored engines interned (every rank holds the
        // same stimulus state; ask one)
        sim.send(0, Cmd::StimState)?;
        match sim.recv(0)? {
            Resp::Stim(state) => {
                for (pop, (_drive, dc)) in state.into_iter().enumerate()
                {
                    if dc == 0.0 {
                        continue;
                    }
                    let base = sim.spec.params
                        [sim.spec.populations[pop].params as usize];
                    if let Some(shifted) = base.with_dc(dc) {
                        if !sim.stim_params.contains(&shifted) {
                            sim.stim_params.push(shifted);
                        }
                    }
                }
            }
            _ => bail!("rank 0: unexpected stimulus-state response"),
        }
        sim.steps_done = steps_done;
        Ok(sim)
    }
}

// ---------------------------------------------------------------------
// The session handle
// ---------------------------------------------------------------------

struct RankLink {
    /// Global rank this link drives (== index for the local transport).
    rank: usize,
    /// `None` once the session hangs up (teardown).
    cmd: Option<Sender<Cmd>>,
    resp: Receiver<Resp>,
    handle: Option<JoinHandle<()>>,
}

/// A live multi-rank simulation: persistent rank engines on
/// session-owned threads, driven through repeated [`Self::run_for`]
/// calls. See the [module docs](self) for the guarantees.
pub struct Simulation {
    spec: Arc<NetworkSpec>,
    partition: Arc<Partition>,
    /// One link per rank **this process** hosts (all ranks on the local
    /// transport, a single rank on TCP).
    links: Vec<RankLink>,
    /// Total cluster rank count (across all processes).
    n_ranks: usize,
    probe_names: Vec<String>,
    record_limit: Option<Gid>,
    backend: DynamicsBackend,
    min_delay: Step,
    steps_done: Step,
    build_seconds: f64,
    /// Session-side mirror of the ranks' interned parameter tables
    /// (they all evolve identically: every DC update interns into every
    /// worker table). Lets `set_dc` reject a would-be table overflow
    /// here, as a recoverable error, instead of on a rank thread.
    stim_params: Vec<ModelParams>,
}

impl Simulation {
    /// Start configuring a session over `spec`.
    pub fn builder(spec: Arc<NetworkSpec>) -> SimulationBuilder {
        SimulationBuilder::new(spec)
    }

    /// Steps completed so far (across all `run_for` calls, plus a
    /// restored checkpoint's position).
    pub fn step(&self) -> Step {
        self.steps_done
    }

    /// The network this session simulates.
    pub fn spec(&self) -> &Arc<NetworkSpec> {
        &self.spec
    }

    /// The rank partition the session runs on.
    pub fn partition(&self) -> &Partition {
        &self.partition
    }

    /// Engine-construction wall time (max over ranks).
    pub fn build_seconds(&self) -> f64 {
        self.build_seconds
    }

    /// Advance every rank `steps` integration steps. Repeated calls are
    /// bit-identical to one combined call.
    pub fn run_for(&mut self, steps: Step) -> Result<()> {
        if steps == 0 {
            return Ok(());
        }
        for r in 0..self.links.len() {
            self.send(r, Cmd::RunFor(steps))?;
        }
        for (r, res) in self.recv_each().into_iter().enumerate() {
            match res? {
                Resp::Ran => {}
                _ => bail!(
                    "rank {}: unexpected run response",
                    self.links[r].rank
                ),
            }
        }
        self.steps_done += steps;
        Ok(())
    }

    /// Drain the named probe: every rank moves its accumulated data out
    /// and the pieces are merged (see [`ProbeData::merge`]).
    pub fn drain(&mut self, probe: &str) -> Result<ProbeData> {
        ensure!(
            self.probe_names.iter().any(|n| n == probe),
            "no probe named '{probe}' is registered on this session"
        );
        for r in 0..self.links.len() {
            self.send(r, Cmd::Drain(probe.to_string()))?;
        }
        let mut merged: Option<ProbeData> = None;
        for (r, res) in self.recv_each().into_iter().enumerate() {
            match res? {
                Resp::Data(d) => {
                    merged = Some(match merged {
                        None => *d,
                        Some(m) => m.merge(*d)?,
                    })
                }
                _ => bail!(
                    "rank {}: unexpected drain response",
                    self.links[r].rank
                ),
            }
        }
        merged.ok_or_else(|| anyhow!("session has no ranks"))
    }

    /// Set the external Poisson drive of every population named `pop`
    /// (applied on all ranks at the next window boundary).
    pub fn set_poisson(
        &mut self,
        pop: &str,
        rate_hz: f64,
        weight_pa: f64,
    ) -> Result<()> {
        let drive = PoissonDrive::new(rate_hz, weight_pa);
        for idx in self.pops_named(pop)? {
            self.stimulus(StimUpdate {
                pop: idx,
                kind: StimKind::Poisson(drive),
            })?;
        }
        Ok(())
    }

    /// Set the DC current offset [pA] of every population named `pop`
    /// (0 restores the spec's parameters; applied at the next window
    /// boundary). Errors for parrot populations and on the PJRT
    /// backend.
    pub fn set_dc(&mut self, pop: &str, dc_pa: f64) -> Result<()> {
        ensure!(
            self.backend == DynamicsBackend::Native || dc_pa == 0.0,
            "DC drive updates are not supported on the PJRT backend"
        );
        let indices = self.pops_named(pop)?;
        for &idx in &indices {
            ensure!(
                self.spec.populations[idx as usize].model
                    != NeuronModel::Parrot,
                "population '{pop}' runs parrot relays and takes no DC \
                 current"
            );
        }
        // mirror the ranks' parameter-table interning so a would-be
        // overflow is a session-level error, not a rank-thread panic
        for &idx in &indices {
            let base = self.spec.params
                [self.spec.populations[idx as usize].params as usize];
            let shifted = base
                .with_dc(dc_pa)
                .expect("parrot populations rejected above");
            if !self.stim_params.contains(&shifted) {
                ensure!(
                    self.stim_params.len() < u8::MAX as usize,
                    "parameter table full (255 distinct parameter \
                     sets); reuse previous DC values or reset offsets \
                     to 0 instead of sweeping unboundedly"
                );
                self.stim_params.push(shifted);
            }
        }
        for idx in indices {
            self.stimulus(StimUpdate {
                pop: idx,
                kind: StimKind::Dc(dc_pa),
            })?;
        }
        Ok(())
    }

    fn pops_named(&self, pop: &str) -> Result<Vec<u16>> {
        let indices = self.spec.pops_named(pop);
        ensure!(
            !indices.is_empty(),
            "network '{}' has no population named '{pop}'",
            self.spec.name
        );
        Ok(indices)
    }

    fn stimulus(&mut self, up: StimUpdate) -> Result<()> {
        for r in 0..self.links.len() {
            self.send(r, Cmd::Stimulus(up))?;
        }
        for (r, res) in self.recv_each().into_iter().enumerate() {
            match res? {
                Resp::Ack => {}
                _ => bail!(
                    "rank {}: unexpected stimulus response",
                    self.links[r].rank
                ),
            }
        }
        Ok(())
    }

    /// Snapshot the whole session (all ranks' dynamical state plus
    /// stimulus overrides; stimulus updates still queued for the next
    /// boundary are applied first, so the snapshot carries them).
    /// Requires a window boundary — call after `run_for` totals that
    /// are a multiple of the spec's `min_delay_steps`. Resume with
    /// [`SimulationBuilder::restore`].
    pub fn checkpoint(&mut self, w: &mut impl Write) -> Result<()> {
        ensure!(
            self.links.len() == self.n_ranks,
            "session checkpoint requires every rank in-process \
             (local transport); this process hosts {} of {} ranks",
            self.links.len(),
            self.n_ranks
        );
        ensure!(
            self.steps_done % self.min_delay == 0,
            "checkpoint requires a window boundary (step {} is not a \
             multiple of min_delay {})",
            self.steps_done,
            self.min_delay
        );
        // Every rank streams its CORTEX3 section through a bounded
        // channel and the session interleaves the copies into `w` in
        // rank order, so peak buffering is O(ranks × chunk) instead
        // of the sum of whole rank blobs. Ranks serialize
        // concurrently; rank r+1 fills its channel while rank r's
        // section is still being copied. On any error the bytes
        // already written to `w` are garbage — discard them.
        let mut rxs = Vec::with_capacity(self.links.len());
        let mut send_err: Option<anyhow::Error> = None;
        for r in 0..self.links.len() {
            let (tx, rx) = sync_channel(CKPT_CHANNEL_CAP);
            match self.send(r, Cmd::Checkpoint(tx)) {
                Ok(()) => rxs.push(rx),
                Err(e) => {
                    // ranks past r never got the command — only the
                    // first `rxs.len()` links owe a response below
                    send_err = Some(e);
                    break;
                }
            }
        }
        let mut stream_err: Option<anyhow::Error> = None;
        if send_err.is_none() {
            if let Err(e) = (|| -> Result<()> {
                put_u64(w, SESSION_MAGIC)?;
                put_u64(w, self.links.len() as u64)?;
                put_u64(w, self.steps_done)
            })() {
                stream_err = Some(e);
            }
        }
        for (r, rx) in rxs.iter().enumerate() {
            if send_err.is_none() && stream_err.is_none() {
                if let Err(e) = copy_rank_section(rx, w) {
                    stream_err = Some(e.context(format!(
                        "streaming rank {} checkpoint section",
                        self.links[r].rank
                    )));
                }
            }
            // always drain to completion: a rank blocked on a full
            // channel must be able to finish and send its response
            while rx.recv().is_ok() {}
        }
        // receive from every rank that was sent a command before
        // acting on any failure (the recv_each discipline)
        let mut rank_err: Option<anyhow::Error> = None;
        let mut bad_resp: Option<u16> = None;
        for r in 0..rxs.len() {
            match self.recv(r) {
                Ok(Resp::Ack) => {}
                Ok(_) => {
                    if bad_resp.is_none() {
                        bad_resp = Some(self.links[r].rank);
                    }
                }
                Err(e) => {
                    // the rank-side error has the root cause; it wins
                    // over the session-side stream symptom
                    if rank_err.is_none() {
                        rank_err = Some(e);
                    }
                }
            }
        }
        if let Some(e) = rank_err {
            return Err(e);
        }
        if let Some(e) = send_err {
            return Err(e);
        }
        if let Some(e) = stream_err {
            return Err(e);
        }
        if let Some(rank) = bad_resp {
            bail!("rank {rank}: unexpected checkpoint response");
        }
        Ok(())
    }

    /// Per-rank heap accounting, merged (the Fig 18 memory quantity).
    pub fn memory(&mut self) -> Result<MemoryReport> {
        for r in 0..self.links.len() {
            self.send(r, Cmd::Memory)?;
        }
        let mut per_rank = Vec::with_capacity(self.links.len());
        for (r, res) in self.recv_each().into_iter().enumerate() {
            match res? {
                Resp::Mem(m) => per_rank.push(*m),
                _ => bail!(
                    "rank {}: unexpected memory response",
                    self.links[r].rank
                ),
            }
        }
        Ok(MemoryReport::new(per_rank))
    }

    /// Separable heap accounting, summed over this process's ranks:
    /// `(shared topology bytes, per-trajectory state bytes)`. The
    /// shared half is the build product ensemble trajectories share —
    /// count it once per network; the trajectory half is what each
    /// additional session over the same network actually costs
    /// (`cortex serve` admission charges exactly this way).
    pub fn memory_split(&mut self) -> Result<(u64, u64)> {
        for r in 0..self.links.len() {
            self.send(r, Cmd::MemorySplit)?;
        }
        let (mut shared, mut state) = (0u64, 0u64);
        for (r, res) in self.recv_each().into_iter().enumerate() {
            match res? {
                Resp::MemSplit(s, t) => {
                    shared += s;
                    state += t;
                }
                _ => bail!(
                    "rank {}: unexpected memory response",
                    self.links[r].rank
                ),
            }
        }
        Ok((shared, state))
    }

    /// Tear the session down and merge the classic one-shot
    /// [`RunOutput`] (raster from the built-in recorder, critical-path
    /// and aggregate timers, memory, exchange statistics).
    pub fn finish(mut self) -> Result<RunOutput> {
        for r in 0..self.links.len() {
            self.send(r, Cmd::Finish)?;
        }
        let mut outputs = Vec::with_capacity(self.links.len());
        for (r, res) in self.recv_each().into_iter().enumerate() {
            match res? {
                Resp::Output(b) => outputs.push(*b),
                _ => bail!(
                    "rank {}: unexpected finish response",
                    self.links[r].rank
                ),
            }
        }
        // rank threads have replied and are exiting; reap them now so
        // teardown errors surface here rather than in Drop
        for link in &mut self.links {
            link.cmd = None;
            if let Some(h) = link.handle.take() {
                h.join()
                    .map_err(|_| anyhow!("rank thread panicked"))?;
            }
        }
        // with every rank thread joined the partition Arc is uniquely
        // held — move it out instead of deep-cloning (rank_of is one
        // entry per neuron); the swapped-in empty satisfies Drop
        let partition = std::mem::replace(
            &mut self.partition,
            Arc::new(Partition {
                n_ranks: 0,
                rank_of: Vec::new(),
                members: Vec::new(),
            }),
        );

        // `None` record limit merges into an explicitly disabled
        // recorder — "record nothing" is a documented choice here, not
        // a `gid_limit: 0` accident
        let mut raster = match self.record_limit {
            Some(limit) => SpikeRecorder::new(limit),
            None => SpikeRecorder::disabled(),
        };
        let mut timer_max = PhaseTimer::new();
        let mut timer_sum = PhaseTimer::new();
        let mut per_rank_mem = Vec::new();
        let mut total_spikes = 0;
        let mut comm_bytes = 0;
        let mut comm_recv_bytes = 0;
        let mut windows = 0;
        let mut comm_frames = 0;
        let mut comm_overlap_ratio = f64::INFINITY;
        let mut wall_seconds: f64 = 0.0;
        let mut build_seconds: f64 = 0.0;
        for (o, sim_s) in &outputs {
            raster.merge(&o.recorder);
            timer_max.merge_max(&o.timer);
            timer_sum.merge(&o.timer);
            per_rank_mem.push(o.memory.clone());
            total_spikes += o.total_spikes;
            comm_bytes += o.comm_bytes;
            comm_recv_bytes += o.comm_recv_bytes;
            windows = windows.max(o.windows);
            comm_frames += o.comm_frames;
            // critical-path view: the rank hiding the least
            comm_overlap_ratio =
                comm_overlap_ratio.min(o.comm_overlap_ratio);
            wall_seconds = wall_seconds.max(*sim_s);
            build_seconds = build_seconds.max(o.build_seconds);
        }
        if !comm_overlap_ratio.is_finite() {
            comm_overlap_ratio = 0.0;
        }
        raster.events.sort_unstable();
        Ok(RunOutput {
            raster,
            timer_max,
            timer_sum,
            memory: MemoryReport::new(per_rank_mem),
            total_spikes,
            wall_seconds,
            build_seconds,
            comm_bytes,
            comm_recv_bytes,
            windows,
            comm_frames,
            comm_overlap_ratio,
            partition: Arc::try_unwrap(partition)
                .unwrap_or_else(|a| (*a).clone()),
        })
    }

    /// Receive one response from **every** link before acting on any of
    /// them. A rank's failure must not leave sibling responses
    /// undrained — the command/response streams would desynchronize and
    /// pair the next command with a stale response.
    fn recv_each(&mut self) -> Vec<Result<Resp>> {
        let mut v = Vec::with_capacity(self.links.len());
        for r in 0..self.links.len() {
            v.push(self.recv(r));
        }
        v
    }

    fn send(&mut self, r: usize, cmd: Cmd) -> Result<()> {
        let rank = self.links[r].rank;
        let Some(tx) = self.links[r].cmd.as_ref() else {
            bail!("rank {rank} is already torn down");
        };
        if tx.send(cmd).is_err() {
            let why = self.reap(r);
            bail!("rank {rank} thread is gone{why}");
        }
        Ok(())
    }

    fn recv(&mut self, r: usize) -> Result<Resp> {
        let rank = self.links[r].rank;
        match self.links[r].resp.recv() {
            Ok(Resp::Err(e)) => bail!("rank {rank}: {e}"),
            Ok(resp) => Ok(resp),
            Err(_) => {
                let why = self.reap(r);
                bail!("rank {rank} thread terminated unexpectedly{why}")
            }
        }
    }

    /// Join a dead rank thread and render its panic payload, if any.
    fn reap(&mut self, r: usize) -> String {
        self.links[r].cmd = None;
        let Some(h) = self.links[r].handle.take() else {
            return String::new();
        };
        match h.join() {
            Ok(()) => String::new(),
            Err(payload) => {
                let msg = payload
                    .downcast_ref::<&str>()
                    .map(|s| s.to_string())
                    .or_else(|| {
                        payload.downcast_ref::<String>().cloned()
                    })
                    .unwrap_or_else(|| "<non-string panic>".into());
                format!(": {msg}")
            }
        }
    }
}

impl Drop for Simulation {
    fn drop(&mut self) {
        // hang up the command channels; rank threads fall out of their
        // loop (they park in recv between commands), then reap them
        for link in &mut self.links {
            link.cmd = None;
        }
        for link in &mut self.links {
            if let Some(h) = link.handle.take() {
                let _ = h.join();
            }
        }
    }
}

// ---------------------------------------------------------------------
// Rank-thread protocol and runtime
// ---------------------------------------------------------------------

enum Cmd {
    RunFor(Step),
    Stimulus(StimUpdate),
    Drain(String),
    /// Stream the rank's checkpoint section through the channel:
    /// [`CkptChunk::Len`] first, then data chunks totalling exactly
    /// that many bytes, then a final [`Resp::Ack`] / [`Resp::Err`].
    Checkpoint(SyncSender<CkptChunk>),
    Restore(Vec<u8>),
    /// Report the engine's current per-population (drive, DC) state.
    StimState,
    Memory,
    /// Report (shared topology bytes, per-trajectory state bytes).
    MemorySplit,
    Finish,
}

/// One message on a rank's checkpoint stream.
enum CkptChunk {
    /// Total section length, announced before any data.
    Len(u64),
    Data(Vec<u8>),
}

/// Streaming-checkpoint chunk size and channel depth: a rank holds at
/// most `CKPT_CHANNEL_CAP` chunks in flight, so the whole session
/// buffers O(ranks × chunk) during a checkpoint.
const CKPT_CHUNK_BYTES: usize = 1 << 20;
const CKPT_CHANNEL_CAP: usize = 4;

/// Copy one rank's streamed checkpoint section into the sink: write
/// the announced length as the section's prefix, then forward data
/// chunks until exactly that many bytes have passed.
fn copy_rank_section(
    rx: &Receiver<CkptChunk>,
    w: &mut impl Write,
) -> Result<()> {
    let len = match rx.recv() {
        Ok(CkptChunk::Len(len)) => len,
        Ok(CkptChunk::Data(_)) => {
            bail!("data chunk before the length announcement")
        }
        Err(_) => bail!("stream closed before the length announcement"),
    };
    put_u64(w, len)?;
    let mut copied = 0u64;
    while copied < len {
        match rx.recv() {
            Ok(CkptChunk::Data(chunk)) => {
                copied += chunk.len() as u64;
                ensure!(
                    copied <= len,
                    "rank streamed {copied} bytes but announced {len}"
                );
                w.write_all(&chunk)?;
            }
            Ok(CkptChunk::Len(_)) => {
                bail!("second length announcement mid-section")
            }
            Err(_) => bail!(
                "stream closed after {copied} of {len} section bytes"
            ),
        }
    }
    Ok(())
}

/// `Write` sink that only counts — the checkpoint sizing pass.
struct ByteCounter(u64);

impl Write for ByteCounter {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        self.0 += buf.len() as u64;
        Ok(buf.len())
    }

    fn flush(&mut self) -> std::io::Result<()> {
        Ok(())
    }
}

/// `Write` sink that ships bounded chunks through the checkpoint
/// channel — the streaming pass. The bounded send applies
/// backpressure: a rank serializes no faster than the session copies.
struct ChunkSink<'a> {
    tx: &'a SyncSender<CkptChunk>,
    buf: Vec<u8>,
}

impl ChunkSink<'_> {
    fn ship(&mut self) -> std::io::Result<()> {
        if self.buf.is_empty() {
            return Ok(());
        }
        let chunk = std::mem::take(&mut self.buf);
        self.tx.send(CkptChunk::Data(chunk)).map_err(|_| {
            std::io::Error::new(
                std::io::ErrorKind::BrokenPipe,
                "session hung up mid-checkpoint",
            )
        })
    }

    fn finish(mut self) -> Result<()> {
        self.ship()?;
        Ok(())
    }
}

impl Write for ChunkSink<'_> {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        self.buf.extend_from_slice(buf);
        if self.buf.len() >= CKPT_CHUNK_BYTES {
            self.ship()?;
        }
        Ok(buf.len())
    }

    fn flush(&mut self) -> std::io::Result<()> {
        self.ship()
    }
}

#[derive(Clone, Copy)]
struct StimUpdate {
    pop: u16,
    kind: StimKind,
}

#[derive(Clone, Copy)]
enum StimKind {
    Poisson(PoissonDrive),
    Dc(f64),
}

enum Resp {
    Built { build_seconds: f64 },
    Ran,
    Ack,
    Data(Box<ProbeData>),
    Stim(Vec<(PoissonDrive, f64)>),
    Mem(Box<MemoryBreakdown>),
    /// (shared topology bytes, per-trajectory state bytes).
    MemSplit(u64, u64),
    /// (rank output, total simulation seconds on this rank)
    Output(Box<(RankOutput, f64)>),
    Err(String),
}

/// Everything one rank thread owns: its engine, its exchange driver,
/// its probes, and its position inside the current exchange window.
struct RankRuntime {
    engine: RankEngine,
    driver: CommDriver,
    /// Min-delay window length in steps.
    m: Step,
    /// Spikes of the window in progress.
    outbox: SpikePacket,
    /// Steps completed inside the current window (0 = at a boundary).
    step_in_window: Step,
    /// The boundary's exchange was already received (checkpoint/restore
    /// path); the next window start must not receive again.
    window_drained: bool,
    /// Stimulus updates queued for the next window boundary.
    pending_stim: Vec<StimUpdate>,
    /// Set when the transport errored. The exchange stream is desynced
    /// from that point on, so every further simulation command must
    /// fail loudly instead of silently running without remote spikes
    /// (the overlap driver's `in_flight` flag was consumed by the
    /// failed receive — a retried window would otherwise get an empty
    /// packet and "succeed").
    poisoned: Option<String>,
    probes: Vec<(String, Box<dyn Probe>)>,
    build_seconds: f64,
    /// Total simulation wall time across `run_for` calls.
    sim_seconds: f64,
    /// Hidden exchange nanoseconds already folded into the
    /// `comm_hidden` timer phase (repeat drains add only deltas).
    hidden_ns_recorded: u64,
}

#[allow(clippy::too_many_arguments)]
fn rank_main(
    spec: Arc<NetworkSpec>,
    partition: Arc<Partition>,
    prebuilt: Option<Arc<RankStore>>,
    r: usize,
    opts: EngineOptions,
    comm_mode: CommMode,
    comm: Box<dyn Communicator>,
    factories: &[(String, ProbeFactory)],
    cmd_rx: Receiver<Cmd>,
    resp_tx: Sender<Resp>,
) {
    let mut rt = match build_runtime(
        spec, partition, prebuilt, r, opts, comm_mode, comm, factories,
    ) {
        Ok(rt) => {
            let built =
                Resp::Built { build_seconds: rt.build_seconds };
            if resp_tx.send(built).is_err() {
                return;
            }
            rt
        }
        Err(e) => {
            let _ = resp_tx.send(Resp::Err(format!("{e}")));
            return;
        }
    };
    while let Ok(cmd) = cmd_rx.recv() {
        match cmd {
            Cmd::Finish => {
                let resp = match rt.finish_output() {
                    Ok(out) => Resp::Output(Box::new(out)),
                    Err(e) => Resp::Err(format!("{e:#}")),
                };
                let _ = resp_tx.send(resp);
                return;
            }
            cmd => {
                if resp_tx.send(rt.handle(cmd)).is_err() {
                    return;
                }
            }
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn build_runtime(
    spec: Arc<NetworkSpec>,
    partition: Arc<Partition>,
    prebuilt: Option<Arc<RankStore>>,
    r: usize,
    opts: EngineOptions,
    comm_mode: CommMode,
    comm: Box<dyn Communicator>,
    factories: &[(String, ProbeFactory)],
) -> Result<RankRuntime> {
    let t_build = Instant::now();
    let routing_mode = opts.routing;
    // store construction runs on the engine's own worker pool (two-pass
    // streaming builder) — the rank thread only orchestrates. On the
    // ensemble path the store is already built and shared: only this
    // trajectory's state gets allocated, which is the whole point.
    let mut engine = match prebuilt {
        Some(store) => {
            RankEngine::with_shared(Arc::clone(&spec), store, opts)?
        }
        None => {
            RankEngine::build(Arc::clone(&spec), &partition, r, opts)?
        }
    };
    // the subscription collective (one alltoall over the run transport,
    // before window 0): ship every peer the set of its gids this rank's
    // sub-graph consumes, receive the sets the peers consume of ours —
    // the routing table the driver then filters every window against
    let mut comm = comm;
    let routing = match routing_mode {
        RoutingMode::Routed | RoutingMode::Hierarchical
            if comm.size() > 1 =>
        {
            Some(engine.timer.time("comm_subscribe", || {
                subscription_collective(
                    &engine.store,
                    &partition,
                    comm.as_mut(),
                )
            })?)
        }
        _ => None,
    };
    let build_seconds = t_build.elapsed().as_secs_f64();
    let mut probes: Vec<(String, Box<dyn Probe>)> = factories
        .iter()
        .map(|(name, make)| (name.clone(), (make.as_ref())(r as u16)))
        .collect();
    // probes validate their configuration against the live network
    // now, so a bad filter fails build() instead of a rank mid-run
    let view = StepView::at_rest(&engine);
    for (name, p) in probes.iter_mut() {
        p.attach(&view)
            .map_err(|e| anyhow!("probe '{name}': {e}"))?;
    }
    drop(view);
    Ok(RankRuntime {
        engine,
        driver: CommDriver::new(comm, comm_mode, routing),
        m: spec.min_delay_steps as Step,
        outbox: Vec::new(),
        step_in_window: 0,
        window_drained: false,
        pending_stim: Vec::new(),
        poisoned: None,
        probes,
        build_seconds,
        sim_seconds: 0.0,
        hidden_ns_recorded: 0,
    })
}

/// Build-time interest exchange: encode this rank's per-source-rank
/// subscription sets ([`RankStore::subscriptions`]) with the gid-list
/// wire codec, alltoall them over the run transport, and decode what
/// every peer wants of this rank into the send-side [`RoutingTable`]
/// the driver filters every window's packet against. One collective,
/// before window 0 — it reuses the spike transport and does not touch
/// the window counter.
fn subscription_collective(
    store: &RankStore,
    partition: &Partition,
    comm: &mut dyn Communicator,
) -> Result<RoutingTable> {
    let blobs: Vec<Vec<u8>> = store
        .subscriptions(partition)
        .iter()
        .map(|b| bsb::encode_gid_list(b))
        .collect();
    let got = comm.alltoall(blobs)?;
    let me = comm.rank() as usize;
    let mut wanted: Vec<Vec<Gid>> = Vec::with_capacity(got.len());
    for (src, blob) in got.iter().enumerate() {
        if src == me {
            wanted.push(Vec::new());
            continue;
        }
        wanted.push(bsb::decode_gid_list(blob).map_err(|e| {
            anyhow!("rank {src} sent a malformed subscription set: {e}")
        })?);
    }
    Ok(RoutingTable::new(&wanted))
}

impl RankRuntime {
    fn handle(&mut self, cmd: Cmd) -> Resp {
        // a poisoned transport refuses everything that would advance or
        // snapshot the simulation (teardown still works)
        if let Some(why) = &self.poisoned {
            if matches!(cmd, Cmd::RunFor(_) | Cmd::Checkpoint(_)) {
                return Resp::Err(format!(
                    "transport poisoned by an earlier exchange \
                     failure: {why}"
                ));
            }
        }
        match cmd {
            Cmd::RunFor(steps) => match self.run_for(steps) {
                Ok(()) => Resp::Ran,
                Err(e) => {
                    let msg = format!("{e:#}");
                    self.poisoned = Some(msg.clone());
                    Resp::Err(msg)
                }
            },
            Cmd::Stimulus(up) => {
                self.pending_stim.push(up);
                Resp::Ack
            }
            Cmd::Drain(name) => {
                self.record_comm_hidden();
                let view = StepView::at_rest(&self.engine);
                match self
                    .probes
                    .iter_mut()
                    .find(|(n, _)| n == &name)
                {
                    Some((_, p)) => Resp::Data(Box::new(p.drain(&view))),
                    None => Resp::Err(format!("no probe named '{name}'")),
                }
            }
            Cmd::Checkpoint(tx) => {
                let res = self.checkpoint_stream(&tx);
                // close the stream before the final response so the
                // session's drain loop terminates
                drop(tx);
                match res {
                    Ok(()) => Resp::Ack,
                    Err(e) => Resp::Err(format!("{e}")),
                }
            }
            Cmd::Restore(blob) => match self.restore_blob(&blob) {
                Ok(()) => Resp::Ack,
                Err(e) => Resp::Err(format!("{e}")),
            },
            Cmd::StimState => Resp::Stim(self.engine.stimulus_state()),
            Cmd::Memory => Resp::Mem(Box::new(self.engine.memory())),
            Cmd::MemorySplit => Resp::MemSplit(
                self.engine.shared_memory().total(),
                self.engine.trajectory_memory().total(),
            ),
            Cmd::Finish => unreachable!("handled by rank_main"),
        }
    }

    /// At a window boundary: receive the previous window's exchange
    /// (unless a checkpoint/restore already did) and apply queued
    /// stimulus updates. Exchange failures (window misalignment,
    /// malformed wire frames, lost peers) propagate as errors.
    fn window_start(&mut self) -> Result<()> {
        // stimulus updates first: they touch drive state only (never
        // the spike stream), so applying them while the previous
        // window's exchange is still in flight is identity-safe — and
        // keeps that work off the blocking receive below
        self.apply_pending_stim();
        if self.window_drained {
            self.window_drained = false;
        } else {
            let RankRuntime { engine, driver, .. } = self;
            let incoming = engine
                .timer
                .time("comm_wait", || driver.recv_completed())?;
            engine.enqueue_remote(&incoming);
        }
        Ok(())
    }

    /// Apply queued stimulus updates to the engine. Only called at
    /// window boundaries (from `window_start` and `checkpoint_stream`),
    /// which is what keeps mutation timing reproducible.
    fn apply_pending_stim(&mut self) {
        for up in std::mem::take(&mut self.pending_stim) {
            // the session validated pop index / model / backend
            let applied = match up.kind {
                StimKind::Poisson(d) => {
                    self.engine.set_pop_poisson(up.pop, d)
                }
                StimKind::Dc(dc) => self.engine.set_pop_dc(up.pop, dc),
            };
            applied.unwrap_or_else(|e| {
                panic!("stimulus update failed to apply: {e}")
            });
        }
    }

    /// Advance `steps` steps, continuing the current window.
    fn run_for(&mut self, steps: Step) -> Result<()> {
        let t_run = Instant::now();
        for _ in 0..steps {
            if self.step_in_window == 0 {
                self.window_start()?;
            }
            let now = self.engine.step();
            let mark = self.outbox.len();
            let t0 = Instant::now();
            self.engine.step_once(&mut self.outbox);
            self.engine.timer.add("compute", t0.elapsed().as_nanos());
            if self.step_in_window + 1 == self.m {
                // the window is complete the moment its last step has
                // computed: ship it before this step's probe
                // processing, so probe work — and everything the
                // caller does until the next window's first step —
                // overlaps the exchange. Probes still observe the
                // step's outbox tail, from a copy taken before the
                // packet moves to the driver.
                let tail: SpikePacket = if self.probes.is_empty() {
                    Vec::new()
                } else {
                    self.outbox[mark..].to_vec()
                };
                let pkt = std::mem::take(&mut self.outbox);
                let RankRuntime { engine, driver, .. } = self;
                engine
                    .timer
                    .time("comm_submit", || driver.submit(pkt))?;
                self.step_in_window = 0;
                if !self.probes.is_empty() {
                    let view =
                        StepView::new(&self.engine, now, &tail);
                    for (_, p) in self.probes.iter_mut() {
                        p.on_step(&view);
                    }
                }
            } else {
                if !self.probes.is_empty() {
                    let view = StepView::new(
                        &self.engine,
                        now,
                        &self.outbox[mark..],
                    );
                    for (_, p) in self.probes.iter_mut() {
                        p.on_step(&view);
                    }
                }
                self.step_in_window += 1;
            }
        }
        self.sim_seconds += t_run.elapsed().as_secs_f64();
        Ok(())
    }

    /// Serialize the engine at a window boundary, streamed through the
    /// session's checkpoint channel, with the boundary's exchange
    /// drained into the pending list first so no spike is in flight
    /// outside the snapshot. Queued stimulus updates are applied
    /// before serializing — they would take effect at this boundary
    /// anyway (the live session sees the identical schedule), and
    /// flushing them keeps the snapshot's stimulus section complete.
    fn checkpoint_stream(
        &mut self,
        tx: &SyncSender<CkptChunk>,
    ) -> Result<()> {
        ensure!(
            self.step_in_window == 0,
            "checkpoint requires a window boundary"
        );
        if !self.window_drained {
            let RankRuntime { engine, driver, poisoned, .. } = self;
            let incoming = match engine
                .timer
                .time("comm_wait", || driver.recv_completed())
            {
                Ok(incoming) => incoming,
                Err(e) => {
                    // unlike a missed-boundary error (benign, the
                    // session can retry later), a failed drain desyncs
                    // the exchange stream for good
                    let msg = format!("{e}");
                    *poisoned = Some(msg.clone());
                    return Err(anyhow!(msg));
                }
            };
            engine.enqueue_remote(&incoming);
            self.window_drained = true;
        }
        self.apply_pending_stim();
        // sizing pass first (serialization is deterministic and does
        // not mutate the engine), so the section length can lead the
        // stream; then the real pass ships bounded chunks instead of
        // materializing the whole blob
        let mut counter = ByteCounter(0);
        self.engine.checkpoint(&mut counter)?;
        tx.send(CkptChunk::Len(counter.0))
            .map_err(|_| anyhow!("session hung up mid-checkpoint"))?;
        let mut sink = ChunkSink { tx, buf: Vec::new() };
        self.engine.checkpoint(&mut sink)?;
        sink.finish()
    }

    /// Load a per-rank blob into the freshly built engine. The snapshot
    /// was taken post-drain, so the next window must not receive.
    fn restore_blob(&mut self, blob: &[u8]) -> Result<()> {
        self.engine.restore(&mut std::io::Cursor::new(blob))?;
        self.step_in_window = 0;
        self.window_drained = true;
        self.outbox.clear();
        self.pending_stim.clear();
        Ok(())
    }

    /// Flush a trailing partial window, tear down the exchange driver
    /// and **move** the recorder/timer out of the engine into the
    /// rank's output.
    /// Fold the driver's hidden exchange time (comm-thread busy time
    /// minus the wait the rank loop actually observed) into the
    /// `comm_hidden` timer phase, so phase probes and reports show
    /// the overlap win next to `comm_wait`. Only the delta since the
    /// last call is added; an exchange still in flight may briefly
    /// overstate the hidden share (busy accrues before its wait is
    /// observed) — fine for a wall-clock phase, which is explicitly
    /// nondeterministic.
    fn record_comm_hidden(&mut self) {
        let s = self.driver.stats();
        let hidden = s.busy_ns.saturating_sub(s.wait_ns);
        let delta = hidden.saturating_sub(self.hidden_ns_recorded);
        if delta > 0 {
            self.engine.timer.add("comm_hidden", delta as u128);
            self.hidden_ns_recorded = hidden;
        }
    }

    fn finish_output(&mut self) -> Result<(RankOutput, f64)> {
        if self.step_in_window != 0 {
            let pkt = std::mem::take(&mut self.outbox);
            let RankRuntime { engine, driver, .. } = self;
            engine
                .timer
                .time("comm_submit", || driver.submit(pkt))?;
            self.step_in_window = 0;
            // drain the flush measured, so the teardown exchange
            // keeps the busy/wait accounting coherent (its spikes are
            // past the last full window and discarded, as before; a
            // teardown exchange failure stays non-fatal)
            let _ = engine
                .timer
                .time("comm_wait", || driver.recv_completed());
        }
        self.record_comm_hidden();
        let stats = self.driver.stats();
        let driver = std::mem::replace(
            &mut self.driver,
            CommDriver::new(
                Box::new(SoloComm::new()),
                CommMode::Serialized,
                None,
            ),
        );
        let comm = driver.finish();
        let memory = self.engine.memory();
        let recorder = std::mem::replace(
            &mut self.engine.recorder,
            SpikeRecorder::disabled(),
        );
        let timer = std::mem::take(&mut self.engine.timer);
        Ok((
            RankOutput {
                rank: self.engine.rank,
                recorder,
                timer,
                memory,
                total_spikes: self.engine.total_spikes,
                comm_bytes: comm.bytes_sent(),
                comm_recv_bytes: comm.bytes_received(),
                windows: comm.exchanges(),
                comm_frames: comm.frames_sent(),
                comm_overlap_ratio: stats.overlap_ratio(),
                build_seconds: self.build_seconds,
            },
            self.sim_seconds,
        ))
    }
}
