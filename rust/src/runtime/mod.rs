//! XLA/PJRT runtime: load the HLO-text artifacts produced by
//! `python/compile/aot.py` and execute them from the Rust hot path.
//!
//! Python never runs at simulation time — `make artifacts` lowers the L2
//! JAX graph (with its L1 Pallas kernels, `interpret=True`) to HLO *text*
//! once; here we parse it with `HloModuleProto::from_text_file`, compile
//! on the PJRT CPU client, and execute per step. Text is the interchange
//! format because jax≥0.5 serialized protos carry 64-bit instruction ids
//! that xla_extension 0.5.1 rejects (see /opt/xla-example/README.md).

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::atlas::NetworkSpec;
use crate::model::dynamics::ModelParams;
use crate::model::lif::{LifState, Propagators};
use crate::util::json::Json;

/// A compiled HLO artifact on the PJRT CPU client.
pub struct HloExecutable {
    pub name: String,
    client: xla::PjRtClient,
    exe: xla::PjRtLoadedExecutable,
}

impl HloExecutable {
    /// Load + compile `<dir>/<name>.hlo.txt`.
    pub fn load(dir: &Path, name: &str) -> Result<HloExecutable> {
        let path = dir.join(format!("{name}.hlo.txt"));
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("pjrt client: {e}"))?;
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .map_err(|e| anyhow!("parse {path:?}: {e}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {name}: {e}"))?;
        Ok(HloExecutable { name: name.to_string(), client, exe })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Execute with f64 vector inputs of the given shapes; returns the
    /// flattened f64 outputs of the result tuple.
    pub fn run_f64(
        &self,
        inputs: &[(&[f64], &[usize])],
    ) -> Result<Vec<Vec<f64>>> {
        let mut literals = Vec::with_capacity(inputs.len());
        for (data, shape) in inputs {
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(data)
                .reshape(&dims)
                .map_err(|e| anyhow!("reshape: {e}"))?;
            literals.push(lit);
        }
        let mut result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("execute {}: {e}", self.name))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch: {e}"))?;
        // aot.py lowers with return_tuple=True
        let tuple = result
            .decompose_tuple()
            .map_err(|e| anyhow!("tuple: {e}"))?;
        tuple
            .into_iter()
            .map(|l| l.to_vec::<f64>().map_err(|e| anyhow!("to_vec: {e}")))
            .collect()
    }
}

/// The AOT manifest: baked LIF config/propagators + available shapes.
pub struct Manifest {
    pub json: Json,
    pub lif_sizes: Vec<usize>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| {
                format!(
                    "missing {}/manifest.json — run `make artifacts`",
                    dir.display()
                )
            })?;
        let json = Json::parse(&text)?;
        let mut lif_sizes: Vec<usize> = json
            .get("artifacts")
            .and_then(|a| match a {
                Json::Obj(m) => Some(
                    m.values()
                        .filter(|v| {
                            v.get("kind").and_then(Json::as_str)
                                == Some("lif_step")
                        })
                        .filter_map(|v| v.get("n").and_then(Json::as_usize))
                        .collect::<Vec<_>>(),
                ),
                _ => None,
            })
            .unwrap_or_default();
        lif_sizes.sort_unstable();
        if lif_sizes.is_empty() {
            bail!("manifest lists no lif_step artifacts");
        }
        Ok(Manifest { json, lif_sizes })
    }

    /// The baked propagators (for the compatibility check).
    pub fn propagators(&self) -> Result<(f64, f64, f64, f64, f64, f64, u32)> {
        let p = self
            .json
            .get("propagators")
            .context("manifest missing propagators")?;
        let g = |k: &str| -> Result<f64> {
            p.get(k).and_then(Json::as_f64).context("bad propagator")
        };
        Ok((
            g("p22")?,
            g("p11e")?,
            g("p11i")?,
            g("p21e")?,
            g("p21i")?,
            g("p20")?,
            g("ref_steps")? as u32,
        ))
    }
}

/// The LIF dynamics backend running the AOT `lif_step` artifact, chunked
/// over the rank's neurons.
pub struct PjrtLif {
    exe: HloExecutable,
    /// artifact block size (neurons per execute call)
    n_block: usize,
    /// baked reset value for padding lanes
    v_reset: f64,
    ref_steps: f64,
}

impl PjrtLif {
    /// Load the best-fitting artifact and verify the network's parameters
    /// match what was baked at AOT time.
    pub fn load(dir: &str, spec: &NetworkSpec) -> Result<PjrtLif> {
        let dir = PathBuf::from(dir);
        let manifest = Manifest::load(&dir)?;

        // compatibility: the artifact bakes exactly one LIF parameter set
        if spec.params.len() != 1 {
            bail!(
                "PJRT backend supports a single neuron parameter set \
                 (network has {})",
                spec.params.len()
            );
        }
        let ModelParams::Lif(lif) = &spec.params[0] else {
            bail!(
                "PJRT backend supports LIF dynamics only (network model \
                 is {:?}); use engine.backend = \"native\"",
                spec.params[0].model()
            );
        };
        let ours = Propagators::new(lif, spec.dt_ms);
        let (p22, p11e, p11i, p21e, p21i, p20, ref_steps) =
            manifest.propagators()?;
        let close = |a: f64, b: f64| (a - b).abs() <= 1e-12 * b.abs().max(1.0);
        if !(close(p22, ours.p22)
            && close(p11e, ours.p11e)
            && close(p11i, ours.p11i)
            && close(p21e, ours.p21e)
            && close(p21i, ours.p21i)
            && close(p20, ours.p20)
            && ref_steps == ours.ref_steps)
        {
            bail!(
                "network parameters do not match the AOT artifact \
                 (re-run `make artifacts` with matching LifConfig)"
            );
        }

        // smallest artifact that minimises padding for typical rank sizes:
        // use the largest block (fewer dispatches; chunking covers any n)
        let n_block = *manifest.lif_sizes.last().unwrap();
        let exe = HloExecutable::load(&dir, &format!("lif_step_n{n_block}"))?;
        Ok(PjrtLif {
            exe,
            n_block,
            v_reset: lif.v_reset,
            ref_steps: ours.ref_steps as f64,
        })
    }

    pub fn block_size(&self) -> usize {
        self.n_block
    }

    /// Advance `state` by one step given this step's synaptic input;
    /// returns the local indices of spiking neurons.
    pub fn step(
        &mut self,
        state: &mut LifState,
        in_e: &[f64],
        in_i: &[f64],
    ) -> Result<Vec<u32>> {
        let n = state.len();
        assert_eq!(in_e.len(), n);
        assert_eq!(in_i.len(), n);
        let mut spikes = Vec::new();
        let nb = self.n_block;
        let mut lo = 0usize;
        // padded per-call buffers (parked in refractory reset state so
        // padding lanes can never spike — same trick as the kernel wrapper)
        let mut u = vec![self.v_reset; nb];
        let mut ie = vec![0.0; nb];
        let mut ii = vec![0.0; nb];
        let mut r = vec![self.ref_steps; nb];
        let mut pe = vec![0.0; nb];
        let mut pi = vec![0.0; nb];
        while lo < n {
            let hi = (lo + nb).min(n);
            let w = hi - lo;
            u[..w].copy_from_slice(&state.u[lo..hi]);
            ie[..w].copy_from_slice(&state.ie[lo..hi]);
            ii[..w].copy_from_slice(&state.ii[lo..hi]);
            r[..w].copy_from_slice(&state.refrac[lo..hi]);
            pe[..w].copy_from_slice(&in_e[lo..hi]);
            pi[..w].copy_from_slice(&in_i[lo..hi]);
            for x in &mut u[w..] {
                *x = self.v_reset;
            }
            for x in &mut ie[w..] {
                *x = 0.0;
            }
            for x in &mut ii[w..] {
                *x = 0.0;
            }
            for x in &mut r[w..] {
                *x = self.ref_steps;
            }
            for x in &mut pe[w..] {
                *x = 0.0;
            }
            for x in &mut pi[w..] {
                *x = 0.0;
            }

            let shape = [nb];
            let outs = self.exe.run_f64(&[
                (&u, &shape),
                (&ie, &shape),
                (&ii, &shape),
                (&r, &shape),
                (&pe, &shape),
                (&pi, &shape),
            ])?;
            debug_assert_eq!(outs.len(), 5);
            state.u[lo..hi].copy_from_slice(&outs[0][..w]);
            state.ie[lo..hi].copy_from_slice(&outs[1][..w]);
            state.ii[lo..hi].copy_from_slice(&outs[2][..w]);
            state.refrac[lo..hi].copy_from_slice(&outs[3][..w]);
            for (i, &s) in outs[4][..w].iter().enumerate() {
                if s != 0.0 {
                    spikes.push((lo + i) as u32);
                }
            }
            lo = hi;
        }
        Ok(spikes)
    }
}
