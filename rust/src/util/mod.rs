//! Support substrates built in-repo (the offline registry only carries the
//! `xla` crate closure): deterministic PRNG, statistics, a minimal JSON
//! reader/writer, and a property-based-testing harness.

pub mod bench;
pub mod bitset;
pub mod json;
pub mod proptest_lite;
pub mod rng;
pub mod stats;

pub use rng::Rng;
