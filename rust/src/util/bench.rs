//! Timing helpers for the custom bench harnesses (criterion is not in the
//! offline registry; every `benches/*.rs` is a `harness = false` binary
//! built on these).

use std::time::Instant;

/// Median wall-clock seconds of `reps` runs of `f` (after one warm-up).
pub fn time_median<F: FnMut()>(reps: usize, mut f: F) -> f64 {
    assert!(reps >= 1);
    f(); // warm-up
    let mut times: Vec<f64> = (0..reps)
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed().as_secs_f64()
        })
        .collect();
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    times[times.len() / 2]
}

/// One timed run.
pub fn time_once<T, F: FnOnce() -> T>(f: F) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// Prevent the optimiser from discarding a value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_median_positive_and_ordered() {
        let t = time_median(3, || {
            black_box((0..1000).sum::<u64>());
        });
        assert!(t >= 0.0);
    }

    #[test]
    fn time_once_returns_value() {
        let (v, t) = time_once(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(t >= 0.0);
    }
}
