//! Minimal JSON reader/writer (no serde offline).
//!
//! Reads the AOT `manifest.json` and the python-generated LIF fixture
//! trajectories; writes bench results. Supports the full JSON value model
//! with f64 numbers (all our payloads are f64/strings/arrays/objects).

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Path access: `j.at(&["config", "tau_m"])`.
    pub fn at(&self, path: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in path {
            cur = cur.get(k)?;
        }
        Some(cur)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Array of numbers -> Vec<f64>.
    pub fn as_f64_vec(&self) -> Option<Vec<f64>> {
        self.as_arr()?.iter().map(Json::as_f64).collect()
    }

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0);
        s
    }

    fn write(&self, out: &mut String, indent: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => {
                out.push_str(if *b { "true" } else { "false" })
            }
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push_str(", ");
                    }
                    v.write(out, indent);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push_str("{\n");
                let pad = "  ".repeat(indent + 1);
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    out.push_str(&pad);
                    write_escaped(out, k);
                    out.push_str(": ");
                    v.write(out, indent + 1);
                }
                out.push('\n');
                out.push_str(&"  ".repeat(indent));
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn skip_ws(&mut self) {
        while self.pos < self.b.len()
            && matches!(self.b[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            b'n' => self.lit("null", Json::Null),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'"' => Ok(Json::Str(self.string()?)),
            b'[' => self.array(),
            b'{' => self.object(),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(self.err(&format!("unexpected byte '{}'", c as char))),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.pos += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.pos += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.pos + 4 > self.b.len() {
                                return Err(self.err("bad \\u"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.pos..self.pos + 4])
                                    .map_err(|_| self.err("bad \\u"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u"))?;
                            self.pos += 4;
                            // no surrogate-pair support needed for our payloads
                            s.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                }
                c => {
                    // collect the full utf-8 sequence starting at c
                    let start = self.pos - 1;
                    let len = utf8_len(c);
                    self.pos = start + len;
                    if self.pos > self.b.len() {
                        return Err(self.err("bad utf8"));
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.pos])
                            .map_err(|_| self.err("bad utf8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err(&format!("bad number '{text}'")))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.skip_ws();
            a.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-2.5e3").unwrap(), Json::Num(-2500.0));
        assert_eq!(
            Json::parse(r#""a\nbA""#).unwrap(),
            Json::Str("a\nbA".into())
        );
    }

    #[test]
    fn nested_structures() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": {}}"#).unwrap();
        assert_eq!(j.at(&["a"]).unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.at(&["a"]).unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str(),
            Some("x")
        );
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"cfg": {"tau": 10.5, "n": 3}, "xs": [1.5, -2, 0]}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn errors() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn f64_vec_helper() {
        let j = Json::parse("[1, 2.5, 3]").unwrap();
        assert_eq!(j.as_f64_vec().unwrap(), vec![1.0, 2.5, 3.0]);
        assert!(Json::parse(r#"[1, "x"]"#).unwrap().as_f64_vec().is_none());
    }

    #[test]
    fn unicode_passthrough() {
        let j = Json::parse(r#""héllo — ∑""#).unwrap();
        assert_eq!(j.as_str(), Some("héllo — ∑"));
    }
}
