//! A small property-based testing harness (proptest is not in the offline
//! registry). Properties run against many seeded random inputs; on failure
//! the seed is reported so the case can be replayed deterministically.
//!
//! ```no_run
//! # // no_run: doctest binaries miss the xla rpath for libstdc++
//! use cortex::util::proptest_lite::{property, Gen};
//! property("reverse twice is identity", 100, |g: &mut Gen| {
//!     let xs = g.vec_u32(0..50, 1000);
//!     let mut ys = xs.clone();
//!     ys.reverse();
//!     ys.reverse();
//!     if ys == xs { Ok(()) } else { Err("mismatch".into()) }
//! });
//! ```

use super::rng::Rng;
use std::ops::Range;

/// Random input generator handed to each property case.
pub struct Gen {
    pub rng: Rng,
    pub case: usize,
}

impl Gen {
    pub fn usize(&mut self, r: Range<usize>) -> usize {
        if r.is_empty() {
            return r.start;
        }
        self.rng.range_u64(r.start as u64, r.end as u64) as usize
    }

    pub fn u32(&mut self, r: Range<u32>) -> u32 {
        if r.is_empty() {
            return r.start;
        }
        self.rng.range_u64(r.start as u64, r.end as u64) as u32
    }

    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.range_f64(lo, hi)
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.rng.bool(p)
    }

    /// Vector of length in `len`, values below `max`.
    pub fn vec_u32(&mut self, len: Range<usize>, max: u32) -> Vec<u32> {
        let n = self.usize(len);
        (0..n).map(|_| self.u32(0..max)).collect()
    }

    /// A random subset of 0..n as a sorted, deduped vec.
    pub fn subset(&mut self, n: u32, p: f64) -> Vec<u32> {
        (0..n).filter(|_| self.rng.bool(p)).collect()
    }
}

/// Run `cases` random cases of the property; panic with the failing seed on
/// the first failure. Set `CORTEX_PROPTEST_SEED` to replay one case.
pub fn property<F>(name: &str, cases: usize, mut f: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    if let Ok(seed) = std::env::var("CORTEX_PROPTEST_SEED") {
        let seed: u64 = seed.parse().expect("CORTEX_PROPTEST_SEED must be u64");
        let mut g = Gen { rng: Rng::new(seed), case: 0 };
        if let Err(msg) = f(&mut g) {
            panic!("property '{name}' failed (replay seed {seed}): {msg}");
        }
        return;
    }
    let base = crate::util::rng::hash_stream(&[name.len() as u64, cases as u64]);
    for case in 0..cases {
        let seed = base.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut g = Gen { rng: Rng::new(seed), case };
        if let Err(msg) = f(&mut g) {
            panic!(
                "property '{name}' failed on case {case}/{cases}: {msg}\n\
                 replay with CORTEX_PROPTEST_SEED={seed}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut ran = 0;
        property("trivial", 25, |_g| {
            ran += 1;
            Ok(())
        });
        assert_eq!(ran, 25);
    }

    #[test]
    #[should_panic(expected = "property 'fails' failed")]
    fn failing_property_panics_with_seed() {
        property("fails", 10, |g| {
            if g.case == 7 {
                Err("boom".into())
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn subset_sorted_unique() {
        property("subset invariants", 50, |g| {
            let s = g.subset(200, 0.3);
            let mut d = s.clone();
            d.dedup();
            if d.len() != s.len() {
                return Err("dups".into());
            }
            if s.windows(2).any(|w| w[0] >= w[1]) {
                return Err("not sorted".into());
            }
            Ok(())
        });
    }
}
