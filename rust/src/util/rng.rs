//! Deterministic PRNG: xoshiro256++ seeded via splitmix64.
//!
//! Every stochastic structure in the simulator (connectome instantiation,
//! Poisson drive, initial membrane potentials) derives its stream from a
//! `(seed, purpose, index)` triple so that any rank/thread can regenerate
//! its share of the network without global state — the property that lets
//! the indegree decomposition build each rank's sub-graph independently.

/// splitmix64 — used to expand seeds into xoshiro state and to hash
/// `(seed, tag)` tuples into independent stream seeds.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Hash an arbitrary list of u64s into one u64 (stream derivation).
pub fn hash_stream(parts: &[u64]) -> u64 {
    let mut s = 0x243F_6A88_85A3_08D3u64; // pi fraction
    for &p in parts {
        s ^= p;
        s = splitmix64(&mut s);
    }
    s
}

/// xoshiro256++ PRNG (Blackman & Vigna). Passes BigCrush; 2^256-1 period.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second normal deviate from the polar method
    spare_normal: Option<f64>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_normal: None }
    }

    /// Independent stream for a `(seed, purpose-tag, indices…)` triple.
    pub fn stream(seed: u64, parts: &[u64]) -> Self {
        let mut all = vec![seed];
        all.extend_from_slice(parts);
        Rng::new(hash_stream(&all))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = (s[0].wrapping_add(s[3]))
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 high bits -> [0,1) with full double precision
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n). Lemire's method (unbiased).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform integer in [lo, hi).
    #[inline]
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.below(hi - lo)
    }

    #[inline]
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via the Marsaglia polar method (exact, no tables).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        loop {
            let u = 2.0 * self.f64() - 1.0;
            let v = 2.0 * self.f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                let k = (-2.0 * s.ln() / s).sqrt();
                self.spare_normal = Some(v * k);
                return u * k;
            }
        }
    }

    /// Normal with mean/std.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Log-normal parameterised by the mean/std of the *underlying* normal.
    #[inline]
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Poisson deviate. Knuth for small lambda, normal approximation above.
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        if lambda <= 0.0 {
            return 0;
        }
        if lambda < 30.0 {
            let l = (-lambda).exp();
            let mut k = 0u64;
            let mut p = 1.0;
            loop {
                p *= self.f64();
                if p <= l {
                    return k;
                }
                k += 1;
            }
        } else {
            // normal approximation with continuity correction
            let x = self.normal_ms(lambda, lambda.sqrt()) + 0.5;
            if x < 0.0 {
                0
            } else {
                x as u64
            }
        }
    }

    /// Exponential deviate with the given rate.
    #[inline]
    pub fn exponential(&mut self, rate: f64) -> f64 {
        -(1.0 - self.f64()).ln() / rate
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (Floyd's algorithm).
    pub fn sample_distinct(&mut self, n: u64, k: u64) -> Vec<u64> {
        debug_assert!(k <= n);
        let mut out = Vec::with_capacity(k as usize);
        for j in (n - k)..n {
            let t = self.below(j + 1);
            if out.contains(&t) {
                out.push(j);
            } else {
                out.push(t);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn stream_derivation_independent() {
        let mut a = Rng::stream(7, &[1, 2]);
        let mut b = Rng::stream(7, &[1, 3]);
        let xs: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(xs, ys);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_unbiased_small_range() {
        let mut r = Rng::new(2);
        let mut counts = [0u32; 5];
        for _ in 0..50_000 {
            counts[r.below(5) as usize] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 500.0, "counts {counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn poisson_moments_small_and_large() {
        let mut r = Rng::new(4);
        for lambda in [0.5, 5.0, 80.0] {
            let n = 40_000;
            let xs: Vec<f64> = (0..n).map(|_| r.poisson(lambda) as f64).collect();
            let mean = xs.iter().sum::<f64>() / n as f64;
            assert!(
                (mean - lambda).abs() < 0.05 * lambda.max(1.0),
                "lambda {lambda} mean {mean}"
            );
        }
    }

    #[test]
    fn poisson_zero_lambda() {
        let mut r = Rng::new(5);
        assert_eq!(r.poisson(0.0), 0);
        assert_eq!(r.poisson(-1.0), 0);
    }

    #[test]
    fn sample_distinct_properties() {
        let mut r = Rng::new(6);
        for _ in 0..100 {
            let n = 1 + r.below(50);
            let k = r.below(n + 1);
            let mut s = r.sample_distinct(n, k);
            s.sort_unstable();
            let len = s.len();
            s.dedup();
            assert_eq!(s.len(), len, "duplicates in sample");
            assert_eq!(len as u64, k);
            assert!(s.iter().all(|&x| x < n));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(7);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<u32>>());
    }
}
