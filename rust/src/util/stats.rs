//! Descriptive statistics used by the verification and bench harnesses
//! (firing rates, CV of inter-spike intervals, pairwise correlations).

/// Arithmetic mean; 0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation; 0 for < 2 samples.
pub fn std(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Coefficient of variation (std/mean); 0 when the mean is 0.
pub fn cv(xs: &[f64]) -> f64 {
    let m = mean(xs);
    if m == 0.0 {
        0.0
    } else {
        std(xs) / m
    }
}

/// Linear-interpolated percentile, p in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (s.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        s[lo]
    } else {
        s[lo] + (rank - lo as f64) * (s[hi] - s[lo])
    }
}

/// Pearson correlation coefficient; 0 if either side is constant.
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    let (mx, my) = (mean(xs), mean(ys));
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    let mut syy = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        sxy += (x - mx) * (y - my);
        sxx += (x - mx) * (x - mx);
        syy += (y - my) * (y - my);
    }
    if sxx == 0.0 || syy == 0.0 {
        0.0
    } else {
        sxy / (sxx * syy).sqrt()
    }
}

/// Fixed-width histogram over [lo, hi) with `bins` buckets.
/// Out-of-range samples are clamped into the edge buckets.
pub fn histogram(xs: &[f64], lo: f64, hi: f64, bins: usize) -> Vec<u64> {
    assert!(bins > 0 && hi > lo);
    let mut h = vec![0u64; bins];
    let w = (hi - lo) / bins as f64;
    for &x in xs {
        let b = (((x - lo) / w) as i64).clamp(0, bins as i64 - 1) as usize;
        h[b] += 1;
    }
    h
}

/// Coefficient of variation of inter-spike intervals for one spike train
/// (times in any unit); 0 for fewer than 3 spikes.
pub fn isi_cv(times: &[f64]) -> f64 {
    if times.len() < 3 {
        return 0.0;
    }
    let isis: Vec<f64> = times.windows(2).map(|w| w[1] - w[0]).collect();
    cv(&isis)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std_basic() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn empty_and_degenerate() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std(&[1.0]), 0.0);
        assert_eq!(cv(&[0.0, 0.0]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(isi_cv(&[1.0, 2.0]), 0.0);
    }

    #[test]
    fn percentiles() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&xs, 100.0) - 100.0).abs() < 1e-12);
        assert!((percentile(&xs, 50.0) - 50.5).abs() < 1e-12);
    }

    #[test]
    fn pearson_perfect_and_constant() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys = [2.0, 4.0, 6.0, 8.0];
        assert!((pearson(&xs, &ys) - 1.0).abs() < 1e-12);
        let neg: Vec<f64> = ys.iter().map(|y| -y).collect();
        assert!((pearson(&xs, &neg) + 1.0).abs() < 1e-12);
        assert_eq!(pearson(&xs, &[5.0; 4]), 0.0);
    }

    #[test]
    fn histogram_counts_and_clamping() {
        let xs = [-1.0, 0.0, 0.5, 1.0, 2.5, 99.0];
        let h = histogram(&xs, 0.0, 3.0, 3);
        assert_eq!(h, vec![3, 1, 2]);
        assert_eq!(h.iter().sum::<u64>() as usize, xs.len());
    }

    #[test]
    fn isi_cv_regular_train_is_zero() {
        let times: Vec<f64> = (0..50).map(|i| i as f64 * 10.0).collect();
        assert!(isi_cv(&times) < 1e-12);
    }
}
