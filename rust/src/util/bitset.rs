//! Compact bit vector for per-edge boolean markers.
//!
//! The edge stores mark STDP-plastic edges. A `Vec<bool>` spends a full
//! byte per edge — at hpc_benchmark indegrees that is as large as the
//! delay array. This fixed-size bitset packs 64 markers per word, and an
//! **empty** set doubles as "no marker anywhere": non-plastic networks
//! keep a zero-allocation `BitSet::new()` whose `get` is always `false`,
//! instead of allocating a vector of `false`s through `Default`.

/// Fixed-length packed bit vector (64 bits per word).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct BitSet {
    words: Vec<u64>,
    len: usize,
}

impl BitSet {
    /// The empty set: zero heap, every `get` answers `false`.
    pub fn new() -> BitSet {
        BitSet::default()
    }

    /// `len` bits, all zero.
    pub fn zeros(len: usize) -> BitSet {
        BitSet { words: vec![0; len.div_ceil(64)], len }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Bit `i`; out-of-range reads answer `false`, so the empty set is
    /// the natural representation of "nothing is marked".
    #[inline]
    pub fn get(&self, i: usize) -> bool {
        if i >= self.len {
            return false;
        }
        (self.words[i / 64] >> (i % 64)) & 1 == 1
    }

    #[inline]
    pub fn set(&mut self, i: usize, v: bool) {
        assert!(i < self.len, "bit {i} out of range (len {})", self.len);
        let mask = 1u64 << (i % 64);
        if v {
            self.words[i / 64] |= mask;
        } else {
            self.words[i / 64] &= !mask;
        }
    }

    pub fn count_ones(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    /// Exact heap bytes (what the allocator holds).
    pub fn bytes(&self) -> u64 {
        (self.words.capacity() * std::mem::size_of::<u64>()) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_set_answers_false_everywhere() {
        let b = BitSet::new();
        assert!(b.is_empty());
        assert_eq!(b.bytes(), 0);
        assert!(!b.get(0));
        assert!(!b.get(1_000_000));
        assert_eq!(b.count_ones(), 0);
    }

    #[test]
    fn set_get_roundtrip_across_word_boundaries() {
        let mut b = BitSet::zeros(130);
        for i in [0usize, 1, 63, 64, 65, 127, 128, 129] {
            assert!(!b.get(i));
            b.set(i, true);
            assert!(b.get(i), "bit {i}");
        }
        assert_eq!(b.count_ones(), 8);
        b.set(64, false);
        assert!(!b.get(64));
        assert_eq!(b.count_ones(), 7);
        // out-of-range reads stay false
        assert!(!b.get(130));
    }

    #[test]
    fn bytes_are_compact() {
        let b = BitSet::zeros(1024);
        // 1024 bits = 16 words = 128 bytes (vs 1024 for Vec<bool>)
        assert_eq!(b.bytes(), 128);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn set_checks_bounds() {
        let mut b = BitSet::zeros(10);
        b.set(10, true);
    }
}
