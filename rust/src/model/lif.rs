//! LIF neuron with exponential PSCs, exact integration.
//!
//! This is the native (Layer-3) twin of the L1 Pallas kernel in
//! `python/compile/kernels/lif_step.py`: identical propagator formulas,
//! identical update order, f64 throughout. Keeping the two bit-compatible
//! is what lets the engine switch between `DynamicsBackend::Native` and
//! `DynamicsBackend::Pjrt` without changing results beyond round-off.

/// Neuron parameters (NEST `iaf_psc_exp` names; defaults = Potjans 2014 /
/// hpc_benchmark values, which the paper's evaluation builds on).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LifParams {
    pub tau_m: f64,      // membrane time constant [ms]
    pub tau_syn_ex: f64, // excitatory synaptic time constant [ms]
    pub tau_syn_in: f64, // inhibitory synaptic time constant [ms]
    pub c_m: f64,        // membrane capacitance [pF]
    pub e_l: f64,        // resting potential [mV]
    pub v_reset: f64,    // post-spike reset [mV]
    pub v_th: f64,       // threshold [mV]
    pub t_ref: f64,      // absolute refractory period [ms]
    pub i_ext: f64,      // constant external current [pA]
}

impl Default for LifParams {
    fn default() -> Self {
        LifParams {
            tau_m: 10.0,
            tau_syn_ex: 0.5,
            tau_syn_in: 0.5,
            c_m: 250.0,
            e_l: -65.0,
            v_reset: -65.0,
            v_th: -50.0,
            t_ref: 2.0,
            i_ext: 0.0,
        }
    }
}

/// Exact-integration propagators for one step of size `dt`
/// (Rotter & Diesmann 1999; identical to `model.py::Propagators`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Propagators {
    pub p22: f64,
    pub p11e: f64,
    pub p11i: f64,
    pub p21e: f64,
    pub p21i: f64,
    pub p20: f64,
    pub ref_steps: u32,
    // baked parameter copies used by the step loop
    pub e_l: f64,
    pub v_reset: f64,
    pub v_th: f64,
    pub i_ext: f64,
}

impl Propagators {
    pub fn new(p: &LifParams, dt: f64) -> Self {
        let p22 = (-dt / p.tau_m).exp();
        let p21 = |tau_s: f64| -> f64 {
            let p11 = (-dt / tau_s).exp();
            if (tau_s - p.tau_m).abs() < 1e-12 {
                // degenerate (equal time constants) limit: h·e^{-h/tau}/C
                dt * p11 / p.c_m
            } else {
                tau_s * p.tau_m / (p.c_m * (tau_s - p.tau_m)) * (p11 - p22)
            }
        };
        Propagators {
            p22,
            p11e: (-dt / p.tau_syn_ex).exp(),
            p11i: (-dt / p.tau_syn_in).exp(),
            p21e: p21(p.tau_syn_ex),
            p21i: p21(p.tau_syn_in),
            p20: p.tau_m / p.c_m * (1.0 - p22),
            ref_steps: (p.t_ref / dt).round() as u32,
            e_l: p.e_l,
            v_reset: p.v_reset,
            v_th: p.v_th,
            i_ext: p.i_ext,
        }
    }
}

/// SoA neuron state for a contiguous block of neurons.
///
/// `refrac` is f64 (small exact integers) to mirror the kernel layout, and
/// `pidx` selects each neuron's propagator set, so one block can mix
/// populations with different parameters.
#[derive(Clone, Debug, Default)]
pub struct LifState {
    pub u: Vec<f64>,
    pub ie: Vec<f64>,
    pub ii: Vec<f64>,
    pub refrac: Vec<f64>,
    pub pidx: Vec<u8>,
}

impl LifState {
    pub fn new(n: usize, props: &[Propagators], pidx: Vec<u8>) -> Self {
        assert_eq!(pidx.len(), n);
        assert!(pidx.iter().all(|&i| (i as usize) < props.len()));
        LifState {
            u: pidx.iter().map(|&i| props[i as usize].e_l).collect(),
            ie: vec![0.0; n],
            ii: vec![0.0; n],
            refrac: vec![0.0; n],
            pidx,
        }
    }

    pub fn len(&self) -> usize {
        self.u.len()
    }

    pub fn is_empty(&self) -> bool {
        self.u.is_empty()
    }

    /// Heap footprint in bytes (for the memory accounting).
    pub fn bytes(&self) -> u64 {
        use crate::metrics::memory::vec_bytes;
        vec_bytes(&self.u)
            + vec_bytes(&self.ie)
            + vec_bytes(&self.ii)
            + vec_bytes(&self.refrac)
            + vec_bytes(&self.pidx)
    }
}

/// Advance neurons `[lo, hi)` of `state` by one step.
///
/// `in_e` / `in_i` are this step's arriving synaptic input for the same
/// index range (i.e. `in_e[i - lo]` belongs to neuron `i`); they are the
/// consumed ring-buffer slots. Local indices (relative to `lo`) of spiking
/// neurons are appended to `spikes`.
///
/// Update order matches the Pallas kernel exactly:
///   1. non-refractory membranes integrate (exact propagator),
///   2. refractory neurons hold reset and count down,
///   3. threshold ⇒ spike, reset, arm refractory counter,
///   4. synaptic currents decay, then input lands.
#[allow(clippy::too_many_arguments)]
pub fn step_slice(
    state: &mut LifState,
    lo: usize,
    hi: usize,
    in_e: &[f64],
    in_i: &[f64],
    props: &[Propagators],
    spikes: &mut Vec<u32>,
) {
    debug_assert!(hi <= state.len());
    debug_assert_eq!(in_e.len(), hi - lo);
    debug_assert_eq!(in_i.len(), hi - lo);
    for i in lo..hi {
        let p = &props[state.pidx[i] as usize];
        let u = state.u[i];
        let ie = state.ie[i];
        let ii = state.ii[i];
        let r = state.refrac[i];

        let (mut u_new, mut r_new);
        if r > 0.0 {
            u_new = p.v_reset;
            r_new = r - 1.0;
        } else {
            u_new = p.e_l
                + (u - p.e_l) * p.p22
                + ie * p.p21e
                + ii * p.p21i
                + p.i_ext * p.p20;
            r_new = r;
            if u_new >= p.v_th {
                u_new = p.v_reset;
                r_new = p.ref_steps as f64;
                spikes.push((i - lo) as u32);
            }
        }
        state.u[i] = u_new;
        state.refrac[i] = r_new;
        state.ie[i] = ie * p.p11e + in_e[i - lo];
        state.ii[i] = ii * p.p11i + in_i[i - lo];
    }
}

/// Spike-mask chunk width of the vector kernels: the inner loops run over
/// at most this many neurons with the mask in a stack array, so the
/// compiler sees fixed-bound, branch-free bodies it can unroll and
/// vectorize.
pub(crate) const MASK_CHUNK: usize = 64;

/// Branch-free, run-segmented twin of [`step_slice`] — bit-identical by
/// construction (`engine.integrate = "vector"`, the default).
///
/// Three transformations, none of which may move a single bit:
/// 1. the span is segmented into homogeneous runs of equal `pidx`, so the
///    propagator lookup (and the constant `i_ext · p20` drive term) hoists
///    out of the inner loop — the hoisted multiply is the same f64 multiply
///    the scalar loop performed per neuron;
/// 2. refractory/threshold handling becomes select arithmetic: both the
///    integrated membrane and the reset value are computed, then chosen by
///    mask. The discarded arm has no side effects and the kept arm is the
///    exact expression (same operation order) the scalar kernel evaluates;
/// 3. `spikes.push` leaves the loop: spike flags land in a stack mask
///    chunk, and a separate compaction pass appends local indices — still
///    in ascending order, exactly as the scalar kernel emits them.
#[allow(clippy::too_many_arguments)]
pub fn step_slice_vector(
    state: &mut LifState,
    lo: usize,
    hi: usize,
    in_e: &[f64],
    in_i: &[f64],
    props: &[Propagators],
    spikes: &mut Vec<u32>,
) {
    debug_assert!(hi <= state.len());
    debug_assert_eq!(in_e.len(), hi - lo);
    debug_assert_eq!(in_i.len(), hi - lo);
    let LifState { u, ie, ii, refrac, pidx } = state;
    let mut start = lo;
    while start < hi {
        // homogeneous run of equal pidx (blocks tile per population, so
        // runs are long — usually the whole span)
        let pi = pidx[start];
        let mut end = start + 1;
        while end < hi && pidx[end] == pi {
            end += 1;
        }
        let p = props[pi as usize];
        let i_drive = p.i_ext * p.p20;
        let ref_arm = p.ref_steps as f64;

        let mut mask = [false; MASK_CHUNK];
        let mut c_lo = start;
        while c_lo < end {
            let c_hi = (c_lo + MASK_CHUNK).min(end);
            for i in c_lo..c_hi {
                let um = u[i];
                let ce = ie[i];
                let ci = ii[i];
                let r = refrac[i];
                let refr = r > 0.0;
                let integ = p.e_l
                    + (um - p.e_l) * p.p22
                    + ce * p.p21e
                    + ci * p.p21i
                    + i_drive;
                let u_int = if refr { p.v_reset } else { integ };
                let spike = !refr && u_int >= p.v_th;
                u[i] = if spike { p.v_reset } else { u_int };
                refrac[i] = if refr {
                    r - 1.0
                } else if spike {
                    ref_arm
                } else {
                    r
                };
                ie[i] = ce * p.p11e + in_e[i - lo];
                ii[i] = ci * p.p11i + in_i[i - lo];
                mask[i - c_lo] = spike;
            }
            // compaction pass: ascending local indices, as scalar emits
            for (j, &fired) in mask[..c_hi - c_lo].iter().enumerate() {
                if fired {
                    spikes.push((c_lo + j - lo) as u32);
                }
            }
            c_lo = c_hi;
        }
        start = end;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn single(props: &[Propagators]) -> LifState {
        LifState::new(1, props, vec![0])
    }

    #[test]
    fn leak_decays_to_rest() {
        let p = LifParams::default();
        let props = [Propagators::new(&p, 0.1)];
        let mut s = single(&props);
        s.u[0] = p.e_l + 8.0;
        let mut spikes = Vec::new();
        for _ in 0..3000 {
            step_slice(&mut s, 0, 1, &[0.0], &[0.0], &props, &mut spikes);
        }
        assert!(spikes.is_empty());
        assert!((s.u[0] - p.e_l).abs() < 1e-8);
    }

    #[test]
    fn constant_drive_steady_state() {
        let p = LifParams { i_ext: 300.0, ..Default::default() };
        let props = [Propagators::new(&p, 0.1)];
        let mut s = single(&props);
        let mut spikes = Vec::new();
        for _ in 0..5000 {
            step_slice(&mut s, 0, 1, &[0.0], &[0.0], &props, &mut spikes);
        }
        // steady state: e_l + tau_m*I/C = -65 + 10*300/250 = -53 mV
        assert!(spikes.is_empty());
        assert!((s.u[0] - (-53.0)).abs() < 1e-9);
    }

    #[test]
    fn suprathreshold_drive_fires_regularly() {
        let p = LifParams { i_ext: 450.0, ..Default::default() };
        let props = [Propagators::new(&p, 0.1)];
        let mut s = single(&props);
        let mut all = Vec::new();
        let mut when = Vec::new();
        for t in 0..3000 {
            let mut spikes = Vec::new();
            step_slice(&mut s, 0, 1, &[0.0], &[0.0], &props, &mut spikes);
            if !spikes.is_empty() {
                when.push(t);
            }
            all.extend(spikes);
        }
        assert!(all.len() > 3, "expected several spikes, got {}", all.len());
        // inter-spike intervals identical for constant drive
        let isis: Vec<i64> =
            when.windows(2).map(|w| w[1] - w[0]).collect();
        assert!(isis.windows(2).all(|w| w[0] == w[1]), "{isis:?}");
        // refractory period respected: isi > ref_steps
        assert!(isis[0] > props[0].ref_steps as i64);
    }

    #[test]
    fn refractory_holds_under_bombardment() {
        let p = LifParams::default();
        let props = [Propagators::new(&p, 0.1)];
        let mut s = single(&props);
        s.u[0] = p.v_th + 1.0; // will spike on first step... (already above)
        let mut spikes = Vec::new();
        step_slice(&mut s, 0, 1, &[0.0], &[0.0], &props, &mut spikes);
        assert_eq!(spikes.len(), 1);
        assert_eq!(s.refrac[0], props[0].ref_steps as f64);
        // bombard with huge input during refractoriness: u must stay at reset
        for _ in 0..props[0].ref_steps {
            let mut sp = Vec::new();
            step_slice(&mut s, 0, 1, &[1e5], &[0.0], &props, &mut sp);
            assert!(sp.is_empty());
            assert_eq!(s.u[0], p.v_reset);
        }
        assert_eq!(s.refrac[0], 0.0);
    }

    #[test]
    fn input_lands_after_decay_ordering() {
        // input delivered at step t must not affect u at step t (only t+1)
        let p = LifParams::default();
        let props = [Propagators::new(&p, 0.1)];
        let mut a = single(&props);
        let mut b = single(&props);
        let mut sp = Vec::new();
        step_slice(&mut a, 0, 1, &[100.0], &[0.0], &props, &mut sp);
        step_slice(&mut b, 0, 1, &[0.0], &[0.0], &props, &mut sp);
        assert_eq!(a.u[0], b.u[0], "u must be unaffected in the same step");
        assert_ne!(a.ie[0], b.ie[0]);
        // ... but the next step differs
        step_slice(&mut a, 0, 1, &[0.0], &[0.0], &props, &mut sp);
        step_slice(&mut b, 0, 1, &[0.0], &[0.0], &props, &mut sp);
        assert!(a.u[0] > b.u[0]);
    }

    #[test]
    fn mixed_populations_in_one_block() {
        let fast = LifParams { tau_m: 5.0, ..Default::default() };
        let slow = LifParams { tau_m: 20.0, ..Default::default() };
        let props = [Propagators::new(&fast, 0.1), Propagators::new(&slow, 0.1)];
        let mut s = LifState::new(2, &props, vec![0, 1]);
        s.u[0] = -60.0;
        s.u[1] = -60.0;
        let mut sp = Vec::new();
        step_slice(&mut s, 0, 2, &[0.0; 2], &[0.0; 2], &props, &mut sp);
        // the fast neuron decays toward rest more per step
        assert!(s.u[0] < s.u[1]);
    }

    #[test]
    fn slice_bounds_respected() {
        let p = LifParams { i_ext: 1000.0, ..Default::default() };
        let props = [Propagators::new(&p, 0.1)];
        let mut s = LifState::new(4, &props, vec![0; 4]);
        let before = s.u.clone();
        let mut sp = Vec::new();
        // step only [1, 3)
        step_slice(&mut s, 1, 3, &[0.0; 2], &[0.0; 2], &props, &mut sp);
        assert_eq!(s.u[0], before[0]);
        assert_eq!(s.u[3], before[3]);
        assert_ne!(s.u[1], before[1]);
        assert_ne!(s.u[2], before[2]);
    }

    #[test]
    fn vector_kernel_bit_identical_to_scalar() {
        // mixed pidx runs (crossing the MASK_CHUNK boundary), drive
        // strong enough to spike, refractory overlap with bombardment
        let fast = LifParams { tau_m: 5.0, i_ext: 600.0, ..Default::default() };
        let slow = LifParams { tau_m: 20.0, ..Default::default() };
        let props =
            [Propagators::new(&fast, 0.1), Propagators::new(&slow, 0.1)];
        let n = 3 * MASK_CHUNK + 7;
        let pidx: Vec<u8> =
            (0..n).map(|i| u8::from(i >= MASK_CHUNK + 3)).collect();
        let mut a = LifState::new(n, &props, pidx.clone());
        let mut b = LifState::new(n, &props, pidx);
        for i in 0..n {
            a.u[i] = -70.0 + (i % 37) as f64;
            b.u[i] = a.u[i];
        }
        for step in 0..400u64 {
            let ine: Vec<f64> = (0..n)
                .map(|i| ((i as u64 * 31 + step * 7) % 11) as f64 * 40.0)
                .collect();
            let ini: Vec<f64> = (0..n)
                .map(|i| ((i as u64 * 13 + step * 3) % 7) as f64 * -25.0)
                .collect();
            let mut sa = Vec::new();
            let mut sb = Vec::new();
            step_slice(&mut a, 0, n, &ine, &ini, &props, &mut sa);
            step_slice_vector(&mut b, 0, n, &ine, &ini, &props, &mut sb);
            assert_eq!(sa, sb, "spikes diverged at step {step}");
            assert_eq!(a.u, b.u, "u diverged at step {step}");
            assert_eq!(a.ie, b.ie);
            assert_eq!(a.ii, b.ii);
            assert_eq!(a.refrac, b.refrac);
        }
    }

    #[test]
    fn vector_kernel_respects_slice_bounds() {
        let p = LifParams { i_ext: 1000.0, ..Default::default() };
        let props = [Propagators::new(&p, 0.1)];
        let mut s = LifState::new(4, &props, vec![0; 4]);
        let before = s.u.clone();
        let mut sp = Vec::new();
        step_slice_vector(&mut s, 1, 3, &[0.0; 2], &[0.0; 2], &props, &mut sp);
        assert_eq!(s.u[0], before[0]);
        assert_eq!(s.u[3], before[3]);
        assert_ne!(s.u[1], before[1]);
        assert_ne!(s.u[2], before[2]);
    }

    #[test]
    fn propagators_match_python_manifest_values() {
        // values cross-checked against python model.Propagators (default cfg)
        let props = Propagators::new(&LifParams::default(), 0.1);
        assert!((props.p22 - (-0.1f64 / 10.0).exp()).abs() < 1e-15);
        assert!((props.p11e - (-0.1f64 / 0.5).exp()).abs() < 1e-15);
        assert_eq!(props.ref_steps, 20);
        // p21e = tau_s*tau_m/(C*(tau_s-tau_m)) * (p11e - p22)
        let want = 0.5 * 10.0 / (250.0 * (0.5 - 10.0))
            * ((-0.2f64).exp() - (-0.01f64).exp());
        assert!((props.p21e - want).abs() < 1e-18);
    }
}
