//! Hodgkin-Huxley neuron (paper ref [31]) — the high compute-intensity
//! model of the paper's §I.C discussion: simulations built on HH-class
//! models show "absolutely better results in scalability" because the
//! per-neuron arithmetic dwarfs communication; the paper deliberately
//! evaluates on LIF ("bad cases") instead. This implementation both
//! *quantifies* that computation/communication argument on our substrate
//! (`ablation_intensity` bench) and runs as a first-class network
//! population model through the model-generic dynamics layer.
//!
//! Classic squid-axon parameters, integrated with exponential-Euler on
//! the gates and forward Euler on the membrane, sub-stepped for
//! stability at dt = 0.1 ms.
//!
//! Synaptic input follows the engine's LIF convention: arriving weights
//! [pA] land (scaled by `syn_scale` into µA/cm²) in exponentially
//! decaying excitatory/inhibitory currents held constant across the
//! sub-steps of one simulator step.

/// Resting potential [mV]; fresh state is seeded here with gates at
/// their steady state.
pub const V_REST: f64 = -65.0;

/// HH parameters (classic Hodgkin & Huxley 1952 values, 1 µF/cm² scale).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct HhParams {
    pub c_m: f64,      // membrane capacitance [µF/cm²]
    pub g_na: f64,     // peak sodium conductance [mS/cm²]
    pub g_k: f64,      // peak potassium conductance [mS/cm²]
    pub g_l: f64,      // leak conductance [mS/cm²]
    pub e_na: f64,     // sodium reversal [mV]
    pub e_k: f64,      // potassium reversal [mV]
    pub e_l: f64,      // leak reversal [mV]
    /// spike detection threshold [mV] (upward crossing)
    pub v_spike: f64,
    /// integration sub-steps per simulator step
    pub substeps: u32,
    /// excitatory / inhibitory synaptic time constants [ms]
    pub tau_syn_ex: f64,
    pub tau_syn_in: f64,
    /// constant external current density [µA/cm²]
    pub i_ext: f64,
    /// pA → µA/cm² conversion for network synaptic weights (an implied
    /// membrane area; 0.02 maps the 87.8 pA reference weight to a
    /// ~1.8 µA/cm² PSC peak)
    pub syn_scale: f64,
}

impl Default for HhParams {
    fn default() -> Self {
        HhParams {
            c_m: 1.0,
            g_na: 120.0,
            g_k: 36.0,
            g_l: 0.3,
            e_na: 50.0,
            e_k: -77.0,
            e_l: -54.387,
            v_spike: 0.0,
            substeps: 10,
            tau_syn_ex: 0.5,
            tau_syn_in: 0.5,
            i_ext: 0.0,
            syn_scale: 0.02,
        }
    }
}

/// SoA state for a block of HH neurons.
#[derive(Clone, Debug)]
pub struct HhState {
    pub v: Vec<f64>,
    pub m: Vec<f64>,
    pub h: Vec<f64>,
    pub n: Vec<f64>,
    /// previous-step voltage (for upward-crossing spike detection)
    pub v_prev: Vec<f64>,
    /// Excitatory / inhibitory synaptic current densities [µA/cm²].
    pub ie: Vec<f64>,
    pub ii: Vec<f64>,
}

impl HhState {
    /// Resting state (v = [`V_REST`], gates at their steady state).
    pub fn new(n_neurons: usize) -> Self {
        let v0 = V_REST;
        HhState {
            v: vec![v0; n_neurons],
            m: vec![steady(alpha_m(v0), beta_m(v0)); n_neurons],
            h: vec![steady(alpha_h(v0), beta_h(v0)); n_neurons],
            n: vec![steady(alpha_n(v0), beta_n(v0)); n_neurons],
            v_prev: vec![v0; n_neurons],
            ie: vec![0.0; n_neurons],
            ii: vec![0.0; n_neurons],
        }
    }

    pub fn len(&self) -> usize {
        self.v.len()
    }

    pub fn is_empty(&self) -> bool {
        self.v.is_empty()
    }
}

/// Re-seed neuron `i` at membrane potential `v` with gates at their
/// steady state for that voltage (used for jittered initial states).
pub fn init_at(state: &mut HhState, i: usize, v: f64) {
    state.v[i] = v;
    state.v_prev[i] = v;
    state.m[i] = steady(alpha_m(v), beta_m(v));
    state.h[i] = steady(alpha_h(v), beta_h(v));
    state.n[i] = steady(alpha_n(v), beta_n(v));
}

#[inline]
fn steady(a: f64, b: f64) -> f64 {
    a / (a + b)
}

// rate functions [1/ms]; the vtrap guards the 0/0 removable singularities
#[inline]
fn vtrap(x: f64, y: f64) -> f64 {
    if (x / y).abs() < 1e-6 {
        y * (1.0 - x / y / 2.0)
    } else {
        x / ((x / y).exp() - 1.0)
    }
}

#[inline]
pub fn alpha_m(v: f64) -> f64 {
    0.1 * vtrap(-(v + 40.0), 10.0)
}
#[inline]
pub fn beta_m(v: f64) -> f64 {
    4.0 * (-(v + 65.0) / 18.0).exp()
}
#[inline]
pub fn alpha_h(v: f64) -> f64 {
    0.07 * (-(v + 65.0) / 20.0).exp()
}
#[inline]
pub fn beta_h(v: f64) -> f64 {
    1.0 / ((-(v + 35.0) / 10.0).exp() + 1.0)
}
#[inline]
pub fn alpha_n(v: f64) -> f64 {
    0.01 * vtrap(-(v + 55.0), 10.0)
}
#[inline]
pub fn beta_n(v: f64) -> f64 {
    0.125 * (-(v + 65.0) / 80.0).exp()
}

/// Advance neurons `[lo, hi)` by one simulator step of `dt_ms`. `in_e` /
/// `in_i` are this step's arriving synaptic weights [pA] for the same
/// index range; local indices of spiking neurons (upward threshold
/// crossings) are appended.
#[allow(clippy::too_many_arguments)]
pub fn step_slice(
    state: &mut HhState,
    lo: usize,
    hi: usize,
    in_e: &[f64],
    in_i: &[f64],
    p: &HhParams,
    dt_ms: f64,
    spikes: &mut Vec<u32>,
) {
    debug_assert!(hi <= state.len());
    debug_assert_eq!(in_e.len(), hi - lo);
    debug_assert_eq!(in_i.len(), hi - lo);
    let h_dt = dt_ms / p.substeps as f64;
    let de = (-dt_ms / p.tau_syn_ex).exp();
    let di = (-dt_ms / p.tau_syn_in).exp();
    for i in lo..hi {
        let mut v = state.v[i];
        let mut m = state.m[i];
        let mut hh = state.h[i];
        let mut n = state.n[i];
        // synaptic + external drive, constant across the sub-steps
        let i_drive = p.i_ext + state.ie[i] + state.ii[i];
        for _ in 0..p.substeps {
            // exponential Euler on gates
            let (am, bm) = (alpha_m(v), beta_m(v));
            let (ah, bh) = (alpha_h(v), beta_h(v));
            let (an, bn) = (alpha_n(v), beta_n(v));
            m = exp_euler(m, am, bm, h_dt);
            hh = exp_euler(hh, ah, bh, h_dt);
            n = exp_euler(n, an, bn, h_dt);
            // membrane
            let i_na = p.g_na * m * m * m * hh * (v - p.e_na);
            let i_k = p.g_k * n * n * n * n * (v - p.e_k);
            let i_l = p.g_l * (v - p.e_l);
            v += h_dt * (i_drive - i_na - i_k - i_l) / p.c_m;
        }
        if state.v_prev[i] < p.v_spike && v >= p.v_spike {
            spikes.push((i - lo) as u32);
        }
        state.v_prev[i] = v;
        state.v[i] = v;
        state.m[i] = m;
        state.h[i] = hh;
        state.n[i] = n;
        // currents decay, then input lands (LIF ordering)
        state.ie[i] = state.ie[i] * de + p.syn_scale * in_e[i - lo];
        state.ii[i] = state.ii[i] * di + p.syn_scale * in_i[i - lo];
    }
}

/// Mask-compacting twin of [`step_slice`] — bit-identical by construction
/// (`engine.integrate = "vector"`, the default).
///
/// The sub-stepped gate/membrane body is already branch-free (the `vtrap`
/// removable-singularity guard is value-preserving and stays — exactness
/// forbids replacing it); what moves is the in-loop `spikes.push`: upward
/// threshold crossings land in a stack mask chunk and compact into
/// `spikes` in a separate ascending pass, keeping the neuron loop free of
/// data-dependent control flow.
#[allow(clippy::too_many_arguments)]
pub fn step_slice_vector(
    state: &mut HhState,
    lo: usize,
    hi: usize,
    in_e: &[f64],
    in_i: &[f64],
    p: &HhParams,
    dt_ms: f64,
    spikes: &mut Vec<u32>,
) {
    use super::lif::MASK_CHUNK;
    debug_assert!(hi <= state.len());
    debug_assert_eq!(in_e.len(), hi - lo);
    debug_assert_eq!(in_i.len(), hi - lo);
    let h_dt = dt_ms / p.substeps as f64;
    let de = (-dt_ms / p.tau_syn_ex).exp();
    let di = (-dt_ms / p.tau_syn_in).exp();
    let mut mask = [false; MASK_CHUNK];
    let mut c_lo = lo;
    while c_lo < hi {
        let c_hi = (c_lo + MASK_CHUNK).min(hi);
        for i in c_lo..c_hi {
            let mut v = state.v[i];
            let mut m = state.m[i];
            let mut hh = state.h[i];
            let mut n = state.n[i];
            let i_drive = p.i_ext + state.ie[i] + state.ii[i];
            for _ in 0..p.substeps {
                let (am, bm) = (alpha_m(v), beta_m(v));
                let (ah, bh) = (alpha_h(v), beta_h(v));
                let (an, bn) = (alpha_n(v), beta_n(v));
                m = exp_euler(m, am, bm, h_dt);
                hh = exp_euler(hh, ah, bh, h_dt);
                n = exp_euler(n, an, bn, h_dt);
                let i_na = p.g_na * m * m * m * hh * (v - p.e_na);
                let i_k = p.g_k * n * n * n * n * (v - p.e_k);
                let i_l = p.g_l * (v - p.e_l);
                v += h_dt * (i_drive - i_na - i_k - i_l) / p.c_m;
            }
            mask[i - c_lo] = state.v_prev[i] < p.v_spike && v >= p.v_spike;
            state.v_prev[i] = v;
            state.v[i] = v;
            state.m[i] = m;
            state.h[i] = hh;
            state.n[i] = n;
            state.ie[i] = state.ie[i] * de + p.syn_scale * in_e[i - lo];
            state.ii[i] = state.ii[i] * di + p.syn_scale * in_i[i - lo];
        }
        for (j, &fired) in mask[..c_hi - c_lo].iter().enumerate() {
            if fired {
                spikes.push((c_lo + j - lo) as u32);
            }
        }
        c_lo = c_hi;
    }
}

#[inline]
fn exp_euler(x: f64, a: f64, b: f64, dt: f64) -> f64 {
    let tau = 1.0 / (a + b);
    let inf = a * tau;
    inf + (x - inf) * (-dt / tau).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn zeros(n: usize) -> Vec<f64> {
        vec![0.0; n]
    }

    #[test]
    fn resting_state_is_stable() {
        let p = HhParams::default();
        let mut s = HhState::new(2);
        let mut spikes = Vec::new();
        for _ in 0..1000 {
            step_slice(
                &mut s, 0, 2, &zeros(2), &zeros(2), &p, 0.1, &mut spikes,
            );
        }
        assert!(spikes.is_empty());
        assert!((s.v[0] + 65.0).abs() < 1.0, "drifted to {}", s.v[0]);
    }

    #[test]
    fn suprathreshold_current_fires_tonically() {
        let p = HhParams { i_ext: 10.0, ..Default::default() };
        let mut s = HhState::new(1);
        let mut count = 0;
        for _ in 0..5000 {
            let mut spikes = Vec::new();
            step_slice(
                &mut s, 0, 1, &zeros(1), &zeros(1), &p, 0.1, &mut spikes,
            );
            count += spikes.len();
        }
        // 10 µA/cm² drives ~60-90 Hz tonic firing: 500 ms -> 30-50 spikes
        assert!(
            (20..=60).contains(&count),
            "unexpected spike count {count}"
        );
    }

    #[test]
    fn subthreshold_current_does_not_fire() {
        let p = HhParams { i_ext: 1.0, ..Default::default() };
        let mut s = HhState::new(1);
        let mut spikes = Vec::new();
        for _ in 0..3000 {
            step_slice(
                &mut s, 0, 1, &zeros(1), &zeros(1), &p, 0.1, &mut spikes,
            );
        }
        assert!(spikes.is_empty(), "fired {} times", spikes.len());
    }

    #[test]
    fn action_potential_shape() {
        // peak above +20 mV, afterhyperpolarization below -70 mV
        let pulse = HhParams { i_ext: 15.0, ..Default::default() };
        let rest = HhParams::default();
        let mut s = HhState::new(1);
        let mut vmax = f64::NEG_INFINITY;
        let mut vmin = f64::INFINITY;
        for step in 0..2000 {
            let p = if (100..150).contains(&step) { &pulse } else { &rest };
            let mut spikes = Vec::new();
            step_slice(
                &mut s, 0, 1, &zeros(1), &zeros(1), p, 0.1, &mut spikes,
            );
            vmax = vmax.max(s.v[0]);
            vmin = vmin.min(s.v[0]);
        }
        assert!(vmax > 20.0, "peak {vmax}");
        assert!(vmin < -70.0, "AHP {vmin}");
    }

    #[test]
    fn gates_stay_in_unit_interval() {
        let hi = HhParams { i_ext: 20.0, ..Default::default() };
        let lo = HhParams { i_ext: -5.0, ..Default::default() };
        let mut s = HhState::new(1);
        for step in 0..4000 {
            let p = if step % 200 < 50 { &hi } else { &lo };
            let mut spikes = Vec::new();
            step_slice(
                &mut s, 0, 1, &zeros(1), &zeros(1), p, 0.1, &mut spikes,
            );
            for g in [s.m[0], s.h[0], s.n[0]] {
                assert!((0.0..=1.0).contains(&g), "gate {g} out of range");
            }
        }
    }

    #[test]
    fn synaptic_bombardment_fires_and_input_is_delayed() {
        let p = HhParams::default();
        let mut a = HhState::new(1);
        let mut b = HhState::new(1);
        let mut sp = Vec::new();
        // weight lands this step but acts from the next step on
        step_slice(&mut a, 0, 1, &[500.0], &zeros(1), &p, 0.1, &mut sp);
        step_slice(&mut b, 0, 1, &zeros(1), &zeros(1), &p, 0.1, &mut sp);
        assert_eq!(a.v[0], b.v[0]);
        assert!(a.ie[0] > 0.0);
        // sustained pA-scale bombardment: steady ie ≈ scale·w/(1-e^{-dt/τ})
        // = 0.02·100/0.18 ≈ 11 µA/cm² — suprathreshold
        let mut count = 0usize;
        for _ in 0..5000 {
            let mut sp = Vec::new();
            step_slice(&mut a, 0, 1, &[100.0], &zeros(1), &p, 0.1, &mut sp);
            count += sp.len();
        }
        assert!(count > 5, "only {count} spikes under bombardment");
    }

    #[test]
    fn vector_kernel_bit_identical_to_scalar() {
        let p = HhParams { i_ext: 8.0, ..Default::default() };
        let n = super::super::lif::MASK_CHUNK + 11;
        let mut a = HhState::new(n);
        let mut b = HhState::new(n);
        for i in 0..n {
            init_at(&mut a, i, -70.0 + (i % 17) as f64);
            init_at(&mut b, i, -70.0 + (i % 17) as f64);
        }
        for step in 0..600u64 {
            let ine: Vec<f64> = (0..n)
                .map(|i| ((i as u64 * 23 + step * 3) % 6) as f64 * 40.0)
                .collect();
            let ini: Vec<f64> = (0..n)
                .map(|i| ((i as u64 * 5 + step * 13) % 4) as f64 * -30.0)
                .collect();
            let mut sa = Vec::new();
            let mut sb = Vec::new();
            step_slice(&mut a, 0, n, &ine, &ini, &p, 0.1, &mut sa);
            step_slice_vector(&mut b, 0, n, &ine, &ini, &p, 0.1, &mut sb);
            assert_eq!(sa, sb, "spikes diverged at step {step}");
            assert_eq!(a.v, b.v, "v diverged at step {step}");
            assert_eq!(a.m, b.m);
            assert_eq!(a.h, b.h);
            assert_eq!(a.n, b.n);
            assert_eq!(a.ie, b.ie);
            assert_eq!(a.ii, b.ii);
        }
    }

    #[test]
    fn init_at_reseeds_gates() {
        let mut s = HhState::new(2);
        init_at(&mut s, 1, -60.0);
        assert_eq!(s.v[1], -60.0);
        assert_eq!(s.v_prev[1], -60.0);
        assert_eq!(s.m[1], steady(alpha_m(-60.0), beta_m(-60.0)));
        // untouched neuron keeps the resting seed
        assert_eq!(s.v[0], V_REST);
    }
}
