//! Hodgkin-Huxley neuron (paper ref [31]) — the high compute-intensity
//! model of the paper's §I.C discussion: simulations built on HH-class
//! models show "absolutely better results in scalability" because the
//! per-neuron arithmetic dwarfs communication; the paper deliberately
//! evaluates on LIF ("bad cases") instead. This implementation exists to
//! *quantify* that computation/communication argument on our substrate
//! (`ablation_intensity` bench) and to extend the framework beyond LIF.
//!
//! Classic squid-axon parameters, integrated with exponential-Euler on
//! the gates and forward Euler on the membrane, sub-stepped for
//! stability at dt = 0.1 ms.

/// HH parameters (classic Hodgkin & Huxley 1952 values, 1 µF/cm² scale).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct HhParams {
    pub c_m: f64,      // membrane capacitance [µF/cm²]
    pub g_na: f64,     // peak sodium conductance [mS/cm²]
    pub g_k: f64,      // peak potassium conductance [mS/cm²]
    pub g_l: f64,      // leak conductance [mS/cm²]
    pub e_na: f64,     // sodium reversal [mV]
    pub e_k: f64,      // potassium reversal [mV]
    pub e_l: f64,      // leak reversal [mV]
    /// spike detection threshold [mV] (upward crossing)
    pub v_spike: f64,
    /// integration sub-steps per simulator step
    pub substeps: u32,
}

impl Default for HhParams {
    fn default() -> Self {
        HhParams {
            c_m: 1.0,
            g_na: 120.0,
            g_k: 36.0,
            g_l: 0.3,
            e_na: 50.0,
            e_k: -77.0,
            e_l: -54.387,
            v_spike: 0.0,
            substeps: 10,
        }
    }
}

/// SoA state for a block of HH neurons.
#[derive(Clone, Debug)]
pub struct HhState {
    pub v: Vec<f64>,
    pub m: Vec<f64>,
    pub h: Vec<f64>,
    pub n: Vec<f64>,
    /// previous-step voltage (for upward-crossing spike detection)
    pub v_prev: Vec<f64>,
}

impl HhState {
    /// Resting state (v = -65 mV, gates at their steady state).
    pub fn new(n_neurons: usize) -> Self {
        let v0 = -65.0;
        HhState {
            v: vec![v0; n_neurons],
            m: vec![steady(alpha_m(v0), beta_m(v0)); n_neurons],
            h: vec![steady(alpha_h(v0), beta_h(v0)); n_neurons],
            n: vec![steady(alpha_n(v0), beta_n(v0)); n_neurons],
            v_prev: vec![v0; n_neurons],
        }
    }

    pub fn len(&self) -> usize {
        self.v.len()
    }

    pub fn is_empty(&self) -> bool {
        self.v.is_empty()
    }
}

#[inline]
fn steady(a: f64, b: f64) -> f64 {
    a / (a + b)
}

// rate functions [1/ms]; the vtrap guards the 0/0 removable singularities
#[inline]
fn vtrap(x: f64, y: f64) -> f64 {
    if (x / y).abs() < 1e-6 {
        y * (1.0 - x / y / 2.0)
    } else {
        x / ((x / y).exp() - 1.0)
    }
}

#[inline]
pub fn alpha_m(v: f64) -> f64 {
    0.1 * vtrap(-(v + 40.0), 10.0)
}
#[inline]
pub fn beta_m(v: f64) -> f64 {
    4.0 * (-(v + 65.0) / 18.0).exp()
}
#[inline]
pub fn alpha_h(v: f64) -> f64 {
    0.07 * (-(v + 65.0) / 20.0).exp()
}
#[inline]
pub fn beta_h(v: f64) -> f64 {
    1.0 / ((-(v + 35.0) / 10.0).exp() + 1.0)
}
#[inline]
pub fn alpha_n(v: f64) -> f64 {
    0.01 * vtrap(-(v + 55.0), 10.0)
}
#[inline]
pub fn beta_n(v: f64) -> f64 {
    0.125 * (-(v + 65.0) / 80.0).exp()
}

/// Advance neurons `[lo, hi)` by one simulator step of `dt_ms` given the
/// external/synaptic current density `i_in` [µA/cm²] per neuron; local
/// indices of spiking neurons (upward threshold crossings) are appended.
pub fn step_slice(
    state: &mut HhState,
    lo: usize,
    hi: usize,
    i_in: &[f64],
    p: &HhParams,
    dt_ms: f64,
    spikes: &mut Vec<u32>,
) {
    let h_dt = dt_ms / p.substeps as f64;
    for i in lo..hi {
        let mut v = state.v[i];
        let mut m = state.m[i];
        let mut hh = state.h[i];
        let mut n = state.n[i];
        let i_ext = i_in[i - lo];
        for _ in 0..p.substeps {
            // exponential Euler on gates
            let (am, bm) = (alpha_m(v), beta_m(v));
            let (ah, bh) = (alpha_h(v), beta_h(v));
            let (an, bn) = (alpha_n(v), beta_n(v));
            m = exp_euler(m, am, bm, h_dt);
            hh = exp_euler(hh, ah, bh, h_dt);
            n = exp_euler(n, an, bn, h_dt);
            // membrane
            let i_na = p.g_na * m * m * m * hh * (v - p.e_na);
            let i_k = p.g_k * n * n * n * n * (v - p.e_k);
            let i_l = p.g_l * (v - p.e_l);
            v += h_dt * (i_ext - i_na - i_k - i_l) / p.c_m;
        }
        if state.v_prev[i] < p.v_spike && v >= p.v_spike {
            spikes.push((i - lo) as u32);
        }
        state.v_prev[i] = v;
        state.v[i] = v;
        state.m[i] = m;
        state.h[i] = hh;
        state.n[i] = n;
    }
}

#[inline]
fn exp_euler(x: f64, a: f64, b: f64, dt: f64) -> f64 {
    let tau = 1.0 / (a + b);
    let inf = a * tau;
    inf + (x - inf) * (-dt / tau).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resting_state_is_stable() {
        let p = HhParams::default();
        let mut s = HhState::new(2);
        let mut spikes = Vec::new();
        for _ in 0..1000 {
            step_slice(&mut s, 0, 2, &[0.0, 0.0], &p, 0.1, &mut spikes);
        }
        assert!(spikes.is_empty());
        assert!((s.v[0] + 65.0).abs() < 1.0, "drifted to {}", s.v[0]);
    }

    #[test]
    fn suprathreshold_current_fires_tonically() {
        let p = HhParams::default();
        let mut s = HhState::new(1);
        let mut count = 0;
        for _ in 0..5000 {
            let mut spikes = Vec::new();
            step_slice(&mut s, 0, 1, &[10.0], &p, 0.1, &mut spikes);
            count += spikes.len();
        }
        // 10 µA/cm² drives ~60-90 Hz tonic firing: 500 ms -> 30-50 spikes
        assert!(
            (20..=60).contains(&count),
            "unexpected spike count {count}"
        );
    }

    #[test]
    fn subthreshold_current_does_not_fire() {
        let p = HhParams::default();
        let mut s = HhState::new(1);
        let mut spikes = Vec::new();
        for _ in 0..3000 {
            step_slice(&mut s, 0, 1, &[1.0], &p, 0.1, &mut spikes);
        }
        assert!(spikes.is_empty(), "fired {} times", spikes.len());
    }

    #[test]
    fn action_potential_shape() {
        // peak above +20 mV, afterhyperpolarization below -70 mV
        let p = HhParams::default();
        let mut s = HhState::new(1);
        let mut vmax = f64::NEG_INFINITY;
        let mut vmin = f64::INFINITY;
        for step in 0..2000 {
            let i = if (100..150).contains(&step) { 15.0 } else { 0.0 };
            let mut spikes = Vec::new();
            step_slice(&mut s, 0, 1, &[i], &p, 0.1, &mut spikes);
            vmax = vmax.max(s.v[0]);
            vmin = vmin.min(s.v[0]);
        }
        assert!(vmax > 20.0, "peak {vmax}");
        assert!(vmin < -70.0, "AHP {vmin}");
    }

    #[test]
    fn gates_stay_in_unit_interval() {
        let p = HhParams::default();
        let mut s = HhState::new(1);
        for step in 0..4000 {
            let i = if step % 200 < 50 { 20.0 } else { -5.0 };
            let mut spikes = Vec::new();
            step_slice(&mut s, 0, 1, &[i], &p, 0.1, &mut spikes);
            for g in [s.m[0], s.h[0], s.n[0]] {
                assert!((0.0..=1.0).contains(&g), "gate {g} out of range");
            }
        }
    }
}
