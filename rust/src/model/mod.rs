//! Neuron and synapse models.
//!
//! - [`lif`] — leaky integrate-and-fire with exponential post-synaptic
//!   currents, advanced by exact integration (Rotter & Diesmann 1999).
//!   The Rust implementation mirrors the L1 Pallas kernel formula-for-
//!   formula; `rust/tests/lif_fixtures.rs` replays python-generated
//!   trajectories to prove both sides agree to f64 round-off.
//! - [`stdp`] — spike-timing-dependent plasticity with multiplicative
//!   depression and power-law potentiation (Morrison et al. 2007), the
//!   rule of the paper's verification case (NEST hpc_benchmark).
//! - [`poisson`] — deterministic, decomposition-independent Poisson drive:
//!   every (neuron, step) pair derives its own counter-based PRNG stream,
//!   so the generated noise is identical regardless of how neurons are
//!   mapped to ranks/threads. This is what makes CORTEX and the NEST-style
//!   baseline *spike-exact* comparable (stronger than the paper's
//!   statistical comparison, where simulator RNGs differ).
//! - [`hh`] / [`adex`] — Hodgkin-Huxley and adaptive-exponential
//!   neurons: the higher compute-intensity models of the paper's §I.C
//!   computation/communication-ratio discussion (refs [31], [22]),
//!   quantified by `benches/ablation_intensity.rs` and runnable as
//!   network populations through [`dynamics`].
//! - [`dynamics`] — the model-generic layer: per-population SoA state
//!   blocks ([`dynamics::PopulationState`]) behind one enum-dispatched
//!   interface, so the execution core steps heterogeneous circuits
//!   (LIF / AdEx / HH / parrot relays) without knowing any model.

pub mod adex;
pub mod dynamics;
pub mod hh;
pub mod lif;
pub mod poisson;
pub mod stdp;

pub use adex::{AdexParams, AdexState};
pub use dynamics::{ModelParams, ModelTables, NeuronModel, PopulationState};
pub use hh::{HhParams, HhState};
pub use lif::{LifParams, LifState, Propagators};
pub use poisson::PoissonDrive;
pub use stdp::{StdpParams, TraceSet};
