//! Adaptive exponential integrate-and-fire neuron (Brette & Gerstner
//! 2005 — the paper's ref [22], cited alongside LIF as the lightweight
//! modeling family its evaluation builds on). Intermediate compute
//! intensity between LIF and Hodgkin-Huxley; completes the
//! `ablation_intensity` sweep of the paper's §I.C argument.
//!
//! dV/dt = (-g_L(V-E_L) + g_L·ΔT·exp((V-V_T)/ΔT) - w + I) / C
//! dw/dt = (a(V-E_L) - w) / τ_w ;  on spike: V→V_r, w→w+b

/// AdEx parameters (Brette & Gerstner 2005, regular-spiking defaults).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AdexParams {
    pub c_m: f64,     // [pF]
    pub g_l: f64,     // [nS]
    pub e_l: f64,     // [mV]
    pub v_t: f64,     // rheobase threshold [mV]
    pub delta_t: f64, // slope factor [mV]
    pub tau_w: f64,   // adaptation time constant [ms]
    pub a: f64,       // subthreshold adaptation [nS]
    pub b: f64,       // spike-triggered adaptation [pA]
    pub v_reset: f64, // [mV]
    pub v_peak: f64,  // numerical spike cutoff [mV]
    pub t_ref: f64,   // refractory period [ms]
}

impl Default for AdexParams {
    fn default() -> Self {
        AdexParams {
            c_m: 281.0,
            g_l: 30.0,
            e_l: -70.6,
            v_t: -50.4,
            delta_t: 2.0,
            tau_w: 144.0,
            a: 4.0,
            b: 80.5,
            v_reset: -70.6,
            v_peak: 0.0,
            t_ref: 2.0,
        }
    }
}

/// SoA state for a block of AdEx neurons.
#[derive(Clone, Debug)]
pub struct AdexState {
    pub v: Vec<f64>,
    pub w: Vec<f64>,
    pub refrac: Vec<f64>,
}

impl AdexState {
    pub fn new(n: usize, p: &AdexParams) -> Self {
        AdexState {
            v: vec![p.e_l; n],
            w: vec![0.0; n],
            refrac: vec![0.0; n],
        }
    }

    pub fn len(&self) -> usize {
        self.v.len()
    }

    pub fn is_empty(&self) -> bool {
        self.v.is_empty()
    }
}

/// Advance neurons `[lo, hi)` one step of `dt_ms` with input currents
/// `i_in` [pA]; local spike indices are appended.
pub fn step_slice(
    state: &mut AdexState,
    lo: usize,
    hi: usize,
    i_in: &[f64],
    p: &AdexParams,
    dt_ms: f64,
    spikes: &mut Vec<u32>,
) {
    let ref_steps = (p.t_ref / dt_ms).round();
    for i in lo..hi {
        if state.refrac[i] > 0.0 {
            state.refrac[i] -= 1.0;
            state.v[i] = p.v_reset;
            // adaptation keeps integrating during refractoriness
            let w = state.w[i];
            state.w[i] =
                w + dt_ms * (p.a * (p.v_reset - p.e_l) - w) / p.tau_w;
            continue;
        }
        let v = state.v[i];
        let w = state.w[i];
        // exponential term clamped to keep the forward-Euler step finite
        let exp_arg = ((v - p.v_t) / p.delta_t).min(20.0);
        let dv = (-p.g_l * (v - p.e_l)
            + p.g_l * p.delta_t * exp_arg.exp()
            - w
            + i_in[i - lo])
            / p.c_m;
        let dw = (p.a * (v - p.e_l) - w) / p.tau_w;
        let mut v_new = v + dt_ms * dv;
        let w_new = w + dt_ms * dw;
        if v_new >= p.v_peak {
            spikes.push((i - lo) as u32);
            v_new = p.v_reset;
            state.w[i] = w_new + p.b;
            state.refrac[i] = ref_steps;
        } else {
            state.w[i] = w_new;
        }
        state.v[i] = v_new;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rest_is_stable() {
        let p = AdexParams::default();
        let mut s = AdexState::new(3, &p);
        let mut spikes = Vec::new();
        for _ in 0..2000 {
            step_slice(&mut s, 0, 3, &[0.0; 3], &p, 0.1, &mut spikes);
        }
        assert!(spikes.is_empty());
        assert!((s.v[0] - p.e_l).abs() < 0.5);
        assert!(s.w[0].abs() < 1.0);
    }

    #[test]
    fn step_current_produces_adapting_train() {
        let p = AdexParams::default();
        let mut s = AdexState::new(1, &p);
        let mut when = Vec::new();
        for t in 0..20_000 {
            let mut spikes = Vec::new();
            step_slice(&mut s, 0, 1, &[700.0], &p, 0.1, &mut spikes);
            if !spikes.is_empty() {
                when.push(t);
            }
        }
        assert!(when.len() >= 4, "only {} spikes", when.len());
        // spike-frequency adaptation: ISIs grow
        let first_isi = when[1] - when[0];
        let last_isi = when[when.len() - 1] - when[when.len() - 2];
        assert!(
            last_isi > first_isi,
            "no adaptation: {first_isi} -> {last_isi}"
        );
    }

    #[test]
    fn refractory_holds_reset() {
        let p = AdexParams::default();
        let mut s = AdexState::new(1, &p);
        s.v[0] = p.v_peak + 1.0;
        let mut spikes = Vec::new();
        step_slice(&mut s, 0, 1, &[0.0], &p, 0.1, &mut spikes);
        assert_eq!(spikes.len(), 1);
        for _ in 0..(p.t_ref / 0.1) as usize {
            let mut sp = Vec::new();
            step_slice(&mut s, 0, 1, &[1e5], &p, 0.1, &mut sp);
            assert!(sp.is_empty());
            assert_eq!(s.v[0], p.v_reset);
        }
    }

    #[test]
    fn spike_increments_adaptation() {
        let p = AdexParams::default();
        let mut s = AdexState::new(1, &p);
        s.v[0] = p.v_peak + 1.0;
        let w0 = s.w[0];
        let mut spikes = Vec::new();
        step_slice(&mut s, 0, 1, &[0.0], &p, 0.1, &mut spikes);
        assert!(s.w[0] >= w0 + p.b * 0.9);
    }

    #[test]
    fn exp_clamp_keeps_values_finite() {
        let p = AdexParams::default();
        let mut s = AdexState::new(1, &p);
        s.v[0] = -20.0; // deep into the exponential regime
        let mut spikes = Vec::new();
        for _ in 0..100 {
            step_slice(&mut s, 0, 1, &[0.0], &p, 0.1, &mut spikes);
            assert!(s.v[0].is_finite() && s.w[0].is_finite());
        }
    }
}
