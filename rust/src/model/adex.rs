//! Adaptive exponential integrate-and-fire neuron (Brette & Gerstner
//! 2005 — the paper's ref [22], cited alongside LIF as the lightweight
//! modeling family its evaluation builds on). Intermediate compute
//! intensity between LIF and Hodgkin-Huxley; completes the
//! `ablation_intensity` sweep of the paper's §I.C argument and, through
//! the model-generic dynamics layer, runs as a first-class network
//! population model.
//!
//! dV/dt = (-g_L(V-E_L) + g_L·ΔT·exp((V-V_T)/ΔT) - w + I_syn + I_ext) / C
//! dw/dt = (a(V-E_L) - w) / τ_w ;  on spike: V→V_r, w→w+b
//!
//! Synaptic input follows the engine's LIF convention: arriving weights
//! [pA] land in exponentially-decaying excitatory/inhibitory currents
//! (`ie`/`ii`, time constants `tau_syn_ex`/`tau_syn_in`), with the same
//! update order as `lif::step_slice` — membrane first, then current
//! decay, then this step's input lands (visible from the next step on).

/// AdEx parameters (Brette & Gerstner 2005, regular-spiking defaults).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AdexParams {
    pub c_m: f64,        // [pF]
    pub g_l: f64,        // [nS]
    pub e_l: f64,        // [mV]
    pub v_t: f64,        // rheobase threshold [mV]
    pub delta_t: f64,    // slope factor [mV]
    pub tau_w: f64,      // adaptation time constant [ms]
    pub a: f64,          // subthreshold adaptation [nS]
    pub b: f64,          // spike-triggered adaptation [pA]
    pub v_reset: f64,    // [mV]
    pub v_peak: f64,     // numerical spike cutoff [mV]
    pub t_ref: f64,      // refractory period [ms]
    pub tau_syn_ex: f64, // excitatory synaptic time constant [ms]
    pub tau_syn_in: f64, // inhibitory synaptic time constant [ms]
    pub i_ext: f64,      // constant external current [pA]
}

impl Default for AdexParams {
    fn default() -> Self {
        AdexParams {
            c_m: 281.0,
            g_l: 30.0,
            e_l: -70.6,
            v_t: -50.4,
            delta_t: 2.0,
            tau_w: 144.0,
            a: 4.0,
            b: 80.5,
            v_reset: -70.6,
            v_peak: 0.0,
            t_ref: 2.0,
            tau_syn_ex: 0.5,
            tau_syn_in: 0.5,
            i_ext: 0.0,
        }
    }
}

/// SoA state for a block of AdEx neurons.
#[derive(Clone, Debug)]
pub struct AdexState {
    pub v: Vec<f64>,
    pub w: Vec<f64>,
    pub refrac: Vec<f64>,
    /// Excitatory / inhibitory synaptic currents [pA].
    pub ie: Vec<f64>,
    pub ii: Vec<f64>,
}

impl AdexState {
    pub fn new(n: usize, p: &AdexParams) -> Self {
        AdexState {
            v: vec![p.e_l; n],
            w: vec![0.0; n],
            refrac: vec![0.0; n],
            ie: vec![0.0; n],
            ii: vec![0.0; n],
        }
    }

    pub fn len(&self) -> usize {
        self.v.len()
    }

    pub fn is_empty(&self) -> bool {
        self.v.is_empty()
    }
}

/// Advance neurons `[lo, hi)` one step of `dt_ms`. `in_e` / `in_i` are
/// this step's arriving synaptic weights [pA] for the same index range;
/// local spike indices (relative to `lo`) are appended.
#[allow(clippy::too_many_arguments)]
pub fn step_slice(
    state: &mut AdexState,
    lo: usize,
    hi: usize,
    in_e: &[f64],
    in_i: &[f64],
    p: &AdexParams,
    dt_ms: f64,
    spikes: &mut Vec<u32>,
) {
    debug_assert!(hi <= state.len());
    debug_assert_eq!(in_e.len(), hi - lo);
    debug_assert_eq!(in_i.len(), hi - lo);
    let ref_steps = (p.t_ref / dt_ms).round();
    let de = (-dt_ms / p.tau_syn_ex).exp();
    let di = (-dt_ms / p.tau_syn_in).exp();
    for i in lo..hi {
        let ie = state.ie[i];
        let ii = state.ii[i];
        if state.refrac[i] > 0.0 {
            state.refrac[i] -= 1.0;
            state.v[i] = p.v_reset;
            // adaptation keeps integrating during refractoriness
            let w = state.w[i];
            state.w[i] =
                w + dt_ms * (p.a * (p.v_reset - p.e_l) - w) / p.tau_w;
        } else {
            let v = state.v[i];
            let w = state.w[i];
            // exponential term clamped to keep the forward-Euler step finite
            let exp_arg = ((v - p.v_t) / p.delta_t).min(20.0);
            let dv = (-p.g_l * (v - p.e_l)
                + p.g_l * p.delta_t * exp_arg.exp()
                - w
                + ie
                + ii
                + p.i_ext)
                / p.c_m;
            let dw = (p.a * (v - p.e_l) - w) / p.tau_w;
            let mut v_new = v + dt_ms * dv;
            let w_new = w + dt_ms * dw;
            if v_new >= p.v_peak {
                spikes.push((i - lo) as u32);
                v_new = p.v_reset;
                state.w[i] = w_new + p.b;
                state.refrac[i] = ref_steps;
            } else {
                state.w[i] = w_new;
            }
            state.v[i] = v_new;
        }
        // currents decay, then input lands (LIF ordering)
        state.ie[i] = ie * de + in_e[i - lo];
        state.ii[i] = ii * di + in_i[i - lo];
    }
}

/// Branch-free twin of [`step_slice`] — bit-identical by construction
/// (`engine.integrate = "vector"`, the default).
///
/// Both the refractory arm and the free evolution are computed for every
/// neuron, then selected by mask; the `exp_arg.min(20.0)` clamp keeps the
/// speculative exponential finite even for held-at-reset membranes, so the
/// discarded arm can never trap or poison the kept one. Each arm keeps
/// the scalar kernel's exact f64 operation order (the refractory `w`
/// update divides *after* the `dt` multiply; the free arm divides before
/// — they are not the same rounding, so both are preserved verbatim).
/// Spikes land in a stack mask chunk and compact into `spikes` in a
/// separate ascending pass.
#[allow(clippy::too_many_arguments)]
pub fn step_slice_vector(
    state: &mut AdexState,
    lo: usize,
    hi: usize,
    in_e: &[f64],
    in_i: &[f64],
    p: &AdexParams,
    dt_ms: f64,
    spikes: &mut Vec<u32>,
) {
    use super::lif::MASK_CHUNK;
    debug_assert!(hi <= state.len());
    debug_assert_eq!(in_e.len(), hi - lo);
    debug_assert_eq!(in_i.len(), hi - lo);
    let ref_steps = (p.t_ref / dt_ms).round();
    let de = (-dt_ms / p.tau_syn_ex).exp();
    let di = (-dt_ms / p.tau_syn_in).exp();
    let AdexState { v, w, refrac, ie, ii } = state;
    let mut mask = [false; MASK_CHUNK];
    let mut c_lo = lo;
    while c_lo < hi {
        let c_hi = (c_lo + MASK_CHUNK).min(hi);
        for i in c_lo..c_hi {
            let ce = ie[i];
            let ci = ii[i];
            let vm = v[i];
            let wm = w[i];
            let r = refrac[i];
            // refractory arm: adaptation integrates against held reset
            let w_ref = wm
                + dt_ms * (p.a * (p.v_reset - p.e_l) - wm) / p.tau_w;
            // free arm: forward-Euler with clamped exponential
            let exp_arg = ((vm - p.v_t) / p.delta_t).min(20.0);
            let dv = (-p.g_l * (vm - p.e_l)
                + p.g_l * p.delta_t * exp_arg.exp()
                - wm
                + ce
                + ci
                + p.i_ext)
                / p.c_m;
            let dw = (p.a * (vm - p.e_l) - wm) / p.tau_w;
            let v_cand = vm + dt_ms * dv;
            let w_free = wm + dt_ms * dw;
            let refr = r > 0.0;
            let spike = !refr && v_cand >= p.v_peak;
            v[i] = if refr || spike { p.v_reset } else { v_cand };
            w[i] = if refr {
                w_ref
            } else if spike {
                w_free + p.b
            } else {
                w_free
            };
            refrac[i] = if refr {
                r - 1.0
            } else if spike {
                ref_steps
            } else {
                r
            };
            ie[i] = ce * de + in_e[i - lo];
            ii[i] = ci * di + in_i[i - lo];
            mask[i - c_lo] = spike;
        }
        for (j, &fired) in mask[..c_hi - c_lo].iter().enumerate() {
            if fired {
                spikes.push((c_lo + j - lo) as u32);
            }
        }
        c_lo = c_hi;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rest_is_stable() {
        let p = AdexParams::default();
        let mut s = AdexState::new(3, &p);
        let mut spikes = Vec::new();
        for _ in 0..2000 {
            step_slice(
                &mut s, 0, 3, &[0.0; 3], &[0.0; 3], &p, 0.1, &mut spikes,
            );
        }
        assert!(spikes.is_empty());
        assert!((s.v[0] - p.e_l).abs() < 0.5);
        assert!(s.w[0].abs() < 1.0);
    }

    #[test]
    fn step_current_produces_adapting_train() {
        let p = AdexParams { i_ext: 700.0, ..Default::default() };
        let mut s = AdexState::new(1, &p);
        let mut when = Vec::new();
        for t in 0..20_000 {
            let mut spikes = Vec::new();
            step_slice(&mut s, 0, 1, &[0.0], &[0.0], &p, 0.1, &mut spikes);
            if !spikes.is_empty() {
                when.push(t);
            }
        }
        assert!(when.len() >= 4, "only {} spikes", when.len());
        // spike-frequency adaptation: ISIs grow
        let first_isi = when[1] - when[0];
        let last_isi = when[when.len() - 1] - when[when.len() - 2];
        assert!(
            last_isi > first_isi,
            "no adaptation: {first_isi} -> {last_isi}"
        );
    }

    #[test]
    fn refractory_holds_reset() {
        let p = AdexParams::default();
        let mut s = AdexState::new(1, &p);
        s.v[0] = p.v_peak + 1.0;
        let mut spikes = Vec::new();
        step_slice(&mut s, 0, 1, &[0.0], &[0.0], &p, 0.1, &mut spikes);
        assert_eq!(spikes.len(), 1);
        for _ in 0..(p.t_ref / 0.1) as usize {
            let mut sp = Vec::new();
            step_slice(&mut s, 0, 1, &[1e5], &[0.0], &p, 0.1, &mut sp);
            assert!(sp.is_empty());
            assert_eq!(s.v[0], p.v_reset);
        }
    }

    #[test]
    fn spike_increments_adaptation() {
        let p = AdexParams::default();
        let mut s = AdexState::new(1, &p);
        s.v[0] = p.v_peak + 1.0;
        let w0 = s.w[0];
        let mut spikes = Vec::new();
        step_slice(&mut s, 0, 1, &[0.0], &[0.0], &p, 0.1, &mut spikes);
        assert!(s.w[0] >= w0 + p.b * 0.9);
    }

    #[test]
    fn exp_clamp_keeps_values_finite() {
        let p = AdexParams::default();
        let mut s = AdexState::new(1, &p);
        s.v[0] = -20.0; // deep into the exponential regime
        let mut spikes = Vec::new();
        for _ in 0..100 {
            step_slice(&mut s, 0, 1, &[0.0], &[0.0], &p, 0.1, &mut spikes);
            assert!(s.v[0].is_finite() && s.w[0].is_finite());
        }
    }

    #[test]
    fn synaptic_input_lands_after_integration() {
        // input delivered at step t must not affect v at step t (only t+1)
        let p = AdexParams::default();
        let mut a = AdexState::new(1, &p);
        let mut b = AdexState::new(1, &p);
        let mut sp = Vec::new();
        step_slice(&mut a, 0, 1, &[500.0], &[0.0], &p, 0.1, &mut sp);
        step_slice(&mut b, 0, 1, &[0.0], &[0.0], &p, 0.1, &mut sp);
        assert_eq!(a.v[0], b.v[0], "v must be unaffected in the same step");
        assert_ne!(a.ie[0], b.ie[0]);
        step_slice(&mut a, 0, 1, &[0.0], &[0.0], &p, 0.1, &mut sp);
        step_slice(&mut b, 0, 1, &[0.0], &[0.0], &p, 0.1, &mut sp);
        assert!(a.v[0] > b.v[0], "EPSC should depolarise on the next step");
    }

    #[test]
    fn sustained_synaptic_bombardment_fires() {
        let p = AdexParams::default();
        let mut s = AdexState::new(1, &p);
        let mut total = 0usize;
        for _ in 0..5000 {
            let mut sp = Vec::new();
            step_slice(&mut s, 0, 1, &[130.0], &[0.0], &p, 0.1, &mut sp);
            total += sp.len();
        }
        // steady EPSC ≈ 130 pA / (1 - e^{-0.2}) ≈ 717 pA, above the
        // ~630 pA adaptation-corrected rheobase
        assert!(total >= 2, "only {total} spikes under bombardment");
    }

    #[test]
    fn slice_bounds_respected() {
        let p = AdexParams { i_ext: 1000.0, ..Default::default() };
        let mut s = AdexState::new(4, &p);
        let before = s.v.clone();
        let mut sp = Vec::new();
        step_slice(&mut s, 1, 3, &[0.0; 2], &[0.0; 2], &p, 0.1, &mut sp);
        assert_eq!(s.v[0], before[0]);
        assert_eq!(s.v[3], before[3]);
        assert_ne!(s.v[1], before[1]);
        assert_ne!(s.v[2], before[2]);
    }

    #[test]
    fn vector_kernel_bit_identical_to_scalar() {
        // spiking + refractory + adaptation across a multi-chunk block
        let p = AdexParams { i_ext: 700.0, ..Default::default() };
        let n = 2 * super::super::lif::MASK_CHUNK + 9;
        let mut a = AdexState::new(n, &p);
        let mut b = AdexState::new(n, &p);
        for i in 0..n {
            a.v[i] = p.e_l + (i % 29) as f64;
            b.v[i] = a.v[i];
        }
        for step in 0..1500u64 {
            let ine: Vec<f64> = (0..n)
                .map(|i| ((i as u64 * 17 + step * 5) % 9) as f64 * 30.0)
                .collect();
            let ini: Vec<f64> = (0..n)
                .map(|i| ((i as u64 * 7 + step * 11) % 5) as f64 * -20.0)
                .collect();
            let mut sa = Vec::new();
            let mut sb = Vec::new();
            step_slice(&mut a, 0, n, &ine, &ini, &p, 0.1, &mut sa);
            step_slice_vector(&mut b, 0, n, &ine, &ini, &p, 0.1, &mut sb);
            assert_eq!(sa, sb, "spikes diverged at step {step}");
            assert_eq!(a.v, b.v, "v diverged at step {step}");
            assert_eq!(a.w, b.w, "w diverged at step {step}");
            assert_eq!(a.refrac, b.refrac);
            assert_eq!(a.ie, b.ie);
            assert_eq!(a.ii, b.ii);
        }
    }
}
