//! Model-generic dynamics layer: heterogeneous neuron populations behind
//! one enum-dispatched SoA interface.
//!
//! The indegree sub-graph decomposition is model-agnostic by design —
//! thread-owned post blocks can run any point-neuron dynamics without
//! races — so the execution core should not be hard-wired to LIF. This
//! module is the seam: a [`PopulationState`] is one contiguous block of
//! neurons sharing a neuron model (CoreNEURON-style per-mechanism SoA
//! dispatch: the *outer* loop switches on the model once per block, the
//! per-model inner loops stay branch-free SoA kernels).
//!
//! Supported models:
//! - [`super::lif`]  — LIF with exact integration (the paper's workload);
//! - [`super::adex`] — adaptive exponential IF (Brette & Gerstner 2005);
//! - [`super::hh`]   — Hodgkin-Huxley (high compute intensity, §I.C);
//! - parrot          — a stateless relay that fires whenever excitatory
//!   input arrives (stimulus/virtual layers, NEST `parrot_neuron` style).
//!
//! Every model consumes the same per-step inputs the engine stages:
//! the due excitatory/inhibitory ring slots plus Poisson drive
//! (`in_e`/`in_i`, weights in pA), and reports spikes as local indices
//! into the worker's span — STDP and spike collection key off that
//! generic spike event, never off model internals.

use crate::config::IntegrateMode;
use crate::metrics::memory::vec_bytes;

use super::adex::{self, AdexParams, AdexState};
use super::hh::{self, HhParams, HhState};
use super::lif::{self, LifParams, LifState, Propagators};

/// Which point-neuron model a population runs (the config-level name).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NeuronModel {
    Lif,
    Adex,
    Hh,
    Parrot,
}

impl NeuronModel {
    /// Number of model kinds (size of per-model accounting arrays).
    pub const COUNT: usize = 4;

    /// All models, in [`Self::index`] order.
    pub const ALL: [NeuronModel; NeuronModel::COUNT] = [
        NeuronModel::Lif,
        NeuronModel::Adex,
        NeuronModel::Hh,
        NeuronModel::Parrot,
    ];

    /// Stable small index for per-model accounting arrays.
    pub fn index(&self) -> usize {
        match self {
            NeuronModel::Lif => 0,
            NeuronModel::Adex => 1,
            NeuronModel::Hh => 2,
            NeuronModel::Parrot => 3,
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            NeuronModel::Lif => "lif",
            NeuronModel::Adex => "adex",
            NeuronModel::Hh => "hh",
            NeuronModel::Parrot => "parrot",
        }
    }

    pub fn parse(s: &str) -> Option<NeuronModel> {
        match s {
            "lif" => Some(NeuronModel::Lif),
            "adex" => Some(NeuronModel::Adex),
            "hh" => Some(NeuronModel::Hh),
            "parrot" => Some(NeuronModel::Parrot),
            _ => None,
        }
    }
}

/// One entry of a network's parameter table: the model plus its
/// parameters. Populations reference entries by index (`Population::
/// params`), so mixed circuits are just tables with mixed variants.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ModelParams {
    Lif(LifParams),
    Adex(AdexParams),
    Hh(HhParams),
    Parrot,
}

impl ModelParams {
    pub fn model(&self) -> NeuronModel {
        match self {
            ModelParams::Lif(_) => NeuronModel::Lif,
            ModelParams::Adex(_) => NeuronModel::Adex,
            ModelParams::Hh(_) => NeuronModel::Hh,
            ModelParams::Parrot => NeuronModel::Parrot,
        }
    }

    /// Resting potential the initial-state jitter is applied around.
    pub fn rest_potential(&self) -> f64 {
        match self {
            ModelParams::Lif(p) => p.e_l,
            ModelParams::Adex(p) => p.e_l,
            ModelParams::Hh(_) => hh::V_REST,
            ModelParams::Parrot => 0.0,
        }
    }

    /// This parameter set with `dc_pa` added to its constant external
    /// current — the session API's per-population DC drive (a LIF block
    /// folds it into its exact-integration propagators, AdEx/HH into
    /// their `i_ext` term). `None` for parrot relays, which carry no
    /// membrane current.
    pub fn with_dc(&self, dc_pa: f64) -> Option<ModelParams> {
        match *self {
            ModelParams::Lif(p) => Some(ModelParams::Lif(LifParams {
                i_ext: p.i_ext + dc_pa,
                ..p
            })),
            ModelParams::Adex(p) => Some(ModelParams::Adex(AdexParams {
                i_ext: p.i_ext + dc_pa,
                ..p
            })),
            ModelParams::Hh(p) => Some(ModelParams::Hh(HhParams {
                i_ext: p.i_ext + dc_pa,
                ..p
            })),
            ModelParams::Parrot => None,
        }
    }

    /// Exact per-neuron heap bytes of the model's SoA state (for the
    /// analytic memory accounting before the live blocks exist).
    pub fn state_bytes_per_neuron(&self) -> u64 {
        match self {
            // u, ie, ii, refrac (f64) + pidx (u8)
            ModelParams::Lif(_) => 4 * 8 + 1,
            // v, w, refrac, ie, ii
            ModelParams::Adex(_) => 5 * 8,
            // v, m, h, n, v_prev, ie, ii
            ModelParams::Hh(_) => 7 * 8,
            ModelParams::Parrot => 0,
        }
    }
}

/// Stateless relay block: fires whenever excitatory input (ring slot +
/// Poisson drive) arrives this step.
#[derive(Clone, Debug)]
pub struct ParrotState {
    pub n: usize,
}

/// Read-only dispatch tables every worker carries: the step size, the
/// LIF propagator table (indexed by params index, like the parameter
/// table itself) and the parameter table for the direct-parameter models.
#[derive(Clone, Debug)]
pub struct ModelTables {
    pub dt_ms: f64,
    pub lif_props: Vec<Propagators>,
    pub params: Vec<ModelParams>,
}

impl ModelTables {
    /// Intern `p`, returning its table index; both tables stay aligned.
    /// Identical entries collapse (so resetting a session's DC drive to
    /// zero lands back on the population's original slot, and repeated
    /// sweeps over the same values cost nothing). Used by the engine's
    /// mid-run stimulus mutation — per-worker tables are owned copies,
    /// so interning never races. Returns `None` when the u8-indexed
    /// table is full (255 distinct parameter sets); callers surface
    /// that as a recoverable error rather than a panic.
    pub fn intern(&mut self, p: ModelParams) -> Option<u8> {
        if let Some(i) = self.params.iter().position(|q| *q == p) {
            return Some(i as u8);
        }
        if self.params.len() >= u8::MAX as usize {
            return None;
        }
        self.lif_props.push(match &p {
            ModelParams::Lif(lp) => Propagators::new(lp, self.dt_ms),
            _ => Propagators::new(&LifParams::default(), self.dt_ms),
        });
        self.params.push(p);
        Some((self.params.len() - 1) as u8)
    }
}

/// SoA dynamical state of one contiguous block of neurons sharing a
/// neuron model. The engine's integrate phase walks a worker's blocks
/// and dispatches once per block; everything inside is branch-free SoA.
#[derive(Clone, Debug)]
pub enum PopulationState {
    Lif(LifState),
    Adex(AdexState),
    Hh(HhState),
    Parrot(ParrotState),
}

impl PopulationState {
    /// Fresh resting-state block of `n` neurons of parameter set `pidx`.
    pub fn new(tables: &ModelTables, pidx: u8, n: usize) -> PopulationState {
        match &tables.params[pidx as usize] {
            ModelParams::Lif(_) => PopulationState::Lif(LifState::new(
                n,
                &tables.lif_props,
                vec![pidx; n],
            )),
            ModelParams::Adex(p) => {
                PopulationState::Adex(AdexState::new(n, p))
            }
            ModelParams::Hh(_) => PopulationState::Hh(HhState::new(n)),
            ModelParams::Parrot => {
                PopulationState::Parrot(ParrotState { n })
            }
        }
    }

    pub fn model(&self) -> NeuronModel {
        match self {
            PopulationState::Lif(_) => NeuronModel::Lif,
            PopulationState::Adex(_) => NeuronModel::Adex,
            PopulationState::Hh(_) => NeuronModel::Hh,
            PopulationState::Parrot(_) => NeuronModel::Parrot,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            PopulationState::Lif(s) => s.len(),
            PopulationState::Adex(s) => s.len(),
            PopulationState::Hh(s) => s.len(),
            PopulationState::Parrot(s) => s.n,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Heap footprint in bytes.
    pub fn bytes(&self) -> u64 {
        match self {
            PopulationState::Lif(s) => s.bytes(),
            PopulationState::Adex(s) => {
                vec_bytes(&s.v)
                    + vec_bytes(&s.w)
                    + vec_bytes(&s.refrac)
                    + vec_bytes(&s.ie)
                    + vec_bytes(&s.ii)
            }
            PopulationState::Hh(s) => {
                vec_bytes(&s.v)
                    + vec_bytes(&s.m)
                    + vec_bytes(&s.h)
                    + vec_bytes(&s.n)
                    + vec_bytes(&s.v_prev)
                    + vec_bytes(&s.ie)
                    + vec_bytes(&s.ii)
            }
            PopulationState::Parrot(_) => 0,
        }
    }

    /// Membrane potential of neuron `i` (`None` for parrot relays, which
    /// have no membrane). Read-only observation hook for voltage probes.
    pub fn voltage(&self, i: usize) -> Option<f64> {
        match self {
            PopulationState::Lif(s) => Some(s.u[i]),
            PopulationState::Adex(s) => Some(s.v[i]),
            PopulationState::Hh(s) => Some(s.v[i]),
            PopulationState::Parrot(_) => None,
        }
    }

    /// Set neuron `i`'s initial membrane potential (no-op for parrots;
    /// HH gates are re-seeded to their steady state at that voltage).
    pub fn set_v_init(&mut self, i: usize, v: f64) {
        match self {
            PopulationState::Lif(s) => s.u[i] = v,
            PopulationState::Adex(s) => s.v[i] = v,
            PopulationState::Hh(s) => hh::init_at(s, i, v),
            PopulationState::Parrot(_) => {}
        }
    }

    /// Advance the whole block one step. `in_e` / `in_i` are this step's
    /// arriving synaptic input (plus drive) for the block's neurons;
    /// spikes are appended as indices relative to the worker span
    /// (`offset` is the block's position within it). `mode` selects the
    /// branch-free vector kernels or the scalar ablation — the two are
    /// bit-identical, so the knob only moves time, never results.
    pub fn step_block(
        &mut self,
        in_e: &[f64],
        in_i: &[f64],
        tables: &ModelTables,
        pidx: u8,
        offset: u32,
        mode: IntegrateMode,
        spikes: &mut Vec<u32>,
    ) {
        let base = spikes.len();
        match self {
            PopulationState::Lif(s) => {
                let n = s.len();
                match mode {
                    IntegrateMode::Vector => lif::step_slice_vector(
                        s,
                        0,
                        n,
                        in_e,
                        in_i,
                        &tables.lif_props,
                        spikes,
                    ),
                    IntegrateMode::Scalar => lif::step_slice(
                        s,
                        0,
                        n,
                        in_e,
                        in_i,
                        &tables.lif_props,
                        spikes,
                    ),
                }
            }
            PopulationState::Adex(s) => {
                let ModelParams::Adex(p) = &tables.params[pidx as usize]
                else {
                    unreachable!("adex block with non-adex params")
                };
                let n = s.len();
                match mode {
                    IntegrateMode::Vector => adex::step_slice_vector(
                        s,
                        0,
                        n,
                        in_e,
                        in_i,
                        p,
                        tables.dt_ms,
                        spikes,
                    ),
                    IntegrateMode::Scalar => adex::step_slice(
                        s,
                        0,
                        n,
                        in_e,
                        in_i,
                        p,
                        tables.dt_ms,
                        spikes,
                    ),
                }
            }
            PopulationState::Hh(s) => {
                let ModelParams::Hh(p) = &tables.params[pidx as usize]
                else {
                    unreachable!("hh block with non-hh params")
                };
                let n = s.len();
                match mode {
                    IntegrateMode::Vector => hh::step_slice_vector(
                        s,
                        0,
                        n,
                        in_e,
                        in_i,
                        p,
                        tables.dt_ms,
                        spikes,
                    ),
                    IntegrateMode::Scalar => hh::step_slice(
                        s,
                        0,
                        n,
                        in_e,
                        in_i,
                        p,
                        tables.dt_ms,
                        spikes,
                    ),
                }
            }
            PopulationState::Parrot(s) => {
                for (i, &e) in in_e.iter().take(s.n).enumerate() {
                    if e > 0.0 {
                        spikes.push(i as u32);
                    }
                }
            }
        }
        if offset != 0 {
            for s in &mut spikes[base..] {
                *s += offset;
            }
        }
    }

    // -- checkpoint views ------------------------------------------------
    // Static structure (pidx, gate layout) regenerates from the spec;
    // only the evolving f64 fields are serialized, in a fixed per-model
    // order behind a model tag.

    pub fn checkpoint_tag(&self) -> u64 {
        match self {
            PopulationState::Lif(_) => 1,
            PopulationState::Adex(_) => 2,
            PopulationState::Hh(_) => 3,
            PopulationState::Parrot(_) => 4,
        }
    }

    pub fn n_fields(&self) -> usize {
        self.field_slices().len()
    }

    /// The evolving fields, in checkpoint order. Must list the same
    /// fields in the same order as the private `field_vecs_mut`; the
    /// `checkpoint_fields_round_trip` test writes through one and reads
    /// through the other to keep the two in sync.
    pub fn field_slices(&self) -> Vec<&[f64]> {
        match self {
            PopulationState::Lif(s) => {
                vec![&s.u, &s.ie, &s.ii, &s.refrac]
            }
            PopulationState::Adex(s) => {
                vec![&s.v, &s.w, &s.refrac, &s.ie, &s.ii]
            }
            PopulationState::Hh(s) => {
                vec![&s.v, &s.m, &s.h, &s.n, &s.v_prev, &s.ie, &s.ii]
            }
            PopulationState::Parrot(_) => Vec::new(),
        }
    }

    /// Mutable twin of [`Self::field_slices`] (same fields, same order).
    fn field_vecs_mut(&mut self) -> Vec<&mut Vec<f64>> {
        match self {
            PopulationState::Lif(s) => {
                vec![&mut s.u, &mut s.ie, &mut s.ii, &mut s.refrac]
            }
            PopulationState::Adex(s) => {
                vec![&mut s.v, &mut s.w, &mut s.refrac, &mut s.ie, &mut s.ii]
            }
            PopulationState::Hh(s) => vec![
                &mut s.v,
                &mut s.m,
                &mut s.h,
                &mut s.n,
                &mut s.v_prev,
                &mut s.ie,
                &mut s.ii,
            ],
            PopulationState::Parrot(_) => Vec::new(),
        }
    }

    /// Replace field `f` (checkpoint order) with `v`; the caller has
    /// already validated the length against [`Self::len`].
    pub fn restore_field(&mut self, f: usize, v: Vec<f64>) {
        debug_assert_eq!(v.len(), self.len());
        let mut fields = self.field_vecs_mut();
        *fields[f] = v;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tables(params: Vec<ModelParams>) -> ModelTables {
        let dt_ms = 0.1;
        let lif_props = params
            .iter()
            .map(|p| match p {
                ModelParams::Lif(lp) => Propagators::new(lp, dt_ms),
                _ => Propagators::new(&LifParams::default(), dt_ms),
            })
            .collect();
        ModelTables { dt_ms, lif_props, params }
    }

    #[test]
    fn model_names_round_trip() {
        for m in [
            NeuronModel::Lif,
            NeuronModel::Adex,
            NeuronModel::Hh,
            NeuronModel::Parrot,
        ] {
            assert_eq!(NeuronModel::parse(m.as_str()), Some(m));
        }
        assert_eq!(NeuronModel::parse("izhikevich"), None);
    }

    #[test]
    fn lif_dispatch_is_bit_identical_to_direct_call() {
        // both integrate modes must reproduce the direct scalar call
        for mode in [IntegrateMode::Scalar, IntegrateMode::Vector] {
            let t = tables(vec![ModelParams::Lif(LifParams::default())]);
            let n = 64;
            let mut direct = LifState::new(n, &t.lif_props, vec![0; n]);
            let mut via = PopulationState::new(&t, 0, n);
            for i in 0..n {
                direct.u[i] = -65.0 + (i as f64) * 0.3;
                via.set_v_init(i, -65.0 + (i as f64) * 0.3);
            }
            let mut sd = Vec::new();
            let mut sv = Vec::new();
            for step in 0..200 {
                let in_e: Vec<f64> = (0..n)
                    .map(|i| ((i * 7 + step) % 11) as f64 * 30.0)
                    .collect();
                let zero = vec![0.0; n];
                lif::step_slice(
                    &mut direct, 0, n, &in_e, &zero, &t.lif_props, &mut sd,
                );
                via.step_block(&in_e, &zero, &t, 0, 0, mode, &mut sv);
            }
            assert_eq!(sd, sv, "{mode:?} changed the spike train");
            let PopulationState::Lif(s) = &via else { panic!() };
            assert_eq!(s.u, direct.u);
            assert_eq!(s.ie, direct.ie);
            assert_eq!(s.refrac, direct.refrac);
        }
    }

    #[test]
    fn spike_offsets_are_applied() {
        let t = tables(vec![ModelParams::Parrot]);
        let mut p = PopulationState::new(&t, 0, 4);
        let mut spikes = Vec::new();
        p.step_block(
            &[1.0, 0.0, 2.0, 0.0],
            &[0.0; 4],
            &t,
            0,
            100,
            IntegrateMode::Vector,
            &mut spikes,
        );
        assert_eq!(spikes, vec![100, 102]);
    }

    #[test]
    fn parrot_relays_only_excitatory_arrivals() {
        let t = tables(vec![ModelParams::Parrot]);
        let mut p = PopulationState::new(&t, 0, 3);
        let mut spikes = Vec::new();
        // inhibitory input must not fire a relay
        p.step_block(
            &[0.0; 3],
            &[-5.0; 3],
            &t,
            0,
            0,
            IntegrateMode::Vector,
            &mut spikes,
        );
        assert!(spikes.is_empty());
        p.step_block(
            &[3.0, 0.0, 0.5],
            &[0.0; 3],
            &t,
            0,
            0,
            IntegrateMode::Vector,
            &mut spikes,
        );
        assert_eq!(spikes, vec![0, 2]);
        assert_eq!(p.bytes(), 0);
    }

    #[test]
    fn adex_and_hh_blocks_step_and_spike() {
        let t = tables(vec![
            ModelParams::Adex(AdexParams {
                i_ext: 800.0,
                ..Default::default()
            }),
            ModelParams::Hh(HhParams { i_ext: 10.0, ..Default::default() }),
        ]);
        for pidx in [0u8, 1u8] {
            let mut s = PopulationState::new(&t, pidx, 8);
            let zero = vec![0.0; 8];
            let mut spikes = Vec::new();
            for _ in 0..5000 {
                s.step_block(
                    &zero,
                    &zero,
                    &t,
                    pidx,
                    0,
                    IntegrateMode::Vector,
                    &mut spikes,
                );
            }
            assert!(
                !spikes.is_empty(),
                "{:?} block never fired under suprathreshold drive",
                s.model()
            );
            assert!(spikes.iter().all(|&x| x < 8));
        }
    }

    #[test]
    fn with_dc_offsets_i_ext_and_interns() {
        let lif = ModelParams::Lif(LifParams::default());
        let mut t = tables(vec![lif]);
        let up = lif.with_dc(120.0).unwrap();
        let ModelParams::Lif(p) = up else { panic!() };
        assert_eq!(p.i_ext, 120.0);
        assert!(ModelParams::Parrot.with_dc(1.0).is_none());
        // interning the offset params appends to both tables in step …
        assert_eq!(t.intern(up), Some(1));
        assert_eq!(t.params.len(), t.lif_props.len());
        assert_eq!(t.lif_props[1].i_ext, 120.0);
        // … and resetting to zero lands back on the original slot
        assert_eq!(t.intern(lif.with_dc(0.0).unwrap()), Some(0));
        // the u8-indexed table caps at 255 entries, gracefully
        for i in 0..300 {
            let q = lif.with_dc(1.0 + i as f64).unwrap();
            if t.intern(q).is_none() {
                assert_eq!(t.params.len(), u8::MAX as usize);
                return;
            }
        }
        panic!("intern never reported a full table");
    }

    #[test]
    fn voltage_accessor_reads_membrane() {
        let t = tables(vec![
            ModelParams::Lif(LifParams::default()),
            ModelParams::Parrot,
        ]);
        let mut s = PopulationState::new(&t, 0, 3);
        s.set_v_init(1, -55.5);
        assert_eq!(s.voltage(1), Some(-55.5));
        let p = PopulationState::new(&t, 1, 3);
        assert_eq!(p.voltage(0), None);
    }

    #[test]
    fn checkpoint_fields_round_trip() {
        let t = tables(vec![
            ModelParams::Lif(LifParams::default()),
            ModelParams::Adex(AdexParams::default()),
            ModelParams::Hh(HhParams::default()),
            ModelParams::Parrot,
        ]);
        for pidx in 0..4u8 {
            let mut s = PopulationState::new(&t, pidx, 5);
            let fields: Vec<Vec<f64>> = s
                .field_slices()
                .iter()
                .map(|f| f.iter().map(|x| x + 1.5).collect())
                .collect();
            assert_eq!(fields.len(), s.n_fields());
            for (f, v) in fields.iter().enumerate() {
                s.restore_field(f, v.clone());
            }
            for (f, v) in fields.iter().enumerate() {
                assert_eq!(s.field_slices()[f], v.as_slice());
            }
        }
    }

    #[test]
    fn state_bytes_match_layout() {
        let t = tables(vec![
            ModelParams::Lif(LifParams::default()),
            ModelParams::Adex(AdexParams::default()),
            ModelParams::Hh(HhParams::default()),
            ModelParams::Parrot,
        ]);
        for pidx in 0..4u8 {
            let n = 16;
            let s = PopulationState::new(&t, pidx, n);
            let analytic =
                t.params[pidx as usize].state_bytes_per_neuron() * n as u64;
            assert_eq!(s.bytes(), analytic, "{:?}", s.model());
        }
    }
}
