//! Spike-timing-dependent plasticity (Morrison, Aertsen & Diesmann 2007),
//! the rule of NEST's `hpc_benchmark` and of the paper's verification case:
//! multiplicative depression, power-law potentiation.
//!
//!   on pre-spike arrival :  w ← w − λ α w · x_post      (depression)
//!   on post spike        :  w ← w + λ w₀^(1−µ) w^µ · x_pre  (potentiation)
//!
//! with all-to-all exponential traces x (τ₊/τ₋ ≈ 20 ms). Both updates are
//! executed by the thread that owns the post-synaptic neuron, on edge state
//! stored with the edge — the indegree layout keeps plasticity race-free,
//! which is exactly what the paper's verification checks ("if an edge or
//! post-vertex is accessed by different threads, Abort will be called").
//!
//! Traces are maintained lazily: each neuron stores (value, last step) and
//! decays analytically on read, so quiet neurons cost nothing per step.

use crate::{Gid, Step};

/// Plasticity parameters (hpc_benchmark defaults).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct StdpParams {
    pub lambda: f64,    // learning rate
    pub alpha: f64,     // relative depression strength
    pub mu: f64,        // potentiation weight exponent
    pub tau_plus_ms: f64,
    pub tau_minus_ms: f64,
    pub w0: f64,        // reference weight [pA]
    pub w_max: f64,     // hard upper bound [pA]
}

impl Default for StdpParams {
    fn default() -> Self {
        StdpParams {
            lambda: 0.1,
            alpha: 0.057,
            mu: 0.4,
            tau_plus_ms: 15.0,
            tau_minus_ms: 30.0,
            w0: 45.0,
            w_max: 900.0,
        }
    }
}

impl StdpParams {
    /// Depression on pre-spike arrival: returns the new weight.
    #[inline]
    pub fn depress(&self, w: f64, post_trace: f64) -> f64 {
        (w - self.lambda * self.alpha * w * post_trace).max(0.0)
    }

    /// Potentiation on post spike: returns the new weight.
    #[inline]
    pub fn potentiate(&self, w: f64, pre_trace: f64) -> f64 {
        (w + self.lambda * self.w0.powf(1.0 - self.mu) * w.powf(self.mu) * pre_trace)
            .min(self.w_max)
    }
}

/// Lazily-decayed exponential traces for a block of neurons.
#[derive(Clone, Debug)]
pub struct TraceSet {
    decay_per_step: f64,
    value: Vec<f64>,
    last: Vec<Step>,
}

impl TraceSet {
    pub fn new(n: usize, tau_ms: f64, dt_ms: f64) -> Self {
        TraceSet {
            decay_per_step: (-dt_ms / tau_ms).exp(),
            value: vec![0.0; n],
            last: vec![0; n],
        }
    }

    /// Trace value of neuron `i` at `step` (analytic decay since last event).
    #[inline]
    pub fn at(&self, i: Gid, step: Step) -> f64 {
        let i = i as usize;
        let dt = step.saturating_sub(self.last[i]);
        self.value[i] * self.decay_per_step.powi(dt as i32)
    }

    /// Register a spike of neuron `i` at `step` (trace += 1 after decay).
    #[inline]
    pub fn bump(&mut self, i: Gid, step: Step) {
        let v = self.at(i, step) + 1.0;
        let i = i as usize;
        self.value[i] = v;
        self.last[i] = step;
    }

    pub fn len(&self) -> usize {
        self.value.len()
    }

    pub fn is_empty(&self) -> bool {
        self.value.is_empty()
    }

    pub fn bytes(&self) -> u64 {
        use crate::metrics::memory::vec_bytes;
        vec_bytes(&self.value) + vec_bytes(&self.last)
    }

    /// Raw access for checkpointing.
    pub fn raw(&self) -> (&[f64], &[Step]) {
        (&self.value, &self.last)
    }

    /// Restore from raw arrays (checkpointing); shapes must match.
    pub fn raw_restore(
        &mut self,
        value: Vec<f64>,
        last: Vec<Step>,
    ) -> Result<(), String> {
        if value.len() != self.value.len() || last.len() != self.last.len() {
            return Err("trace shape mismatch".into());
        }
        self.value = value;
        self.last = last;
        Ok(())
    }

}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_decays_exponentially() {
        let mut t = TraceSet::new(2, 20.0, 0.1);
        t.bump(0, 100);
        assert!((t.at(0, 100) - 1.0).abs() < 1e-15);
        // after 200 steps (20 ms = one tau): e^-1
        let v = t.at(0, 300);
        assert!((v - (-1.0f64).exp()).abs() < 1e-12, "{v}");
        // untouched neuron stays zero
        assert_eq!(t.at(1, 300), 0.0);
    }

    #[test]
    fn trace_accumulates_across_spikes() {
        let mut t = TraceSet::new(1, 20.0, 0.1);
        t.bump(0, 0);
        t.bump(0, 200); // one tau later: e^-1 + 1
        let want = (-1.0f64).exp() + 1.0;
        assert!((t.at(0, 200) - want).abs() < 1e-12);
    }

    #[test]
    fn depression_multiplicative_and_clamped() {
        let p = StdpParams::default();
        let w = 100.0;
        let w1 = p.depress(w, 1.0);
        assert!((w1 - (w - p.lambda * p.alpha * w)).abs() < 1e-12);
        // strong trace cannot push weight below zero
        let w2 = p.depress(1e-3, 1e9);
        assert_eq!(w2, 0.0);
    }

    #[test]
    fn potentiation_power_law_and_capped() {
        let p = StdpParams::default();
        let w = 45.0;
        let w1 = p.potentiate(w, 1.0);
        let want = w + p.lambda * p.w0.powf(1.0 - p.mu) * w.powf(p.mu);
        assert!((w1 - want).abs() < 1e-12);
        assert_eq!(p.potentiate(p.w_max, 10.0), p.w_max);
    }

    #[test]
    fn closed_form_pair_protocol() {
        // single pre at t=0 arriving at a post that spikes at t=Δ:
        // potentiation uses x_pre = e^{-Δ/τ₊}
        let p = StdpParams::default();
        let dt_ms = 0.1;
        let mut pre = TraceSet::new(1, p.tau_plus_ms, dt_ms);
        pre.bump(0, 0);
        let delta_steps = 50; // 5 ms
        let x = pre.at(0, delta_steps);
        let want_x = (-5.0f64 / p.tau_plus_ms).exp();
        assert!((x - want_x).abs() < 1e-12);
        let w1 = p.potentiate(45.0, x);
        assert!(w1 > 45.0);
    }

    #[test]
    fn balance_drift_direction() {
        // near w0 with unit traces, potentiation > depression for defaults
        let p = StdpParams::default();
        let up = p.potentiate(p.w0, 1.0) - p.w0;
        let down = p.w0 - p.depress(p.w0, 1.0);
        assert!(up > down);
    }
}
