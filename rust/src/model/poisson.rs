//! Decomposition-independent Poisson background drive.
//!
//! NEST's hpc_benchmark and the Potjans microcircuit drive every neuron
//! with an independent Poisson spike source. We implement it counter-based:
//! the number of source spikes hitting neuron `gid` at step `t` is drawn
//! from a PRNG stream derived from `(seed, gid, t)`, so the realised noise
//! is a pure function of the experiment seed — independent of rank count,
//! thread count, mapping strategy, or engine. That invariance is load-
//! bearing for the test suite: CORTEX and the NEST-style baseline must be
//! *spike-exact* equal on identical networks.

use crate::util::rng::hash_stream;
use crate::{Gid, Step};

/// Poisson drive: `rate_hz` source firing rate onto each neuron, each
/// source spike depositing `weight_pa` into the excitatory (or inhibitory,
/// if negative) input.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PoissonDrive {
    pub rate_hz: f64,
    pub weight_pa: f64,
}

impl PoissonDrive {
    pub fn new(rate_hz: f64, weight_pa: f64) -> Self {
        PoissonDrive { rate_hz, weight_pa }
    }

    pub fn off() -> Self {
        PoissonDrive { rate_hz: 0.0, weight_pa: 0.0 }
    }

    pub fn is_off(&self) -> bool {
        self.rate_hz <= 0.0 || self.weight_pa == 0.0
    }

    /// Input current contribution for (gid, step): weight × Poisson count.
    ///
    /// Delegates to [`PreparedPoisson::sample`] so the unprepared and
    /// prepared paths draw from the *same* counter-based stream — a
    /// drive sampled ad hoc and one prepared for the hot loop must
    /// agree noise-for-noise or decomposition-independence quietly
    /// breaks between call sites.
    #[inline]
    pub fn sample(&self, seed: u64, gid: Gid, step: Step, dt_ms: f64) -> f64 {
        self.prepare(dt_ms).sample(seed, gid, step)
    }

    /// Precompute the per-step constants for the hot path.
    pub fn prepare(&self, dt_ms: f64) -> PreparedPoisson {
        let lambda = self.rate_hz.max(0.0) * dt_ms * 1e-3;
        PreparedPoisson {
            weight_pa: self.weight_pa,
            lambda,
            exp_neg_lambda: (-lambda).exp(),
            off: self.is_off(),
        }
    }
}

/// Hot-path form of [`PoissonDrive`]: `exp(-λ)` is precomputed and the
/// per-(neuron, step) stream is a raw splitmix64 sequence — no xoshiro
/// state expansion per sample. Still a pure function of
/// (seed, gid, step), so decomposition-independence is preserved.
///
/// `PartialEq` lets `gather_inputs` segment a post range into runs of
/// identical drives and hoist the off/λ checks out of the per-neuron
/// loop.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PreparedPoisson {
    pub weight_pa: f64,
    lambda: f64,
    exp_neg_lambda: f64,
    off: bool,
}

impl PreparedPoisson {
    #[inline]
    pub fn is_off(&self) -> bool {
        self.off
    }

    /// Weight × Poisson count for (gid, step).
    #[inline]
    pub fn sample(&self, seed: u64, gid: Gid, step: Step) -> f64 {
        if self.off {
            return 0.0;
        }
        let mut s = hash_stream(&[seed, 0x50524550, gid as u64, step]);
        let n = if self.lambda < 30.0 {
            // Knuth, uniforms straight from splitmix64
            let mut k = 0u64;
            let mut p = 1.0f64;
            loop {
                let u = (crate::util::rng::splitmix64(&mut s) >> 11) as f64
                    * (1.0 / (1u64 << 53) as f64);
                p *= u;
                if p <= self.exp_neg_lambda {
                    break k;
                }
                k += 1;
            }
        } else {
            // normal approximation via two splitmix uniforms (polar
            // would loop; Box-Muller is branch-free here)
            let u1 = ((crate::util::rng::splitmix64(&mut s) >> 11) as f64
                + 0.5)
                * (1.0 / (1u64 << 53) as f64);
            let u2 = (crate::util::rng::splitmix64(&mut s) >> 11) as f64
                * (1.0 / (1u64 << 53) as f64);
            let z = (-2.0 * u1.ln()).sqrt()
                * (std::f64::consts::TAU * u2).cos();
            let x = self.lambda + self.lambda.sqrt() * z + 0.5;
            if x < 0.0 {
                0
            } else {
                x as u64
            }
        };
        self.weight_pa * n as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_order_independent() {
        let d = PoissonDrive::new(8000.0, 50.0);
        let a = d.sample(1, 42, 100, 0.1);
        // resample in any order: same value
        let _ = d.sample(1, 7, 3, 0.1);
        let b = d.sample(1, 42, 100, 0.1);
        assert_eq!(a, b);
        // different gid/step/seed give (almost surely) different streams
        assert!(
            d.sample(1, 43, 100, 0.1) != a
                || d.sample(1, 42, 101, 0.1) != a
                || d.sample(2, 42, 100, 0.1) != a
        );
    }

    #[test]
    fn mean_rate_matches() {
        // rate 8 kHz, dt 0.1 ms -> lambda = 0.8 per step
        let d = PoissonDrive::new(8000.0, 1.0);
        let n = 50_000;
        let total: f64 = (0..n).map(|t| d.sample(9, 0, t, 0.1)).sum();
        let mean = total / n as f64;
        assert!((mean - 0.8).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn off_drive_contributes_nothing() {
        assert_eq!(PoissonDrive::off().sample(1, 2, 3, 0.1), 0.0);
        assert_eq!(PoissonDrive::new(0.0, 5.0).sample(1, 2, 3, 0.1), 0.0);
    }

    #[test]
    fn negative_weight_is_inhibitory() {
        let d = PoissonDrive::new(100_000.0, -2.0);
        let x = d.sample(1, 0, 0, 0.1);
        assert!(x <= 0.0);
    }

    #[test]
    fn prepared_mean_matches_lambda() {
        for rate in [800.0, 8000.0, 400_000.0] {
            let p = PoissonDrive::new(rate, 1.0).prepare(0.1);
            let lambda = rate * 0.1e-3;
            let n = 40_000;
            let mean: f64 = (0..n)
                .map(|t| p.sample(3, 5, t))
                .sum::<f64>()
                / n as f64;
            assert!(
                (mean - lambda).abs() < 0.05 * lambda.max(0.3),
                "rate {rate}: mean {mean} vs lambda {lambda}"
            );
        }
    }

    #[test]
    fn unprepared_and_prepared_draw_the_same_stream() {
        // the ad-hoc path must be the prepared path: same tag, same
        // sampler, same noise for identical (seed, gid, step)
        for rate in [800.0, 8000.0, 400_000.0] {
            let d = PoissonDrive::new(rate, 2.5);
            let p = d.prepare(0.1);
            for (seed, gid, step) in
                [(1u64, 0u32, 0u64), (7, 42, 100), (23, 1599, 599)]
            {
                assert_eq!(
                    d.sample(seed, gid, step, 0.1),
                    p.sample(seed, gid, step),
                    "rate {rate}: POIS/PREP streams diverged at \
                     ({seed}, {gid}, {step})"
                );
            }
        }
    }

    #[test]
    fn prepared_deterministic_and_off() {
        let p = PoissonDrive::new(8000.0, 2.0).prepare(0.1);
        assert_eq!(p.sample(1, 2, 3), p.sample(1, 2, 3));
        assert!(PoissonDrive::off().prepare(0.1).is_off());
        assert_eq!(PoissonDrive::off().prepare(0.1).sample(1, 2, 3), 0.0);
    }
}
