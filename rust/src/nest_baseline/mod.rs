//! NEST-style baseline engine — the comparison target of the paper's
//! evaluation (§IV, Fig 18), reproducing the *design choices* the paper
//! attributes to NEST-class simulators rather than NEST's codebase:
//!
//! * **Random-equivalent neuron distribution** (round-robin/random over
//!   ranks, no atlas awareness) — paper Fig 9;
//! * **Global node bookkeeping**: every rank keeps a proxy entry for all
//!   N neurons in the network (NEST 2.x's `SiblingContainer`/proxy-node
//!   tables — the O(N)-per-rank term that dominates its memory curve at
//!   scale);
//! * **Thread-parallel delivery over spikes** with atomic accumulation
//!   into shared ring buffers — the mutex/atomic pattern of [12], [13]
//!   that the paper's indegree ownership scheme eliminates;
//! * **Blocking spike exchange** at every window end (no dedicated
//!   communication thread, no overlap).
//!
//! Neuron dynamics, delays, Poisson drive and the deterministic network
//! instantiation are *identical* to the CORTEX engine (same `NetworkSpec`
//! streams), so with one thread per rank the two engines are spike-exact
//! comparable — a stronger verification than the paper's statistical
//! raster comparison.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::atlas::NetworkSpec;
use crate::comm::{Communicator, LocalCluster, SpikeMsg, SpikePacket};
use crate::decomp::{random_equivalent_partition, Partition};
use crate::metrics::memory::{vec_bytes, MemoryBreakdown, MemoryReport};
use crate::metrics::{PhaseTimer, SpikeRecorder};
use crate::model::lif::{step_slice, LifState};
use crate::model::poisson::PreparedPoisson;
use crate::{Gid, Step};

/// Bytes of per-neuron global bookkeeping each rank holds (proxy node +
/// sparse-table slot; NEST 2.x measured ~50-100 B/neuron/rank).
pub const PROXY_BYTES: u64 = 64;

/// Extra bytes per synapse beyond our packed arrays: NEST-class
/// simulators store each synapse as a polymorphic `Connection` object
/// inside a per-(thread, source) `Connector` — alignment padding, the
/// target pointer (8 B vs our 4 B local index), the full f64 delay, and
/// container overhead. Kunkel et al. 2014 (the paper's NEST reference)
/// report ~30-60 B per static synapse on the K computer; our packed
/// layout is 14 B, so the surplus is modelled explicitly.
pub const CONNECTION_OVERHEAD_BYTES: u64 = 26;

/// Atomic f64 accumulate (CAS loop) — the cost the paper avoids.
#[inline]
fn atomic_add_f64(cell: &AtomicU64, w: f64) {
    let mut cur = cell.load(Ordering::Relaxed);
    loop {
        let new = (f64::from_bits(cur) + w).to_bits();
        match cell.compare_exchange_weak(
            cur,
            new,
            Ordering::Relaxed,
            Ordering::Relaxed,
        ) {
            Ok(_) => return,
            Err(c) => cur = c,
        }
    }
}

/// One rank of the baseline.
pub struct NestRank {
    pub rank: u16,
    spec: Arc<NetworkSpec>,
    /// owned neurons (ascending gid)
    posts: Vec<Gid>,
    state: LifState,
    drives: Vec<PreparedPoisson>,
    /// CSR by pre over *all* N gids (the global bookkeeping): edge run of
    /// gid g is edges[offsets[g]..offsets[g+1]].
    offsets: Vec<u32>,
    e_post: Vec<u32>, // local post index
    e_weight: Vec<f64>,
    e_delay: Vec<u16>,
    /// shared ring buffers (atomics: multiple delivery threads may write
    /// the same post) — slot-padded layout [post * len + slot]
    ring_e: Vec<AtomicU64>,
    ring_i: Vec<AtomicU64>,
    ring_len: usize,
    pending: Vec<(u32, Step)>, // (gid index into offsets = gid itself, emit)
    n_threads: usize,
    pub recorder: SpikeRecorder,
    pub timer: PhaseTimer,
    step: Step,
    pub total_spikes: u64,
}

impl NestRank {
    pub fn new(
        spec: Arc<NetworkSpec>,
        posts: &[Gid],
        rank: u16,
        n_threads: usize,
        record_limit: Option<Gid>,
    ) -> NestRank {
        assert!(
            spec.all_lif(),
            "the NEST-style baseline models LIF dynamics only; run \
             non-LIF populations on the CORTEX engine"
        );
        let n = posts.len();
        let props = spec.lif_propagators();
        let pidx: Vec<u8> = posts.iter().map(|&g| spec.pidx(g)).collect();
        let mut state = LifState::new(n, &props, pidx);
        for (i, &g) in posts.iter().enumerate() {
            state.u[i] = spec.v_init(g);
        }
        // global-CSR edge store: every source gid gets a slot, mirroring
        // NEST's full node table per rank. Built by streaming the
        // deterministic edge generator twice (count, then fill into the
        // exact-capacity arrays) — the baseline keeps its *modelled*
        // per-synapse overheads but no longer holds a transient copy of
        // the whole edge list on top of them.
        let n_total = spec.n_total();
        let mut max_delay = 1u16;
        let mut counts = vec![0u32; n_total + 1];
        for &g in posts {
            spec.for_each_in_edge(g, |e, _| {
                counts[e.pre as usize + 1] += 1;
                max_delay = max_delay.max(e.delay);
            });
        }
        for i in 0..n_total {
            counts[i + 1] += counts[i];
        }
        let offsets = counts.clone();
        let mut cursor = counts;
        let n_edges = offsets[n_total] as usize;
        let mut e_post = vec![0u32; n_edges];
        let mut e_weight = vec![0.0f64; n_edges];
        let mut e_delay = vec![0u16; n_edges];
        for (li, &g) in posts.iter().enumerate() {
            spec.for_each_in_edge(g, |e, _| {
                let k = cursor[e.pre as usize] as usize;
                cursor[e.pre as usize] += 1;
                e_post[k] = li as u32;
                e_weight[k] = e.weight;
                e_delay[k] = e.delay;
            });
        }
        let ring_len = max_delay as usize + 1;
        let mk_ring = || -> Vec<AtomicU64> {
            (0..n * ring_len).map(|_| AtomicU64::new(0)).collect()
        };
        let drives = posts
            .iter()
            .map(|&g| spec.drive(g).prepare(spec.dt_ms))
            .collect();
        NestRank {
            rank,
            spec,
            posts: posts.to_vec(),
            state,
            drives,
            offsets,
            e_post,
            e_weight,
            e_delay,
            ring_e: mk_ring(),
            ring_i: mk_ring(),
            ring_len,
            pending: Vec::new(),
            n_threads,
            recorder: match record_limit {
                Some(l) => SpikeRecorder::new(l),
                None => SpikeRecorder::disabled(),
            },
            timer: PhaseTimer::new(),
            step: 0,
            total_spikes: 0,
        }
    }

    pub fn enqueue_remote(&mut self, spikes: &[SpikeMsg]) {
        for m in spikes {
            // NEST-style: every rank scans every spike against its global
            // table (no pre-filtering by a compact pre set)
            self.pending.push((m.gid, m.step as Step));
        }
    }

    pub fn step_once(&mut self, outbox: &mut SpikePacket) {
        let now = self.step;
        let pending = std::mem::take(&mut self.pending);
        let n = self.posts.len();
        let props = self.spec.lif_propagators();

        // --- delivery: parallel over spikes, atomic ring accumulation ---
        {
            let shards: Vec<&[(Gid, Step)]> = if self.n_threads <= 1
                || pending.len() < 2
            {
                vec![&pending[..]]
            } else {
                let per = pending.len().div_ceil(self.n_threads);
                pending.chunks(per).collect()
            };
            let ring_e = &self.ring_e;
            let ring_i = &self.ring_i;
            let offsets = &self.offsets;
            let e_post = &self.e_post;
            let e_weight = &self.e_weight;
            let e_delay = &self.e_delay;
            let ring_len = self.ring_len;
            std::thread::scope(|scope| {
                let mut handles = Vec::new();
                for shard in shards {
                    let work = move || {
                        for &(gid, emit) in shard {
                            let run = offsets[gid as usize] as usize
                                ..offsets[gid as usize + 1] as usize;
                            for ei in run {
                                let due =
                                    (emit + e_delay[ei] as Step) as usize
                                        % ring_len;
                                let idx = e_post[ei] as usize * ring_len
                                    + due;
                                let w = e_weight[ei];
                                if w >= 0.0 {
                                    atomic_add_f64(&ring_e[idx], w);
                                } else {
                                    atomic_add_f64(&ring_i[idx], w);
                                }
                            }
                        }
                    };
                    if self.n_threads <= 1 {
                        work();
                    } else {
                        handles.push(scope.spawn(work));
                    }
                }
                for h in handles {
                    h.join().expect("delivery thread panicked");
                }
            });
        }

        // --- integrate (thread ranges like any simulator) ---------------
        let slot = (now % self.ring_len as u64) as usize;
        let mut in_e = vec![0.0; n];
        let mut in_i = vec![0.0; n];
        for i in 0..n {
            let idx = i * self.ring_len + slot;
            in_e[i] =
                f64::from_bits(self.ring_e[idx].swap(0, Ordering::Relaxed));
            in_i[i] =
                f64::from_bits(self.ring_i[idx].swap(0, Ordering::Relaxed));
            let d = &self.drives[i];
            if !d.is_off() {
                // negative-weight drives are inhibitory input, matching
                // the engine's gather_inputs (the seed dropped them)
                let x = d.sample(self.spec.seed, self.posts[i], now);
                if x >= 0.0 {
                    in_e[i] += x;
                } else {
                    in_i[i] += x;
                }
            }
        }
        let mut spikes = Vec::new();
        step_slice(&mut self.state, 0, n, &in_e, &in_i, &props, &mut spikes);

        // --- collect --------------------------------------------------
        for &ls in &spikes {
            let gid = self.posts[ls as usize];
            self.total_spikes += 1;
            self.recorder.record(now, gid);
            outbox.push(SpikeMsg { gid, step: now as u32 });
            self.pending.push((gid, now));
        }
        self.step += 1;
    }

    pub fn memory(&self) -> MemoryBreakdown {
        let mut m = MemoryBreakdown::new();
        // the O(N)-per-rank global bookkeeping term
        m.add("proxies", self.spec.n_total() as u64 * PROXY_BYTES);
        m.add(
            "edges",
            vec_bytes(&self.offsets)
                + vec_bytes(&self.e_post)
                + vec_bytes(&self.e_weight)
                + vec_bytes(&self.e_delay)
                + self.e_post.len() as u64 * CONNECTION_OVERHEAD_BYTES,
        );
        m.add("posts", vec_bytes(&self.posts));
        m.add(
            "rings",
            (self.ring_e.len() + self.ring_i.len()) as u64 * 8,
        );
        m.add("state", self.state.bytes());
        m
    }
}

/// Run the baseline on `ranks` simulated ranks (always random-equivalent
/// mapping, always blocking exchange — the structure under comparison).
pub struct NestRunConfig {
    pub ranks: usize,
    pub threads: usize,
    pub steps: Step,
    pub record_limit: Option<Gid>,
    pub seed: u64,
}

pub struct NestRunOutput {
    pub raster: SpikeRecorder,
    pub timer_max: PhaseTimer,
    pub memory: MemoryReport,
    pub total_spikes: u64,
    /// Simulation wall time (excludes network construction).
    pub wall_seconds: f64,
    pub build_seconds: f64,
    pub comm_bytes: u64,
    pub partition: Partition,
}

pub fn run_nest_simulation(
    spec: &Arc<NetworkSpec>,
    cfg: &NestRunConfig,
) -> NestRunOutput {
    let partition = Arc::new(random_equivalent_partition(
        spec.n_total(),
        cfg.ranks,
        cfg.seed,
    ));
    let comms = LocalCluster::new(cfg.ranks);
    let m = spec.min_delay_steps as Step;
    let barrier = Arc::new(std::sync::Barrier::new(cfg.ranks));

    let outputs: Vec<(NestRank, u64, f64, f64)> =
        std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for (r, mut comm) in comms.into_iter().enumerate() {
            let spec = Arc::clone(spec);
            let partition = Arc::clone(&partition);
            let barrier = Arc::clone(&barrier);
            let threads = cfg.threads;
            let steps = cfg.steps;
            let record = cfg.record_limit;
            handles.push(scope.spawn(move || {
                let t_build = std::time::Instant::now();
                let mut rank = NestRank::new(
                    spec,
                    &partition.members[r],
                    r as u16,
                    threads,
                    record,
                );
                let build_s = t_build.elapsed().as_secs_f64();
                barrier.wait();
                let t_sim = std::time::Instant::now();
                let mut done: Step = 0;
                let mut incoming: SpikePacket = Vec::new();
                while done < steps {
                    rank.enqueue_remote(&incoming);
                    let mut outbox = Vec::new();
                    let win = m.min(steps - done);
                    for _ in 0..win {
                        let t = std::time::Instant::now();
                        rank.step_once(&mut outbox);
                        rank.timer.add("compute", t.elapsed().as_nanos());
                    }
                    done += win;
                    // blocking exchange — no overlap in the baseline
                    // (in-memory channels; errors mean a sibling rank
                    // thread died, which the join below also surfaces)
                    incoming = rank
                        .timer
                        .time("comm_wait", || comm.exchange(outbox))
                        .expect("window exchange failed");
                }
                (
                    rank,
                    comm.bytes_sent(),
                    build_s,
                    t_sim.elapsed().as_secs_f64(),
                )
            }));
        }
        handles
            .into_iter()
            .map(|h| h.join().expect("nest rank panicked"))
            .collect()
    });

    let mut raster = SpikeRecorder::new(cfg.record_limit.unwrap_or(0));
    let mut timer_max = PhaseTimer::new();
    let mut mems = Vec::new();
    let mut total_spikes = 0;
    let mut comm_bytes = 0;
    let mut wall_seconds: f64 = 0.0;
    let mut build_seconds: f64 = 0.0;
    for (rank, bytes, build_s, sim_s) in &outputs {
        raster.merge(&rank.recorder);
        timer_max.merge_max(&rank.timer);
        mems.push(rank.memory());
        total_spikes += rank.total_spikes;
        comm_bytes += bytes;
        wall_seconds = wall_seconds.max(*sim_s);
        build_seconds = build_seconds.max(*build_s);
    }
    raster.events.sort_unstable();
    NestRunOutput {
        raster,
        timer_max,
        memory: MemoryReport::new(mems),
        total_spikes,
        wall_seconds,
        build_seconds,
        comm_bytes,
        partition: Arc::try_unwrap(partition)
            .unwrap_or_else(|a| (*a).clone()),
    }
}
