//! Built-in probes: spike rasters, population rates, voltage traces,
//! STDP weight snapshots, phase timers.
//!
//! All are `Clone`, so one configured instance registered on the session
//! builder replicates across rank threads. All except [`PhaseStream`]
//! produce bit-identical output for a given network regardless of rank
//! internals (thread count, exec mode, exchange mode).

use std::collections::BTreeMap;

use crate::{Gid, Step};

use super::{Probe, ProbeData, StepView, WeightSnapshot};

/// Which gids a [`SpikeRaster`] records.
#[derive(Clone, Debug)]
pub enum GidFilter {
    /// Every spike.
    All,
    /// Gids strictly below the bound (the engine recorder's semantics).
    Below(Gid),
    /// Gids in `[lo, hi)`.
    Range(Gid, Gid),
    /// All populations with one of these names (resolved against the
    /// spec on first use; unknown names panic with a clear message).
    Pops(Vec<String>),
}

/// Spike raster with gid/population filters. Drains to
/// [`ProbeData::Raster`]: sorted `(step, gid)` events.
#[derive(Clone, Debug)]
pub struct SpikeRaster {
    name: String,
    filter: GidFilter,
    /// Gid ranges resolved from `GidFilter::Pops` (lazily, needs spec).
    ranges: Option<Vec<(Gid, Gid)>>,
    events: Vec<(Step, Gid)>,
}

impl SpikeRaster {
    pub fn new(name: &str, filter: GidFilter) -> SpikeRaster {
        SpikeRaster {
            name: name.into(),
            filter,
            ranges: None,
            events: Vec::new(),
        }
    }

    /// Record every spike.
    pub fn all(name: &str) -> SpikeRaster {
        Self::new(name, GidFilter::All)
    }

    /// Record gids below `limit`.
    pub fn below(name: &str, limit: Gid) -> SpikeRaster {
        Self::new(name, GidFilter::Below(limit))
    }

    /// Record the named populations only.
    pub fn pops(name: &str, pops: &[&str]) -> SpikeRaster {
        Self::new(
            name,
            GidFilter::Pops(pops.iter().map(|s| s.to_string()).collect()),
        )
    }

    /// Resolve a `Pops` filter against the spec (no-op otherwise or if
    /// already resolved). Unknown names error.
    fn resolve(&mut self, view: &StepView<'_>) -> anyhow::Result<()> {
        if self.ranges.is_some() {
            return Ok(());
        }
        if let GidFilter::Pops(names) = &self.filter {
            let ranges = resolve_pops(names, view)?;
            self.ranges = Some(ranges);
        }
        Ok(())
    }

    fn passes(&mut self, gid: Gid, view: &StepView<'_>) -> bool {
        match &self.filter {
            GidFilter::All => return true,
            GidFilter::Below(lim) => return gid < *lim,
            GidFilter::Range(lo, hi) => return gid >= *lo && gid < *hi,
            GidFilter::Pops(_) => {}
        }
        if self.ranges.is_none() {
            // the session validates via attach() at build time; manual
            // drivers that skip attach get the resolution (and any
            // unknown-name error) on first use
            self.resolve(view).expect("raster probe filter");
        }
        self.ranges
            .as_ref()
            .map(|rs| rs.iter().any(|&(lo, hi)| gid >= lo && gid < hi))
            .unwrap_or(false)
    }
}

/// Gid ranges of every population matching one of `names` (the same
/// lookup the session's stimulus targeting uses).
fn resolve_pops(
    names: &[String],
    view: &StepView<'_>,
) -> anyhow::Result<Vec<(Gid, Gid)>> {
    let spec = view.spec();
    let mut out = Vec::new();
    for name in names {
        let indices = spec.pops_named(name);
        anyhow::ensure!(
            !indices.is_empty(),
            "filter names unknown population '{name}' (network '{}')",
            spec.name
        );
        for i in indices {
            let p = &spec.populations[i as usize];
            out.push((p.first_gid, p.first_gid + p.n));
        }
    }
    Ok(out)
}

impl Probe for SpikeRaster {
    fn name(&self) -> &str {
        &self.name
    }

    fn attach(&mut self, view: &StepView<'_>) -> anyhow::Result<()> {
        self.resolve(view)
    }

    fn on_step(&mut self, view: &StepView<'_>) {
        for m in view.spikes() {
            if self.passes(m.gid, view) {
                self.events.push((m.step as Step, m.gid));
            }
        }
    }

    fn drain(&mut self, _view: &StepView<'_>) -> ProbeData {
        let mut events = std::mem::take(&mut self.events);
        events.sort_unstable();
        ProbeData::Raster(events)
    }
}

/// Per-population firing rates over fixed time bins. Drains to
/// [`ProbeData::Rates`].
///
/// A row is emitted for every completed bin (including silent ones).
/// Draining mid-bin flushes the partial bin as a row computed over the
/// **full** bin width and restarts binning at the current step, so for
/// clean rows drain at bin boundaries.
#[derive(Clone, Debug)]
pub struct PopRates {
    name: String,
    bin_steps: Step,
    bin_start: Step,
    started: bool,
    counts: Vec<u64>,
    pops: Vec<String>,
    rows: Vec<(Step, Vec<f64>)>,
}

impl PopRates {
    /// Rates binned every `bin_steps` integration steps.
    pub fn new(name: &str, bin_steps: Step) -> PopRates {
        assert!(bin_steps >= 1, "rate bin must cover at least one step");
        PopRates {
            name: name.into(),
            bin_steps,
            bin_start: 0,
            started: false,
            counts: Vec::new(),
            pops: Vec::new(),
            rows: Vec::new(),
        }
    }

    fn ensure_init(&mut self, view: &StepView<'_>) {
        if !self.started {
            let spec = view.spec();
            self.counts = vec![0; spec.populations.len()];
            self.pops = spec
                .populations
                .iter()
                .map(|p| p.name.clone())
                .collect();
            self.bin_start = view.step();
            self.started = true;
        }
    }

    fn flush_bin(&mut self, view: &StepView<'_>) {
        let spec = view.spec();
        let bin_s = self.bin_steps as f64 * spec.dt_ms * 1e-3;
        let rates: Vec<f64> = self
            .counts
            .iter()
            .zip(&spec.populations)
            .map(|(&c, p)| c as f64 / (p.n as f64 * bin_s))
            .collect();
        self.rows.push((self.bin_start, rates));
        self.counts.iter_mut().for_each(|c| *c = 0);
        self.bin_start += self.bin_steps;
    }
}

impl Probe for PopRates {
    fn name(&self) -> &str {
        &self.name
    }

    fn on_step(&mut self, view: &StepView<'_>) {
        self.ensure_init(view);
        while view.step() >= self.bin_start + self.bin_steps {
            self.flush_bin(view);
        }
        let spec = view.spec();
        for m in view.spikes() {
            self.counts[spec.pop_of(m.gid) as usize] += 1;
        }
    }

    fn drain(&mut self, view: &StepView<'_>) -> ProbeData {
        self.ensure_init(view);
        while view.step() >= self.bin_start + self.bin_steps {
            self.flush_bin(view);
        }
        if view.step() > self.bin_start {
            // partial trailing bin
            self.flush_bin(view);
        }
        self.bin_start = view.step();
        ProbeData::Rates {
            bin_steps: self.bin_steps,
            pops: self.pops.clone(),
            rows: std::mem::take(&mut self.rows),
        }
    }
}

/// Sampled membrane-voltage traces of selected gids. Drains to
/// [`ProbeData::Traces`]. Each gid is recorded by the one rank that owns
/// it; gids of voltage-free models (parrot) or outside the network yield
/// no trace.
#[derive(Clone, Debug)]
pub struct VoltageTrace {
    name: String,
    every: Step,
    samples: Vec<(Gid, Vec<(Step, f64)>)>,
}

impl VoltageTrace {
    /// Sample each of `gids` every `every` steps.
    pub fn new(name: &str, gids: &[Gid], every: Step) -> VoltageTrace {
        assert!(every >= 1, "sampling interval must be >= 1 step");
        VoltageTrace {
            name: name.into(),
            every,
            samples: gids.iter().map(|&g| (g, Vec::new())).collect(),
        }
    }
}

impl Probe for VoltageTrace {
    fn name(&self) -> &str {
        &self.name
    }

    fn on_step(&mut self, view: &StepView<'_>) {
        if view.step() % self.every != 0 {
            return;
        }
        for (gid, buf) in &mut self.samples {
            if let Some(v) = view.voltage(*gid) {
                buf.push((view.step(), v));
            }
        }
    }

    fn drain(&mut self, _view: &StepView<'_>) -> ProbeData {
        let mut out = Vec::new();
        for (gid, buf) in &mut self.samples {
            if !buf.is_empty() {
                out.push((*gid, std::mem::take(buf)));
            }
        }
        ProbeData::Traces(out)
    }
}

/// STDP weight snapshots. Drains to [`ProbeData::Weights`]; every drain
/// appends a snapshot of the current weights, and [`Self::every`] adds
/// periodic mid-run snapshots on top.
#[derive(Clone, Debug)]
pub struct WeightSnapshots {
    name: String,
    every: Option<Step>,
    snaps: Vec<WeightSnapshot>,
}

impl WeightSnapshots {
    /// Snapshot at drain time only.
    pub fn new(name: &str) -> WeightSnapshots {
        WeightSnapshots { name: name.into(), every: None, snaps: Vec::new() }
    }

    /// Additionally snapshot every `steps` steps.
    pub fn every(mut self, steps: Step) -> WeightSnapshots {
        assert!(steps >= 1, "snapshot interval must be >= 1 step");
        self.every = Some(steps);
        self
    }
}

impl Probe for WeightSnapshots {
    fn name(&self) -> &str {
        &self.name
    }

    fn on_step(&mut self, view: &StepView<'_>) {
        if let Some(k) = self.every {
            if view.step() % k == 0 {
                self.snaps.push((view.step(), view.plastic_edges()));
            }
        }
    }

    fn drain(&mut self, view: &StepView<'_>) -> ProbeData {
        let mut snaps = std::mem::take(&mut self.snaps);
        snaps.push((view.step(), view.plastic_edges()));
        ProbeData::Weights(snaps)
    }
}

/// Phase-timer stream: each drain reports every phase's wall-clock
/// seconds accumulated since the previous drain, tagged by rank. Drains
/// to [`ProbeData::Phases`]. Wall clock — **not** deterministic.
#[derive(Clone, Debug)]
pub struct PhaseStream {
    name: String,
    last: BTreeMap<String, f64>,
}

impl PhaseStream {
    pub fn new(name: &str) -> PhaseStream {
        PhaseStream { name: name.into(), last: BTreeMap::new() }
    }
}

impl Probe for PhaseStream {
    fn name(&self) -> &str {
        &self.name
    }

    fn on_step(&mut self, _view: &StepView<'_>) {}

    fn drain(&mut self, view: &StepView<'_>) -> ProbeData {
        let mut rows = Vec::new();
        for (phase, secs) in view.timer().phases() {
            let prev = self.last.get(phase).copied().unwrap_or(0.0);
            let delta = secs - prev;
            if delta > 0.0 {
                rows.push((view.rank(), phase.to_string(), delta));
            }
            self.last.insert(phase.to_string(), secs);
        }
        ProbeData::Phases(rows)
    }
}
