//! Pluggable observation: the session API's recording layer.
//!
//! A [`Probe`] is a per-rank observer that a [`crate::engine::Simulation`]
//! session instantiates on **every rank thread** at build time. After each
//! integration step the rank loop hands its probes a read-only
//! [`StepView`] — the step number, the spikes the rank just emitted, and
//! accessor methods into the rank's engine state (membrane voltages,
//! plastic weights, phase timers). Between `run_for` calls the session
//! drains a probe by name: each rank moves its accumulated data out over
//! its response channel and the session merges the per-rank pieces into
//! one [`ProbeData`].
//!
//! This design preserves the engine's no-data-racing property (paper
//! §III.B): a probe lives on exactly one rank thread, observes only that
//! rank's state through `&`-references, and communicates with the session
//! exclusively by value over channels — no probe ever holds a lock or a
//! shared mutable reference into the simulation.
//!
//! Determinism: everything a [`StepView`] exposes except the phase timer
//! is a deterministic function of the simulation state, so the built-in
//! spike/rate/voltage/weight probes produce bit-identical output across
//! thread counts, exec modes and exchange modes (asserted in
//! `rust/tests/session_api.rs`). The [`builtin::PhaseStream`] probe
//! reports wall-clock times and is the deliberate exception.
//!
//! Built-ins live in [`builtin`]: spike rasters with gid/population
//! filters, per-population firing rates, sampled membrane-voltage traces,
//! STDP weight snapshots, and a phase-timer stream.

pub mod builtin;

pub use builtin::{
    GidFilter, PhaseStream, PopRates, SpikeRaster, VoltageTrace,
    WeightSnapshots,
};

use crate::atlas::NetworkSpec;
use crate::comm::SpikeMsg;
use crate::engine::RankEngine;
use crate::metrics::PhaseTimer;
use crate::{Gid, Step};

/// A per-rank observer plugged into a simulation session.
///
/// Implementations must be `Send` (they live on the rank thread) and are
/// usually `Clone` so one instance registered on the builder can be
/// replicated per rank (see `SimulationBuilder::probe`).
pub trait Probe: Send {
    /// Registration name; the session drains the probe by this name.
    fn name(&self) -> &str;

    /// Called once when the probe is installed on its rank thread (the
    /// engine exists; no steps have run on it yet). Resolve and
    /// validate configuration against the network here — an error
    /// fails `SimulationBuilder::build` with a clear message instead
    /// of surfacing mid-run.
    fn attach(&mut self, _view: &StepView<'_>) -> anyhow::Result<()> {
        Ok(())
    }

    /// Observe one completed integration step.
    fn on_step(&mut self, view: &StepView<'_>);

    /// Move the accumulated data out (the probe keeps running and starts
    /// accumulating afresh). `view` is at-rest: `view.spikes()` is empty
    /// but engine state is accessible for drain-time snapshots.
    fn drain(&mut self, view: &StepView<'_>) -> ProbeData;
}

/// Read-only view of one rank handed to probes after each step (and, with
/// no spikes, at drain time).
pub struct StepView<'a> {
    engine: &'a RankEngine,
    step: Step,
    spikes: &'a [SpikeMsg],
}

impl<'a> StepView<'a> {
    /// View of the step that just completed. `spikes` are the spikes this
    /// rank emitted during it (all of them — independent of the engine's
    /// raster `record_limit`).
    pub fn new(
        engine: &'a RankEngine,
        step: Step,
        spikes: &'a [SpikeMsg],
    ) -> StepView<'a> {
        StepView { engine, step, spikes }
    }

    /// At-rest view (drain time): no step events, state accessible.
    pub fn at_rest(engine: &'a RankEngine) -> StepView<'a> {
        StepView { engine, step: engine.step(), spikes: &[] }
    }

    /// The step this view describes (at drain time: steps completed).
    pub fn step(&self) -> Step {
        self.step
    }

    /// Spikes this rank emitted during the step.
    pub fn spikes(&self) -> &[SpikeMsg] {
        self.spikes
    }

    pub fn rank(&self) -> u16 {
        self.engine.rank
    }

    pub fn spec(&self) -> &NetworkSpec {
        self.engine.spec()
    }

    /// Membrane potential of `gid`, if this rank owns it and its model
    /// has one (parrot relays do not).
    pub fn voltage(&self, gid: Gid) -> Option<f64> {
        self.engine.voltage_of(gid)
    }

    /// This rank's plastic edges as (pre gid, post gid, delay, weight),
    /// canonically sorted — comparable across thread counts.
    pub fn plastic_edges(&self) -> Vec<WeightEdge> {
        self.engine.plastic_edges_global()
    }

    /// The rank's accumulating phase timer (wall clock — the one
    /// non-deterministic quantity a probe can observe).
    pub fn timer(&self) -> &PhaseTimer {
        &self.engine.timer
    }
}

/// One plastic edge as probes report it: (pre gid, post gid, delay
/// steps, weight pA).
pub type WeightEdge = (Gid, Gid, u16, f64);
/// One weight snapshot: the step it was taken at, plus every plastic
/// edge, canonically sorted.
pub type WeightSnapshot = (Step, Vec<WeightEdge>);

/// Typed payload a probe hands back on drain. Per-rank pieces of the same
/// variant merge into one session-level value via [`ProbeData::merge`].
#[derive(Clone, Debug, PartialEq)]
pub enum ProbeData {
    /// Spike events (step, gid), sorted.
    Raster(Vec<(Step, Gid)>),
    /// Per-population firing rates: one row per time bin,
    /// `(bin start step, rate in Hz per population)`. Rates are averaged
    /// over each population's **global** size, so per-rank partial rows
    /// sum to the population rate on merge.
    Rates {
        bin_steps: Step,
        pops: Vec<String>,
        rows: Vec<(Step, Vec<f64>)>,
    },
    /// Sampled membrane-voltage traces per gid: (gid, [(step, mV)]).
    Traces(Vec<(Gid, Vec<(Step, f64)>)>),
    /// Plastic-weight snapshots: (step, [(pre, post, delay, weight)]),
    /// canonically sorted within each snapshot.
    Weights(Vec<WeightSnapshot>),
    /// Phase-timer deltas since the previous drain:
    /// (rank, phase, seconds).
    Phases(Vec<(u16, String, f64)>),
    /// Free-form lines (escape hatch for custom probes).
    Lines(Vec<String>),
}

impl ProbeData {
    /// Merge another rank's piece of the same probe into this one.
    /// Variants must match (they do, for pieces of one probe).
    pub fn merge(self, other: ProbeData) -> anyhow::Result<ProbeData> {
        use ProbeData::*;
        Ok(match (self, other) {
            (Raster(mut a), Raster(b)) => {
                a.extend(b);
                a.sort_unstable();
                Raster(a)
            }
            (
                Rates { bin_steps, pops, rows: mut a },
                Rates { bin_steps: b_bin, pops: b_pops, rows: b },
            ) => {
                anyhow::ensure!(
                    bin_steps == b_bin && pops == b_pops && a.len() == b.len(),
                    "rate probe pieces disagree on binning"
                );
                for (ra, rb) in a.iter_mut().zip(b) {
                    anyhow::ensure!(
                        ra.0 == rb.0,
                        "rate probe pieces disagree on bin starts"
                    );
                    for (x, y) in ra.1.iter_mut().zip(rb.1) {
                        *x += y;
                    }
                }
                Rates { bin_steps, pops, rows: a }
            }
            (Traces(mut a), Traces(b)) => {
                a.extend(b);
                a.sort_by_key(|(g, _)| *g);
                Traces(a)
            }
            (Weights(mut a), Weights(b)) => {
                anyhow::ensure!(
                    a.len() == b.len(),
                    "weight probe pieces disagree on snapshot count"
                );
                for (sa, sb) in a.iter_mut().zip(b) {
                    anyhow::ensure!(
                        sa.0 == sb.0,
                        "weight probe pieces disagree on snapshot steps"
                    );
                    sa.1.extend(sb.1);
                    sa.1.sort_by_key(|&(pre, post, delay, _)| {
                        (pre, post, delay)
                    });
                }
                Weights(a)
            }
            (Phases(mut a), Phases(b)) => {
                a.extend(b);
                Phases(a)
            }
            (Lines(mut a), Lines(b)) => {
                a.extend(b);
                Lines(a)
            }
            _ => anyhow::bail!("probe data variants differ across ranks"),
        })
    }

    /// Convenience: unwrap a [`ProbeData::Raster`].
    pub fn into_raster(self) -> anyhow::Result<Vec<(Step, Gid)>> {
        match self {
            ProbeData::Raster(v) => Ok(v),
            other => anyhow::bail!(
                "expected raster probe data, got {other:?}"
            ),
        }
    }

    /// Convenience: unwrap [`ProbeData::Weights`].
    pub fn into_weights(self) -> anyhow::Result<Vec<WeightSnapshot>> {
        match self {
            ProbeData::Weights(v) => Ok(v),
            other => anyhow::bail!(
                "expected weight probe data, got {other:?}"
            ),
        }
    }
}
