//! Spike/raster recording and the activity statistics used to compare
//! CORTEX against the NEST-style baseline (paper Fig 19: rasters must be
//! "similar to each other with slight differences" — we compare rates,
//! ISI-CV irregularity and population synchrony).

use crate::util::stats;
use crate::{Gid, Step};

/// Records (step, gid) spike events for gids below `gid_limit`.
///
/// The engine's `record_limit: Option<Gid>` knob maps onto this as:
/// `Some(limit)` → [`SpikeRecorder::new`] (use `Some(u32::MAX)` to
/// record everything), `None` → [`SpikeRecorder::disabled`] — nothing
/// is recorded. Filtered/structured recording lives in `crate::probe`.
#[derive(Clone, Debug)]
pub struct SpikeRecorder {
    pub gid_limit: Gid,
    pub events: Vec<(Step, Gid)>,
    enabled: bool,
}

impl SpikeRecorder {
    pub fn new(gid_limit: Gid) -> Self {
        SpikeRecorder { gid_limit, events: Vec::new(), enabled: true }
    }

    /// A recorder that keeps nothing — the explicit form of
    /// "`record_limit: None`" (not a zero gid bound by accident).
    pub fn disabled() -> Self {
        SpikeRecorder { gid_limit: 0, events: Vec::new(), enabled: false }
    }

    /// Wrap pre-collected events (e.g. a drained raster probe) so the
    /// [`Self::stats`] / [`Self::to_csv`] helpers apply to them too.
    pub fn from_events(events: Vec<(Step, Gid)>) -> Self {
        SpikeRecorder { gid_limit: Gid::MAX, events, enabled: true }
    }

    #[inline]
    pub fn record(&mut self, step: Step, gid: Gid) {
        if self.enabled && gid < self.gid_limit {
            self.events.push((step, gid));
        }
    }

    pub fn record_all(&mut self, step: Step, gids: &[Gid]) {
        for &g in gids {
            self.record(step, g);
        }
    }

    pub fn merge(&mut self, other: &SpikeRecorder) {
        self.events.extend_from_slice(&other.events);
    }

    /// Raster statistics over the recorded window.
    pub fn stats(&self, n_neurons: usize, dt_ms: f64, steps: Step) -> RasterStats {
        let sim_s = steps as f64 * dt_ms * 1e-3;
        let mut per_neuron: Vec<Vec<f64>> = vec![Vec::new(); n_neurons];
        for &(t, g) in &self.events {
            if (g as usize) < n_neurons {
                per_neuron[g as usize].push(t as f64 * dt_ms);
            }
        }
        let counts: Vec<f64> =
            per_neuron.iter().map(|v| v.len() as f64).collect();
        let rates: Vec<f64> = counts.iter().map(|c| c / sim_s).collect();
        let cvs: Vec<f64> = per_neuron
            .iter()
            .filter(|v| v.len() >= 3)
            .map(|v| stats::isi_cv(v))
            .collect();

        // population synchrony: variance/mean of the per-step population
        // spike count (Fano factor of the summed activity)
        let mut per_step = vec![0.0f64; steps as usize + 1];
        for &(t, _) in &self.events {
            if (t as usize) < per_step.len() {
                per_step[t as usize] += 1.0;
            }
        }
        let m = stats::mean(&per_step);
        let synchrony = if m > 0.0 {
            stats::std(&per_step).powi(2) / m
        } else {
            0.0
        };

        RasterStats {
            n_events: self.events.len(),
            mean_rate_hz: stats::mean(&rates),
            max_rate_hz: rates.iter().cloned().fold(0.0, f64::max),
            mean_isi_cv: stats::mean(&cvs),
            synchrony,
            active_fraction: counts.iter().filter(|&&c| c > 0.0).count() as f64
                / n_neurons.max(1) as f64,
        }
    }

    /// CSV lines "time_ms,gid" (the Fig 19 raster format).
    pub fn to_csv(&self, dt_ms: f64) -> String {
        let mut out = String::from("time_ms,gid\n");
        let mut sorted = self.events.clone();
        sorted.sort_unstable();
        for (t, g) in sorted {
            out.push_str(&format!("{},{}\n", t as f64 * dt_ms, g));
        }
        out
    }
}

/// Summary statistics of one raster (the quantities compared in Fig 19).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RasterStats {
    pub n_events: usize,
    pub mean_rate_hz: f64,
    pub max_rate_hz: f64,
    pub mean_isi_cv: f64,
    pub synchrony: f64,
    pub active_fraction: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_only_below_limit() {
        let mut r = SpikeRecorder::new(10);
        r.record(1, 5);
        r.record(1, 15);
        r.record_all(2, &[3, 12, 7]);
        assert_eq!(r.events, vec![(1, 5), (2, 3), (2, 7)]);
    }

    #[test]
    fn disabled_records_nothing() {
        let mut r = SpikeRecorder::disabled();
        r.record(1, 0);
        assert!(r.events.is_empty());
    }

    #[test]
    fn stats_rates() {
        let mut r = SpikeRecorder::new(100);
        // neuron 0 fires every 10 steps for 1000 steps at dt=1ms -> 100 Hz
        for t in (0..1000).step_by(10) {
            r.record(t, 0);
        }
        let s = r.stats(2, 1.0, 1000);
        assert_eq!(s.n_events, 100);
        // mean over 2 neurons, one at 100 Hz one silent
        assert!((s.mean_rate_hz - 50.0).abs() < 1e-9);
        assert!((s.max_rate_hz - 100.0).abs() < 1e-9);
        assert!(s.mean_isi_cv.abs() < 1e-12); // perfectly regular
        assert!((s.active_fraction - 0.5).abs() < 1e-12);
    }

    #[test]
    fn csv_sorted_output() {
        let mut r = SpikeRecorder::new(10);
        r.record(5, 2);
        r.record(1, 3);
        let csv = r.to_csv(0.1);
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines[0], "time_ms,gid");
        assert!(lines[1].starts_with("0.1"));
    }
}
