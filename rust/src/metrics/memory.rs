//! Per-rank memory accounting.
//!
//! The paper's Fig 18 memory panel reports the *maximal per-node memory
//! consumption*. We account bytes analytically from the engines' data
//! structures (every store reports its exact heap footprint), which is both
//! deterministic and the quantity the paper's O(n_pre + n_post + n_edges)
//! analysis speaks about.

use std::collections::BTreeMap;

/// A breakdown of one rank's memory by component, plus non-additive
/// **gauges**: quantities reported alongside the components but never
/// summed into [`Self::total`] — e.g. the transient peak a store held
/// during construction, which coexisted with nothing else in the
/// steady-state breakdown.
#[derive(Clone, Debug, Default)]
pub struct MemoryBreakdown {
    components: BTreeMap<&'static str, u64>,
    gauges: BTreeMap<&'static str, u64>,
}

impl MemoryBreakdown {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, component: &'static str, bytes: u64) {
        *self.components.entry(component).or_insert(0) += bytes;
    }

    /// Record a gauge; repeated settings keep the maximum (a rank's
    /// build peak is the max over its stores' peaks).
    pub fn set_gauge(&mut self, name: &'static str, bytes: u64) {
        let e = self.gauges.entry(name).or_insert(0);
        *e = (*e).max(bytes);
    }

    /// Steady-state total: the sum of the components (gauges excluded).
    pub fn total(&self) -> u64 {
        self.components.values().sum()
    }

    pub fn get(&self, component: &str) -> u64 {
        self.components.get(component).copied().unwrap_or(0)
    }

    pub fn gauge(&self, name: &str) -> u64 {
        self.gauges.get(name).copied().unwrap_or(0)
    }

    pub fn components(&self) -> impl Iterator<Item = (&'static str, u64)> + '_ {
        self.components.iter().map(|(k, v)| (*k, *v))
    }

    pub fn gauges(&self) -> impl Iterator<Item = (&'static str, u64)> + '_ {
        self.gauges.iter().map(|(k, v)| (*k, *v))
    }
}

/// Memory across all ranks of a run.
#[derive(Clone, Debug, Default)]
pub struct MemoryReport {
    pub per_rank: Vec<MemoryBreakdown>,
}

impl MemoryReport {
    pub fn new(per_rank: Vec<MemoryBreakdown>) -> Self {
        MemoryReport { per_rank }
    }

    /// The paper's reported quantity: max over ranks.
    pub fn max_rank_bytes(&self) -> u64 {
        self.per_rank.iter().map(|b| b.total()).max().unwrap_or(0)
    }

    pub fn total_bytes(&self) -> u64 {
        self.per_rank.iter().map(|b| b.total()).sum()
    }

    /// Load imbalance: max/mean of per-rank totals (1.0 = perfectly even).
    pub fn imbalance(&self) -> f64 {
        if self.per_rank.is_empty() {
            return 1.0;
        }
        let totals: Vec<f64> =
            self.per_rank.iter().map(|b| b.total() as f64).collect();
        let mean = totals.iter().sum::<f64>() / totals.len() as f64;
        if mean == 0.0 {
            1.0
        } else {
            totals.iter().cloned().fold(0.0, f64::max) / mean
        }
    }

    pub fn report(&self) -> String {
        let mut out = String::new();
        for (i, b) in self.per_rank.iter().enumerate() {
            out.push_str(&format!(
                "rank {i}: {:.2} MiB\n",
                b.total() as f64 / (1024.0 * 1024.0)
            ));
            for (k, v) in b.components() {
                out.push_str(&format!(
                    "    {k:<20} {:>10.2} KiB\n",
                    v as f64 / 1024.0
                ));
            }
            for (k, v) in b.gauges() {
                // '~' marks a transient gauge, excluded from the total
                out.push_str(&format!(
                    "    ~{k:<19} {:>10.2} KiB\n",
                    v as f64 / 1024.0
                ));
            }
        }
        out.push_str(&format!(
            "max-rank {:.2} MiB, imbalance {:.3}\n",
            self.max_rank_bytes() as f64 / (1024.0 * 1024.0),
            self.imbalance()
        ));
        out
    }
}

/// Exact heap bytes of a Vec<T> (capacity, not len — what the allocator holds).
pub fn vec_bytes<T>(v: &Vec<T>) -> u64 {
    (v.capacity() * std::mem::size_of::<T>()) as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn breakdown_totals() {
        let mut b = MemoryBreakdown::new();
        b.add("edges", 1000);
        b.add("neurons", 200);
        b.add("edges", 500);
        assert_eq!(b.total(), 1700);
        assert_eq!(b.get("edges"), 1500);
        assert_eq!(b.get("nothing"), 0);
    }

    #[test]
    fn gauges_report_but_never_sum() {
        let mut b = MemoryBreakdown::new();
        b.add("edges", 1000);
        b.set_gauge("build_peak", 5000);
        b.set_gauge("build_peak", 3000); // keeps the max
        assert_eq!(b.total(), 1000);
        assert_eq!(b.gauge("build_peak"), 5000);
        assert_eq!(b.gauge("missing"), 0);
        assert_eq!(b.gauges().count(), 1);
        let r = MemoryReport::new(vec![b]);
        assert_eq!(r.max_rank_bytes(), 1000);
        assert!(r.report().contains("~build_peak"));
    }

    #[test]
    fn report_max_and_imbalance() {
        let mk = |bytes: u64| {
            let mut b = MemoryBreakdown::new();
            b.add("x", bytes);
            b
        };
        let r = MemoryReport::new(vec![mk(100), mk(300), mk(200)]);
        assert_eq!(r.max_rank_bytes(), 300);
        assert_eq!(r.total_bytes(), 600);
        assert!((r.imbalance() - 1.5).abs() < 1e-12);
    }

    #[test]
    fn empty_report() {
        let r = MemoryReport::default();
        assert_eq!(r.max_rank_bytes(), 0);
        assert_eq!(r.imbalance(), 1.0);
    }

    #[test]
    fn vec_bytes_counts_capacity() {
        let v: Vec<u64> = Vec::with_capacity(10);
        assert_eq!(vec_bytes(&v), 80);
    }
}
