//! Aligned plain-text tables + CSV output for the bench harnesses: every
//! bench prints the same rows/series the paper's figures show.

use std::fmt::Write as _;
use std::path::Path;

/// Column-aligned text table with a header row.
#[derive(Clone, Debug)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(
            cells.len(),
            self.headers.len(),
            "row width mismatch in table '{}'",
            self.title
        );
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> =
            self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "== {} ==", self.title);
        let line = |out: &mut String, cells: &[String]| {
            for i in 0..ncol {
                let _ = write!(out, "{:>w$}  ", cells[i], w = widths[i]);
            }
            out.pop();
            out.pop();
            out.push('\n');
        };
        line(&mut out, &self.headers);
        let rule: usize = widths.iter().sum::<usize>() + 2 * (ncol - 1);
        let _ = writeln!(out, "{}", "-".repeat(rule));
        for row in &self.rows {
            line(&mut out, row);
        }
        out
    }

    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "{}", self.headers.join(","));
        for row in &self.rows {
            let _ = writeln!(out, "{}", row.join(","));
        }
        out
    }

    /// Print to stdout and persist CSV under `dir/<name>.csv`.
    pub fn emit(&self, dir: &Path, name: &str) -> std::io::Result<()> {
        println!("{}", self.render());
        write_csv(dir, name, &self.to_csv())
    }
}

/// Write `contents` to `dir/name.csv`, creating the directory.
pub fn write_csv(dir: &Path, name: &str, contents: &str) -> std::io::Result<()> {
    std::fs::create_dir_all(dir)?;
    std::fs::write(dir.join(format!("{name}.csv")), contents)
}

/// Format bytes as a human-readable string.
pub fn human_bytes(bytes: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut x = bytes as f64;
    let mut u = 0;
    while x >= 1024.0 && u < UNITS.len() - 1 {
        x /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{bytes} B")
    } else {
        format!("{x:.2} {}", UNITS[u])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_aligns_columns() {
        let mut t = Table::new("demo", &["size", "time_s"]);
        t.row(&["1".into(), "0.5".into()]);
        t.row(&["1000".into(), "12.25".into()]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        let lines: Vec<&str> = s.lines().collect();
        // header and rows right-aligned to same width
        assert_eq!(lines[1].len(), lines[3].len());
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn row_width_checked() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn csv_output() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(&["1".into(), "2".into()]);
        assert_eq!(t.to_csv(), "a,b\n1,2\n");
    }

    #[test]
    fn human_bytes_units() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(2048), "2.00 KiB");
        assert_eq!(human_bytes(3 * 1024 * 1024), "3.00 MiB");
    }
}
