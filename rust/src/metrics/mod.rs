//! Instrumentation: phase timers, per-rank memory accounting, spike/raster
//! recording, and plain-text table / CSV output for the bench harnesses.

pub mod memory;
pub mod recorder;
pub mod table;
pub mod timer;

pub use memory::{MemoryBreakdown, MemoryReport};
pub use recorder::{RasterStats, SpikeRecorder};
pub use table::{write_csv, Table};
pub use timer::PhaseTimer;
