//! Accumulating phase timer: wall-clock nanoseconds per named phase, the
//! instrument behind the paper's Fig 18 time panel and EXPERIMENTS.md
//! §Perf.
//!
//! Phases recorded by the CORTEX engine: `deliver` and `integrate` (per
//! worker, summed), `sync` (per step: the parallel section's wall time
//! minus the busiest worker's compute — the coordination overhead of the
//! execution backend, i.e. the channel round-trip of the persistent pool
//! or the spawn/join cost of the scoped fallback), `compute` (whole
//! steps), and `comm_wait` / `comm_submit` (window exchange).

use std::collections::BTreeMap;
use std::time::Instant;

#[derive(Debug, Default, Clone)]
pub struct PhaseTimer {
    acc: BTreeMap<&'static str, u128>,
    counts: BTreeMap<&'static str, u64>,
}

impl PhaseTimer {
    pub fn new() -> Self {
        Self::default()
    }

    /// Time one closure under `phase`.
    pub fn time<T>(&mut self, phase: &'static str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.add(phase, t0.elapsed().as_nanos());
        out
    }

    pub fn add(&mut self, phase: &'static str, nanos: u128) {
        *self.acc.entry(phase).or_insert(0) += nanos;
        *self.counts.entry(phase).or_insert(0) += 1;
    }

    /// Merge another timer (e.g. from a worker rank) into this one.
    pub fn merge(&mut self, other: &PhaseTimer) {
        for (k, v) in &other.acc {
            *self.acc.entry(k).or_insert(0) += v;
        }
        for (k, v) in &other.counts {
            *self.counts.entry(k).or_insert(0) += v;
        }
    }

    /// Keep the elementwise max per phase (the critical-path view across
    /// ranks: total time is governed by the slowest rank).
    pub fn merge_max(&mut self, other: &PhaseTimer) {
        for (k, v) in &other.acc {
            let e = self.acc.entry(k).or_insert(0);
            *e = (*e).max(*v);
        }
        for (k, v) in &other.counts {
            let e = self.counts.entry(k).or_insert(0);
            *e = (*e).max(*v);
        }
    }

    pub fn nanos(&self, phase: &str) -> u128 {
        self.acc.get(phase).copied().unwrap_or(0)
    }

    pub fn seconds(&self, phase: &str) -> f64 {
        self.nanos(phase) as f64 * 1e-9
    }

    pub fn total_seconds(&self) -> f64 {
        self.acc.values().sum::<u128>() as f64 * 1e-9
    }

    pub fn phases(&self) -> impl Iterator<Item = (&'static str, f64)> + '_ {
        self.acc.iter().map(|(k, v)| (*k, *v as f64 * 1e-9))
    }

    pub fn report(&self) -> String {
        let mut out = String::new();
        for (k, v) in &self.acc {
            let n = self.counts.get(k).copied().unwrap_or(0);
            out.push_str(&format!(
                "{k:<14} {:>10.3} ms  ({n} calls)\n",
                *v as f64 * 1e-6
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_and_counts() {
        let mut t = PhaseTimer::new();
        t.add("delivery", 1000);
        t.add("delivery", 500);
        t.add("dynamics", 2000);
        assert_eq!(t.nanos("delivery"), 1500);
        assert_eq!(t.nanos("dynamics"), 2000);
        assert_eq!(t.nanos("missing"), 0);
        assert!(t.report().contains("delivery"));
    }

    #[test]
    fn time_closure_returns_value() {
        let mut t = PhaseTimer::new();
        let v = t.time("x", || 41 + 1);
        assert_eq!(v, 42);
        assert!(t.nanos("x") > 0);
    }

    #[test]
    fn merge_and_merge_max() {
        let mut a = PhaseTimer::new();
        a.add("p", 100);
        let mut b = PhaseTimer::new();
        b.add("p", 300);
        b.add("q", 50);
        let mut sum = a.clone();
        sum.merge(&b);
        assert_eq!(sum.nanos("p"), 400);
        assert_eq!(sum.nanos("q"), 50);
        a.merge_max(&b);
        assert_eq!(a.nanos("p"), 300);
    }
}
