//! Inter-rank communication (paper §III.C).
//!
//! The paper runs MPI ranks over Fugaku's Tofu-D; here a rank is either
//! an OS thread wired by in-memory channels ([`local::LocalComm`]) or an
//! OS **process** wired by TCP sockets ([`tcp::TcpComm`]) — both behind
//! the same interface an MPI backend would implement
//! ([`Communicator`]). What the algorithm exchanges — spiking
//! pre-synaptic gids, once per min-delay window — and what overlaps
//! what is identical; only the transport differs. On the wire the
//! payload is the [`bsb`] packed format (varint delta coding plus an
//! embedded window counter), which makes the codec a trust boundary:
//! every decode is fallible and every exchange returns a [`CommError`]
//! instead of panicking when a peer misbehaves. [`netmodel`] carries
//! Tofu-D constants to project measured message volumes onto
//! Fugaku-scale communication times.

pub mod bsb;
pub mod hier;
pub mod local;
pub mod netmodel;
pub mod tcp;

pub use hier::{CommGroups, HierarchicalComm};
pub use local::LocalCluster;
pub use netmodel::{frames_per_window, TofuModel};
pub use tcp::TcpComm;

use std::fmt;

use crate::Gid;

/// Sanity bound on any single length-prefixed payload frame. A frame
/// announcing more is treated as stream corruption by the transports
/// and as an over-merge by the hierarchical relay — 64 MiB of packed
/// varint spikes is far beyond any window this simulator produces.
pub const MAX_FRAME_BYTES: usize = 64 << 20;

/// One spike in flight: source neuron and emission step.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SpikeMsg {
    pub gid: Gid,
    pub step: u32,
}

/// Payload of one window exchange.
pub type SpikePacket = Vec<SpikeMsg>;

/// What one rank contributes to a window exchange.
///
/// `Broadcast` is the paper's baseline allgather: the same packet goes
/// to every peer, and each receiver drops the gids its sub-graph does
/// not consume. `Routed` is the interest-routed form: one packet per
/// destination rank (own slot ignored), pre-filtered to that peer's
/// subscription so unconsumed spikes never touch the wire. Both forms
/// deliver bit-identical spike streams — routing only removes traffic
/// the receiver would have discarded.
#[derive(Clone, Debug)]
pub enum Outbound {
    Broadcast(SpikePacket),
    Routed(Vec<SpikePacket>),
}

impl Outbound {
    /// The packet destined for peer `d` (shared packet if broadcast).
    pub fn packet_for(&self, d: usize) -> &[SpikeMsg] {
        match self {
            Outbound::Broadcast(p) => p,
            Outbound::Routed(per) => &per[d],
        }
    }
}

/// Send-side interest router: which destination ranks subscribe to
/// which of this rank's source gids.
///
/// Built from the per-destination subscription lists shipped in the
/// build-time collective ([`Communicator::alltoall`]). Destinations are
/// kept as a multi-word bitmask per gid, so routing a packet is one
/// binary search per spike plus a bit scan — independent of rank count
/// for sparse interest.
#[derive(Clone, Debug)]
pub struct RoutingTable {
    size: usize,
    words: usize,
    /// Sorted union of every gid at least one destination subscribes to.
    gids: Vec<Gid>,
    /// `gids.len() * words` mask words; bit `d` ⇒ rank `d` wants the gid.
    masks: Vec<u64>,
}

impl RoutingTable {
    /// `wanted[d]` is the sorted gid list destination `d` subscribed to
    /// (own rank's slot empty). Lists need not be disjoint.
    pub fn new(wanted: &[Vec<Gid>]) -> RoutingTable {
        let size = wanted.len();
        let words = size.div_ceil(64).max(1);
        let mut gids: Vec<Gid> =
            wanted.iter().flatten().copied().collect();
        gids.sort_unstable();
        gids.dedup();
        let mut masks = vec![0u64; gids.len() * words];
        for (d, list) in wanted.iter().enumerate() {
            for g in list {
                let i = gids.binary_search(g).expect("gid in union");
                masks[i * words + d / 64] |= 1u64 << (d % 64);
            }
        }
        RoutingTable { size, words, gids, masks }
    }

    /// Number of ranks the table routes to.
    pub fn size(&self) -> usize {
        self.size
    }

    /// Gids at least one destination subscribes to.
    pub fn n_subscribed(&self) -> usize {
        self.gids.len()
    }

    /// Split an outbox into per-destination packets, preserving the
    /// outbox order within each packet (the receive-side delivery order
    /// is therefore identical to broadcast-then-drop). Spikes no
    /// destination wants are dropped here instead of at every receiver.
    pub fn route(&self, outbox: &[SpikeMsg]) -> Vec<SpikePacket> {
        let mut per: Vec<SpikePacket> = vec![Vec::new(); self.size];
        for &m in outbox {
            let Ok(i) = self.gids.binary_search(&m.gid) else {
                continue;
            };
            for w in 0..self.words {
                let mut bits = self.masks[i * self.words + w];
                while bits != 0 {
                    let d = w * 64 + bits.trailing_zeros() as usize;
                    per[d].push(m);
                    bits &= bits - 1;
                }
            }
        }
        per
    }
}

/// A failed window exchange. Recoverable at the session layer (the
/// rank loop surfaces it as an error response instead of poisoning the
/// process) — malformed or misaligned wire traffic must never panic.
#[derive(Debug)]
pub enum CommError {
    /// The peer's payload failed to decode (truncated / bit-flipped /
    /// adversarial bytes).
    Codec(bsb::CodecError),
    /// The embedded window counter disagrees with this rank's window
    /// position — a stale or reordered packet that must not be consumed.
    WindowMismatch { got: u64, want: u64 },
    /// A peer hung up (its channel closed / its process died)
    /// mid-simulation.
    PeerLost { peer: u16, window: u64 },
    /// A length-prefixed frame announces a size beyond the sanity bound.
    FrameTooLarge { bytes: usize, limit: usize },
    /// The peer sent a well-formed frame of the wrong kind for the
    /// protocol position (e.g. a subscription blob where a spike frame
    /// was due).
    Protocol(&'static str),
    /// The dedicated communication thread is gone (overlap mode).
    Shutdown,
    /// Transport-level I/O failure.
    Io(std::io::Error),
}

impl fmt::Display for CommError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CommError::Codec(e) => write!(f, "malformed spike frame: {e}"),
            CommError::WindowMismatch { got, want } => write!(
                f,
                "window misalignment: peer sent window {got}, \
                 expected {want}"
            ),
            CommError::PeerLost { peer, window } => {
                write!(f, "lost peer rank {peer} during window {window}")
            }
            CommError::FrameTooLarge { bytes, limit } => write!(
                f,
                "frame of {bytes} bytes exceeds the {limit}-byte bound"
            ),
            CommError::Protocol(what) => {
                write!(f, "protocol violation: {what}")
            }
            CommError::Shutdown => {
                write!(f, "communication thread terminated")
            }
            CommError::Io(e) => write!(f, "transport I/O error: {e}"),
        }
    }
}

impl std::error::Error for CommError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            CommError::Codec(e) => Some(e),
            CommError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<bsb::CodecError> for CommError {
    fn from(e: bsb::CodecError) -> CommError {
        CommError::Codec(e)
    }
}

impl From<std::io::Error> for CommError {
    fn from(e: std::io::Error) -> CommError {
        CommError::Io(e)
    }
}

/// MPI-like collective interface for one rank. `Send` so each rank's
/// endpoint can live on its own thread (or be handed to a dedicated
/// communication thread, paper §III.C.2).
pub trait Communicator: Send {
    fn rank(&self) -> u16;
    fn size(&self) -> usize;

    /// One window exchange: contribute this rank's outbound spikes
    /// (broadcast or per-destination routed), receive every peer's
    /// contribution for this rank, concatenated in source-rank order.
    /// Blocking; one call per rank per window, in window order, and
    /// every rank of a window must agree on the [`Outbound`] variant.
    /// Window misalignment, peer loss and malformed wire input surface
    /// as [`CommError`]s — an endpoint that has returned an error must
    /// not be reused.
    fn exchange_outbound(
        &mut self,
        out: Outbound,
    ) -> Result<SpikePacket, CommError>;

    /// Allgather-style spike broadcast — the baseline ablation path:
    /// every peer gets the full packet.
    fn exchange(
        &mut self,
        local: SpikePacket,
    ) -> Result<SpikePacket, CommError> {
        self.exchange_outbound(Outbound::Broadcast(local))
    }

    /// One-shot build-time collective: deliver `out[d]` to rank `d`
    /// (own slot ignored) and return the blob each rank addressed to
    /// this one, indexed by source rank (own slot empty). Used to ship
    /// the interest subscription sets before the first window; does not
    /// advance the window counter and is not counted in the per-window
    /// byte volumes.
    fn alltoall(
        &mut self,
        out: Vec<Vec<u8>>,
    ) -> Result<Vec<Vec<u8>>, CommError>;

    /// Total spike payload bytes this rank has sent so far (for the
    /// network cost model).
    fn bytes_sent(&self) -> u64;

    /// Total spike payload bytes this rank has received so far.
    fn bytes_received(&self) -> u64;

    /// Number of exchanges performed.
    fn exchanges(&self) -> u64;

    /// Point-to-point: deliver one opaque payload frame to `peer`.
    /// The hierarchical relay protocol ([`hier::HierarchicalComm`])
    /// moves its gather/merge/scatter rounds through this; transports
    /// without point-to-point frames refuse with
    /// [`CommError::Protocol`].
    fn send_frame(
        &mut self,
        peer: usize,
        payload: &[u8],
    ) -> Result<(), CommError> {
        let _ = (peer, payload);
        Err(CommError::Protocol(
            "transport has no point-to-point frames",
        ))
    }

    /// Point-to-point: block for the next payload frame from `peer`.
    fn recv_frame(&mut self, peer: usize) -> Result<Vec<u8>, CommError> {
        let _ = peer;
        Err(CommError::Protocol(
            "transport has no point-to-point frames",
        ))
    }

    /// Payload frames this rank has put on the wire for spike
    /// exchanges (the frames-per-window accounting the hierarchical
    /// layer exists to shrink). Mesh transports emit one frame per
    /// peer per window; relay transports override with their true
    /// count.
    fn frames_sent(&self) -> u64 {
        self.exchanges() * (self.size() as u64).saturating_sub(1)
    }
}

/// Payload size of one spike on the wire (gid + step, packed).
pub const SPIKE_WIRE_BYTES: u64 = 8;

/// A no-op communicator for single-rank runs.
pub struct SoloComm {
    count: u64,
}

impl SoloComm {
    pub fn new() -> Self {
        SoloComm { count: 0 }
    }
}

impl Default for SoloComm {
    fn default() -> Self {
        Self::new()
    }
}

impl Communicator for SoloComm {
    fn rank(&self) -> u16 {
        0
    }
    fn size(&self) -> usize {
        1
    }
    fn exchange_outbound(
        &mut self,
        _out: Outbound,
    ) -> Result<SpikePacket, CommError> {
        self.count += 1;
        Ok(Vec::new())
    }
    fn alltoall(
        &mut self,
        out: Vec<Vec<u8>>,
    ) -> Result<Vec<Vec<u8>>, CommError> {
        Ok(vec![Vec::new(); out.len().max(1)])
    }
    fn bytes_sent(&self) -> u64 {
        0
    }
    fn bytes_received(&self) -> u64 {
        0
    }
    fn exchanges(&self) -> u64 {
        self.count
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn solo_comm_echoes_nothing() {
        let mut c = SoloComm::new();
        assert_eq!(c.size(), 1);
        let got = c.exchange(vec![SpikeMsg { gid: 1, step: 2 }]).unwrap();
        assert!(got.is_empty());
        assert_eq!(c.exchanges(), 1);
        assert_eq!(c.bytes_received(), 0);
    }

    fn msg(gid: Gid, step: u32) -> SpikeMsg {
        SpikeMsg { gid, step }
    }

    #[test]
    fn routing_table_splits_by_subscription_preserving_order() {
        // rank 1's view of a 3-rank cluster: rank 0 wants {3, 5},
        // rank 2 wants {5, 9}; nobody wants 7
        let rt = RoutingTable::new(&[
            vec![3, 5],
            vec![],
            vec![5, 9],
        ]);
        assert_eq!(rt.size(), 3);
        assert_eq!(rt.n_subscribed(), 3);
        let out =
            vec![msg(5, 10), msg(7, 10), msg(3, 11), msg(5, 12)];
        let per = rt.route(&out);
        assert_eq!(per[0], vec![msg(5, 10), msg(3, 11), msg(5, 12)]);
        assert!(per[1].is_empty());
        assert_eq!(per[2], vec![msg(5, 10), msg(5, 12)]);
    }

    #[test]
    fn routing_table_equals_broadcast_then_drop() {
        // property: for random interest sets, routing to d then
        // concatenating equals broadcasting and dropping non-subscribed
        // gids at d — the bit-identity argument in miniature
        crate::util::proptest_lite::property(
            "route == filter",
            200,
            |g| {
                let ranks = g.usize(1..70); // spans the 64-bit word edge
                let wanted: Vec<Vec<Gid>> = (0..ranks)
                    .map(|_| {
                        let n = g.usize(0..20);
                        let mut v: Vec<Gid> =
                            (0..n).map(|_| g.u32(0..50)).collect();
                        v.sort_unstable();
                        v.dedup();
                        v
                    })
                    .collect();
                let rt = RoutingTable::new(&wanted);
                let outbox: Vec<SpikeMsg> = (0..g.usize(0..60))
                    .map(|_| msg(g.u32(0..50), g.u32(0..5)))
                    .collect();
                let per = rt.route(&outbox);
                for (d, want_list) in wanted.iter().enumerate() {
                    let want: Vec<SpikeMsg> = outbox
                        .iter()
                        .copied()
                        .filter(|m| {
                            want_list.binary_search(&m.gid).is_ok()
                        })
                        .collect();
                    if per[d] != want {
                        return Err(format!(
                            "dest {d}: {} routed, {} expected",
                            per[d].len(),
                            want.len()
                        ));
                    }
                }
                Ok(())
            },
        );
    }
}
