//! Inter-rank communication (paper §III.C).
//!
//! The paper runs MPI ranks over Fugaku's Tofu-D; here ranks are OS
//! threads wired by in-memory channels behind the same interface an MPI
//! backend would implement ([`Communicator`]). What the algorithm
//! exchanges — spiking pre-synaptic gids, once per min-delay window —
//! and what overlaps what is identical; only the transport differs.
//! [`netmodel`] carries Tofu-D constants to project measured message
//! volumes onto Fugaku-scale communication times.

pub mod bsb;
pub mod local;
pub mod netmodel;

pub use local::LocalCluster;
pub use netmodel::TofuModel;

use crate::Gid;

/// One spike in flight: source neuron and emission step.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SpikeMsg {
    pub gid: Gid,
    pub step: u32,
}

/// Payload of one window exchange.
pub type SpikePacket = Vec<SpikeMsg>;

/// MPI-like collective interface for one rank. `Send` so each rank's
/// endpoint can live on its own thread (or be handed to a dedicated
/// communication thread, paper §III.C.2).
pub trait Communicator: Send {
    fn rank(&self) -> u16;
    fn size(&self) -> usize;

    /// Allgather-style spike broadcast: contribute this rank's spikes for
    /// the current window, receive every other rank's. Blocking; one call
    /// per rank per window, in window order.
    fn exchange(&mut self, local: SpikePacket) -> SpikePacket;

    /// Total payload bytes this rank has sent so far (for the network
    /// cost model).
    fn bytes_sent(&self) -> u64;

    /// Number of exchanges performed.
    fn exchanges(&self) -> u64;
}

/// Payload size of one spike on the wire (gid + step, packed).
pub const SPIKE_WIRE_BYTES: u64 = 8;

/// A no-op communicator for single-rank runs.
pub struct SoloComm {
    count: u64,
}

impl SoloComm {
    pub fn new() -> Self {
        SoloComm { count: 0 }
    }
}

impl Default for SoloComm {
    fn default() -> Self {
        Self::new()
    }
}

impl Communicator for SoloComm {
    fn rank(&self) -> u16 {
        0
    }
    fn size(&self) -> usize {
        1
    }
    fn exchange(&mut self, _local: SpikePacket) -> SpikePacket {
        self.count += 1;
        Vec::new()
    }
    fn bytes_sent(&self) -> u64 {
        0
    }
    fn exchanges(&self) -> u64 {
        self.count
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn solo_comm_echoes_nothing() {
        let mut c = SoloComm::new();
        assert_eq!(c.size(), 1);
        let got = c.exchange(vec![SpikeMsg { gid: 1, step: 2 }]);
        assert!(got.is_empty());
        assert_eq!(c.exchanges(), 1);
    }
}
