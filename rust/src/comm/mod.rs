//! Inter-rank communication (paper §III.C).
//!
//! The paper runs MPI ranks over Fugaku's Tofu-D; here a rank is either
//! an OS thread wired by in-memory channels ([`local::LocalComm`]) or an
//! OS **process** wired by TCP sockets ([`tcp::TcpComm`]) — both behind
//! the same interface an MPI backend would implement
//! ([`Communicator`]). What the algorithm exchanges — spiking
//! pre-synaptic gids, once per min-delay window — and what overlaps
//! what is identical; only the transport differs. On the wire the
//! payload is the [`bsb`] packed format (varint delta coding plus an
//! embedded window counter), which makes the codec a trust boundary:
//! every decode is fallible and every exchange returns a [`CommError`]
//! instead of panicking when a peer misbehaves. [`netmodel`] carries
//! Tofu-D constants to project measured message volumes onto
//! Fugaku-scale communication times.

pub mod bsb;
pub mod local;
pub mod netmodel;
pub mod tcp;

pub use local::LocalCluster;
pub use netmodel::TofuModel;
pub use tcp::TcpComm;

use std::fmt;

use crate::Gid;

/// One spike in flight: source neuron and emission step.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SpikeMsg {
    pub gid: Gid,
    pub step: u32,
}

/// Payload of one window exchange.
pub type SpikePacket = Vec<SpikeMsg>;

/// A failed window exchange. Recoverable at the session layer (the
/// rank loop surfaces it as an error response instead of poisoning the
/// process) — malformed or misaligned wire traffic must never panic.
#[derive(Debug)]
pub enum CommError {
    /// The peer's payload failed to decode (truncated / bit-flipped /
    /// adversarial bytes).
    Codec(bsb::CodecError),
    /// The embedded window counter disagrees with this rank's window
    /// position — a stale or reordered packet that must not be consumed.
    WindowMismatch { got: u64, want: u64 },
    /// A peer hung up (its channel closed / its process died)
    /// mid-simulation.
    PeerLost { peer: u16, window: u64 },
    /// A length-prefixed frame announces a size beyond the sanity bound.
    FrameTooLarge { bytes: usize, limit: usize },
    /// The dedicated communication thread is gone (overlap mode).
    Shutdown,
    /// Transport-level I/O failure.
    Io(std::io::Error),
}

impl fmt::Display for CommError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CommError::Codec(e) => write!(f, "malformed spike frame: {e}"),
            CommError::WindowMismatch { got, want } => write!(
                f,
                "window misalignment: peer sent window {got}, \
                 expected {want}"
            ),
            CommError::PeerLost { peer, window } => {
                write!(f, "lost peer rank {peer} during window {window}")
            }
            CommError::FrameTooLarge { bytes, limit } => write!(
                f,
                "frame of {bytes} bytes exceeds the {limit}-byte bound"
            ),
            CommError::Shutdown => {
                write!(f, "communication thread terminated")
            }
            CommError::Io(e) => write!(f, "transport I/O error: {e}"),
        }
    }
}

impl std::error::Error for CommError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            CommError::Codec(e) => Some(e),
            CommError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<bsb::CodecError> for CommError {
    fn from(e: bsb::CodecError) -> CommError {
        CommError::Codec(e)
    }
}

impl From<std::io::Error> for CommError {
    fn from(e: std::io::Error) -> CommError {
        CommError::Io(e)
    }
}

/// MPI-like collective interface for one rank. `Send` so each rank's
/// endpoint can live on its own thread (or be handed to a dedicated
/// communication thread, paper §III.C.2).
pub trait Communicator: Send {
    fn rank(&self) -> u16;
    fn size(&self) -> usize;

    /// Allgather-style spike broadcast: contribute this rank's spikes for
    /// the current window, receive every other rank's. Blocking; one call
    /// per rank per window, in window order. Window misalignment, peer
    /// loss and malformed wire input surface as [`CommError`]s — an
    /// endpoint that has returned an error must not be reused.
    fn exchange(
        &mut self,
        local: SpikePacket,
    ) -> Result<SpikePacket, CommError>;

    /// Total payload bytes this rank has sent so far (for the network
    /// cost model).
    fn bytes_sent(&self) -> u64;

    /// Number of exchanges performed.
    fn exchanges(&self) -> u64;
}

/// Payload size of one spike on the wire (gid + step, packed).
pub const SPIKE_WIRE_BYTES: u64 = 8;

/// A no-op communicator for single-rank runs.
pub struct SoloComm {
    count: u64,
}

impl SoloComm {
    pub fn new() -> Self {
        SoloComm { count: 0 }
    }
}

impl Default for SoloComm {
    fn default() -> Self {
        Self::new()
    }
}

impl Communicator for SoloComm {
    fn rank(&self) -> u16 {
        0
    }
    fn size(&self) -> usize {
        1
    }
    fn exchange(
        &mut self,
        _local: SpikePacket,
    ) -> Result<SpikePacket, CommError> {
        self.count += 1;
        Ok(Vec::new())
    }
    fn bytes_sent(&self) -> u64 {
        0
    }
    fn exchanges(&self) -> u64 {
        self.count
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn solo_comm_echoes_nothing() {
        let mut c = SoloComm::new();
        assert_eq!(c.size(), 1);
        let got = c.exchange(vec![SpikeMsg { gid: 1, step: 2 }]).unwrap();
        assert!(got.is_empty());
        assert_eq!(c.exchanges(), 1);
    }
}
