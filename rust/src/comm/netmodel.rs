//! Tofu-D network cost model (paper §I.E: 6.8 GB/s link bandwidth,
//! 40.8 GB/s injection per node, ~1 µs MPI latency on Fugaku).
//!
//! Our ranks exchange through memory, so wall-clock communication time on
//! this testbed says nothing about Fugaku. Instead the engines record
//! *message volumes*, and this model projects what the paper's spike
//! broadcast would cost at scale — the quantity behind the overlap
//! ablation's "how much communication can the window hide" analysis.

/// Network constants.
#[derive(Clone, Copy, Debug)]
pub struct TofuModel {
    pub link_bw_gbs: f64,
    pub injection_bw_gbs: f64,
    pub latency_us: f64,
    /// MPI ranks per node (paper: 4 CMGs per A64FX).
    pub ranks_per_node: f64,
}

impl Default for TofuModel {
    fn default() -> Self {
        TofuModel {
            link_bw_gbs: 6.8,
            injection_bw_gbs: 40.8,
            latency_us: 1.0,
            ranks_per_node: 4.0,
        }
    }
}

impl TofuModel {
    /// Estimated time (seconds) of one allgather-style spike broadcast of
    /// `bytes_per_rank` payload among `ranks` ranks: a recursive-doubling
    /// allgatherv moves (R-1)/R of the total volume through each rank's
    /// injection port over log2(R) latency-bound stages.
    pub fn allgather_seconds(&self, ranks: usize, bytes_per_rank: f64) -> f64 {
        if ranks <= 1 {
            return 0.0;
        }
        let r = ranks as f64;
        let stages = r.log2().ceil();
        let recv_bytes = bytes_per_rank * (r - 1.0);
        // each node injects for ranks_per_node ranks concurrently
        let eff_bw =
            self.injection_bw_gbs * 1e9 / self.ranks_per_node;
        stages * self.latency_us * 1e-6 + recv_bytes / eff_bw
    }

    /// Time to stream `bytes` over one Tofu link (the per-hop bound).
    pub fn link_seconds(&self, bytes: f64) -> f64 {
        bytes / (self.link_bw_gbs * 1e9)
    }

    /// Estimated time (seconds) of one interest-routed exchange where
    /// this rank sends `sent_bytes` total (across its targeted peer
    /// frames) and receives `recv_bytes`. Routed exchange is pairwise,
    /// not staged: one latency to every peer it actually talks to
    /// (bounded by the allgather's log2(R) stages, since sends launch
    /// concurrently), and the larger of the injection-in/out volumes
    /// through the node port. With every peer subscribed to everything
    /// this degenerates to [`Self::allgather_seconds`]'s bandwidth
    /// term.
    pub fn routed_exchange_seconds(
        &self,
        ranks: usize,
        sent_bytes: f64,
        recv_bytes: f64,
    ) -> f64 {
        if ranks <= 1 {
            return 0.0;
        }
        let stages = (ranks as f64).log2().ceil();
        let eff_bw =
            self.injection_bw_gbs * 1e9 / self.ranks_per_node;
        stages * self.latency_us * 1e-6
            + sent_bytes.max(recv_bytes) / eff_bw
    }

    /// Project a full routed simulation's communication time:
    /// `windows` exchanges at the run's **average** per-window sent /
    /// received volumes of its busiest rank.
    pub fn total_routed_seconds(
        &self,
        ranks: usize,
        windows: u64,
        avg_sent_bytes: f64,
        avg_recv_bytes: f64,
    ) -> f64 {
        windows as f64
            * self.routed_exchange_seconds(
                ranks,
                avg_sent_bytes,
                avg_recv_bytes,
            )
    }

    /// Estimated time (seconds) of one **hierarchical** exchange
    /// (gather → relay↔relay merged frames → scatter). Three
    /// serialized rounds on the critical path:
    ///
    /// * gather/scatter are intra-node hops (a host group maps to one
    ///   node): one latency each, with the relay's injection port
    ///   carrying `group_size - 1` member frames of `gather_bytes`;
    /// * the relay round is a routed exchange among `n_groups` relays
    ///   shipping one merged multi-source frame of `merged_bytes` per
    ///   destination group — `merged_bytes` is roughly `group_size`×
    ///   a member frame, but the latency floor drops from
    ///   `log2(ranks)` to `2 + log2(n_groups)` stages.
    pub fn hierarchical_exchange_seconds(
        &self,
        n_groups: usize,
        group_size: usize,
        gather_bytes: f64,
        merged_bytes: f64,
    ) -> f64 {
        if n_groups <= 1 && group_size <= 1 {
            return 0.0;
        }
        let inj = self.injection_bw_gbs * 1e9;
        let intra = if group_size > 1 {
            2.0 * (self.latency_us * 1e-6
                + (group_size as f64 - 1.0) * gather_bytes / inj)
        } else {
            0.0
        };
        intra
            + self.routed_exchange_seconds(
                n_groups,
                (n_groups as f64 - 1.0) * merged_bytes,
                (n_groups as f64 - 1.0) * merged_bytes,
            )
    }

    /// Project a full simulation's communication time: `windows` exchanges
    /// of `avg_bytes_per_rank` each.
    pub fn total_comm_seconds(
        &self,
        ranks: usize,
        windows: u64,
        avg_bytes_per_rank: f64,
    ) -> f64 {
        windows as f64 * self.allgather_seconds(ranks, avg_bytes_per_rank)
    }
}

/// Point-to-point frames one window exchange puts on the wire:
/// `(flat, hierarchical)`. The flat routed mesh sends `R·(R-1)`
/// frames; the two-level protocol sends one gather and one scatter
/// frame per non-relay member plus the `G·(G-1)` merged relay frames.
/// (Intra-group frames that ride an in-process fast path still count
/// — this is the transport-agnostic message count.)
pub fn frames_per_window(ranks: usize, n_groups: usize) -> (u64, u64) {
    if ranks <= 1 {
        return (0, 0);
    }
    let r = ranks as u64;
    let g = n_groups.clamp(1, ranks) as u64;
    (r * (r - 1), 2 * (r - g) + g * (g - 1))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_rank_costs_nothing() {
        let m = TofuModel::default();
        assert_eq!(m.allgather_seconds(1, 1e6), 0.0);
    }

    #[test]
    fn latency_dominated_small_messages() {
        let m = TofuModel::default();
        // 100 bytes among 1024 ranks: ~10 stages of 1 us each
        let t = m.allgather_seconds(1024, 100.0);
        assert!(t > 9e-6 && t < 30e-6, "{t}");
    }

    #[test]
    fn bandwidth_dominated_large_messages() {
        let m = TofuModel::default();
        // 10 MB among 4 ranks: >= 30 MB received at ~10.2 GB/s effective
        let t = m.allgather_seconds(4, 10e6);
        assert!(t > 2.5e-3, "{t}");
    }

    #[test]
    fn monotone_in_ranks_and_bytes() {
        let m = TofuModel::default();
        assert!(
            m.allgather_seconds(16, 1e4) < m.allgather_seconds(256, 1e4)
        );
        assert!(
            m.allgather_seconds(16, 1e4) < m.allgather_seconds(16, 1e6)
        );
    }

    #[test]
    fn routed_never_beats_latency_and_tracks_volume() {
        let m = TofuModel::default();
        assert_eq!(m.routed_exchange_seconds(1, 1e6, 1e6), 0.0);
        // same volume as a broadcast → same bandwidth cost shape
        let bcast = m.allgather_seconds(64, 1e6);
        let routed_full =
            m.routed_exchange_seconds(64, 63e6, 63e6);
        assert!((routed_full - bcast).abs() < 1e-9, "{routed_full}");
        // a 10% subscription share cuts the bandwidth term 10×
        let routed = m.routed_exchange_seconds(64, 6.3e6, 6.3e6);
        assert!(routed < bcast, "{routed} !< {bcast}");
        // but the per-exchange latency floor stays
        let floor = 6.0 * 1e-6;
        assert!(
            m.routed_exchange_seconds(64, 1.0, 1.0) >= floor
        );
    }

    #[test]
    fn merged_frames_shrink_the_mesh() {
        assert_eq!(frames_per_window(4, 2), (12, 6));
        assert_eq!(frames_per_window(8, 4), (56, 20));
        // degenerate shapes: 1-rank groups and a single pair change
        // nothing — the win needs ranks > groups > 1
        assert_eq!(frames_per_window(2, 2), (2, 2));
        assert_eq!(frames_per_window(1, 1), (0, 0));
    }

    #[test]
    fn hierarchical_cuts_the_latency_floor() {
        let m = TofuModel::default();
        assert_eq!(
            m.hierarchical_exchange_seconds(1, 1, 0.0, 0.0),
            0.0
        );
        // tiny packets, 64 ranks: the flat mesh pays ceil(log2 64) = 6
        // latency stages; 4 groups of 16 pay two intra-node hops plus
        // ceil(log2 4) = 2 relay stages even though each merged frame
        // is 16× a member frame
        let flat = m.routed_exchange_seconds(64, 64.0, 64.0);
        let hier =
            m.hierarchical_exchange_seconds(4, 16, 64.0, 1024.0);
        assert!(hier < flat, "{hier} !< {flat}");
        // bandwidth-bound regime: merged frames move the same volume,
        // so hierarchy must not promise a >2x win there
        let flat_bw = m.routed_exchange_seconds(64, 64e6, 64e6);
        let hier_bw =
            m.hierarchical_exchange_seconds(4, 16, 1e6, 16e6);
        assert!(hier_bw > 0.4 * flat_bw, "{hier_bw} vs {flat_bw}");
    }

    #[test]
    fn total_scales_with_windows() {
        let m = TofuModel::default();
        let one = m.total_comm_seconds(8, 1, 1e5);
        let many = m.total_comm_seconds(8, 1000, 1e5);
        assert!((many / one - 1000.0).abs() < 1e-6);
    }
}
