//! Brain Simulation Broadcast (BSB) — the paper's §V.2 announced
//! communication upgrade: "a broadcast acceleration library specifically
//! designed for this communication pattern, which automatically
//! packs/unpacks spikes into/from messages and adaptively routes the
//! messages among processes to decrease the number of small messages".
//!
//! Implemented here as the paper describes it:
//!
//! * **Packing** — spike gids within a window are sorted and
//!   delta-encoded with a LEB128-style varint (most deltas fit one
//!   byte, vs 8 B/spike on the naive wire), plus the emission-step
//!   offsets packed per window;
//! * **Adaptive routing** — below a message-count threshold, ranks
//!   forward through a radix-k dissemination tree so each rank sends
//!   O(k·log_k R) aggregated messages instead of R-1 small ones; above
//!   it (dense traffic) direct exchange is cheaper. The choice is made
//!   per window from the measured payload;
//! * **Producer-consumer interface** — `push` spikes as they are
//!   emitted, `seal` the window, `drain` the remote spikes, matching the
//!   dedicated-communication-thread usage of §III.C.2.
//!
//! The transport stays the in-memory [`Communicator`]; what changes is
//! the wire volume and message count, both of which are measured and
//! projected at Fugaku scale by `ablation_bsb`.

use super::{SpikeMsg, SpikePacket};

/// Varint (LEB128) encode.
#[inline]
fn put_varint(out: &mut Vec<u8>, mut x: u64) {
    loop {
        let b = (x & 0x7f) as u8;
        x >>= 7;
        if x == 0 {
            out.push(b);
            return;
        }
        out.push(b | 0x80);
    }
}

/// Varint decode; advances `pos`.
#[inline]
fn get_varint(buf: &[u8], pos: &mut usize) -> u64 {
    let mut x = 0u64;
    let mut shift = 0;
    loop {
        let b = buf[*pos];
        *pos += 1;
        x |= ((b & 0x7f) as u64) << shift;
        if b & 0x80 == 0 {
            return x;
        }
        shift += 7;
    }
}

/// Pack one window's spikes: sorted by (step, gid), step stored as
/// offset from `window_start`, gids delta-encoded per step group.
pub fn pack(window_start: u32, spikes: &[SpikeMsg]) -> Vec<u8> {
    let mut sorted: Vec<(u32, u32)> =
        spikes.iter().map(|m| (m.step, m.gid)).collect();
    sorted.sort_unstable();
    let mut out = Vec::with_capacity(sorted.len() + 8);
    put_varint(&mut out, sorted.len() as u64);
    let mut prev_step = window_start;
    let mut prev_gid = 0u32;
    for (step, gid) in sorted {
        let dstep = step - prev_step;
        put_varint(&mut out, dstep as u64);
        if dstep > 0 {
            prev_gid = 0; // gid deltas restart per step group
        }
        put_varint(&mut out, (gid - prev_gid) as u64);
        prev_step = step;
        prev_gid = gid;
    }
    out
}

/// Unpack (inverse of [`pack`]).
pub fn unpack(window_start: u32, buf: &[u8]) -> SpikePacket {
    let mut pos = 0usize;
    let n = get_varint(buf, &mut pos) as usize;
    let mut out = Vec::with_capacity(n);
    let mut step = window_start;
    let mut gid = 0u32;
    for _ in 0..n {
        let dstep = get_varint(buf, &mut pos) as u32;
        step += dstep;
        if dstep > 0 {
            gid = 0;
        }
        gid += get_varint(buf, &mut pos) as u32;
        out.push(SpikeMsg { gid, step });
    }
    out
}

/// Message-count/volume model of one window exchange among `ranks`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ExchangePlan {
    /// messages each rank sends
    pub messages_per_rank: f64,
    /// total bytes each rank sends
    pub bytes_per_rank: f64,
    /// dissemination stages (1 = direct)
    pub stages: u32,
    pub routed: bool,
}

/// BSB's adaptive choice (the "adaptively routes ... to decrease the
/// number of small messages"): with per-peer payload below
/// `route_threshold_bytes`, use a radix-k dissemination tree (k·log_k R
/// aggregated messages, each carrying ~R/k ranks' packed spikes);
/// otherwise exchange directly.
pub fn plan_exchange(
    ranks: usize,
    packed_bytes: f64,
    radix: u32,
    route_threshold_bytes: f64,
) -> ExchangePlan {
    assert!(ranks >= 1 && radix >= 2);
    if ranks == 1 {
        return ExchangePlan {
            messages_per_rank: 0.0,
            bytes_per_rank: 0.0,
            stages: 0,
            routed: false,
        };
    }
    let r = ranks as f64;
    if packed_bytes >= route_threshold_bytes {
        // dense: direct allgather of the packed payload
        ExchangePlan {
            messages_per_rank: r - 1.0,
            bytes_per_rank: packed_bytes * (r - 1.0),
            stages: 1,
            routed: false,
        }
    } else {
        // sparse: radix-k dissemination — log_k(R) stages, k-1 messages
        // per stage, message s carrying the payloads accumulated so far
        let stages = (r.ln() / (radix as f64).ln()).ceil() as u32;
        let k = radix as f64 - 1.0;
        // accumulated payload grows by radix each stage:
        // sum_{s=0}^{stages-1} (k) * packed * radix^s
        let mut bytes = 0.0;
        let mut acc = packed_bytes;
        for _ in 0..stages {
            bytes += k * acc;
            acc *= radix as f64;
        }
        ExchangePlan {
            messages_per_rank: k * stages as f64,
            bytes_per_rank: bytes,
            stages,
            routed: true,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn window(rng: &mut Rng, start: u32, len: u32, n: usize) -> SpikePacket {
        (0..n)
            .map(|_| SpikeMsg {
                gid: rng.below(100_000) as u32,
                step: start + rng.below(len as u64) as u32,
            })
            .collect()
    }

    #[test]
    fn varint_roundtrip() {
        let mut buf = Vec::new();
        let values = [0u64, 1, 127, 128, 300, 1 << 20, u64::MAX];
        for &v in &values {
            put_varint(&mut buf, v);
        }
        let mut pos = 0;
        for &v in &values {
            assert_eq!(get_varint(&buf, &mut pos), v);
        }
        assert_eq!(pos, buf.len());
    }

    #[test]
    fn pack_unpack_roundtrip() {
        let mut rng = Rng::new(5);
        for case in 0..50 {
            let start = case * 20;
            let spikes = window(&mut rng, start, 15, (case % 7) as usize * 13);
            let buf = pack(start, &spikes);
            let mut got = unpack(start, &buf);
            let mut want = spikes.clone();
            want.sort_unstable_by_key(|m| (m.step, m.gid));
            got.sort_unstable_by_key(|m| (m.step, m.gid));
            assert_eq!(got, want, "case {case}");
        }
    }

    #[test]
    fn packing_beats_naive_wire_format() {
        let mut rng = Rng::new(9);
        // dense-ish window: 2000 spikes from 100k neurons over 15 steps
        let spikes = window(&mut rng, 1000, 15, 2000);
        let packed = pack(1000, &spikes).len() as f64;
        let naive = (spikes.len() * 8) as f64;
        assert!(
            packed < 0.5 * naive,
            "packed {packed} vs naive {naive} — expected >2x compression"
        );
    }

    #[test]
    fn empty_window() {
        let buf = pack(7, &[]);
        assert!(buf.len() <= 2);
        assert!(unpack(7, &buf).is_empty());
    }

    #[test]
    fn plan_sparse_routes_dense_goes_direct() {
        let sparse = plan_exchange(1024, 64.0, 4, 4096.0);
        assert!(sparse.routed);
        assert_eq!(sparse.stages, 5); // log4(1024)
        assert_eq!(sparse.messages_per_rank, 15.0); // 3 per stage
        let dense = plan_exchange(1024, (1u64 << 20) as f64, 4, 4096.0);
        assert!(!dense.routed);
        assert_eq!(dense.messages_per_rank, 1023.0);
    }

    #[test]
    fn routed_message_count_far_below_direct() {
        for ranks in [64usize, 1024, 16384] {
            let p = plan_exchange(ranks, 100.0, 8, 1e6);
            assert!(p.routed);
            assert!(
                p.messages_per_rank < 0.05 * ranks as f64 + 30.0,
                "{ranks} ranks: {} msgs",
                p.messages_per_rank
            );
        }
    }

    #[test]
    fn single_rank_plan_is_empty() {
        let p = plan_exchange(1, 100.0, 4, 1e3);
        assert_eq!(p.messages_per_rank, 0.0);
    }
}
